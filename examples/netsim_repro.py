"""Reproduce the paper's headline evaluation table in one run.

Prints, per workload family (Figs 7-13), RailS's gains against the
baselines, next to the claims in the paper's abstract:
  * sparse loads: BusBw +20%..78%, CCT -17%..78%
  * Mixtral iteration: -18%..40% (dense), >=40% (sparse)
  * skewed loads: lowest NIC-load MSE.

Two sparse variants are shown: ``gpu`` pins each hot expert's ingress to
one GPU (the paper's §VI-F sparse setup — large gaps), ``domain`` spreads
it across the domain (milder, lands in the abstract's 20-78% band).

    PYTHONPATH=src python examples/netsim_repro.py
"""

import numpy as np

from repro.core.traffic import (
    mixtral_trace_workload,
    receiver_skew_workload,
    sender_skew_workload,
    sparse_topk_workload,
    uniform_workload,
)
from repro.netsim import run_policy_suite

M, N = 8, 8
B = 32 * 2**20
CHUNK = 2 * 2**20
TOTAL = B * M * (M - 1) * N * N / 8


def stats(tm):
    res = run_policy_suite(tm, chunk_bytes=CHUNK)
    rails = res["rails"]
    others = [res[p] for p in ("ecmp", "minrtt", "plb", "reps")]
    return {
        "busbw_vs_ecmp": (rails.bus_bw / res["ecmp"].bus_bw - 1) * 100,
        "busbw_vs_best": (rails.bus_bw / max(o.bus_bw for o in others) - 1) * 100,
        # iteration time == makespan (the all-to-all barrier; paper Figs 12b/13b)
        "cct_vs_ecmp": (1 - rails.makespan / res["ecmp"].makespan) * 100,
        "cct_vs_best": (1 - rails.makespan / min(o.makespan for o in others)) * 100,
        "smse": rails.send_mse,
        "rmse": rails.recv_mse,
        "base_smse": max(o.send_mse for o in others),
        "base_rmse": max(o.recv_mse for o in others),
    }


def avg_stats(makers):
    rows = [stats(mk()) for mk in makers]
    return {k: float(np.mean([r[k] for r in rows])) for k in rows[0]}


def show(tag, s):
    print(
        f"{tag:28s} busbw +{s['busbw_vs_ecmp']:6.1f}% ecmp /+{s['busbw_vs_best']:6.1f}% best | "
        f"cct -{s['cct_vs_ecmp']:5.1f}% ecmp /-{s['cct_vs_best']:5.1f}% best | "
        f"MSE {s['smse']:.3f}/{s['rmse']:.3f} (baselines {s['base_smse']:.2f}/{s['base_rmse']:.2f})"
    )


def main() -> None:
    print("=== RailS vs baselines (paper Figs 7-13 reproduction; mean of 3 seeds) ===")
    show("uniform (Fig7a)", avg_stats(
        [lambda s=s: uniform_workload(M, N, bytes_per_pair=B) for s in range(1)]))
    for sp in (0.6, 0.4, 0.2, 0.0):
        show(f"sparse-{sp:g} gpu (Fig7b-e)", avg_stats(
            [lambda s=s, sp=sp: sparse_topk_workload(M, N, sparsity=sp, bytes_per_pair=B, seed=s)
             for s in (1, 2, 3)]))
    for sp in (0.6, 0.2):
        show(f"sparse-{sp:g} domain", avg_stats(
            [lambda s=s, sp=sp: sparse_topk_workload(M, N, sparsity=sp, bytes_per_pair=B,
                                                     seed=s, concentrate="domain")
             for s in (1, 2, 3)]))
    show("sender-skew (Fig10)", avg_stats(
        [lambda s=s: sender_skew_workload(M, N, total_bytes=TOTAL, seed=s) for s in (1, 2, 3)]))
    show("receiver-skew (Fig11)", avg_stats(
        [lambda s=s: receiver_skew_workload(M, N, total_bytes=TOTAL, seed=s) for s in (1, 2, 3)]))
    for mode in ("dense", "sparse"):
        for phase in ("start", "stable"):
            show(f"mixtral-{mode}-{phase} (Fig{12 if mode=='dense' else 13})", avg_stats(
                [lambda s=s, m=mode, ph=phase: mixtral_trace_workload(M, N, phase=ph, mode=m, seed=s)
                 for s in (2, 3, 4)]))


if __name__ == "__main__":
    main()
