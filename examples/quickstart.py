"""Quickstart: the RailS pipeline end-to-end in 60 seconds (CPU).

1. Build a skewed MoE traffic matrix (the paper's hard case).
2. Split -> LPT-schedule -> spray, all per-sender (Theorem 3 locality).
3. Verify Theorem 4's bound and the Theorem 2/3 optimum.
4. Run the netsim against all five policies.

    PYTHONPATH=src python examples/quickstart.py
"""

import numpy as np

from repro.core import (
    build_all_plans,
    build_rail_schedule,
    closed_form_opt,
    plan_quality,
    theorem2_optimal_time,
    theorem4_mse_bound,
)
from repro.core.traffic import receiver_skew_workload
from repro.netsim import run_policy_suite


def main() -> None:
    m, n = 8, 8
    total = 8 * 2**30  # 8 GiB of all-to-all payload
    tm = receiver_skew_workload(m, n, seed=0, total_bytes=total)
    print(f"workload: {tm.name}, {tm.total_bytes() / 1e6:.1f} MB across {m}x{n} GPUs")

    # --- the paper's pipeline, host-side -------------------------------
    plans = build_all_plans(tm.d1, chunk_bytes=tm.total_bytes() / 2000, policy="lpt")
    q = plan_quality(plans, n)
    _, t_star = closed_form_opt(tm.d2, n)
    print(f"LPT plan max rail load: {q['max_load']:.3e}  (Theorem-3 optimum {t_star:.3e})")
    for plan in plans[:2]:
        mse, bound, ok = theorem4_mse_bound(plan.loads, plan.w_max)
        print(f"  sender {plan.src_domain}: MSE {mse:.3e} <= w_max^2 {bound:.3e}: {ok}")

    # --- the device-side schedule (what the MoE layer executes) --------
    sched = build_rail_schedule(num_devices=8, num_rails=4, num_chunks=2)
    print(f"rail schedule: {sched.num_transfers()} transfers over {sched.num_rails} rails, "
          f"loads {sched.loads}")

    # --- simulated fabric: all five policies ---------------------------
    print(f"theoretical optimum (Thm 2): {theorem2_optimal_time(tm.d2, n, 50e9)*1e3:.2f} ms")
    res = run_policy_suite(tm, chunk_bytes=4 * 2**20)
    for p, mtr in res.items():
        print(f"  {p:7s} CCT p99 {mtr.cct['p99']*1e3:7.2f} ms  "
              f"recvMSE {mtr.recv_mse:.4f}  optx {mtr.opt_ratio:.2f}")


if __name__ == "__main__":
    main()
