"""End-to-end driver: train a small Mixtral-family MoE with RailS dispatch.

Uses the real framework stack — config system, data pipeline, sharded train
step, AdamW, async checkpointing — at CPU scale (a ~15M-param MoE). The same
driver runs the full mixtral-8x7b on the production mesh via
``python -m repro.launch.train --arch mixtral-8x7b --production``.

    PYTHONPATH=src python examples/train_moe.py --steps 200
"""

import argparse

from repro.launch.train import main as train_main


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--ckpt-dir", type=str, default="/tmp/repro_train_moe")
    args = ap.parse_args()

    out = train_main(
        [
            "--arch", "mixtral-8x7b", "--reduced",
            "--steps", str(args.steps),
            "--batch", str(args.batch),
            "--seq", str(args.seq),
            "--microbatches", "2",
            "--lr", "1e-3",
            "--ckpt-dir", args.ckpt_dir,
            "--ckpt-every", "50",
            "--log-every", "10",
        ]
    )
    first = out["losses"][0][1]
    last = out["losses"][-1][1]
    print(f"\nloss {first:.3f} -> {last:.3f} over {args.steps} steps "
          f"({(first-last)/first*100:.1f}% reduction); checkpoints in {args.ckpt_dir}")


if __name__ == "__main__":
    main()
