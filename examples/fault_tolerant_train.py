"""Fault-tolerant training demo: kill a node mid-run, restart, verify
bitwise-identical convergence.

Wires the REAL stack together: sharded train step + async checkpointer +
heartbeat supervisor + deterministic step-keyed data. A node failure is
injected mid-training; the supervisor detects the missed heartbeats, rolls
back to the last committed checkpoint, and replays — and because the data
pipeline is step-keyed, the replayed run produces exactly the losses the
uninterrupted run would have.

    PYTHONPATH=src python examples/fault_tolerant_train.py
"""

import tempfile

import numpy as np

import jax

from repro.checkpoint import restore, save
from repro.configs import get_config
from repro.configs.base import ShapeSpec
from repro.data import DataConfig, SyntheticTokens
from repro.launch.steps import make_train_step
from repro.launch.train import make_local_mesh
from repro.models import init_params
from repro.optim import AdamWConfig, adamw_init
from repro.parallel.mesh_view import build_mesh_context
from repro.runtime import HeartbeatRegistry, TrainingSupervisor

STEPS, BATCH, SEQ = 24, 4, 64


def main() -> None:
    cfg = get_config("deepseek-7b").reduced()
    mesh = make_local_mesh()
    ctx = build_mesh_context(mesh, cfg)
    shape = ShapeSpec("ft", SEQ, BATCH, "train", 1)
    step_fn = jax.jit(
        make_train_step(cfg, ctx, shape, AdamWConfig(learning_rate=1e-3)),
        donate_argnums=(0, 1),
    )
    data = SyntheticTokens(DataConfig(cfg.vocab_size, SEQ, BATCH, seed=0))

    def fresh_state():
        params = init_params(cfg, jax.random.PRNGKey(0))
        return params, adamw_init(params)

    def train(with_failure: bool, ckpt_dir: str):
        reg = HeartbeatRegistry(num_nodes=2, deadline=1.0)

        def save_fn(step, state):
            save(ckpt_dir, step, state)

        def restore_fn():
            (params, opt), step = restore(ckpt_dir, fresh_state())
            return (params, opt), step

        losses = {}

        def one_step(state, step):
            params, opt = state
            batch = {k: jax.numpy.asarray(v) for k, v in data.batch(step).items()}
            params, opt, metrics = step_fn(params, opt, batch)
            losses[step] = float(metrics["loss"])
            return params, opt

        fired = []

        def injector(step):
            if with_failure and step == 13 and not fired:
                fired.append(step)
                print("  !! node 1 stops heartbeating at step 13")
                return 1
            return None

        sup = TrainingSupervisor(reg, save_fn, restore_fn, checkpoint_every=8)
        with ctx.mesh:
            sup.run(fresh_state(), one_step, steps=STEPS,
                    failure_injector=injector if with_failure else None)
        return losses, sup.restarts

    with tempfile.TemporaryDirectory() as d1, tempfile.TemporaryDirectory() as d2:
        print("clean run...")
        clean, r0 = train(False, d1)
        print("run with injected failure...")
        failed, r1 = train(True, d2)

    print(f"\nrestarts: clean={r0}, failure-run={r1}")
    diffs = [s for s in clean if abs(clean[s] - failed[s]) > 1e-6]
    print(f"loss trajectory: {len(clean)} steps, {len(diffs)} diverging steps")
    print(f"final loss: clean {clean[STEPS-1]:.5f} vs recovered {failed[STEPS-1]:.5f}")
    assert r1 >= 1 and not diffs, "recovery must replay to identical losses"
    print("OK — failure recovered with bitwise-identical training trajectory")


if __name__ == "__main__":
    main()
