"""Serving-path tail latency: p99/p99.9 TTFT under a degraded fabric.

Simulates a Poisson request stream (prefill + decode rounds of
expert-routed all-to-alls) on a healthy and a degraded rail fabric, for
proactive `rails-online`+feedback vs the reactive PLB/REPS baselines.

    PYTHONPATH=src python examples/serve_tail_latency.py
"""

from repro.netsim import FaultSpec, LossConfig, step_profile
from repro.serve import run_serving, serve_workload

M, N = 4, 4


def main() -> None:
    wl = serve_workload(
        M, N, num_requests=32, mean_gap=5e-4, process="poisson",
        prefill_tokens=1024, decode_rounds=4, decode_tokens=8,
        decode_gap=1e-4, bytes_per_token=16 * 2**10, seed=12,
    )
    degraded = FaultSpec(
        rail_profiles={N - 1: step_profile(0.0, 0.25)},
        loss=LossConfig(rate=0.01, rto=1e-4, bad_rate=0.3,
                        p_enter_bad=0.02, p_leave_bad=0.3),
        seed=11,
    )
    for fault, spec in (("clean", None), ("degraded", degraded)):
        print(f"\n{fault} fabric ({M}x{N}, {len(wl.requests)} requests):")
        for policy, fb in (("rails-online", True), ("plb", False), ("reps", False)):
            res = run_serving(
                wl, policy, chunk_bytes=256 * 2**10, fault_spec=spec, feedback=fb
            )
            t = res.request.ttft_percentiles()
            print(
                f"  {policy + ('+fb' if fb else ''):16s} TTFT "
                f"p50 {t['p50'] * 1e6:8.1f}us  p99 {t['p99'] * 1e6:8.1f}us  "
                f"p99.9 {t['p99.9'] * 1e6:8.1f}us"
            )


if __name__ == "__main__":
    main()
