"""Serving example: batched prefill + autoregressive decode (gemma2 family).

    PYTHONPATH=src python examples/serve_decode.py --gen 24
"""

import argparse

from repro.launch.serve import main as serve_main


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma2-9b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=24)
    args = ap.parse_args()
    serve_main(
        [
            "--arch", args.arch, "--reduced",
            "--batch", str(args.batch),
            "--prompt-len", str(args.prompt_len),
            "--gen", str(args.gen),
            "--temperature", "0.8",
        ]
    )


if __name__ == "__main__":
    main()
