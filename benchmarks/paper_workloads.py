"""Shared workload builders for the paper-figure benchmarks."""

from __future__ import annotations

from repro.core.traffic import (
    mixtral_trace_workload,
    receiver_skew_workload,
    sender_skew_workload,
    sparse_topk_workload,
    uniform_workload,
)

M, N = 8, 8
BYTES = 32 * 2**20
CHUNK = 2 * 2**20
POLICIES = ("ecmp", "minrtt", "plb", "reps", "rails")


def uniform():
    return uniform_workload(M, N, bytes_per_pair=BYTES)


def sparse(sparsity: float, seed: int = 1):
    return sparse_topk_workload(M, N, sparsity=sparsity, bytes_per_pair=BYTES, seed=seed)


def sender_skew(seed: int = 1):
    return sender_skew_workload(M, N, total_bytes=BYTES * M * (M - 1) * N * N / 8, seed=seed)


def receiver_skew(seed: int = 1):
    return receiver_skew_workload(M, N, total_bytes=BYTES * M * (M - 1) * N * N / 8, seed=seed)


def mixtral(phase: str, mode: str, seed: int = 2):
    return mixtral_trace_workload(M, N, phase=phase, mode=mode, seed=seed)
