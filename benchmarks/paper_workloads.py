"""Shared workload builders for the paper-figure benchmarks.

``configure(quick=True)`` shrinks the fabric and message sizes so the full
suite runs as a CI smoke check (seconds, not minutes); the module-level
scale constants are read at call time by every builder.
"""

from __future__ import annotations

from repro.core.traffic import (
    bursty_release_times,
    drifting_expert_counts,
    drifting_gating_stream,
    microbatch_stream,
    mixtral_trace_workload,
    receiver_skew_workload,
    sender_skew_workload,
    serve_workload,
    sparse_topk_workload,
    uniform_workload,
)

#: Fabric-scaling grid for ``bench_scale``: (domains, rails, target chunks)
#: — 64/256/512-node fabrics up to the 10⁶-chunk sweep the vector backend
#: unlocked (the event engine is only timed up to ``EVENT_CHUNK_CAP``).
SCALE_GRID = (
    (8, 8, 20_000),
    (32, 8, 50_000),
    (64, 8, 100_000),
    (64, 8, 1_000_000),
)
SCALE_GRID_QUICK = ((8, 8, 5_000),)

#: Largest chunk count the event backend is timed at in ``bench_scale`` —
#: the full grid's 10⁶-chunk sweep is included (the ~25 s event run is the
#: denominator of the headline speedup); raise this when the grid grows.
EVENT_CHUNK_CAP = 1_000_000


def scale_fabric(m: int, n: int, target_chunks: int, seed: int = 7):
    """A hot-expert (sparse top-k) workload on an ``m``×``n`` fabric with a
    chunk size calibrated to land ~``target_chunks`` atomic chunks."""
    tm = sparse_topk_workload(m, n, sparsity=0.5, bytes_per_pair=BYTES, seed=seed)
    chunk_bytes = tm.total_bytes() / target_chunks
    return tm, chunk_bytes

M, N = 8, 8
BYTES = 32 * 2**20
CHUNK = 2 * 2**20
POLICIES = ("ecmp", "minrtt", "plb", "reps", "rails")
QUICK = False


def configure(quick: bool = False) -> None:
    """Switch between the paper-scale grid and the CI smoke-check scale."""
    global M, N, BYTES, CHUNK, QUICK
    QUICK = quick
    if quick:
        M, N = 4, 4
        BYTES = 8 * 2**20
        CHUNK = 1 * 2**20
    else:
        M, N = 8, 8
        BYTES = 32 * 2**20
        CHUNK = 2 * 2**20


def uniform():
    return uniform_workload(M, N, bytes_per_pair=BYTES)


def sparse(sparsity: float, seed: int = 1):
    return sparse_topk_workload(M, N, sparsity=sparsity, bytes_per_pair=BYTES, seed=seed)


def sender_skew(seed: int = 1):
    return sender_skew_workload(M, N, total_bytes=BYTES * M * (M - 1) * N * N / 8, seed=seed)


def receiver_skew(seed: int = 1):
    return receiver_skew_workload(M, N, total_bytes=BYTES * M * (M - 1) * N * N / 8, seed=seed)


def mixtral(phase: str, mode: str, seed: int = 2):
    return mixtral_trace_workload(M, N, phase=phase, mode=mode, seed=seed)


# -- streaming workloads (bench_online_*) -----------------------------------


def micro_stream(num_microbatches: int = 6, seed: int = 1):
    """One iteration split into noisy micro-batch rounds (same total bytes
    as the uniform figure workload)."""
    return microbatch_stream(
        M, N, num_microbatches, bytes_per_pair=BYTES / num_microbatches, seed=seed
    )


def bursty_releases(
    num_rounds: int, mean_gap: float, seed: int = 2, burstiness: float = 1.5
):
    return bursty_release_times(num_rounds, mean_gap, burstiness=burstiness, seed=seed)


def drift_stream(num_rounds: int = 6, seed: int = 3):
    """Gating counts drifting round-to-round, scaled to the figure totals."""
    tokens = M * (M - 1) * N * N
    return drifting_gating_stream(
        M, N, num_rounds, tokens_per_round=tokens,
        bytes_per_token=BYTES / (N * N), seed=seed,
    )


# -- placement workloads (bench_placement) -----------------------------------


def placement_drift_counts(drift: float, num_rounds: int | None = None, seed: int = 21):
    """Mixtral-shaped drifting gating counts for ``bench_placement``.

    Emits raw per-(shard, expert) count matrices (Zipf expert popularity
    random-walking at ``drift`` per round, skewed senders) at the figure
    byte scale, plus the lowering constants: ``(counts_rounds,
    bytes_per_token, expert_weight_bytes)``. Experts number ``2M`` so a
    hot pair can collide on one shard under round-robin — the regime where
    re-layout has something to fix (at ``E == M`` every capacity-1
    placement is a permutation and ingress is immovable). Expert weights
    are 1/16 of a round's payload: heavy enough that migrations must
    amortize, light enough that the online controller can afford them.
    """
    rounds = 6 if num_rounds is None else num_rounds
    tokens = M * (M - 1) * N * N
    bytes_per_token = BYTES / (N * N)
    counts, _ = drifting_expert_counts(
        M, 2 * M, rounds, tokens_per_round=tokens,
        popularity_alpha=1.2, drift=drift, sender_alpha=0.8, seed=seed,
    )
    expert_bytes = tokens * bytes_per_token / 16
    return counts, bytes_per_token, expert_bytes


# -- serving workloads (bench_serving) ---------------------------------------


def serve_requests(mean_gap: float, process: str = "poisson", seed: int = 12):
    """Request stream for ``bench_serving`` at the current scale: prefill +
    decode rounds per request, expert-routed, arrivals paced by
    ``mean_gap`` (smaller gap = higher offered load). Prefill is sized so
    each round splits into ~10² chunks — enough multiplicity that the
    slow-rail structural effect (not per-chunk loss luck) sets the tail."""
    return serve_workload(
        M, N,
        num_requests=16 if QUICK else 48,
        mean_gap=mean_gap,
        process=process,
        prefill_tokens=512 if QUICK else 1024,
        decode_rounds=2 if QUICK else 4,
        decode_tokens=8,
        decode_gap=1e-4,
        bytes_per_token=16 * 2**10,
        seed=seed,
    )
