"""Benchmark harness — one entry per paper table/figure + online scheduling.

Prints ``name,us_per_call,derived`` CSV rows. ``us_per_call`` is the wall
time of one simulated collective (or scheduler call); ``derived`` is the
paper-relevant metric for that figure (normalized BusBw, CCT reduction,
MSE, speedup, ...). The ``bench_online_*`` entries exercise the streaming
control plane (`repro.sched`): bursty micro-batch arrivals, degraded-rail
feedback, routing replay under gating drift, and the windowed re-planning
sweep. ``bench_scale`` drives 64→512-node fabrics at up to 10⁵ chunks —
the perf trajectory for the "fast as the hardware allows" north star.

``--json PATH`` additionally writes every row (plus environment metadata)
as machine-readable JSON — CI uploads ``BENCH_netsim.json`` per PR so the
perf trajectory accumulates across the repo's history.

    PYTHONPATH=src python -m benchmarks.run            # all
    PYTHONPATH=src python -m benchmarks.run --only fig7
    PYTHONPATH=src python -m benchmarks.run --quick    # CI smoke scale
    PYTHONPATH=src python -m benchmarks.run --quick --json BENCH_netsim.json
"""

from __future__ import annotations

import argparse
import datetime
import json
import platform
import subprocess
import time

import numpy as np

from repro.core.lpt import lpt_schedule, lpt_schedule_reference
from repro.core.lp import closed_form_opt, solve_minmax_lp
from repro.core.theorems import theorem2_optimal_time
from repro.core.traffic import (
    TrafficMatrix,
    rl_phase_counts,
    uniform_workload,
)
from repro.netsim import (
    FaultSpec,
    FecConfig,
    LinkIndex,
    LossConfig,
    MultiPodFabric,
    build_job_arrays,
    make_policy,
    run_collective,
    run_policy_suite,
    run_streaming_collective,
    step_profile,
)
from repro.placement import Placement
from repro.sched import RoutingReplayState, run_pipeline

from . import paper_workloads as W

#: Rows accumulated for --json output: (name, us_per_call, derived).
_ROWS: list[dict] = []


#: bench_scale backend selection (``--backend``): "both" (event+vector),
#: "event", "vector", or "device" (jax backend vs vector reference).
_BACKEND = "both"


def _timed(fn):
    t0 = time.perf_counter()
    out = fn()
    return out, (time.perf_counter() - t0) * 1e6


def _emit(
    name: str,
    us: float,
    derived: str,
    *,
    bench: str | None = None,
    backend: str | None = None,
    size: int | None = None,
) -> None:
    """Print a CSV row and record it for ``--json``.

    ``bench``/``backend``/``size`` are structured keys for the perf
    trajectory (``scripts/perf_report.py`` keys rows on them so event and
    vector measurements of one bench never overwrite each other).
    """
    print(f"{name},{us:.1f},{derived}")
    row = {"name": name, "us_per_call": round(us, 1), "derived": derived}
    if bench is not None:
        row["bench"] = bench
    if backend is not None:
        row["backend"] = backend
    if size is not None:
        row["size"] = size
    _ROWS.append(row)


def _write_json(path: str, quick: bool, only: str | None) -> None:
    try:
        git_rev = subprocess.run(
            ["git", "rev-parse", "--short", "HEAD"],
            capture_output=True, text=True, timeout=5,
        ).stdout.strip() or None
    except (OSError, subprocess.SubprocessError):
        git_rev = None
    doc = {
        "schema": 1,
        "generated_utc": datetime.datetime.now(datetime.timezone.utc).isoformat(),
        "quick": quick,
        "only": only,
        "git_rev": git_rev,
        "python": platform.python_version(),
        "numpy": np.__version__,
        "machine": platform.machine(),
        "rows": _ROWS,
    }
    with open(path, "w") as f:
        json.dump(doc, f, indent=2)
        f.write("\n")
    print(f"# wrote {len(_ROWS)} rows to {path}")


def bench_fig7_9_uniform() -> None:
    """Figs 7a/8a/9a: normalized BusBw + CCT under uniform load."""
    tm = W.uniform()
    res, us = _timed(lambda: run_policy_suite(tm, chunk_bytes=W.CHUNK))
    base = res["ecmp"]
    for p, m in res.items():
        _emit(f"fig7a_busbw_{p}", us / len(res), f"{m.bus_bw / base.bus_bw:.3f}x_ecmp")
        _emit(f"fig9a_cct_p99_{p}", us / len(res), f"{m.cct['p99'] / res['rails'].cct['p99']:.3f}x_rails")


def bench_fig7_9_sparse() -> None:
    """Figs 7b-e/8/9: sparsity sweep — RailS advantage grows with sparsity."""
    for sp in (0.6, 0.2) if W.QUICK else (0.6, 0.4, 0.2, 0.0):
        tm = W.sparse(sp)
        res, us = _timed(lambda tm=tm: run_policy_suite(tm, chunk_bytes=W.CHUNK))
        best_other = max(
            res[p].bus_bw for p in ("ecmp", "minrtt", "plb", "reps")
        )
        _emit(
            f"fig7_sparse{sp:g}_rails_busbw_gain",
            us / 5,
            f"{(res['rails'].bus_bw / best_other - 1) * 100:.1f}pct_over_best_baseline",
        )
        _emit(
            f"fig9_sparse{sp:g}_rails_cct_cut_vs_ecmp",
            us / 5,
            f"{(1 - res['rails'].cct['p99'] / res['ecmp'].cct['p99']) * 100:.1f}pct",
        )


def bench_fig10_sender_skew() -> None:
    tm = W.sender_skew()
    res, us = _timed(lambda: run_policy_suite(tm, chunk_bytes=W.CHUNK))
    for p, m in res.items():
        _emit(f"fig10b_send_mse_{p}", us / 5, f"{m.send_mse:.4f}")
    _emit(
        "fig10a_rails_busbw_vs_ecmp", us / 5,
        f"{res['rails'].bus_bw / res['ecmp'].bus_bw:.2f}x",
    )
    _emit(
        "fig10d_rails_cct_cut", us / 5,
        f"{(1 - res['rails'].cct['p99'] / res['ecmp'].cct['p99']) * 100:.1f}pct",
    )


def bench_fig11_receiver_skew() -> None:
    tm = W.receiver_skew()
    res, us = _timed(lambda: run_policy_suite(tm, chunk_bytes=W.CHUNK))
    for p, m in res.items():
        _emit(f"fig11c_recv_mse_{p}", us / 5, f"{m.recv_mse:.4f}")
    _emit(
        "fig11a_rails_busbw_vs_ecmp", us / 5,
        f"{res['rails'].bus_bw / res['ecmp'].bus_bw:.2f}x",
    )
    _emit(
        "fig11d_rails_cct_cut", us / 5,
        f"{(1 - res['rails'].cct['p99'] / res['ecmp'].cct['p99']) * 100:.1f}pct",
    )


def bench_fig12_13_mixtral() -> None:
    """Figs 12/13: Mixtral trace, dense + sparse setups, 4 phases."""
    for mode in ("dense", "sparse"):
        for phase in ("start", "early", "mid", "stable"):
            # Iteration time == the all-to-all barrier == makespan (the
            # paper's Figs 12b/13b metric); mean over 3 trace seeds.
            cuts_best, cuts_worst, us_tot = [], [], 0.0
            for seed in (2,) if W.QUICK else (2, 3, 4):
                tm = W.mixtral(phase, mode, seed=seed)
                res, us = _timed(lambda tm=tm: run_policy_suite(tm, chunk_bytes=W.CHUNK))
                us_tot += us
                others = [res[p].makespan for p in ("ecmp", "minrtt", "plb", "reps")]
                cuts_best.append((1 - res["rails"].makespan / min(others)) * 100)
                cuts_worst.append((1 - res["rails"].makespan / max(others)) * 100)
            _emit(
                f"fig{12 if mode == 'dense' else 13}_{phase}_rails_iter_cut",
                us_tot / 15,
                f"{np.mean(cuts_best):.1f}to{np.mean(cuts_worst):.1f}pct",
            )


def _time_sched(fn, w, n, reps):
    fn(w, n)  # warm
    t0 = time.perf_counter()
    for _ in range(reps):
        res = fn(w, n)
    return res, (time.perf_counter() - t0) / reps * 1e6


def bench_lpt_scheduler() -> None:
    """Algorithm-2 microbenchmark: fast path vs the naive O(F·N) loop.

    ``lpt_sched_F*_N*`` rows use equal-size chunks (the common case —
    ``split_message`` cuts messages into equal atomic chunks): the fast
    path is closed-form round-robin there. ``lpt_sched_mixed_*`` rows use
    heterogeneous (exponential) weights, exercising the heap path.
    """
    rng = np.random.default_rng(0)
    cases = ((1000, 8), (10_000, 64)) if W.QUICK else (
        (1000, 8), (10_000, 64), (100_000, 512)
    )
    for f, n in cases:
        reps = max(1, 20_000 // f)
        w_eq = np.full(f, 4.0 * 2**20)
        res, us = _time_sched(lpt_schedule, w_eq, n, reps)
        _, us_ref = _time_sched(lpt_schedule_reference, w_eq, n, reps)
        _emit(
            f"lpt_sched_F{f}_N{n}", us,
            f"speedup={us_ref / us:.1f}x_vs_reference_mse={res.mse:.3e}",
        )
        w_mix = rng.exponential(1.0, f)
        res, us = _time_sched(lpt_schedule, w_mix, n, reps)
        _, us_ref = _time_sched(lpt_schedule_reference, w_mix, n, reps)
        _emit(
            f"lpt_sched_mixed_F{f}_N{n}", us,
            f"speedup={us_ref / us:.1f}x_vs_reference_mse={res.mse:.3e}",
        )


def bench_lp_solver() -> None:
    """Eq.-24 simplex vs Theorem-3 closed form (validation + timing)."""
    rng = np.random.default_rng(1)
    d2 = rng.uniform(0, 10, (4, 4))
    np.fill_diagonal(d2, 0)
    (p, t_lp, sol), us = _timed(lambda: solve_minmax_lp(d2, 4))
    _, t_cf = closed_form_opt(d2, 4)
    _emit("lp_eq24_simplex_M4N4", us, f"gap_vs_closed_form={abs(t_lp - t_cf):.2e}")


def bench_theorem_bounds() -> None:
    """Theorem-4 bound tightness across skew levels."""
    rng = np.random.default_rng(2)
    for alpha in (0.5, 1.0, 2.0):
        w = rng.zipf(1.0 + alpha, 2000).astype(float)
        res, us = _timed(lambda w=w: lpt_schedule(w, 8))
        _emit(
            f"thm4_mse_over_bound_zipf{alpha:g}", us,
            f"{res.mse / (w.max() ** 2):.2e}",
        )


def bench_online_microbatch() -> None:
    """Streaming micro-batches with bursty releases: the online regime's
    headline — proactive rails-online vs the reactive baselines."""
    rounds = 3 if W.QUICK else 6
    tms = W.micro_stream(num_microbatches=rounds, seed=1)
    # Gaps at half each round's optimal drain time: rounds overlap.
    mean_gap = 0.5 * theorem2_optimal_time(tms[0].d2, W.N, 50e9)
    releases = W.bursty_releases(rounds, mean_gap, seed=2)
    stream = list(zip(releases, tms))
    results, times = {}, {}
    for pol in ("rails-online", "minrtt", "reps"):
        res, us = _timed(
            lambda pol=pol: run_streaming_collective(stream, pol, chunk_bytes=W.CHUNK)
        )
        results[pol], times[pol] = res, us
    rails = results["rails-online"].metrics
    for pol in ("minrtt", "reps"):
        m = results[pol].metrics
        _emit(
            f"online_microbatch_rails_cct_vs_{pol}",
            times[pol],
            f"{rails.makespan / m.makespan:.3f}x_{pol}",
        )
    _emit(
        "online_microbatch_rails_recv_mse",
        times["rails-online"],
        f"{rails.recv_mse:.4f}",
    )
    # Flowlet-coalescing error bound (ROADMAP): measured CCT drift of the
    # coalesced event engine vs the exact vector-backend result on the
    # same release-driven stream.
    exact, us_x = _timed(
        lambda: run_streaming_collective(
            stream, "rails-online", chunk_bytes=W.CHUNK, backend="vector"
        )
    )
    coal, us_c = _timed(
        lambda: run_streaming_collective(
            stream, "rails-online", chunk_bytes=W.CHUNK, coalesce=True
        )
    )
    _emit(
        "online_microbatch_coalesce_drift", us_c,
        f"makespan_drift="
        f"{abs(coal.metrics.makespan / exact.metrics.makespan - 1) * 100:.2f}pct"
        f"_p99_drift="
        f"{abs(coal.metrics.cct['p99'] / exact.metrics.cct['p99'] - 1) * 100:.2f}pct"
        f"_speedup={us_x / us_c:.1f}x_vs_vector_exact",
        bench="online_coalesce_drift", backend="event",
    )


def bench_online_degraded() -> None:
    """Degraded rail: EWMA health feedback pre-charges the online LPT."""
    rounds = 3 if W.QUICK else 6
    tms = W.micro_stream(num_microbatches=rounds, seed=3)
    mean_gap = 0.5 * theorem2_optimal_time(tms[0].d2, W.N, 50e9)
    releases = W.bursty_releases(rounds, mean_gap, seed=4)
    stream = list(zip(releases, tms))
    speeds = [1.0] * (W.N - 1) + [0.4]
    blind, us_b = _timed(
        lambda: run_streaming_collective(
            stream, "rails-online", chunk_bytes=W.CHUNK, rail_speeds=speeds
        )
    )
    fb, us_f = _timed(
        lambda: run_streaming_collective(
            stream, "rails-online", chunk_bytes=W.CHUNK, rail_speeds=speeds,
            feedback=True,
        )
    )
    _emit(
        "online_degraded_feedback_cct_cut",
        us_b + us_f,
        f"{(1 - fb.metrics.makespan / blind.metrics.makespan) * 100:.1f}pct",
    )
    slow_share_fb = fb.metrics.nic_tx[:, -1].sum() / fb.metrics.nic_tx.sum()
    _emit("online_degraded_slow_rail_share", us_f, f"{slow_share_fb:.3f}_of_tx")


def bench_online_replay() -> None:
    """Gating drift: routing replay + overlap pipeline vs no replay."""
    rounds = 3 if W.QUICK else 6
    tms = W.drift_stream(num_rounds=rounds, seed=5)
    speeds = [1.0] * (W.N - 1) + [0.5]
    kwargs = dict(
        gap_fraction=0.5, chunk_bytes=W.CHUNK, rail_speeds=speeds, feedback=True
    )
    off, us_o = _timed(lambda: run_pipeline(tms, use_replay=False, **kwargs))
    rep, us_r = _timed(lambda: run_pipeline(tms, use_replay=True, **kwargs))
    _emit(
        "online_replay_cct_vs_noreplay",
        us_o + us_r,
        f"{rep.makespan / off.makespan:.3f}x_noreplay",
    )
    piped, us_p = _timed(
        lambda: run_pipeline(tms, use_replay=True, compare_sequential=True, **kwargs)
    )
    _emit(
        "online_replay_overlap_speedup",
        us_p,
        f"{piped.overlap_speedup:.2f}x_sequential",
    )


def bench_scale() -> None:
    """ROADMAP fabric scaling: 64→512 nodes, chunk counts up to 10⁶.

    Times one RailS one-shot collective per fabric size on both simulation
    backends (``--backend`` restricts to one), reporting simulated-chunk
    throughput — the raw "fast as the hardware allows" trajectory metric.
    The event engine is only timed up to ``EVENT_CHUNK_CAP`` chunks; above
    that the speedup row compares against the largest event rate measured
    on the same fabric. Flowlet coalescing (an event-engine approximation)
    reports its measured CCT drift against the exact vector result — the
    ROADMAP's "error bound on the CCT drift".
    """
    grid = W.SCALE_GRID_QUICK if W.QUICK else W.SCALE_GRID
    event_rate: dict[int, float] = {}  # nodes -> chunks/s at the largest capped size
    for m, n, target_chunks in grid:
        tm, chunk_bytes = W.scale_fabric(m, n, target_chunks)
        nodes = m * n
        chunks = int(round(tm.total_bytes() / chunk_bytes))
        tag = f"scale_nodes{nodes}_chunks{chunks}"
        res_v = res_e = None
        if _BACKEND in ("both", "vector", "device"):
            res_v, us_v = _timed(
                lambda: run_collective(
                    tm, "rails", chunk_bytes=chunk_bytes, backend="vector"
                )
            )
            _emit(
                f"{tag}_vector", us_v,
                f"{chunks / (us_v / 1e6) / 1e3:.0f}kchunks_per_s_opt_ratio="
                f"{res_v.opt_ratio:.2f}",
                bench="scale", backend="vector", size=chunks,
            )
        if _BACKEND in ("both", "event") and chunks <= W.EVENT_CHUNK_CAP:
            res_e, us_e = _timed(
                lambda: run_collective(
                    tm, "rails", chunk_bytes=chunk_bytes, backend="event"
                )
            )
            event_rate[nodes] = chunks / (us_e / 1e6)
            _emit(
                f"{tag}_event", us_e,
                f"{chunks / (us_e / 1e6) / 1e3:.0f}kchunks_per_s_opt_ratio="
                f"{res_e.opt_ratio:.2f}",
                bench="scale", backend="event", size=chunks,
            )
        if res_v is not None:
            rate_v = chunks / (us_v / 1e6)
            if res_e is not None:
                _emit(
                    f"{tag}_vector_speedup", us_v,
                    f"{us_e / us_v:.1f}x_event_makespan_drift="
                    f"{abs(res_v.makespan / res_e.makespan - 1) * 100:.2e}pct",
                    bench="scale_speedup", backend="vector", size=chunks,
                )
            elif event_rate.get(nodes):
                _emit(
                    f"{tag}_vector_speedup", us_v,
                    f"{rate_v / event_rate[nodes]:.1f}x_event_rate_at_cap",
                    bench="scale_speedup", backend="vector", size=chunks,
                )
        if _BACKEND == "device":
            # Device backend: cold call pays the jit trace (amortized by
            # the power-of-two padding buckets — same-bucket sizes reuse
            # it); the warm rate is the trajectory metric. The suite row
            # is the batching headline: all five policies planned
            # host-side, scanned in one vmap-ed dispatch, vs the serial
            # vector loop over the same grid.
            _, us_cold = _timed(
                lambda: run_collective(
                    tm, "rails", chunk_bytes=chunk_bytes, backend="device"
                )
            )
            res_d, us_d = _timed(
                lambda: run_collective(
                    tm, "rails", chunk_bytes=chunk_bytes, backend="device"
                )
            )
            _emit(
                f"{tag}_device", us_d,
                f"{chunks / (us_d / 1e6) / 1e3:.0f}kchunks_per_s_opt_ratio="
                f"{res_d.opt_ratio:.2f}_jit_cold={us_cold / 1e6:.2f}s",
                bench="scale", backend="device", size=chunks,
            )
            if res_v is not None:
                _emit(
                    f"{tag}_device_speedup", us_d,
                    f"{us_v / us_d:.1f}x_vector_makespan_drift="
                    f"{abs(res_d.makespan / res_v.makespan - 1) * 100:.2e}pct",
                    bench="scale_speedup", backend="device", size=chunks,
                )
            suite_v, us_sv = _timed(
                lambda: run_policy_suite(
                    tm, chunk_bytes=chunk_bytes, backend="vector"
                )
            )
            run_policy_suite(tm, chunk_bytes=chunk_bytes, backend="device")
            suite_d, us_sd = _timed(
                lambda: run_policy_suite(
                    tm, chunk_bytes=chunk_bytes, backend="device"
                )
            )
            npol = len(suite_d)
            _emit(
                f"{tag}_device_suite", us_sd,
                f"{npol}policies_1dispatch_{us_sv / us_sd:.1f}x_vector_loop",
                bench="scale_suite", backend="device", size=chunks,
            )
        if _BACKEND == "both":
            # Coalescing drift vs the exact (vector-backend) result.
            exact = res_v if res_v is not None else res_e
            res_c, us_c = _timed(
                lambda: run_collective(
                    tm, "rails", chunk_bytes=chunk_bytes, coalesce=True
                )
            )
            _emit(
                f"{tag}_coalesced", us_c,
                f"makespan_drift={abs(res_c.makespan / exact.makespan - 1) * 100:.2f}pct"
                f"_p99_drift={abs(res_c.cct['p99'] / exact.cct['p99'] - 1) * 100:.2f}pct"
                "_vs_vector_exact",
                bench="scale_coalesce_drift", backend="event", size=chunks,
            )
    if _BACKEND == "device":
        _bench_scale_microbatch()


def _bench_scale_microbatch() -> None:
    """Batched-sweep regime: many small sims in one device dispatch.

    Times a batch of B independently-planned small collectives through
    ``simulate_many_device`` (one shared padding bucket, one vmap-ed
    call) against the serial vector loop over the same planned arrays —
    planning cost is identical (host-side) in both arms and excluded.
    This is the dispatch-amortization regime the device backend targets:
    the batch dimension is embarrassingly parallel, so on an accelerator
    (or a multi-core host where XLA's thread pool covers the vmap dim)
    one dispatch replaces B python/numpy round trips. On a single-core
    CPU jax install there is nothing to parallelize over and the row
    records the honest ratio vs numpy's serial scans (<1x) — the
    trajectory metric to watch when the toolchain gains a real device.
    """
    from repro.netsim.devicesim import PlannedJobs, simulate_many_device
    from repro.netsim.fastsim import LinkIndex, simulate_chunk_arrays
    from repro.netsim.simulate import _plan_collective
    from repro.netsim.topology import RailTopology

    B = 32 if W.QUICK else 256
    target_chunks = 200
    topo = RailTopology(4, 4)
    index = LinkIndex(topo)
    planned = []
    for i in range(B):
        tm, chunk_bytes = W.scale_fabric(4, 4, target_chunks, seed=100 + i)
        ja, link_by_level, entry_rank = _plan_collective(
            topo, index, tm, "rails", chunk_bytes, seed=i, probe_every=64
        )
        planned.append(
            PlannedJobs(
                link_by_level=link_by_level,
                size=ja.size,
                release=ja.release,
                entry_rank=entry_rank,
                flow_id=ja.flow_id,
                round_id=ja.round_id,
            )
        )
    chunks = sum(p.num_chunks for p in planned)

    def vector_loop():
        return [
            simulate_chunk_arrays(
                index, p.link_by_level, p.size, p.release, p.entry_rank,
                flow_id=p.flow_id, round_id=p.round_id,
            )
            for p in planned
        ]

    res_v, us_v = _timed(vector_loop)
    simulate_many_device(index, planned)  # jit warmup (shared bucket)
    res_d, us_d = _timed(lambda: simulate_many_device(index, planned))
    drift = max(
        abs(d.makespan / v.makespan - 1) for d, v in zip(res_d, res_v)
    )
    _emit(
        f"scale_microbatch_{B}x{target_chunks}chunks_device", us_d,
        f"{us_v / us_d:.2f}x_vector_loop_1dispatch_makespan_drift="
        f"{drift * 100:.2e}pct",
        bench="scale_microbatch", backend="device", size=chunks,
    )


def bench_fault_sweep() -> None:
    """Fabric-dynamics grid: loss rate × degradation depth × policy.

    Each cell runs the same seeded streaming workload under a FaultSpec
    combining Gilbert–Elliott chunk loss (go-back-N recovery) with one
    rail stepping down mid-run, for proactive ``rails-online``+feedback vs
    the reactive ``plb``/``reps`` baselines. Per-policy rows carry raw CCT
    and retransmit counts; the per-cell ``ordering`` row (structured key
    ``bench=fault_l<loss>_d<depth>``) tracks the reactive-over-rails CCT
    ratios — the §VI-E margin — across the repo's perf trajectory.
    """
    rounds = 3 if W.QUICK else 6
    tms = W.micro_stream(num_microbatches=rounds, seed=8)
    mean_gap = 0.5 * theorem2_optimal_time(tms[0].d2, W.N, 50e9)
    releases = W.bursty_releases(rounds, mean_gap, seed=9)
    stream = list(zip(releases, tms))
    t_mid = releases[rounds // 2]
    losses = (0.0, 0.01) if W.QUICK else (0.0, 0.005, 0.02)
    depths = (1.0, 0.5) if W.QUICK else (1.0, 0.5, 0.25)
    for loss in losses:
        for depth in depths:
            def make_spec(loss=loss, depth=depth):
                profiles = (
                    {} if depth == 1.0 else {W.N - 1: step_profile(t_mid, depth)}
                )
                lcfg = (
                    None
                    if loss == 0.0
                    else LossConfig(
                        rate=loss, rto=5e-4, bad_rate=min(0.3, 30 * loss),
                        p_enter_bad=0.02, p_leave_bad=0.3,
                    )
                )
                return FaultSpec(rail_profiles=profiles, loss=lcfg, seed=11)

            cell = f"fault_l{loss:g}_d{depth:g}"
            cct, us_tot = {}, 0.0
            for pol, fb in (("rails-online", True), ("plb", False), ("reps", False)):
                res, us = _timed(
                    lambda pol=pol, fb=fb: run_streaming_collective(
                        stream, pol, chunk_bytes=W.CHUNK,
                        fault_spec=make_spec(), feedback=fb,
                    )
                )
                cct[pol] = res.metrics.makespan
                us_tot += us
                dyn = res.sim.dynamics or {}
                _emit(
                    f"{cell}_{pol}", us,
                    f"cct={res.metrics.makespan:.4e}s"
                    f"_retr={dyn.get('retransmits', 0)}",
                )
            rails = cct["rails-online"]
            _emit(
                f"{cell}_ordering", us_tot,
                f"plb={cct['plb'] / rails:.3f}x"
                f"_reps={cct['reps'] / rails:.3f}x_rails",
                bench=cell, backend="event",
            )


def bench_serving() -> None:
    """Serving-path tail-latency grid: arrival rate × fault × policy.

    Each cell runs one seeded request stream (prefill + decode rounds per
    request, Poisson arrivals) through ``repro.serve.run_serving`` and
    reports release-relative TTFT percentiles (p50/p99/p99.9 — the SLO
    metrics of the serving regime) plus the per-cell ordering row:
    reactive-over-rails p99-TTFT ratios under the PR-4 degraded fabrics.
    Structured bench key ``serve_g<gap>_<fault>`` feeds
    ``perf_report.py --serving``.
    """
    from repro.sched.serving import run_serving

    gaps = (5e-4, 1.25e-4)  # moderate load / near-saturation
    faults = {
        "clean": lambda: None,
        "degraded": lambda: FaultSpec(
            rail_profiles={W.N - 1: step_profile(0.0, 0.25)},
            loss=LossConfig(rate=0.01, rto=1e-4, bad_rate=0.3,
                            p_enter_bad=0.02, p_leave_bad=0.3),
            seed=11,
        ),
    }
    if not W.QUICK:
        faults["loss"] = lambda: FaultSpec(
            loss=LossConfig(rate=0.02, rto=1e-4, bad_rate=0.3,
                            p_enter_bad=0.02, p_leave_bad=0.3),
            seed=11,
        )
    for gap in gaps:
        wl = W.serve_requests(mean_gap=gap)
        for fname, make_spec in faults.items():
            cell = f"serve_g{gap:g}_{fname}"
            p99_ttft, us_tot = {}, 0.0
            for pol, fb in (("rails-online", True), ("plb", False), ("reps", False)):
                res, us = _timed(
                    lambda pol=pol, fb=fb: run_serving(
                        wl, pol, chunk_bytes=256 * 2**10,
                        fault_spec=make_spec(), feedback=fb,
                    )
                )
                row = res.row()
                p99_ttft[pol] = row["ttft_p99_s"]
                us_tot += us
                # No structured bench key: the full row name (unique per
                # policy, still `serve_`-prefixed) keys the trajectory, so
                # these never collide with the cell's ordering row.
                _emit(
                    f"{cell}_{pol}", us,
                    f"ttft_p50={row['ttft_p50_s']:.3e}s"
                    f"_p99={row['ttft_p99_s']:.3e}s"
                    f"_p99.9={row['ttft_p99.9_s']:.3e}s"
                    f"_retr={row['retransmits']}",
                )
            rails = p99_ttft["rails-online"]
            _emit(
                f"{cell}_ordering", us_tot,
                f"plb={p99_ttft['plb'] / rails:.3f}x"
                f"_reps={p99_ttft['reps'] / rails:.3f}x_rails_p99_ttft",
                bench=cell, backend="event",
            )


def bench_serving_slo() -> None:
    """SLO-attainment grid for the serving control plane.

    Offered load (``mean_gap``) × fabric ({clean, one-dead-rail}) ×
    control arm ({no-control, admission, admission+brownout}), every cell
    one seeded request stream through the epoch-windowed
    :func:`repro.serve.gateway.run_gateway` array loop (full mode sweeps
    10⁴ requests per cell — the feedback-at-scale regime the windowed
    loop exists for). Scored shed-aware: goodput = served requests whose
    TTFT met the SLO, per second of trace. The per-cell ``ordering`` row
    (structured key ``bench=slo_g<gap>_<fabric>``, keyed by backend)
    tracks the controlled-over-uncontrolled goodput ratio — the
    overload-robustness headline — via ``perf_report.py --slo``. The
    fabric is a fixed 4×4 (the control loop, not fabric scale, is under
    test); the dead rail is a 2 %-speed crawl, the array loops' fail-stop
    proxy. ``--backend device`` runs each window's scan on the jax
    backend instead and raises full mode to 10⁵ requests per cell — the
    p99.99-tail regime, and the scale where an accelerator-backed jax
    install would amortize per-window dispatch (on single-core CPU jax
    the vector loop stays faster; the rows record what this host
    measures).
    """
    from repro.core.traffic import serve_workload
    from repro.sched.control import (
        AdmissionConfig,
        BrownoutConfig,
        ControlConfig,
    )
    from repro.serve.gateway import run_gateway

    m, n = 4, 4
    slo = 0.002
    gw_backend = "device" if _BACKEND == "device" else "vector"
    if W.QUICK:
        num_req = 300
    else:
        num_req = 100_000 if gw_backend == "device" else 10_000
    gaps = (2e-4, 5e-5) if W.QUICK else (2e-4, 1e-4, 5e-5)
    dead = np.ones(n)
    dead[-1] = 0.02
    fabrics = {"clean": None, "dead1": dead}
    arms = {
        "nocontrol": lambda: None,
        "admission": lambda: ControlConfig(
            slo_s=slo, admission=AdmissionConfig(rate_rps=4000.0)
        ),
        "admission_brownout": lambda: ControlConfig(
            slo_s=slo,
            admission=AdmissionConfig(rate_rps=4000.0),
            brownout=BrownoutConfig(),
        ),
    }
    for gap in gaps:
        wl = serve_workload(m, n, num_requests=num_req, mean_gap=gap, seed=12)
        for fab, speeds in fabrics.items():
            cell = f"slo_g{gap:g}_{fab}"
            goodput, us_tot = {}, 0.0
            for arm, make_control in arms.items():
                res, us = _timed(
                    lambda arm=arm, make_control=make_control: run_gateway(
                        wl, "rails-online", control=make_control(),
                        rail_speeds=speeds, backend=gw_backend, slo_s=slo,
                    )
                )
                s = res.slo
                goodput[arm] = s["goodput_rps"]
                us_tot += us
                _emit(
                    f"{cell}_{arm}", us,
                    f"goodput={s['goodput_rps']:.1f}rps"
                    f"_shed={s['shed_rate']:.3f}"
                    f"_att={s['slo_attainment']:.3f}"
                    f"_brownout_w={res.brownout_windows}",
                    bench=f"{cell}_{arm}", backend=gw_backend, size=num_req,
                )
            base = max(goodput["nocontrol"], 1e-9)
            _emit(
                f"{cell}_ordering", us_tot,
                f"admission={goodput['admission'] / base:.2f}x"
                f"_brownout={goodput['admission_brownout'] / base:.2f}"
                "x_nocontrol_goodput",
                bench=cell, backend=gw_backend, size=num_req,
            )


def bench_placement() -> None:
    """Placement × spraying grid: drift rate × placement mode (ISSUE 6).

    Each cell replays one seeded Mixtral-shaped drifting gating trace
    (``W.placement_drift_counts``) end to end through
    ``repro.placement.run_relayout_trace`` under every placement mode —
    spraying-only ``static`` round-robin, one-shot ``greedy``/``lp``
    re-layouts, and the ``online`` drift-triggered migration controller.
    Per-mode rows carry raw CCT plus migration bytes (the re-layout cost
    rides the simulated fabric); the per-cell ``ordering`` row (structured
    key ``bench=plc_d<drift>``) tracks the static-over-mode CCT ratios —
    the placement+spraying vs spraying-only RailS headline — across the
    repo's perf trajectory via ``perf_report.py --placement``.
    """
    from repro.placement import RelayoutConfig, run_relayout_trace

    drifts = (0.05, 0.4) if W.QUICK else (0.05, 0.2, 0.4)
    modes = ("static", "greedy", "lp", "online")
    # Faster EWMA + shorter cooldown than the library default: the bench
    # traces are short (6 rounds), so the controller must react within a
    # round or two of a collision appearing to amortize before trace end.
    cfg = RelayoutConfig(alpha=0.7, cooldown=1, hysteresis=0.05)
    for drift in drifts:
        counts, bpt, expert_bytes = W.placement_drift_counts(drift)
        cell = f"plc_d{drift:g}"
        cct, mig, us_tot = {}, {}, 0.0
        for mode in modes:
            res, us = _timed(
                lambda mode=mode: run_relayout_trace(
                    counts, W.M, W.N, bpt, mode=mode,
                    weight_bytes=expert_bytes, chunk_bytes=W.CHUNK,
                    config=cfg,
                )
            )
            cct[mode], mig[mode] = res.makespan, res.migration_bytes
            us_tot += us
            _emit(
                f"{cell}_{mode}", us,
                f"cct={res.makespan:.4e}s"
                f"_mig={res.migration_bytes / 2**20:.1f}MiB"
                f"_moves={res.num_migrations}",
            )
        static = cct["static"]
        _emit(
            f"{cell}_ordering", us_tot,
            f"greedy={static / cct['greedy']:.3f}x"
            f"_lp={static / cct['lp']:.3f}x"
            f"_online={static / cct['online']:.3f}x_static_cct",
            bench=cell, backend="event",
        )


def bench_recovery() -> None:
    """Fail-stop recovery grid: failed-rail count × watchdog timeout × policy.

    Each cell runs the seeded ``repro.runtime.failover`` drill — a rail
    (or two) fail-stops mid-collective, stranded chunks retry with
    backoff onto survivors, the silence watchdog flips the planner to the
    N−k survivor mask — and reports time-to-detect, time-to-recover, and
    the steady-state degraded CCT against the Theorem-2 bound recomputed
    on survivors (``track`` = degradation beyond what that bound
    predicts; ~1.0 means failover costs nothing the math doesn't charge).
    Reactive ``reps`` rows have no detection (ttd is planner-side) — they
    recover purely through per-chunk path probing, the baseline the
    proactive path must beat. A serving leg re-runs the PR-5 request
    stream through a mid-trace rail-down and reports the p99-TTFT
    recovery curve (pre/during/post buckets). Structured keys
    ``recov_k<k>_t<mult>`` feed ``perf_report.py --recovery``.
    """
    from repro.netsim import FailStopEvent, RetryConfig
    from repro.runtime.failover import run_failover_drill
    from repro.sched.feedback import DeadRailDetector
    from repro.sched.serving import run_serving, ttft_recovery_curve

    ks = (1, 2)
    mults = (1.0,) if W.QUICK else (1.0, 3.0)
    for k in ks:
        rails = tuple(range(1, 1 + k))
        for mult in mults:
            cell = f"recov_k{k}_t{mult:g}"
            degr, us_tot = {}, 0.0
            for pol in ("rails-online", "reps"):
                rep, us = _timed(
                    lambda pol=pol: run_failover_drill(
                        fail_rail=rails, deadline_gaps=0.6 * mult, policy=pol
                    )
                )
                degr[pol] = rep.degraded_cct_s
                us_tot += us
                ttd = rep.time_to_detect
                _emit(
                    f"{cell}_{pol}", us,
                    f"ttd={'na' if ttd is None else f'{ttd:.3e}s'}"
                    f"_ttr={rep.time_to_recover:.3e}s"
                    f"_track={rep.bound_tracking_ratio:.3f}"
                    f"_eo={int(rep.exactly_once)}"
                    f"_strands={rep.strands}",
                )
            rails_cct = degr["rails-online"]
            _emit(
                f"{cell}_ordering", us_tot,
                f"reps={degr['reps'] / rails_cct:.3f}x_rails_degraded_cct",
                bench=cell, backend="event",
            )
    # Serving leg: mid-trace rail-down + repair through the PR-5 request
    # stream; the recovery curve buckets p99 TTFT by request arrival.
    wl = W.serve_requests(mean_gap=5e-4)
    spec = FaultSpec(
        failures=(FailStopEvent("rail", 2e-3, rail=W.N - 1, t_repair=5e-3),),
        retry=RetryConfig(rto=1e-4),
        seed=11,
    )
    res, us = _timed(
        lambda: run_serving(
            wl, "rails-online", chunk_bytes=256 * 2**10, fault_spec=spec,
            detector=DeadRailDetector(W.N, deadline=5e-4),
        )
    )
    curve = ttft_recovery_curve(res, bucket_s=1e-3)
    pre = [p for t, p in zip(curve["t"], curve["p99"]) if t < 2e-3]
    during = [p for t, p in zip(curve["t"], curve["p99"]) if 2e-3 <= t < 5e-3]
    post = [p for t, p in zip(curve["t"], curve["p99"]) if t >= 5e-3]
    dyn = res.streaming.sim.dynamics or {}
    _emit(
        "recov_serving_raildown", us,
        f"p99_pre={max(pre, default=0.0):.3e}s"
        f"_fail={max(during, default=0.0):.3e}s"
        f"_post={max(post, default=0.0):.3e}s"
        f"_strands={dyn.get('fail_strands', 0)}",
        bench="recov_serving_raildown", backend="event",
        size=len(wl.requests),
    )


def bench_online_window_sweep() -> None:
    """ROADMAP windowed re-planning sweep: CCT vs decision latency as the
    re-planning window goes 1 (greedy on arrival) → ∞ (whole-batch LPT),
    across burstiness levels."""
    rounds = 3 if W.QUICK else 6
    tms = W.micro_stream(num_microbatches=rounds, seed=6)
    mean_gap = 0.5 * theorem2_optimal_time(tms[0].d2, W.N, 50e9)
    bursts = (1.5,) if W.QUICK else (0.5, 1.5, 3.0)
    windows = (1, None) if W.QUICK else (1, 8, 64, None)
    for burst in bursts:
        releases = W.bursty_releases(rounds, mean_gap, seed=7, burstiness=burst)
        stream = list(zip(releases, tms))
        greedy_makespan = None
        for window in windows:
            res, us = _timed(
                lambda window=window: run_streaming_collective(
                    stream, "rails-online", chunk_bytes=W.CHUNK, window=window
                )
            )
            if greedy_makespan is None:
                greedy_makespan = res.metrics.makespan
            label = "inf" if window is None else str(window)
            _emit(
                f"online_window_burst{burst:g}_w{label}", us,
                f"{res.metrics.makespan / greedy_makespan:.4f}x_greedy_cct",
            )


def _xdc_moe_tm(m: int, n: int, bytes_per_pair: float, top_k: int, seed: int) -> TrafficMatrix:
    """MoE-gated sparse all-to-all: each sender GPU routes to ``top_k``
    remote (domain, gpu) experts with lognormal flow sizes.

    Few large flows per sender is exactly where the flat policy's static
    ``rail % wan_lanes`` spray leaves WAN lanes unbalanced — dense uniform
    traffic self-averages over lanes and hides the hierarchy (Theorem 3's
    symmetry, one tier up); ``bench_xdc`` emits both regimes to show it.
    """
    rng = np.random.default_rng(seed)
    d1 = np.zeros((m, n, m, n))
    for d in range(m):
        for g in range(n):
            dsts = rng.choice(
                [x for x in range(m) if x != d], size=top_k, replace=False
            )
            for dd in dsts:
                gg = int(rng.integers(0, n))
                d1[d, g, int(dd), gg] = bytes_per_pair * rng.lognormal(0.0, 0.5)
    return TrafficMatrix(d1=d1, d2=d1.sum(axis=(1, 3)), name=f"xdc-moe-top{top_k}")


def _wan_lane_imbalance(tm: TrafficMatrix, topo, policy_name: str, chunk: float) -> float:
    """Mean over active pod pairs of max-lane-load / mean-lane-load on the
    WAN tier under a policy's static plan (1.0 = perfectly lane-balanced)."""
    ja = build_job_arrays(tm, chunk_bytes=chunk)
    index = LinkIndex(topo)
    pol = make_policy(policy_name, topo, seed=0)
    lbl = pol.plan_arrays(ja, index)
    wan_links = lbl[:, index.level_of_kind["wan"]]
    loads = np.zeros(index.num_links)
    mask = wan_links >= 0
    np.add.at(loads, wan_links[mask], ja.size[mask])
    imbs = []
    p = topo.num_pods
    for ps in range(p):
        for pd in range(p):
            if ps == pd:
                continue
            lane_loads = loads[index.wan[ps, pd]]
            if lane_loads.sum() > 0:
                imbs.append(lane_loads.max() / lane_loads.mean())
    return float(np.mean(imbs)) if imbs else 1.0


def bench_xdc() -> None:
    """Hierarchical multi-pod fabrics: hier-LPT vs flat LPT vs reactive.

    Sweeps oversubscription x WAN RTT on a 4-pod fabric (2 domains/pod)
    carrying MoE-gated sparse traffic, reporting per-policy CCT, the
    hier-vs-flat margin, and the WAN per-lane imbalance that explains it.
    A dense-uniform row quantifies the symmetry break: uniform send keeps
    Theorem 3's balance one tier up and the hierarchy-aware pass is a
    no-op; gated traffic breaks it and two-level LPT wins the difference.
    FEC rows compare XOR parity against go-back-N on the lossy WAN tier.
    """
    pods, dpp, n, lanes = 4, 2, 4, 4
    m = pods * dpp
    chunk = 2 * 2**20
    tm = _xdc_moe_tm(m, n, bytes_per_pair=8 * 2**20, top_k=4, seed=1)
    grid = [(16.0, 10e-3)] if W.QUICK else [
        (4.0, 1e-3), (4.0, 10e-3), (16.0, 1e-3), (16.0, 10e-3)
    ]
    for oversub, rtt in grid:
        topo = MultiPodFabric(
            num_pods=pods, domains_per_pod=dpp, num_rails=n,
            oversub=oversub, wan_rtt=rtt, wan_lanes=lanes,
        )
        tag = f"xdc_o{oversub:g}_rtt{rtt * 1e3:g}ms"
        res, us = {}, {}
        for pol in ("ecmp", "rails", "hier-rails"):
            res[pol], us[pol] = _timed(
                lambda p=pol: run_collective(
                    tm, p, chunk_bytes=chunk, fabric=topo, backend="vector"
                )
            )
            _emit(
                f"{tag}_{pol}", us[pol],
                f"{res[pol].makespan * 1e3:.2f}ms_opt_ratio="
                f"{res[pol].opt_ratio:.2f}",
                bench=f"{tag}_{pol}", backend="vector",
            )
        _emit(
            f"{tag}_hier_vs_flat", us["rails"] + us["hier-rails"],
            f"{(1 - res['hier-rails'].makespan / res['rails'].makespan) * 100:.2f}"
            "pct_cct_cut",
            bench=f"{tag}_hier_vs_flat", backend="vector",
        )
    # The symmetry break, quantified: WAN lane imbalance under each plan,
    # and the hier margin collapsing to ~0 on dense-uniform traffic.
    topo = MultiPodFabric(
        num_pods=pods, domains_per_pod=dpp, num_rails=n,
        oversub=16.0, wan_rtt=10e-3, wan_lanes=lanes,
    )
    for pol in ("rails", "hier-rails"):
        imb, us_i = _timed(lambda p=pol: _wan_lane_imbalance(tm, topo, p, chunk))
        _emit(
            f"xdc_wan_lane_imbalance_{pol}", us_i, f"{imb:.3f}x_mean_lane",
            bench=f"xdc_wan_lane_imbalance_{pol}", backend="vector",
        )
    utm = uniform_workload(m, n, bytes_per_pair=2 * 2**20)
    uflat, us_uf = _timed(
        lambda: run_collective(utm, "rails", chunk_bytes=chunk, fabric=topo,
                               backend="vector")
    )
    uhier, us_uh = _timed(
        lambda: run_collective(utm, "hier-rails", chunk_bytes=chunk, fabric=topo,
                               backend="vector")
    )
    _emit(
        "xdc_uniform_hier_vs_flat", us_uf + us_uh,
        f"{(1 - uhier.makespan / uflat.makespan) * 100:.2f}pct_cct_cut",
        bench="xdc_uniform_hier_vs_flat", backend="vector",
    )
    # FEC vs go-back-N on the lossy WAN: XOR parity absorbs losses without
    # waiting out the 10 ms RTT's RTO (wins under loss), but its r/k
    # redundancy bandwidth is a pure tax at zero loss (loses there).
    fec_chunk = 2**20  # >= k chunks per lane so groups actually fill
    for rate, label in ((0.01, "loss1pct"), (0.0, "loss0")):
        loss = LossConfig(rate=rate, rto=2 * 10e-3, links="wan")
        out = {}
        us_fec = 0.0
        for variant, fec in (("gbn", None), ("fec", FecConfig(k=4, r=1))):
            ftopo = MultiPodFabric(
                num_pods=pods, domains_per_pod=dpp, num_rails=n,
                oversub=16.0, wan_rtt=10e-3, wan_lanes=lanes,
                fault_spec=FaultSpec(loss=loss, fec=fec, seed=7),
            )
            out[variant], us_v = _timed(
                lambda t=ftopo: run_collective(
                    tm, "hier-rails", chunk_bytes=fec_chunk, fabric=t,
                    backend="event",
                )
            )
            us_fec += us_v
        _emit(
            f"xdc_fec_vs_gbn_{label}", us_fec,
            f"{(1 - out['fec'].makespan / out['gbn'].makespan) * 100:.2f}"
            "pct_cct_cut",
            bench=f"xdc_fec_vs_gbn_{label}", backend="event",
        )


def bench_rl_phases() -> None:
    """RL rollout/train lurches: replay forecast quality across phase
    boundaries (PR 8's open question), scored like the gating-drift sweep.

    ``rl_phase_counts`` alternates peaky rollout gating with flat train
    gating; the routing distribution *lurches* at each boundary instead of
    drifting. Pure last-iteration replay (alpha=1) tracks within-phase
    drift best but eats the full lurch at each boundary; EWMA smoothing
    trades steady-state lag for boundary shock absorption. The CCT rows
    re-score run_pipeline's replay warm-start on the lurching stream.
    """
    m, n = W.M, W.N
    rounds = 8 if W.QUICK else 24
    phase_len = 2 if W.QUICK else 6
    tokens = float(m * (m - 1) * 64)
    counts_rounds, shard, phases = rl_phase_counts(
        m, num_experts=4 * m, num_rounds=rounds, tokens_per_round=tokens,
        rollout_len=phase_len, train_len=phase_len, seed=9,
        return_phases=True,
    )
    placement = Placement.round_robin(4 * m, m)
    bpt = float(2**17)  # 128 KiB/token -> ~8 MiB mean off-diagonal entry
    tms = [
        placement.traffic(c, bpt, n, name=f"rl-{phases[i]}-{i}")
        for i, c in enumerate(counts_rounds)
    ]
    forecasters = {"replay": 1.0, "ewma": 0.35}
    for name, alpha in forecasters.items():
        def score(alpha=alpha):
            errs = {"boundary": [], "steady": []}
            rs = RoutingReplayState(m, n, alpha=alpha)
            prev = None
            for tm, phase in zip(tms, phases):
                realized = tm.domain_send_totals()
                if rs.iterations > 0:
                    predicted = rs.expected_totals()
                    err = float(
                        np.abs(predicted - realized).sum()
                        / max(np.abs(realized).sum(), 1e-12)
                    )
                    errs["boundary" if phase != prev else "steady"].append(err)
                rs.update_from_loads(realized)
                prev = phase
            return errs
        errs, us = _timed(score)
        _emit(
            f"rl_forecast_err_{name}", us,
            f"boundary={np.mean(errs['boundary']):.3f}"
            f"_steady={np.mean(errs['steady']):.3f}_rel_l1",
            bench=f"rl_forecast_err_{name}",
        )
    speeds = [1.0] * (n - 1) + [0.5]
    kwargs = dict(
        gap_fraction=0.5, chunk_bytes=W.CHUNK, rail_speeds=speeds, feedback=True
    )
    off, us_o = _timed(lambda: run_pipeline(tms, use_replay=False, **kwargs))
    rep, us_r = _timed(lambda: run_pipeline(tms, use_replay=True, **kwargs))
    _emit(
        "rl_phase_replay_cct_vs_noreplay", us_o + us_r,
        f"{rep.makespan / off.makespan:.3f}x_noreplay",
        bench="rl_phase_replay_cct", backend="event",
    )


def parity_check() -> int:
    """CI gate: the simulation backends must agree on the quick config.

    Two legs, both required (returns 0 only if every check passes):

    * event vs vector — makespan + CCT percentiles; rail-path policies at
      fp tolerance, spine-path baselines at 2e-3 for tie-order degeneracy
      on the synthetic equal-chunk workloads (see tests/test_fastsim.py).
    * vector vs device — the jax backend on CPU jax; makespan at fp
      tolerance for every policy, CCT percentiles at fp tolerance for
      rails and 2e-2 otherwise (degenerate equal-chunk waves can resolve
      ties into a different — equally valid — FIFO order on device; see
      tests/test_devicesim.py).
    """
    W.configure(quick=True)
    workloads = {
        "uniform": W.uniform(),
        "sparse04": W.sparse(0.4),
        "mixtral": W.mixtral("stable", "sparse"),
    }
    failures = []
    for pol in W.POLICIES:
        rtol = 1e-9 if pol in ("rails", "minrtt") else 2e-3
        pol_failures = 0
        for name, tm in workloads.items():
            e = run_collective(tm, pol, chunk_bytes=W.CHUNK, seed=3, backend="event")
            v = run_collective(tm, pol, chunk_bytes=W.CHUNK, seed=3, backend="vector")
            checks = {"makespan": (v.makespan, e.makespan)}
            checks.update({k: (v.cct[k], e.cct[k]) for k in e.cct})
            for key, (got, want) in checks.items():
                if abs(got - want) > rtol * abs(want) + 1e-15:
                    failures.append((pol, name, key, got, want))
                    pol_failures += 1
                    print(f"parity MISMATCH: {pol}/{name}/{key} vector={got!r} event={want!r}")
        verdict = "ok" if pol_failures == 0 else f"FAILED ({pol_failures})"
        print(f"parity {verdict}: {pol} ({len(workloads)} workloads, rtol={rtol:g})")
    for pol in W.POLICIES:
        mk_rtol = 1e-9
        cct_rtol = 1e-9 if pol == "rails" else 2e-2
        pol_failures = 0
        for name, tm in workloads.items():
            v = run_collective(tm, pol, chunk_bytes=W.CHUNK, seed=3, backend="vector")
            d = run_collective(tm, pol, chunk_bytes=W.CHUNK, seed=3, backend="device")
            checks = {"makespan": (d.makespan, v.makespan, mk_rtol)}
            checks.update({k: (d.cct[k], v.cct[k], cct_rtol) for k in v.cct})
            for key, (got, want, rtol) in checks.items():
                if abs(got - want) > rtol * abs(want) + 1e-15:
                    failures.append((pol, name, key, got, want))
                    pol_failures += 1
                    print(f"parity MISMATCH: {pol}/{name}/{key} device={got!r} vector={want!r}")
        verdict = "ok" if pol_failures == 0 else f"FAILED ({pol_failures})"
        print(f"device parity {verdict}: {pol} ({len(workloads)} workloads, "
              f"cct_rtol={cct_rtol:g})")
    if failures:
        print(f"# backend parity FAILED: {len(failures)} mismatches")
        return 1
    print("# backend parity OK: event == vector == device on the quick config")
    return 0


BENCHES = {
    "fig7_9_uniform": bench_fig7_9_uniform,
    "fig7_9_sparse": bench_fig7_9_sparse,
    "fig10": bench_fig10_sender_skew,
    "fig11": bench_fig11_receiver_skew,
    "fig12_13": bench_fig12_13_mixtral,
    "lpt": bench_lpt_scheduler,
    "lp": bench_lp_solver,
    "thm4": bench_theorem_bounds,
    "scale": bench_scale,
    "online_microbatch": bench_online_microbatch,
    "online_degraded": bench_online_degraded,
    "online_replay": bench_online_replay,
    "online_window_sweep": bench_online_window_sweep,
    "fault_sweep": bench_fault_sweep,
    "serving": bench_serving,
    "serving_slo": bench_serving_slo,
    "placement": bench_placement,
    "recovery": bench_recovery,
    "xdc": bench_xdc,
    "rl_phases": bench_rl_phases,
}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", type=str, default=None)
    ap.add_argument(
        "--quick",
        action="store_true",
        help="smaller M x N fabric and fewer repeats (CI smoke check)",
    )
    ap.add_argument(
        "--json",
        type=str,
        default=None,
        metavar="PATH",
        help="also write rows + environment metadata as JSON (perf trajectory)",
    )
    ap.add_argument(
        "--backend",
        choices=("both", "event", "vector", "device"),
        default="both",
        help="bench_scale/bench_serving_slo backend selection (default: "
             "time event+vector; 'device' times the jax backend against "
             "the vector reference)",
    )
    ap.add_argument(
        "--parity-check",
        action="store_true",
        help="run the backend agreement gates (event-vs-vector and "
             "vector-vs-device) and exit (CI)",
    )
    args = ap.parse_args()
    if args.parity_check:
        raise SystemExit(parity_check())
    global _BACKEND
    _BACKEND = args.backend
    W.configure(quick=args.quick)
    print("name,us_per_call,derived")
    for name, fn in BENCHES.items():
        if args.only and args.only not in name:
            continue
        fn()
    if args.json:
        _write_json(args.json, quick=args.quick, only=args.only)


if __name__ == "__main__":
    main()
