"""§Perf report: compare hillclimb variants per cell (markdown).

    PYTHONPATH=src python scripts/perf_report.py results/perf
"""

from __future__ import annotations

import json
import sys
from collections import defaultdict
from pathlib import Path


def main(outdir: str) -> None:
    cells = defaultdict(dict)
    for p in sorted(Path(outdir).glob("*.json")):
        d = json.loads(p.read_text())
        if d.get("status") != "ok":
            continue
        parts = p.stem.split("__")
        tag = parts[3] if len(parts) > 3 else "base"
        cells[f"{d['arch']}__{d['shape']}"][tag] = d

    for cell, variants in cells.items():
        base = variants.get("base")
        if base is None:
            continue
        print(f"\n#### {cell}\n")
        print("| variant | compute s | memory s | collective s | dominant | peak GiB | Δ dominant vs base |")
        print("|---|---|---|---|---|---|---|")
        base_r = base["roofline"]
        for tag, d in sorted(variants.items(), key=lambda kv: (kv[0] != "base", kv[0])):
            r = d["roofline"]
            delta = (r[base_r["dominant"]] / base_r[base_r["dominant"]] - 1) * 100
            print(
                f"| {tag} | {r['compute_s']:.3f} | {r['memory_s']:.3f} | {r['collective_s']:.3f} "
                f"| {r['dominant'].replace('_s','')} | {d['memory']['peak_estimate_gib']} | "
                f"{delta:+.1f}% |"
            )


if __name__ == "__main__":
    main(sys.argv[1] if len(sys.argv) > 1 else "results/perf")
