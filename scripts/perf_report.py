"""§Perf report: compare hillclimb variants per cell (markdown).

    PYTHONPATH=src python scripts/perf_report.py results/perf
"""

from __future__ import annotations

import json
import sys
from collections import defaultdict
from pathlib import Path


def main(outdir: str) -> None:
    cells = defaultdict(dict)
    for p in sorted(Path(outdir).glob("*.json")):
        d = json.loads(p.read_text())
        if d.get("status") != "ok":
            continue
        parts = p.stem.split("__")
        tag = parts[3] if len(parts) > 3 else "base"
        cells[f"{d['arch']}__{d['shape']}"][tag] = d

    for cell, variants in cells.items():
        base = variants.get("base")
        if base is None:
            continue
        print(f"\n#### {cell}\n")
        print("| variant | compute s | memory s | collective s | dominant | peak GiB | Δ dominant vs base |")
        print("|---|---|---|---|---|---|---|")
        # Partial result dirs (killed sweeps, older schema) may lack the
        # roofline block, the dominant key, or carry a zero baseline —
        # report "n/a" instead of KeyError / ZeroDivisionError.
        base_r = base.get("roofline", {})
        dominant = base_r.get("dominant")
        base_val = base_r.get(dominant) if dominant else None
        for tag, d in sorted(variants.items(), key=lambda kv: (kv[0] != "base", kv[0])):
            r = d.get("roofline", {})
            if base_val and r.get(dominant) is not None:
                delta = f"{(r[dominant] / base_val - 1) * 100:+.1f}%"
            else:
                delta = "n/a"
            cols = " | ".join(
                f"{r[k]:.3f}" if isinstance(r.get(k), (int, float)) else "n/a"
                for k in ("compute_s", "memory_s", "collective_s")
            )
            dom = r.get("dominant", "n/a").replace("_s", "")
            peak = d.get("memory", {}).get("peak_estimate_gib", "n/a")
            print(f"| {tag} | {cols} | {dom} | {peak} | {delta} |")


if __name__ == "__main__":
    main(sys.argv[1] if len(sys.argv) > 1 else "results/perf")
