"""§Perf report: compare hillclimb variants per cell (markdown), and
render the netsim benchmark trajectory across BENCH_netsim.json snapshots.

    PYTHONPATH=src python scripts/perf_report.py results/perf
    PYTHONPATH=src python scripts/perf_report.py BENCH_a.json BENCH_b.json
    PYTHONPATH=src python scripts/perf_report.py --fault-sweep BENCH_a.json ...
    PYTHONPATH=src python scripts/perf_report.py --serving BENCH_a.json ...
    PYTHONPATH=src python scripts/perf_report.py --placement BENCH_a.json ...
    PYTHONPATH=src python scripts/perf_report.py --recovery BENCH_a.json ...
    PYTHONPATH=src python scripts/perf_report.py --slo BENCH_a.json ...
    PYTHONPATH=src python scripts/perf_report.py --xdc BENCH_a.json ...
    PYTHONPATH=src python scripts/perf_report.py --rl-phases BENCH_a.json ...

``--fault-sweep`` restricts the trajectory to the fault-sweep grid (rows
whose bench key starts with ``fault_``): one row per (loss rate ×
degradation depth) cell and policy, so the §VI-E ordering margins —
reactive-over-rails CCT ratios under loss + mid-run degradation — read as
their own table across snapshots.

``--serving`` restricts it to the serving-path grid (bench keys starting
with ``serve_``): one row per (arrival rate × fault) cell and policy,
carrying p50/p99/p99.9 TTFT plus the per-cell reactive-over-rails
p99-TTFT ordering.

``--placement`` restricts it to the expert-placement grid (bench keys
starting with ``plc_``): one row per drift-rate cell and placement mode,
carrying end-to-end CCT + migration bytes plus the per-cell
static-over-mode ordering — the placement+spraying vs spraying-only
margin across snapshots.

``--recovery`` restricts it to the fail-stop recovery grid (bench keys
starting with ``recov_``): one row per (failed-rail count × watchdog
timeout) cell and policy, carrying time-to-detect / time-to-recover /
bound-tracking ratio plus the reactive-over-rails degraded-CCT ordering
and the serving rail-down p99-TTFT recovery leg.

``--slo`` restricts it to the serving control-plane grid (bench keys
starting with ``slo_``): one row per (offered load × fabric) cell,
carrying the controlled-over-uncontrolled goodput ordering — the
admission / brownout overload-robustness margin across snapshots.

``--xdc`` restricts it to the hierarchical-fabric grid (bench keys
starting with ``xdc``): one row per (oversubscription × WAN RTT) cell
and policy, carrying the hier-over-flat CCT margin, the WAN per-lane
imbalance, and the FEC-vs-go-back-N ordering across snapshots.

``--rl-phases`` restricts it to the RL rollout/train forecast study
(bench keys starting with ``rl_``): replay-vs-EWMA forecast error at
phase boundaries vs steady state, plus the replay warm-start CCT ratio
on the lurching stream.

Netsim trajectory rows are keyed by **(bench, backend, size)** — not by
bench name alone — so the event and vector measurements of one benchmark
(and the same benchmark at different chunk counts) land on separate rows
instead of overwriting each other. Rows from older snapshots without the
structured keys fall back to their full row name as the bench key, which
is unique per backend/size by construction there.
"""

from __future__ import annotations

import json
import sys
from collections import defaultdict
from pathlib import Path


def main(outdir: str) -> None:
    cells = defaultdict(dict)
    for p in sorted(Path(outdir).glob("*.json")):
        d = json.loads(p.read_text())
        if d.get("status") != "ok":
            continue
        parts = p.stem.split("__")
        tag = parts[3] if len(parts) > 3 else "base"
        cells[f"{d['arch']}__{d['shape']}"][tag] = d

    for cell, variants in cells.items():
        base = variants.get("base")
        if base is None:
            continue
        print(f"\n#### {cell}\n")
        print("| variant | compute s | memory s | collective s | dominant | peak GiB | Δ dominant vs base |")
        print("|---|---|---|---|---|---|---|")
        # Partial result dirs (killed sweeps, older schema) may lack the
        # roofline block, the dominant key, or carry a zero baseline —
        # report "n/a" instead of KeyError / ZeroDivisionError.
        base_r = base.get("roofline", {})
        dominant = base_r.get("dominant")
        base_val = base_r.get(dominant) if dominant else None
        for tag, d in sorted(variants.items(), key=lambda kv: (kv[0] != "base", kv[0])):
            r = d.get("roofline", {})
            if base_val and r.get(dominant) is not None:
                delta = f"{(r[dominant] / base_val - 1) * 100:+.1f}%"
            else:
                delta = "n/a"
            cols = " | ".join(
                f"{r[k]:.3f}" if isinstance(r.get(k), (int, float)) else "n/a"
                for k in ("compute_s", "memory_s", "collective_s")
            )
            dom = r.get("dominant", "n/a").replace("_s", "")
            peak = d.get("memory", {}).get("peak_estimate_gib", "n/a")
            print(f"| {tag} | {cols} | {dom} | {peak} | {delta} |")


def _row_key(row: dict) -> tuple:
    """Trajectory key: (bench, backend, size) — never the bare name.

    Falls back to the row name for pre-metadata snapshots; names there
    already encode backend/size, so the fallback cannot collide with a
    structured key (structured benches are short tags, names are long).
    """
    return (
        row.get("bench") or row["name"],
        row.get("backend") or "-",
        row.get("size") if row.get("size") is not None else "-",
    )


def netsim_trajectory(paths: list[str], bench_prefix: str | None = None) -> None:
    """Markdown trajectory across BENCH_netsim.json snapshots.

    One row per (bench, backend, size) key; one column pair per snapshot
    (us_per_call + derived), labelled by git revision when recorded.
    ``bench_prefix`` restricts to rows whose bench key starts with it
    (``fault_`` renders the fault-sweep grid on its own).
    """
    columns: list[str] = []
    table: dict[tuple, dict[str, dict]] = defaultdict(dict)
    names: dict[tuple, str] = {}
    for p in paths:
        doc = json.loads(Path(p).read_text())
        label = doc.get("git_rev") or Path(p).stem
        if label in columns:
            label = f"{label}:{len(columns)}"
        columns.append(label)
        for row in doc.get("rows", []):
            key = _row_key(row)
            if bench_prefix is not None and not str(key[0]).startswith(bench_prefix):
                continue
            table[key][label] = row
            names.setdefault(key, row["name"])
    header = "| bench | backend | size | " + " | ".join(
        f"{c} us | {c} derived" for c in columns
    ) + " |"
    print(header)
    print("|" + "---|" * (3 + 2 * len(columns)))
    def _sort(k):
        bench, backend, size = k
        return (bench, backend, size if isinstance(size, int) else -1)
    for key in sorted(table, key=_sort):
        bench, backend, size = key
        cells = []
        for c in columns:
            row = table[key].get(c)
            if row is None:
                cells += ["n/a", "n/a"]
            else:
                cells += [f"{row['us_per_call']:.1f}", str(row["derived"])]
        print(f"| {bench} | {backend} | {size} | " + " | ".join(cells) + " |")


if __name__ == "__main__":
    args = sys.argv[1:]
    flags = {
        "--fault-sweep": "fault_",
        "--serving": "serve_",
        "--placement": "plc_",
        "--recovery": "recov_",
        "--slo": "slo_",
        "--xdc": "xdc",
        "--rl-phases": "rl_",
    }
    selected = [f for f in flags if f in args]
    args = [a for a in args if a not in flags]
    if len(selected) > 1:
        raise SystemExit(f"{' and '.join(selected)} are mutually exclusive")
    prefix = flags[selected[0]] if selected else None
    if args and all(a.endswith(".json") for a in args):
        netsim_trajectory(args, bench_prefix=prefix)
    elif prefix is not None:
        raise SystemExit(
            f"{selected[0]} needs one or more BENCH_*.json paths"
        )
    else:
        main(args[0] if args else "results/perf")
