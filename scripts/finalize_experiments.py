"""Splice generated tables into EXPERIMENTS.md.

    PYTHONPATH=src python scripts/finalize_experiments.py \
        --netsim /tmp/netsim_repro.txt
"""

from __future__ import annotations

import argparse
import io
import subprocess
import sys
from contextlib import redirect_stdout
from pathlib import Path

ROOT = Path(__file__).resolve().parent.parent


def _capture(mod_main, *args) -> str:
    buf = io.StringIO()
    with redirect_stdout(buf):
        mod_main(*args)
    return buf.getvalue()


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--netsim", type=str, default=None,
                    help="file with examples/netsim_repro.py output")
    ap.add_argument("--dryrun", type=str, default="results/dryrun")
    ap.add_argument("--perf", type=str, default="results/perf")
    args = ap.parse_args()

    sys.path.insert(0, str(ROOT / "scripts"))
    import build_experiments
    import perf_report

    text = (ROOT / "EXPERIMENTS.md").read_text()

    tables = _capture(build_experiments.main, args.dryrun)
    dry, _, roof = tables.partition("### Roofline table")
    roof = "### Roofline table" + roof
    text = text.replace("<!-- DRYRUN_TABLE -->", dry.strip())
    text = text.replace("<!-- ROOFLINE_TABLE -->", roof.strip())

    if args.netsim and Path(args.netsim).exists():
        net = Path(args.netsim).read_text().strip()
        text = text.replace("<!-- NETSIM_TABLE -->", f"```\n{net}\n```")

    if Path(args.perf).exists():
        perf = _capture(perf_report.main, args.perf)
        text = text.replace("<!-- PERF_TABLES -->", perf.strip())

    (ROOT / "EXPERIMENTS.md").write_text(text)
    print("EXPERIMENTS.md updated")


if __name__ == "__main__":
    main()
