"""Build the §Dry-run and §Roofline tables of EXPERIMENTS.md from
results/dryrun/*.json. Prints markdown to stdout.

    PYTHONPATH=src python scripts/build_experiments.py results/dryrun
"""

from __future__ import annotations

import json
import sys
from pathlib import Path


def fmt_bytes(b: float) -> str:
    for unit in ("B", "KiB", "MiB", "GiB", "TiB"):
        if abs(b) < 1024:
            return f"{b:.1f}{unit}"
        b /= 1024
    return f"{b:.1f}PiB"


def main(outdir: str) -> None:
    cells = []
    for p in sorted(Path(outdir).glob("*.json")):
        cells.append(json.loads(p.read_text()))

    print("### Dry-run table (single-pod sp = 256 chips, multi-pod mp = 512 chips)\n")
    print("| arch | shape | mesh | status | compile s | peak GiB/dev | flops/dev | HBM B/dev | coll B/dev | collective ops |")
    print("|---|---|---|---|---|---|---|---|---|---|")
    for d in cells:
        mesh = "mp" if d.get("multi_pod") else "sp"
        if d.get("status") != "ok":
            print(f"| {d['arch']} | {d['shape']} | {mesh} | {d['status']}: {d.get('reason', d.get('error',''))[:60]} | | | | | | |")
            continue
        ops = d.get("collective_op_counts", {})
        opstr = " ".join(f"{k.split('-')[-1][:4]}:{v}" for k, v in ops.items() if v)
        print(
            f"| {d['arch']} | {d['shape']} | {mesh} | ok | {d['compile_s']} | "
            f"{d['memory']['peak_estimate_gib']} | {d['cost']['device_flops']:.2e} | "
            f"{fmt_bytes(d['cost']['device_bytes'])} | {fmt_bytes(d['collective_bytes_total'])} | {opstr} |"
        )

    print("\n### Roofline table (single-pod, per step; terms in seconds)\n")
    print("| arch | shape | compute s | memory s | collective s | dominant | MODEL_FLOPS | useful ratio | one-line lever |")
    print("|---|---|---|---|---|---|---|---|---|")
    levers = {
        "compute_s": "reduce recompute (remat policy) / larger microbatch",
        "memory_s": "fuse + shard activations harder; bf16 gathers; bigger xent chunks",
        "collective_s": "cut FSDP regathers (bf16 gather-once), reduce-scatter grads, overlap rails",
    }
    for d in cells:
        if d.get("status") != "ok" or d.get("multi_pod"):
            continue
        r = d["roofline"]
        print(
            f"| {d['arch']} | {d['shape']} | {r['compute_s']:.3f} | {r['memory_s']:.3f} | "
            f"{r['collective_s']:.3f} | {r['dominant'].replace('_s','')} | {d['model_flops']:.2e} | "
            f"{d['useful_flops_ratio']} | {levers[r['dominant']]} |"
        )


if __name__ == "__main__":
    main(sys.argv[1] if len(sys.argv) > 1 else "results/dryrun")
