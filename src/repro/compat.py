"""Compatibility helpers for jax API drift.

``jax.make_mesh`` grew an ``axis_types`` keyword (and ``jax.sharding``
an ``AxisType`` enum) in newer releases; older runtimes build the same
Auto-sharded mesh without them. ``jax.shard_map`` graduated from
``jax.experimental.shard_map`` with ``axis_names=`` replacing the
experimental ``auto=`` complement. Route mesh construction and shard_map
through these helpers so the codebase runs on both API generations.
"""

from __future__ import annotations

import inspect

import jax

__all__ = ["make_mesh", "pvary", "shard_map"]


def pvary(x, axis_names):
    """``jax.lax.pvary`` where it exists; identity on older jax (whose
    shard_map treats values as device-varying already)."""
    if hasattr(jax.lax, "pvary"):
        return jax.lax.pvary(x, axis_names)
    return x


def make_mesh(axis_shapes, axis_names, devices=None):
    """``jax.make_mesh`` with Auto axis types where the API supports them."""
    kwargs = {}
    if devices is not None:
        kwargs["devices"] = devices
    axis_type = getattr(jax.sharding, "AxisType", None)
    if (
        axis_type is not None
        and "axis_types" in inspect.signature(jax.make_mesh).parameters
    ):
        kwargs["axis_types"] = (axis_type.Auto,) * len(tuple(axis_names))
    return jax.make_mesh(tuple(axis_shapes), tuple(axis_names), **kwargs)


def shard_map(f, mesh, in_specs, out_specs, axis_names=None, **kwargs):
    """``jax.shard_map`` on new jax, ``jax.experimental.shard_map`` on old.

    ``axis_names`` (new API: the *manual* axes) passes through on new jax.
    On old jax the partial-manual form is NOT emulated: the call runs
    fully manual with ``check_rep=False`` (see the comment below), which
    matches the auto-axis semantics only when the body never names the
    non-manual axes — the invariant every shard_map in this repo keeps.
    """
    if hasattr(jax, "shard_map"):
        if axis_names is not None:
            kwargs["axis_names"] = axis_names
        return jax.shard_map(
            f, mesh=mesh, in_specs=in_specs, out_specs=out_specs, **kwargs
        )
    from jax.experimental.shard_map import shard_map as _shard_map

    if axis_names is not None and frozenset(mesh.axis_names) != frozenset(axis_names):
        # Old XLA cannot lower partial-manual shard_map (SPMD partitioner
        # check failure on manual subgroups). Run fully manual instead:
        # axes absent from the specs see replicated operands, which matches
        # the auto-axis semantics whenever the body never names those axes
        # — true for every shard_map in this repo. check_rep can't prove
        # the resulting replication, so it must be off.
        kwargs.setdefault("check_rep", False)
    return _shard_map(
        f, mesh=mesh, in_specs=in_specs, out_specs=out_specs, **kwargs
    )
