"""Mesh views: per-arch axis factorization of the pinned production mesh.

The dry-run contract pins the device meshes to ``(16, 16)`` axes
``("data", "model")`` and ``(2, 16, 16)`` axes ``("pod", "data", "model")``.
Architectures need finer axes — MoE wants an ``expert`` axis whose size
divides ``num_experts``. A *mesh view* re-labels the same device array
(same device order, so sharding layouts compose with the production mesh's
NamedShardings inside one jit):

    model(16) -> expert(ep) x tp(16/ep),   ep = gcd-style largest divisor
    pod stays an outer pure-DP axis (params replicated across pods,
    gradients all-reduced over DCN — where RailS planning / compression
    applies).
"""

from __future__ import annotations

import dataclasses
import math
from typing import Optional

import numpy as np

import jax
from jax.sharding import Mesh, PartitionSpec as P

from ..configs.base import ModelConfig
from ..models.moe import EpInfo

__all__ = ["MeshContext", "build_mesh_context"]


@dataclasses.dataclass
class MeshContext:
    mesh: Mesh  # the view mesh used by all internal shardings
    has_pod: bool
    data_size: int
    ep: int
    tp: int
    batch_axes: tuple  # axes to shard batch-like dims over
    fsdp_axes: tuple  # axes to shard parameter storage over
    model_axes: tuple  # axes to shard model (heads/ffn/vocab) dims over
    expert_axis: Optional[str]  # the manual axis for MoE dispatch

    @property
    def ep_info(self) -> Optional[EpInfo]:
        if self.expert_axis is None:
            return None
        return EpInfo(self.mesh, self.expert_axis, self.ep)

    @property
    def total_devices(self) -> int:
        return int(np.prod([self.mesh.shape[a] for a in self.mesh.axis_names]))


def _expert_factor(num_experts: int, model_size: int) -> int:
    """Largest ep <= model_size with ep | model_size and ep | num_experts."""
    best = 1
    for ep in range(1, model_size + 1):
        if model_size % ep == 0 and num_experts % ep == 0:
            best = ep
    return best


def build_mesh_context(production_mesh: Mesh, cfg: ModelConfig) -> MeshContext:
    axis_names = production_mesh.axis_names
    has_pod = "pod" in axis_names
    data_size = production_mesh.shape["data"]
    model_size = production_mesh.shape["model"]
    devices = production_mesh.devices  # ndarray in production layout

    if cfg.is_moe:
        ep = _expert_factor(cfg.num_experts, model_size)
        tp = model_size // ep
        if has_pod:
            pod = production_mesh.shape["pod"]
            dev = devices.reshape(pod, data_size, ep, tp)
            names = ("pod", "data", "expert", "tp")
        else:
            dev = devices.reshape(data_size, ep, tp)
            names = ("data", "expert", "tp")
        mesh = Mesh(dev, names)
        return MeshContext(
            mesh=mesh,
            has_pod=has_pod,
            data_size=data_size,
            ep=ep,
            tp=tp,
            batch_axes=(("pod", "data") if has_pod else ("data",)),
            fsdp_axes=("data",),
            model_axes=("expert", "tp"),
            expert_axis="expert",
        )

    # Dense / ssm / hybrid / audio: model axis stays whole ("tp" == model).
    mesh = Mesh(devices, axis_names)
    return MeshContext(
        mesh=mesh,
        has_pod=has_pod,
        data_size=data_size,
        ep=1,
        tp=model_size,
        batch_axes=(("pod", "data") if has_pod else ("data",)),
        fsdp_axes=("data",),
        model_axes=("model",),
        expert_axis=None,
    )
