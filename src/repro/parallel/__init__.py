"""Distribution layer: mesh views, sharding rules, pipeline parallelism."""

from .mesh_view import MeshContext, build_mesh_context
from .sharding import (
    batch_pspecs,
    cache_pspecs,
    fit_axes,
    make_shard_fn,
    opt_state_pspecs,
    param_pspecs,
    param_shardings,
    to_shardings,
)

__all__ = [
    "MeshContext",
    "batch_pspecs",
    "build_mesh_context",
    "cache_pspecs",
    "fit_axes",
    "make_shard_fn",
    "opt_state_pspecs",
    "param_pspecs",
    "param_shardings",
    "to_shardings",
]
