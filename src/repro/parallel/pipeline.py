"""GPipe-style pipeline parallelism over a ``stage`` mesh axis.

SPMD formulation (no per-stage programs): every device runs the same
schedule of ``n_mb + n_stages - 1`` ticks. Each tick, a device applies its
local stage block to its current activation and passes the result to the
next stage with a ``collective_permute``; stage 0 injects a fresh
microbatch, the last stage emits a finished one. Bubbles are the standard
GPipe ``(S-1)/(S-1+M)`` fraction.

Available as a config option and exercised by tests; the headline dry-runs
use DP x TP/EP (DESIGN.md §4.2).
"""

from __future__ import annotations

from functools import partial
from typing import Callable

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from ..compat import pvary as compat_pvary
from ..compat import shard_map as compat_shard_map

__all__ = ["gpipe", "pipeline_loss"]


def gpipe(
    stage_fn: Callable,
    params_stacked,
    x_microbatches: jnp.ndarray,
    mesh,
    stage_axis: str = "stage",
):
    """Run ``stage_fn(params_stage, x) -> x`` as a pipeline.

    Args:
      stage_fn: one pipeline stage (a block of layers).
      params_stacked: pytree with leading dim ``n_stages`` (stage-sharded).
      x_microbatches: ``(n_mb, mb, ...)`` inputs.
      mesh: mesh containing ``stage_axis``.

    Returns ``(n_mb, mb, ...)`` outputs, equal to applying all stages
    sequentially to each microbatch.
    """
    n_stages = mesh.shape[stage_axis]
    n_mb = x_microbatches.shape[0]
    ticks = n_mb + n_stages - 1

    def per_shard(params_local, xs):
        # params_local: (1, ...) slice of this shard's stage params.
        p_mine = jax.tree.map(lambda a: a[0], params_local)
        stage = jax.lax.axis_index(stage_axis)
        mb_shape = xs.shape[1:]
        # carries become device-varying over the stage axis inside the loop;
        # mark the (replicated) initial values accordingly.
        carry = compat_pvary(jnp.zeros(mb_shape, xs.dtype), (stage_axis,))
        outputs = compat_pvary(jnp.zeros((n_mb,) + mb_shape, xs.dtype), (stage_axis,))
        perm = [(i, (i + 1) % n_stages) for i in range(n_stages)]

        def tick(state, t):
            carry, outputs = state
            inject = xs[jnp.clip(t, 0, n_mb - 1)]
            x_in = jnp.where(stage == 0, inject, carry)
            y = stage_fn(p_mine, x_in)
            # pass to next stage; wraps last->0 but stage 0 ignores it
            carry_next = jax.lax.ppermute(y, stage_axis, perm)
            # last stage emits microbatch t - (n_stages - 1)
            out_idx = jnp.clip(t - (n_stages - 1), 0, n_mb - 1)
            emit = jnp.logical_and(stage == n_stages - 1, t >= n_stages - 1)
            updated = jax.lax.dynamic_update_index_in_dim(
                outputs, y.astype(outputs.dtype), out_idx, 0
            )
            outputs = jnp.where(emit, updated, outputs)
            return (carry_next, outputs), None

        (carry, outputs), _ = jax.lax.scan(
            tick, (carry, outputs), jnp.arange(ticks)
        )
        # broadcast the last stage's outputs to every shard
        outputs = jax.lax.psum(
            jnp.where(stage == n_stages - 1, outputs, jnp.zeros_like(outputs)),
            stage_axis,
        )
        return outputs

    return compat_shard_map(
        per_shard,
        mesh=mesh,
        in_specs=(P(stage_axis), P()),
        out_specs=P(),
        axis_names={stage_axis},
    )(params_stacked, x_microbatches)


def pipeline_loss(stage_fn, params_stacked, x_mbs, y_mbs, mesh, stage_axis="stage"):
    """Mean-squared-error training objective through the pipeline (demo)."""
    out = gpipe(stage_fn, params_stacked, x_mbs, mesh, stage_axis)
    return jnp.mean((out - y_mbs) ** 2)
