"""Sharding rules: parameter/activation/cache PartitionSpecs per arch.

Name-based rules over the parameter tree (DESIGN.md §4.2):

* in-projections  ``(d_in, d_out)`` -> ``P(fsdp, model)``
* out-projections ``(d_model_side, d_out)`` -> ``P(model, fsdp)``
* embeddings      ``(V, D)`` -> ``P(model, fsdp)`` (vocab over model)
* MoE experts     ``(E, D, F)`` -> ``P(expert, fsdp, tp)`` (w_down mirrored)
* norms/scalars   replicated

Every rule passes through :func:`fit_axes`, which drops axes that do not
divide the dimension (e.g. 8 KV heads on a 16-wide model axis fall back to
replication) — this is what makes one rule set serve all 10 architectures
on the pinned production meshes.
"""

from __future__ import annotations

from typing import Any

import numpy as np

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

from ..configs.base import ModelConfig, ShapeSpec
from .mesh_view import MeshContext

__all__ = [
    "fit_axes",
    "param_pspecs",
    "param_shardings",
    "make_shard_fn",
    "batch_pspecs",
    "cache_pspecs",
    "opt_state_pspecs",
    "to_shardings",
]

_IN_PROJ = {"wq", "wk", "wv", "w_gate", "w_up", "w_ff1", "w_gates", "in_proj", "router"}
_OUT_PROJ = {"wo", "w_down", "out_proj", "w_ff2"}
_EMBED = {"embed", "lm_head", "enc_pos"}


def fit_axes(dim: int, axes: tuple, ctx: MeshContext):
    """Largest prefix of ``axes`` whose mesh-size product divides ``dim``."""
    sizes = {a: ctx.mesh.shape[a] for a in ctx.mesh.axis_names}
    for end in range(len(axes), 0, -1):
        cand = axes[:end]
        prod = int(np.prod([sizes[a] for a in cand]))
        if dim % prod == 0 and prod > 1:
            return cand if len(cand) > 1 else cand[0]
    return None


def _path_keys(path) -> list[str]:
    keys = []
    for p in path:
        if hasattr(p, "key"):
            keys.append(str(p.key))
        elif hasattr(p, "name"):
            keys.append(str(p.name))
    return keys


def _rule_for(keys: list[str], shape: tuple, cfg: ModelConfig, ctx: MeshContext):
    name = keys[-1] if keys else ""
    stacked = "blocks" in keys and not any(k.startswith("shared") for k in keys)
    base = shape[1:] if stacked else shape
    lead = (None,) if stacked else ()

    def spec(*entries):
        return P(*lead, *entries)

    is_expert_w = cfg.is_moe and "moe" in keys and len(base) == 3
    if is_expert_w:
        e_ax = fit_axes(base[0], ("expert",), ctx)
        if name in ("w_gate", "w_up"):
            return spec(e_ax, fit_axes(base[1], ctx.fsdp_axes, ctx), fit_axes(base[2], ("tp",), ctx))
        if name == "w_down":
            return spec(e_ax, fit_axes(base[1], ("tp",), ctx), fit_axes(base[2], ctx.fsdp_axes, ctx))
    if name in _EMBED and len(base) == 2:
        return spec(fit_axes(base[0], ctx.model_axes, ctx), fit_axes(base[1], ctx.fsdp_axes, ctx))
    if name in _IN_PROJ and len(base) == 2:
        return spec(fit_axes(base[0], ctx.fsdp_axes, ctx), fit_axes(base[1], ctx.model_axes, ctx))
    if name in _OUT_PROJ and len(base) == 2:
        return spec(fit_axes(base[0], ctx.model_axes, ctx), fit_axes(base[1], ctx.fsdp_axes, ctx))
    if name == "conv_w" and len(base) == 2:
        return spec(None, fit_axes(base[1], ctx.model_axes, ctx))
    # norms, gate biases, scalars: replicate (tiny).
    return spec(*([None] * len(base)))


def param_pspecs(cfg: ModelConfig, ctx: MeshContext, params_tree: Any) -> Any:
    def rule(path, leaf):
        return _rule_for(_path_keys(path), leaf.shape, cfg, ctx)

    return jax.tree_util.tree_map_with_path(rule, params_tree)


def to_shardings(ctx: MeshContext, pspec_tree: Any) -> Any:
    return jax.tree.map(
        lambda s: NamedSharding(ctx.mesh, s),
        pspec_tree,
        is_leaf=lambda x: isinstance(x, P),
    )


def param_shardings(cfg: ModelConfig, ctx: MeshContext, params_tree: Any) -> Any:
    return to_shardings(ctx, param_pspecs(cfg, ctx, params_tree))


def opt_state_pspecs(cfg: ModelConfig, ctx: MeshContext, params_tree: Any) -> dict:
    ps = param_pspecs(cfg, ctx, params_tree)
    return {"m": ps, "v": ps, "count": P()}


def make_shard_fn(ctx: MeshContext):
    """Activation constraint hook for the model code ('resid' boundaries).

    Residuals ``(B, T, D)`` shard batch over the batch axes AND sequence
    over the model axes (Megatron-style sequence-parallel activations) —
    without the T sharding, activations replicate model_axes-fold and blow
    the per-device memory budget.
    """

    def shard_fn(x, kind=None):
        if x.ndim < 2:
            return x
        if kind == "logits":
            # (tokens_chunk, V): tokens over batch axes, vocab over model.
            spec = P(
                fit_axes(x.shape[0], ctx.batch_axes, ctx),
                fit_axes(x.shape[1], ctx.model_axes, ctx),
            )
            return jax.lax.with_sharding_constraint(x, NamedSharding(ctx.mesh, spec))
        b_ax = fit_axes(x.shape[0], ctx.batch_axes, ctx)
        if x.ndim >= 3:
            t_ax = fit_axes(x.shape[1], ctx.model_axes, ctx)
            if b_ax is None and t_ax is None:
                # tiny batch + tiny seq (decode): shard T over batch axes.
                t_ax = fit_axes(x.shape[1], ctx.batch_axes, ctx)
            spec = P(b_ax, t_ax, *([None] * (x.ndim - 2)))
        else:
            spec = P(b_ax, *([None] * (x.ndim - 1)))
        return jax.lax.with_sharding_constraint(x, NamedSharding(ctx.mesh, spec))

    return shard_fn


def batch_pspecs(cfg: ModelConfig, ctx: MeshContext, shape: ShapeSpec) -> dict:
    b = shape.global_batch
    b_ax = fit_axes(b, ctx.batch_axes, ctx)
    specs = {"tokens": P(b_ax, None)}
    if shape.kind == "train":
        specs["labels"] = P(b_ax, None)
    if cfg.use_mrope:
        specs["positions"] = P(b_ax, None, None)
    if cfg.is_encoder_decoder:
        specs["embeds"] = P(b_ax, None, fit_axes(cfg.d_model, ctx.model_axes, ctx))
    return specs


def cache_pspecs(cfg: ModelConfig, ctx: MeshContext, cache_tree: Any) -> Any:
    """Decode-cache rules: batch over batch axes when divisible, else the
    sequence dim over the model axes (sequence-parallel cache)."""

    def rule(path, leaf):
        shape = leaf.shape
        # stacked caches: (L, B, ...) — dim0 is the layer/scan dim.
        entries: list = [None]
        b = shape[1] if len(shape) > 1 else 0
        b_ax = fit_axes(b, ctx.batch_axes, ctx) if len(shape) > 1 else None
        entries.append(b_ax)
        for i, d in enumerate(shape[2:], start=2):
            if i == 2 and len(shape) >= 4 and b_ax is None:
                # batch unshardable (long-context decode, B=1): spread the
                # sequence dim over EVERY axis that divides it.
                all_axes = tuple(ctx.batch_axes) + tuple(ctx.model_axes)
                entries.append(fit_axes(d, all_axes, ctx))
            elif i == 2 and len(shape) >= 5:
                entries.append(fit_axes(d, ctx.model_axes, ctx))  # seq dim
            else:
                entries.append(None)
        return P(*entries)

    return jax.tree_util.tree_map_with_path(rule, cache_tree)
