"""Request-level serving simulation layer (``repro.serve``).

Façade over the serving-path subsystem:

* workload generation — :func:`~repro.core.traffic.serve_workload`
  (Poisson / bursty / diurnal request arrivals; per-request prefill +
  autoregressive decode rounds, each decode round emitting a small
  expert-routed all-to-all);
* simulation driver — :func:`~repro.sched.serving.run_serving` (any
  policy, any :class:`~repro.netsim.linkmodel.FaultSpec` degraded
  fabric), scoring release-relative tails: TTFT, per-token latency and
  request sojourn at p50/p90/p99/p99.9;
* control plane — :func:`~repro.serve.gateway.run_gateway`, the
  closed-loop epoch-windowed gateway on top of ``run_serving``:
  token-bucket + queue-depth + p99-tracking admission control with
  prefill/decode priority classes, continuous decode batching, and
  graceful degradation (brownout) wired to the EWMA rail-health
  estimator and the dead-rail watchdog
  (:mod:`repro.sched.control` holds the controllers);
* trace replay — :func:`~repro.sched.serving.simulate_decode_trace`
  drives the simulated fabric with per-step expert counts recorded from
  a real decode loop (``python -m repro.launch.serve --sim-fabric``).

Quick start::

    from repro.serve import serve_workload, run_serving, run_gateway
    from repro.sched.control import AdmissionConfig, BrownoutConfig, ControlConfig
    wl = serve_workload(8, 8, num_requests=64, mean_gap=2e-3)
    res = run_serving(wl, "rails-online", feedback=True)
    print(res.request.ttft_percentiles())   # {'p50': ..., 'p99.9': ...}
    ctl = ControlConfig(slo_s=0.05, admission=AdmissionConfig(rate_rps=400.0),
                        brownout=BrownoutConfig())
    gw = run_gateway(wl, "rails-online", control=ctl, backend="vector")
    print(gw.slo["goodput_rps"], gw.slo["shed_rate"])
"""

from ..core.traffic import (
    ServeRequest,
    ServeRound,
    ServeWorkload,
    request_arrival_times,
    serve_workload,
)
from ..sched.control import (
    AdmissionConfig,
    AdmissionController,
    BrownoutConfig,
    BrownoutController,
    ControlConfig,
    RailProbeMonitor,
    TokenBucket,
    slo_summary,
)
from ..sched.serving import (
    SERVE_QS,
    DecodeTraceResult,
    RequestMetrics,
    ServingResult,
    expert_counts_to_matrix,
    normalized_rounds,
    run_serving,
    simulate_decode_trace,
)
from .gateway import GatewayResult, WindowStats, run_gateway

__all__ = [
    "SERVE_QS",
    "AdmissionConfig",
    "AdmissionController",
    "BrownoutConfig",
    "BrownoutController",
    "ControlConfig",
    "DecodeTraceResult",
    "GatewayResult",
    "RailProbeMonitor",
    "RequestMetrics",
    "ServeRequest",
    "ServeRound",
    "ServeWorkload",
    "ServingResult",
    "TokenBucket",
    "WindowStats",
    "expert_counts_to_matrix",
    "normalized_rounds",
    "request_arrival_times",
    "run_gateway",
    "run_serving",
    "serve_workload",
    "simulate_decode_trace",
    "slo_summary",
]
