"""Closed-loop serving gateway: the epoch-windowed feedback loop.

:func:`~repro.sched.serving.run_serving` is open-loop — a fixed request
stream through a fixed policy. This module is the control plane on top:
:func:`run_gateway` splits the trace into *epoch windows* and, per
window, (1) freezes the control decisions computed from everything
observed so far, (2) admits or sheds the window's new requests
(:class:`~repro.sched.control.AdmissionController` — decode rounds of
admitted requests are protected, new prefills shed first), (3) merges
admitted decode rounds releasing within one quantum into shared
all-to-all rounds (continuous batching), (4) plans the window's chunks
with the persistent ``rails-online`` LPT state over the current survivor
mask and EWMA pre-charge, (5) simulates the window, and (6) feeds the
observed tail back into the admission / brownout controllers for the
next window — plan on window *k*'s observed state, simulate window
*k+1*.

Three simulation backends, mirroring the rest of the repo:

* ``vector`` (default) — each window runs on the exact prefix-scan
  simulator; fabric state chains across windows through the per-link
  busy-until carry (``simulate_chunk_arrays(link_busy=...)``), so the
  concatenation of windows reproduces the single-shot vector run
  flow-exactly and 10⁴–10⁶-request SLO sweeps stay cheap. Rail health is
  observed by out-of-band probes
  (:class:`~repro.sched.control.RailProbeMonitor` feeding the EWMA
  estimator); degraded fabrics are piecewise-static ``fabric_schedule``
  segments (a "dead" rail crawls at ε speed).
* ``device`` — same window loop and busy-until chaining, but each
  window's scan runs on the jitted jax backend
  (:func:`~repro.netsim.devicesim.simulate_chunk_arrays_device`): plan
  window *k* on the host, scan window *k+1* on device with the
  ``link_busy`` carry threaded through. Float-tolerance parity with
  ``vector``; pays off on accelerator hosts where one dispatch replaces
  per-window numpy round trips (on single-core CPU jax the vector loop
  stays faster — see the README backends table).
* ``event`` — each window runs the DES with the
  :class:`~repro.sched.feedback.RailHealthEstimator` and
  :class:`~repro.sched.feedback.DeadRailDetector` attached as live
  observers (true fail-stop / loss dynamics). Windows do not carry link
  backlog across boundaries — an approximation acceptable at the epoch
  granularity the controllers run on; use the vector loop when exact
  chaining matters.

With ``control=None`` the gateway is a transparent façade over
``run_serving`` — bit-exact against the pre-gateway goldens, the anchor
``tests/test_control.py`` pins down.
"""

from __future__ import annotations

import dataclasses
import heapq

import numpy as np

from ..core.traffic import ServeWorkload, TrafficMatrix, aggregate_domains
from ..sched.control import (
    AdmissionController,
    BrownoutController,
    ControlConfig,
    RailProbeMonitor,
    slo_summary,
)
from ..sched.feedback import RailHealthEstimator
from ..sched.serving import (
    RequestMetrics,
    ServingResult,
    normalized_rounds,
    run_serving,
)

__all__ = ["WindowStats", "GatewayResult", "run_gateway"]


@dataclasses.dataclass
class WindowStats:
    """Per-epoch-window control-plane telemetry."""

    t0: float
    t1: float
    mode: str  # "normal" | "brownout"
    offered: int  # new requests arriving in the window
    admitted: int
    shed: int
    rounds: int  # simulated fabric rounds (after batching/shedding)
    p99_ttft: float | None  # this window's prefill-TTFT p99 (None: none)
    queue_depth: int  # admitted requests in flight at window end
    masked_rails: tuple[int, ...]


@dataclasses.dataclass
class GatewayResult:
    """Outcome of one gateway run, shed-aware.

    ``request`` holds **served requests only** — shed requests are
    excluded from every percentile and reported through ``shed_reason`` /
    ``slo`` instead (a rejection is not a latency). ``served_mask``
    aligns with ``workload.requests``; ``request.ttft[k]`` is the k-th
    *served* request in request-id order.
    """

    workload: ServeWorkload
    policy: str
    control: ControlConfig | None
    request: RequestMetrics
    served_mask: np.ndarray
    shed_reason: dict[int, str]
    slo: dict
    windows: list[WindowStats] = dataclasses.field(default_factory=list)
    health: RailHealthEstimator | None = None
    monitor: RailProbeMonitor | None = None
    brownout: BrownoutController | None = None
    serving: ServingResult | None = None  # control-off delegation keeps it

    @property
    def shed_rate(self) -> float:
        return self.slo["shed_rate"]

    @property
    def goodput_rps(self) -> float:
        return self.slo["goodput_rps"]

    @property
    def brownout_windows(self) -> int:
        return sum(1 for w in self.windows if w.mode == "brownout")

    def row(self) -> dict:
        """Flat benchmark row (the SLO-attainment grid)."""
        t = self.request.ttft_percentiles()
        return {
            "policy": self.policy,
            "num_requests": len(self.workload.requests),
            "offered_rps": self.slo["offered_rps"],
            "served": self.slo["served"],
            "shed_rate": self.slo["shed_rate"],
            "slo_attainment": self.slo["slo_attainment"],
            "goodput_rps": self.slo["goodput_rps"],
            "ttft_p50_s": t["p50"],
            "ttft_p99_s": t["p99"],
            "brownout_windows": self.brownout_windows,
        }


def _speeds_at(fabric_schedule, t: float, n: int, rail_speeds) -> np.ndarray:
    """Current true per-rail speeds: last schedule segment at or before t."""
    if fabric_schedule is None:
        if rail_speeds is None:
            return np.ones(n)
        return np.asarray(rail_speeds, dtype=np.float64)
    speeds = None
    for seg_t, seg_speeds in fabric_schedule:
        if seg_t <= t:
            speeds = seg_speeds
        else:
            break
    if speeds is None:
        raise ValueError("fabric_schedule must cover t=0 (first segment t <= 0)")
    return np.asarray(speeds, dtype=np.float64)


class _SpeedCursor:
    """Monotone cursor over the piecewise-static fabric schedule.

    The window loop queries speeds at every epoch boundary; re-scanning
    the whole segment list each time is O(windows × segments). Boundaries
    advance monotonically, so a cursor resumes where the last query left
    off — O(windows + segments) total — and the per-segment arrays are
    materialized once instead of per window. Matches :func:`_speeds_at`
    exactly (including the t=0 coverage error) and falls back to a fresh
    scan if a caller ever queries backwards.
    """

    def __init__(self, fabric_schedule, n: int, rail_speeds):
        self._static = None
        self._segs: list[tuple[float, np.ndarray]] = []
        if fabric_schedule is None:
            self._static = (
                np.ones(n)
                if rail_speeds is None
                else np.asarray(rail_speeds, dtype=np.float64)
            )
        else:
            self._segs = [
                (seg_t, np.asarray(seg_speeds, dtype=np.float64))
                for seg_t, seg_speeds in fabric_schedule
            ]
        self._idx = -1  # last segment known to start at/before the cursor
        self._t = -np.inf

    def at(self, t: float) -> np.ndarray:
        if self._static is not None:
            return self._static
        if t < self._t:
            self._idx = -1  # backwards query: rescan (never hit in the loop)
        self._t = t
        while (
            self._idx + 1 < len(self._segs)
            and self._segs[self._idx + 1][0] <= t
        ):
            self._idx += 1
        if self._idx < 0:
            raise ValueError(
                "fabric_schedule must cover t=0 (first segment t <= 0)"
            )
        return self._segs[self._idx][1]


@dataclasses.dataclass
class _WinRound:
    """One fabric round the gateway actually simulates.

    ``members`` lists the request rounds folded into it — one entry for a
    plain prefill/decode round, several for a continuous decode batch —
    as ``(req_id, kind, member_release)``.
    """

    release: float
    tm: TrafficMatrix
    members: list[tuple[int, str, float]]


def _merged_tm(tms: list[TrafficMatrix], scale: float) -> TrafficMatrix:
    """Sum decode traffic matrices (× brownout fan-out scale) into one.

    One output allocation and in-place accumulation — the old
    ``d1 = d1 + tm.d1 * scale`` built two fresh arrays per member, which
    dominated allocation churn in continuous-batching windows. The sum is
    left-to-right over members and the scale distributes (``(a+b)*s`` vs
    ``a*s + b*s`` differ in float), so the scale is applied per member to
    keep the result bit-identical to the old expression.
    """
    if len(tms) == 1 and scale == 1.0:
        return tms[0]
    d1 = tms[0].d1 * scale
    scratch = np.empty_like(d1) if scale != 1.0 and len(tms) > 1 else None
    for tm in tms[1:]:
        if scale == 1.0:
            np.add(d1, tm.d1, out=d1)
        else:
            # Same rounding as `d1 + tm.d1 * scale` (one product, one
            # add), through a single reused scratch instead of a fresh
            # temporary per member.
            np.multiply(tm.d1, scale, out=scratch)
            np.add(d1, scratch, out=d1)
    return TrafficMatrix(
        d1=d1, d2=aggregate_domains(d1), name="decode-batch"
    )


class _Inflight:
    """Admitted-requests-in-flight counter (the queue-depth signal).

    A request occupies the system from admission until its last round
    completes; completions are retired lazily against each new arrival's
    timestamp via a min-heap, so the count is O(log Q) per event at any
    depth.
    """

    def __init__(self):
        self.count = 0
        self._done: list[tuple[float, int]] = []

    def admit(self):
        self.count += 1

    def retire_at(self, fin: float, req_id: int):
        heapq.heappush(self._done, (fin, req_id))

    def depth(self, now: float) -> int:
        while self._done and self._done[0][0] <= now:
            heapq.heappop(self._done)
            self.count -= 1
        return self.count


def run_gateway(
    workload: ServeWorkload,
    policy: str = "rails-online",
    control: ControlConfig | None = None,
    r1: float = 400e9,
    r2: float = 50e9,
    chunk_bytes: float = 256 * 2**10,
    seed: int = 0,
    probe_every: int = 64,
    rail_speeds=None,
    fabric_schedule=None,
    fault_spec=None,
    detector=None,
    feedback: bool = False,
    window: int | None = None,
    backend: str = "vector",
    slo_s: float | None = None,
    fabric=None,
) -> GatewayResult:
    """Serve one workload through the closed-loop gateway.

    Args:
      control: the :class:`~repro.sched.control.ControlConfig`. ``None``
        delegates to :func:`~repro.sched.serving.run_serving` unchanged
        (bit-exact control-off path) and wraps the result.
      slo_s: SLO used for scoring the control-off path (``control=None``);
        ignored otherwise (``control.slo_s`` governs). Defaults to the
        ``ControlConfig`` default so every arm of an SLO-attainment curve
        is scored against the same threshold.
      rail_speeds: static per-rail speed factors (either backend).
      fabric_schedule: piecewise-static ``[(t_start, speeds), ...]``
        segments, array backends only; speeds switch at the first window
        boundary at/after each segment start. The out-of-band probes read
        these true speeds — the analytic stand-in for a latency probe on
        a real fabric.
      fault_spec: PR-4/PR-7 link dynamics — event backend only (the
        vector simulator rejects non-static specs by construction).
      detector: a :class:`~repro.sched.feedback.DeadRailDetector` to
        attach as an engine observer (event backend): in-band silence
        detection + survivor masking, complementing the vector loop's
        probe monitor.
      feedback: control-off passthrough to ``run_serving`` (the
        controlled path governs EWMA feedback via ``control.feedback``).
      backend: ``vector`` (default; epoch windows chained exactly via the
        per-link busy carry), ``device`` (the same window loop with each
        window's scan jitted on the jax backend, float-tolerance parity),
        or ``event``.
      fabric: optional prebuilt topology (e.g. a
        :class:`~repro.netsim.topology.MultiPodFabric` — pod-aware
        serving); replaces the flat ``RailTopology`` built from
        ``r1``/``r2`` and is mutually exclusive with
        ``rail_speeds``/``fault_spec`` (bake those into the fabric).
        ``fabric_schedule`` still applies — per-window speeds rebuild the
        fabric through its ``with_rail_speeds`` hook.
    """
    if fabric is not None and (rail_speeds is not None or fault_spec is not None):
        raise ValueError(
            "pass rail_speeds/fault_spec via the prebuilt fabric, not "
            "alongside it"
        )
    if control is None:
        if fabric is not None:
            raise ValueError(
                "fabric needs the controlled gateway loop; the control-off "
                "path (control=None) delegates to run_serving, which is "
                "flat-fabric only"
            )
        serving = run_serving(
            workload,
            policy,
            r1=r1,
            r2=r2,
            chunk_bytes=chunk_bytes,
            seed=seed,
            probe_every=probe_every,
            rail_speeds=rail_speeds,
            fault_spec=fault_spec,
            feedback=feedback,
            window=window,
            detector=detector,
            backend=backend,
        )
        num_req = len(workload.requests)
        ordered, releases, t0 = normalized_rounds(workload)
        horizon = max(
            (releases[-1] if releases else 0.0),
            float(serving.streaming.metrics.makespan),
        )
        return GatewayResult(
            workload=workload,
            policy=policy,
            control=None,
            request=serving.request,
            served_mask=np.ones(num_req, dtype=bool),
            shed_reason={},
            slo=slo_summary(
                serving.request.ttft,
                ControlConfig().slo_s if slo_s is None else slo_s,
                horizon, num_req, 0,
            ),
            serving=serving,
            health=serving.streaming.health,
        )
    from ..netsim.simulate import resolve_backend

    resolve_backend(backend)  # reject unknown names with the shared message
    if backend == "event" and fabric_schedule is not None:
        raise ValueError("fabric_schedule is a vector-loop construct; "
                         "use fault_spec with backend='event'")
    if backend in ("vector", "device"):
        # The one shared dynamics gate: non-static specs (whether passed
        # directly or baked into a prebuilt fabric) need the event engine.
        probe_topo = fabric
        if probe_topo is None and fault_spec is not None:
            from ..netsim.topology import RailTopology as _T

            probe_topo = _T(
                workload.num_domains, workload.num_rails,
                r1=r1, r2=r2, fault_spec=fault_spec,
            )
        if probe_topo is not None:
            resolve_backend(backend, probe_topo)
    return _run_gateway_loop(
        workload, policy, control, r1, r2, chunk_bytes, seed, probe_every,
        rail_speeds, fabric_schedule, fault_spec, detector, window, backend,
        fabric,
    )


def _run_gateway_loop(
    workload, policy_name, control, r1, r2, chunk_bytes, seed, probe_every,
    rail_speeds, fabric_schedule, fault_spec, detector, plan_window, backend,
    fabric=None,
):
    from ..netsim.balancers import (
        OnlineRailSPolicy, POLICIES, Policy, RailSPolicy, make_policy,
    )
    from ..netsim.events import Engine
    from ..netsim.fastsim import (
        LinkIndex, paths_from_jobs, simulate_chunk_arrays,
    )
    from ..netsim.simulate import build_streaming_jobs
    from ..netsim.topology import RailTopology

    array_backend = backend in ("vector", "device")
    if backend == "device":
        from ..netsim.devicesim import simulate_chunk_arrays_device

        sim_arrays = simulate_chunk_arrays_device
    else:
        sim_arrays = simulate_chunk_arrays

    m, n = workload.num_domains, workload.num_rails
    ordered, releases, t0 = normalized_rounds(workload)
    if not ordered:
        raise ValueError("serving workload has no rounds")
    from ..sched.serving import _snap

    num_req = len(workload.requests)
    arrival_n = np.array(
        [_snap(r.arrival - t0) for r in workload.requests]
    )
    rounds_left = np.zeros(num_req, dtype=np.int64)
    for r in ordered:
        rounds_left[r.req_id] += 1

    span = releases[-1] if releases else 0.0
    epoch_s = control.epoch_s
    if epoch_s is None:
        epoch_s = max(span / 20.0, 1e-4)

    # -- controllers (decisions frozen per window, updated at boundaries) --
    health = RailHealthEstimator(n, nominal_rate=r2) if (
        control.feedback or array_backend
    ) else None
    monitor = None
    if array_backend:
        monitor = RailProbeMonitor(
            health,
            dead_speed=control.dead_speed,
            healthy_speed=control.healthy_speed,
            revive_windows=control.revive_windows,
            probe_bytes=control.probe_bytes,
        )
    admission = (
        AdmissionController(control.admission, control.slo_s)
        if control.admission is not None
        else None
    )
    brownout = (
        BrownoutController(control.brownout)
        if control.brownout is not None
        else None
    )

    # -- planner (persistent across windows: the LPT LoadState is the plan
    #    memory; the mask/pre-charge it reads are the control decisions) --
    if fabric is not None:
        if (fabric.m, fabric.n) != (m, n):
            raise ValueError(
                f"fabric shape ({fabric.m} domains x {fabric.n} rails) "
                f"does not match workload ({m} x {n})"
            )
        nominal_topo = fabric
    else:
        nominal_topo = RailTopology(
            m, n, r1=r1, r2=r2,
            rail_speeds=None if fabric_schedule is not None else rail_speeds,
            fault_spec=fault_spec if backend == "event" else None,
        )
    policy_cls = POLICIES.get(policy_name, Policy)
    policy_mask_src = monitor if array_backend else detector
    if issubclass(policy_cls, OnlineRailSPolicy):
        policy = make_policy(
            policy_name, nominal_topo, seed=seed, window=plan_window,
            health=health if control.feedback else None,
            replay=None, detector=policy_mask_src,
        )
    else:
        if array_backend and not issubclass(
            policy_cls, (RailSPolicy, OnlineRailSPolicy)
        ):
            raise ValueError(
                f"{backend} gateway requires a proactive planner; "
                f"{policy_name!r} reads live backlog estimates during the run"
            )
        policy = make_policy(policy_name, nominal_topo, seed=seed)

    # -- per-request outcome accumulators ----------------------------------
    admitted_req = np.zeros(num_req, dtype=bool)
    shed_reason: dict[int, str] = {}
    ttft = np.full(num_req, np.nan)
    sojourn = np.zeros(num_req)
    last_fin = np.zeros(num_req)
    token_latency: list[float] = []
    inflight = _Inflight()
    windows: list[WindowStats] = []
    p99_est: float | None = None  # gateway-level EWMA (brownout signal)
    link_busy = None  # created lazily from the first window's LinkIndex
    quantum = control.batch_quantum_s
    speed_cursor = _SpeedCursor(fabric_schedule, n, rail_speeds)
    # Fabric objects are pure functions of the speed vector; windows that
    # share a schedule segment reuse them instead of rebuilding
    # RailTopology + LinkIndex per window.
    fabric_cache: dict[tuple, tuple] = {}

    ptr = 0
    num_rounds = len(ordered)
    k = 0
    eps = 1e-12
    while ptr < num_rounds:
        t_lo = k * epoch_s
        t_hi = (k + 1) * epoch_s
        speeds_now = speed_cursor.at(t_lo)
        if monitor is not None:
            # Out-of-band probe at the window boundary — the only place
            # the vector loop touches ground truth, and only through the
            # EWMA estimator's normal observer interface.
            monitor.observe(speeds_now, t_lo)
        if detector is not None and backend == "event":
            detector.sweep(t_lo)
        mask = (
            policy_mask_src.survivor_mask()
            if policy_mask_src is not None
            else np.ones(n, dtype=bool)
        )
        survivor_frac = float(mask.sum()) / n
        brown_active = brownout.active if brownout is not None else False
        if admission is not None:
            admission.set_rate_scale(
                brownout.admission_scale(survivor_frac)
                if brownout is not None
                else 1.0
            )
        fanout = control.brownout.fanout_keep if brown_active else 1.0
        batch_cap = control.brownout.decode_batch_cap if brown_active else None

        # -- admit / shed the window's rounds ------------------------------
        offered = admitted_count = shed_count = 0
        kept: list[tuple[float, object]] = []  # (release, ServeRound)
        while ptr < num_rounds and releases[ptr] < t_hi - eps:
            rel = releases[ptr]
            rnd = ordered[ptr]
            ptr += 1
            rid = rnd.req_id
            if rnd.kind == "prefill":
                offered += 1
                if admission is not None:
                    ok, reason = admission.admit(rel, inflight.depth(rel))
                else:
                    ok, reason = True, "admitted"
                if ok:
                    admitted_req[rid] = True
                    admitted_count += 1
                    inflight.admit()
                    kept.append((rel, rnd))
                else:
                    shed_count += 1
                    shed_reason[rid] = reason
                    rounds_left[rid] = 0
            elif admitted_req[rid]:
                # Decode rounds of admitted requests: protected class —
                # never shed, whatever the controllers say.
                kept.append((rel, rnd))
            # decode rounds of shed requests vanish with their request

        # -- continuous batching of decode rounds --------------------------
        win_rounds: list[_WinRound] = []
        if quantum is None:
            for rel, rnd in kept:
                tm = rnd.tm if rnd.kind == "prefill" else _merged_tm([rnd.tm], fanout)
                win_rounds.append(
                    _WinRound(rel, tm, [(rnd.req_id, rnd.kind, rel)])
                )
        else:
            batches: dict[int, list[tuple[float, object]]] = {}
            for rel, rnd in kept:
                if rnd.kind == "prefill":
                    win_rounds.append(
                        _WinRound(rel, rnd.tm, [(rnd.req_id, "prefill", rel)])
                    )
                else:
                    batches.setdefault(int(rel / quantum), []).append((rel, rnd))
            for q in sorted(batches):
                group = batches[q]
                cap = batch_cap if batch_cap is not None else len(group)
                for lo in range(0, len(group), max(cap, 1)):
                    part = group[lo:lo + max(cap, 1)]
                    rel = max(r for r, _ in part)  # batch waits for members
                    win_rounds.append(
                        _WinRound(
                            rel,
                            _merged_tm([rnd.tm for _, rnd in part], fanout),
                            [(rnd.req_id, "decode", r) for r, rnd in part],
                        )
                    )
        win_rounds.sort(key=lambda w: w.release)

        # -- simulate the window -------------------------------------------
        round_fin: dict[int, float] = {}
        if win_rounds:
            jobs = build_streaming_jobs(
                [(w.release, w.tm) for w in win_rounds], chunk_bytes
            )
            policy.prepare(jobs)  # no-op for the online planner
            if array_backend:
                speeds_key = tuple(speeds_now.tolist())
                cached = fabric_cache.get(speeds_key)
                if cached is None:
                    # Window fabrics are static rebuilds of the nominal
                    # geometry (flat or multi-pod) at the segment speeds.
                    topo = nominal_topo.with_rail_speeds(speeds_now)
                    index = LinkIndex(topo)
                    fabric_cache[speeds_key] = (topo, index)
                else:
                    topo, index = cached
                if link_busy is None:
                    link_busy = np.zeros(index.num_links)
                rel_batches: dict[float, dict] = {}
                nchunks = 0
                for key, sender_jobs in jobs.items():
                    for j in sender_jobs:
                        rel_batches.setdefault(j.arrival_time, {}).setdefault(
                            key, []
                        ).append(j)
                        nchunks += 1
                eng = Engine(topo, probe_every=probe_every, seed=seed)
                assigned: list = []
                for t in sorted(rel_batches):
                    assigned.extend(
                        policy.assign_batch(eng, rel_batches[t], now=t)
                    )
                link_by_level, entry_rank = paths_from_jobs(
                    assigned, index, nchunks
                )
                size = np.empty(nchunks)
                release = np.empty(nchunks)
                round_id = np.empty(nchunks, dtype=np.int64)
                for j in assigned:
                    cid = j.chunk_id
                    size[cid] = j.size
                    release[cid] = j.arrival_time
                    round_id[cid] = j.round_id
                res = sim_arrays(
                    index, link_by_level, size, release, entry_rank,
                    hop_latency=1e-6, round_id=round_id,
                    link_busy=link_busy,
                )
                link_busy = res.link_last
                round_fin = res.round_completion_times()
            else:
                engine = Engine(nominal_topo, probe_every=probe_every, seed=seed)
                if health is not None:
                    engine.add_observer(health)
                if detector is not None:
                    engine.add_observer(detector)
                sim = engine.run_streaming(jobs, policy)
                round_fin = sim.round_times()[0]

        # -- harvest completions back onto requests ------------------------
        win_ttfts: list[float] = []
        for i, w in enumerate(win_rounds):
            fin = round_fin.get(i, w.release)
            for rid, kind, member_rel in w.members:
                if kind == "prefill":
                    ttft[rid] = fin - arrival_n[rid]
                    win_ttfts.append(float(ttft[rid]))
                else:
                    token_latency.append(float(fin - member_rel))
                sojourn[rid] = max(sojourn[rid], fin - arrival_n[rid])
                last_fin[rid] = max(last_fin[rid], fin)
                rounds_left[rid] -= 1
                if rounds_left[rid] == 0 and admitted_req[rid]:
                    inflight.retire_at(float(last_fin[rid]), rid)

        # -- feed the observations into the controllers --------------------
        win_p99 = (
            float(np.percentile(np.asarray(win_ttfts), 99.0))
            if win_ttfts
            else None
        )
        if win_p99 is not None:
            p99_est = (
                win_p99 if p99_est is None else 0.5 * win_p99 + 0.5 * p99_est
            )
        if admission is not None:
            admission.observe_window(win_p99)
        masked = tuple(
            policy_mask_src.dead_rails() if policy_mask_src is not None else ()
        )
        if brownout is not None:
            brownout.observe_window(t_hi, p99_est, control.slo_s, len(masked))
        windows.append(
            WindowStats(
                t0=t_lo,
                t1=t_hi,
                mode="brownout" if brown_active else "normal",
                offered=offered,
                admitted=admitted_count,
                shed=shed_count,
                rounds=len(win_rounds),
                p99_ttft=win_p99,
                queue_depth=inflight.depth(t_hi),
                masked_rails=masked,
            )
        )
        k += 1

    served = admitted_req.copy()
    served_ttft = ttft[served]
    # An admitted request whose prefill never completed would be a
    # bookkeeping bug, not a data point — assert instead of filtering.
    assert not np.isnan(served_ttft).any()
    horizon = max(span, float(last_fin.max()) if num_req else 0.0)
    request = RequestMetrics(
        ttft=served_ttft,
        token_latency=np.asarray(token_latency),
        sojourn=sojourn[served],
    )
    return GatewayResult(
        workload=workload,
        policy=policy_name,
        control=control,
        request=request,
        served_mask=served,
        shed_reason=shed_reason,
        slo=slo_summary(
            served_ttft, control.slo_s, horizon, num_req, int((~served).sum())
        ),
        windows=windows,
        health=health,
        monitor=monitor,
        brownout=brownout,
    )
