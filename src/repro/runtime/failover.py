"""End-to-end fail-stop failover drill: inject → detect → re-spray → evacuate.

PR-7's integration layer. The pieces live in four subsystems — fail-stop
events + exactly-once retry in :mod:`repro.netsim.events`, the silence
watchdog in :mod:`repro.sched.feedback`, survivor-mask LPT in
:mod:`repro.core.lpt`, and the control-plane failover hooks in
:mod:`repro.sched.online` / :mod:`repro.placement.controller` — and this
module exercises them as one story, the way ``launch/train.py --fail-at``
would on real hardware:

1. **Inject** a :class:`~repro.netsim.linkmodel.FailStopEvent` (rail /
   NIC / node) mid-way through a streaming collective.
2. **Detect** it by silence: the :class:`~repro.sched.feedback.
   DeadRailDetector` watchdog turns the rail FAILED within its configured
   deadline of fabric activity.
3. **Re-spray**: stranded in-flight chunks retry with exponential backoff
   onto surviving rails (engine-level), and every post-detection round is
   LPT-planned over the survivor mask (control-plane level).
4. **Evacuate** (node drills): the placement controller force-migrates
   the dead shard's experts to the least-loaded survivors, weight bytes
   sourced from checkpoint replicas on the surviving shards; elastic
   re-mesh (:func:`repro.runtime.elastic.plan_remesh`) and supervisor
   checkpoint-rollback close the loop.

The report quantifies the three recovery figures of merit: time-to-detect
(failure → watchdog sweep that caught it), time-to-recover (failure →
the disrupted round's last chunk landing), and the steady-state degraded
CCT against the Theorem-2 bound *recomputed on the survivor set* — the
N−k analogue of eq. 20, ``max_i max(row_i, col_i) / (alive_i · R2)``
with per-domain alive-rail counts.
"""

from __future__ import annotations

import dataclasses

import numpy as np

__all__ = [
    "DrillReport",
    "degraded_alive_matrix",
    "degraded_theorem2_bound",
    "run_failover_drill",
]


def degraded_alive_matrix(num_domains: int, num_rails: int, event) -> np.ndarray:
    """Per-(domain, rail) NIC-lane liveness under one fail-stop event.

    ``alive[d, r]`` is False when domain ``d``'s lane on rail ``r`` is
    down: every domain's lane for a rail-down, one domain's lane for a
    NIC-down, every lane of one domain for a node-down.
    """
    alive = np.ones((num_domains, num_rails), dtype=bool)
    if event.kind == "rail":
        alive[:, event.rail] = False
    elif event.kind == "nic":
        alive[event.domain, event.rail] = False
    elif event.kind == "node":
        alive[event.domain, :] = False
    else:
        raise ValueError(f"unknown fail-stop kind {event.kind!r}")
    return alive


def degraded_theorem2_bound(d2: np.ndarray, alive: np.ndarray, r2: float) -> float:
    """Theorem-2 optimal time over an asymmetric surviving rail set.

    The healthy bound ``max(row, col) / (N · R2)`` assumes every domain
    sprays over N lanes; with ``alive_i`` lanes left at domain ``i`` the
    floor becomes ``max_i max(row_i, col_i) / (alive_i · R2)`` — each
    domain's egress *and* ingress must drain through its own survivors.
    Returns ``inf`` when some domain with traffic has no lane at all (a
    partition: no schedule completes until repair).
    """
    d2 = np.asarray(d2, dtype=np.float64)
    alive = np.asarray(alive, dtype=bool)
    rows = d2.sum(axis=1)
    cols = d2.sum(axis=0)
    per_domain = np.maximum(rows, cols)
    counts = alive.sum(axis=1).astype(np.float64)
    worst = 0.0
    for i in range(d2.shape[0]):
        if per_domain[i] <= 0.0:
            continue
        if counts[i] == 0:
            return float("inf")
        worst = max(worst, per_domain[i] / (counts[i] * r2))
    return worst


@dataclasses.dataclass
class DrillReport:
    """Everything ``launch/train.py --fail-at`` prints and the recovery
    bench aggregates; times in seconds, absolute sim clock."""

    num_domains: int
    num_rails: int
    fail_kind: str
    fail_rail: int | None
    fail_domain: int | None
    t_fail: float
    t_repair: float | None
    deadline: float
    # -- detection / recovery ------------------------------------------------
    detected_at: float | None
    time_to_detect: float | None
    time_to_recover: float
    survivor_mask: list[bool]
    # -- exactly-once data plane ---------------------------------------------
    total_chunks: int
    delivered_chunks: int
    exactly_once: bool
    strands: int
    failovers: int
    # -- CCT vs the recomputed bound -----------------------------------------
    pre_bound_s: float
    degraded_bound_s: float
    pre_cct_s: float
    degraded_cct_s: float
    pre_ratio: float
    degraded_ratio: float
    #: ``degraded_ratio / pre_ratio`` — degradation beyond what the
    #: survivor-recomputed bound predicts. The event engine tracks the
    #: analytic bound with a constant fabric factor (two store-and-forward
    #: hops, receive-side contention), so *this* is the quantity that
    #: should stay within ~10% of 1.0 when failover works: the fabric
    #: degrades exactly as much as Theorem 2 over N−k rails says it must,
    #: and no more.
    bound_tracking_ratio: float
    makespan_s: float
    # -- control-plane legs --------------------------------------------------
    plan_alive_rails: int  # GatingFeedbackHook's post-failure rail count
    plan_cache_cleared: bool
    evacuation_bytes: float
    evacuated_experts: int
    elastic: object | None  # runtime.elastic.ElasticPlan (node drills)
    supervisor: dict | None

    def row(self) -> dict:
        """Flat benchmark row (``bench_recovery`` / BENCH_recovery.json)."""
        return {
            "fail_kind": self.fail_kind,
            "t_fail_s": self.t_fail,
            "time_to_detect_s": self.time_to_detect,
            "time_to_recover_s": self.time_to_recover,
            "degraded_ratio": self.degraded_ratio,
            "pre_ratio": self.pre_ratio,
            "bound_tracking_ratio": self.bound_tracking_ratio,
            "strands": self.strands,
            "failovers": self.failovers,
            "exactly_once": self.exactly_once,
            "evacuation_bytes": self.evacuation_bytes,
        }


def _supervisor_leg(fail_domain: int, num_domains: int) -> dict:
    """Checkpoint-rollback drill: one injected node death, full recovery."""
    from .fault_tolerance import HeartbeatRegistry, TrainingSupervisor

    registry = HeartbeatRegistry(num_domains, deadline=5.0, suspect_after=2.0)
    saved: dict[int, int] = {}
    sup = TrainingSupervisor(
        registry,
        save_fn=lambda step, state: saved.__setitem__(step, state),
        restore_fn=lambda: (saved[max(saved)], max(saved)),
        checkpoint_every=2,
    )
    fired = []

    def injector(step: int):
        if step == 5 and not fired:
            fired.append(step)
            return fail_domain
        return None

    state, steps = sup.run(0, lambda s, i: s + 1, steps=8, failure_injector=injector)
    return {
        "restarts": sup.restarts,
        "steps": steps,
        "final_state": state,
        "recovered": sup.restarts == 1 and steps == 8,
    }


def run_failover_drill(
    num_domains: int = 4,
    num_rails: int = 4,
    rounds: int = 6,
    bytes_per_pair: float = 1 * 2**20,
    chunk_bytes: float = 128 * 2**10,
    fail_kind: str = "rail",
    fail_rail=1,
    fail_domain: int | None = None,
    fail_round: int | None = None,
    t_repair: float | None = None,
    deadline: float | None = None,
    deadline_gaps: float = 0.6,
    policy: str = "rails-online",
    r1: float = 400e9,
    r2: float = 50e9,
    seed: int = 0,
    num_experts: int = 16,
    expert_weight_bytes: float = 8 * 2**20,
) -> DrillReport:
    """Run the full fail-stop drill on a uniform streaming collective.

    ``rounds`` identical all-to-alls release at a cadence of 1.25× the
    *degraded* Theorem-2 bound (so the post-failure fabric is loaded but
    not oversubscribed); the fail-stop event lands a quarter-gap into
    round ``fail_round`` (default: a third of the way through the run).
    The watchdog deadline defaults to 0.6 release gaps of fabric
    activity — tight enough that the very next assignment batch plans
    over the survivors. Node drills get a default repair at
    ``t_fail + 1.5 gaps`` (a node-down partitions its ingress; no
    schedule can finish without repair) plus the evacuation, elastic
    re-mesh, and supervisor legs.
    """
    from ..core.theorems import theorem2_optimal_time
    from ..core.traffic import uniform_workload
    from ..netsim.linkmodel import FailStopEvent, FaultSpec, RetryConfig
    from ..netsim.simulate import run_streaming_collective
    from ..sched.feedback import DeadRailDetector
    from ..sched.online import GatingFeedbackHook
    from .elastic import plan_remesh

    if fail_kind in ("nic", "node") and fail_domain is None:
        fail_domain = num_domains - 1
    if fail_kind == "node":
        fail_rails: tuple[int, ...] = ()
    elif isinstance(fail_rail, (int, np.integer)):
        fail_rails = (int(fail_rail),)
    else:
        # A k-rail drill ("rail" kind only): every listed rail dies at the
        # same instant — the N−k planning regime.
        fail_rails = tuple(int(r) for r in fail_rail)
        if fail_kind != "rail" or not fail_rails:
            raise ValueError("multi-rail failures need fail_kind='rail'")
        if len(fail_rails) >= num_rails:
            raise ValueError("at least one rail must survive")
    tm = uniform_workload(num_domains, num_rails, bytes_per_pair=bytes_per_pair)
    pre_bound = theorem2_optimal_time(tm.d2, num_rails, r2)
    alive = np.ones((num_domains, num_rails), dtype=bool)
    probes = [
        FailStopEvent(fail_kind, 0.0, rail=r, domain=fail_domain)
        for r in (fail_rails or (None,))
    ]
    for probe in probes:
        alive &= degraded_alive_matrix(num_domains, num_rails, probe)
    degraded_bound = degraded_theorem2_bound(tm.d2, alive, r2)
    # Node-down partitions the victim's ingress (degraded bound is inf);
    # pace and judge those drills on the healthy bound around the repair.
    pacing_bound = degraded_bound if np.isfinite(degraded_bound) else pre_bound
    gap = 1.25 * pacing_bound
    if fail_round is None:
        fail_round = max(1, rounds // 3)
    if not 0 < fail_round < rounds - 2:
        raise ValueError(
            f"fail_round={fail_round} needs healthy rounds before it and at "
            f"least two steady degraded rounds after it (rounds={rounds})"
        )
    t_fail = (fail_round + 0.25) * gap
    if fail_kind == "node" and t_repair is None:
        t_repair = t_fail + 1.5 * gap
    if deadline is None:
        deadline = deadline_gaps * gap
    events = tuple(
        FailStopEvent(
            fail_kind, t_fail, rail=r, domain=fail_domain, t_repair=t_repair
        )
        for r in (fail_rails or (None,))
    )
    spec = FaultSpec(
        failures=events,
        retry=RetryConfig(rto=gap / 16.0, backoff=2.0, max_retries=50),
        seed=seed,
    )
    detector = DeadRailDetector(num_rails, deadline=deadline)
    releases = [(i * gap, tm) for i in range(rounds)]
    res = run_streaming_collective(
        releases,
        policy,
        r1=r1,
        r2=r2,
        chunk_bytes=chunk_bytes,
        seed=seed,
        fault_spec=spec,
        detector=detector,
        backend="event",
    )
    dyn = res.sim.dynamics or {}
    total = len(res.sim.jobs)
    delivered = int(dyn.get("delivered_chunks", 0))
    # Recovery = the disrupted round's last chunk landing (stranded
    # traffic redelivered); detection may lag it when retries win the race.
    t_recover = max(
        (res.round_cct[i] for i in range(fail_round + 1) if i in res.round_cct),
        default=t_fail,
    )
    pre = [res.round_sojourn[i] for i in range(fail_round) if i in res.round_sojourn]
    steady = [
        res.round_sojourn[i]
        for i in range(fail_round + 2, rounds)
        if i in res.round_sojourn
    ]
    pre_cct = float(np.median(pre)) if pre else 0.0
    degraded_cct = float(np.median(steady)) if steady else 0.0
    judge_bound = degraded_bound if t_repair is None else pre_bound
    pre_ratio = pre_cct / pre_bound if pre_bound > 0 else 0.0
    degraded_ratio = degraded_cct / judge_bound if judge_bound > 0 else 0.0

    # -- control-plane legs --------------------------------------------------
    dead = detector.dead_rails() or list(fail_rails)
    hook = GatingFeedbackHook(num_domains, num_rails, bytes_per_token=1024.0)
    counts = np.full(num_experts, 64.0)
    hook.on_step(counts)
    if dead:
        hook.on_rail_failure(dead)
    post = hook.on_step(counts)
    cache_cleared = hook.plan_cache.misses >= 2  # second step re-planned

    evac_bytes = 0.0
    evac_experts = 0
    elastic = None
    if fail_kind == "node":
        from ..placement import OnlinePlacementController, Placement

        ctl = OnlinePlacementController(
            Placement.round_robin(num_experts, num_domains, expert_weight_bytes),
            num_rails,
            bytes_per_token=1024.0,
        )
        before = ctl.placement.expert_shard.copy()
        decision = ctl.evacuate([fail_domain], counts=counts)
        evac_bytes = decision.migration_bytes
        evac_experts = int((decision.placement.expert_shard != before).sum())
        elastic = plan_remesh(
            old_data=num_domains, old_model=1, new_devices=num_domains - 1
        )
    supervisor = _supervisor_leg(
        fail_domain if fail_domain is not None else 0, num_domains
    )

    rail_for_ttd = fail_rails[0] if fail_rails else 0
    ttd = detector.time_to_detect(rail_for_ttd, t_fail)
    return DrillReport(
        num_domains=num_domains,
        num_rails=num_rails,
        fail_kind=fail_kind,
        fail_rail=fail_rails[0] if fail_rails else None,
        fail_domain=fail_domain,
        t_fail=t_fail,
        t_repair=t_repair,
        deadline=deadline,
        detected_at=detector.detected_at.get(rail_for_ttd),
        time_to_detect=ttd,
        time_to_recover=t_recover - t_fail,
        survivor_mask=detector.survivor_mask().tolist(),
        total_chunks=total,
        delivered_chunks=delivered,
        exactly_once=delivered == total,
        strands=int(dyn.get("fail_strands", 0)),
        failovers=int(dyn.get("failovers", 0)),
        pre_bound_s=pre_bound,
        degraded_bound_s=degraded_bound,
        pre_cct_s=pre_cct,
        degraded_cct_s=degraded_cct,
        pre_ratio=pre_ratio,
        degraded_ratio=degraded_ratio,
        bound_tracking_ratio=degraded_ratio / pre_ratio if pre_ratio > 0 else 0.0,
        makespan_s=res.metrics.makespan,
        plan_alive_rails=int(post["alive_rails"]),
        plan_cache_cleared=cache_cleared,
        evacuation_bytes=evac_bytes,
        evacuated_experts=evac_experts,
        elastic=elastic,
        supervisor=supervisor,
    )
