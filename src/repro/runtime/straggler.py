"""Straggler mitigation.

Two mechanisms, matching DESIGN.md §4.3:

1. **Deadline + backup dispatch** (speculative redundancy): per-step
   deadline derived from a running latency percentile; work units that miss
   it are re-dispatched to a healthy spare, first completion wins.
2. **LPT rebalancing of degraded rails** — the paper's own scheduler doubles
   as straggler mitigation: a rail (lane/NIC) observed slow gets its
   LoadState pre-charged so the LPT greedy assigns it proportionally less.
"""

from __future__ import annotations

import dataclasses

import numpy as np

from ..core.lpt import lpt_schedule
from ..sched.feedback import speed_precharge

__all__ = ["StragglerDetector", "degraded_rail_schedule", "speculative_dispatch"]


@dataclasses.dataclass
class StragglerDetector:
    """EWMA latency tracker with a percentile-style deadline multiplier."""

    alpha: float = 0.2
    multiplier: float = 2.0
    ewma: float = 0.0
    steps: int = 0

    def observe(self, latency: float) -> None:
        self.ewma = latency if self.steps == 0 else (
            self.alpha * latency + (1 - self.alpha) * self.ewma
        )
        self.steps += 1

    @property
    def deadline(self) -> float:
        return self.multiplier * self.ewma if self.steps else float("inf")

    def is_straggler(self, latency: float) -> bool:
        return self.steps > 0 and latency > self.deadline


def degraded_rail_schedule(
    weights: np.ndarray, num_rails: int, rail_speeds, at_time: float = 0.0
):
    """LPT with speed-aware pre-charging (the paper's scheduler as
    straggler mitigation).

    ``rail_speeds[j]`` > 0: a rail at speed s behaves like a rail with
    ``(1/s - 1) * mean_load`` of pre-existing load, so LPT routes around it.
    Entries may also be :class:`repro.netsim.linkmodel.LinkModel` rate
    profiles (step degradation, flapping optics) — they are evaluated at
    ``at_time``, the *plan* time, so a schedule cut while a rail is in its
    degraded phase pre-charges against the speed that phase will actually
    deliver. The pre-charge is the shared
    :func:`repro.sched.feedback.speed_precharge` formula — the same one the
    online control plane derives from EWMA health estimates, so offline
    mitigation and online feedback agree.
    Returns the LptResult plus the *time* each rail finishes (load/speed).
    """
    from ..netsim.linkmodel import LinkModel, speeds_at

    if any(isinstance(s, LinkModel) for s in rail_speeds):
        rail_speeds = speeds_at(rail_speeds, at_time)
    rail_speeds = np.asarray(rail_speeds, dtype=np.float64)
    total = float(np.sum(weights))
    # Ideal per-rail load proportional to speed.
    speed_share = rail_speeds / rail_speeds.sum()
    pre = speed_precharge(total, rail_speeds)
    res = lpt_schedule(np.asarray(weights), num_rails, initial_loads=pre)
    real_loads = res.loads - pre
    finish = real_loads / rail_speeds
    return res, real_loads, finish, speed_share * total


def speculative_dispatch(
    unit_latencies: dict[int, float],
    detector: StragglerDetector,
    backup_latency: float,
) -> dict[int, float]:
    """First-completion-wins backup dispatch for units past the deadline."""
    out = {}
    for unit, lat in unit_latencies.items():
        if detector.is_straggler(lat):
            out[unit] = min(lat, detector.deadline + backup_latency)
        else:
            out[unit] = lat
        detector.observe(out[unit])
    return out
