"""Fault tolerance: heartbeats, failure detection, restart-from-checkpoint.

Scaled design (1000+ nodes): a coordinator tracks per-node heartbeats with
a deadline; a missed deadline marks the node failed, the step generation is
bumped, and every surviving node rejoins at the last committed checkpoint.
Here the coordinator and nodes run in one process (simulated clock) so the
whole protocol is unit-testable on CPU; the state machine is exactly what a
multi-host deployment would run against a KV store.
"""

from __future__ import annotations

import dataclasses
import enum
from typing import Callable, Optional

__all__ = ["NodeState", "HeartbeatRegistry", "TrainingSupervisor"]


class NodeState(enum.Enum):
    HEALTHY = "healthy"
    SUSPECT = "suspect"
    FAILED = "failed"


@dataclasses.dataclass
class _Node:
    node_id: int
    last_beat: float
    state: NodeState = NodeState.HEALTHY


class HeartbeatRegistry:
    """Deadline-based failure detector (simulated or wall clock)."""

    def __init__(self, num_nodes: int, deadline: float = 30.0, suspect_after: float = 10.0):
        self.deadline = deadline
        self.suspect_after = suspect_after
        self.nodes = {i: _Node(i, 0.0) for i in range(num_nodes)}
        self.generation = 0

    def beat(self, node_id: int, now: float) -> None:
        node = self.nodes[node_id]
        node.last_beat = now
        if node.state is NodeState.SUSPECT:
            node.state = NodeState.HEALTHY

    def sweep(self, now: float) -> list[int]:
        """Advance detector; returns newly-failed node ids."""
        newly_failed = []
        for node in self.nodes.values():
            if node.state is NodeState.FAILED:
                continue
            age = now - node.last_beat
            if age > self.deadline:
                node.state = NodeState.FAILED
                newly_failed.append(node.node_id)
            elif age > self.suspect_after:
                node.state = NodeState.SUSPECT
        if newly_failed:
            self.generation += 1
        return newly_failed

    def healthy(self) -> list[int]:
        return [n.node_id for n in self.nodes.values() if n.state is not NodeState.FAILED]

    def revive(self, node_id: int, now: float) -> None:
        """A replacement node joins under the same id."""
        self.nodes[node_id] = _Node(node_id, now)
        self.generation += 1


class TrainingSupervisor:
    """Restart-from-checkpoint orchestration around a step function.

    ``run`` drives ``steps`` training steps; on any node failure reported by
    the registry it rolls state back to the last committed checkpoint and
    replays. Deterministic data (step-keyed, see data/pipeline.py) makes the
    replay bitwise-reproducible.
    """

    def __init__(
        self,
        registry: HeartbeatRegistry,
        save_fn: Callable[[int, object], None],
        restore_fn: Callable[[], tuple[object, int]],
        checkpoint_every: int = 10,
        max_restarts: int = 100,
    ):
        self.registry = registry
        self.save_fn = save_fn
        self.restore_fn = restore_fn
        self.checkpoint_every = checkpoint_every
        self.max_restarts = max_restarts
        self.restarts = 0

    def run(
        self,
        state,
        step_fn: Callable[[object, int], object],
        steps: int,
        failure_injector: Optional[Callable[[int], Optional[int]]] = None,
        clock: float = 0.0,
        step_time: float = 1.0,
    ):
        step = 0
        self.save_fn(0, state)
        # The simulated clock is *monotone*: it never rewinds, even when a
        # rollback sends `step` backwards. The old `now = clock + step *
        # step_time` recomputation moved time backwards after a restore,
        # so heartbeat ages went negative and a later genuine silence
        # could hide inside the stale (future) last-beat stamps.
        now = clock
        while step < steps:
            victim = failure_injector(step) if failure_injector is not None else None
            for node in self.registry.healthy():
                if node != victim:
                    self.registry.beat(node, now)
            if victim is not None:
                # Detection consumes wall time: the victim's beat must age
                # past the deadline before any sweep can see it.
                now += self.registry.deadline + 1e-9
            failed = self.registry.sweep(now)
            if failed:
                # Roll back: replacement hardware rejoins *at the advanced
                # clock*, state restores, and time keeps moving forward
                # through the replay.
                for node in failed:
                    self.registry.revive(node, now)
                state, step = self.restore_fn()
                self.restarts += 1
                if self.restarts > self.max_restarts:
                    raise RuntimeError(
                        f"exceeded max_restarts={self.max_restarts}: a node is "
                        "crash-looping (failure recurs deterministically after "
                        "every restore) — operator intervention required"
                    )
                continue
            state = step_fn(state, step)
            step += 1
            now += step_time
            if step % self.checkpoint_every == 0:
                self.save_fn(step, state)
        return state, step
