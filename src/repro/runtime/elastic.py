"""Elastic scaling: re-mesh plans when the device count changes.

When nodes leave (failure) or join (scale-up), the framework recomputes the
mesh factorization, derives new PartitionSpecs from the same rules, and
reshards the checkpointed state. Because checkpoints are stored as full
logical arrays (host-side npz, see checkpoint/), resharding is just loading
under new shardings — the plan below records what changes so the launcher
can decide whether a restart is worth it.
"""

from __future__ import annotations

import dataclasses
from typing import Optional

__all__ = ["ElasticPlan", "plan_remesh", "scale_batch"]


@dataclasses.dataclass(frozen=True)
class ElasticPlan:
    old_devices: int
    new_devices: int
    new_data: int
    new_model: int
    batch_scale: float  # keep tokens/device constant
    feasible: bool
    reason: str = ""


def _factor(n: int, prefer_model: int) -> Optional[tuple[int, int]]:
    """Factor n into (data, model) keeping model as close to prefer_model
    as possible (model parallelism degree is dictated by memory, not DP)."""
    best = None
    for model in range(min(prefer_model, n), 0, -1):
        if n % model == 0:
            best = (n // model, model)
            break
    return best


def plan_remesh(
    old_data: int, old_model: int, new_devices: int, min_model: int = 1
) -> ElasticPlan:
    old_devices = old_data * old_model
    fac = _factor(new_devices, old_model)
    if fac is None or fac[1] < min_model:
        return ElasticPlan(
            old_devices, new_devices, 0, 0, 0.0, False,
            f"cannot keep model>={min_model} with {new_devices} devices",
        )
    data, model = fac
    return ElasticPlan(
        old_devices=old_devices,
        new_devices=new_devices,
        new_data=data,
        new_model=model,
        batch_scale=(data * model) / old_devices,
        feasible=True,
    )


def scale_batch(global_batch: int, plan: ElasticPlan, multiple: int = 1) -> int:
    """Rescale the global batch to keep per-device tokens ~constant."""
    raw = int(round(global_batch * plan.batch_scale))
    raw = max(multiple, (raw // multiple) * multiple)
    # data-parallel divisibility
    while raw % plan.new_data:
        raw += multiple
    return raw
