"""Distributed runtime: fault tolerance, elastic scaling, stragglers."""

from .elastic import ElasticPlan, plan_remesh, scale_batch
from .fault_tolerance import HeartbeatRegistry, NodeState, TrainingSupervisor
from .straggler import StragglerDetector, degraded_rail_schedule, speculative_dispatch

__all__ = [
    "ElasticPlan",
    "HeartbeatRegistry",
    "NodeState",
    "StragglerDetector",
    "TrainingSupervisor",
    "degraded_rail_schedule",
    "plan_remesh",
    "scale_batch",
    "speculative_dispatch",
]
