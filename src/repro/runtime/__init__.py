"""Distributed runtime: fault tolerance, elastic scaling, stragglers,
and the end-to-end fail-stop failover drill (:mod:`~repro.runtime.failover`)."""

from .elastic import ElasticPlan, plan_remesh, scale_batch
from .failover import (
    DrillReport,
    degraded_alive_matrix,
    degraded_theorem2_bound,
    run_failover_drill,
)
from .fault_tolerance import HeartbeatRegistry, NodeState, TrainingSupervisor
from .straggler import StragglerDetector, degraded_rail_schedule, speculative_dispatch

__all__ = [
    "DrillReport",
    "ElasticPlan",
    "HeartbeatRegistry",
    "NodeState",
    "StragglerDetector",
    "TrainingSupervisor",
    "degraded_alive_matrix",
    "degraded_rail_schedule",
    "degraded_theorem2_bound",
    "plan_remesh",
    "run_failover_drill",
    "scale_batch",
    "speculative_dispatch",
]
