"""Qwen2-VL-72B backbone [arXiv:2409.12191; hf].

VLM: the transformer backbone only — the vision frontend is a stub
(``input_specs`` provides M-RoPE position streams; patch embeddings would
enter through the same embedding interface). M-RoPE splits each head's
rotary spectrum into (temporal, height, width) sections of (16, 24, 24)
frequency pairs for head_dim=128.
"""

from .base import ModelConfig, register

CONFIG = register(
    ModelConfig(
        name="qwen2-vl-72b",
        family="vlm",
        num_layers=80,
        d_model=8192,
        num_heads=64,
        num_kv_heads=8,
        head_dim=128,
        d_ff=29568,
        vocab_size=152064,
        rope_theta=1e6,
        use_mrope=True,
        mrope_sections=(16, 24, 24),
        attn_pattern="full",
    )
)
