"""Architecture registry: one module per assigned architecture."""

from .base import SHAPES, ModelConfig, ShapeSpec, get_config, list_archs, supports_shape

_ARCH_MODULES = (
    "qwen2_vl_72b",
    "deepseek_7b",
    "h2o_danube3_4b",
    "gemma2_9b",
    "phi4_mini_3_8b",
    "zamba2_1_2b",
    "xlstm_125m",
    "mixtral_8x7b",
    "qwen3_moe_30b_a3b",
    "whisper_small",
)


def _load_all() -> None:
    import importlib

    for mod in _ARCH_MODULES:
        importlib.import_module(f"{__name__}.{mod}")


__all__ = [
    "SHAPES",
    "ModelConfig",
    "ShapeSpec",
    "get_config",
    "list_archs",
    "supports_shape",
]
