"""Zamba2-1.2B [arXiv:2411.15242; hf] — hybrid Mamba2 + shared attention.

38 Mamba2 layers with a *weight-shared* attention+MLP block applied every
``shared_attn_period`` Mamba layers (Zamba2's signature design: one global
attention block reused across depth).
"""

from .base import ModelConfig, register

CONFIG = register(
    ModelConfig(
        name="zamba2-1.2b",
        family="hybrid",
        num_layers=38,
        d_model=2048,
        num_heads=32,
        num_kv_heads=32,
        d_ff=8192,
        vocab_size=32000,
        ssm_state=64,
        mamba_expand=2,
        mamba_head_dim=64,
        shared_attn_period=6,
        attn_pattern="full",
    )
)
