"""Whisper-small [arXiv:2212.04356; unverified] — enc-dec audio backbone.

Backbone only: the conv frontend is a STUB — ``input_specs`` provides
precomputed frame embeddings ``(B, encoder_seq, d_model)`` directly to the
encoder; the decoder is a standard causal transformer with cross-attention.
"""

from .base import ModelConfig, register

CONFIG = register(
    ModelConfig(
        name="whisper-small",
        family="audio",
        num_layers=12,
        d_model=768,
        num_heads=12,
        num_kv_heads=12,
        d_ff=3072,
        vocab_size=51865,
        act="gelu",
        is_encoder_decoder=True,
        encoder_layers=12,
        encoder_seq=1500,
        attn_pattern="full",
    )
)
