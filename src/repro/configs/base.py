"""Config system: model configs, input-shape specs, and the arch registry.

Every assigned architecture is a ``ModelConfig`` instance in its own module
(``src/repro/configs/<id>.py``) built from the public-literature numbers in
the assignment. ``reduced()`` derives the family-preserving smoke config
(small dims, few layers/experts) used by CPU tests; the full config is only
ever touched through ``jax.eval_shape`` + the dry-run.
"""

from __future__ import annotations

import dataclasses
from typing import Optional

__all__ = ["ModelConfig", "ShapeSpec", "SHAPES", "supports_shape", "register", "get_config", "list_archs"]


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str  # dense | moe | hybrid | ssm | audio | vlm
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: Optional[int] = None  # defaults to d_model // num_heads

    # attention
    rope_theta: float = 1e4
    attn_pattern: str = "full"  # full | swa | alt_local_global
    sliding_window: Optional[int] = None
    attn_logit_softcap: Optional[float] = None
    final_logit_softcap: Optional[float] = None
    use_mrope: bool = False
    mrope_sections: tuple = ()  # head_dim/2 split across (t, h, w) streams
    use_qk_norm: bool = False

    # ffn
    act: str = "silu"  # silu | gelu

    # moe
    num_experts: int = 0
    experts_per_token: int = 0
    moe_d_ff: int = 0
    router_aux_coef: float = 0.01
    capacity_factor: float = 1.25

    # ssm / hybrid (Mamba2)
    ssm_state: int = 0
    mamba_expand: int = 2
    mamba_head_dim: int = 64
    conv_width: int = 4
    shared_attn_period: int = 0  # zamba2: shared attention every k mamba layers

    # xlstm
    xlstm_pattern: tuple = ()  # per-layer "m" (mLSTM) / "s" (sLSTM)

    # encoder-decoder (whisper)
    is_encoder_decoder: bool = False
    encoder_layers: int = 0
    encoder_seq: int = 1500  # precomputed frame embeddings (frontend stub)

    # norms / embeddings
    rms_eps: float = 1e-6
    tie_embeddings: bool = False
    use_post_norm: bool = False  # gemma2 post-block norms
    embed_scale: bool = False  # gemma scales embeddings by sqrt(d_model)

    # RailS dispatch (MoE all-to-all)
    dispatch_mode: str = "dense"  # dense | ring | rails | spray
    num_rails: int = 4
    dispatch_chunks: int = 2

    # numerics / compilation
    dtype: str = "bfloat16"
    remat: bool = True
    remat_policy: str = "full"  # full | dots (save matmul outputs)
    xent_chunk: int = 2048

    def __post_init__(self):
        if self.head_dim is None:
            object.__setattr__(self, "head_dim", self.d_model // self.num_heads)
        if self.num_heads % max(self.num_kv_heads, 1) != 0:
            raise ValueError(f"{self.name}: num_heads must be divisible by num_kv_heads")
        if self.num_experts and self.moe_d_ff == 0:
            object.__setattr__(self, "moe_d_ff", self.d_ff)

    # -- derived -------------------------------------------------------------

    @property
    def is_moe(self) -> bool:
        return self.num_experts > 0

    @property
    def sub_quadratic(self) -> bool:
        """Eligible for long_500k: SSM/hybrid state or windowed attention."""
        return self.family in ("ssm", "hybrid") or self.attn_pattern in (
            "swa",
            "alt_local_global",
        )

    def param_count(self) -> int:
        """Approximate parameter count (embeddings + blocks)."""
        d, h = self.d_model, self.head_dim
        emb = self.vocab_size * d * (1 if self.tie_embeddings else 2)
        per_layer = 0
        if self.family == "ssm":
            d_in = self.mamba_expand * d
            per_layer = self.num_layers * (3 * d * d_in)  # coarse
        else:
            attn = d * (self.num_heads * h) + 2 * d * (self.num_kv_heads * h) + (self.num_heads * h) * d
            if self.is_moe:
                ffn = self.num_experts * 3 * d * self.moe_d_ff + d * self.num_experts
            else:
                ffn = 3 * d * self.d_ff
            per_layer = self.num_layers * (attn + ffn)
            if self.family == "hybrid":
                d_in = self.mamba_expand * d
                per_layer = self.num_layers * (3 * d * d_in) + attn  # mamba + shared attn
        enc = 0
        if self.is_encoder_decoder:
            enc = self.encoder_layers * (4 * d * d + 2 * d * self.d_ff)
            per_layer += self.num_layers * (4 * d * d)  # cross-attention
        return emb + per_layer + enc

    def active_param_count(self) -> int:
        """Parameters touched per token (MoE: only routed experts)."""
        if not self.is_moe:
            return self.param_count()
        d = self.d_model
        full = self.param_count()
        all_experts = self.num_layers * self.num_experts * 3 * d * self.moe_d_ff
        active = self.num_layers * self.experts_per_token * 3 * d * self.moe_d_ff
        return full - all_experts + active

    def reduced(self) -> "ModelConfig":
        """Family-preserving smoke config for CPU tests."""
        changes = dict(
            num_layers=max(2, 2 * (1 if self.attn_pattern != "alt_local_global" else 1)),
            d_model=128,
            num_heads=4,
            num_kv_heads=2 if self.num_kv_heads < self.num_heads else 4,
            head_dim=32,
            d_ff=256,
            vocab_size=512,
            xent_chunk=64,
        )
        if self.attn_pattern == "alt_local_global":
            changes["num_layers"] = 2
        if self.sliding_window:
            changes["sliding_window"] = 16
        if self.is_moe:
            changes.update(num_experts=4, experts_per_token=2, moe_d_ff=128)
        if self.family in ("ssm", "hybrid"):
            changes.update(ssm_state=16, mamba_head_dim=32)
        if self.shared_attn_period:
            changes.update(num_layers=4, shared_attn_period=2)
        if self.xlstm_pattern:
            changes.update(xlstm_pattern=("m", "s"), num_layers=2)
        if self.is_encoder_decoder:
            changes.update(encoder_layers=2, encoder_seq=8)
        if self.use_mrope:
            changes.update(head_dim=32, mrope_sections=(4, 6, 6))
        return dataclasses.replace(self, **changes)


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode
    num_microbatches: int = 1


#: The assigned input-shape set (applies to every LM arch).
SHAPES = {
    "train_4k": ShapeSpec("train_4k", 4096, 256, "train", num_microbatches=8),
    "prefill_32k": ShapeSpec("prefill_32k", 32768, 32, "prefill", num_microbatches=4),
    "decode_32k": ShapeSpec("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524288, 1, "decode"),
}


def supports_shape(cfg: ModelConfig, shape: ShapeSpec) -> tuple[bool, str]:
    """Cell policy (DESIGN.md §6): long_500k only for sub-quadratic archs."""
    if shape.name == "long_500k" and not cfg.sub_quadratic:
        return False, "pure full-attention arch: long_500k skipped (DESIGN.md §6)"
    return True, ""


_REGISTRY: dict[str, "ModelConfig"] = {}


def register(cfg: ModelConfig) -> ModelConfig:
    _REGISTRY[cfg.name] = cfg
    return cfg


def get_config(name: str) -> ModelConfig:
    if not _REGISTRY:
        from . import _load_all  # lazy import of all arch modules

        _load_all()
    try:
        return _REGISTRY[name]
    except KeyError:
        raise ValueError(f"unknown arch {name!r}; available: {sorted(_REGISTRY)}") from None


def list_archs() -> list[str]:
    if not _REGISTRY:
        from . import _load_all

        _load_all()
    return sorted(_REGISTRY)
