"""xLSTM-125M [arXiv:2405.04517; unverified] — alternating sLSTM/mLSTM blocks.

d_ff=0 in the assignment: the blocks carry their own internal projections
(mLSTM up-projects 2x, sLSTM uses a 4/3 GeGLU), there is no separate FFN.
"""

from .base import ModelConfig, register

CONFIG = register(
    ModelConfig(
        name="xlstm-125m",
        family="ssm",
        num_layers=12,
        d_model=768,
        num_heads=4,
        num_kv_heads=4,
        d_ff=0,
        vocab_size=50304,
        xlstm_pattern=("m", "s") * 6,
        act="gelu",
    )
)
