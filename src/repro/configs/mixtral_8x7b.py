"""Mixtral-8x7B [arXiv:2401.04088; hf] — 8 experts top-2, SWA.

This is the paper's own evaluation model (§VI-F): the MoE dispatch/combine
all-to-alls run in RailS mode (LPT-scheduled rail spraying) by default.
"""

from .base import ModelConfig, register

CONFIG = register(
    ModelConfig(
        name="mixtral-8x7b",
        family="moe",
        num_layers=32,
        d_model=4096,
        num_heads=32,
        num_kv_heads=8,
        d_ff=14336,
        vocab_size=32000,
        rope_theta=1e6,
        attn_pattern="swa",
        sliding_window=4096,
        num_experts=8,
        experts_per_token=2,
        moe_d_ff=14336,
        dispatch_mode="rails",
        num_rails=4,
        dispatch_chunks=2,
    )
)
