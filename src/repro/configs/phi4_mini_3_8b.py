"""Phi-4-mini-3.8B [arXiv:2412.08905; hf] — RoPE + SwiGLU + GQA dense LM."""

from .base import ModelConfig, register

CONFIG = register(
    ModelConfig(
        name="phi4-mini-3.8b",
        family="dense",
        num_layers=32,
        d_model=3072,
        num_heads=24,
        num_kv_heads=8,
        d_ff=8192,
        vocab_size=200064,
        rope_theta=1e4,
        attn_pattern="full",
        tie_embeddings=True,
    )
)
