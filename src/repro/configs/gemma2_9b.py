"""Gemma2-9B [arXiv:2408.00118; hf].

Alternating local(SWA-4096)/global attention, attention- and final-logit
softcapping, GeGLU, post-block norms, tied + scaled embeddings,
head_dim 256 (decoupled from d_model/num_heads).
"""

from .base import ModelConfig, register

CONFIG = register(
    ModelConfig(
        name="gemma2-9b",
        family="dense",
        num_layers=42,
        d_model=3584,
        num_heads=16,
        num_kv_heads=8,
        head_dim=256,
        d_ff=14336,
        vocab_size=256000,
        rope_theta=1e4,
        attn_pattern="alt_local_global",
        sliding_window=4096,
        attn_logit_softcap=50.0,
        final_logit_softcap=30.0,
        act="gelu",
        use_post_norm=True,
        tie_embeddings=True,
        embed_scale=True,
    )
)
