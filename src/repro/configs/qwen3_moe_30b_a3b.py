"""Qwen3-30B-A3B [hf:Qwen/Qwen3-30B-A3B; hf] — 128 experts, top-8, QK-norm.

The richest RailS case: a 128-way expert traffic matrix with top-8 routing
generates the strongest all-to-all imbalance of the assigned pool.
"""

from .base import ModelConfig, register

CONFIG = register(
    ModelConfig(
        name="qwen3-moe-30b-a3b",
        family="moe",
        num_layers=48,
        d_model=2048,
        num_heads=32,
        num_kv_heads=4,
        head_dim=128,
        d_ff=768,
        vocab_size=151936,
        rope_theta=1e6,
        use_qk_norm=True,
        attn_pattern="full",
        num_experts=128,
        experts_per_token=8,
        moe_d_ff=768,
        dispatch_mode="rails",
        num_rails=4,
        dispatch_chunks=2,
    )
)
