import os

os.environ["XLA_FLAGS"] = (
    os.environ.get("REPRO_DRYRUN_XLA_FLAGS")
    or "--xla_force_host_platform_device_count=512"
)

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

The two lines above MUST stay first — jax locks the device count on first
init, and the dry-run (and ONLY the dry-run) needs 512 placeholder devices
so ``jax.make_mesh`` can build the production meshes. Smoke tests and
benches see 1 device.

Usage:
    PYTHONPATH=src python -m repro.launch.dryrun --arch mixtral-8x7b \
        --shape train_4k --multipod 0 --out results/dryrun
    PYTHONPATH=src python -m repro.launch.dryrun --all --out results/dryrun

Per cell this script:
  1. builds the production mesh ((16,16) or (2,16,16)) and the arch's view,
  2. lowers + compiles the step function with explicit in/out shardings,
  3. prints ``compiled.memory_analysis()`` (proves it fits) and
     ``compiled.cost_analysis()`` (FLOPs/bytes for the roofline),
  4. parses collective bytes from the compiled HLO,
  5. writes one JSON blob per cell (consumed by EXPERIMENTS.md tooling).
"""

import argparse
import json
import time
import traceback
from pathlib import Path

import jax
import numpy as np

from repro.configs import SHAPES, get_config, list_archs, supports_shape
from repro.launch.inputs import batch_specs, cache_specs, input_specs
from repro.launch.mesh import make_production_mesh
from repro.launch.steps import (
    abstract_train_state,
    make_decode_step,
    make_prefill_step,
    make_train_step,
)
from repro.parallel.mesh_view import build_mesh_context
from repro.parallel.sharding import opt_state_pspecs, param_pspecs, to_shardings
from repro.roofline.analysis import HW_V5E, collective_bytes, model_flops, roofline_terms
from repro.roofline.hlo_cost import analyze_hlo


def _sds_with(tree, shardings):
    return jax.tree.map(
        lambda s, sh: jax.ShapeDtypeStruct(s.shape, s.dtype, sharding=sh),
        tree,
        shardings,
    )


def run_cell(arch: str, shape_name: str, multi_pod: bool, verbose: bool = True,
             overrides: dict | None = None, microbatches: int | None = None) -> dict:
    import dataclasses

    cfg = get_config(arch)
    if overrides:
        cfg = dataclasses.replace(cfg, **overrides)
    shape = SHAPES[shape_name]
    if microbatches:
        shape = dataclasses.replace(shape, num_microbatches=microbatches)
    ok, why = supports_shape(cfg, shape)
    if not ok:
        return {"arch": arch, "shape": shape_name, "multi_pod": multi_pod,
                "status": "skipped", "reason": why}

    t0 = time.time()
    mesh = make_production_mesh(multi_pod=multi_pod)
    ctx = build_mesh_context(mesh, cfg)
    n_chips = ctx.total_devices

    params_abs, opt_abs = abstract_train_state(cfg)
    p_shard = to_shardings(ctx, param_pspecs(cfg, ctx, params_abs))
    params_sds = _sds_with(params_abs, p_shard)

    if shape.kind == "train":
        opt_spec = opt_state_pspecs(cfg, ctx, params_abs)
        o_shard = to_shardings(ctx, opt_spec)
        opt_sds = _sds_with(opt_abs, o_shard)
        step = make_train_step(cfg, ctx, shape)
        batch = batch_specs(cfg, shape, ctx)
        with ctx.mesh:
            lowered = jax.jit(step, donate_argnums=(0, 1)).lower(
                params_sds, opt_sds, batch
            )
    elif shape.kind == "prefill":
        step = make_prefill_step(cfg, ctx, shape)
        batch = batch_specs(cfg, shape, ctx)
        with ctx.mesh:
            lowered = jax.jit(step).lower(params_sds, batch)
    else:  # decode
        step = make_decode_step(cfg, ctx)
        batch = batch_specs(cfg, shape, ctx)
        cache = cache_specs(cfg, shape, ctx)
        pos = jax.ShapeDtypeStruct((), np.int32)
        with ctx.mesh:
            lowered = jax.jit(step, donate_argnums=(1,)).lower(
                params_sds, cache, batch, pos
            )
    t_lower = time.time() - t0

    t1 = time.time()
    compiled = lowered.compile()
    t_compile = time.time() - t1

    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis()
    hlo = compiled.as_text()
    coll_raw = collective_bytes(hlo)
    # Loop-corrected per-device costs (cost_analysis counts while bodies
    # once — see roofline/hlo_cost.py).
    walked = analyze_hlo(hlo)

    dev_flops = float(walked.flops)
    dev_bytes = float(walked.hbm_bytes)
    dev_coll = float(walked.collective_bytes)
    terms = roofline_terms(dev_flops, dev_bytes, dev_coll)
    tokens = shape.global_batch * (shape.seq_len if shape.kind == "train" else (
        shape.seq_len if shape.kind == "prefill" else 1))
    mf = model_flops(cfg.active_param_count(), tokens,
                     "train" if shape.kind == "train" else "infer")
    useful_ratio = mf / (dev_flops * n_chips) if dev_flops else 0.0

    result = {
        "arch": arch,
        "shape": shape_name,
        "multi_pod": multi_pod,
        "status": "ok",
        "chips": n_chips,
        "mesh_view": {a: int(ctx.mesh.shape[a]) for a in ctx.mesh.axis_names},
        "dispatch_mode": cfg.dispatch_mode if cfg.is_moe else None,
        "lower_s": round(t_lower, 2),
        "compile_s": round(t_compile, 2),
        "memory": {
            "argument_bytes_per_device": mem.argument_size_in_bytes,
            "output_bytes_per_device": mem.output_size_in_bytes,
            "temp_bytes_per_device": mem.temp_size_in_bytes,
            "alias_bytes_per_device": mem.alias_size_in_bytes,
            "peak_estimate_gib": round(
                (mem.argument_size_in_bytes + mem.output_size_in_bytes
                 + mem.temp_size_in_bytes - mem.alias_size_in_bytes) / 2**30, 3),
        },
        "cost": {
            "device_flops": dev_flops,
            "device_dot_flops": float(walked.dot_flops),
            "device_elementwise_flops": float(walked.elementwise_flops),
            "device_bytes": dev_bytes,
            "global_flops": dev_flops * n_chips,
            "raw_cost_analysis_flops": float(cost.get("flops", 0.0)),
        },
        "collectives": {k: float(v) for k, v in walked.collective.items()},
        "collective_bytes_total": dev_coll,
        "collective_op_counts": walked.collective_ops,
        "collectives_raw_unlooped": {
            k: v for k, v in coll_raw.items() if k != "op_counts"
        },
        "roofline": terms,
        "model_flops": mf,
        "useful_flops_ratio": round(useful_ratio, 4),
        "hw": HW_V5E,
    }
    if verbose:
        print(f"[{arch} x {shape_name} x {'multipod' if multi_pod else 'pod'}] "
              f"compile={t_compile:.1f}s peak={result['memory']['peak_estimate_gib']}GiB "
              f"flops/dev={dev_flops:.3e} coll/dev={dev_coll:.3e}B "
              f"dominant={terms['dominant']} bound={terms['bound_s']*1e3:.2f}ms "
              f"useful={useful_ratio:.2f}")
        print("  memory_analysis:", mem)
    result["_hlo_text"] = hlo
    return result


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", type=str, default=None)
    ap.add_argument("--shape", type=str, default=None)
    ap.add_argument("--multipod", type=int, default=0, choices=(0, 1))
    ap.add_argument("--all", action="store_true", help="run every cell")
    ap.add_argument("--out", type=str, default="results/dryrun")
    ap.add_argument("--tag", type=str, default=None, help="output file tag suffix")
    ap.add_argument("--mb", type=int, default=None, help="override microbatches")
    ap.add_argument(
        "--set", action="append", default=[],
        help="config override field=value (repeatable), e.g. --set dispatch_mode=dense",
    )
    args = ap.parse_args()

    overrides: dict = {}
    for kv in args.set:
        k, v = kv.split("=", 1)
        try:
            import ast

            overrides[k] = ast.literal_eval(v)
        except (ValueError, SyntaxError):
            overrides[k] = v

    outdir = Path(args.out)
    outdir.mkdir(parents=True, exist_ok=True)

    cells = []
    if args.all:
        for arch in list_archs():
            for shape in SHAPES:
                for mp in (False, True):
                    cells.append((arch, shape, mp))
    else:
        if not args.arch or not args.shape:
            ap.error("--arch and --shape required (or --all)")
        cells = [(args.arch, args.shape, bool(args.multipod))]

    failures = 0
    for arch, shape, mp in cells:
        tag = f"{arch}__{shape}__{'mp' if mp else 'sp'}"
        if args.tag:
            tag += f"__{args.tag}"
        path = outdir / f"{tag}.json"
        if path.exists():
            print(f"[{tag}] cached, skipping")
            continue
        try:
            result = run_cell(arch, shape, mp, overrides=overrides or None,
                              microbatches=args.mb)
        except Exception as e:  # noqa: BLE001 — record the failure, keep going
            traceback.print_exc()
            result = {"arch": arch, "shape": shape, "multi_pod": mp,
                      "status": "error", "error": f"{type(e).__name__}: {e}"}
            failures += 1
        hlo_text = result.pop("_hlo_text", None)
        if hlo_text is not None:
            import zstandard

            (outdir / f"{tag}.hlo.zst").write_bytes(
                zstandard.ZstdCompressor(level=6).compress(hlo_text.encode())
            )
        path.write_text(json.dumps(result, indent=2))
    if failures:
        raise SystemExit(f"{failures} cells failed")


if __name__ == "__main__":
    main()
