"""Serving driver: batched prefill + autoregressive decode.

Small-scale runnable (CPU, reduced config) and production-mesh lowering
share the same step functions. Requests are batched; decode is a jit'd
single-token step donated in place.

``--sim-fabric`` closes the loop with the RailS simulator: the decode
loop's *real* per-step expert routing counts (MoE archs; uniform synthetic
counts for dense ones) and measured step timestamps are replayed as
release-timed all-to-all rounds through
:func:`repro.sched.serving.simulate_decode_trace`, reporting the p50/p99/
p99.9 per-token fabric latency those decode batches would pay on the
chosen policy — optionally under a degraded fabric (``--sim-fault``).

``--gateway`` runs the overload-control plane instead of the model: a
synthetic request stream through :func:`repro.serve.gateway.run_gateway`
on the simulated fabric (``--slo-ms``, ``--admission-rps``,
``--brownout``, ``--gw-dead-rail``), reporting shed rate, SLO attainment
and goodput. No model or accelerator is touched in this mode.

Example:
    PYTHONPATH=src python -m repro.launch.serve --arch gemma2-9b --reduced \
        --batch 4 --prompt-len 32 --gen 16
    PYTHONPATH=src python -m repro.launch.serve --arch mixtral-8x7b --reduced \
        --batch 2 --prompt-len 8 --gen 8 --sim-fabric --sim-fault degraded
    PYTHONPATH=src python -m repro.launch.serve --arch mixtral-8x7b --gateway \
        --gw-requests 2000 --admission-rps 500 --brownout --gw-dead-rail
"""

from __future__ import annotations

import argparse
import time

import numpy as np

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.launch.steps import make_decode_step, make_prefill_step
from repro.launch.train import make_local_mesh
from repro.models import init_cache, init_params
from repro.parallel.mesh_view import build_mesh_context
from repro.parallel.sharding import param_shardings


def _sim_fault_spec(kind: str, num_rails: int):
    """The --sim-fault presets: the PR-4 fault grid's serving-path cells."""
    if kind == "none":
        return None
    from repro.netsim import FaultSpec, LossConfig, step_profile

    if kind == "loss":
        return FaultSpec(
            loss=LossConfig(rate=0.01, rto=5e-4, bad_rate=0.3,
                            p_enter_bad=0.02, p_leave_bad=0.3),
            seed=11,
        )
    if kind == "degraded":
        return FaultSpec(
            rail_profiles={num_rails - 1: step_profile(0.0, 0.25)},
            loss=LossConfig(rate=0.005, rto=5e-4, bad_rate=0.15,
                            p_enter_bad=0.02, p_leave_bad=0.3),
            seed=11,
        )
    raise ValueError(f"unknown --sim-fault {kind!r}")


def _run_sim_fabric(args, cfg, counts_per_step, releases) -> dict:
    """Replay the recorded decode trace onto the simulated rail fabric."""
    from repro.sched.serving import simulate_decode_trace

    res = simulate_decode_trace(
        counts_per_step,
        releases,
        num_domains=args.sim_domains,
        num_rails=args.sim_rails,
        bytes_per_token=float(cfg.d_model * 2),  # bf16 activations
        policy=args.sim_policy,
        fault_spec=_sim_fault_spec(args.sim_fault, args.sim_rails),
        feedback=args.sim_policy == "rails-online",
    )
    s = res.summary()
    print(
        f"sim-fabric [{args.sim_policy}, fault={args.sim_fault}, "
        f"{args.sim_domains}x{args.sim_rails}]: per-token fabric latency "
        f"p50 {s['p50'] * 1e6:.1f}us p99 {s['p99'] * 1e6:.1f}us "
        f"p99.9 {s['p99.9'] * 1e6:.1f}us"
    )
    return {"summary": s, "token_latency": res.token_latency}


def _run_gateway_mode(args) -> dict:
    """--gateway: the control plane on a synthetic stream, no model."""
    from repro.core.traffic import serve_workload
    from repro.sched.control import AdmissionConfig, BrownoutConfig, ControlConfig
    from repro.serve.gateway import run_gateway

    wl = serve_workload(
        args.sim_domains,
        args.sim_rails,
        num_requests=args.gw_requests,
        mean_gap=args.gw_mean_gap,
        seed=args.seed,
    )
    control = ControlConfig(
        slo_s=args.slo_ms * 1e-3,
        admission=(
            AdmissionConfig(rate_rps=args.admission_rps)
            if args.admission_rps > 0
            else AdmissionConfig()
        ),
        brownout=BrownoutConfig() if args.brownout else None,
    )
    fabric_schedule = None
    if args.gw_dead_rail:
        speeds = np.ones(args.sim_rails)
        speeds[-1] = 0.02  # crawling rail: the vector loop's fail-stop proxy
        fabric_schedule = [(0.0, speeds)]
    res = run_gateway(
        wl,
        args.sim_policy,
        control=control,
        fabric_schedule=fabric_schedule,
        backend="vector",
    )
    s = res.slo
    print(
        f"gateway [{args.sim_policy}, slo={args.slo_ms:.1f}ms, "
        f"dead_rail={args.gw_dead_rail}]: offered {s['offered']} "
        f"shed {s['shed']} ({s['shed_rate']:.1%}) "
        f"slo_attainment {s['slo_attainment']:.1%} "
        f"goodput {s['goodput_rps']:.1f} req/s "
        f"brownout_windows {res.brownout_windows}"
    )
    return {"gateway": res.row(), "windows": len(res.windows)}


def main(argv=None) -> dict:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument(
        "--sim-fabric",
        action="store_true",
        help="replay the decode loop's routing counts + step timing onto "
        "the simulated rail fabric and report per-token p99/p99.9 latency",
    )
    ap.add_argument("--sim-domains", type=int, default=8,
                    help="fabric domains (M) for --sim-fabric")
    ap.add_argument("--sim-rails", type=int, default=8,
                    help="rails per domain (N) for --sim-fabric")
    ap.add_argument("--sim-policy", type=str, default="rails-online",
                    help="load-balancing policy for --sim-fabric")
    ap.add_argument("--sim-fault", choices=("none", "loss", "degraded"),
                    default="none",
                    help="degraded-fabric preset for --sim-fabric")
    ap.add_argument("--gateway", action="store_true",
                    help="run the serving control plane on a synthetic "
                    "request stream (no model); see --slo-ms/--admission-rps")
    ap.add_argument("--gw-requests", type=int, default=1000,
                    help="synthetic request count for --gateway")
    ap.add_argument("--gw-mean-gap", type=float, default=2e-3,
                    help="mean inter-arrival gap (s) for --gateway")
    ap.add_argument("--slo-ms", type=float, default=50.0,
                    help="TTFT SLO in milliseconds for --gateway")
    ap.add_argument("--admission-rps", type=float, default=0.0,
                    help="token-bucket admission rate (req/s) for "
                    "--gateway; 0 = queue/p99 shedding only")
    ap.add_argument("--brownout", action="store_true",
                    help="enable graceful degradation for --gateway")
    ap.add_argument("--gw-dead-rail", action="store_true",
                    help="degrade the last rail to 2%% speed for --gateway")
    args = ap.parse_args(argv)

    if args.gateway:
        return _run_gateway_mode(args)

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    mesh = make_local_mesh()
    ctx = build_mesh_context(mesh, cfg)
    max_len = args.prompt_len + args.gen

    # Real gating counts exist only for MoE archs; --sim-fabric on dense
    # models falls back to uniform synthetic counts (batch tokens spread
    # evenly over 8 pseudo-experts) so the timing replay still works.
    trace_counts = args.sim_fabric and bool(cfg.num_experts)

    key = jax.random.PRNGKey(args.seed)
    with ctx.mesh:
        params = init_params(cfg, key)
        params = jax.tree.map(jax.device_put, params, param_shardings(cfg, ctx, params))
        decode = jax.jit(
            make_decode_step(cfg, ctx, return_counts=trace_counts),
            donate_argnums=(1,),
        )

        rng = np.random.default_rng(args.seed)
        prompts = rng.integers(2, cfg.vocab_size, size=(args.batch, args.prompt_len))
        cache = init_cache(cfg, args.batch, max_len)

        def step(logits_cache_args):
            """One decode call, normalizing the optional counts output."""
            out = decode(*logits_cache_args)
            if trace_counts:
                return out
            logits, new_cache = out
            return logits, new_cache, None

        # Prefill via repeated decode steps (token-at-a-time priming keeps
        # one compiled program; a fused prefill path exists for the dry-run).
        t0 = time.time()
        logits = None
        for pos in range(args.prompt_len):
            batch = {"tokens": jnp.asarray(prompts[:, pos : pos + 1], jnp.int32)}
            logits, cache, _ = step((params, cache, batch, jnp.int32(pos)))
        t_prefill = time.time() - t0

        generated = []
        step_counts: list[np.ndarray] = []
        step_times: list[float] = []
        t1 = time.time()
        tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)[:, None]
        for i in range(args.gen):
            generated.append(np.asarray(tok))
            step_times.append(time.time())
            logits, cache, counts = step(
                (params, cache, {"tokens": tok}, jnp.int32(args.prompt_len + i))
            )
            if counts is not None:
                step_counts.append(np.asarray(counts))
            if args.temperature > 0:
                key, sub = jax.random.split(key)
                tok = jax.random.categorical(sub, logits / args.temperature)[:, None]
            else:
                tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)[:, None]
        t_gen = time.time() - t1

    out_tokens = np.concatenate(generated, axis=1)
    tput = args.batch * args.gen / t_gen if t_gen > 0 else 0.0
    print(f"prefill {args.prompt_len} tok x{args.batch}: {t_prefill:.2f}s")
    print(f"decode {args.gen} tok x{args.batch}: {t_gen:.2f}s  ({tput:.1f} tok/s)")
    print("sample:", out_tokens[0][:12])
    result = {"tokens": out_tokens, "tput": tput}
    if args.sim_fabric and args.gen > 0:
        if not step_counts:
            # Dense arch: uniform synthetic routing (the step's batch
            # tokens spread evenly over enough pseudo-experts to cover
            # every fabric domain), real cadence.
            k = max(8, args.sim_domains)
            step_counts = [np.full(k, args.batch / k) for _ in step_times]
        result["sim_fabric"] = _run_sim_fabric(
            args, cfg, step_counts, np.asarray(step_times)
        )
    return result


if __name__ == "__main__":
    main()
