"""Serving driver: batched prefill + autoregressive decode.

Small-scale runnable (CPU, reduced config) and production-mesh lowering
share the same step functions. Requests are batched; decode is a jit'd
single-token step donated in place.

Example:
    PYTHONPATH=src python -m repro.launch.serve --arch gemma2-9b --reduced \
        --batch 4 --prompt-len 32 --gen 16
"""

from __future__ import annotations

import argparse
import time

import numpy as np

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.launch.steps import make_decode_step, make_prefill_step
from repro.launch.train import make_local_mesh
from repro.models import init_cache, init_params
from repro.parallel.mesh_view import build_mesh_context
from repro.parallel.sharding import param_shardings


def main(argv=None) -> dict:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--temperature", type=float, default=0.0)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    mesh = make_local_mesh()
    ctx = build_mesh_context(mesh, cfg)
    max_len = args.prompt_len + args.gen

    key = jax.random.PRNGKey(args.seed)
    with ctx.mesh:
        params = init_params(cfg, key)
        params = jax.tree.map(jax.device_put, params, param_shardings(cfg, ctx, params))
        decode = jax.jit(make_decode_step(cfg, ctx), donate_argnums=(1,))

        rng = np.random.default_rng(args.seed)
        prompts = rng.integers(2, cfg.vocab_size, size=(args.batch, args.prompt_len))
        cache = init_cache(cfg, args.batch, max_len)

        # Prefill via repeated decode steps (token-at-a-time priming keeps
        # one compiled program; a fused prefill path exists for the dry-run).
        t0 = time.time()
        logits = None
        for pos in range(args.prompt_len):
            batch = {"tokens": jnp.asarray(prompts[:, pos : pos + 1], jnp.int32)}
            logits, cache = decode(params, cache, batch, jnp.int32(pos))
        t_prefill = time.time() - t0

        generated = []
        t1 = time.time()
        tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)[:, None]
        for i in range(args.gen):
            generated.append(np.asarray(tok))
            logits, cache = decode(
                params, cache, {"tokens": tok}, jnp.int32(args.prompt_len + i)
            )
            if args.temperature > 0:
                key, sub = jax.random.split(key)
                tok = jax.random.categorical(sub, logits / args.temperature)[:, None]
            else:
                tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)[:, None]
        t_gen = time.time() - t1

    out_tokens = np.concatenate(generated, axis=1)
    tput = args.batch * args.gen / t_gen if t_gen > 0 else 0.0
    print(f"prefill {args.prompt_len} tok x{args.batch}: {t_prefill:.2f}s")
    print(f"decode {args.gen} tok x{args.batch}: {t_gen:.2f}s  ({tput:.1f} tok/s)")
    print("sample:", out_tokens[0][:12])
    return {"tokens": out_tokens, "tput": tput}


if __name__ == "__main__":
    main()
