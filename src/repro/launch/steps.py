"""Step-function factories: train / prefill / decode, mesh-aware.

``make_train_step`` builds a jit-able ``(params, opt_state, batch) ->
(params, opt_state, metrics)`` with microbatched gradient accumulation
(fp32 accumulator, scanned), remat'd model blocks, and AdamW. Sharding
enters through the ctx-derived ``shard_fn`` + in/out shardings at the jit
boundary (see launch/dryrun.py and launch/train.py).
"""

from __future__ import annotations

import functools
import os
from typing import Optional

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig, ShapeSpec
from ..models import decode_fn, init_params, loss_fn, prefill_fn
from ..optim import AdamWConfig, adamw_init, adamw_update
from ..parallel.mesh_view import MeshContext
from ..parallel.sharding import cache_pspecs, make_shard_fn, param_pspecs, to_shardings

__all__ = [
    "make_train_step",
    "make_prefill_step",
    "make_decode_step",
    "abstract_train_state",
]


def _split_microbatches(batch: dict, n_mb: int) -> dict:
    def split(x):
        b = x.shape[0]
        return x.reshape(n_mb, b // n_mb, *x.shape[1:])

    return {k: split(v) for k, v in batch.items()}


def make_train_step(
    cfg: ModelConfig,
    ctx: MeshContext,
    shape: ShapeSpec,
    opt_cfg: Optional[AdamWConfig] = None,
):
    opt_cfg = opt_cfg or AdamWConfig()
    shard_fn = make_shard_fn(ctx)
    ep_info = ctx.ep_info
    n_mb = shape.num_microbatches

    def mb_loss(params, mb):
        return loss_fn(params, cfg, mb, ep_info, shard_fn)

    # Hillclimb lever (EXPERIMENTS.md §Perf): constrain the fp32 gradient
    # accumulator to the parameter shardings so per-microbatch gradient
    # reduction lowers to reduce-scatter into sharded buffers instead of
    # all-reduce into replicated ones.
    shard_grad_acc = os.environ.get("REPRO_SHARD_GRAD_ACC", "0") == "1"
    grad_shardings = None

    def train_step(params, opt_state, batch):
        batch_mb = _split_microbatches(batch, n_mb)
        g_constrain = (
            (lambda t: jax.tree.map(jax.lax.with_sharding_constraint, t,
                                    to_shardings(ctx, param_pspecs(cfg, ctx, params))))
            if shard_grad_acc
            else (lambda t: t)
        )

        def body(carry, mb):
            g_acc, loss_acc = carry
            (loss, metrics), grads = jax.value_and_grad(mb_loss, has_aux=True)(
                params, mb
            )
            g_acc = g_constrain(jax.tree.map(
                lambda a, g: a + g.astype(jnp.float32) / n_mb, g_acc, grads
            ))
            return (g_acc, loss_acc + loss / n_mb), metrics

        g0 = g_constrain(
            jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
        )
        # Hillclimb lever (EXPERIMENTS.md §Perf): the FSDP weight gathers are
        # loop-invariant but XLA cannot hoist them out of a while body —
        # unrolling the microbatch loop lets CSE share one gather across all
        # microbatches (HLO grows n_mb-fold; collective bytes drop ~n_mb-fold).
        if os.environ.get("REPRO_UNROLL_MB", "0") == "1":
            carry = (g0, jnp.float32(0.0))
            metrics_list = []
            for i in range(n_mb):
                mb = jax.tree.map(lambda a: a[i], batch_mb)
                carry, m = body(carry, mb)
                metrics_list.append(m)
            grads, loss = carry
            metrics = jax.tree.map(lambda *ms: jnp.stack(ms), *metrics_list)
        else:
            (grads, loss), metrics = jax.lax.scan(body, (g0, jnp.float32(0.0)), batch_mb)
        new_params, new_opt, stats = adamw_update(grads, opt_state, params, opt_cfg)
        out_metrics = {
            "loss": loss,
            "nll": jnp.mean(metrics["nll"]),
            "moe_aux": jnp.mean(metrics["moe_aux"]),
            "moe_counts": jnp.sum(metrics["moe_counts"], axis=0),
            **stats,
        }
        return new_params, new_opt, out_metrics

    return train_step


def make_prefill_step(cfg: ModelConfig, ctx: MeshContext, shape: Optional[ShapeSpec] = None):
    shard_fn = make_shard_fn(ctx)
    ep_info = ctx.ep_info
    n_mb = shape.num_microbatches if shape is not None else 1

    def prefill_one(params, batch):
        logits, caches, _aux = prefill_fn(params, cfg, batch, ep_info, shard_fn)
        return logits, caches

    if n_mb == 1:
        return prefill_one

    def prefill_step(params, batch):
        """Batch-chunked prefill: full-sequence transients scale with the
        chunk, not the global request batch (MoE dispatch buffers at 32k
        sequence x 32 batch otherwise dominate the HBM budget)."""
        batch_mb = _split_microbatches(batch, n_mb)

        def body(_, mb):
            return None, prefill_one(params, mb)

        _, (logits, caches) = jax.lax.scan(body, None, batch_mb)
        logits = logits.reshape(-1, logits.shape[-1])
        if caches is not None:
            # (MB, L, Bc, ...) -> (L, MB*Bc, ...); constrain the target
            # layout explicitly or the transpose replicates multi-GiB caches.
            caches = jax.tree.map(
                lambda a: jnp.moveaxis(a, 0, 1).reshape(
                    a.shape[1], a.shape[0] * a.shape[2], *a.shape[3:]
                ),
                caches,
            )
            shardings = to_shardings(ctx, cache_pspecs(cfg, ctx, caches))
            caches = jax.tree.map(jax.lax.with_sharding_constraint, caches, shardings)
        return logits, caches

    return prefill_step


def make_decode_step(cfg: ModelConfig, ctx: MeshContext, return_counts: bool = False):
    """Decode-step factory. ``return_counts=True`` surfaces the step's
    per-expert routed-token counts (``(logits, cache, counts)``) — the
    gating trace `launch/serve.py --sim-fabric` replays onto the
    simulated rail fabric."""
    shard_fn = make_shard_fn(ctx)
    ep_info = ctx.ep_info

    if return_counts:
        def decode_step_counts(params, cache, batch, pos):
            return decode_fn(
                params, cfg, cache, batch["tokens"], pos, ep_info, shard_fn,
                return_counts=True,
            )
        return decode_step_counts

    def decode_step(params, cache, batch, pos):
        logits, new_cache = decode_fn(
            params, cfg, cache, batch["tokens"], pos, ep_info, shard_fn
        )
        return logits, new_cache

    return decode_step


def abstract_train_state(cfg: ModelConfig):
    """(params, opt_state) as ShapeDtypeStructs via eval_shape — no alloc."""
    params = jax.eval_shape(lambda: init_params(cfg, jax.random.PRNGKey(0)))
    opt = jax.eval_shape(lambda: adamw_init(params))
    return params, opt
