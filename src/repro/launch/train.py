"""End-to-end training driver.

Runs the real thing at any scale: on a laptop/CI (``--reduced``, 1 CPU
device) or on the production mesh (``--production``). Wires together data
pipeline, mesh view, sharded train step, async checkpointing and restart.

Example (CPU, ~100M-param class run):
    PYTHONPATH=src python -m repro.launch.train --arch mixtral-8x7b \
        --reduced --steps 200 --batch 8 --seq 128 --ckpt-dir /tmp/ckpt
"""

from __future__ import annotations

import argparse
import dataclasses
import time

import numpy as np

import jax

from repro.checkpoint import Checkpointer, latest_step, restore
from repro.configs import SHAPES, get_config
from repro.configs.base import ShapeSpec
from repro.data import DataConfig, SyntheticTokens
from repro.launch.mesh import make_production_mesh
from repro.compat import make_mesh as compat_make_mesh
from repro.launch.steps import make_train_step
from repro.models import init_params
from repro.optim import AdamWConfig, adamw_init, warmup_cosine
from repro.parallel.mesh_view import build_mesh_context
from repro.parallel.sharding import param_shardings, to_shardings, opt_state_pspecs


def make_local_mesh():
    n = len(jax.devices())
    return compat_make_mesh((n, 1), ("data", "model"))


def main(argv=None) -> dict:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--production", action="store_true")
    ap.add_argument("--multipod", action="store_true")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--microbatches", type=int, default=2)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt-dir", type=str, default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument(
        "--sched-replay",
        action="store_true",
        help="feed per-iteration MoE gating counts to the repro.sched "
        "routing-replay planner and log its all-to-all forecast",
    )
    ap.add_argument("--sched-domains", type=int, default=8,
                    help="fabric domains (M) for the --sched-replay planner")
    ap.add_argument("--sched-rails", type=int, default=8,
                    help="rails per domain (N) for the --sched-replay planner")
    ap.add_argument(
        "--placement",
        choices=["static", "greedy", "lp", "online"],
        default="static",
        help="expert layout for the --sched-replay planner: static "
        "round-robin, a one-shot greedy/LP re-layout planned after "
        "--placement-warmup steps, or the online drift-triggered "
        "migration controller (repro.placement)",
    )
    ap.add_argument(
        "--placement-warmup", type=int, default=10,
        help="gating-count steps accumulated before a one-shot "
        "greedy/lp re-layout is planned",
    )
    ap.add_argument(
        "--fail-at", type=int, default=None,
        help="fail-stop drill: at this step the scheduler is told rail "
        "--fail-rail died (plan cache flushed, next plans over N-1 "
        "rails), and after the loop a full inject→detect→re-spray→"
        "evacuate drill (repro.runtime.failover) reports time-to-detect/"
        "recover and the degraded-CCT ratio",
    )
    ap.add_argument("--fail-rail", type=int, default=1,
                    help="rail index the --fail-at drill kills")
    ap.add_argument(
        "--fail-kind", choices=["rail", "nic", "node"], default="rail",
        help="fail-stop flavor for the --fail-at drill (node drills add "
        "expert evacuation + elastic re-mesh + supervisor rollback legs)",
    )
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    mesh = (
        make_production_mesh(multi_pod=args.multipod)
        if args.production
        else make_local_mesh()
    )
    ctx = build_mesh_context(mesh, cfg)
    shape = ShapeSpec("cli", args.seq, args.batch, "train", args.microbatches)

    opt_cfg = AdamWConfig(
        learning_rate=warmup_cosine(args.lr, min(100, args.steps // 10 + 1), args.steps)
    )
    step_fn = make_train_step(cfg, ctx, shape, opt_cfg)

    key = jax.random.PRNGKey(args.seed)
    with ctx.mesh:
        params = init_params(cfg, key)
        p_sh = param_shardings(cfg, ctx, params)
        params = jax.tree.map(jax.device_put, params, p_sh)
        opt_state = adamw_init(params)
        jit_step = jax.jit(step_fn, donate_argnums=(0, 1))

    data = SyntheticTokens(
        DataConfig(cfg.vocab_size, args.seq, args.batch, seed=args.seed)
    )
    start_step = 0
    ckpt = None
    if args.ckpt_dir:
        ckpt = Checkpointer(args.ckpt_dir)
        if latest_step(args.ckpt_dir) is not None:
            (params, opt_state), start_step = restore(
                args.ckpt_dir, (params, opt_state)
            )
            print(f"restored from step {start_step}")

    # Online-scheduling hook: each iteration's gating counts feed the
    # routing-replay planner, which forecasts and LPT-plans the *next*
    # iteration's expert all-to-all (repro.sched control plane).
    sched_hook = None
    placement_state = None  # (method, warmup_sum) until the one-shot re-layout
    if args.sched_replay and cfg.num_experts:
        from repro.sched import GatingFeedbackHook

        bytes_per_token = float(cfg.d_model * 2)  # bf16 activations
        # One expert's parameter footprint: w1/w2/w3 of the FFN, bf16.
        expert_bytes = float(3 * cfg.d_model * cfg.moe_d_ff * 2)
        controller = None
        if args.placement == "online":
            from repro.placement import OnlinePlacementController, Placement

            controller = OnlinePlacementController(
                Placement.round_robin(
                    cfg.num_experts, args.sched_domains, expert_bytes
                ),
                num_rails=args.sched_rails,
                bytes_per_token=bytes_per_token,
            )
        elif args.placement in ("greedy", "lp"):
            placement_state = (args.placement, expert_bytes, None)
        sched_hook = GatingFeedbackHook(
            num_domains=args.sched_domains,
            num_rails=args.sched_rails,
            bytes_per_token=bytes_per_token,
            controller=controller,
        )

    losses = []
    t0 = time.time()
    with ctx.mesh:
        for step in range(start_step, args.steps):
            batch = {k: jax.numpy.asarray(v) for k, v in data.batch(step).items()}
            params, opt_state, metrics = jit_step(params, opt_state, batch)
            if (
                args.fail_at is not None
                and step == args.fail_at
                and sched_hook is not None
                and args.fail_kind != "node"
            ):
                # The control-plane half of the drill, live: the watchdog
                # verdict reaches the planner, which drops cached plans
                # and LPT-plans every later iteration over the survivors.
                sched_hook.on_rail_failure([args.fail_rail])
                print(
                    f"  failover: rail {args.fail_rail} marked dead at step "
                    f"{step} — plan cache flushed, planning over "
                    f"{int(sched_hook.survivor_mask.sum())} rails"
                )
            if sched_hook is not None and "moe_counts" in metrics:
                counts = np.asarray(metrics["moe_counts"], dtype=np.float64)
                if placement_state is not None:
                    # One-shot greedy/LP re-layout: accumulate gating counts
                    # through the warmup, then fix the searched placement.
                    method, expert_bytes, acc = placement_state
                    acc = counts if acc is None else acc + counts
                    placement_state = (method, expert_bytes, acc)
                    if step - start_step + 1 >= args.placement_warmup:
                        from repro.placement import Placement, search_placement

                        cand = search_placement(
                            acc, args.sched_domains, args.sched_rails,
                            sched_hook.bytes_per_token, method=method,
                            weight_bytes=expert_bytes, score=False,
                        ).placement
                        _, mig_bytes = Placement.round_robin(
                            cfg.num_experts, args.sched_domains, expert_bytes
                        ).migration_to(cand)
                        sched_hook.placement = cand
                        placement_state = None
                        print(
                            f"  placement[{method}]: re-layout after "
                            f"{args.placement_warmup} steps, migrating "
                            f"{mig_bytes / 2**20:.1f}MiB of expert weights"
                        )
                plan = sched_hook.on_step(counts)
                if plan["migrated"]:
                    print(
                        f"  placement[online]: migrated "
                        f"{plan['migration_bytes'] / 2**20:.1f}MiB at step {step}"
                    )
                if step % args.log_every == 0:
                    print(
                        f"  a2a plan: chunk {plan['chunk_bytes'] / 2**20:.2f}MiB "
                        f"send_mse {plan['pred_send_mse']:.2e} "
                        f"opt {plan['opt_time_s'] * 1e3:.2f}ms "
                        f"fc_err {plan['forecast_err']:.2f}"
                    )
            if step % args.log_every == 0 or step == args.steps - 1:
                loss = float(metrics["loss"])
                losses.append((step, loss))
                dt = time.time() - t0
                print(
                    f"step {step:5d} loss {loss:8.4f} nll {float(metrics['nll']):7.4f} "
                    f"gnorm {float(metrics['grad_norm']):7.3f} ({dt:.1f}s)"
                )
            if ckpt and step and step % args.ckpt_every == 0:
                ckpt.save_async(step, (params, opt_state))
        if ckpt:
            ckpt.wait()
            ckpt.save_async(args.steps, (params, opt_state))
            ckpt.wait()
    result = {"losses": losses, "final_loss": losses[-1][1] if losses else None}
    if args.fail_at is not None:
        # Data-plane half of the drill on a reference 4x4 fabric (the
        # full sched fabric would take minutes of DES for no extra
        # signal): inject -> silence-detect -> re-spray -> evacuate.
        from repro.runtime.failover import run_failover_drill

        m = min(args.sched_domains, 4)
        n = min(args.sched_rails, 4)
        report = run_failover_drill(
            num_domains=m,
            num_rails=n,
            fail_kind=args.fail_kind,
            fail_rail=args.fail_rail % n if args.fail_kind != "node" else None,
            fail_domain=m - 1 if args.fail_kind in ("nic", "node") else None,
        )
        ttd = report.time_to_detect
        print(
            f"failover drill [{args.fail_kind}]: "
            f"detect {'n/a' if ttd is None else f'{ttd * 1e3:.3f}ms'} "
            f"recover {report.time_to_recover * 1e3:.3f}ms "
            f"degraded-CCT x{report.degraded_ratio:.3f} of bound "
            f"(tracking x{report.bound_tracking_ratio:.3f}) "
            f"exactly_once={report.exactly_once}"
        )
        if report.evacuation_bytes:
            print(
                f"  evacuated {report.evacuated_experts} experts, "
                f"{report.evacuation_bytes / 2**20:.1f}MiB over survivors; "
                f"remesh feasible={report.elastic.feasible}"
            )
        result["failover_drill"] = report
    return result


if __name__ == "__main__":
    main()
