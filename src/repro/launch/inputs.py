"""ShapeDtypeStruct stand-ins for every model input (dry-run contract).

``input_specs`` returns weak-type-correct, shardable stand-ins — no device
allocation anywhere. For training that's ``{tokens, labels}``; for serving
the request batch (+ the KV/state caches for decode).
"""

from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig, ShapeSpec
from ..models import init_cache
from ..parallel.mesh_view import MeshContext
from ..parallel.sharding import batch_pspecs, cache_pspecs, to_shardings

__all__ = ["batch_specs", "cache_specs", "input_specs"]


def _sds(shape, dtype, sharding=None):
    return jax.ShapeDtypeStruct(shape, dtype, sharding=sharding)


def batch_specs(cfg: ModelConfig, shape: ShapeSpec, ctx: MeshContext) -> dict:
    b, t = shape.global_batch, shape.seq_len
    if shape.kind == "decode":
        t = 1
    specs: dict[str, Any] = {"tokens": _sds((b, t), jnp.int32)}
    if shape.kind == "train":
        specs["labels"] = _sds((b, t), jnp.int32)
    if cfg.use_mrope:
        specs["positions"] = _sds((b, 3, t), jnp.int32)
    if cfg.is_encoder_decoder and shape.kind != "decode":
        specs["embeds"] = _sds((b, cfg.encoder_seq, cfg.d_model), jnp.dtype(cfg.dtype))
    shardings = to_shardings(ctx, batch_pspecs(cfg, ctx, shape))
    return {
        k: _sds(v.shape, v.dtype, shardings.get(k)) for k, v in specs.items()
    }


def cache_specs(cfg: ModelConfig, shape: ShapeSpec, ctx: MeshContext):
    """Decode caches as ShapeDtypeStructs (shapes via eval_shape, no alloc)."""
    cache_shape = jax.eval_shape(
        lambda: init_cache(cfg, shape.global_batch, shape.seq_len)
    )
    shardings = to_shardings(ctx, cache_pspecs(cfg, ctx, cache_shape))
    return jax.tree.map(
        lambda s, sh: _sds(s.shape, s.dtype, sh), cache_shape, shardings
    )


def input_specs(cfg: ModelConfig, shape: ShapeSpec, ctx: MeshContext) -> dict:
    """All inputs for the step function of this (arch x shape) cell."""
    out: dict[str, Any] = {"batch": batch_specs(cfg, shape, ctx)}
    if shape.kind == "decode":
        out["cache"] = cache_specs(cfg, shape, ctx)
        out["pos"] = _sds((), jnp.int32)
    return out
