"""Production meshes (pinned by the multi-pod dry-run contract).

A FUNCTION, not a module-level constant — importing this module never
touches jax device state.
"""

from __future__ import annotations

from repro.compat import make_mesh as compat_make_mesh

__all__ = ["make_production_mesh", "PRODUCTION_SHAPES"]

PRODUCTION_SHAPES = {
    False: ((16, 16), ("data", "model")),
    True: ((2, 16, 16), ("pod", "data", "model")),
}


def make_production_mesh(*, multi_pod: bool = False):
    shape, axes = PRODUCTION_SHAPES[multi_pod]
    return compat_make_mesh(shape, axes)
