"""Launchers: production mesh, dry-run, train/serve drivers.

NOTE: ``dryrun`` sets XLA_FLAGS at import — import it only in a dedicated
process (the ``python -m repro.launch.dryrun`` entry point).
"""

from .mesh import make_production_mesh
from .steps import (
    abstract_train_state,
    make_decode_step,
    make_prefill_step,
    make_train_step,
)

__all__ = [
    "abstract_train_state",
    "make_decode_step",
    "make_prefill_step",
    "make_production_mesh",
    "make_train_step",
]
