"""Array-based exact simulation backend (the ``vector`` backend).

The event engine (:mod:`repro.netsim.events`) pays a Python dispatch per
chunk-hop arrival and per service completion — ~30–50k chunks/s at 512
nodes, far short of the 10⁶-chunk sweeps the RailS regime calls for. This
module computes the *same FIFO dynamics* with numpy array ops and no
per-event Python loop.

**Core identity.** A link is a FIFO server: with jobs sorted in arrival
order, completion times satisfy the prefix recurrence

    c_i = max(a_i, c_{i-1}) + t_i

i.e. ``cumsum(t)`` plus a running max of the idle-gap term — one prefix
scan per link. The closed form's re-associated additions drift in the last
fp bits, so it is used only to *predict* where the ``max`` binds (the
busy-period boundaries); the completions themselves are then seeded
left-to-right ``np.add.accumulate`` runs per busy period — float-op-for-
float-op what the event engine computes — and every predicted boundary is
verified against the exact result (mispredictions repair themselves; see
:func:`_scan_busy_periods`). A t=0 release batch — the offline collective —
short-circuits to one accumulate per link. Total element work is O(F) after
an O(F log F) integer sort. :func:`_scan_wavefront` (one ``max``/add pair
per queue position across all links at once) is the slower oracle the
parity tests compare against.

**Multi-hop paths.** Links are processed in topological *levels* by kind —
``up → l2s → s2l → down`` — so every arrival at a level (release time at
the first hop, previous completion + ``hop_latency`` after) is known before
that level's scan runs, regardless of how many hops each path has (2 for
rail-direct, 4 for spine paths).

**Tie-breaking.** Simultaneous events in the engine resolve by a global
sequence number. The vector backend carries an integer tie key per job:
fabric-entry order (the round-robin assignment sequence) at the first hop,
then per level the lexicographic rank of ``(service start, busy-period
leader)`` — the order in which the engine's finish events would pop.
Identical-size chunk waves (the common LPT case) reproduce the engine's
order exactly; heterogeneous fp ties are astronomically rare and covered by
the parity tests' fp tolerance.

**Struct-of-arrays pipeline.** :func:`build_job_arrays` flow-splits a
traffic matrix straight into :class:`JobArrays` (src/dst/size/release/flow
columns); planner policies fill per-level link-id columns via their
``plan_arrays`` hooks (:mod:`repro.netsim.balancers`); ChunkJob lists are
materialized only for the legacy event engine.
"""

from __future__ import annotations

import dataclasses

import numpy as np

from ..core.plan import split_sizes_vector
from .events import DEFAULT_QS, ChunkJob, cct_percentile_dict
from .topology import RailTopology

__all__ = [
    "NUM_LEVELS",
    "LinkIndex",
    "JobArrays",
    "ArraySimResult",
    "build_job_arrays",
    "chunk_jobs_from_arrays",
    "entry_order_rank",
    "paths_from_jobs",
    "simulate_chunk_arrays",
]

#: Flat-pod topological link levels (the historical four-kind structure);
#: kept as the default for fabrics that predate ``Fabric.level_kinds``.
#: Every path visits at most one link per kind and kinds only ever appear
#: in level order, so each level's arrivals are fully known once the
#: previous levels are scanned — true per fabric for whatever
#: ``level_kinds`` it declares (multi-pod fabrics insert a ``wan`` level).
_LEVEL_OF_KIND = {"up": 0, "l2s": 1, "s2l": 2, "down": 3}
NUM_LEVELS = 4


class LinkIndex:
    """Integer link ids plus rate/level/latency arrays for one fabric.

    The level structure is *per fabric*: ``topo.level_kinds`` (the ordered
    link-kind tuple) defines ``num_levels`` and the kind→level map; the
    flat pod keeps the historical four levels, multi-pod fabrics add a
    ``wan`` level. Also exposes id grids (``up[d, r]``, ``down[d, r]``,
    ``l2s[leaf, s]``, ``s2l[s, leaf]`` and — on multi-pod fabrics —
    ``wan[p, q, lane]``) so planners can gather whole path columns without
    formatting a single link-name string.
    """

    def __init__(self, topo: RailTopology):
        if topo.has_dynamics:
            raise ValueError(
                "vector backend supports constant-profile link models only; "
                "time-varying rails and PFC/ECN/loss need the event engine "
                "(backend='event')"
            )
        self.topo = topo
        names = list(topo.links)
        self.names = names
        self.id_of = {nm: i for i, nm in enumerate(names)}
        self.rate = np.array([topo.links[nm].rate for nm in names])
        self.level_kinds = tuple(
            getattr(topo, "level_kinds", ("up", "l2s", "s2l", "down"))
        )
        self.level_of_kind = {k: i for i, k in enumerate(self.level_kinds)}
        self.num_levels = len(self.level_kinds)
        self.down_level = self.level_of_kind["down"]
        self.level = np.array(
            [self.level_of_kind[nm.split(":", 1)[0]] for nm in names],
            dtype=np.int8,
        )
        # Fixed propagation delay per link, charged after each service
        # (zero except WAN lanes). ``has_latency`` gates the extra adds so
        # flat fabrics stay bit-identical to the historical arithmetic.
        self.latency = np.array([topo.links[nm].latency for nm in names])
        self.has_latency = bool(self.latency.any())
        # Compact ids keep the (F, num_levels) path columns small and let
        # the grouping sort radix over 2 bytes instead of 8.
        self.id_dtype = np.int16 if len(names) < 2**15 else np.int32
        m, n = topo.m, topo.n
        num_pods = getattr(topo, "num_pods", 1)
        self.up = np.array(
            [[self.id_of[f"up:{d}:{r}"] for r in range(n)] for d in range(m)],
            dtype=self.id_dtype,
        )
        self.down = np.array(
            [[self.id_of[f"down:{d}:{r}"] for r in range(n)] for d in range(m)],
            dtype=self.id_dtype,
        )
        # Leaf/spine ids are globalized per pod (pod*n + rail, pod*S + s);
        # cross-pod pairs don't exist and read as -1. The flat pod (one
        # pod) reproduces the historical dense (n, num_spines) grids.
        num_leaves = num_pods * n
        num_spines = num_pods * topo.num_spines
        self.l2s = np.array(
            [
                [self.id_of.get(f"l2s:{lf}:{s}", -1) for s in range(num_spines)]
                for lf in range(num_leaves)
            ],
            dtype=self.id_dtype,
        )
        self.s2l = np.array(
            [
                [self.id_of.get(f"s2l:{s}:{lf}", -1) for lf in range(num_leaves)]
                for s in range(num_spines)
            ],
            dtype=self.id_dtype,
        )
        if num_pods > 1:
            lanes = topo.wan_lanes
            self.wan = np.array(
                [
                    [
                        [
                            self.id_of.get(f"wan:{p}:{q}:{lane}", -1)
                            for lane in range(lanes)
                        ]
                        for q in range(num_pods)
                    ]
                    for p in range(num_pods)
                ],
                dtype=self.id_dtype,
            )
        else:
            self.wan = None

    @property
    def num_links(self) -> int:
        return len(self.names)


@dataclasses.dataclass
class JobArrays:
    """Struct-of-arrays form of one collective's atomic chunks.

    Chunk id is the array index; chunks are ordered exactly like the legacy
    ``build_jobs`` loops — by (src_domain, src_gpu, dst_domain, dst_gpu,
    seq) — so flows and sender groups are contiguous runs.
    """

    src_domain: np.ndarray  # (F,) int32
    src_gpu: np.ndarray  # (F,) int32
    dst_domain: np.ndarray  # (F,) int32
    dst_gpu: np.ndarray  # (F,) int32
    size: np.ndarray  # (F,) float64
    release: np.ndarray  # (F,) float64
    flow_id: np.ndarray  # (F,) int64
    round_id: np.ndarray  # (F,) int64
    num_flows: int  # size of the flow-id space (zero-chunk flows included)

    @property
    def num_chunks(self) -> int:
        return self.size.size


def build_job_arrays(tm, chunk_bytes: float) -> JobArrays:
    """Flow-split ``D1`` straight into :class:`JobArrays` (no ChunkJob).

    Chunk/flow ids replicate the scalar pipeline bit for bit: messages are
    enumerated in C order over ``(d, g, f, gd)``, intra-domain entries stay
    on NVLink (Theorem 1), every positive message consumes a flow id even
    when splitting yields zero chunks (sub-dust remainders).
    """
    d1 = np.asarray(tm.d1, dtype=np.float64)
    m, n = tm.num_domains, tm.num_rails
    flat = d1.reshape(-1)
    d_idx, g_idx, f_idx, gd_idx = np.unravel_index(
        np.arange(flat.size), d1.shape
    )
    valid = (flat > 0) & (d_idx != f_idx)
    msg_sizes = flat[valid]
    counts, chunk_sizes = split_sizes_vector(msg_sizes, chunk_bytes)
    rep = counts
    # Cast to int32 before the repeat: per-message arrays are tiny, the
    # per-chunk ones are not.
    return JobArrays(
        src_domain=np.repeat(d_idx[valid].astype(np.int32), rep),
        src_gpu=np.repeat(g_idx[valid].astype(np.int32), rep),
        dst_domain=np.repeat(f_idx[valid].astype(np.int32), rep),
        dst_gpu=np.repeat(gd_idx[valid].astype(np.int32), rep),
        size=chunk_sizes,
        release=np.zeros(chunk_sizes.size),
        flow_id=np.repeat(np.arange(msg_sizes.size, dtype=np.int64), rep),
        round_id=np.zeros(chunk_sizes.size, dtype=np.int64),
        num_flows=int(msg_sizes.size),
    )


def chunk_jobs_from_arrays(ja: JobArrays) -> dict[tuple[int, int], list[ChunkJob]]:
    """Materialize the legacy per-sender ChunkJob lists (event engine only)."""
    jobs: dict[tuple[int, int], list[ChunkJob]] = {}
    src_d = ja.src_domain.tolist()
    src_g = ja.src_gpu.tolist()
    dst_d = ja.dst_domain.tolist()
    dst_g = ja.dst_gpu.tolist()
    size = ja.size.tolist()
    release = ja.release.tolist()
    flow = ja.flow_id.tolist()
    rnd = ja.round_id.tolist()
    for i in range(ja.num_chunks):
        key = (src_d[i], src_g[i])
        sender = jobs.get(key)
        if sender is None:
            sender = jobs[key] = []
        sender.append(
            ChunkJob(
                chunk_id=i,
                flow_id=flow[i],
                src_domain=src_d[i],
                src_gpu=src_g[i],
                dst_domain=dst_d[i],
                dst_gpu=dst_g[i],
                size=size[i],
                arrival_time=release[i],
                round_id=rnd[i],
            )
        )
    return jobs


def entry_order_rank(
    src_domain: np.ndarray, src_gpu: np.ndarray, num_gpus: int
) -> np.ndarray:
    """Fabric-entry sequence replicating ``Policy.assign_batch`` round-robin.

    Senders are visited in sorted ``(domain, gpu)`` order, one chunk per
    sender per lap — i.e. entry order sorts by (position within sender,
    sender). Requires sender groups to be contiguous runs (the build order
    guarantees it).
    """
    f = src_domain.size
    if f == 0:
        return np.empty(0, dtype=np.int64)
    sender = src_domain.astype(np.int64) * num_gpus + src_gpu
    if np.any(np.diff(sender) < 0):
        raise ValueError("sender groups must be contiguous non-decreasing runs")
    idx = np.arange(f)
    starts, ends = _group_bounds(sender)
    counts = ends - starts
    num_senders = counts.size
    grp_idx = np.repeat(np.arange(num_senders), counts)
    pos = idx - starts[grp_idx]
    max_pos = int(counts.max())
    if num_senders * max_pos <= 4 * f + 1024:
        # Closed form, no sort: rank = (chunks in earlier laps) + (rank of
        # this sender among senders still active in its lap). The dense
        # (sender, lap) activity table is ~F cells for round-robin-ish
        # queues; the guard falls back to a sort for degenerate skew.
        active = counts[:, None] > np.arange(max_pos)[None, :]
        rank_in_lap = np.cumsum(active, axis=0, dtype=np.int64)
        lap_off = np.concatenate(([0], np.cumsum(rank_in_lap[-1])[:-1]))
        rank = lap_off[pos] + rank_in_lap.ravel()[grp_idx * max_pos + pos] - 1
        return rank
    # (pos, sender) pairs are unique per chunk, so one composite-key
    # quicksort replaces the two-key lexsort; positions are bounded by the
    # deepest sender queue, so the composite usually fits 32 bits.
    span = int(sender[-1]) + 1
    composite = pos * span + sender
    if max_pos * span + span < 2**31:
        composite = composite.astype(np.int32)
    order = np.argsort(composite)
    rank = np.empty(f, dtype=np.int64)
    rank[order] = idx
    return rank


def paths_from_jobs(
    ordered_jobs: list[ChunkJob], index: LinkIndex, num_chunks: int
):
    """Arrays from an already-assigned job list (the generic-policy bridge).

    Reactive policies decide chunk-by-chunk against live backlog estimates,
    so their assignment phase stays the Python ``assign_batch``; this
    converts its output — paths plus fabric-entry order — into the columns
    the vector simulator consumes, indexed by chunk id.
    """
    if len(ordered_jobs) != num_chunks:
        raise ValueError("assignment must cover every chunk exactly once")
    link_by_level = np.full(
        (num_chunks, index.num_levels), -1, dtype=index.id_dtype, order="F"
    )
    entry_rank = np.empty(num_chunks, dtype=np.int64)
    id_of = index.id_of
    level = index.level
    for i, job in enumerate(ordered_jobs):
        cid = job.chunk_id
        entry_rank[cid] = i
        for name in job.path:
            li = id_of[name]
            link_by_level[cid, level[li]] = li
    return link_by_level, entry_rank


def _single_link_tail(
    off, a_f, t_f, kb_f, kc_f, comp_f, start_f, lead_b_f, lead_c_f,
    c0, lb0, lc0,
):
    """Finish the last busy link with a scalar recurrence.

    The wavefront loop costs a handful of numpy calls per queue position;
    once only one link remains (extreme receiver skew) that overhead
    dominates, so the remaining positions run as plain float ops — the
    exact ops the event engine performs.
    """
    a = a_f[off:].tolist()
    t = t_f[off:].tolist()
    kb = kb_f[off:].tolist()
    kc = kc_f[off:].tolist()
    comp: list[float] = []
    start: list[float] = []
    lead_b: list[int] = []
    lead_c: list[int] = []
    c = c0
    lb = lb0
    lc = lc0
    for i in range(len(a)):
        ai = a[i]
        if ai >= c:
            s = ai
            lb = kb[i]
            lc = kc[i]
        else:
            s = c
        c = s + t[i]
        start.append(s)
        comp.append(c)
        lead_b.append(lb)
        lead_c.append(lc)
    comp_f[off:] = comp
    start_f[off:] = start
    lead_b_f[off:] = lead_b
    lead_c_f[off:] = lead_c


def _grouped_order(link, arrival, ties):
    """Service order for one level: by link, then (arrival, *ties).

    A global multi-key float lexsort is the naive answer but dominates the
    whole simulation at 10⁶ chunks. Instead: one *small-integer* stable
    argsort groups jobs by link (numpy radix-sorts integer keys — link ids
    fit int16), then each link's queue — a few thousand jobs at most — is
    ordered by a per-link lexsort. Total cost is O(F + F log(F/L)) with
    integer-sort constants.
    """
    # int16 keys cut the radix passes in half; fall back for giant fabrics.
    if link.dtype.itemsize > 2 and int(link.max()) < 2**15:
        link = link.astype(np.int16)
    order = np.argsort(link, kind="stable")
    l_s = link[order]
    starts, ends = _group_bounds(l_s)
    # Pre-gather the sort keys into link-major layout once (per-link slices
    # below are then views), dropping tie columns that are constant — e.g.
    # the opener-arrival column after a t=0 first hop.
    cols = [arrival[order]]
    for t in ties:
        if t[0] != t[-1] or (t != t[0]).any():
            cols.append(t[order])
    cols.reverse()  # lexsort wants least-significant first
    for s, e in zip(starts.tolist(), ends.tolist()):
        if e - s > 1:
            sub = np.lexsort(tuple(c[s:e] for c in cols))
            seg = order[s:e]
            order[s:e] = seg[sub]
    return order


def _level_rank(arrival, ties):
    """Rank of each job in the level-wide (arrival, *ties) total order."""
    f = arrival.size
    r = np.lexsort(tuple(reversed(ties)) + (arrival,))
    rank = np.empty(f, dtype=np.int64)
    rank[r] = np.arange(f)
    return rank


def _group_bounds(l_s):
    """Group start/end offsets of a link-sorted id array."""
    bounds = np.flatnonzero(l_s[1:] != l_s[:-1]) + 1
    starts = np.concatenate(([0], bounds))
    ends = np.concatenate((bounds, [l_s.size]))
    return starts, ends


def _scan_constant_release(link, tie_c, service, a0, need_tie, tie_is_perm):
    """Level scan when every job shares one release instant (a t=0 batch).

    With a single arrival instant a link is never idle after its first
    service, so each queue's completions are one ``np.add.accumulate`` —
    the same left-to-right repeated addition the event engine performs,
    bit for bit — and the whole busy period shares one leader (its first
    chunk). The service order is by (link, tie): when the tie column is a
    permutation of 0..F-1 (the fabric-entry rank at the first hop) an O(F)
    inverse scatter plus one small-integer radix sort replaces the
    composite-key quicksort.
    """
    f = service.size
    if tie_is_perm:
        by_tie = np.empty(f, dtype=np.int64)
        by_tie[tie_c] = np.arange(f)
        key = link[by_tie]
        if key.dtype.itemsize > 2 and int(link.max()) < 2**15:
            key = key.astype(np.int16)
        order = by_tie[np.argsort(key, kind="stable")]
    else:
        # At partial levels tie_c carries opener ranks from the *previous*
        # level's rank space, which can exceed this level's job count —
        # scale by the actual key span so links never interleave, and sort
        # stably: same-link jobs sharing one opener (same busy period
        # upstream) are tie-equivalent, so chunk order breaks the tie
        # deterministically.
        span = int(tie_c.max()) + 1
        order = np.argsort(link.astype(np.int64) * span + tie_c, kind="stable")
    t_s = service[order]
    l_s = link[order]
    comp_s = np.empty(f)
    starts, ends = _group_bounds(l_s)
    if a0 == 0.0:
        # accumulate(t) reproduces c_i = c_{i-1} + t_i exactly (c_0 = 0+t_0).
        for s, e in zip(starts.tolist(), ends.tolist()):
            np.add.accumulate(t_s[s:e], out=comp_s[s:e])
    else:
        for s, e in zip(starts.tolist(), ends.tolist()):
            tmp = np.empty(e - s + 1)
            tmp[0] = a0
            tmp[1:] = t_s[s:e]
            np.add.accumulate(tmp, out=tmp)
            comp_s[s:e] = tmp[1:]
    start_s = np.empty(f)
    start_s[1:] = comp_s[:-1]
    start_s[starts] = a0
    completion = np.empty(f)
    start = np.empty(f)
    completion[order] = comp_s
    start[order] = start_s
    if not need_tie:
        return completion, start, None, None, None
    next_a = np.empty(f, dtype=np.int64)
    next_a[order] = start_s.view(np.int64)
    # One busy period per link -> the leader is the link's first chunk; its
    # arrival is the shared release instant, its rank order is its tie.
    a0_bits = int(np.array(a0, dtype=np.float64).view(np.int64))
    next_b = np.full(f, a0_bits, dtype=np.int64)
    k_s = tie_c[order]
    lead_s = np.repeat(k_s[starts], ends - starts)
    next_c = np.empty(f, dtype=np.int64)
    next_c[order] = lead_s
    return completion, start, next_a, next_b, next_c


def _scan_busy_periods(link, arrival, ties, service, need_tie):
    """General level scan: exact FIFO dynamics via busy-period decomposition.

    The FIFO recurrence ``c_i = max(a_i, c_{i-1}) + t_i`` only branches at
    *busy-period boundaries* (arrivals that find the link idle). Those
    boundaries are first predicted from the closed-form prefix scan
    ``c̃ = cumsum(t) + running_max(a − cumsum(t)_prev)``, then every busy
    period's completions are one seeded left-to-right
    ``np.add.accumulate`` — float-op-for-float-op what the event engine
    computes. The prediction is *verified* against the exact completions
    (the first wrong boundary always reveals itself as an inconsistent
    idle test); the astronomically rare ulp-edge miss falls back to the
    wavefront scan, so exactness never rests on the approximation.

    Short periods (the common case under balanced load — arrivals pace
    service) run as one positional sweep across all periods at once; long
    periods (hot incast links) get individual accumulate calls, of which
    there can only be a few.
    """
    f = arrival.size
    order = _grouped_order(link, arrival, ties)
    l_s = link[order]
    a_s = arrival[order]
    t_s = service[order]
    gstarts, gends = _group_bounds(l_s)
    # Closed-form estimate of the completions, one prefix scan per link.
    s_cum = np.empty(f)
    m_run = np.empty(f)
    for s, e in zip(gstarts.tolist(), gends.tolist()):
        np.add.accumulate(t_s[s:e], out=s_cum[s:e])
    gap = a_s - s_cum + t_s  # a_i - cumsum(t)_{i-1}
    for s, e in zip(gstarts.tolist(), gends.tolist()):
        np.maximum.accumulate(gap[s:e], out=m_run[s:e])
    c_est = s_cum + m_run
    # Predicted busy-period boundaries (idle starts).
    idle = np.empty(f, dtype=bool)
    np.greater_equal(a_s[1:], c_est[:-1], out=idle[1:])
    idle[gstarts] = True
    seg_starts = np.flatnonzero(idle)
    seg_lens = np.diff(np.concatenate((seg_starts, [f])))
    comp_s = _exact_segment_completions(a_s, t_s, idle, seg_starts, seg_lens)
    # Verify every boundary against the exact completions — the first
    # divergence always reveals itself, so links that verify clean are
    # exact and exactness never rests on the estimate. Links with a
    # value-affecting miss are repaired individually.
    mismatch = _settle_boundaries(a_s, comp_s, idle, gstarts)
    if mismatch is not None:
        if mismatch.any():
            bad_groups = np.flatnonzero(np.logical_or.reduceat(mismatch, gstarts))
            for grp in bad_groups.tolist():
                s = int(gstarts[grp])
                e = int(gends[grp])
                comp_s[s:e], idle[s:e] = _repair_link(a_s[s:e], t_s[s:e])
        seg_starts = np.flatnonzero(idle)
        seg_lens = np.diff(np.concatenate((seg_starts, [f])))
    start_s = np.empty(f)
    start_s[1:] = comp_s[:-1]
    np.copyto(start_s, a_s, where=idle)
    completion = np.empty(f)
    start = np.empty(f)
    completion[order] = comp_s
    start[order] = start_s
    if not need_tie:
        return completion, start, None, None, None
    next_a = np.empty(f, dtype=np.int64)
    next_a[order] = start_s.view(np.int64)
    # Leaders are encoded as the opener's (arrival time, level rank): the
    # engine orders the trigger chains of simultaneous service grants by
    # the arrival events that opened the busy periods — arrival *times*
    # compare globally across levels, and the level rank is inductively
    # the opener's own predecessor pop-order key.
    lvl_rank_s = _level_rank(arrival, ties)[order]
    lead_b_s = np.repeat(a_s.view(np.int64)[seg_starts], seg_lens)
    lead_c_s = np.repeat(lvl_rank_s[seg_starts], seg_lens)
    next_b = np.empty(f, dtype=np.int64)
    next_b[order] = lead_b_s
    next_c = np.empty(f, dtype=np.int64)
    next_c[order] = lead_c_s
    return completion, start, next_a, next_b, next_c


def _settle_boundaries(a, comp, idle, starts):
    """Re-test every boundary against the exact completions.

    Mispredictions at exact-equality points (``a == c_prev``) are
    value-neutral — ``max(a, c) + t`` is the same number either way — and
    just adopt the engine's ``>=``-is-idle semantics by flipping ``idle``
    in place. Returns ``None`` when the prediction verified clean (no
    changes at all), else the residual *value-affecting* mismatch mask.
    """
    f = a.size
    check = np.empty(f, dtype=bool)
    np.greater_equal(a[1:], comp[:-1], out=check[1:])
    check[starts] = True
    mismatch = check != idle
    if not mismatch.any():
        return None
    neutral = np.zeros(f, dtype=bool)
    np.equal(a[1:], comp[:-1], out=neutral[1:])
    neutral &= mismatch
    idle |= neutral
    mismatch &= ~neutral
    return mismatch


def _sequential_link(a, t):
    """The plain FIFO recurrence for one link — exact by construction."""
    a_l = a.tolist()
    t_l = t.tolist()
    comp_l: list[float] = []
    idle_l: list[bool] = []
    c = -np.inf
    for i in range(len(a_l)):
        ai = a_l[i]
        if ai >= c:
            st = ai
            idle_l.append(True)
        else:
            st = c
            idle_l.append(False)
        c = st + t_l[i]
        comp_l.append(c)
    return np.array(comp_l), np.array(idle_l, dtype=bool)


def _repair_link(a, t):
    """Exact ``(completions, idle)`` for one link the plain estimate missed.

    The typical customer is a service-paced queue whose arrivals trail (or
    lead) completions by an ulp per chunk — rounding drift between the
    sending and receiving accumulate chains. A re-prediction biased a few
    ulps toward *busy* classifies the trailing chains correctly; whatever
    still fails verification (leading chains inside the ambiguity band)
    runs the sequential recurrence — a couple thousand floats at most.
    """
    n = a.size
    s_cum = np.add.accumulate(t)
    m_run = np.maximum.accumulate(a - s_cum + t)
    c_est = s_cum + m_run
    idle = np.empty(n, dtype=bool)
    idle[0] = True
    np.greater(
        a[1:] - c_est[:-1], 4.0 * np.spacing(np.abs(c_est[:-1])), out=idle[1:]
    )
    seg_starts = np.flatnonzero(idle)
    seg_lens = np.diff(np.concatenate((seg_starts, [n])))
    comp = _exact_segment_completions(a, t, idle, seg_starts, seg_lens)
    mismatch = _settle_boundaries(a, comp, idle, np.zeros(1, dtype=np.int64))
    if mismatch is not None and mismatch.any():
        return _sequential_link(a, t)
    return comp, idle


def _exact_segment_completions(a_s, t_s, idle, seg_starts, seg_lens):
    """Exact completions under a given busy-period segmentation.

    Each period is a seeded left-to-right accumulate; short periods (the
    common case — arrivals pace service) run as one positional sweep
    across all periods, long periods (hot incast links) get individual
    accumulate calls, of which there can only be a few.
    """
    f = a_s.size
    t_seed = np.where(idle, a_s + t_s, t_s)
    comp_s = np.empty(f)
    long_threshold = 512
    long_idx = np.flatnonzero(seg_lens > long_threshold)
    for j in long_idx.tolist():
        s = int(seg_starts[j])
        e = s + int(seg_lens[j])
        np.add.accumulate(t_seed[s:e], out=comp_s[s:e])
    if long_idx.size:
        short = seg_lens <= long_threshold
        ss, sl = seg_starts[short], seg_lens[short]
    else:
        ss, sl = seg_starts, seg_lens
    if ss.size:
        len_order = np.argsort(-sl, kind="stable")
        ss_d = ss[len_order]
        sl_d = sl[len_order]
        kmax = int(sl_d[0])
        widths = np.searchsorted(-sl_d, -np.arange(kmax), side="left")
        for p in range(kmax):
            act = ss_d[: int(widths[p])] + p
            if p == 0:
                comp_s[act] = t_seed[act]
            else:
                comp_s[act] = comp_s[act - 1] + t_seed[act]
    return comp_s


def _busy_clamped(arrival, ties, busy_of_link):
    """Raise arrivals to the carried per-link busy-until, order-preserving.

    Jobs whose arrivals collapse onto one busy-until instant must still be
    served in their *true* arrival order (the engine queued them as they
    came in), so the original ``(arrival, *ties)`` total order is folded
    into a single rank tie key whenever the clamp binds. Returns the
    original inputs untouched when it never does — the all-zeros-carry
    path stays bit-identical to no carry at all.
    """
    clamped = np.maximum(arrival, busy_of_link)
    if np.array_equal(clamped, arrival):
        return arrival, ties, False
    rank = _level_rank(arrival, ties)
    zeros = np.zeros(arrival.size, dtype=np.int64)
    return clamped, (rank, zeros, zeros), True


def _fifo_level_scan(
    link, arrival, ties, service, need_tie=True, tie_is_perm=False
):
    """One topological level: exact FIFO prefix scan over every link at once.

    ``ties`` is the per-job tie-key triple ``(start bits, opener-arrival
    bits, opener rank)`` — zeros/entry-rank at the first hop. Returns
    per-job ``(completion, start, next_a, next_b, next_c)``: the next-level
    triple mirrors the engine's pop order for simultaneous finish events —
    the service start instant first (earlier starts drew earlier sequence
    numbers), then the busy-period opener's arrival time and level rank
    (dequeue-trigger chains bottom out at the arrival event that opened
    the busy period). ``need_tie=False`` (terminal level) skips the
    bookkeeping — nothing downstream consumes it. ``tie_is_perm`` promises
    the rank column is a permutation of 0..F-1 (true for the fabric-entry
    rank), enabling a sort shortcut.
    """
    f = arrival.size
    tie_a, tie_b, tie_c = ties
    if (
        tie_a[0] == 0
        and arrival[0] == arrival[f - 1]
        and not tie_a.any()
        and not tie_b.any()
        and np.all(arrival == arrival[0])
    ):
        return _scan_constant_release(
            link, tie_c, service, float(arrival[0]), need_tie, tie_is_perm
        )
    return _scan_busy_periods(link, arrival, ties, service, need_tie)


def _scan_wavefront(link, arrival, ties, service, need_tie=True):
    """Wavefront oracle scan: one max/add pair per queue position.

    Exact for any input (no boundary prediction involved) but pays a few
    numpy dispatches per queue position; kept as the cross-check oracle
    for the busy-period scan (see the parity tests).
    """
    f = arrival.size
    order = _grouped_order(link, arrival, ties)
    l_s = link[order]
    new_grp = np.empty(f, dtype=bool)
    new_grp[0] = True
    np.not_equal(l_s[1:], l_s[:-1], out=new_grp[1:])
    gid = np.cumsum(new_grp) - 1
    num_groups = int(gid[-1]) + 1
    counts = np.bincount(gid, minlength=num_groups)
    # Wavefront layout: links ordered by descending queue length so the
    # active set at queue position k is always a prefix, and the previous
    # wave's completions/leaders are plain views into the flat outputs.
    grank_order = np.argsort(-counts, kind="stable")
    grank = np.empty(num_groups, dtype=np.int64)
    grank[grank_order] = np.arange(num_groups)
    gstarts = np.concatenate(([0], np.cumsum(counts)[:-1]))
    pos = np.arange(f) - gstarts[gid]
    order2 = np.argsort(pos * num_groups + grank[gid])  # unique composite
    perm = order[order2]
    a_f = arrival[perm]
    t_f = service[perm]
    # Leader bookkeeping: opener arrival bits + opener level rank (the
    # engine's event-sequence order for busy-period openers).
    kb_f = a_f.view(np.int64)
    kc_f = _level_rank(arrival, ties)[perm] if need_tie else ties[2][perm]
    comp_f = np.empty(f)
    start_f = np.empty(f)
    lead_b_f = np.empty(f, dtype=np.int64)
    lead_c_f = np.empty(f, dtype=np.int64)
    counts_desc = counts[grank_order]
    kmax = int(counts_desc[0])
    # Active width per wave, precomputed in one searchsorted.
    ws = np.searchsorted(-counts_desc, -np.arange(kmax), side="left")
    offs = np.concatenate(([0], np.cumsum(ws[:-1])))
    ws_l = ws.tolist()
    offs_l = offs.tolist()
    mask_buf = np.empty(int(ws[0]), dtype=bool)
    poff = 0
    for k in range(kmax):
        w = ws_l[k]
        off = offs_l[k]
        if w == 1:
            _single_link_tail(
                off, a_f, t_f, kb_f, kc_f, comp_f, start_f, lead_b_f, lead_c_f,
                comp_f[poff] if k else -np.inf,
                lead_b_f[poff] if k else 0,
                lead_c_f[poff] if k else 0,
            )
            break
        sl = slice(off, off + w)
        if k == 0:
            start_f[sl] = a_f[sl]
            lead_b_f[sl] = kb_f[sl]
            lead_c_f[sl] = kc_f[sl]
        else:
            a_k = a_f[sl]
            cp = comp_f[poff:poff + w]
            np.maximum(a_k, cp, out=start_f[sl])
            m = np.greater_equal(a_k, cp, out=mask_buf[:w])
            lead_b_f[sl] = lead_b_f[poff:poff + w]
            np.copyto(lead_b_f[sl], kb_f[sl], where=m)
            lead_c_f[sl] = lead_c_f[poff:poff + w]
            np.copyto(lead_c_f[sl], kc_f[sl], where=m)
        np.add(start_f[sl], t_f[sl], out=comp_f[sl])
        poff = off
    completion = np.empty(f)
    start = np.empty(f)
    completion[perm] = comp_f
    start[perm] = start_f
    if not need_tie:
        return completion, start, None, None, None
    # Service starts are non-negative, so their IEEE-754 bit patterns sort
    # like the floats themselves — an integer tie key for free.
    next_a = np.empty(f, dtype=np.int64)
    next_a[perm] = start_f.view(np.int64)
    next_b = np.empty(f, dtype=np.int64)
    next_b[perm] = lead_b_f
    next_c = np.empty(f, dtype=np.int64)
    next_c[perm] = lead_c_f
    return completion, start, next_a, next_b, next_c


@dataclasses.dataclass
class ArraySimResult:
    """Vector-backend counterpart of :class:`SimResult` (no ChunkJob lists).

    Duck-types the surface ``compute_metrics`` and the streaming driver
    touch: ``link_bytes``/``makespan`` fields plus ``cct_percentiles`` /
    ``round_completion_times`` / ``round_sojourn_times``; ``flow_cct``
    materializes a dict lazily for API compatibility. Like the event
    engine, per-flow CCT is the *sojourn* (finish − release) — identical
    float op on both backends, so parity still holds bit for bit.
    """

    finish: np.ndarray  # (F,) per-chunk completion times
    start: np.ndarray  # (F,) first-hop service start times
    link_bytes: dict[str, float]
    makespan: float
    flow_ids: np.ndarray  # present parent-flow ids, chunk order
    flow_finish: np.ndarray  # absolute completion per present flow
    round_ids: np.ndarray  # present round ids
    round_finish: np.ndarray  # absolute completion per present round
    flow_release: np.ndarray  # earliest release per present flow
    round_release: np.ndarray  # earliest release per present round
    # Per-link busy-until times (last service completion; carried input
    # for idle links). Present only when the caller passed ``link_busy`` —
    # the epoch-windowed serving loop chains windows through it.
    link_last: np.ndarray | None = None

    @property
    def flow_sojourn(self) -> np.ndarray:
        return self.flow_finish - self.flow_release

    def cct_percentiles(self, qs=DEFAULT_QS) -> dict[str, float]:
        return cct_percentile_dict(self.flow_sojourn, qs)

    def round_completion_times(self) -> dict[int, float]:
        return {
            int(r): float(t) for r, t in zip(self.round_ids, self.round_finish)
        }

    def round_sojourn_times(self) -> dict[int, float]:
        return {
            int(r): float(t - rel)
            for r, t, rel in zip(self.round_ids, self.round_finish, self.round_release)
        }

    def round_times(self) -> tuple[dict[int, float], dict[int, float]]:
        """(absolute finish, sojourn) per round — already materialized."""
        return self.round_completion_times(), self.round_sojourn_times()

    @property
    def flow_cct(self) -> dict[int, float]:
        return {int(i): float(t) for i, t in zip(self.flow_ids, self.flow_sojourn)}


def _segment_max(values: np.ndarray, keys: np.ndarray):
    """Max of ``values`` over contiguous runs of ``keys`` (chunk order)."""
    if values.size == 0:
        return np.empty(0, dtype=keys.dtype), np.empty(0)
    if keys[0] == keys[-1]:  # single segment (e.g. the offline round id)
        return keys[:1].copy(), np.array([values.max()])
    d = np.diff(keys)
    if np.any(d < 0):
        raise ValueError("segment keys must be non-decreasing in chunk order")
    starts = np.concatenate(([0], np.flatnonzero(d) + 1))
    return keys[starts], np.maximum.reduceat(values, starts)


def _segment_min_like(values: np.ndarray, keys: np.ndarray):
    """Min of ``values`` over the same contiguous key runs as ``_segment_max``.

    Used for per-flow / per-round release times; the key validation
    already happened in the paired ``_segment_max`` call.
    """
    if values.size == 0:
        return np.empty(0)
    if keys[0] == keys[-1]:
        return np.array([values.min()])
    starts = np.concatenate(([0], np.flatnonzero(np.diff(keys)) + 1))
    return np.minimum.reduceat(values, starts)


def simulate_chunk_arrays(
    index: LinkIndex,
    link_by_level: np.ndarray,
    size: np.ndarray,
    release: np.ndarray,
    entry_rank: np.ndarray,
    hop_latency: float = 1e-6,
    flow_id: np.ndarray | None = None,
    round_id: np.ndarray | None = None,
    link_busy: np.ndarray | None = None,
) -> ArraySimResult:
    """Exact FIFO dynamics of one assigned collective, no event loop.

    ``link_by_level`` is ``(F, index.num_levels)`` int link ids (−1 = level
    not on the path); every path must start at level 0 (an up-link) — true
    for the rail-direct, spine and cross-pod WAN families. ``flow_id``/
    ``round_id`` (when given) must be non-decreasing in chunk order, which
    the builders guarantee; ``None`` treats every chunk as its own flow /
    one round. Links with a fixed propagation ``latency`` (WAN lanes)
    charge it after their service completes, on top of ``hop_latency`` —
    the gated extra add keeps zero-latency fabrics bit-identical to the
    historical arithmetic.

    ``link_busy`` is an optional ``(num_links,)`` busy-until carry from a
    previous window: each job's arrival at a link is raised to that link's
    carried busy-until before the scan. For the FIFO recurrence
    ``c_i = max(a_i, c_{i-1}) + t_i`` with carried backlog ``B`` this is
    value-exact — ``c_{i-1} >= B`` for every non-head job, so the clamp
    only binds where ``max(a_0, B)`` would have. The result then reports
    ``link_last`` (per-link last completion, carry-forward for idle
    links), which the epoch-windowed serving loop feeds into the next
    window. An all-zeros carry is bit-identical to ``None``.
    """
    f = size.size
    num_links = index.num_links
    if link_busy is not None:
        link_busy = np.asarray(link_busy, dtype=np.float64)
        if link_busy.shape != (num_links,):
            raise ValueError(
                f"link_busy must be ({num_links},), got {link_busy.shape}"
            )
        link_last = link_busy.copy()
    else:
        link_last = None
    link_volume = np.zeros(num_links)
    finish = np.zeros(f)
    start0 = np.zeros(f)
    if f:
        if np.any(link_by_level[:, 0] < 0):
            raise ValueError("every path must start with an up-link (level 0)")
        # +0.0 normalizes any -0.0 release so start-time bit patterns stay
        # monotone when reused as integer tie keys.
        arrival = np.asarray(release, dtype=np.float64) + 0.0
        tie_a = np.zeros(f, dtype=np.int64)
        tie_b = np.zeros(f, dtype=np.int64)
        tie_c = np.asarray(entry_rank, dtype=np.int64).copy()
        last_level = link_by_level.shape[1] - 1
        for lv in range(link_by_level.shape[1]):
            links = link_by_level[:, lv]
            need_tie = lv < last_level
            if links.min() >= 0:
                # Every chunk visits this level (both columns of rail-only
                # runs) — skip the gather/scatter round trip entirely. At
                # the first hop the tie rank is the entry rank, a
                # permutation by construction.
                arr_lv, ties_lv, clamped = (
                    (arrival, (tie_a, tie_b, tie_c), False)
                    if link_busy is None
                    else _busy_clamped(
                        arrival, (tie_a, tie_b, tie_c), link_busy[links]
                    )
                )
                service = size / index.rate[links]
                comp, sv, na, nb, nc = _fifo_level_scan(
                    links, arr_lv, ties_lv, service,
                    need_tie=need_tie,
                    tie_is_perm=(lv == 0 and not clamped),
                )
                if lv == 0:
                    start0 = sv
                finish = comp
                if need_tie:
                    arrival = comp + hop_latency
                    if index.has_latency:
                        arrival = arrival + index.latency[links]
                    tie_a = na
                    tie_b = nb
                    tie_c = nc
                link_volume += np.bincount(links, weights=size, minlength=num_links)
                if link_last is not None:
                    np.maximum.at(link_last, links, comp)
                continue
            sel = np.flatnonzero(links >= 0)
            if sel.size == 0:
                continue
            l_sel = links[sel]
            sizes_sel = size[sel]
            arr_lv, ties_lv, _clamped = (
                (arrival[sel], (tie_a[sel], tie_b[sel], tie_c[sel]), False)
                if link_busy is None
                else _busy_clamped(
                    arrival[sel],
                    (tie_a[sel], tie_b[sel], tie_c[sel]),
                    link_busy[l_sel],
                )
            )
            service = sizes_sel / index.rate[l_sel]
            comp, sv, na, nb, nc = _fifo_level_scan(
                l_sel, arr_lv, ties_lv, service,
                need_tie=need_tie,
            )
            if lv == 0:
                start0[sel] = sv
            finish[sel] = comp
            if need_tie:
                hop_arrival = comp + hop_latency
                if index.has_latency:
                    hop_arrival = hop_arrival + index.latency[l_sel]
                arrival[sel] = hop_arrival
                tie_a[sel] = na
                tie_b[sel] = nb
                tie_c[sel] = nc
            link_volume += np.bincount(l_sel, weights=sizes_sel, minlength=num_links)
            if link_last is not None:
                np.maximum.at(link_last, l_sel, comp)
    if flow_id is None:
        flow_id = np.arange(f, dtype=np.int64)
    if round_id is None:
        round_id = np.zeros(f, dtype=np.int64)
    release_arr = np.asarray(release, dtype=np.float64)
    flow_ids, flow_finish = _segment_max(finish, np.asarray(flow_id))
    round_ids, round_finish = _segment_max(finish, np.asarray(round_id))
    flow_release = _segment_min_like(release_arr, np.asarray(flow_id))
    round_release = _segment_min_like(release_arr, np.asarray(round_id))
    return ArraySimResult(
        finish=finish,
        start=start0,
        link_bytes={nm: float(v) for nm, v in zip(index.names, link_volume)},
        makespan=float(finish.max()) if f else 0.0,
        flow_ids=flow_ids,
        flow_finish=flow_finish,
        round_ids=round_ids,
        round_finish=round_finish,
        flow_release=flow_release,
        round_release=round_release,
        link_last=link_last,
    )
