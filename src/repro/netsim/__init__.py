"""Discrete-event rail-fabric simulator — the paper's evaluation substrate.

The paper evaluates RailS in a Mininet/SoftRoCE datacenter emulation; this
package provides the deterministic equivalent: an explicit rail topology
(`topology`), a chunk-granularity FIFO queueing engine (`events`), the five
policies of §VI-A (`balancers`), and the paper's metrics (`metrics`).
`simulate.run_collective` is the benchmark entry point.
"""

from .balancers import (
    POLICIES,
    EcmpPolicy,
    MinRttPolicy,
    PlbPolicy,
    Policy,
    RailSPolicy,
    RepsPolicy,
    make_policy,
)
from .events import ChunkJob, Engine, SimResult
from .metrics import CollectiveMetrics, compute_metrics
from .simulate import build_jobs, run_collective, run_policy_suite
from .topology import Link, RailTopology

__all__ = [k for k in dir() if not k.startswith("_")]
