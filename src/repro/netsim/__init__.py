"""Rail-fabric simulator — the paper's evaluation substrate.

The paper evaluates RailS in a Mininet/SoftRoCE datacenter emulation; this
package provides the deterministic equivalent: an explicit rail topology
(`topology`), two parity-locked FIFO simulators — the incremental
discrete-event engine (`events`) and the array prefix-scan backend
(`fastsim`, the offline default: exact dynamics at ~50× the event
throughput) — the five policies of §VI-A plus the streaming `rails-online`
control plane (`balancers`), and the paper's metrics (`metrics`).
`simulate.run_collective` is the offline benchmark entry point (with a
`backend={"event","vector","device"}` switch — `device` is the jitted jax
port of the scans with batched `vmap` sweep execution, see `devicesim`);
`simulate.run_streaming_collective` is its online counterpart (release
times, rail-health feedback, telemetry observers — see `repro.sched`).
`devicesim` itself is imported lazily (first `backend="device"` use) so
the numpy paths never pay the jax import. The pluggable link-dynamics layer
(`linkmodel`) turns the frozen fabric into a scenario generator: per-link
rate profiles (step degradation, flapping optics), PFC pause, ECN marking
with sender rate cuts, Gilbert–Elliott chunk loss with go-back-N recovery,
and fail-stop events (rail/NIC/node down, optional repair) with
exactly-once retry onto surviving rails, all switched through a
`FaultSpec` on the run drivers.
"""

from .balancers import (
    POLICIES,
    EcmpPolicy,
    HierRailSPolicy,
    MinRttPolicy,
    OnlineRailSPolicy,
    PlbPolicy,
    Policy,
    RailSPolicy,
    RepsPolicy,
    make_policy,
)
from .events import ChunkJob, Engine, SimResult
from .linkmodel import (
    CONSTANT,
    ConstantRate,
    EcnConfig,
    FailStopEvent,
    FaultSpec,
    FecConfig,
    GilbertElliott,
    LinkModel,
    LossConfig,
    PfcConfig,
    PiecewiseRate,
    RetryConfig,
    as_link_model,
    flapping_profile,
    speeds_at,
    step_profile,
)
from .fastsim import (
    ArraySimResult,
    JobArrays,
    LinkIndex,
    build_job_arrays,
    chunk_jobs_from_arrays,
    entry_order_rank,
    simulate_chunk_arrays,
)
from .metrics import CollectiveMetrics, compute_metrics
from .simulate import (
    BACKENDS,
    StreamingResult,
    build_jobs,
    build_streaming_jobs,
    run_collective,
    run_policy_suite,
    run_streaming_collective,
)
from .topology import Fabric, Link, MultiPodFabric, RailTopology

__all__ = [k for k in dir() if not k.startswith("_")]
