"""Evaluation metrics (paper §VI-A).

* **CCT** — collective completion time, *release-relative* (sojourn): mean
  / p80 / p95 / p99 / p99.9 / max over parent flows (p99 ≈ total transfer
  completion in the paper). For t=0 one-shot collectives sojourn equals
  the absolute finish time bit for bit.
* **BusBw** — effective bus bandwidth based on *goodput* (unique delivered
  bytes): ``goodput_bytes / makespan`` normalized by the Theorem-1
  aggregate capacity actually available to one domain. Under lossy
  fabrics go-back-N retransmissions re-cross the up-links, so the raw
  wire volume would overstate "achieved" bandwidth — it is kept as the
  separate ``wire_bytes`` / ``wire_bus_bw`` fields instead.
* **NIC TX/RX volumes** — per-(domain, rail) bytes on up/down links (wire
  volume, retransmissions included — this is what the cables carried).
* **Normalized load MSE** — per-domain NIC-load MSE on a 0–1 scale
  (0 = perfectly uniform), paper eq. 6 + §VI-A normalization.
"""

from __future__ import annotations

import dataclasses

import numpy as np

from ..core.lpt import normalized_load_mse
from .events import SimResult
from .topology import RailTopology

__all__ = ["CollectiveMetrics", "compute_metrics"]


@dataclasses.dataclass(frozen=True)
class CollectiveMetrics:
    policy: str
    workload: str
    makespan: float
    cct: dict  # mean/p50/p80/p95/p99/p99.9/max — release-relative sojourn
    bus_bw: float  # bytes/sec achieved (goodput: unique delivered bytes)
    bus_bw_frac: float  # fraction of N*R2 aggregate (one domain's share)
    nic_tx: np.ndarray  # (M, N) bytes sent per NIC
    nic_rx: np.ndarray  # (M, N) bytes received per NIC
    send_mse: float  # worst per-domain normalized MSE (TX)
    recv_mse: float  # worst per-domain normalized MSE (RX)
    opt_time: float  # Theorem-2 lower bound for this workload
    opt_ratio: float  # makespan / opt_time (1.0 = optimal)
    # Goodput vs wire accounting. On a static fabric the two coincide;
    # under loss, wire > goodput by exactly the retransmitted volume.
    goodput_bytes: float = 0.0  # unique delivered bytes
    wire_bytes: float = 0.0  # raw up-link volume (retransmissions included)
    wire_bus_bw: float = 0.0  # wire_bytes / makespan

    def row(self) -> dict:
        return {
            "policy": self.policy,
            "workload": self.workload,
            "makespan_s": self.makespan,
            "cct_mean_s": self.cct["mean"],
            "cct_p99_s": self.cct["p99"],
            "cct_p99.9_s": self.cct["p99.9"],
            "busbw_gbps": self.bus_bw * 8 / 1e9,
            "busbw_frac": self.bus_bw_frac,
            "wire_busbw_gbps": self.wire_bus_bw * 8 / 1e9,
            "send_mse": self.send_mse,
            "recv_mse": self.recv_mse,
            "opt_ratio": self.opt_ratio,
        }


def compute_metrics(
    result: SimResult,
    topo: RailTopology,
    workload_name: str,
    policy_name: str,
    opt_time: float,
) -> CollectiveMetrics:
    m, n = topo.m, topo.n
    nic_tx = np.zeros((m, n))
    nic_rx = np.zeros((m, n))
    for name, volume in result.link_bytes.items():
        # Only NIC lanes are 3-part "kind:domain:rail"; hierarchical
        # fabrics add 4-part "wan:p:q:lane" links, which carry no NIC
        # accounting (their bytes already crossed an up lane).
        kind, *rest = name.split(":")
        if kind == "up":
            nic_tx[int(rest[0]), int(rest[1])] += volume
        elif kind == "down":
            nic_rx[int(rest[0]), int(rest[1])] += volume
    # Up-link volume is the wire view: under lossy FaultSpecs go-back-N
    # retransmissions re-cross the NICs and inflate it past the unique
    # delivered bytes. "Achieved" BusBw is goodput-based; the wire volume
    # stays available as its own field.
    wire_bytes = float(nic_tx.sum())
    dynamics = getattr(result, "dynamics", None)
    goodput = (
        float(dynamics["goodput_bytes"])
        if dynamics is not None and "goodput_bytes" in dynamics
        else wire_bytes
    )
    makespan = result.makespan
    bus_bw = goodput / makespan if makespan > 0 else 0.0
    wire_bus_bw = wire_bytes / makespan if makespan > 0 else 0.0
    # Theorem 1: one domain's aggregate is N*R2; the full fabric carries
    # M domains concurrently, so normalize by M*N*R2 for the fabric view.
    bus_bw_frac = bus_bw / (m * n * topo.r2)
    send_mse = max(
        (normalized_load_mse(nic_tx[d]) for d in range(m) if nic_tx[d].sum() > 0),
        default=0.0,
    )
    recv_mse = max(
        (normalized_load_mse(nic_rx[d]) for d in range(m) if nic_rx[d].sum() > 0),
        default=0.0,
    )
    return CollectiveMetrics(
        policy=policy_name,
        workload=workload_name,
        makespan=makespan,
        cct=result.cct_percentiles(),
        bus_bw=bus_bw,
        bus_bw_frac=bus_bw_frac,
        nic_tx=nic_tx,
        nic_rx=nic_rx,
        send_mse=send_mse,
        recv_mse=recv_mse,
        opt_time=opt_time,
        # A zero-byte collective (e.g. every round fully shed, or all
        # traffic intra-domain) is trivially optimal, not infinitely bad.
        opt_ratio=(
            makespan / opt_time
            if opt_time > 0
            else (1.0 if makespan == 0.0 else float("inf"))
        ),
        goodput_bytes=goodput,
        wire_bytes=wire_bytes,
        wire_bus_bw=wire_bus_bw,
    )
