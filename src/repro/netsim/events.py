"""Discrete-event queueing engine for the rail fabric.

Two phases, mirroring how a real deployment separates *control* (path
decisions from imperfect signals) from *data* (what the fabric actually
does):

**Assignment phase.** Senders are visited round-robin (an all-to-all is a
single synchronized burst); the policy assigns each atomic chunk a path.
Reactive policies estimate congestion from per-link *backlog* counters
(assigned minus transmitted bytes) — their own domain's up-links fresh,
everything remote through a stale snapshot refreshed every ``probe_every``
decisions (RTT-delayed signals; the staleness is what makes reactive
schemes herd under incast, paper §VI-E). RailS ignores the estimates
entirely: its plan is proactive (Theorem 3 + LPT).

**Simulation phase.** A proper discrete-event simulation: every link is a
FIFO server (rate ``R`` bytes/s); chunks enter their first-hop queue at
their release time (``arrival_time``, t=0 for the classic one-shot
collective), are serviced in arrival order, and hop to the next link after
``hop_latency``. Store-and-forward at chunk granularity — pipelining across
chunks of the same flow arises naturally.

**Event-loop structure (hot path).** Earlier revisions kept one heap per
link plus a global completion heap — a heap tuple per chunk per hop.
Arrivals, however, are generated in non-decreasing time order (releases
are injected through a single sorted stream, and hop arrivals inherit the
completion order plus a constant ``hop_latency``), so per-link FIFO queues
are now plain deques with O(1) append/popleft, and only *service
completions* — at most one in flight per link — live in a heap. Event
payloads carry a global sequence number so simultaneous events keep the
deterministic round-robin order of the assignment phase.

**Streaming mode** (:meth:`Engine.run_streaming`) interleaves the two
phases: chunks are only revealed to the policy when they are *released*
(micro-batch boundaries, bursty arrivals), so online policies must decide
with partial information while earlier chunks are still in flight. The
engine notifies registered observers of every link-service interval and
chunk completion — the feed that `repro.sched.feedback` (EWMA rail health)
and `repro.sched.telemetry` (timelines, Chrome traces) consume. Observer
fan-out is pre-resolved into bound-method lists, so a run with no
observers pays a single falsy check per event.

**Flowlet coalescing** (``Engine(coalesce_flowlets=True)``) merges the
chunks of one release batch that share (sender GPU, path) — i.e. the same
(sender, rail, destination) lane — into one service event, cutting event
count by up to the per-lane chunk multiplicity. Member completion times
are reconstructed from the aggregate's final-hop service interval
(chunks drain sequentially at the last link's rate), which is exact for
an uncontended lane and a close approximation under contention; observers
see the merged flowlet, not its members. With coalescing off (the
default) the simulation is event-for-event identical to the reference
semantics — `run_streaming` bit-matches `run` for t=0 releases.
"""

from __future__ import annotations

import dataclasses
import heapq
import itertools
import math
from collections import deque

import numpy as np

from .topology import RailTopology

__all__ = ["ChunkJob", "SimResult", "Engine", "cct_percentile_dict"]

_INF = float("inf")


def cct_percentile_dict(values, qs=(50.0, 80.0, 95.0, 99.0)) -> dict[str, float]:
    """CCT summary dict shared by the event and vector backends.

    Sorting before the mean keeps the summation order (and hence the last
    fp bit) identical no matter which backend produced ``values``. Empty
    collectives (all-zero traffic rows) still report a complete key set so
    downstream tables never KeyError.
    """
    vals = np.sort(np.asarray(values, dtype=np.float64))
    if vals.size == 0:
        return {"mean": 0.0, **{f"p{int(q)}": 0.0 for q in qs}, "max": 0.0}
    out = {"mean": float(vals.mean())}
    for q in qs:
        out[f"p{int(q)}"] = float(np.percentile(vals, q))
    out["max"] = float(vals.max())
    return out


@dataclasses.dataclass(slots=True)
class ChunkJob:
    """One atomic chunk to be transferred.

    ``arrival_time`` is the release time: the chunk does not exist for
    either the policy or the fabric before it (0.0 reproduces the one-shot
    collective). ``round_id`` tags the micro-batch / iteration the chunk
    belongs to in streaming runs. Slotted — the engine allocates one per
    chunk, and 10⁵–10⁶-chunk sweeps are memory- and attribute-access-bound.
    """

    chunk_id: int
    flow_id: int
    src_domain: int
    src_gpu: int
    dst_domain: int
    dst_gpu: int
    size: float
    arrival_time: float = 0.0
    round_id: int = 0
    # Filled by the engine:
    path: list[str] | None = None
    start_time: float = 0.0
    finish_time: float = 0.0


class _Flowlet:
    """Aggregated service unit: same-(sender, path) chunks of one batch.

    Duck-types the ``ChunkJob`` surface the engine and observers touch;
    identity fields come from the first member. Member times are
    reconstructed after the run (see :meth:`Engine._expand_flowlets`).
    """

    __slots__ = (
        "members", "path", "size", "arrival_time", "start_time", "finish_time",
        "chunk_id", "flow_id", "src_domain", "src_gpu", "dst_domain",
        "dst_gpu", "round_id",
    )

    def __init__(self, members: list[ChunkJob]):
        head = members[0]
        self.members = members
        self.path = head.path
        self.size = float(sum(j.size for j in members))
        self.arrival_time = head.arrival_time
        self.start_time = 0.0
        self.finish_time = 0.0
        self.chunk_id = head.chunk_id
        self.flow_id = head.flow_id
        self.src_domain = head.src_domain
        self.src_gpu = head.src_gpu
        self.dst_domain = head.dst_domain
        self.dst_gpu = head.dst_gpu
        self.round_id = head.round_id


@dataclasses.dataclass
class SimResult:
    jobs: list[ChunkJob]
    link_bytes: dict[str, float]
    makespan: float
    flow_cct: dict[int, float]  # per parent-flow completion time

    def cct_percentiles(self, qs=(50.0, 80.0, 95.0, 99.0)) -> dict[str, float]:
        return cct_percentile_dict(list(self.flow_cct.values()), qs)

    def round_completion_times(self) -> dict[int, float]:
        """Finish time of the last chunk of each streaming round.

        Empty job lists yield an empty mapping (no rounds ever released).
        """
        out: dict[int, float] = {}
        for j in self.jobs:
            out[j.round_id] = max(out.get(j.round_id, 0.0), j.finish_time)
        return out


class _FifoNetwork:
    """Incremental FIFO-server network: inject chunks at any time, advance
    the event clock piecewise.

    Three event sources feed one loop, merged by ``(time, seq)``:

    * ``finishes`` — the only heap: service completions, at most one per
      link in flight.
    * ``hop_arrivals`` — deque; completion order is non-decreasing in time
      and ``hop_latency`` is constant, so next-hop arrivals are produced
      already sorted.
    * ``injections`` — deque of released chunks; callers inject in
      non-decreasing release order (the single sorted release stream).

    Per-link queues are deques: arrivals are appended in global time
    order, so FIFO service is a popleft.
    """

    def __init__(self, engine: "Engine"):
        self.eng = engine
        topo = engine.topo
        self.link_queue: dict[str, deque] = {k: deque() for k in topo.links}
        self.link_busy: dict[str, bool] = {k: False for k in topo.links}
        self.link_rate: dict[str, float] = {k: l.rate for k, l in topo.links.items()}
        self.finishes: list = []  # heap of (finish, seq, job, hop, link, start)
        self.hop_arrivals: deque = deque()  # (t, seq, job, hop)
        self.injections: deque = deque()  # (t, seq, job)
        self._seq = itertools.count()
        self.now = 0.0

    def inject(self, job, t: float) -> None:
        t = max(t, job.arrival_time)
        if self.injections and t < self.injections[-1][0]:
            raise ValueError("injections must arrive in non-decreasing time order")
        self.injections.append((t, next(self._seq), job))

    def _start(self, link: str, job, hop: int, t: float) -> None:
        self.link_busy[link] = True
        if hop == 0:
            job.start_time = t
        finish = t + job.size / self.link_rate[link]
        self.eng.link_bytes[link] += job.size
        heapq.heappush(self.finishes, (finish, next(self._seq), job, hop, link, t))

    def advance_to(self, horizon: float) -> None:
        """Process all events strictly before ``horizon``."""
        self._run(horizon)
        self.now = max(self.now, horizon)

    def drain(self) -> None:
        self._run(None)

    def _run(self, horizon: float | None) -> None:
        """The event loop: pop (time, seq)-ordered events until ``horizon``
        (exclusive; ``None`` = until idle). Locals are bound once — this
        loop runs once per chunk-hop arrival and once per service finish."""
        finishes = self.finishes
        arrivals = self.hop_arrivals
        injections = self.injections
        link_queue = self.link_queue
        link_busy = self.link_busy
        eng = self.eng
        transmitted = eng.transmitted_bytes
        service_cbs = eng._service_cbs
        completion_cbs = eng._completion_cbs
        hop_latency = eng.hop_latency
        heappop = heapq.heappop
        seq = self._seq
        start = self._start
        bound = _INF if horizon is None else horizon
        while True:
            t_f = finishes[0][0] if finishes else _INF
            s_f = finishes[0][1] if finishes else 0
            t_a, s_a = (arrivals[0][0], arrivals[0][1]) if arrivals else (_INF, 0)
            t_i, s_i = (injections[0][0], injections[0][1]) if injections else (_INF, 0)
            # Earliest of the three sources, ties by global sequence.
            if t_a < t_i or (t_a == t_i and s_a < s_i):
                t_n, s_n, src = t_a, s_a, 1
            else:
                t_n, s_n, src = t_i, s_i, 2
            if t_f < t_n or (t_f == t_n and s_f < s_n):
                t_n, src = t_f, 0
            if t_n >= bound:
                return
            if src == 0:
                t, _s, job, hop, link, started = heappop(finishes)
                self.now = t
                link_busy[link] = False
                transmitted[link] += job.size
                # Observers hear about the service interval only once it
                # has finished — a real controller cannot measure an
                # in-flight transfer's rate before the transfer completes.
                if service_cbs:
                    for cb in service_cbs:
                        cb(link, started, t, job)
                path = job.path
                if hop + 1 < len(path):
                    arrivals.append((t + hop_latency, next(seq), job, hop + 1))
                else:
                    job.finish_time = t
                    if completion_cbs:
                        for cb in completion_cbs:
                            cb(job, t)
                q = link_queue[link]
                if q:
                    job2, hop2 = q.popleft()
                    start(link, job2, hop2, t)
            else:
                if src == 1:
                    t, _s, job, hop = arrivals.popleft()
                else:
                    t, _s, job = injections.popleft()
                    hop = 0
                self.now = t
                link = job.path[hop]
                if link_busy[link]:
                    link_queue[link].append((job, hop))
                else:
                    start(link, job, hop, t)


class Engine:
    def __init__(
        self,
        topo: RailTopology,
        hop_latency: float = 1e-6,
        probe_every: int = 64,
        seed: int = 0,
        observers: tuple = (),
        coalesce_flowlets: bool = False,
    ):
        self.topo = topo
        self.hop_latency = hop_latency
        self.probe_every = probe_every
        self.coalesce_flowlets = coalesce_flowlets
        self.rng = np.random.default_rng(seed)
        self.assigned_bytes: dict[str, float] = {k: 0.0 for k in topo.links}
        self.transmitted_bytes: dict[str, float] = {k: 0.0 for k in topo.links}
        self._snapshot: dict[str, float] = dict(self.assigned_bytes)
        self.link_bytes: dict[str, float] = {k: 0.0 for k in topo.links}
        # Pre-parsed link metadata: the up-link's domain (or -1) and the
        # rate, so the per-chunk estimate path never splits strings.
        self._up_domain: dict[str, int] = {}
        self._link_rate: dict[str, float] = {}
        for name, link in topo.links.items():
            parts = name.split(":")
            self._up_domain[name] = int(parts[1]) if parts[0] == "up" else -1
            self._link_rate[name] = link.rate
        self._decisions = 0
        self._flowlets: list[_Flowlet] = []
        # Observers receive (link, start, end, job) service intervals and
        # (job, t) completions — telemetry and feedback estimators hook
        # here. Callbacks are resolved once so the no-observer hot path is
        # a single falsy check per event.
        self.observers: list = []
        self._service_cbs: list = []
        self._completion_cbs: list = []
        for obs in observers:
            self.add_observer(obs)

    # -- observer fan-out -----------------------------------------------------

    def add_observer(self, obs) -> None:
        self.observers.append(obs)
        record = getattr(obs, "record_service", None)
        if record is not None:
            self._service_cbs.append(record)
        record = getattr(obs, "record_completion", None)
        if record is not None:
            self._completion_cbs.append(record)

    def _notify_service(self, link: str, start: float, end: float, job) -> None:
        for cb in self._service_cbs:
            cb(link, start, end, job)

    def _notify_completion(self, job, t: float) -> None:
        for cb in self._completion_cbs:
            cb(job, t)

    # -- state the policies may query (assignment-phase estimates) ----------

    def queue_delay(self, link: str, now: float = 0.0, fresh: bool = True) -> float:
        """Estimated seconds of backlog on ``link``: assigned minus already
        transmitted bytes. The stale view is the backlog *as of the last
        snapshot* — both counters frozen together, the way a delayed probe
        reports a consistent (if old) reading. In the one-shot collective
        nothing has been transmitted during assignment, so both views
        equal the assigned-bytes estimate."""
        if fresh:
            backlog = self.assigned_bytes[link] - self.transmitted_bytes[link]
        else:
            backlog = self._snapshot[link]
        return max(backlog, 0.0) / self.topo.links[link].rate

    def path_delay(self, path: list[str], src_domain: int, now: float = 0.0) -> float:
        """Estimated waiting along a path: fresh for the sender's own
        up-links, stale snapshot for everything remote."""
        assigned = self.assigned_bytes
        transmitted = self.transmitted_bytes
        snapshot = self._snapshot
        up_domain = self._up_domain
        rate = self._link_rate
        total = 0.0
        for link in path:
            if up_domain[link] == src_domain:
                backlog = assigned[link] - transmitted[link]
            else:
                backlog = snapshot[link]
            if backlog > 0.0:
                total += backlog / rate[link]
        return total

    def _commit(self, job, path: list[str]) -> None:
        job.path = path
        size = job.size
        assigned = self.assigned_bytes
        for link in path:
            assigned[link] += size
        self._decisions += 1
        if self._decisions % self.probe_every == 0:
            transmitted = self.transmitted_bytes
            self._snapshot = {k: assigned[k] - transmitted[k] for k in assigned}

    # -- flowlet coalescing ---------------------------------------------------

    def _coalesce(self, batch: list[ChunkJob]) -> list:
        """Merge same-(sender GPU, path) chunks of one release batch into
        flowlets; singletons pass through untouched. Order of first
        appearance is preserved so fabric entry stays deterministic."""
        groups: dict[tuple, list[ChunkJob]] = {}
        keys: list[tuple] = []
        for j in batch:
            k = (j.src_domain, j.src_gpu, tuple(j.path))
            g = groups.get(k)
            if g is None:
                groups[k] = [j]
                keys.append(k)
            else:
                g.append(j)
        out: list = []
        for k in keys:
            g = groups[k]
            if len(g) == 1:
                out.append(g[0])
            else:
                flowlet = _Flowlet(g)
                self._flowlets.append(flowlet)
                out.append(flowlet)
        return out

    def _expand_flowlets(self) -> None:
        """Reconstruct member chunk times from each finished flowlet: the
        members drain back-to-back at the final link's rate, ending at the
        flowlet's completion."""
        for fl in self._flowlets:
            rate = self.topo.links[fl.path[-1]].rate
            remaining = fl.size
            t_end = fl.finish_time
            for j in fl.members:
                j.start_time = fl.start_time
                remaining -= j.size
                j.finish_time = t_end - remaining / rate
        self._flowlets.clear()

    # -- orchestration --------------------------------------------------------

    def run(self, jobs_by_sender: dict[tuple[int, int], list[ChunkJob]], policy) -> SimResult:
        """One-shot collective: assign everything, then simulate."""
        # Phase 1: the whole collective is one release batch; the policy's
        # assign_batch fixes the round-robin fabric-entry order.
        all_jobs: list[ChunkJob] = policy.assign_batch(self, jobs_by_sender, now=0.0)
        # Phase 2: discrete-event FIFO simulation.
        net = _FifoNetwork(self)
        sim_jobs = self._coalesce(all_jobs) if self.coalesce_flowlets else all_jobs
        # Stable sort keeps assignment order among equal release times (the
        # whole batch, in the t=0 one-shot case).
        for job in sorted(sim_jobs, key=lambda j: j.arrival_time):
            net.inject(job, job.arrival_time)
        net.drain()
        if self._flowlets:
            self._expand_flowlets()
        return self._result(all_jobs)

    def run_streaming(
        self, jobs_by_sender: dict[tuple[int, int], list[ChunkJob]], policy
    ) -> SimResult:
        """Streaming collective: chunks are revealed at their release time.

        All chunks sharing one release instant form a *batch*: the policy
        assigns the whole batch at once (so a planner can LPT over it),
        senders visited round-robin exactly as in the one-shot phase — with
        every release at t=0 this reproduces :meth:`run` event-for-event.
        The network is advanced to each release time first, so completion
        feedback observed by then is available to the policy.
        """
        releases: dict[float, dict[tuple[int, int], list[ChunkJob]]] = {}
        for key, jobs in jobs_by_sender.items():
            for j in jobs:
                releases.setdefault(j.arrival_time, {}).setdefault(key, []).append(j)
        net = _FifoNetwork(self)
        all_jobs: list[ChunkJob] = []
        for t in sorted(releases):
            if not math.isfinite(t):
                raise ValueError(f"non-finite release time {t!r}")
            net.advance_to(t)
            batch = policy.assign_batch(self, releases[t], now=t)
            all_jobs.extend(batch)
            sim_batch = self._coalesce(batch) if self.coalesce_flowlets else batch
            for job in sim_batch:
                net.inject(job, t)
        net.drain()
        if self._flowlets:
            self._expand_flowlets()
        return self._result(all_jobs)

    def _result(self, all_jobs: list[ChunkJob]) -> SimResult:
        flow_cct: dict[int, float] = {}
        for j in all_jobs:
            prev = flow_cct.get(j.flow_id)
            if prev is None or j.finish_time > prev:
                flow_cct[j.flow_id] = j.finish_time
        makespan = max((j.finish_time for j in all_jobs), default=0.0)
        return SimResult(
            jobs=all_jobs,
            link_bytes=dict(self.link_bytes),
            makespan=makespan,
            flow_cct=flow_cct,
        )
