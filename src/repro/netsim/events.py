"""Discrete-event queueing engine for the rail fabric.

Two phases, mirroring how a real deployment separates *control* (path
decisions from imperfect signals) from *data* (what the fabric actually
does):

**Assignment phase.** Senders are visited round-robin (an all-to-all is a
single synchronized burst); the policy assigns each atomic chunk a path.
Reactive policies estimate congestion from per-link *backlog* counters
(assigned minus transmitted bytes) — their own domain's up-links fresh,
everything remote through a stale snapshot refreshed every ``probe_every``
decisions (RTT-delayed signals; the staleness is what makes reactive
schemes herd under incast, paper §VI-E). RailS ignores the estimates
entirely: its plan is proactive (Theorem 3 + LPT).

**Simulation phase.** A proper discrete-event simulation: every link is a
FIFO server (rate ``R`` bytes/s); chunks enter their first-hop queue at
their release time (``arrival_time``, t=0 for the classic one-shot
collective), are serviced in arrival order, and hop to the next link after
``hop_latency``. Store-and-forward at chunk granularity — pipelining across
chunks of the same flow arises naturally.

**Streaming mode** (:meth:`Engine.run_streaming`) interleaves the two
phases: chunks are only revealed to the policy when they are *released*
(micro-batch boundaries, bursty arrivals), so online policies must decide
with partial information while earlier chunks are still in flight. The
engine notifies registered observers of every link-service interval and
chunk completion — the feed that `repro.sched.feedback` (EWMA rail health)
and `repro.sched.telemetry` (timelines, Chrome traces) consume.
"""

from __future__ import annotations

import dataclasses
import heapq
import itertools
import math

import numpy as np

from .topology import RailTopology

__all__ = ["ChunkJob", "SimResult", "Engine"]


@dataclasses.dataclass
class ChunkJob:
    """One atomic chunk to be transferred.

    ``arrival_time`` is the release time: the chunk does not exist for
    either the policy or the fabric before it (0.0 reproduces the one-shot
    collective). ``round_id`` tags the micro-batch / iteration the chunk
    belongs to in streaming runs.
    """

    chunk_id: int
    flow_id: int
    src_domain: int
    src_gpu: int
    dst_domain: int
    dst_gpu: int
    size: float
    arrival_time: float = 0.0
    round_id: int = 0
    # Filled by the engine:
    path: list[str] | None = None
    start_time: float = 0.0
    finish_time: float = 0.0


@dataclasses.dataclass
class SimResult:
    jobs: list[ChunkJob]
    link_bytes: dict[str, float]
    makespan: float
    flow_cct: dict[int, float]  # per parent-flow completion time

    def cct_percentiles(self, qs=(50.0, 80.0, 95.0, 99.0)) -> dict[str, float]:
        vals = np.array(sorted(self.flow_cct.values()))
        out = {"mean": float(vals.mean())}
        for q in qs:
            out[f"p{int(q)}"] = float(np.percentile(vals, q))
        out["max"] = float(vals.max())
        return out

    def round_completion_times(self) -> dict[int, float]:
        """Finish time of the last chunk of each streaming round."""
        out: dict[int, float] = {}
        for j in self.jobs:
            out[j.round_id] = max(out.get(j.round_id, 0.0), j.finish_time)
        return out


class _FifoNetwork:
    """Incremental FIFO-server network: inject chunks at any time, advance
    the event clock piecewise. Extracted from the one-shot simulation so
    streaming releases can interleave with in-flight service."""

    def __init__(self, engine: "Engine"):
        self.eng = engine
        topo = engine.topo
        self.link_queue: dict[str, list] = {k: [] for k in topo.links}
        self.link_busy: dict[str, bool] = {k: False for k in topo.links}
        self.events: list = []  # heap of (finish, seq, job, hop, link, start)
        self._seq = itertools.count()
        self.now = 0.0

    def inject(self, job: ChunkJob, t: float) -> None:
        self._arrive(max(t, job.arrival_time), job, 0)

    def _arrive(self, t: float, job: ChunkJob, hop: int) -> None:
        assert job.path is not None
        link = job.path[hop]
        heapq.heappush(self.link_queue[link], (t, next(self._seq), job, hop))
        self._maybe_start(link, t)

    def _maybe_start(self, link: str, t: float) -> None:
        if self.link_busy[link] or not self.link_queue[link]:
            return
        arr, _s, job, hop = heapq.heappop(self.link_queue[link])
        self.link_busy[link] = True
        if hop == 0:
            job.start_time = t
        finish = t + job.size / self.eng.topo.links[link].rate
        self.eng.link_bytes[link] += job.size
        heapq.heappush(self.events, (finish, next(self._seq), job, hop, link, t))

    def advance_to(self, horizon: float) -> None:
        """Process all service completions strictly before ``horizon``."""
        while self.events and self.events[0][0] < horizon:
            self._step()
        self.now = max(self.now, horizon)

    def drain(self) -> None:
        while self.events:
            self._step()

    def _step(self) -> None:
        t, _s, job, hop, link, started = heapq.heappop(self.events)
        self.now = t
        self.link_busy[link] = False
        self.eng.transmitted_bytes[link] += job.size
        # Observers hear about the service interval only once it has
        # finished — a real controller cannot measure an in-flight
        # transfer's rate before the transfer completes.
        self.eng._notify_service(link, started, t, job)
        assert job.path is not None
        if hop + 1 < len(job.path):
            self._arrive(t + self.eng.hop_latency, job, hop + 1)
        else:
            job.finish_time = t
            self.eng._notify_completion(job, t)
        self._maybe_start(link, t)


class Engine:
    def __init__(
        self,
        topo: RailTopology,
        hop_latency: float = 1e-6,
        probe_every: int = 64,
        seed: int = 0,
        observers: tuple = (),
    ):
        self.topo = topo
        self.hop_latency = hop_latency
        self.probe_every = probe_every
        self.rng = np.random.default_rng(seed)
        self.assigned_bytes: dict[str, float] = {k: 0.0 for k in topo.links}
        self.transmitted_bytes: dict[str, float] = {k: 0.0 for k in topo.links}
        self._snapshot: dict[str, float] = dict(self.assigned_bytes)
        self.link_bytes: dict[str, float] = {k: 0.0 for k in topo.links}
        self._decisions = 0
        # Observers receive (link, start, end, job) service intervals and
        # (job, t) completions — telemetry and feedback estimators hook here.
        self.observers: list = list(observers)

    # -- observer fan-out -----------------------------------------------------

    def add_observer(self, obs) -> None:
        self.observers.append(obs)

    def _notify_service(self, link: str, start: float, end: float, job: ChunkJob) -> None:
        for obs in self.observers:
            record = getattr(obs, "record_service", None)
            if record is not None:
                record(link, start, end, job)

    def _notify_completion(self, job: ChunkJob, t: float) -> None:
        for obs in self.observers:
            record = getattr(obs, "record_completion", None)
            if record is not None:
                record(job, t)

    # -- state the policies may query (assignment-phase estimates) ----------

    def queue_delay(self, link: str, now: float = 0.0, fresh: bool = True) -> float:
        """Estimated seconds of backlog on ``link``: assigned minus already
        transmitted bytes. The stale view is the backlog *as of the last
        snapshot* — both counters frozen together, the way a delayed probe
        reports a consistent (if old) reading. In the one-shot collective
        nothing has been transmitted during assignment, so both views
        equal the assigned-bytes estimate."""
        if fresh:
            backlog = self.assigned_bytes[link] - self.transmitted_bytes[link]
        else:
            backlog = self._snapshot[link]
        return max(backlog, 0.0) / self.topo.links[link].rate

    def path_delay(self, path: list[str], src_domain: int, now: float = 0.0) -> float:
        """Estimated waiting along a path: fresh for the sender's own
        up-links, stale snapshot for everything remote."""
        total = 0.0
        for link in path:
            fresh = link.startswith("up:") and link.split(":")[1] == str(src_domain)
            total += self.queue_delay(link, now, fresh=fresh)
        return total

    def _commit(self, job: ChunkJob, path: list[str]) -> None:
        job.path = path
        for link in path:
            self.assigned_bytes[link] += job.size
        self._decisions += 1
        if self._decisions % self.probe_every == 0:
            self._snapshot = {
                k: self.assigned_bytes[k] - self.transmitted_bytes[k]
                for k in self.assigned_bytes
            }

    # -- orchestration --------------------------------------------------------

    def run(self, jobs_by_sender: dict[tuple[int, int], list[ChunkJob]], policy) -> SimResult:
        """One-shot collective: assign everything, then simulate."""
        # Phase 1: the whole collective is one release batch; the policy's
        # assign_batch fixes the round-robin fabric-entry order.
        all_jobs: list[ChunkJob] = policy.assign_batch(self, jobs_by_sender, now=0.0)
        # Phase 2: discrete-event FIFO simulation.
        net = _FifoNetwork(self)
        for job in all_jobs:
            net.inject(job, job.arrival_time)
        net.drain()
        return self._result(all_jobs)

    def run_streaming(
        self, jobs_by_sender: dict[tuple[int, int], list[ChunkJob]], policy
    ) -> SimResult:
        """Streaming collective: chunks are revealed at their release time.

        All chunks sharing one release instant form a *batch*: the policy
        assigns the whole batch at once (so a planner can LPT over it),
        senders visited round-robin exactly as in the one-shot phase — with
        every release at t=0 this reproduces :meth:`run` event-for-event.
        The network is advanced to each release time first, so completion
        feedback observed by then is available to the policy.
        """
        releases: dict[float, dict[tuple[int, int], list[ChunkJob]]] = {}
        for key, jobs in jobs_by_sender.items():
            for j in jobs:
                releases.setdefault(j.arrival_time, {}).setdefault(key, []).append(j)
        net = _FifoNetwork(self)
        all_jobs: list[ChunkJob] = []
        for t in sorted(releases):
            if not math.isfinite(t):
                raise ValueError(f"non-finite release time {t!r}")
            net.advance_to(t)
            batch = policy.assign_batch(self, releases[t], now=t)
            for job in batch:
                all_jobs.append(job)
                net.inject(job, t)
        net.drain()
        return self._result(all_jobs)

    def _result(self, all_jobs: list[ChunkJob]) -> SimResult:
        flow_cct: dict[int, float] = {}
        for j in all_jobs:
            flow_cct[j.flow_id] = max(flow_cct.get(j.flow_id, 0.0), j.finish_time)
        makespan = max((j.finish_time for j in all_jobs), default=0.0)
        return SimResult(
            jobs=all_jobs,
            link_bytes=dict(self.link_bytes),
            makespan=makespan,
            flow_cct=flow_cct,
        )
