"""Discrete-event queueing engine for the rail fabric.

Two phases, mirroring how a real deployment separates *control* (path
decisions from imperfect signals) from *data* (what the fabric actually
does):

**Assignment phase.** Senders are visited round-robin (an all-to-all is a
single synchronized burst); the policy assigns each atomic chunk a path.
Reactive policies estimate congestion from per-link *backlog* counters
(assigned minus transmitted bytes) — their own domain's up-links fresh,
everything remote through a stale snapshot refreshed every ``probe_every``
decisions (RTT-delayed signals; the staleness is what makes reactive
schemes herd under incast, paper §VI-E). RailS ignores the estimates
entirely: its plan is proactive (Theorem 3 + LPT).

**Simulation phase.** A proper discrete-event simulation: every link is a
FIFO server (rate ``R`` bytes/s); chunks enter their first-hop queue at
their release time (``arrival_time``, t=0 for the classic one-shot
collective), are serviced in arrival order, and hop to the next link after
``hop_latency``. Store-and-forward at chunk granularity — pipelining across
chunks of the same flow arises naturally.

**Event-loop structure (hot path).** Earlier revisions kept one heap per
link plus a global completion heap — a heap tuple per chunk per hop.
Arrivals, however, are generated in non-decreasing time order (releases
are injected through a single sorted stream, and hop arrivals inherit the
completion order plus a constant ``hop_latency``), so per-link FIFO queues
are now plain deques with O(1) append/popleft, and only *service
completions* — at most one in flight per link — live in a heap. Event
payloads carry a global sequence number so simultaneous events keep the
deterministic round-robin order of the assignment phase.

**Streaming mode** (:meth:`Engine.run_streaming`) interleaves the two
phases: chunks are only revealed to the policy when they are *released*
(micro-batch boundaries, bursty arrivals), so online policies must decide
with partial information while earlier chunks are still in flight. The
engine notifies registered observers of every link-service interval and
chunk completion — the feed that `repro.sched.feedback` (EWMA rail health)
and `repro.sched.telemetry` (timelines, Chrome traces) consume. Observer
fan-out is pre-resolved into bound-method lists, so a run with no
observers pays a single falsy check per event.

**Flowlet coalescing** (``Engine(coalesce_flowlets=True)``) merges the
chunks of one release batch that share (sender GPU, path) — i.e. the same
(sender, rail, destination) lane — into one service event, cutting event
count by up to the per-lane chunk multiplicity. Member completion times
are reconstructed from the aggregate's final-hop service interval
(chunks drain sequentially at the last link's rate), which is exact for
an uncontended lane and a close approximation under contention; observers
see the merged flowlet, not its members. With coalescing off (the
default) the simulation is event-for-event identical to the reference
semantics — `run_streaming` bit-matches `run` for t=0 releases.

**Link dynamics** (:mod:`repro.netsim.linkmodel`). When the topology
carries a non-static :class:`~repro.netsim.linkmodel.FaultSpec`, the
network switches to a second event loop (``_run_dyn``) implementing the
full dynamics contract — the static loop is never entered, so frozen
fabrics stay bit-exact and pay nothing:

* service times consult each link's :class:`LinkModel` (piecewise-constant
  rate profiles integrate over their segments);
* **PFC** — a link whose queued bytes reach ``pause_bytes`` asserts pause;
  an upstream link about to serve a chunk *into* it stalls entirely
  (head-of-line blocking) until the backlog drains to ``resume_bytes``;
* **ECN** — chunks entering a queue above ``mark_bytes`` are marked; on
  delivery of a marked chunk the sender's pacing factor takes a
  multiplicative cut that slows its future first-hop serialization;
* **loss + go-back-N** — each completed link service draws from a seeded
  per-link Gilbert–Elliott chain; a lost chunk vanishes (wire bytes spent)
  and re-enters its first hop ``rto`` seconds later, and a receiver holding
  an earlier outstanding loss on the same transport lane — (flow, source
  NIC), the testbed's per-rail RC-QP granularity — discards later chunks
  of that lane (go-back-N in-order delivery), which become outstanding
  themselves and are retransmitted too.

Retransmissions are a fourth event source (a deque — detection times are
produced in non-decreasing event order and ``rto`` is constant, so it
stays sorted). Mark/drop/pause events reach observers through
``record_mark`` / ``record_drop`` / ``record_pause`` callbacks, and the
reactive policies' ``path_delay`` folds recent-mark and live-pause
penalties into its estimate — the stale congestion signals that make
reactive schemes herd in §VI-E.

**Fail-stop failures** (``FaultSpec.failures`` — rail / NIC / node deaths
with optional repair) add two more event sources: a pre-sorted deque of
down/up transitions (``failq``; fail events get the smallest sequence
numbers so they win ties against chunk events at the same instant) and a
retry *heap* (``retryq`` — exponential backoff makes redelivery times
non-monotone, unlike the constant-``rto`` loss deque). A dead link
transmits nothing: its in-flight service is cancelled (a tombstone set
invalidates the already-heaped finish event), its queue is drained, and
every stranded chunk is re-injected after
``rto * backoff**min(attempt-1, max_exponent)`` — re-planned onto a
surviving rail when any link of its original path is still dead
(:class:`~repro.netsim.linkmodel.RetryConfig`). Chunks arriving at a dead
link strand the same way (the sender only learns of the death by
timeout). Every chunk lives in exactly one container at any instant
(link queue, in-flight service, hop-arrival deque, or retry heap), so
delivery stays exactly-once: ``dynamics["delivered_chunks"]`` equals the
chunk count even through a mid-collective rail loss. With no failures
configured both new sources stay empty and the dynamic loop is bit-exact
with its PR-4 behaviour.

**Hierarchical fabrics.** Any :class:`~repro.netsim.topology.Fabric` is
accepted — the engine walks whatever per-link path the policy committed,
so multi-pod paths (``up -> wan -> down``) need no special casing. Two
wrinkles: (a) per-link *propagation latency* (``Link.latency``, nonzero
only on wan links) is charged after a link's service, on top of the
constant ``hop_latency``; heterogeneous latencies break the
non-decreasing hop-arrival order the deque relies on, so the hop-arrival
container switches to a heap iff any link has nonzero latency (flat
fabrics keep the deque and stay bit-exact); (b) ``LossConfig.links``
gains a ``"wan"`` scope so loss can be confined to the long-haul hops —
the eligibility of every link is precomputed into one dict.

**XOR-FEC** (``FaultSpec.fec`` — :class:`~repro.netsim.linkmodel.FecConfig`).
With forward error correction, every ``k`` consecutive data chunks a
transport lane — (flow, first-hop link), the go-back-N granularity —
commits form a *group*, and the engine injects ``r`` parity chunks right
behind the group's last member (sized like its largest member, on its
path). The receiver reconstructs as soon as any ``k`` of the ``k + r``
group members have landed: a group therefore *absorbs* up to ``r``
losses — an absorbed data chunk schedules **no** retransmission and
never enters the go-back-N window (no head-of-line blocking); it is
delivered at the instant reconstruction becomes possible. Parity losses
consume the same budget and are never retransmitted. Past the budget the
group is *busted*: previously-absorbed data chunks are flushed to the
PR-4 go-back-N retransmit path (otherwise ``k=2, r=2`` with both parity
chunks lost deadlocks — only one arrival can ever happen, forever short
of ``k``) and every later loss is handled legacy. Parity chunks are
invisible to flow accounting: CCT, makespan, ``delivered_chunks`` and
goodput count data only, while ``fec_*`` counters in the dynamics
summary expose the redundancy spent. Chunks left in a partially-filled
group at the end of assignment are unprotected. FEC is inert without a
``LossConfig`` (use ``rate=0.0`` to measure pure parity overhead).
"""

from __future__ import annotations

import dataclasses
import heapq
import itertools
import math
from collections import deque

import numpy as np

from .linkmodel import GilbertElliott, RetryConfig
from .topology import RailTopology

__all__ = [
    "ChunkJob",
    "SimResult",
    "Engine",
    "DEFAULT_QS",
    "cct_percentile_dict",
    "quantile_label",
]

_INF = float("inf")


#: Default quantile set for CCT/latency summaries. 99.9 rides along so the
#: serving-path tail (p99.9 TTFT) is reported everywhere without another pass.
DEFAULT_QS = (50.0, 80.0, 95.0, 99.0, 99.9)


def quantile_label(q: float) -> str:
    """``p50`` / ``p99`` / ``p99.9`` — fractional quantiles keep their
    fraction. The old ``f"p{int(q)}"`` silently collapsed 99.9 onto p99
    (the later assignment overwrote the p99 value with the p99.9 one)."""
    return f"p{q:g}"


def cct_percentile_dict(values, qs=DEFAULT_QS) -> dict[str, float]:
    """CCT summary dict shared by the event and vector backends.

    Sorting before the mean keeps the summation order (and hence the last
    fp bit) identical no matter which backend produced ``values``. Empty
    collectives (all-zero traffic rows) still report a complete key set so
    downstream tables never KeyError.
    """
    vals = np.sort(np.asarray(values, dtype=np.float64))
    if vals.size == 0:
        return {"mean": 0.0, **{quantile_label(q): 0.0 for q in qs}, "max": 0.0}
    out = {"mean": float(vals.mean())}
    for q in qs:
        out[quantile_label(q)] = float(np.percentile(vals, q))
    out["max"] = float(vals.max())
    return out


@dataclasses.dataclass(slots=True)
class ChunkJob:
    """One atomic chunk to be transferred.

    ``arrival_time`` is the release time: the chunk does not exist for
    either the policy or the fabric before it (0.0 reproduces the one-shot
    collective). ``round_id`` tags the micro-batch / iteration the chunk
    belongs to in streaming runs. Slotted — the engine allocates one per
    chunk, and 10⁵–10⁶-chunk sweeps are memory- and attribute-access-bound.
    """

    chunk_id: int
    flow_id: int
    src_domain: int
    src_gpu: int
    dst_domain: int
    dst_gpu: int
    size: float
    arrival_time: float = 0.0
    round_id: int = 0
    # Filled by the engine:
    path: list[str] | None = None
    start_time: float = 0.0
    finish_time: float = 0.0
    # Dynamics bookkeeping (only touched by the dynamic event loop):
    ecn_marked: bool = False
    retries: int = 0


class _Flowlet:
    """Aggregated service unit: same-(sender, path) chunks of one batch.

    Duck-types the ``ChunkJob`` surface the engine and observers touch;
    identity fields come from the first member. Member times are
    reconstructed after the run (see :meth:`Engine._expand_flowlets`).
    """

    __slots__ = (
        "members", "path", "size", "arrival_time", "start_time", "finish_time",
        "chunk_id", "flow_id", "src_domain", "src_gpu", "dst_domain",
        "dst_gpu", "round_id",
    )

    def __init__(self, members: list[ChunkJob]):
        head = members[0]
        self.members = members
        self.path = head.path
        self.size = float(sum(j.size for j in members))
        self.arrival_time = head.arrival_time
        self.start_time = 0.0
        self.finish_time = 0.0
        self.chunk_id = head.chunk_id
        self.flow_id = head.flow_id
        self.src_domain = head.src_domain
        self.src_gpu = head.src_gpu
        self.dst_domain = head.dst_domain
        self.dst_gpu = head.dst_gpu
        self.round_id = head.round_id


class _FecGroup:
    """Receiver-side state of one FEC group (k data + r parity chunks).

    ``arrived`` counts landed members (data delivered + parity received);
    once it reaches ``k`` every ``absorbed`` chunk (lost but within the
    redundancy budget) is reconstructable. ``busted`` means the loss
    count exceeded ``r`` — the group fell back to go-back-N and this
    state is only consulted to route parity arrivals to /dev/null.
    """

    __slots__ = ("k", "r", "losses", "arrived", "absorbed", "busted")

    def __init__(self, k: int, r: int):
        self.k = k
        self.r = r
        self.losses = 0
        self.arrived = 0
        self.absorbed: list[ChunkJob] = []
        self.busted = False


@dataclasses.dataclass
class SimResult:
    jobs: list[ChunkJob]
    link_bytes: dict[str, float]
    makespan: float
    # Per parent-flow *sojourn* time: last-chunk finish minus the flow's
    # release. The paper's completion-time claims are release-relative; a
    # flow released late must not report its absolute finish as "CCT".
    # For t=0 one-shot collectives sojourn == absolute finish bit-exactly
    # (x - 0.0 == x), which is what keeps the pre-fix goldens valid.
    flow_cct: dict[int, float]
    # Release time of each flow (min over its chunks); empty for the
    # hand-built empty-result case.
    flow_release: dict[int, float] = dataclasses.field(default_factory=dict)
    # Fabric-dynamics summary (drops / retransmits / marks / pause time);
    # None for static fabrics, where none of these mechanisms exist.
    dynamics: dict | None = None

    def cct_percentiles(self, qs=DEFAULT_QS) -> dict[str, float]:
        return cct_percentile_dict(list(self.flow_cct.values()), qs)

    def round_completion_times(self) -> dict[int, float]:
        """Absolute finish time of the last chunk of each streaming round.

        Empty job lists yield an empty mapping (no rounds ever released).
        """
        out: dict[int, float] = {}
        for j in self.jobs:
            out[j.round_id] = max(out.get(j.round_id, 0.0), j.finish_time)
        return out

    def round_times(self) -> tuple[dict[int, float], dict[int, float]]:
        """(absolute finish, sojourn) per round — one pass over the jobs.

        The sojourn (last finish minus earliest release) is the engine-side
        version of the ``cct - releases[rnd]`` bookkeeping the pipeline
        driver used to hand-compute; the streaming driver wants both views,
        so they share the scan.
        """
        finish: dict[int, float] = {}
        release: dict[int, float] = {}
        for j in self.jobs:
            rnd = j.round_id
            prev_f = finish.get(rnd)
            if prev_f is None or j.finish_time > prev_f:
                finish[rnd] = j.finish_time
            prev_r = release.get(rnd)
            if prev_r is None or j.arrival_time < prev_r:
                release[rnd] = j.arrival_time
        return finish, {rnd: finish[rnd] - release[rnd] for rnd in finish}

    def round_sojourn_times(self) -> dict[int, float]:
        """Per-round sojourn: last finish minus the round's earliest release."""
        return self.round_times()[1]


class _FifoNetwork:
    """Incremental FIFO-server network: inject chunks at any time, advance
    the event clock piecewise.

    Three event sources feed one loop, merged by ``(time, seq)``:

    * ``finishes`` — the only heap: service completions, at most one per
      link in flight.
    * ``hop_arrivals`` — deque; completion order is non-decreasing in time
      and ``hop_latency`` is constant, so next-hop arrivals are produced
      already sorted. On fabrics with heterogeneous per-link propagation
      latency (multi-pod wan hops) that invariant breaks — a short-hop
      arrival can be produced *after* but land *before* a long-hop one —
      so the container becomes a heap instead (flat fabrics keep the
      deque: same peek, bit-exact event order).
    * ``injections`` — deque of released chunks; callers inject in
      non-decreasing release order (the single sorted release stream).

    Per-link queues are deques: arrivals are appended in global time
    order, so FIFO service is a popleft.
    """

    def __init__(self, engine: "Engine"):
        self.eng = engine
        topo = engine.topo
        self.link_queue: dict[str, deque] = {k: deque() for k in topo.links}
        self.link_busy: dict[str, bool] = {k: False for k in topo.links}
        self.link_rate: dict[str, float] = {k: l.rate for k, l in topo.links.items()}
        self.finishes: list = []  # heap of (finish, seq, job, hop, link, start)
        # Heap iff any link carries propagation latency (see class docstring).
        self.var_latency = engine._var_latency
        self.hop_arrivals = [] if self.var_latency else deque()  # (t, seq, job, hop)
        self.injections: deque = deque()  # (t, seq, job)
        self._seq = itertools.count()
        self.now = 0.0
        self.dyn = engine._dynamic
        if self.dyn:
            self.link_model = {k: l.model for k, l in topo.links.items()}
            self.queued_bytes: dict[str, float] = {k: 0.0 for k in topo.links}
            self.retrans: deque = deque()  # (t, seq, job) — 4th event source
            self.asserted: dict[str, float] = {}  # paused link -> assert time
            self.waiters: dict[str, list[str]] = {}  # paused -> stalled upstream
            self.stalled: dict[str, tuple] = {}  # upstream -> (job, hop, since)
            self.loss_chains: dict[str, GilbertElliott] = {}
            # Fail-stop machinery (5th + 6th event sources). The fail queue
            # is pre-sorted and takes the *first* sequence numbers, so a
            # death at time t wins ties against any chunk event at t. The
            # retry queue is a heap: exponential backoff makes redelivery
            # times non-monotone, unlike the constant-rto loss deque.
            self.dead: set[str] = set()
            self.in_flight: dict[str, tuple] = {}  # link -> (finish seq, job)
            self.cancelled: set[int] = set()  # tombstoned finish seqs
            self.retryq: list = []  # heap of (t, seq, job)
            transitions = []
            for ev in engine._failures:
                names = ev.links(topo.m, topo.n)
                transitions.append((ev.t_fail, 0, names))
                if ev.t_repair is not None:
                    transitions.append((ev.t_repair, 1, names))
            transitions.sort(key=lambda e: (e[0], e[1]))
            self.failq: deque = deque(
                (t, next(self._seq), "down" if k == 0 else "up", names)
                for t, k, names in transitions
            )

    def inject(self, job, t: float) -> None:
        t = max(t, job.arrival_time)
        if self.injections and t < self.injections[-1][0]:
            raise ValueError("injections must arrive in non-decreasing time order")
        self.injections.append((t, next(self._seq), job))

    def _start(self, link: str, job, hop: int, t: float) -> None:
        self.link_busy[link] = True
        if hop == 0:
            job.start_time = t
        finish = t + job.size / self.link_rate[link]
        self.eng.link_bytes[link] += job.size
        heapq.heappush(self.finishes, (finish, next(self._seq), job, hop, link, t))

    def advance_to(self, horizon: float) -> None:
        """Process all events strictly before ``horizon``."""
        self._run(horizon)
        self.now = max(self.now, horizon)

    def drain(self) -> None:
        self._run(None)

    def _run(self, horizon: float | None) -> None:
        """The event loop: pop (time, seq)-ordered events until ``horizon``
        (exclusive; ``None`` = until idle). Locals are bound once — this
        loop runs once per chunk-hop arrival and once per service finish.
        Fabrics with a non-static fault spec run the dynamic loop instead;
        this static loop is byte-for-byte the pre-dynamics engine."""
        if self.dyn:
            return self._run_dyn(horizon)
        finishes = self.finishes
        arrivals = self.hop_arrivals
        injections = self.injections
        link_queue = self.link_queue
        link_busy = self.link_busy
        eng = self.eng
        transmitted = eng.transmitted_bytes
        service_cbs = eng._service_cbs
        completion_cbs = eng._completion_cbs
        hop_latency = eng.hop_latency
        heappop = heapq.heappop
        seq = self._seq
        start = self._start
        var_lat = self.var_latency
        link_latency = eng._link_latency
        bound = _INF if horizon is None else horizon
        while True:
            t_f = finishes[0][0] if finishes else _INF
            s_f = finishes[0][1] if finishes else 0
            t_a, s_a = (arrivals[0][0], arrivals[0][1]) if arrivals else (_INF, 0)
            t_i, s_i = (injections[0][0], injections[0][1]) if injections else (_INF, 0)
            # Earliest of the three sources, ties by global sequence.
            if t_a < t_i or (t_a == t_i and s_a < s_i):
                t_n, s_n, src = t_a, s_a, 1
            else:
                t_n, s_n, src = t_i, s_i, 2
            if t_f < t_n or (t_f == t_n and s_f < s_n):
                t_n, src = t_f, 0
            if t_n >= bound:
                return
            if src == 0:
                t, _s, job, hop, link, started = heappop(finishes)
                self.now = t
                link_busy[link] = False
                transmitted[link] += job.size
                # Observers hear about the service interval only once it
                # has finished — a real controller cannot measure an
                # in-flight transfer's rate before the transfer completes.
                if service_cbs:
                    for cb in service_cbs:
                        cb(link, started, t, job)
                path = job.path
                if hop + 1 < len(path):
                    # Same association order as the vector/device backends:
                    # (finish + hop_latency) + per-link latency.
                    t_a = t + hop_latency
                    if var_lat:
                        t_a += link_latency[link]
                        heapq.heappush(arrivals, (t_a, next(seq), job, hop + 1))
                    else:
                        arrivals.append((t_a, next(seq), job, hop + 1))
                else:
                    job.finish_time = t
                    if completion_cbs:
                        for cb in completion_cbs:
                            cb(job, t)
                q = link_queue[link]
                if q:
                    job2, hop2 = q.popleft()
                    start(link, job2, hop2, t)
            else:
                if src == 1:
                    if var_lat:
                        t, _s, job, hop = heappop(arrivals)
                    else:
                        t, _s, job, hop = arrivals.popleft()
                else:
                    t, _s, job = injections.popleft()
                    hop = 0
                self.now = t
                link = job.path[hop]
                if link_busy[link]:
                    link_queue[link].append((job, hop))
                else:
                    start(link, job, hop, t)

    # -- dynamic event loop (link models + PFC/ECN/loss) ---------------------

    def _run_dyn(self, horizon: float | None) -> None:
        """Dynamics-aware event loop: six (time, seq)-merged sources —
        service finishes (heap), hop arrivals, injections, scheduled
        retransmissions (deques, produced in non-decreasing time order),
        fail-stop down/up transitions (pre-sorted deque), and stranded-
        chunk retries (heap — backoff times are non-monotone)."""
        finishes = self.finishes
        arrivals = self.hop_arrivals
        injections = self.injections
        retrans = self.retrans
        failq = self.failq
        retryq = self.retryq
        heappop = heapq.heappop
        bound = _INF if horizon is None else horizon
        while True:
            t_n, s_n, src = _INF, 0, -1
            if finishes:
                t_n, s_n, src = finishes[0][0], finishes[0][1], 0
            for cand, tag in (
                (arrivals, 1), (injections, 2), (retrans, 3),
                (failq, 4), (retryq, 5),
            ):
                if cand:
                    t_c, s_c = cand[0][0], cand[0][1]
                    if t_c < t_n or (t_c == t_n and s_c < s_n):
                        t_n, s_n, src = t_c, s_c, tag
            if t_n >= bound:
                return
            if src == 0:
                self._finish_dyn(heappop(finishes))
            elif src == 1:
                if self.var_latency:
                    t, _s, job, hop = heappop(arrivals)
                else:
                    t, _s, job, hop = arrivals.popleft()
                self.now = t
                self._arrive_dyn(job.path[hop], job, hop, t)
            elif src == 4:
                t, _s, tag, names = failq.popleft()
                self.now = t
                self._apply_fail(t, tag, names)
            elif src == 5:
                t, _s, job = heappop(retryq)
                self.now = t
                self._retry_fire(job, t)
            else:
                if src == 2:
                    t, _s, job = injections.popleft()
                else:
                    t, _s, job = retrans.popleft()
                self.now = t
                self._arrive_dyn(job.path[0], job, 0, t)

    def _arrive_dyn(self, link: str, job, hop: int, t: float) -> None:
        """Chunk reaches a link's ingress: ECN-mark against the current
        backlog, update PFC assertion, then serve or queue. A chunk
        arriving at a dead link strands immediately — the sender only
        learns of the death through its retry timeout, so the chunk backs
        off and re-enters (possibly re-sprayed) when the timer fires."""
        if link in self.dead:
            self._strand(job, t, link)
            return
        eng = self.eng
        backlog = self.queued_bytes[link]
        ecn = eng._ecn
        if ecn is not None and backlog >= ecn.mark_bytes and not job.ecn_marked:
            job.ecn_marked = True
            eng.ecn_marks[link] += 1
            for cb in eng._mark_cbs:
                cb(link, t, job)
        self.queued_bytes[link] = backlog + job.size
        pfc = eng._pfc
        if (
            pfc is not None
            and link not in self.asserted
            and backlog + job.size >= pfc.pause_bytes
        ):
            self.asserted[link] = t
            eng.paused_links.add(link)
        if self.link_busy[link] or link in self.stalled:
            self.link_queue[link].append((job, hop))
        else:
            self._try_start_dyn(link, job, hop, t)

    # -- fail-stop handling (strand / retry / failover) ----------------------

    def _apply_fail(self, t: float, tag: str, names: list[str]) -> None:
        """One fail-stop transition. ``down``: mark the links dead, cancel
        their in-flight services (tombstone the heaped finish), drain their
        queues and any PFC-stalled head, and strand every chunk onto the
        retry heap. A dead link also stops asserting pause — its upstream
        waiters restart and their chunks strand at the dead ingress
        instead. ``up``: the links rejoin the fabric; backed-off retries
        land on them again (nothing queues on a dead link, so there is
        nothing to kick)."""
        eng = self.eng
        if tag == "up":
            for link in names:
                self.dead.discard(link)
                eng.dead_links.discard(link)
            return
        for link in names:
            if link not in self.link_queue or link in self.dead:
                continue
            self.dead.add(link)
            eng.dead_links.add(link)
            held = self.in_flight.pop(link, None)
            if held is not None:
                fseq, job = held
                self.cancelled.add(fseq)
                self.link_busy[link] = False
                self.queued_bytes[link] -= job.size
                self._strand(job, t, link)
            q = self.link_queue[link]
            while q:
                job2, _hop2 = q.popleft()
                self.queued_bytes[link] -= job2.size
                self._strand(job2, t, link)
            held = self.stalled.pop(link, None)
            if held is not None:
                # The dead link itself was PFC-stalled; its held head
                # strands and it stops waiting on its downstream.
                job2, _hop2, since2 = held
                eng.stall_time[link] = eng.stall_time.get(link, 0.0) + (t - since2)
                self.queued_bytes[link] -= job2.size
                self._strand(job2, t, link)
                for ups in self.waiters.values():
                    if link in ups:
                        ups.remove(link)
            if link in self.asserted:
                since = self.asserted.pop(link)
                eng.paused_links.discard(link)
                eng.pause_time[link] = eng.pause_time.get(link, 0.0) + (t - since)
                for up in sorted(self.waiters.pop(link, ())):
                    held2 = self.stalled.pop(up, None)
                    if held2 is not None:
                        job3, hop3, since3 = held2
                        eng.stall_time[up] = (
                            eng.stall_time.get(up, 0.0) + (t - since3)
                        )
                        self._try_start_dyn(up, job3, hop3, t)

    def _strand(self, job, t: float, link: str) -> None:
        """Schedule a stranded chunk's redelivery with exponential backoff."""
        eng = self.eng
        retry = eng._retry
        job.retries += 1
        job.ecn_marked = False
        if retry is None or job.retries > retry.max_retries:
            raise RuntimeError(
                f"chunk {job.flow_id}/{job.chunk_id} exceeded "
                f"{retry.max_retries if retry else 0} retries at dead link "
                f"{link} — unrecoverable partition (no surviving path)"
            )
        eng.fail_strands[link] = eng.fail_strands.get(link, 0) + 1
        heapq.heappush(
            self.retryq,
            (t + retry.delay(job.retries), next(self._seq), job),
        )

    def _retry_fire(self, job, t: float) -> None:
        """A stranded chunk's timer fires: if its path still crosses a dead
        link, re-spray it onto a surviving rail first, then re-inject at
        hop 0 (the source retransmits from scratch)."""
        if self.dead and any(link in self.dead for link in job.path):
            self._failover_path(job)
        self._arrive_dyn(job.path[0], job, 0, t)

    def _failover_path(self, job) -> None:
        """Re-plan a stranded chunk onto a surviving rail.

        Candidate rails are scanned in a deterministic order offset by the
        chunk id, so one dead rail's chunks spread across *all* survivors
        instead of herding onto a single neighbour. When no fully-alive
        rail exists (e.g. destination node down) the original path is
        kept: the chunk strands again on arrival and backs off until a
        repair lands — or max_retries surfaces the partition."""
        eng = self.eng
        topo = eng.topo
        dead = self.dead
        src, dst = job.src_domain, job.dst_domain
        cur_rail = int(job.path[0].split(":")[2])
        for i in range(topo.n):
            r = (cur_rail + 1 + job.chunk_id + i) % topo.n
            path = topo.rail_path(src, dst, r)
            if any(link in dead for link in path):
                continue
            # The go-back-N lane is keyed by (flow, first hop); moving
            # rails moves lanes, so drop any stale outstanding entry.
            lane = (job.flow_id, job.path[0])
            outs = eng._lane_outstanding.get(lane)
            if outs is not None:
                outs.discard(job.chunk_id)
                if not outs:
                    del eng._lane_outstanding[lane]
            job.path = path
            assigned = eng.assigned_bytes
            for link in path:
                assigned[link] += job.size
            eng.failovers += 1
            return

    def _try_start_dyn(self, link: str, job, hop: int, t: float) -> None:
        """Start service unless PFC blocks it: a chunk headed into a
        pause-asserting link stalls its whole upstream link (head-of-line
        blocking — everything queued behind it waits too)."""
        eng = self.eng
        path = job.path
        if link in self.dead:
            # PFC waiter resumed onto a link that died in the same fail
            # event (node-down kills several lanes at once): strand.
            self.queued_bytes[link] -= job.size
            self._strand(job, t, link)
            return
        if eng._pfc is not None and hop + 1 < len(path):
            nxt = path[hop + 1]
            if nxt in self.asserted:
                self.stalled[link] = (job, hop, t)
                self.waiters.setdefault(nxt, []).append(link)
                return
        self.link_busy[link] = True
        size = job.size
        if hop == 0:
            if job.retries == 0:
                job.start_time = t
            # Sender pacing: the ECN rate cut stretches the NIC's effective
            # serialization time for this sender's subsequent chunks.
            if eng._ecn is not None:
                f = eng.sender_factor.get((job.src_domain, job.src_gpu), 1.0)
                if f < 1.0:
                    size = size / f
        finish = self.link_model[link].service_finish(t, size, self.link_rate[link])
        eng.link_bytes[link] += job.size
        fseq = next(self._seq)
        heapq.heappush(self.finishes, (finish, fseq, job, hop, link, t))
        self.in_flight[link] = (fseq, job)

    def _finish_dyn(self, ev) -> None:
        """One service completion under dynamics: deassert PFC if drained,
        draw the loss chain, forward / deliver / retransmit, pull the next
        queued chunk."""
        t, _s, job, hop, link, started = ev
        if _s in self.cancelled:
            # Service was cancelled by a fail-stop event after this finish
            # was heaped; the chunk already went through _strand.
            self.cancelled.discard(_s)
            return
        eng = self.eng
        self.now = t
        self.in_flight.pop(link, None)
        self.link_busy[link] = False
        self.queued_bytes[link] -= job.size
        eng.transmitted_bytes[link] += job.size
        if eng._service_cbs:
            for cb in eng._service_cbs:
                cb(link, started, t, job)
        pfc = eng._pfc
        if (
            pfc is not None
            and link in self.asserted
            and self.queued_bytes[link] <= pfc.resume_bytes
        ):
            since = self.asserted.pop(link)
            eng.paused_links.discard(link)
            eng.pause_time[link] = eng.pause_time.get(link, 0.0) + (t - since)
            for cb in eng._pause_cbs:
                cb(link, since, t)
            # Resume stalled upstream links in sorted order (deterministic).
            for up in sorted(self.waiters.pop(link, ())):
                held = self.stalled.pop(up, None)
                if held is not None:
                    job2, hop2, since2 = held
                    eng.stall_time[up] = eng.stall_time.get(up, 0.0) + (t - since2)
                    self._try_start_dyn(up, job2, hop2, t)
        loss = eng._loss
        lost = False
        if loss is not None and eng._loss_eligible[link]:
            chain = self.loss_chains.get(link)
            if chain is None:
                chain = self.loss_chains[link] = GilbertElliott(loss)
            lost = chain.draw(eng.fault_rng)
        if lost:
            # The wire time was spent. A FEC-protected chunk whose group
            # still has redundancy budget is *absorbed* — no retransmit,
            # reconstruction happens receiver-side (see _fec_lost).
            # Otherwise the chunk vanishes and re-enters its first hop
            # once the sender's retransmission timer fires. The links it
            # already crossed (and will cross again) re-absorb its bytes
            # into the assigned ledger so backlog estimates stay
            # consistent — without this, retransmissions push transmitted
            # past assigned and lossy links read as permanently idle to
            # the reactive policies.
            eng.drops[link] = eng.drops.get(link, 0) + 1
            for cb in eng._drop_cbs:
                cb(link, t, job)
            if not (eng._fec is not None and self._fec_lost(job, t)):
                lane = (job.flow_id, job.path[0])
                eng._lane_outstanding.setdefault(lane, set()).add(job.chunk_id)
                assigned = eng.assigned_bytes
                for crossed in job.path[: hop + 1]:
                    assigned[crossed] += job.size
                job.retries += 1
                job.ecn_marked = False
                self.retrans.append((t + loss.rto, next(self._seq), job))
        elif hop + 1 < len(job.path):
            t_a = t + eng.hop_latency
            if self.var_latency:
                t_a += eng._link_latency[link]
                heapq.heappush(
                    self.hop_arrivals, (t_a, next(self._seq), job, hop + 1)
                )
            else:
                self.hop_arrivals.append((t_a, next(self._seq), job, hop + 1))
        else:
            self._deliver_dyn(job, t)
        q = self.link_queue[link]
        if q and not self.link_busy[link] and link not in self.stalled:
            job2, hop2 = q.popleft()
            self._try_start_dyn(link, job2, hop2, t)

    # -- FEC (XOR parity groups; see module docstring) ------------------------

    def _fec_lost(self, job, t: float) -> bool:
        """FEC view of one lost chunk. Returns True when the loss is fully
        handled here — absorbed within the group's redundancy budget, or a
        parity chunk (never retransmitted). False sends the caller down
        the legacy go-back-N retransmit path."""
        eng = self.eng
        g = eng._fec_group_of.get(id(job))
        if g is None:
            return False  # unprotected tail chunk of a partial group
        parity = id(job) in eng._parity_ids
        if g.busted:
            if parity:
                eng.fec_absorbed += 1  # parity is never retransmitted
            return parity  # busted group: data goes legacy
        g.losses += 1
        if g.losses <= g.r:
            eng.fec_absorbed += 1
            if parity:
                return True  # budget spent; nothing to reconstruct
            g.absorbed.append(job)
            # The receiver may already hold >= k members — a chunk lost
            # after the k-th arrival must reconstruct *now*; no further
            # arrival will ever re-trigger the decode.
            self._fec_decode(g, t)
            return True
        # Budget exceeded: bust the group and flush every previously
        # absorbed data chunk back onto the go-back-N retransmit path.
        # Without the flush, k=2/r=2 with both parity chunks lost
        # deadlocks: one data chunk absorbed, one arrival possible —
        # forever short of k.
        g.busted = True
        eng.fec_busted += 1
        loss = eng._loss
        assigned = eng.assigned_bytes
        for aj in g.absorbed:
            lane = (aj.flow_id, aj.path[0])
            eng._lane_outstanding.setdefault(lane, set()).add(aj.chunk_id)
            for crossed in aj.path:
                assigned[crossed] += aj.size
            aj.retries += 1
            aj.ecn_marked = False
            self.retrans.append((t + loss.rto, next(self._seq), aj))
        g.absorbed = []
        if parity:
            eng.fec_absorbed += 1
        return parity

    def _fec_decode(self, g: _FecGroup, t: float) -> None:
        """Reconstruct every absorbed data chunk once >= k group members
        have landed. Called after each arrival *and* each absorbed loss.
        Reconstructed chunks deliver at the decode instant with full
        bookkeeping; they never touched the go-back-N window (that is the
        point — no head-of-line blocking on the recovered lane)."""
        if g.arrived < g.k or not g.absorbed:
            return
        eng = self.eng
        for aj in g.absorbed:
            aj.finish_time = t
            eng.delivered_chunks += 1
            eng.goodput_bytes += aj.size
            eng.fec_recovered += 1
            if eng._completion_cbs:
                for cb in eng._completion_cbs:
                    cb(aj, t)
        g.absorbed = []

    def _deliver_dyn(self, job, t: float) -> None:
        """Receiver side: go-back-N in-order delivery + ECN echo.

        Sequencing is per transport *lane* — (flow, source NIC), the RC-QP
        granularity of the paper's SoftRoCE testbed, where each rail pair
        runs its own queue pair. A chunk arriving while an earlier chunk
        of its lane is still outstanding (lost, not yet redelivered) is
        discarded — go-back-N receivers reject out-of-order data — becomes
        outstanding itself (nothing behind it lands either), and its
        retransmission is scheduled. In-order chunks deliver exactly once
        and feed the sender's ECN pacing factor (cut on marked, additive
        recovery)."""
        eng = self.eng
        fecg = None
        if eng._fec is not None:
            fecg = eng._fec_group_of.get(id(job))
            if fecg is not None and id(job) in eng._parity_ids:
                # Parity never reaches the flow: count the arrival toward
                # reconstruction (unless the group already fell back to
                # go-back-N) and discard it.
                if not fecg.busted:
                    fecg.arrived += 1
                    self._fec_decode(fecg, t)
                return
        lane = (job.flow_id, job.path[0])
        outstanding = eng._lane_outstanding.get(lane)
        loss = eng._loss
        if (
            loss is not None
            and outstanding
            and min(outstanding) < job.chunk_id
        ):
            outstanding.add(job.chunk_id)
            eng.gbn_discards += 1
            job.retries += 1
            job.ecn_marked = False
            assigned = eng.assigned_bytes
            for crossed in job.path:
                assigned[crossed] += job.size
            self.retrans.append((t + loss.rto, next(self._seq), job))
            return
        if outstanding is not None:
            outstanding.discard(job.chunk_id)
            if not outstanding:
                del eng._lane_outstanding[lane]
        job.finish_time = t
        eng.delivered_chunks += 1
        eng.goodput_bytes += job.size
        ecn = eng._ecn
        if ecn is not None:
            key = (job.src_domain, job.src_gpu)
            f = eng.sender_factor.get(key, 1.0)
            if job.ecn_marked:
                f = max(ecn.min_factor, f * ecn.cut)
                if f < eng.min_sender_factor:
                    eng.min_sender_factor = f
            elif f < 1.0:
                f = min(1.0, f + ecn.recover)
            eng.sender_factor[key] = f
        if eng._completion_cbs:
            for cb in eng._completion_cbs:
                cb(job, t)
        if fecg is not None and not fecg.busted:
            fecg.arrived += 1
            self._fec_decode(fecg, t)


class Engine:
    def __init__(
        self,
        topo: RailTopology,
        hop_latency: float = 1e-6,
        probe_every: int = 64,
        seed: int = 0,
        observers: tuple = (),
        coalesce_flowlets: bool = False,
    ):
        self.topo = topo
        self.hop_latency = hop_latency
        self.probe_every = probe_every
        self.coalesce_flowlets = coalesce_flowlets
        self.rng = np.random.default_rng(seed)
        self.assigned_bytes: dict[str, float] = {k: 0.0 for k in topo.links}
        self.transmitted_bytes: dict[str, float] = {k: 0.0 for k in topo.links}
        self._snapshot: dict[str, float] = dict(self.assigned_bytes)
        self.link_bytes: dict[str, float] = {k: 0.0 for k in topo.links}
        # Pre-parsed link metadata: the up-link's domain (or -1), the rate,
        # the NIC/WAN-lane flags and the propagation latency, so the
        # per-chunk estimate path and the loss filter never split strings.
        self._up_domain: dict[str, int] = {}
        self._link_rate: dict[str, float] = {}
        self._nic_link: dict[str, bool] = {}
        self._wan_link: dict[str, bool] = {}
        self._link_latency: dict[str, float] = {}
        for name, link in topo.links.items():
            parts = name.split(":")
            self._up_domain[name] = int(parts[1]) if parts[0] == "up" else -1
            self._link_rate[name] = link.rate
            self._nic_link[name] = parts[0] in ("up", "down")
            self._wan_link[name] = parts[0] == "wan"
            self._link_latency[name] = getattr(link, "latency", 0.0)
        # Heterogeneous propagation latency flips the hop-arrival container
        # to a heap; flat fabrics (all-zero) keep the bit-exact deque.
        self._var_latency = any(v != 0.0 for v in self._link_latency.values())
        self._decisions = 0
        self._flowlets: list[_Flowlet] = []
        # Fabric dynamics (repro.netsim.linkmodel): active only when the
        # topology carries a non-static FaultSpec. The static hot path pays
        # one falsy check at construction and nothing per event.
        spec = topo.fault_spec
        self._dynamic = topo.has_dynamics
        self._pfc = spec.pfc if self._dynamic else None
        self._ecn = spec.ecn if self._dynamic else None
        self._loss = spec.loss if self._dynamic else None
        self._failures = spec.failures if self._dynamic else ()
        self._retry = (
            (spec.retry or RetryConfig()) if self._failures else None
        )
        # FEC is inert without a LossConfig (is_static stays loss-driven;
        # a rate=0 LossConfig measures pure parity overhead).
        self._fec = spec.fec if self._loss is not None else None
        self._signals = self._pfc is not None or self._ecn is not None
        # Links currently fail-stopped (empty unless failures fire); the
        # policy-facing delay estimates treat them as unusable (inf).
        self.dead_links: set[str] = set()
        if self._dynamic:
            if coalesce_flowlets:
                raise ValueError(
                    "flowlet coalescing merges service events; fabric "
                    "dynamics (time-varying rails, PFC/ECN/loss) need "
                    "per-chunk services — drop coalesce=True or the "
                    "fault_spec"
                )
            # Fault-layer RNG is decoupled from the policy seed so one
            # fault realization replays identically across policies.
            self.fault_rng = np.random.default_rng(spec.seed)
            self.ecn_marks: dict[str, int] = {k: 0 for k in topo.links}
            self.drops: dict[str, int] = {}
            self.pause_time: dict[str, float] = {}
            self.stall_time: dict[str, float] = {}
            self.paused_links: set[str] = set()
            self.sender_factor: dict[tuple[int, int], float] = {}
            # Go-back-N windows keyed by transport lane (flow, first-hop
            # link) — the per-rail RC-QP granularity of the testbed.
            self._lane_outstanding: dict[tuple[int, str], set[int]] = {}
            self.gbn_discards = 0
            self.delivered_chunks = 0
            self.goodput_bytes = 0.0
            # Per-link loss eligibility (LossConfig.links scope), resolved
            # once so _finish_dyn never inspects names.
            self._loss_eligible: dict[str, bool] = (
                {
                    k: (
                        self._loss.links == "all"
                        or (self._loss.links == "nic" and self._nic_link[k])
                        or (self._loss.links == "wan" and self._wan_link[k])
                    )
                    for k in topo.links
                }
                if self._loss is not None
                else {}
            )
            # XOR-FEC state (module docstring): open per-lane groups being
            # filled at commit time, chunk->group map (object identity —
            # chunk ids collide across flows), synthesized parity ids, and
            # the parity chunks to inject right behind each group closer.
            self.fec_recovered = 0
            self.fec_parity_chunks = 0
            self.fec_parity_bytes = 0.0
            self.fec_busted = 0
            self.fec_absorbed = 0  # losses that scheduled no retransmit
            if self._fec is not None:
                self._fec_open: dict[tuple[int, str], list[ChunkJob]] = {}
                self._fec_group_of: dict[int, _FecGroup] = {}
                self._parity_ids: set[int] = set()
                self._parity_after: dict[int, list[ChunkJob]] = {}
                self._parity_seq = itertools.count(1)
            # Fail-stop telemetry: strand counts per dead link, and how
            # many stranded chunks were re-sprayed onto a surviving rail.
            self.fail_strands: dict[str, int] = {}
            self.failovers = 0
            # Deepest ECN cut any sender took (end-of-run factors recover
            # additively and would hide it).
            self.min_sender_factor = 1.0
            # Stale mark counts (refreshed with the backlog snapshot) plus
            # per-link penalty scales for the reactive-policy signals.
            self._recent_marks: dict[str, int] = {}
            self._marks_at_snapshot: dict[str, int] = {}
            self._ecn_delay = (
                {k: self._ecn.mark_bytes / r for k, r in self._link_rate.items()}
                if self._ecn is not None
                else {}
            )
            self._pause_delay = (
                {k: self._pfc.pause_bytes / r for k, r in self._link_rate.items()}
                if self._pfc is not None
                else {}
            )
        # Observers receive (link, start, end, job) service intervals and
        # (job, t) completions — telemetry and feedback estimators hook
        # here. Callbacks are resolved once so the no-observer hot path is
        # a single falsy check per event.
        self.observers: list = []
        self._service_cbs: list = []
        self._completion_cbs: list = []
        self._mark_cbs: list = []
        self._drop_cbs: list = []
        self._pause_cbs: list = []
        for obs in observers:
            self.add_observer(obs)

    # -- observer fan-out -----------------------------------------------------

    def add_observer(self, obs) -> None:
        self.observers.append(obs)
        record = getattr(obs, "record_service", None)
        if record is not None:
            self._service_cbs.append(record)
        record = getattr(obs, "record_completion", None)
        if record is not None:
            self._completion_cbs.append(record)
        # Dynamics events: ECN marks, chunk drops, PFC pause intervals.
        record = getattr(obs, "record_mark", None)
        if record is not None:
            self._mark_cbs.append(record)
        record = getattr(obs, "record_drop", None)
        if record is not None:
            self._drop_cbs.append(record)
        record = getattr(obs, "record_pause", None)
        if record is not None:
            self._pause_cbs.append(record)

    def _notify_service(self, link: str, start: float, end: float, job) -> None:
        for cb in self._service_cbs:
            cb(link, start, end, job)

    def _notify_completion(self, job, t: float) -> None:
        for cb in self._completion_cbs:
            cb(job, t)

    # -- state the policies may query (assignment-phase estimates) ----------

    def queue_delay(self, link: str, now: float = 0.0, fresh: bool = True) -> float:
        """Estimated seconds of backlog on ``link``: assigned minus already
        transmitted bytes. The stale view is the backlog *as of the last
        snapshot* — both counters frozen together, the way a delayed probe
        reports a consistent (if old) reading. In the one-shot collective
        nothing has been transmitted during assignment, so both views
        equal the assigned-bytes estimate. A fail-stopped link is
        unusable, not merely backlogged: the sentinel is ``inf``."""
        if self.dead_links and link in self.dead_links:
            return _INF
        if fresh:
            backlog = self.assigned_bytes[link] - self.transmitted_bytes[link]
        else:
            backlog = self._snapshot[link]
        return max(backlog, 0.0) / self.topo.links[link].rate

    def path_delay(self, path: list[str], src_domain: int, now: float = 0.0) -> float:
        """Estimated waiting along a path: fresh for the sender's own
        up-links, stale snapshot for everything remote. Under fabric
        dynamics the estimate also folds in the congestion-control signals
        a real reactive transport would see — recent ECN marks (stale, via
        the probe snapshot) and live PFC pause assertions. A path crossing
        a fail-stopped link is unusable: the sentinel is ``inf`` (the
        policies must treat it as "never pick this while an alternative
        exists" — a 0-rate link has no finite drain time)."""
        if self.dead_links:
            for link in path:
                if link in self.dead_links:
                    return _INF
        assigned = self.assigned_bytes
        transmitted = self.transmitted_bytes
        snapshot = self._snapshot
        up_domain = self._up_domain
        rate = self._link_rate
        total = 0.0
        for link in path:
            if up_domain[link] == src_domain:
                backlog = assigned[link] - transmitted[link]
            else:
                backlog = snapshot[link]
            if backlog > 0.0:
                total += backlog / rate[link]
        if self._signals:
            total += self._signal_delay(path)
        return total

    def _signal_delay(self, path: list[str]) -> float:
        """Mark/pause penalty in seconds for a candidate path.

        ECN: recent marks (since the last probe snapshot — the same
        staleness as the backlog view) scaled by the queue-drain time the
        mark threshold represents. PFC: a currently-asserting link costs a
        full pause backlog's drain time. Every sender sees the same stale
        signals at once, which is exactly what makes reactive schemes herd
        (§VI-E)."""
        pen = 0.0
        recent = self._recent_marks
        if self._ecn is not None and recent:
            probe = self.probe_every
            ecn_delay = self._ecn_delay
            for link in path:
                m = recent.get(link)
                if m:
                    pen += (m / probe) * ecn_delay[link]
        if self._pfc is not None and self.paused_links:
            for link in path:
                if link in self.paused_links:
                    pen += self._pause_delay[link]
        return pen

    def _commit(self, job, path: list[str]) -> None:
        job.path = path
        size = job.size
        assigned = self.assigned_bytes
        for link in path:
            assigned[link] += size
        if self._fec is not None:
            self._fec_commit(job)
        self._decisions += 1
        if self._decisions % self.probe_every == 0:
            transmitted = self.transmitted_bytes
            self._snapshot = {k: assigned[k] - transmitted[k] for k in assigned}
            if self._ecn is not None:
                # Refresh the stale mark view on the same probe cadence.
                prev = self._marks_at_snapshot
                self._recent_marks = {
                    k: v - prev.get(k, 0) for k, v in self.ecn_marks.items() if v
                }
                self._marks_at_snapshot = dict(self.ecn_marks)

    # -- FEC encode (sender side) --------------------------------------------

    def _fec_commit(self, job: ChunkJob) -> None:
        """Accumulate a committed data chunk into its lane's open FEC
        group; on the k-th member, close the group and synthesize its r
        parity chunks (largest-member size, last member's path), to be
        injected right behind that member. Parity bytes are charged to the
        assigned ledger — they are real wire traffic the reactive backlog
        estimates must see."""
        lane = (job.flow_id, job.path[0])
        buf = self._fec_open.setdefault(lane, [])
        buf.append(job)
        fec = self._fec
        if len(buf) < fec.k:
            return
        del self._fec_open[lane]
        group = _FecGroup(fec.k, fec.r)
        for j in buf:
            self._fec_group_of[id(j)] = group
        last = buf[-1]
        psize = max(j.size for j in buf)
        assigned = self.assigned_bytes
        parity: list[ChunkJob] = []
        for _ in range(fec.r):
            pj = ChunkJob(
                chunk_id=-next(self._parity_seq),
                flow_id=last.flow_id,
                src_domain=last.src_domain,
                src_gpu=last.src_gpu,
                dst_domain=last.dst_domain,
                dst_gpu=last.dst_gpu,
                size=psize,
                arrival_time=last.arrival_time,
                round_id=last.round_id,
                path=list(last.path),
            )
            self._fec_group_of[id(pj)] = group
            self._parity_ids.add(id(pj))
            self.fec_parity_chunks += 1
            self.fec_parity_bytes += psize
            for link in pj.path:
                assigned[link] += psize
            parity.append(pj)
        self._parity_after[id(last)] = parity

    def _with_parity(self, jobs: list) -> list:
        """Interleave synthesized parity chunks right behind the data
        chunk that closed their group, preserving injection order (and
        hence deterministic fabric entry)."""
        if self._fec is None or not self._parity_after:
            return jobs
        after = self._parity_after
        out: list = []
        for j in jobs:
            out.append(j)
            ps = after.pop(id(j), None)
            if ps:
                out.extend(ps)
        return out

    # -- flowlet coalescing ---------------------------------------------------

    def _coalesce(self, batch: list[ChunkJob]) -> list:
        """Merge same-(sender GPU, path) chunks of one release batch into
        flowlets; singletons pass through untouched. Order of first
        appearance is preserved so fabric entry stays deterministic."""
        groups: dict[tuple, list[ChunkJob]] = {}
        keys: list[tuple] = []
        for j in batch:
            k = (j.src_domain, j.src_gpu, tuple(j.path))
            g = groups.get(k)
            if g is None:
                groups[k] = [j]
                keys.append(k)
            else:
                g.append(j)
        out: list = []
        for k in keys:
            g = groups[k]
            if len(g) == 1:
                out.append(g[0])
            else:
                flowlet = _Flowlet(g)
                self._flowlets.append(flowlet)
                out.append(flowlet)
        return out

    def _expand_flowlets(self) -> None:
        """Reconstruct member chunk times from each finished flowlet: the
        members drain back-to-back at the final link's rate, ending at the
        flowlet's completion."""
        for fl in self._flowlets:
            rate = self.topo.links[fl.path[-1]].rate
            remaining = fl.size
            t_end = fl.finish_time
            for j in fl.members:
                j.start_time = fl.start_time
                remaining -= j.size
                j.finish_time = t_end - remaining / rate
        self._flowlets.clear()

    # -- orchestration --------------------------------------------------------

    def run(self, jobs_by_sender: dict[tuple[int, int], list[ChunkJob]], policy) -> SimResult:
        """One-shot collective: assign everything, then simulate."""
        # Phase 1: the whole collective is one release batch; the policy's
        # assign_batch fixes the round-robin fabric-entry order.
        all_jobs: list[ChunkJob] = policy.assign_batch(self, jobs_by_sender, now=0.0)
        # Phase 2: discrete-event FIFO simulation.
        net = _FifoNetwork(self)
        sim_jobs = self._coalesce(all_jobs) if self.coalesce_flowlets else all_jobs
        # Stable sort keeps assignment order among equal release times (the
        # whole batch, in the t=0 one-shot case).
        for job in self._with_parity(sorted(sim_jobs, key=lambda j: j.arrival_time)):
            net.inject(job, job.arrival_time)
        net.drain()
        if self._flowlets:
            self._expand_flowlets()
        return self._result(all_jobs)

    def run_streaming(
        self, jobs_by_sender: dict[tuple[int, int], list[ChunkJob]], policy
    ) -> SimResult:
        """Streaming collective: chunks are revealed at their release time.

        All chunks sharing one release instant form a *batch*: the policy
        assigns the whole batch at once (so a planner can LPT over it),
        senders visited round-robin exactly as in the one-shot phase — with
        every release at t=0 this reproduces :meth:`run` event-for-event.
        The network is advanced to each release time first, so completion
        feedback observed by then is available to the policy.
        """
        releases: dict[float, dict[tuple[int, int], list[ChunkJob]]] = {}
        for key, jobs in jobs_by_sender.items():
            for j in jobs:
                releases.setdefault(j.arrival_time, {}).setdefault(key, []).append(j)
        net = _FifoNetwork(self)
        all_jobs: list[ChunkJob] = []
        for t in sorted(releases):
            if not math.isfinite(t):
                raise ValueError(f"non-finite release time {t!r}")
            net.advance_to(t)
            batch = policy.assign_batch(self, releases[t], now=t)
            all_jobs.extend(batch)
            sim_batch = self._coalesce(batch) if self.coalesce_flowlets else batch
            for job in self._with_parity(sim_batch):
                net.inject(job, t)
        net.drain()
        if self._flowlets:
            self._expand_flowlets()
        return self._result(all_jobs)

    def _result(self, all_jobs: list[ChunkJob]) -> SimResult:
        # Track last finish AND earliest release per flow so the reported
        # CCT is the sojourn (finish - release). All chunks of a flow share
        # one release in practice (a flow belongs to one round), but min()
        # keeps the accounting honest for hand-built job lists.
        flow_finish: dict[int, float] = {}
        flow_release: dict[int, float] = {}
        for j in all_jobs:
            fid = j.flow_id
            prev = flow_finish.get(fid)
            if prev is None or j.finish_time > prev:
                flow_finish[fid] = j.finish_time
            prev_r = flow_release.get(fid)
            if prev_r is None or j.arrival_time < prev_r:
                flow_release[fid] = j.arrival_time
        flow_cct = {fid: flow_finish[fid] - flow_release[fid] for fid in flow_finish}
        makespan = max((j.finish_time for j in all_jobs), default=0.0)
        return SimResult(
            jobs=all_jobs,
            link_bytes=dict(self.link_bytes),
            makespan=makespan,
            flow_cct=flow_cct,
            flow_release=flow_release,
            dynamics=self._dynamics_summary(),
        )

    def _dynamics_summary(self) -> dict | None:
        """Fabric-dynamics telemetry for the finished run (None = static)."""
        if not self._dynamic:
            return None
        drops = sum(self.drops.values())
        out = {
            "drops": drops,
            "gbn_discards": self.gbn_discards,
            "retransmits": drops + self.gbn_discards,
            "ecn_marks": sum(self.ecn_marks.values()),
            "pause_time": sum(self.pause_time.values()),
            "stall_time": sum(self.stall_time.values()),
            "delivered_chunks": self.delivered_chunks,
            "goodput_bytes": self.goodput_bytes,
            "wire_bytes": sum(self.link_bytes.values()),
            "min_sender_factor": self.min_sender_factor,
            "fail_strands": sum(self.fail_strands.values()),
            "failovers": self.failovers,
            "dead_links": sorted(self.dead_links),
        }
        if self._fec is not None:
            # Absorbed losses scheduled no retransmission — correct the
            # drops-based estimate above.
            out["retransmits"] = drops + self.gbn_discards - self.fec_absorbed
            out["fec_recovered"] = self.fec_recovered
            out["fec_absorbed"] = self.fec_absorbed
            out["fec_parity_chunks"] = self.fec_parity_chunks
            out["fec_parity_bytes"] = self.fec_parity_bytes
            out["fec_busted_groups"] = self.fec_busted
        return out
