"""Discrete-event queueing engine for the rail fabric.

Two phases, mirroring how a real deployment separates *control* (path
decisions from imperfect signals) from *data* (what the fabric actually
does):

**Assignment phase.** Senders are visited round-robin (an all-to-all is a
single synchronized burst); the policy assigns each atomic chunk a path.
Reactive policies estimate congestion from per-link *assigned-bytes*
counters — their own domain's up-links fresh, everything remote through a
stale snapshot refreshed every ``probe_every`` decisions (RTT-delayed
signals; the staleness is what makes reactive schemes herd under incast,
paper §VI-E). RailS ignores the estimates entirely: its plan is proactive
(Theorem 3 + LPT).

**Simulation phase.** A proper discrete-event simulation: every link is a
FIFO server (rate ``R`` bytes/s); chunks enter their first-hop queue at
t=0 in assignment order, are serviced in arrival order, and hop to the next
link after ``hop_latency``. Store-and-forward at chunk granularity —
pipelining across chunks of the same flow arises naturally.
"""

from __future__ import annotations

import dataclasses
import heapq

import numpy as np

from .topology import RailTopology

__all__ = ["ChunkJob", "SimResult", "Engine"]


@dataclasses.dataclass
class ChunkJob:
    """One atomic chunk to be transferred."""

    chunk_id: int
    flow_id: int
    src_domain: int
    src_gpu: int
    dst_domain: int
    dst_gpu: int
    size: float
    # Filled by the engine:
    path: list[str] | None = None
    start_time: float = 0.0
    finish_time: float = 0.0


@dataclasses.dataclass
class SimResult:
    jobs: list[ChunkJob]
    link_bytes: dict[str, float]
    makespan: float
    flow_cct: dict[int, float]  # per parent-flow completion time

    def cct_percentiles(self, qs=(50.0, 80.0, 95.0, 99.0)) -> dict[str, float]:
        vals = np.array(sorted(self.flow_cct.values()))
        out = {"mean": float(vals.mean())}
        for q in qs:
            out[f"p{int(q)}"] = float(np.percentile(vals, q))
        out["max"] = float(vals.max())
        return out


class Engine:
    def __init__(
        self,
        topo: RailTopology,
        hop_latency: float = 1e-6,
        probe_every: int = 64,
        seed: int = 0,
    ):
        self.topo = topo
        self.hop_latency = hop_latency
        self.probe_every = probe_every
        self.rng = np.random.default_rng(seed)
        self.assigned_bytes: dict[str, float] = {k: 0.0 for k in topo.links}
        self._snapshot: dict[str, float] = dict(self.assigned_bytes)
        self.link_bytes: dict[str, float] = {k: 0.0 for k in topo.links}
        self._decisions = 0

    # -- state the policies may query (assignment-phase estimates) ----------

    def queue_delay(self, link: str, now: float = 0.0, fresh: bool = True) -> float:
        """Estimated seconds of backlog on ``link`` from assigned bytes."""
        src = self.assigned_bytes if fresh else self._snapshot
        return src[link] / self.topo.links[link].rate

    def path_delay(self, path: list[str], src_domain: int, now: float = 0.0) -> float:
        """Estimated waiting along a path: fresh for the sender's own
        up-links, stale snapshot for everything remote."""
        total = 0.0
        for link in path:
            fresh = link.startswith("up:") and link.split(":")[1] == str(src_domain)
            total += self.queue_delay(link, now, fresh=fresh)
        return total

    def _commit(self, job: ChunkJob, path: list[str]) -> None:
        job.path = path
        for link in path:
            self.assigned_bytes[link] += job.size
        self._decisions += 1
        if self._decisions % self.probe_every == 0:
            self._snapshot = dict(self.assigned_bytes)

    # -- orchestration --------------------------------------------------------

    def run(self, jobs_by_sender: dict[tuple[int, int], list[ChunkJob]], policy) -> SimResult:
        # Phase 1: round-robin assignment.
        queues = {k: list(v) for k, v in jobs_by_sender.items() if v}
        order = sorted(queues)
        all_jobs: list[ChunkJob] = []
        while queues:
            for key in list(order):
                q = queues.get(key)
                if not q:
                    queues.pop(key, None)
                    continue
                job = q.pop(0)
                self._commit(job, policy.choose_path(self, job))
                all_jobs.append(job)
            order = [k for k in order if k in queues]
        # Phase 2: discrete-event FIFO simulation.
        self._simulate(all_jobs)
        flow_cct: dict[int, float] = {}
        for j in all_jobs:
            flow_cct[j.flow_id] = max(flow_cct.get(j.flow_id, 0.0), j.finish_time)
        makespan = max((j.finish_time for j in all_jobs), default=0.0)
        return SimResult(
            jobs=all_jobs,
            link_bytes=dict(self.link_bytes),
            makespan=makespan,
            flow_cct=flow_cct,
        )

    def _simulate(self, jobs: list[ChunkJob]) -> None:
        """Heap-driven DES: links are FIFO servers, service in arrival order."""
        link_queue: dict[str, list] = {k: [] for k in self.topo.links}  # heap of (arr, seq, job_idx, hop)
        link_busy: dict[str, bool] = {k: False for k in self.topo.links}
        events: list = []  # heap of (time, seq, kind, link, job_idx, hop)
        seq = 0

        def arrive(t: float, job_idx: int, hop: int):
            nonlocal seq
            job = jobs[job_idx]
            assert job.path is not None
            link = job.path[hop]
            heapq.heappush(link_queue[link], (t, seq, job_idx, hop))
            seq += 1
            maybe_start(link, t)

        def maybe_start(link: str, t: float):
            nonlocal seq
            if link_busy[link] or not link_queue[link]:
                return
            arr, _s, job_idx, hop = heapq.heappop(link_queue[link])
            job = jobs[job_idx]
            link_busy[link] = True
            if hop == 0:
                job.start_time = t
            finish = t + job.size / self.topo.links[link].rate
            self.link_bytes[link] += job.size
            heapq.heappush(events, (finish, seq, "done", link, job_idx, hop))
            seq += 1

        # All chunks hit their first-hop queue at t=0, in assignment order.
        for i, _job in enumerate(jobs):
            arrive(0.0, i, 0)

        while events:
            t, _s, _kind, link, job_idx, hop = heapq.heappop(events)
            job = jobs[job_idx]
            link_busy[link] = False
            assert job.path is not None
            if hop + 1 < len(job.path):
                arrive(t + self.hop_latency, job_idx, hop + 1)
            else:
                job.finish_time = t
            maybe_start(link, t)
