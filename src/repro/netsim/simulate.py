"""Top-level simulation drivers: traffic matrix -> policy -> metrics.

Two regimes:

* **Offline** (``run_collective``) — the paper's experiment loop: build
  atomic chunks from ``D1`` (flow splitting), hand them to a policy (which
  may plan proactively over the full matrix), run the queueing engine, and
  score with §VI-A metrics against the Theorem-2 optimum.
* **Streaming** (``run_streaming_collective``) — the online control plane:
  the workload is a sequence of *rounds* released over time (micro-batch
  boundaries, bursty gating); chunks are revealed to the policy only at
  their release instant, rail-health feedback and telemetry observers hook
  into the engine, and per-round completion times come back alongside the
  aggregate metrics. A single round released at t=0 with feedback disabled
  reproduces ``run_collective`` exactly.

Both regimes select a simulation **backend**:

* ``vector`` (offline default) — the array prefix-scan simulator
  (:mod:`repro.netsim.fastsim`): exact FIFO dynamics, no per-event Python
  dispatch, ~50–100× the event engine's chunk throughput. Planner policies
  (``rails``, ``ecmp``) assign in array form too; reactive policies keep
  their chunk-by-chunk assignment loop and only the fabric simulation is
  vectorized.
* ``event`` (streaming default) — the incremental DES
  (:mod:`repro.netsim.events`): required for flowlet coalescing, rail-health
  feedback, telemetry observers, and any policy that reads live backlog
  during a streaming run.
* ``device`` — the jax port of the vector scans
  (:mod:`repro.netsim.devicesim`): the same FIFO dynamics as one jitted
  device call over padded fixed-shape arrays, and — the point — batched
  ``vmap`` execution so a whole policy-suite grid or placement candidate
  set is a single dispatch. Parity with ``vector`` is float-tolerance,
  not bit-exact.
"""

from __future__ import annotations

import dataclasses

import numpy as np

from ..core.theorems import theorem2_optimal_time
from ..core.traffic import TrafficMatrix
from ..sched.feedback import RailHealthEstimator
from .balancers import POLICIES, OnlineRailSPolicy, Policy, RailSPolicy, make_policy
from .events import ChunkJob, Engine, SimResult
from .fastsim import (
    LinkIndex,
    build_job_arrays,
    chunk_jobs_from_arrays,
    entry_order_rank,
    paths_from_jobs,
    simulate_chunk_arrays,
)
from .metrics import CollectiveMetrics, compute_metrics
from .topology import RailTopology

__all__ = [
    "build_jobs",
    "build_streaming_jobs",
    "resolve_backend",
    "run_collective",
    "run_streaming_collective",
    "run_policy_suite",
    "StreamingResult",
]

BACKENDS = ("event", "vector", "device")


def build_jobs(
    tm: TrafficMatrix, chunk_bytes: float
) -> dict[tuple[int, int], list[ChunkJob]]:
    """Flow-split D1 into atomic ChunkJobs, grouped by source GPU.

    The struct-of-arrays splitter (:func:`repro.netsim.fastsim.
    build_job_arrays`) is the single source of truth; this materializes its
    columns as the legacy per-sender lists the event engine consumes.
    """
    return chunk_jobs_from_arrays(build_job_arrays(tm, chunk_bytes))


def resolve_backend(
    backend: str | None, topo: RailTopology | None = None
) -> str:
    """The one backend resolver every driver shares (offline, streaming,
    serving gateway).

    Unknown backend names are rejected first, so typos never run silently.
    With no fabric (or a static one) the explicit choice — or the
    ``vector`` default — stands. A *dynamic* fabric (non-static fault
    spec: time-varying profiles, PFC/ECN/loss) only runs on the event
    engine: an unspecified backend falls back to it silently, an explicit
    array backend is an error naming that fallback (``device`` first
    consults :func:`repro.netsim.devicesim.check_device_supports`, which
    raises the device-side gap by name).
    """
    if backend is not None and backend not in BACKENDS:
        raise ValueError(f"unknown backend {backend!r}; choose {BACKENDS}")
    if topo is not None and topo.has_dynamics:
        if backend == "device":
            from .devicesim import check_device_supports

            check_device_supports(topo)  # raises NotImplementedError
        if backend in ("vector", "device"):
            raise ValueError(
                f"backend={backend!r} supports constant-profile link "
                "models only; this fault_spec needs the event fallback "
                "(backend='event')"
            )
        return "event"
    return backend if backend is not None else "vector"


def _resolve_fabric(
    fabric: RailTopology | None,
    tm: TrafficMatrix,
    r1: float,
    r2: float,
    rail_speeds,
    fault_spec,
) -> RailTopology:
    """The driver-side fabric source: a prebuilt ``fabric`` wins, a flat
    ``RailTopology`` is built otherwise. A prebuilt fabric must match the
    workload's ``(M, N)`` shape and owns its own speeds/dynamics — passing
    ``rail_speeds``/``fault_spec`` alongside it is ambiguous and rejected.
    """
    if fabric is None:
        return RailTopology(
            tm.num_domains, tm.num_rails, r1=r1, r2=r2,
            rail_speeds=rail_speeds, fault_spec=fault_spec,
        )
    if rail_speeds is not None or fault_spec is not None:
        raise ValueError(
            "pass rail_speeds/fault_spec via the prebuilt fabric, not "
            "alongside it"
        )
    if (fabric.m, fabric.n) != (tm.num_domains, tm.num_rails):
        raise ValueError(
            f"fabric shape ({fabric.m} domains x {fabric.n} rails) does "
            f"not match workload ({tm.num_domains} x {tm.num_rails})"
        )
    return fabric


def _array_simulator(backend: str):
    """The chunk-array simulate function for an array backend name."""
    if backend == "device":
        from .devicesim import simulate_chunk_arrays_device

        return simulate_chunk_arrays_device
    return simulate_chunk_arrays


def _plan_collective(
    topo: RailTopology,
    index: LinkIndex,
    tm: TrafficMatrix,
    policy_name: str,
    chunk_bytes: float,
    seed: int,
    probe_every: int,
):
    """Host-side planning phase of one offline collective.

    Planner policies fill path columns straight from :class:`JobArrays`;
    everything else runs its normal assignment phase against a (never
    simulated) engine. Returns ``(job_arrays, link_by_level, entry_rank)``
    — the columns any array backend consumes.
    """
    ja = build_job_arrays(tm, chunk_bytes)
    policy = make_policy(policy_name, topo, seed=seed)
    if hasattr(policy, "plan_arrays"):
        link_by_level = policy.plan_arrays(ja, index)
        entry_rank = entry_order_rank(ja.src_domain, ja.src_gpu, topo.n)
    else:
        jobs = chunk_jobs_from_arrays(ja)
        policy.prepare(jobs)
        eng = Engine(topo, probe_every=probe_every, seed=seed)
        ordered = policy.assign_batch(eng, jobs, now=0.0)
        link_by_level, entry_rank = paths_from_jobs(ordered, index, ja.num_chunks)
    return ja, link_by_level, entry_rank


def _run_collective_vector(
    topo: RailTopology,
    tm: TrafficMatrix,
    policy_name: str,
    chunk_bytes: float,
    seed: int,
    probe_every: int,
    backend: str = "vector",
):
    """Offline collective on an array backend (``vector`` or ``device``)."""
    index = LinkIndex(topo)
    ja, link_by_level, entry_rank = _plan_collective(
        topo, index, tm, policy_name, chunk_bytes, seed, probe_every
    )
    return _array_simulator(backend)(
        index,
        link_by_level,
        ja.size,
        ja.release,
        entry_rank,
        hop_latency=1e-6,  # the Engine default — all backends share it
        flow_id=ja.flow_id,
        round_id=ja.round_id,
    )


def run_collective(
    tm: TrafficMatrix,
    policy_name: str,
    r1: float = 400e9,
    r2: float = 50e9,
    chunk_bytes: float = 4 * 2**20,
    seed: int = 0,
    probe_every: int = 64,
    coalesce: bool = False,
    backend: str | None = None,
    rail_speeds=None,
    fault_spec=None,
    fabric: RailTopology | None = None,
) -> CollectiveMetrics:
    """Simulate one all-to-all under one policy; return §VI-A metrics.

    ``backend`` selects the simulator: ``vector`` (the default for exact
    runs) computes the exact FIFO dynamics with array prefix scans;
    ``device`` runs the same dynamics as one jitted jax call (float-
    tolerance parity with ``vector``); ``event`` runs the discrete-event
    engine. ``coalesce=True`` enables
    flowlet coalescing — an event-engine approximation (merged same-lane
    service events) — so it defaults to the event backend, and asking for
    ``backend="vector"`` together with it is an error (mirroring
    :func:`run_streaming_collective`).

    ``rail_speeds`` are static per-rail speed factors; ``fault_spec`` (a
    :class:`repro.netsim.linkmodel.FaultSpec`) attaches the link-dynamics
    layer — time-varying rate profiles, PFC, ECN, loss + go-back-N. A
    non-static spec forces the event backend (the vector simulator rejects
    it by name); a fully static spec runs on either backend bit-exactly.

    ``fabric`` passes a prebuilt topology (e.g. a
    :class:`~repro.netsim.topology.MultiPodFabric`) instead of the flat
    ``RailTopology`` constructed from ``r1``/``r2``; the two forms are
    mutually exclusive with ``rail_speeds``/``fault_spec`` (bake those
    into the fabric itself).
    """
    if coalesce and backend is None:
        backend = "event"
    topo = _resolve_fabric(
        fabric, tm, r1, r2, rail_speeds, fault_spec
    )
    backend = resolve_backend(backend, topo)
    if coalesce and backend in ("vector", "device"):
        raise ValueError(
            "flowlet coalescing is an event-engine approximation; drop "
            "coalesce=True or use backend='event'"
        )
    opt = theorem2_optimal_time(tm.d2, tm.num_rails, topo.r2)
    if backend in ("vector", "device"):
        result = _run_collective_vector(
            topo, tm, policy_name, chunk_bytes, seed, probe_every,
            backend=backend,
        )
        return compute_metrics(result, topo, tm.name, policy_name, opt)
    jobs = build_jobs(tm, chunk_bytes)
    policy = make_policy(policy_name, topo, seed=seed)
    policy.prepare(jobs)
    engine = Engine(topo, probe_every=probe_every, seed=seed, coalesce_flowlets=coalesce)
    result = engine.run(jobs, policy)
    return compute_metrics(result, topo, tm.name, policy_name, opt)


def build_streaming_jobs(
    rounds: list[tuple[float, TrafficMatrix]], chunk_bytes: float
) -> dict[tuple[int, int], list[ChunkJob]]:
    """Flow-split a sequence of ``(release_time, TrafficMatrix)`` rounds.

    Chunk/flow ids stay globally unique across rounds; every chunk carries
    its round's release as ``arrival_time`` and its round index as
    ``round_id``.
    """
    out: dict[tuple[int, int], list[ChunkJob]] = {}
    chunk_off = 0
    flow_off = 0
    for rnd, (release, tm) in enumerate(rounds):
        if release < 0:
            raise ValueError(f"release times must be >= 0, got {release}")
        per_round = build_jobs(tm, chunk_bytes)
        max_flow = -1
        num_chunks = 0
        for key, jobs in per_round.items():
            for j in jobs:
                j.chunk_id += chunk_off
                j.flow_id += flow_off
                j.arrival_time = float(release)
                j.round_id = rnd
                max_flow = max(max_flow, j.flow_id)
                num_chunks += 1
            out.setdefault(key, []).extend(jobs)
        chunk_off += num_chunks
        # max() keeps the offset monotone across empty rounds (max_flow
        # stays -1 there, which must not reset the id space).
        flow_off = max(flow_off, max_flow + 1)
    return out


@dataclasses.dataclass
class StreamingResult:
    """Outcome of one streaming collective."""

    metrics: CollectiveMetrics
    sim: SimResult
    round_cct: dict[int, float]  # round_id -> last *absolute* completion time
    # round_id -> sojourn (last completion minus the round's release);
    # the release-relative counterpart of round_cct, computed by the
    # simulation backends themselves.
    round_sojourn: dict[int, float] = dataclasses.field(default_factory=dict)
    health: RailHealthEstimator | None = None

    @property
    def makespan(self) -> float:
        return self.metrics.makespan


def _run_streaming_vector(
    topo: RailTopology,
    jobs: dict[tuple[int, int], list[ChunkJob]],
    policy,
    probe_every: int,
    seed: int,
    backend: str = "vector",
):
    """Streaming collective on an array backend (proactive planners only).

    The policy assigns each release batch exactly as the event engine
    would — batches in release order, round-robin senders — but against a
    state-holder engine whose network is never advanced. That is lossless
    precisely when the policy ignores live fabric feedback (RailS /
    rails-online without health estimation), which the caller enforces.
    """
    releases: dict[float, dict[tuple[int, int], list[ChunkJob]]] = {}
    num_chunks = 0
    for key, sender_jobs in jobs.items():
        for j in sender_jobs:
            releases.setdefault(j.arrival_time, {}).setdefault(key, []).append(j)
            num_chunks += 1
    eng = Engine(topo, probe_every=probe_every, seed=seed)
    ordered: list[ChunkJob] = []
    for t in sorted(releases):
        ordered.extend(policy.assign_batch(eng, releases[t], now=t))
    index = LinkIndex(topo)
    link_by_level, entry_rank = paths_from_jobs(ordered, index, num_chunks)
    size = np.empty(num_chunks)
    release = np.empty(num_chunks)
    flow_id = np.empty(num_chunks, dtype=np.int64)
    round_id = np.empty(num_chunks, dtype=np.int64)
    for j in ordered:
        cid = j.chunk_id
        size[cid] = j.size
        release[cid] = j.arrival_time
        flow_id[cid] = j.flow_id
        round_id[cid] = j.round_id
    return _array_simulator(backend)(
        index,
        link_by_level,
        size,
        release,
        entry_rank,
        hop_latency=1e-6,  # the Engine default — both backends share it
        flow_id=flow_id,
        round_id=round_id,
    )


def run_streaming_collective(
    workload: TrafficMatrix | list[tuple[float, TrafficMatrix]],
    policy_name: str,
    r1: float = 400e9,
    r2: float = 50e9,
    chunk_bytes: float = 4 * 2**20,
    seed: int = 0,
    probe_every: int = 64,
    rail_speeds=None,
    fault_spec=None,
    feedback: bool = False,
    window: int | None = None,
    replay=None,
    recorder=None,
    detector=None,
    coalesce: bool = False,
    backend: str = "event",
    fabric: RailTopology | None = None,
) -> StreamingResult:
    """Simulate a streaming all-to-all (chunks released over time).

    Args:
      workload: a single :class:`TrafficMatrix` (one round at t=0 — the
        offline-parity case) or a list of ``(release_time, TrafficMatrix)``
        rounds.
      policy_name: any registered policy; reactive baselines run unchanged
        (they always decided chunk-by-chunk), ``rails-online`` engages the
        online control plane.
      rail_speeds: optional static per-rail speed factors (> 0; below 1.0
        models the straggler-rail scenario, above 1.0 an over-provisioned
        rail).
      fault_spec: optional :class:`repro.netsim.linkmodel.FaultSpec` — the
        link-dynamics layer (time-varying rate profiles, PFC pause, ECN
        marking, chunk loss + go-back-N recovery). Non-static specs need
        the event backend.
      feedback: attach a :class:`RailHealthEstimator` to the engine and, for
        ``rails-online``, fold its speed estimates into the LoadState.
        Pass an estimator instance (e.g. with ``track_history=True``) to
        use it directly instead of the default-constructed one.
      window: re-planning window for ``rails-online`` (None = whole batch).
      replay: optional ``RoutingReplayState`` forecast for ``rails-online``;
        updated in place with this run's realized per-domain loads.
      recorder: optional ``repro.sched.telemetry.TraceRecorder``.
      detector: optional ``repro.sched.feedback.DeadRailDetector`` — the
        silence-based dead-rail watchdog. Registered as an engine observer
        (every NIC-lane service is a heartbeat) and, for ``rails-online``,
        swept at each assignment batch so the windowed LPT plans over the
        survivor mask (event backend only).
      coalesce: enable flowlet coalescing (merged same-lane service
        events); exact CCTs require the default ``False``.
      backend: ``event`` (default — the incremental DES, required for
        feedback/telemetry/coalescing and reactive policies), ``vector``
        (exact array simulation; proactive planners without fabric feedback
        only — the reference for coalescing drift measurements) or
        ``device`` (the jitted jax scan, same restrictions as ``vector``,
        float-tolerance parity).
      fabric: optional prebuilt topology (e.g. a
        :class:`~repro.netsim.topology.MultiPodFabric`) replacing the flat
        ``RailTopology`` built from ``r1``/``r2``; mutually exclusive with
        ``rail_speeds``/``fault_spec`` (bake those into the fabric).
    """
    resolve_backend(backend)
    if isinstance(workload, TrafficMatrix):
        rounds = [(0.0, workload)]
    else:
        rounds = sorted(workload, key=lambda rt: rt[0])
    if not rounds:
        raise ValueError("streaming workload needs at least one round")
    tm0 = rounds[0][1]
    m, n = tm0.num_domains, tm0.num_rails
    for _t, tm in rounds:
        if (tm.num_domains, tm.num_rails) != (m, n):
            raise ValueError("all rounds must share one (M, N) fabric shape")
    topo = _resolve_fabric(fabric, tm0, r1, r2, rail_speeds, fault_spec)
    jobs = build_streaming_jobs(rounds, chunk_bytes)
    if isinstance(feedback, RailHealthEstimator):
        if feedback.num_rails != n:
            raise ValueError(
                f"feedback estimator covers {feedback.num_rails} rails, "
                f"fabric has {n}"
            )
        health = feedback
    else:
        health = RailHealthEstimator(n, nominal_rate=topo.r2) if feedback else None
    kwargs: dict = {}
    policy_cls = POLICIES.get(policy_name, Policy)
    if issubclass(policy_cls, OnlineRailSPolicy):
        kwargs = {
            "window": window, "health": health, "replay": replay,
            "detector": detector,
        }
    policy = make_policy(policy_name, topo, seed=seed, **kwargs)
    policy.prepare(jobs)
    if backend in ("vector", "device"):
        resolve_backend(backend, topo)  # dynamics need the event engine
        if feedback or recorder is not None or coalesce or detector is not None:
            raise ValueError(
                f"{backend} streaming is feedback-free: rail-health "
                "estimation, dead-rail detection, telemetry recording and "
                "flowlet coalescing need the event engine's live service "
                "stream"
            )
        if not issubclass(policy_cls, (RailSPolicy, OnlineRailSPolicy)):
            raise ValueError(
                f"{backend} streaming requires a proactive planner; "
                f"{policy_name!r} reads live backlog estimates during the run"
            )
        result = _run_streaming_vector(
            topo, jobs, policy, probe_every, seed, backend=backend
        )
    else:
        engine = Engine(
            topo, probe_every=probe_every, seed=seed, coalesce_flowlets=coalesce
        )
        if health is not None:
            engine.add_observer(health)
        if recorder is not None:
            engine.add_observer(recorder)
        if detector is not None:
            engine.add_observer(detector)
        result = engine.run_streaming(jobs, policy)
    # Lower bound: each round cannot beat its own Theorem-2 time after its
    # release, nor can the union beat the aggregate matrix's bound.
    d2_total = sum(tm.d2 for _t, tm in rounds)
    opt = max(
        [theorem2_optimal_time(d2_total, n, topo.r2)]
        + [t + theorem2_optimal_time(tm.d2, n, topo.r2) for t, tm in rounds]
    )
    name = tm0.name if len(rounds) == 1 else f"stream[{len(rounds)}x{tm0.name}]"
    metrics = compute_metrics(result, topo, name, policy_name, opt)
    if replay is not None:
        sent = {d: 0.0 for d in range(m)}
        for js in jobs.values():
            for j in js:
                sent[j.src_domain] += j.size
        loads = getattr(policy, "loads", None)
        replay.update_from_loads(
            [sent[d] for d in range(m)],
            [loads.get(d, np.zeros(n)) for d in range(m)] if loads else None,
        )
    round_cct, round_sojourn = result.round_times()
    return StreamingResult(
        metrics=metrics,
        sim=result,
        round_cct=round_cct,
        round_sojourn=round_sojourn,
        health=health,
    )


def run_policy_suite(
    tm: TrafficMatrix,
    policies: tuple[str, ...] = ("ecmp", "minrtt", "plb", "reps", "rails"),
    **kwargs,
) -> dict[str, CollectiveMetrics]:
    """Run every policy on the same workload (the paper's comparison grid).

    ``kwargs`` pass through to :func:`run_collective` — in particular
    ``backend={"event","vector","device"}`` (vector is the offline default,
    which is what keeps full-grid sweeps at paper scale under a minute).
    ``backend="device"`` batches the whole grid: every policy plans
    host-side, then all members run as **one** ``vmap``-ed device call
    instead of a Python loop over simulations.
    """
    if kwargs.get("backend") == "device":
        return _run_policy_suite_device(tm, policies, **kwargs)
    return {p: run_collective(tm, p, **kwargs) for p in policies}


def _run_policy_suite_device(
    tm: TrafficMatrix,
    policies: tuple[str, ...],
    r1: float = 400e9,
    r2: float = 50e9,
    chunk_bytes: float = 4 * 2**20,
    seed: int = 0,
    probe_every: int = 64,
    backend: str = "device",
    rail_speeds=None,
    fault_spec=None,
    fabric: RailTopology | None = None,
) -> dict[str, CollectiveMetrics]:
    """The batched policy-suite grid: one device dispatch for all policies."""
    from .devicesim import PlannedJobs, check_device_supports, simulate_many_device

    assert backend == "device"
    topo = _resolve_fabric(fabric, tm, r1, r2, rail_speeds, fault_spec)
    check_device_supports(topo)
    index = LinkIndex(topo)
    planned = []
    for p in policies:
        ja, link_by_level, entry_rank = _plan_collective(
            topo, index, tm, p, chunk_bytes, seed, probe_every
        )
        planned.append(
            PlannedJobs(
                link_by_level=link_by_level,
                size=ja.size,
                release=ja.release,
                entry_rank=entry_rank,
                flow_id=ja.flow_id,
                round_id=ja.round_id,
            )
        )
    results = simulate_many_device(index, planned, hop_latency=1e-6)
    opt = theorem2_optimal_time(tm.d2, tm.num_rails, topo.r2)
    return {
        p: compute_metrics(res, topo, tm.name, p, opt)
        for p, res in zip(policies, results)
    }
