"""Top-level simulation drivers: traffic matrix -> policy -> metrics.

Two regimes:

* **Offline** (``run_collective``) — the paper's experiment loop: build
  atomic chunks from ``D1`` (flow splitting), hand them to a policy (which
  may plan proactively over the full matrix), run the queueing engine, and
  score with §VI-A metrics against the Theorem-2 optimum.
* **Streaming** (``run_streaming_collective``) — the online control plane:
  the workload is a sequence of *rounds* released over time (micro-batch
  boundaries, bursty gating); chunks are revealed to the policy only at
  their release instant, rail-health feedback and telemetry observers hook
  into the engine, and per-round completion times come back alongside the
  aggregate metrics. A single round released at t=0 with feedback disabled
  reproduces ``run_collective`` exactly.
"""

from __future__ import annotations

import dataclasses

import numpy as np

from ..core.plan import split_message
from ..core.theorems import theorem2_optimal_time
from ..core.traffic import TrafficMatrix
from ..sched.feedback import RailHealthEstimator
from .balancers import POLICIES, OnlineRailSPolicy, Policy, make_policy
from .events import ChunkJob, Engine, SimResult
from .metrics import CollectiveMetrics, compute_metrics
from .topology import RailTopology

__all__ = [
    "build_jobs",
    "build_streaming_jobs",
    "run_collective",
    "run_streaming_collective",
    "run_policy_suite",
    "StreamingResult",
]


def build_jobs(
    tm: TrafficMatrix, chunk_bytes: float
) -> dict[tuple[int, int], list[ChunkJob]]:
    """Flow-split D1 into atomic ChunkJobs, grouped by source GPU."""
    m, n = tm.num_domains, tm.num_rails
    jobs: dict[tuple[int, int], list[ChunkJob]] = {}
    chunk_id = 0
    flow_id = 0
    for d in range(m):
        for g in range(n):
            sender_jobs: list[ChunkJob] = []
            for f in range(m):
                if f == d:
                    continue  # intra-domain stays on NVLink (Theorem 1)
                for gd in range(n):
                    size = float(tm.d1[d, g, f, gd])
                    if size <= 0:
                        continue
                    for part in split_message(size, chunk_bytes, d, f, g, flow_id):
                        sender_jobs.append(
                            ChunkJob(
                                chunk_id=chunk_id,
                                flow_id=flow_id,
                                src_domain=d,
                                src_gpu=g,
                                dst_domain=f,
                                dst_gpu=gd,
                                size=part.size,
                            )
                        )
                        chunk_id += 1
                    flow_id += 1
            if sender_jobs:
                jobs[(d, g)] = sender_jobs
    return jobs


def run_collective(
    tm: TrafficMatrix,
    policy_name: str,
    r1: float = 400e9,
    r2: float = 50e9,
    chunk_bytes: float = 4 * 2**20,
    seed: int = 0,
    probe_every: int = 64,
    coalesce: bool = False,
) -> CollectiveMetrics:
    """Simulate one all-to-all under one policy; return §VI-A metrics.

    ``coalesce=True`` enables flowlet coalescing in the engine (merged
    same-lane service events — faster at large scale, approximate CCTs).
    """
    topo = RailTopology(tm.num_domains, tm.num_rails, r1=r1, r2=r2)
    jobs = build_jobs(tm, chunk_bytes)
    policy = make_policy(policy_name, topo, seed=seed)
    policy.prepare(jobs)
    engine = Engine(topo, probe_every=probe_every, seed=seed, coalesce_flowlets=coalesce)
    result = engine.run(jobs, policy)
    opt = theorem2_optimal_time(tm.d2, tm.num_rails, r2)
    return compute_metrics(result, topo, tm.name, policy_name, opt)


def build_streaming_jobs(
    rounds: list[tuple[float, TrafficMatrix]], chunk_bytes: float
) -> dict[tuple[int, int], list[ChunkJob]]:
    """Flow-split a sequence of ``(release_time, TrafficMatrix)`` rounds.

    Chunk/flow ids stay globally unique across rounds; every chunk carries
    its round's release as ``arrival_time`` and its round index as
    ``round_id``.
    """
    out: dict[tuple[int, int], list[ChunkJob]] = {}
    chunk_off = 0
    flow_off = 0
    for rnd, (release, tm) in enumerate(rounds):
        if release < 0:
            raise ValueError(f"release times must be >= 0, got {release}")
        per_round = build_jobs(tm, chunk_bytes)
        max_flow = -1
        num_chunks = 0
        for key, jobs in per_round.items():
            for j in jobs:
                j.chunk_id += chunk_off
                j.flow_id += flow_off
                j.arrival_time = float(release)
                j.round_id = rnd
                max_flow = max(max_flow, j.flow_id)
                num_chunks += 1
            out.setdefault(key, []).extend(jobs)
        chunk_off += num_chunks
        # max() keeps the offset monotone across empty rounds (max_flow
        # stays -1 there, which must not reset the id space).
        flow_off = max(flow_off, max_flow + 1)
    return out


@dataclasses.dataclass
class StreamingResult:
    """Outcome of one streaming collective."""

    metrics: CollectiveMetrics
    sim: SimResult
    round_cct: dict[int, float]  # round_id -> last completion time
    health: RailHealthEstimator | None = None

    @property
    def makespan(self) -> float:
        return self.metrics.makespan


def run_streaming_collective(
    workload: TrafficMatrix | list[tuple[float, TrafficMatrix]],
    policy_name: str,
    r1: float = 400e9,
    r2: float = 50e9,
    chunk_bytes: float = 4 * 2**20,
    seed: int = 0,
    probe_every: int = 64,
    rail_speeds=None,
    feedback: bool = False,
    window: int | None = None,
    replay=None,
    recorder=None,
    coalesce: bool = False,
) -> StreamingResult:
    """Simulate a streaming all-to-all (chunks released over time).

    Args:
      workload: a single :class:`TrafficMatrix` (one round at t=0 — the
        offline-parity case) or a list of ``(release_time, TrafficMatrix)``
        rounds.
      policy_name: any registered policy; reactive baselines run unchanged
        (they always decided chunk-by-chunk), ``rails-online`` engages the
        online control plane.
      rail_speeds: optional per-rail degradation factors in (0, 1] — the
        straggler-rail scenario.
      feedback: attach a :class:`RailHealthEstimator` to the engine and, for
        ``rails-online``, fold its speed estimates into the LoadState.
      window: re-planning window for ``rails-online`` (None = whole batch).
      replay: optional ``RoutingReplayState`` forecast for ``rails-online``;
        updated in place with this run's realized per-domain loads.
      recorder: optional ``repro.sched.telemetry.TraceRecorder``.
      coalesce: enable flowlet coalescing (merged same-lane service
        events); exact CCTs require the default ``False``.
    """
    if isinstance(workload, TrafficMatrix):
        rounds = [(0.0, workload)]
    else:
        rounds = sorted(workload, key=lambda rt: rt[0])
    if not rounds:
        raise ValueError("streaming workload needs at least one round")
    tm0 = rounds[0][1]
    m, n = tm0.num_domains, tm0.num_rails
    for _t, tm in rounds:
        if (tm.num_domains, tm.num_rails) != (m, n):
            raise ValueError("all rounds must share one (M, N) fabric shape")
    topo = RailTopology(m, n, r1=r1, r2=r2, rail_speeds=rail_speeds)
    jobs = build_streaming_jobs(rounds, chunk_bytes)
    health = RailHealthEstimator(n, nominal_rate=r2) if feedback else None
    kwargs: dict = {}
    if issubclass(POLICIES.get(policy_name, Policy), OnlineRailSPolicy):
        kwargs = {"window": window, "health": health, "replay": replay}
    policy = make_policy(policy_name, topo, seed=seed, **kwargs)
    policy.prepare(jobs)
    engine = Engine(topo, probe_every=probe_every, seed=seed, coalesce_flowlets=coalesce)
    if health is not None:
        engine.add_observer(health)
    if recorder is not None:
        engine.add_observer(recorder)
    result = engine.run_streaming(jobs, policy)
    # Lower bound: each round cannot beat its own Theorem-2 time after its
    # release, nor can the union beat the aggregate matrix's bound.
    d2_total = sum(tm.d2 for _t, tm in rounds)
    opt = max(
        [theorem2_optimal_time(d2_total, n, r2)]
        + [t + theorem2_optimal_time(tm.d2, n, r2) for t, tm in rounds]
    )
    name = tm0.name if len(rounds) == 1 else f"stream[{len(rounds)}x{tm0.name}]"
    metrics = compute_metrics(result, topo, name, policy_name, opt)
    if replay is not None:
        sent = {d: 0.0 for d in range(m)}
        for js in jobs.values():
            for j in js:
                sent[j.src_domain] += j.size
        loads = getattr(policy, "loads", None)
        replay.update_from_loads(
            [sent[d] for d in range(m)],
            [loads.get(d, np.zeros(n)) for d in range(m)] if loads else None,
        )
    return StreamingResult(
        metrics=metrics,
        sim=result,
        round_cct=result.round_completion_times(),
        health=health,
    )


def run_policy_suite(
    tm: TrafficMatrix,
    policies: tuple[str, ...] = ("ecmp", "minrtt", "plb", "reps", "rails"),
    **kwargs,
) -> dict[str, CollectiveMetrics]:
    """Run every policy on the same workload (the paper's comparison grid)."""
    return {p: run_collective(tm, p, **kwargs) for p in policies}
