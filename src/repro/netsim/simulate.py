"""Top-level simulation driver: traffic matrix -> policy -> metrics.

``run_collective`` is the single entry point the benchmarks use; it mirrors
the paper's experiment loop: build atomic chunks from ``D1`` (flow
splitting), hand them to a policy (which may plan proactively), run the
queueing engine, and score with §VI-A metrics against the Theorem-2 optimum.
"""

from __future__ import annotations

from ..core.plan import split_message
from ..core.theorems import theorem2_optimal_time
from ..core.traffic import TrafficMatrix
from .balancers import make_policy
from .events import ChunkJob, Engine
from .metrics import CollectiveMetrics, compute_metrics
from .topology import RailTopology

__all__ = ["build_jobs", "run_collective", "run_policy_suite"]


def build_jobs(
    tm: TrafficMatrix, chunk_bytes: float
) -> dict[tuple[int, int], list[ChunkJob]]:
    """Flow-split D1 into atomic ChunkJobs, grouped by source GPU."""
    m, n = tm.num_domains, tm.num_rails
    jobs: dict[tuple[int, int], list[ChunkJob]] = {}
    chunk_id = 0
    flow_id = 0
    for d in range(m):
        for g in range(n):
            sender_jobs: list[ChunkJob] = []
            for f in range(m):
                if f == d:
                    continue  # intra-domain stays on NVLink (Theorem 1)
                for gd in range(n):
                    size = float(tm.d1[d, g, f, gd])
                    if size <= 0:
                        continue
                    for part in split_message(size, chunk_bytes, d, f, g, flow_id):
                        sender_jobs.append(
                            ChunkJob(
                                chunk_id=chunk_id,
                                flow_id=flow_id,
                                src_domain=d,
                                src_gpu=g,
                                dst_domain=f,
                                dst_gpu=gd,
                                size=part.size,
                            )
                        )
                        chunk_id += 1
                    flow_id += 1
            if sender_jobs:
                jobs[(d, g)] = sender_jobs
    return jobs


def run_collective(
    tm: TrafficMatrix,
    policy_name: str,
    r1: float = 400e9,
    r2: float = 50e9,
    chunk_bytes: float = 4 * 2**20,
    seed: int = 0,
    probe_every: int = 64,
) -> CollectiveMetrics:
    """Simulate one all-to-all under one policy; return §VI-A metrics."""
    topo = RailTopology(tm.num_domains, tm.num_rails, r1=r1, r2=r2)
    jobs = build_jobs(tm, chunk_bytes)
    policy = make_policy(policy_name, topo, seed=seed)
    policy.prepare(jobs)
    engine = Engine(topo, probe_every=probe_every, seed=seed)
    result = engine.run(jobs, policy)
    opt = theorem2_optimal_time(tm.d2, tm.num_rails, r2)
    return compute_metrics(result, topo, tm.name, policy_name, opt)


def run_policy_suite(
    tm: TrafficMatrix,
    policies: tuple[str, ...] = ("ecmp", "minrtt", "plb", "reps", "rails"),
    **kwargs,
) -> dict[str, CollectiveMetrics]:
    """Run every policy on the same workload (the paper's comparison grid)."""
    return {p: run_collective(tm, p, **kwargs) for p in policies}
