"""Fabric topology models: the flat rail pod (paper §III-A, Fig. 3) and
hierarchical multi-pod fabrics joined by oversubscribed inter-pod links.

The flat case — :class:`RailTopology` — is the paper's: M domains × N NICs.
NIC ``(d, n)`` connects to leaf switch ``S_n`` at rate ``R2``; leaves
connect to a spine layer (for ECMP cross-rail paths); GPUs inside a domain
interconnect at rate ``R1 > R2`` (NVLink analogue — per Theorem 1 it never
bottlenecks, so intra-domain hops are modeled as free).

A *path* is the ordered list of serialization resources (links) a chunk
occupies. Two path families exist, matching the paper's Challenge 1:

* **rail-direct**: ``NIC(src,n) → S_n → NIC(dst,n)`` — same rail index n on
  both sides (the one-to-one mapping RailS exploits).
* **spine**: ``NIC(src,n) → S_n → spine_p → S_m → NIC(dst,m)`` — crosses
  rails via the spine; this is what ECMP hashing uses.

:class:`MultiPodFabric` generalizes this to P rail pods joined by
oversubscribed inter-pod WAN lanes (long RTT, low aggregate rate — the
cross-datacenter regime). Cross-pod paths leave on a source NIC lane,
cross one of the scarce ``wan:{p}:{q}:{lane}`` links, and land on the
destination NIC lane. ``P=1`` degenerates to the exact flat pod: the link
inventory, names, insertion order and level structure are byte-identical
to :class:`RailTopology`, which is what the BitExact parity gate pins.

Both classes implement the :class:`Fabric` protocol. The load-bearing
addition over the historical single-topology code is ``level_kinds``: the
ordered tuple of link-name kinds a path may visit (at most one link per
kind, in tuple order). The array backends derive their per-level scan
structure from it instead of hard-coding the four flat phases.

Every link carries a :class:`~repro.netsim.linkmodel.LinkModel` handle (the
pluggable dynamics layer) and a fixed propagation ``latency`` charged after
each serialization (zero everywhere except WAN lanes). Static
``rail_speeds`` are sugar for degenerate constant profiles — their factor
is pre-folded into ``Link.rate`` so a constant-profile fabric is
bit-identical to the historical static one. A
:class:`~repro.netsim.linkmodel.FaultSpec` attaches time-varying profiles
(and the PFC/ECN/loss/FEC knobs the event engine implements) per rail.
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Protocol, runtime_checkable

from .linkmodel import CONSTANT, FaultSpec, LinkModel

__all__ = ["Link", "Fabric", "RailTopology", "MultiPodFabric"]


@dataclasses.dataclass(frozen=True)
class Link:
    """A unidirectional serialization resource.

    ``rate`` is the static rate in bytes/sec with any constant speed factor
    already folded in; ``model`` holds the dynamics handle (a constant
    model for frozen links — its factor is *not* applied again on top of
    ``rate``; non-constant profiles scale ``rate`` over time). ``latency``
    is a fixed propagation delay charged *after* serialization completes,
    before the chunk reaches the next hop (or the receiver) — zero on
    intra-pod links, half the configured RTT on WAN lanes.
    """

    name: str
    rate: float
    model: LinkModel = CONSTANT
    latency: float = 0.0


@runtime_checkable
class Fabric(Protocol):
    """The surface every topology exposes to the simulators and policies.

    Attributes: ``m`` (total domains), ``n`` (rails per domain), ``r1``,
    ``r2``, ``num_spines``, ``rail_speeds``, ``fault_spec``, ``links``
    (name → :class:`Link`, insertion-ordered — the array backends index
    links by this order), ``level_kinds`` (ordered link-kind tuple; every
    path visits at most one link per kind, in tuple order — the invariant
    the level-sweep scans rely on), ``num_pods``, ``domains_per_pod``,
    ``wan_lanes`` and ``inter_pod_cost_factor`` (1.0 on flat fabrics; the
    slowdown multiple of a byte that must cross pods, used to price
    migrations).
    """

    m: int
    n: int
    r1: float
    r2: float
    links: dict[str, Link]
    level_kinds: tuple[str, ...]
    num_pods: int

    @property
    def has_dynamics(self) -> bool: ...

    def pod_of(self, domain: int) -> int: ...

    def rail_path(self, src_domain: int, dst_domain: int, rail: int) -> list[str]: ...

    def spine_path(
        self, src_domain: int, dst_domain: int, src_rail: int, dst_rail: int,
        spine: int,
    ) -> list[str]: ...

    def all_paths(self, src_domain: int, dst_domain: int) -> list[list[str]]: ...

    def capacity(self, src_domain: int, dst_domain: int) -> float: ...

    def with_rail_speeds(self, rail_speeds, fault_spec=None) -> "Fabric": ...


class RailTopology:
    """Explicit link inventory + path construction for the flat rail pod."""

    #: Ordered link kinds a path may visit (one per kind, in this order).
    level_kinds: tuple[str, ...] = ("up", "l2s", "s2l", "down")
    #: Flat fabric: one pod, no WAN lanes, intra-pod migration pricing.
    num_pods: int = 1
    wan_lanes: int = 0
    inter_pod_cost_factor: float = 1.0

    def __init__(
        self,
        num_domains: int,
        num_rails: int,
        r1: float = 400e9,
        r2: float = 50e9,
        num_spines: Optional[int] = None,
        spine_rate: Optional[float] = None,
        rail_speeds=None,
        fault_spec: Optional[FaultSpec] = None,
    ):
        if num_spines is None:
            # Non-blocking spine: each leaf has M NIC-facing ports at R2, so
            # it needs M spine uplinks at R2 for full bisection.
            num_spines = num_domains
        if spine_rate is None:
            spine_rate = r2
        if not r1 > r2:
            raise ValueError("Theorem 1 premise requires R1 > R2")
        self.m = num_domains
        self.n = num_rails
        self.r1 = r1
        self.r2 = r2
        self.num_spines = num_spines
        self.spine_rate = spine_rate
        # Subclasses set num_pods (a class attr of 1 here) before chaining
        # up, so pod geometry derives uniformly.
        self.domains_per_pod = num_domains // self.num_pods
        # Per-rail speed factors: rail n's NIC links run at
        # r2 * rail_speeds[n]. Values below 1.0 model a slow leaf/optics
        # lane (the straggler-rail scenario repro.sched.feedback learns to
        # route around); values above 1.0 an over-provisioned rail.
        if rail_speeds is None:
            rail_speeds = [1.0] * self.n
        if len(rail_speeds) != self.n:
            raise ValueError(f"rail_speeds must have {self.n} entries")
        if any(not s > 0.0 for s in rail_speeds):
            raise ValueError(
                "rail_speeds must be positive (values > 1.0 mean an "
                "over-provisioned rail)"
            )
        self.rail_speeds = tuple(float(s) for s in rail_speeds)
        self.fault_spec = fault_spec
        self.links: dict[str, Link] = {}
        # Memoized path lists — policies ask for the same few thousand
        # paths once per chunk; building the strings each time dominated
        # reactive-policy assignment at large chunk counts. Callers treat
        # paths as read-only, so sharing one list per key is safe.
        self._rail_paths: dict[tuple, list[str]] = {}
        self._spine_paths: dict[tuple, list[str]] = {}
        self._build_links(spine_rate)

    def _build_links(self, spine_rate: float) -> None:
        """Populate ``self.links`` (insertion order is the array backends'
        link-id order — subclasses that degenerate to the flat pod must
        reproduce it exactly)."""
        rail_models = self._rail_models(self.fault_spec)
        for d in range(self.m):
            for n in range(self.n):
                rate, model = rail_models[n]
                self._add(f"up:{d}:{n}", rate, model)  # NIC(d,n) -> leaf S_n
                self._add(f"down:{d}:{n}", rate, model)  # leaf S_n -> NIC(d,n)
        for n in range(self.n):
            for p in range(self.num_spines):
                self._add(f"l2s:{n}:{p}", spine_rate)  # leaf S_n -> spine p
                self._add(f"s2l:{p}:{n}", spine_rate)  # spine p -> leaf S_n

    def _rail_models(self, fault_spec: Optional[FaultSpec]):
        """Per-rail (static rate, model): constant profile factors fold into
        the rate — bit-exact with the historical static fabric — while
        time-varying profiles ride on the model handle."""
        out = []
        for n in range(self.n):
            rate = self.r2 * self.rail_speeds[n]
            model = CONSTANT
            profile = fault_spec.profile_for_rail(n) if fault_spec else None
            if profile is not None:
                if profile.is_constant:
                    rate = rate * profile.factor_at(0.0)
                else:
                    model = profile
            out.append((rate, model))
        return out

    def _add(
        self, name: str, rate: float, model: LinkModel = CONSTANT,
        latency: float = 0.0,
    ) -> None:
        self.links[name] = Link(name, rate, model, latency)

    @property
    def has_dynamics(self) -> bool:
        """True when the fabric needs the event engine's dynamic loop
        (non-constant profiles or any PFC/ECN/loss knob)."""
        return self.fault_spec is not None and not self.fault_spec.is_static

    def pod_of(self, domain: int) -> int:
        """Pod index of a global domain id (always 0 on the flat fabric)."""
        return domain // self.domains_per_pod

    def with_rail_speeds(
        self, rail_speeds, fault_spec: Optional[FaultSpec] = None
    ) -> "RailTopology":
        """Same fabric geometry with different static per-rail speeds (the
        serving gateway's per-window rebuild hook). ``fault_spec`` is NOT
        inherited — window rebuilds are static by construction; pass one
        explicitly to attach dynamics."""
        return RailTopology(
            self.m, self.n, r1=self.r1, r2=self.r2,
            num_spines=self.num_spines, spine_rate=self.spine_rate,
            rail_speeds=rail_speeds, fault_spec=fault_spec,
        )

    # -- path families ------------------------------------------------------

    def rail_path(self, src_domain: int, dst_domain: int, rail: int) -> list[str]:
        """Direct rail path: single-hop through leaf S_rail (Theorem 1)."""
        key = (src_domain, dst_domain, rail)
        path = self._rail_paths.get(key)
        if path is None:
            path = [f"up:{src_domain}:{rail}", f"down:{dst_domain}:{rail}"]
            self._rail_paths[key] = path
        return path

    def spine_path(
        self,
        src_domain: int,
        dst_domain: int,
        src_rail: int,
        dst_rail: int,
        spine: int,
    ) -> list[str]:
        """Cross-rail path through the spine layer (what ECMP hashes over)."""
        if src_rail == dst_rail:
            return self.rail_path(src_domain, dst_domain, src_rail)
        key = (src_domain, dst_domain, src_rail, dst_rail, spine)
        path = self._spine_paths.get(key)
        if path is None:
            path = [
                f"up:{src_domain}:{src_rail}",
                f"l2s:{src_rail}:{spine}",
                f"s2l:{spine}:{dst_rail}",
                f"down:{dst_domain}:{dst_rail}",
            ]
            self._spine_paths[key] = path
        return path

    def all_paths(self, src_domain: int, dst_domain: int) -> list[list[str]]:
        """Every simple path (N rail-direct + N*(N-1)*num_spines spine)."""
        paths = [self.rail_path(src_domain, dst_domain, n) for n in range(self.n)]
        for sn in range(self.n):
            for dn in range(self.n):
                if sn == dn:
                    continue
                for p in range(self.num_spines):
                    paths.append(self.spine_path(src_domain, dst_domain, sn, dn, p))
        return paths

    def capacity(self, src_domain: int, dst_domain: int) -> float:
        """Theorem 1: N * R2."""
        return self.n * self.r2


class MultiPodFabric(RailTopology):
    """P rail pods joined by oversubscribed inter-pod WAN lanes.

    Each pod is a full :class:`RailTopology` (``domains_per_pod`` domains ×
    ``num_rails`` NICs, its own leaf/spine layer); pods ``p → q`` are
    joined by ``wan_lanes`` unidirectional lanes ``wan:{p}:{q}:{lane}``.
    Global domain ids are pod-major (domain ``d`` lives in pod
    ``d // domains_per_pod``); leaf/spine switch ids are globalized as
    ``pod * num_rails + rail`` / ``pod * num_spines + s`` so every name
    stays unique.

    The WAN tier is scarce by construction. A pod's full-bisection egress
    is ``domains_per_pod * num_rails * r2``; with oversubscription factor
    ``oversub`` only ``1/oversub`` of that leaves the pod, split evenly
    over ``(P-1)`` peer pods × ``wan_lanes`` lanes::

        wan_rate = domains_per_pod * num_rails * r2
                   / (oversub * (num_pods - 1) * wan_lanes)

    (overridable via ``wan_rate``). Each lane also carries a fixed
    propagation latency of ``wan_rtt / 2`` — the long-RTT half of the
    cross-DC regime; loss there is what FEC (vs go-back-N) trades against.

    Cross-pod paths are ``up → wan → down``: out on the source NIC lane,
    across one WAN lane (default ``rail % wan_lanes`` — the topology-blind
    mapping whose symmetry break the xdc bench quantifies; hierarchy-aware
    policies pass an explicit ``lane``), in on the destination NIC lane.
    ``level_kinds`` therefore grows a ``wan`` level between ``s2l`` and
    ``down`` when ``num_pods > 1``.

    ``num_pods=1`` is the degenerate flat pod: no WAN links, the flat
    four-kind level structure, and a link inventory byte-identical (names,
    rates, insertion order) to ``RailTopology`` — the BitExact parity
    anchor.
    """

    def __init__(
        self,
        num_pods: int,
        domains_per_pod: int,
        num_rails: int,
        r1: float = 400e9,
        r2: float = 50e9,
        num_spines: Optional[int] = None,
        spine_rate: Optional[float] = None,
        oversub: float = 4.0,
        wan_rtt: float = 10e-3,
        wan_lanes: Optional[int] = None,
        wan_rate: Optional[float] = None,
        rail_speeds=None,
        fault_spec: Optional[FaultSpec] = None,
    ):
        if num_pods < 1:
            raise ValueError("num_pods must be >= 1")
        if domains_per_pod < 1:
            raise ValueError("domains_per_pod must be >= 1")
        if not oversub > 0.0:
            raise ValueError("oversub must be positive")
        if not wan_rtt >= 0.0:
            raise ValueError("wan_rtt must be >= 0")
        self.num_pods = int(num_pods)
        self.oversub = float(oversub)
        self.wan_rtt = float(wan_rtt)
        self.wan_lanes = int(wan_lanes) if wan_lanes is not None else int(num_rails)
        if self.wan_lanes < 1:
            raise ValueError("wan_lanes must be >= 1")
        if num_spines is None:
            num_spines = domains_per_pod  # non-blocking *per pod*
        if self.num_pods > 1:
            pod_egress = domains_per_pod * num_rails * r2
            if wan_rate is None:
                wan_rate = pod_egress / (
                    self.oversub * (self.num_pods - 1) * self.wan_lanes
                )
            if not wan_rate > 0.0:
                raise ValueError("wan_rate must be positive")
            self.wan_rate = float(wan_rate)
            # Slowdown multiple of a byte that must cross pods vs staying
            # inside one (= `oversub` at the default wan_rate): pod
            # full-bisection egress over aggregate egress toward one peer.
            self.inter_pod_cost_factor = pod_egress / (
                self.wan_rate * (self.num_pods - 1) * self.wan_lanes
            )
            self.level_kinds = ("up", "l2s", "s2l", "wan", "down")
        else:
            self.wan_rate = 0.0
            self.inter_pod_cost_factor = 1.0
            self.level_kinds = RailTopology.level_kinds
        super().__init__(
            num_pods * domains_per_pod, num_rails, r1=r1, r2=r2,
            num_spines=num_spines, spine_rate=spine_rate,
            rail_speeds=rail_speeds, fault_spec=fault_spec,
        )

    def _build_links(self, spine_rate: float) -> None:
        if self.num_pods == 1:
            super()._build_links(spine_rate)
            return
        rail_models = self._rail_models(self.fault_spec)
        for d in range(self.m):
            for n in range(self.n):
                rate, model = rail_models[n]
                self._add(f"up:{d}:{n}", rate, model)
                self._add(f"down:{d}:{n}", rate, model)
        for pod in range(self.num_pods):
            for n in range(self.n):
                leaf = pod * self.n + n
                for s in range(self.num_spines):
                    spine = pod * self.num_spines + s
                    self._add(f"l2s:{leaf}:{spine}", spine_rate)
                    self._add(f"s2l:{spine}:{leaf}", spine_rate)
        half_rtt = self.wan_rtt / 2.0
        for p in range(self.num_pods):
            for q in range(self.num_pods):
                if p == q:
                    continue
                for lane in range(self.wan_lanes):
                    self._add(
                        f"wan:{p}:{q}:{lane}", self.wan_rate, latency=half_rtt
                    )

    def wan_link(self, src_pod: int, dst_pod: int, lane: int) -> str:
        """Name of one inter-pod WAN lane."""
        return f"wan:{src_pod}:{dst_pod}:{lane}"

    def with_rail_speeds(
        self, rail_speeds, fault_spec: Optional[FaultSpec] = None
    ) -> "MultiPodFabric":
        return MultiPodFabric(
            self.num_pods, self.domains_per_pod, self.n,
            r1=self.r1, r2=self.r2, num_spines=self.num_spines,
            spine_rate=self.spine_rate, oversub=self.oversub,
            wan_rtt=self.wan_rtt, wan_lanes=self.wan_lanes,
            wan_rate=self.wan_rate if self.num_pods > 1 else None,
            rail_speeds=rail_speeds, fault_spec=fault_spec,
        )

    # -- path families ------------------------------------------------------

    def rail_path(
        self, src_domain: int, dst_domain: int, rail: int,
        lane: Optional[int] = None,
    ) -> list[str]:
        """Same-pod: the flat rail-direct path. Cross-pod: ``up → wan →
        down`` on the same rail both sides, WAN lane ``lane`` (default
        ``rail % wan_lanes`` — the topology-blind mapping)."""
        ps = self.pod_of(src_domain)
        pd = self.pod_of(dst_domain)
        if ps == pd:
            return super().rail_path(src_domain, dst_domain, rail)
        if lane is None:
            lane = rail % self.wan_lanes
        key = (src_domain, dst_domain, rail, lane)
        path = self._rail_paths.get(key)
        if path is None:
            path = [
                f"up:{src_domain}:{rail}",
                f"wan:{ps}:{pd}:{lane}",
                f"down:{dst_domain}:{rail}",
            ]
            self._rail_paths[key] = path
        return path

    def spine_path(
        self,
        src_domain: int,
        dst_domain: int,
        src_rail: int,
        dst_rail: int,
        spine: int,
    ) -> list[str]:
        """Same-pod: the flat cross-rail path through the pod's own
        leaf/spine layer. Cross-pod: ``up → wan → down`` with the hashed
        ``spine`` recycled as WAN-lane entropy (``spine % wan_lanes``) —
        how the reactive baselines spray over lanes."""
        ps = self.pod_of(src_domain)
        pd = self.pod_of(dst_domain)
        if ps == pd:
            if self.num_pods == 1:
                return super().spine_path(
                    src_domain, dst_domain, src_rail, dst_rail, spine
                )
            if src_rail == dst_rail:
                return self.rail_path(src_domain, dst_domain, src_rail)
            key = (src_domain, dst_domain, src_rail, dst_rail, spine)
            path = self._spine_paths.get(key)
            if path is None:
                leaf_s = ps * self.n + src_rail
                leaf_d = ps * self.n + dst_rail
                sp = ps * self.num_spines + (spine % self.num_spines)
                path = [
                    f"up:{src_domain}:{src_rail}",
                    f"l2s:{leaf_s}:{sp}",
                    f"s2l:{sp}:{leaf_d}",
                    f"down:{dst_domain}:{dst_rail}",
                ]
                self._spine_paths[key] = path
            return path
        lane = spine % self.wan_lanes
        key = (src_domain, dst_domain, src_rail, dst_rail, lane)
        path = self._spine_paths.get(key)
        if path is None:
            path = [
                f"up:{src_domain}:{src_rail}",
                f"wan:{ps}:{pd}:{lane}",
                f"down:{dst_domain}:{dst_rail}",
            ]
            self._spine_paths[key] = path
        return path

    def all_paths(self, src_domain: int, dst_domain: int) -> list[list[str]]:
        if self.pod_of(src_domain) == self.pod_of(dst_domain):
            return super().all_paths(src_domain, dst_domain)
        return [
            self.rail_path(src_domain, dst_domain, n, lane=lane)
            for n in range(self.n)
            for lane in range(self.wan_lanes)
        ]

    def capacity(self, src_domain: int, dst_domain: int) -> float:
        """Same-pod: Theorem 1's ``N * R2``. Cross-pod: capped by the WAN
        lane aggregate toward the destination pod."""
        if self.pod_of(src_domain) == self.pod_of(dst_domain):
            return self.n * self.r2
        return min(self.n * self.r2, self.wan_lanes * self.wan_rate)
