"""Rail-optimized datacenter topology model (paper §III-A, Fig. 3).

M domains × N NICs. NIC ``(d, n)`` connects to leaf switch ``S_n`` at rate
``R2``; leaves connect to a spine layer (for ECMP cross-rail paths); GPUs
inside a domain interconnect at rate ``R1 > R2`` (NVLink analogue — per
Theorem 1 it never bottlenecks, so intra-domain hops are modeled as free).

A *path* is the ordered list of serialization resources (links) a chunk
occupies. Two path families exist, matching the paper's Challenge 1:

* **rail-direct**: ``NIC(src,n) → S_n → NIC(dst,n)`` — same rail index n on
  both sides (the one-to-one mapping RailS exploits).
* **spine**: ``NIC(src,n) → S_n → spine_p → S_m → NIC(dst,m)`` — crosses
  rails via the spine; this is what ECMP hashing uses.
"""

from __future__ import annotations

import dataclasses

__all__ = ["Link", "RailTopology"]


@dataclasses.dataclass(frozen=True)
class Link:
    """A unidirectional serialization resource with rate in bytes/sec."""

    name: str
    rate: float


class RailTopology:
    """Explicit link inventory + path construction for the rail fabric."""

    def __init__(
        self,
        num_domains: int,
        num_rails: int,
        r1: float = 400e9,
        r2: float = 50e9,
        num_spines: int = None,  # type: ignore[assignment]
        spine_rate: float = None,  # type: ignore[assignment]
        rail_speeds=None,
    ):
        if num_spines is None:
            # Non-blocking spine: each leaf has M NIC-facing ports at R2, so
            # it needs M spine uplinks at R2 for full bisection.
            num_spines = num_domains
        if spine_rate is None:
            spine_rate = r2
        if not r1 > r2:
            raise ValueError("Theorem 1 premise requires R1 > R2")
        self.m = num_domains
        self.n = num_rails
        self.r1 = r1
        self.r2 = r2
        self.num_spines = num_spines
        # Per-rail degradation factors in (0, 1]: rail n's NIC links run at
        # r2 * rail_speeds[n] (a slow leaf/optics lane — the straggler-rail
        # scenario repro.sched.feedback learns to route around).
        if rail_speeds is None:
            rail_speeds = [1.0] * self.n
        if len(rail_speeds) != self.n:
            raise ValueError(f"rail_speeds must have {self.n} entries")
        if any(not 0.0 < s <= 1.0 for s in rail_speeds):
            raise ValueError("rail_speeds must lie in (0, 1]")
        self.rail_speeds = tuple(float(s) for s in rail_speeds)
        self.links: dict[str, Link] = {}
        # Memoized path lists — policies ask for the same few thousand
        # paths once per chunk; building the strings each time dominated
        # reactive-policy assignment at large chunk counts. Callers treat
        # paths as read-only, so sharing one list per key is safe.
        self._rail_paths: dict[tuple, list[str]] = {}
        self._spine_paths: dict[tuple, list[str]] = {}
        for d in range(self.m):
            for n in range(self.n):
                self._add(f"up:{d}:{n}", r2 * self.rail_speeds[n])  # NIC(d,n) -> leaf S_n
                self._add(f"down:{d}:{n}", r2 * self.rail_speeds[n])  # leaf S_n -> NIC(d,n)
        for n in range(self.n):
            for p in range(num_spines):
                self._add(f"l2s:{n}:{p}", spine_rate)  # leaf S_n -> spine p
                self._add(f"s2l:{p}:{n}", spine_rate)  # spine p -> leaf S_n

    def _add(self, name: str, rate: float) -> None:
        self.links[name] = Link(name, rate)

    # -- path families ------------------------------------------------------

    def rail_path(self, src_domain: int, dst_domain: int, rail: int) -> list[str]:
        """Direct rail path: single-hop through leaf S_rail (Theorem 1)."""
        key = (src_domain, dst_domain, rail)
        path = self._rail_paths.get(key)
        if path is None:
            path = [f"up:{src_domain}:{rail}", f"down:{dst_domain}:{rail}"]
            self._rail_paths[key] = path
        return path

    def spine_path(
        self,
        src_domain: int,
        dst_domain: int,
        src_rail: int,
        dst_rail: int,
        spine: int,
    ) -> list[str]:
        """Cross-rail path through the spine layer (what ECMP hashes over)."""
        if src_rail == dst_rail:
            return self.rail_path(src_domain, dst_domain, src_rail)
        key = (src_domain, dst_domain, src_rail, dst_rail, spine)
        path = self._spine_paths.get(key)
        if path is None:
            path = [
                f"up:{src_domain}:{src_rail}",
                f"l2s:{src_rail}:{spine}",
                f"s2l:{spine}:{dst_rail}",
                f"down:{dst_domain}:{dst_rail}",
            ]
            self._spine_paths[key] = path
        return path

    def all_paths(self, src_domain: int, dst_domain: int) -> list[list[str]]:
        """Every simple path (N rail-direct + N*(N-1)*num_spines spine)."""
        paths = [self.rail_path(src_domain, dst_domain, n) for n in range(self.n)]
        for sn in range(self.n):
            for dn in range(self.n):
                if sn == dn:
                    continue
                for p in range(self.num_spines):
                    paths.append(self.spine_path(src_domain, dst_domain, sn, dn, p))
        return paths

    def capacity(self, src_domain: int, dst_domain: int) -> float:
        """Theorem 1: N * R2."""
        return self.n * self.r2
