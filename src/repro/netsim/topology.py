"""Rail-optimized datacenter topology model (paper §III-A, Fig. 3).

M domains × N NICs. NIC ``(d, n)`` connects to leaf switch ``S_n`` at rate
``R2``; leaves connect to a spine layer (for ECMP cross-rail paths); GPUs
inside a domain interconnect at rate ``R1 > R2`` (NVLink analogue — per
Theorem 1 it never bottlenecks, so intra-domain hops are modeled as free).

A *path* is the ordered list of serialization resources (links) a chunk
occupies. Two path families exist, matching the paper's Challenge 1:

* **rail-direct**: ``NIC(src,n) → S_n → NIC(dst,n)`` — same rail index n on
  both sides (the one-to-one mapping RailS exploits).
* **spine**: ``NIC(src,n) → S_n → spine_p → S_m → NIC(dst,m)`` — crosses
  rails via the spine; this is what ECMP hashing uses.

Every link carries a :class:`~repro.netsim.linkmodel.LinkModel` handle (the
pluggable dynamics layer). Static ``rail_speeds`` are sugar for degenerate
constant profiles — their factor is pre-folded into ``Link.rate`` so a
constant-profile fabric is bit-identical to the historical static one. A
:class:`~repro.netsim.linkmodel.FaultSpec` attaches time-varying profiles
(and the PFC/ECN/loss knobs the event engine implements) per rail.
"""

from __future__ import annotations

import dataclasses
from typing import Optional

from .linkmodel import CONSTANT, FaultSpec, LinkModel

__all__ = ["Link", "RailTopology"]


@dataclasses.dataclass(frozen=True)
class Link:
    """A unidirectional serialization resource.

    ``rate`` is the static rate in bytes/sec with any constant speed factor
    already folded in; ``model`` holds the dynamics handle (a constant
    model for frozen links — its factor is *not* applied again on top of
    ``rate``; non-constant profiles scale ``rate`` over time).
    """

    name: str
    rate: float
    model: LinkModel = CONSTANT


class RailTopology:
    """Explicit link inventory + path construction for the rail fabric."""

    def __init__(
        self,
        num_domains: int,
        num_rails: int,
        r1: float = 400e9,
        r2: float = 50e9,
        num_spines: Optional[int] = None,
        spine_rate: Optional[float] = None,
        rail_speeds=None,
        fault_spec: Optional[FaultSpec] = None,
    ):
        if num_spines is None:
            # Non-blocking spine: each leaf has M NIC-facing ports at R2, so
            # it needs M spine uplinks at R2 for full bisection.
            num_spines = num_domains
        if spine_rate is None:
            spine_rate = r2
        if not r1 > r2:
            raise ValueError("Theorem 1 premise requires R1 > R2")
        self.m = num_domains
        self.n = num_rails
        self.r1 = r1
        self.r2 = r2
        self.num_spines = num_spines
        # Per-rail speed factors: rail n's NIC links run at
        # r2 * rail_speeds[n]. Values below 1.0 model a slow leaf/optics
        # lane (the straggler-rail scenario repro.sched.feedback learns to
        # route around); values above 1.0 an over-provisioned rail.
        if rail_speeds is None:
            rail_speeds = [1.0] * self.n
        if len(rail_speeds) != self.n:
            raise ValueError(f"rail_speeds must have {self.n} entries")
        if any(not s > 0.0 for s in rail_speeds):
            raise ValueError(
                "rail_speeds must be positive (values > 1.0 mean an "
                "over-provisioned rail)"
            )
        self.rail_speeds = tuple(float(s) for s in rail_speeds)
        self.fault_spec = fault_spec
        self.links: dict[str, Link] = {}
        # Memoized path lists — policies ask for the same few thousand
        # paths once per chunk; building the strings each time dominated
        # reactive-policy assignment at large chunk counts. Callers treat
        # paths as read-only, so sharing one list per key is safe.
        self._rail_paths: dict[tuple, list[str]] = {}
        self._spine_paths: dict[tuple, list[str]] = {}
        rail_models = self._rail_models(fault_spec)
        for d in range(self.m):
            for n in range(self.n):
                rate, model = rail_models[n]
                self._add(f"up:{d}:{n}", rate, model)  # NIC(d,n) -> leaf S_n
                self._add(f"down:{d}:{n}", rate, model)  # leaf S_n -> NIC(d,n)
        for n in range(self.n):
            for p in range(num_spines):
                self._add(f"l2s:{n}:{p}", spine_rate)  # leaf S_n -> spine p
                self._add(f"s2l:{p}:{n}", spine_rate)  # spine p -> leaf S_n

    def _rail_models(self, fault_spec: Optional[FaultSpec]):
        """Per-rail (static rate, model): constant profile factors fold into
        the rate — bit-exact with the historical static fabric — while
        time-varying profiles ride on the model handle."""
        out = []
        for n in range(self.n):
            rate = self.r2 * self.rail_speeds[n]
            model = CONSTANT
            profile = fault_spec.profile_for_rail(n) if fault_spec else None
            if profile is not None:
                if profile.is_constant:
                    rate = rate * profile.factor_at(0.0)
                else:
                    model = profile
            out.append((rate, model))
        return out

    def _add(self, name: str, rate: float, model: LinkModel = CONSTANT) -> None:
        self.links[name] = Link(name, rate, model)

    @property
    def has_dynamics(self) -> bool:
        """True when the fabric needs the event engine's dynamic loop
        (non-constant profiles or any PFC/ECN/loss knob)."""
        return self.fault_spec is not None and not self.fault_spec.is_static

    # -- path families ------------------------------------------------------

    def rail_path(self, src_domain: int, dst_domain: int, rail: int) -> list[str]:
        """Direct rail path: single-hop through leaf S_rail (Theorem 1)."""
        key = (src_domain, dst_domain, rail)
        path = self._rail_paths.get(key)
        if path is None:
            path = [f"up:{src_domain}:{rail}", f"down:{dst_domain}:{rail}"]
            self._rail_paths[key] = path
        return path

    def spine_path(
        self,
        src_domain: int,
        dst_domain: int,
        src_rail: int,
        dst_rail: int,
        spine: int,
    ) -> list[str]:
        """Cross-rail path through the spine layer (what ECMP hashes over)."""
        if src_rail == dst_rail:
            return self.rail_path(src_domain, dst_domain, src_rail)
        key = (src_domain, dst_domain, src_rail, dst_rail, spine)
        path = self._spine_paths.get(key)
        if path is None:
            path = [
                f"up:{src_domain}:{src_rail}",
                f"l2s:{src_rail}:{spine}",
                f"s2l:{spine}:{dst_rail}",
                f"down:{dst_domain}:{dst_rail}",
            ]
            self._spine_paths[key] = path
        return path

    def all_paths(self, src_domain: int, dst_domain: int) -> list[list[str]]:
        """Every simple path (N rail-direct + N*(N-1)*num_spines spine)."""
        paths = [self.rail_path(src_domain, dst_domain, n) for n in range(self.n)]
        for sn in range(self.n):
            for dn in range(self.n):
                if sn == dn:
                    continue
                for p in range(self.num_spines):
                    paths.append(self.spine_path(src_domain, dst_domain, sn, dn, p))
        return paths

    def capacity(self, src_domain: int, dst_domain: int) -> float:
        """Theorem 1: N * R2."""
        return self.n * self.r2
