"""Device-resident exact-FIFO simulation backend (the ``device`` backend).

The vector backend (:mod:`repro.netsim.fastsim`) already removed the
per-event Python dispatch, but each simulation is still a host-side numpy
pipeline: policy-suite grids, serving SLO sweeps and placement candidate
scoring all call it once per cell, serially. This module ports the same
FIFO busy-period dynamics to jax so one jitted (and ``vmap``-batched)
device call evaluates a whole grid of padded simulations at once.

**Same recurrence, scan formulation.** Per link, completions satisfy
``c_i = max(a_i, c_{i-1}) + t_i``. With ``b_i = a_i + t_i`` this is the
max-plus recurrence ``c_i = max(b_i, c_{i-1} + t_i)``, whose segmented
associative form scans ``(flag, t, b)`` triples::

    combine((fx,tx,bx), (fy,ty,by)) =
        (fx|fy, where(fy, ty, tx+ty), where(fy, by, max(bx+ty, by)))

where ``flag`` marks busy-queue (= link-run) heads after one multi-key
``lax.sort`` by ``(link, clamped arrival, original arrival, start-time
tie, rank tie)``. Levels sweep topologically in the fabric's
``level_kinds`` order (``up -> l2s -> s2l -> down`` flat, with a ``wan``
level on multi-pod fabrics) exactly like the vector backend; per-link
``link_busy`` carry is an arrival clamp whose sort keys preserve the
pre-clamp order, mirroring ``fastsim._busy_clamped``.

**Two scan kernels.** The inner segmented scan has a Pallas kernel —
grid over blocks of per-link job lanes, a sequential ``fori_loop`` over
the padded lane depth doing one max/add per position across the block's
links — and a pure ``lax.associative_scan`` fallback over the flat
sorted arrays. The Pallas path is selected at import when the backend
can actually lower it (TPU-style targets); CPU jax compiles the ``lax``
fallback. ``impl="pallas_interpret"`` forces the kernel through the
Pallas interpreter so its numerics are testable anywhere.

**Tolerance contract, not bit parity.** The associative scan
re-associates the additions inside a busy period, and simultaneous-finish
tie keys carry ``(service start, previous-level service order)`` instead
of the engine's full opener chain, so results match ``backend="vector"``
to float tolerance (~1e-9 relative on randomized workloads; identical-
size chunk waves can reorder degenerate CCT ties, same class of drift as
the vector backend's spine-path tolerance) rather than bit for bit.
Makespans agree tightly — equal-arrival ties cannot change a link's last
completion.

**Fixed shapes.** :func:`pad_job_arrays` pads planned per-chunk columns
to power-of-two buckets (sentinel link ids, zero sizes) so jit traces
are reused across calls; :func:`simulate_many_device` stacks a list of
planned simulations to one bucket and runs them through a single
``vmap``-ed device call. Everything is f64 under the
``jax.experimental.enable_x64`` context — precision matches the numpy
backend without flipping the process-global x64 flag.
"""

from __future__ import annotations

import dataclasses
import functools

import numpy as np

import jax
import jax.numpy as jnp
from jax.experimental import enable_x64

from .fastsim import (
    ArraySimResult,
    LinkIndex,
    _segment_max,
    _segment_min_like,
)

__all__ = [
    "PlannedJobs",
    "check_device_supports",
    "pad_job_arrays",
    "pallas_available",
    "scan_impl",
    "simulate_chunk_arrays_device",
    "simulate_many_device",
]

#: Smallest padding bucket — tiny collectives share one trace instead of
#: compiling per chunk count.
MIN_BUCKET = 256

#: Links per Pallas grid block (second-to-minor tile of the lane layout).
_LANE_BLOCK = 8

#: Minimum Pallas lane depth (minor dimension — keep it register-tile wide).
_MIN_LANE = 128


# --------------------------------------------------------------------------
# Kernel selection


@functools.cache
def pallas_available() -> bool:
    """Whether this jax backend can actually lower a Pallas kernel.

    Probes by compiling a trivial ``pallas_call``; CPU jax (the CI / dev
    environment) fails the probe and falls back to ``lax.associative_scan``.
    Cached — the probe compiles, so it must run at most once.
    """
    try:
        from jax.experimental import pallas as pl

        def k(x_ref, o_ref):
            o_ref[...] = x_ref[...] * 2.0

        fn = pl.pallas_call(
            k, out_shape=jax.ShapeDtypeStruct((8, 128), jnp.float32)
        )
        jax.jit(fn).lower(jnp.zeros((8, 128), jnp.float32)).compile()
        return True
    except Exception:
        return False


def scan_impl() -> str:
    """The default segmented-scan implementation for this process."""
    return "pallas" if pallas_available() else "lax"


_IMPLS = ("lax", "pallas", "pallas_interpret")


# --------------------------------------------------------------------------
# Segmented max-plus scan — the two implementations


def _maxplus_combine(x, y):
    fx, tx, bx = x
    fy, ty, by = y
    return (
        fx | fy,
        jnp.where(fy, ty, tx + ty),
        jnp.where(fy, by, jnp.maximum(bx + ty, by)),
    )


def _segmented_maxplus_lax(head, service, b):
    """Flat segmented scan: c_i = max(b_i, c_{i-1} + t_i), reset at heads."""
    _, _, c = jax.lax.associative_scan(_maxplus_combine, (head, service, b))
    return c


def _lane_scan_kernel(t_ref, b_ref, out_ref):
    """One block of link lanes: sequential max-plus over lane positions.

    ``t_ref``/``b_ref`` are ``(block_links, lane_depth)``; position ``j``
    advances every link's carry with one vectorized max/add pair. Padded
    lane tails hold ``t=0, b=-inf`` so the carry passes through them.
    """
    from jax.experimental import pallas as pl

    bl, depth = t_ref.shape

    def body(j, c):
        t = pl.load(t_ref, (slice(None), pl.dslice(j, 1)))[:, 0]
        b = pl.load(b_ref, (slice(None), pl.dslice(j, 1)))[:, 0]
        c = jnp.maximum(b, c + t)
        pl.store(out_ref, (slice(None), pl.dslice(j, 1)), c[:, None])
        return c

    jax.lax.fori_loop(
        0, depth, body, jnp.full((bl,), -jnp.inf, dtype=t_ref.dtype)
    )


def _segmented_maxplus_pallas(head, service, b, num_segments, lane_depth, interpret):
    """Dense-lane Pallas path: scatter sorted jobs into (link, position)
    lanes, scan each lane in the kernel, gather completions back.

    ``lane_depth`` (static) must bound the deepest per-link queue — the
    host computes it from the planned assignment and buckets it to a
    power of two so recompiles stay bounded.
    """
    from jax.experimental import pallas as pl

    f = service.shape[0]
    iota = jnp.arange(f, dtype=jnp.int32)
    seg = jnp.cumsum(head.astype(jnp.int32)) - 1
    seg_start = jax.ops.segment_max(
        jnp.where(head, iota, -1), seg, num_segments=num_segments,
        indices_are_sorted=True,
    )
    pos = iota - seg_start[seg]
    padded_segs = -(-num_segments // _LANE_BLOCK) * _LANE_BLOCK
    lane_t = (
        jnp.zeros((padded_segs, lane_depth), service.dtype)
        .at[seg, pos].set(service, mode="drop")
    )
    lane_b = (
        jnp.full((padded_segs, lane_depth), -jnp.inf, b.dtype)
        .at[seg, pos].set(b, mode="drop")
    )
    out = pl.pallas_call(
        _lane_scan_kernel,
        grid=(padded_segs // _LANE_BLOCK,),
        in_specs=[
            pl.BlockSpec((_LANE_BLOCK, lane_depth), lambda i: (i, 0)),
            pl.BlockSpec((_LANE_BLOCK, lane_depth), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((_LANE_BLOCK, lane_depth), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((padded_segs, lane_depth), service.dtype),
        interpret=interpret,
    )(lane_t, lane_b)
    return out[seg, pos]


# --------------------------------------------------------------------------
# Level scan + topological sweep (traced core)


def _level_scan(el, clamped, arrival, tie1, tie2, service, num_links,
                impl, lane_depth):
    """Exact FIFO scan of one topological level, all links at once.

    Sort keys ``(link, clamped arrival, original arrival, start tie, rank
    tie)`` reproduce the vector backend's service order: the two trailing
    keys only matter on exact float ties, and the original arrival keeps
    the pre-clamp order whenever a ``link_busy`` carry collapses arrivals
    onto one busy-until instant. Returns chunk-order ``(completion,
    start, service rank, per-link last completion)``.
    """
    f = el.shape[0]
    iota = jnp.arange(f, dtype=jnp.int32)
    l_s, a_s, _ao, _t1, _t2, perm = jax.lax.sort(
        (el, clamped, arrival, tie1, tie2, iota), num_keys=5
    )
    service_s = service[perm]
    head = jnp.concatenate(
        [jnp.ones((1,), bool), l_s[1:] != l_s[:-1]]
    )
    c_s = _segmented_maxplus_lax(head, service_s, a_s + service_s) \
        if impl == "lax" else _segmented_maxplus_pallas(
            head, service_s, a_s + service_s, num_links + 1, lane_depth,
            interpret=(impl == "pallas_interpret"),
        )
    # Re-derive the final step from the scan carry: start = max(a, c_prev)
    # exactly (the scan's re-associated sum would otherwise leak into the
    # reported starts and their use as tie keys).
    c_prev = jnp.where(
        head, -jnp.inf,
        jnp.concatenate([jnp.full((1,), -jnp.inf, c_s.dtype), c_s[:-1]]),
    )
    start_s = jnp.maximum(a_s, c_prev)
    c_s = start_s + service_s
    seg_last = jax.ops.segment_max(
        c_s, l_s.astype(jnp.int32), num_segments=num_links,
        indices_are_sorted=True,
    )
    comp = jnp.zeros(f, c_s.dtype).at[perm].set(c_s)
    start = jnp.zeros(f, c_s.dtype).at[perm].set(start_s)
    rank = jnp.zeros(f, jnp.int32).at[perm].set(iota)
    return comp, start, rank, seg_last


def _scan_core(link_by_level, size, release, entry_rank, rate, latency,
               link_busy, valid, hop_latency, *, impl, lane_depth):
    """The full level sweep over one padded simulation (traced).

    ``link_by_level`` is ``(F, num_levels)`` int32 — the level count is a
    static trace dimension taken from the fabric's ``level_kinds`` (4 flat,
    5 multi-pod); −1 = level not on the path (padded chunks are −1
    everywhere); ``valid`` masks real chunks. Sentinel rows sort to the
    tail as their own zero-service segment and are dropped from every
    per-link reduction by the out-of-range scatter rule. ``latency`` is
    the per-link fixed propagation delay charged after each service (zero
    except WAN lanes). Returns ``(finish, start0, link_volume, link_last,
    makespan)``.
    """
    f = size.shape[0]
    num_links = rate.shape[0]
    rate_ext = jnp.concatenate([rate, jnp.ones((1,), rate.dtype)])
    lat_ext = jnp.concatenate([latency, jnp.zeros((1,), latency.dtype)])
    busy_ext = jnp.concatenate([link_busy, jnp.zeros((1,), link_busy.dtype)])
    arrival = release + 0.0
    tie1 = jnp.zeros(f, release.dtype)
    tie2 = entry_rank.astype(jnp.int32)
    finish = jnp.zeros(f, release.dtype)
    start0 = jnp.zeros(f, release.dtype)
    link_last = link_busy
    link_volume = jnp.zeros(num_links, size.dtype)
    for lv in range(link_by_level.shape[1]):
        links = link_by_level[:, lv]
        served = links >= 0
        el = jnp.where(served, links, num_links).astype(jnp.int32)
        service = jnp.where(served, size / rate_ext[el], 0.0)
        # Clamp against the *carried* busy-until (not the running
        # link_last) — the vector backend clamps each level against the
        # input carry too; within-window backlog is already in the scan.
        clamped = jnp.maximum(arrival, busy_ext[el])
        comp, start, rank, seg_last = _level_scan(
            el, clamped, arrival, tie1, tie2, service, num_links,
            impl, lane_depth,
        )
        if lv == 0:
            start0 = jnp.where(served, start, 0.0)
        finish = jnp.where(served, comp, finish)
        arrival = jnp.where(served, comp + hop_latency + lat_ext[el], arrival)
        tie1 = jnp.where(served, start, tie1)
        tie2 = jnp.where(served, rank, tie2)
        link_volume = link_volume + jax.ops.segment_sum(
            jnp.where(served, size, 0.0), el, num_segments=num_links
        )
        link_last = jnp.maximum(link_last, seg_last)
    makespan = jnp.max(jnp.where(valid, finish, -jnp.inf))
    return finish, start0, link_volume, link_last, makespan


@functools.partial(jax.jit, static_argnames=("impl", "lane_depth"))
def _scan_single_jit(link_by_level, size, release, entry_rank, rate, latency,
                     link_busy, valid, hop_latency, *, impl, lane_depth):
    return _scan_core(
        link_by_level, size, release, entry_rank, rate, latency, link_busy,
        valid, hop_latency, impl=impl, lane_depth=lane_depth,
    )


@functools.partial(jax.jit, static_argnames=("impl", "lane_depth"))
def _scan_batch_jit(link_by_level, size, release, entry_rank, rate, latency,
                    link_busy, valid, hop_latency, *, impl, lane_depth):
    core = functools.partial(_scan_core, impl=impl, lane_depth=lane_depth)
    return jax.vmap(core, in_axes=(0, 0, 0, 0, 0, None, 0, 0, None))(
        link_by_level, size, release, entry_rank, rate, latency, link_busy,
        valid, hop_latency,
    )


# --------------------------------------------------------------------------
# Host-side padding, planning containers, result assembly


@dataclasses.dataclass
class PlannedJobs:
    """One planned simulation in column form (policy already applied).

    The device batch API takes a list of these — the planning phase stays
    host-side (policies are Python), only the fabric dynamics batch.
    """

    link_by_level: np.ndarray  # (F, num_levels) int, -1 = level skipped
    size: np.ndarray  # (F,) float64
    release: np.ndarray  # (F,) float64
    entry_rank: np.ndarray  # (F,) int
    flow_id: np.ndarray | None = None
    round_id: np.ndarray | None = None

    @property
    def num_chunks(self) -> int:
        return int(self.size.size)


def bucket_size(num_chunks: int) -> int:
    """Power-of-two padding bucket (>= MIN_BUCKET) for one chunk count.

    Jit traces key on padded shape, so the number of distinct compilations
    is log2-bounded in the largest collective ever simulated.
    """
    if num_chunks <= MIN_BUCKET:
        return MIN_BUCKET
    return 1 << (num_chunks - 1).bit_length()


def pad_job_arrays(planned: PlannedJobs, bucket: int | None = None):
    """Pad one planned simulation's columns to a fixed bucketed length.

    Padding appends chunks *after* the valid prefix — chunk order within
    ``[0, F)`` is untouched, so flow/round ids stay contiguous runs and
    the host-side segment reductions run on a plain slice. Padded chunks
    carry sentinel link ids (−1 at every level), zero size and past-end
    entry ranks; inside the scan they sort to the tail as zero-service
    segments and contribute to nothing.

    Returns ``(link_by_level, size, release, entry_rank, valid)`` numpy
    arrays of length ``bucket`` (default: :func:`bucket_size`).
    """
    f = planned.num_chunks
    if bucket is None:
        bucket = bucket_size(f)
    if bucket < f:
        raise ValueError(f"bucket {bucket} smaller than job count {f}")
    lbl = np.full(
        (bucket, planned.link_by_level.shape[1]), -1, dtype=np.int32
    )
    lbl[:f] = planned.link_by_level
    size = np.zeros(bucket)
    size[:f] = planned.size
    release = np.zeros(bucket)
    release[:f] = planned.release
    rank = np.arange(bucket, dtype=np.int64)
    rank[:f] = planned.entry_rank
    valid = np.zeros(bucket, dtype=bool)
    valid[:f] = True
    return lbl, size, release, rank, valid


def check_device_supports(topo) -> None:
    """Reject fabrics the device backend cannot express.

    Time-varying link dynamics (rate profiles, PFC/ECN/loss) have no
    fixed-shape scan form; static specs can fall back to the numpy
    ``backend='vector'`` path, dynamic fault_specs need the event engine.
    """
    if topo.has_dynamics:
        raise NotImplementedError(
            "backend='device' supports constant-profile link models only; "
            "use backend='vector' for static specs on the host or "
            "backend='event' for dynamic fault_specs"
        )


def _resolve_impl(impl: str | None) -> str:
    if impl is None:
        return scan_impl()
    if impl not in _IMPLS:
        raise ValueError(f"unknown scan impl {impl!r}; choose {_IMPLS}")
    return impl


def _lane_depth_for(link_by_level_list, num_links: int) -> int:
    """Static Pallas lane depth: deepest per-(level, link) queue, padded.

    Only consulted on the Pallas paths; the ``lax`` fallback scans the
    flat sorted arrays and ignores it (pass 0 so the jit cache key stays
    constant there).
    """
    deepest = 1
    for lbl in link_by_level_list:
        for lv in range(lbl.shape[1]):
            col = lbl[:, lv]
            col = col[col >= 0]
            if col.size:
                deepest = max(deepest, int(np.bincount(col).max()))
    return max(_MIN_LANE, 1 << (deepest - 1).bit_length())


def _result_from_rows(index, finish, start0, link_volume, link_last,
                      makespan, planned, had_busy):
    """Assemble an :class:`ArraySimResult` from one device row (host side)."""
    f = planned.num_chunks
    finish = finish[:f]
    release = np.asarray(planned.release, dtype=np.float64)
    flow_id = (
        planned.flow_id if planned.flow_id is not None
        else np.arange(f, dtype=np.int64)
    )
    round_id = (
        planned.round_id if planned.round_id is not None
        else np.zeros(f, dtype=np.int64)
    )
    flow_ids, flow_finish = _segment_max(finish, np.asarray(flow_id))
    round_ids, round_finish = _segment_max(finish, np.asarray(round_id))
    return ArraySimResult(
        finish=finish,
        start=start0[:f],
        link_bytes={
            nm: float(v) for nm, v in zip(index.names, link_volume)
        },
        makespan=float(makespan) if f else 0.0,
        flow_ids=flow_ids,
        flow_finish=flow_finish,
        round_ids=round_ids,
        round_finish=round_finish,
        flow_release=_segment_min_like(release, np.asarray(flow_id)),
        round_release=_segment_min_like(release, np.asarray(round_id)),
        link_last=link_last if had_busy else None,
    )


def _check_level0(link_by_level, f) -> None:
    if f and np.any(np.asarray(link_by_level)[:f, 0] < 0):
        raise ValueError("every path must start with an up-link (level 0)")


def simulate_chunk_arrays_device(
    index: LinkIndex,
    link_by_level: np.ndarray,
    size: np.ndarray,
    release: np.ndarray,
    entry_rank: np.ndarray,
    hop_latency: float = 1e-6,
    flow_id: np.ndarray | None = None,
    round_id: np.ndarray | None = None,
    link_busy: np.ndarray | None = None,
    bucket: int | None = None,
    impl: str | None = None,
) -> ArraySimResult:
    """Drop-in device counterpart of ``fastsim.simulate_chunk_arrays``.

    Same signature and result type; the scan runs as one jitted device
    call on padded fixed-shape arrays. ``impl`` forces a scan kernel
    (``lax``, ``pallas``, ``pallas_interpret``) — default auto-selects
    via :func:`pallas_available`. Parity with the vector backend is float
    tolerance, not bit-exact (see the module docstring).
    """
    check_device_supports(index.topo)
    impl = _resolve_impl(impl)
    f = size.size
    num_links = index.num_links
    planned = PlannedJobs(
        link_by_level=np.asarray(link_by_level),
        size=np.asarray(size, dtype=np.float64),
        release=np.asarray(release, dtype=np.float64),
        entry_rank=np.asarray(entry_rank, dtype=np.int64),
        flow_id=flow_id,
        round_id=round_id,
    )
    _check_level0(planned.link_by_level, f)
    if link_busy is not None:
        busy = np.asarray(link_busy, dtype=np.float64)
        if busy.shape != (num_links,):
            raise ValueError(
                f"link_busy must be ({num_links},), got {busy.shape}"
            )
    else:
        busy = np.zeros(num_links)
    lbl, psize, prelease, prank, valid = pad_job_arrays(planned, bucket)
    lane_depth = (
        _lane_depth_for([planned.link_by_level], num_links)
        if impl != "lax" else 0
    )
    with enable_x64():
        finish, start0, link_volume, link_last, makespan = _scan_single_jit(
            jnp.asarray(lbl), jnp.asarray(psize), jnp.asarray(prelease),
            jnp.asarray(prank), jnp.asarray(index.rate),
            jnp.asarray(index.latency), jnp.asarray(busy),
            jnp.asarray(valid),
            jnp.asarray(hop_latency, dtype=jnp.float64),
            impl=impl, lane_depth=lane_depth,
        )
    return _result_from_rows(
        index,
        np.asarray(finish), np.asarray(start0), np.asarray(link_volume),
        np.asarray(link_last), np.asarray(makespan), planned,
        had_busy=link_busy is not None,
    )


def simulate_many_device(
    index: LinkIndex,
    planned: list[PlannedJobs],
    hop_latency: float = 1e-6,
    link_busy: np.ndarray | None = None,
    bucket: int | None = None,
    impl: str | None = None,
) -> list[ArraySimResult]:
    """Batched sweep execution: many planned simulations, one device call.

    All members pad to one shared bucket (sized for the largest) and run
    through the ``vmap``-ed scan — the policy-suite grid, placement
    candidate scoring and SLO sweeps become a single dispatch instead of
    a Python loop over simulations. ``link_busy`` (optional) is a
    ``(B, num_links)`` per-member carry.
    """
    check_device_supports(index.topo)
    impl = _resolve_impl(impl)
    if not planned:
        return []
    num_links = index.num_links
    b = len(planned)
    if bucket is None:
        bucket = bucket_size(max(p.num_chunks for p in planned))
    for p in planned:
        _check_level0(p.link_by_level, p.num_chunks)
    cols = [pad_job_arrays(p, bucket) for p in planned]
    lbl = np.stack([c[0] for c in cols])
    size = np.stack([c[1] for c in cols])
    release = np.stack([c[2] for c in cols])
    rank = np.stack([c[3] for c in cols])
    valid = np.stack([c[4] for c in cols])
    rate = np.broadcast_to(index.rate, (b, num_links))
    if link_busy is not None:
        busy = np.asarray(link_busy, dtype=np.float64)
        if busy.shape != (b, num_links):
            raise ValueError(
                f"link_busy must be ({b}, {num_links}), got {busy.shape}"
            )
    else:
        busy = np.zeros((b, num_links))
    lane_depth = (
        _lane_depth_for([p.link_by_level for p in planned], num_links)
        if impl != "lax" else 0
    )
    with enable_x64():
        finish, start0, link_volume, link_last, makespan = _scan_batch_jit(
            jnp.asarray(lbl), jnp.asarray(size), jnp.asarray(release),
            jnp.asarray(rank), jnp.asarray(rate),
            jnp.asarray(index.latency), jnp.asarray(busy),
            jnp.asarray(valid),
            jnp.asarray(hop_latency, dtype=jnp.float64),
            impl=impl, lane_depth=lane_depth,
        )
    finish = np.asarray(finish)
    start0 = np.asarray(start0)
    link_volume = np.asarray(link_volume)
    link_last = np.asarray(link_last)
    makespan = np.asarray(makespan)
    return [
        _result_from_rows(
            index, finish[i], start0[i], link_volume[i], link_last[i],
            makespan[i], p, had_busy=link_busy is not None,
        )
        for i, p in enumerate(planned)
    ]
