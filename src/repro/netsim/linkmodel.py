"""Pluggable link-dynamics layer: the fabric stops being a frozen pipe.

The paper's testbed results (§VI-E) hinge on fabric *dynamics*: reactive
baselines herd because their congestion signals are stale and lossy, while
RailS's proactive spraying stays balanced. A static ``Link(name, rate)``
cannot express any of that, so every link now carries a :class:`LinkModel`
handle and the whole stack (topology → engine → policies → feedback)
consults it. Four mechanisms, each independently switchable through a
:class:`FaultSpec`:

* **Time-varying rates** — :class:`PiecewiseRate`: a piecewise-constant
  rate-factor profile (step degradation via :func:`step_profile`, periodic
  flapping optics via :func:`flapping_profile`). The static ``rail_speeds``
  scalar is absorbed as the degenerate case: a :class:`ConstantRate` whose
  factor is pre-folded into ``Link.rate`` — so a constant-profile fabric is
  *bit-exact* with the pre-dynamics simulator on both backends.
* **PFC pause frames** (:class:`PfcConfig`) — a link whose ingress backlog
  crosses ``pause_bytes`` asserts pause; upstream links whose head-of-queue
  chunk targets it stall entirely (head-of-line blocking) until the backlog
  drains below ``resume_bytes``.
* **ECN marking** (:class:`EcnConfig`) — chunks entering a queue above
  ``mark_bytes`` are marked; on delivery of a marked chunk the *sender*
  applies a multiplicative rate cut (DCTCP-style), recovering additively on
  unmarked deliveries. Marked/paused links also feed the reactive policies'
  path estimates — the stale herding signal of §VI-E.
* **Chunk loss + go-back-N** (:class:`LossConfig`) — i.i.d. or bursty
  (Gilbert–Elliott) loss per link service; a lost chunk is retransmitted
  from the source after ``rto`` seconds, and a receiver holding an earlier
  outstanding loss discards later chunks of the same flow (go-back-N
  in-order delivery), triggering their retransmission too.
* **XOR-FEC** (:class:`FecConfig`) — forward error correction layered on
  top of the loss model: every ``k`` data chunks on a transport lane are
  followed by ``r`` XOR parity chunks, and the receiver reconstructs up to
  ``r`` lost chunks per group without a retransmission round trip. Past
  the redundancy budget the group falls back to go-back-N. The tradeoff
  the cross-DC study measures: on a 10 ms inter-DC RTT a retransmission
  costs a round trip while FEC costs only redundancy bandwidth — and at
  zero loss the parity bandwidth is pure overhead.

Only the event engine (:mod:`repro.netsim.events`) implements the dynamic
behaviours; the vector backend rejects any non-static spec with an error
naming the event fallback. A fully static spec (constant profiles, no
PFC/ECN/loss) costs nothing: the engine never enters its dynamic loop.
"""

from __future__ import annotations

import dataclasses
import math

import numpy as np

__all__ = [
    "LinkModel",
    "ConstantRate",
    "CONSTANT",
    "PiecewiseRate",
    "step_profile",
    "flapping_profile",
    "as_link_model",
    "speeds_at",
    "PfcConfig",
    "EcnConfig",
    "LossConfig",
    "FecConfig",
    "GilbertElliott",
    "FailStopEvent",
    "RetryConfig",
    "FaultSpec",
]

_INF = float("inf")


class LinkModel:
    """Protocol for per-link rate dynamics.

    A model answers two questions: what is the link's rate *factor*
    (relative to the link's static ``rate``) at time ``t``, and when does a
    transmission of ``size`` bytes starting at ``t`` finish. Constant
    models short-circuit to ``t + size / rate`` — the exact float op the
    static engine performs — so attaching them is free.
    """

    is_constant = True

    def factor_at(self, t: float) -> float:
        return 1.0

    def next_change(self, t: float) -> float:
        """First instant strictly after ``t`` where the factor changes."""
        return _INF

    def service_finish(self, start: float, size: float, rate: float) -> float:
        """Completion time of ``size`` bytes starting service at ``start``.

        ``rate`` is the link's static rate (any constant speed factor is
        already folded into it by the topology).
        """
        return start + size / rate


@dataclasses.dataclass(frozen=True)
class ConstantRate(LinkModel):
    """Degenerate profile: a fixed speed factor.

    ``rail_speeds`` entries become ``ConstantRate(s)`` models whose factor
    the topology pre-folds into ``Link.rate`` — ``service_finish`` is the
    inherited ``start + size / rate``, bit-identical to the static engine.
    """

    factor: float = 1.0

    def __post_init__(self):
        if not self.factor > 0.0:
            raise ValueError("rate factor must be positive")

    def factor_at(self, t: float) -> float:
        return self.factor


#: Shared do-nothing model for frozen links (factor 1.0, pre-folded rates).
CONSTANT = ConstantRate(1.0)


class PiecewiseRate(LinkModel):
    """Piecewise-constant rate-factor profile.

    ``breakpoints`` are strictly increasing times; ``factors`` has one more
    entry than ``breakpoints`` (the factor before the first breakpoint,
    then after each). ``period`` makes the profile repeat (flapping optics):
    times are folded modulo ``period``, which must then cover the last
    breakpoint.
    """

    is_constant = False

    def __init__(self, breakpoints, factors, period: float | None = None):
        self.breakpoints = tuple(float(b) for b in breakpoints)
        self.factors = tuple(float(f) for f in factors)
        self.period = float(period) if period is not None else None
        if len(self.factors) != len(self.breakpoints) + 1:
            raise ValueError("need len(factors) == len(breakpoints) + 1")
        if any(b2 <= b1 for b1, b2 in zip(self.breakpoints, self.breakpoints[1:])):
            raise ValueError("breakpoints must be strictly increasing")
        if any(not f > 0.0 for f in self.factors):
            raise ValueError("rate factors must be positive")
        if self.period is not None:
            if self.breakpoints and self.period <= self.breakpoints[-1]:
                raise ValueError("period must exceed the last breakpoint")
            if self.breakpoints and self.breakpoints[0] <= 0.0:
                raise ValueError("periodic breakpoints must be positive")

    def _segment(self, t: float) -> tuple[float, float]:
        """(factor, local end) of the segment containing local time ``t``."""
        bp = self.breakpoints
        # Linear scan: profiles have a handful of breakpoints.
        for i, b in enumerate(bp):
            if t < b:
                return self.factors[i], b
        return self.factors[len(bp)], _INF if self.period is None else self.period

    def factor_at(self, t: float) -> float:
        if self.period is not None:
            t = t % self.period
        return self._segment(t)[0]

    def next_change(self, t: float) -> float:
        if self.period is not None:
            base = math.floor(t / self.period) * self.period
            local = t - base
            end = self._segment(local)[1]
            return base + end
        return self._segment(t)[1]

    def service_finish(self, start: float, size: float, rate: float) -> float:
        """Integrate the piecewise rate ``rate * factor(t)`` from ``start``
        until ``size`` bytes have been transmitted."""
        remaining = size
        t = start
        # Bounded: each iteration consumes a full profile segment.
        while True:
            factor = self.factor_at(t)
            seg_end = self.next_change(t)
            dt = remaining / (rate * factor)
            if t + dt <= seg_end:
                return t + dt
            remaining -= rate * factor * (seg_end - t)
            t = seg_end


def step_profile(t_step: float, after: float, before: float = 1.0) -> PiecewiseRate:
    """Mid-run degradation: factor ``before`` until ``t_step``, then ``after``
    (the slow-leaf / partial-optics-failure scenario)."""
    return PiecewiseRate((t_step,), (before, after))


def flapping_profile(
    period: float, duty: float, low: float, high: float = 1.0, offset: float = 0.0
) -> PiecewiseRate:
    """Periodic flapping optics: ``high`` for ``duty`` of each ``period``,
    ``low`` for the rest, starting the high phase at ``offset``."""
    if not 0.0 < duty < 1.0:
        raise ValueError("duty must lie in (0, 1)")
    up = duty * period
    if offset == 0.0:
        return PiecewiseRate((up,), (high, low), period=period)
    if not 0.0 < offset < period - up:
        raise ValueError("offset must keep both phase edges inside the period")
    return PiecewiseRate((offset, offset + up), (low, high, low), period=period)


def as_link_model(value) -> LinkModel:
    """Coerce a profile spec: LinkModel pass-through, scalar → ConstantRate."""
    if isinstance(value, LinkModel):
        return value
    return ConstantRate(float(value))


def speeds_at(profiles, t: float) -> np.ndarray:
    """Per-rail speed factors of a profile list evaluated at time ``t``.

    Accepts a mixed list of scalars and :class:`LinkModel` instances — the
    plan-time view :func:`repro.runtime.straggler.degraded_rail_schedule`
    pre-charges from.
    """
    return np.array(
        [as_link_model(p).factor_at(t) for p in profiles], dtype=np.float64
    )


@dataclasses.dataclass(frozen=True)
class PfcConfig:
    """Priority flow control: per-ingress backlog pause/resume thresholds.

    A link whose queued bytes reach ``pause_bytes`` asserts pause; any
    upstream link whose head-of-queue chunk targets it stalls (head-of-line
    blocking — chunks behind the stalled head wait too, which is exactly
    the §VI-E herding amplifier). Pause deasserts when the backlog drains
    to ``resume_bytes`` (default: half the pause threshold).
    """

    pause_bytes: float
    resume_bytes: float | None = None

    def __post_init__(self):
        if not self.pause_bytes > 0.0:
            raise ValueError("pause_bytes must be positive")
        if self.resume_bytes is None:
            object.__setattr__(self, "resume_bytes", 0.5 * self.pause_bytes)
        if not 0.0 <= self.resume_bytes < self.pause_bytes:
            raise ValueError("need 0 <= resume_bytes < pause_bytes")


@dataclasses.dataclass(frozen=True)
class EcnConfig:
    """ECN marking + DCTCP-style multiplicative sender rate cut.

    Chunks entering a queue whose backlog is at least ``mark_bytes`` get
    marked. When a marked chunk is *delivered*, its sender's pacing factor
    is multiplied by ``cut`` (floored at ``min_factor``); every unmarked
    delivery recovers the factor additively by ``recover``. The factor
    scales the sender's first-hop serialization rate — the abstraction of
    end-host pacing at chunk granularity.
    """

    mark_bytes: float
    cut: float = 0.8
    recover: float = 0.05
    min_factor: float = 0.25

    def __post_init__(self):
        if not self.mark_bytes > 0.0:
            raise ValueError("mark_bytes must be positive")
        if not 0.0 < self.cut < 1.0:
            raise ValueError("cut must lie in (0, 1)")
        if not 0.0 < self.min_factor <= 1.0:
            raise ValueError("min_factor must lie in (0, 1]")
        if not self.recover >= 0.0:
            raise ValueError("recover must be >= 0")


@dataclasses.dataclass(frozen=True)
class LossConfig:
    """Per-link chunk loss with go-back-N recovery.

    ``rate`` is the i.i.d. loss probability per link service. Setting
    ``bad_rate``/``p_enter_bad``/``p_leave_bad`` overlays a Gilbert–Elliott
    burst process: each link carries a two-state (good/bad) chain advanced
    once per service; the good-state loss probability is ``rate`` and the
    bad-state probability ``bad_rate``. A lost chunk is retransmitted from
    its source ``rto`` seconds after the failed service ends; a receiver
    holding an earlier outstanding loss on the same transport lane —
    (flow, source NIC), the per-rail RC-QP granularity of the paper's
    testbed — *discards* later chunks of that lane (go-back-N in-order
    delivery), which become outstanding themselves and are retransmitted
    too.
    """

    rate: float
    rto: float
    bad_rate: float | None = None
    p_enter_bad: float = 0.0
    p_leave_bad: float = 0.25
    links: str = "nic"  # "nic" (up/down lanes), "wan" (inter-pod) or "all"

    def __post_init__(self):
        if not 0.0 <= self.rate < 1.0:
            raise ValueError("loss rate must lie in [0, 1)")
        if self.bad_rate is not None and not 0.0 <= self.bad_rate < 1.0:
            raise ValueError("bad-state loss rate must lie in [0, 1)")
        if self.bad_rate is not None and not self.p_enter_bad > 0.0:
            raise ValueError(
                "bad_rate without p_enter_bad > 0 never enters the bad "
                "state; set p_enter_bad or drop bad_rate"
            )
        if not self.rto > 0.0:
            raise ValueError("rto must be positive")
        if not 0.0 <= self.p_enter_bad <= 1.0 or not 0.0 < self.p_leave_bad <= 1.0:
            raise ValueError("Gilbert-Elliott transition probs out of range")
        if self.links not in ("nic", "wan", "all"):
            raise ValueError("links must be 'nic', 'wan' or 'all'")

    @property
    def bursty(self) -> bool:
        return self.bad_rate is not None and self.p_enter_bad > 0.0


@dataclasses.dataclass(frozen=True)
class FecConfig:
    """XOR forward error correction over transport-lane chunk groups.

    Every ``k`` consecutive data chunks committed to one transport lane —
    (flow, first-hop link), the go-back-N granularity — form a group; the
    sender follows them with ``r`` XOR parity chunks sized like the
    largest group member. The receiver reconstructs a group's lost data
    as soon as any ``k`` of its ``k + r`` members arrive (the XOR decode
    instant — no retransmission, no RTO). A group losing *more* than
    ``r`` members is **busted**: its losses fall back to the go-back-N
    retransmission path of :class:`LossConfig`, including data losses the
    group had previously absorbed (they can no longer decode). Parity
    chunks are never retransmitted and never delivered to the flow — they
    cost exactly redundancy bandwidth, ``r / k`` of the protected bytes.

    FEC engages only on lanes whose path crosses a loss-eligible link
    (per ``LossConfig.links``), and is inert without a ``loss`` config —
    set ``LossConfig(rate=0.0, ...)`` to measure pure parity overhead.
    """

    k: int = 4
    r: int = 1

    def __post_init__(self):
        if not self.k >= 1:
            raise ValueError("FEC group size k must be >= 1")
        if not self.r >= 1:
            raise ValueError("FEC parity count r must be >= 1")

    @property
    def overhead(self) -> float:
        """Redundancy bandwidth fraction: parity bytes / data bytes."""
        return self.r / self.k


class GilbertElliott:
    """Two-state burst-loss chain for one link (advanced once per service)."""

    __slots__ = ("cfg", "bad")

    def __init__(self, cfg: LossConfig):
        self.cfg = cfg
        self.bad = False

    def draw(self, rng) -> bool:
        """One service worth of loss: advance the chain, then draw the loss.

        Two RNG draws per call regardless of state, so the stream consumed
        is a deterministic function of the number of services simulated.
        """
        cfg = self.cfg
        u_state = rng.random()
        u_loss = rng.random()
        if cfg.bursty:
            if self.bad:
                if u_state < cfg.p_leave_bad:
                    self.bad = False
            elif u_state < cfg.p_enter_bad:
                self.bad = True
            p = cfg.bad_rate if self.bad else cfg.rate
        else:
            p = cfg.rate
        return u_loss < p


@dataclasses.dataclass(frozen=True)
class FailStopEvent:
    """One fail-stop event: a rail, NIC, or node that *dies* at ``t_fail``.

    Unlike the degradation profiles above (which slow a link down), a
    fail-stop link transmits nothing: in-flight chunks are stranded and
    must be redelivered via timeout-triggered retry onto surviving rails
    (see :class:`RetryConfig`). Three kinds:

    * ``"rail"`` — rail ``rail`` dies fabric-wide: every domain's ``up``
      and ``down`` lane on that rail (the rail switch / optics plane).
    * ``"nic"`` — one (node, rail) NIC dies: domain ``domain``'s ``up``
      and ``down`` lanes on rail ``rail`` only.
    * ``"node"`` — node ``domain`` dies entirely: all of its NIC lanes on
      every rail (the expert-evacuation trigger).

    ``t_repair`` (None = permanent) restores the affected links, after
    which backed-off retries land on them again and the dead-rail detector
    observes traffic and revives the rail.
    """

    kind: str
    t_fail: float
    rail: int | None = None
    domain: int | None = None
    t_repair: float | None = None

    def __post_init__(self):
        if self.kind not in ("rail", "nic", "node"):
            raise ValueError("kind must be 'rail', 'nic' or 'node'")
        if not self.t_fail >= 0.0:
            raise ValueError("t_fail must be >= 0")
        if self.t_repair is not None and not self.t_repair > self.t_fail:
            raise ValueError("t_repair must exceed t_fail")
        if self.kind in ("rail", "nic") and self.rail is None:
            raise ValueError(f"kind={self.kind!r} needs a rail index")
        if self.kind in ("nic", "node") and self.domain is None:
            raise ValueError(f"kind={self.kind!r} needs a domain index")

    def links(self, num_domains: int, num_rails: int) -> list[str]:
        """Names of the ``up``/``down`` lanes this event kills."""
        if self.kind == "rail":
            pairs = [(d, self.rail) for d in range(num_domains)]
        elif self.kind == "nic":
            pairs = [(self.domain, self.rail)]
        else:  # node
            pairs = [(self.domain, r) for r in range(num_rails)]
        return [
            f"{kind}:{d}:{r}" for d, r in pairs for kind in ("up", "down")
        ]


@dataclasses.dataclass(frozen=True)
class RetryConfig:
    """Timeout-triggered retry with exponential backoff for stranded chunks.

    A chunk stranded by a fail-stop event (in flight on the dead link, or
    arriving at one before the sender has re-sprayed) is re-injected after
    ``rto * backoff**min(attempt - 1, max_exponent)`` seconds; at fire time
    the source re-plans the chunk onto a surviving rail if any link of its
    original path is still dead. ``max_retries`` bounds the attempts per
    chunk (exceeded = unrecoverable partition, surfaced as an error rather
    than a silent hang).
    """

    rto: float = 5e-4
    backoff: float = 2.0
    max_exponent: int = 10
    max_retries: int = 50

    def __post_init__(self):
        if not self.rto > 0.0:
            raise ValueError("rto must be positive")
        if not self.backoff >= 1.0:
            raise ValueError("backoff must be >= 1")
        if not self.max_exponent >= 0:
            raise ValueError("max_exponent must be >= 0")
        if not self.max_retries >= 1:
            raise ValueError("max_retries must be >= 1")

    def delay(self, attempt: int) -> float:
        """Backoff delay before retry number ``attempt`` (1-based)."""
        return self.rto * self.backoff ** min(attempt - 1, self.max_exponent)


@dataclasses.dataclass(frozen=True)
class FaultSpec:
    """One fabric's dynamics: per-rail rate profiles + PFC/ECN/loss knobs.

    ``rail_profiles`` maps rail index → profile (a :class:`LinkModel` or a
    bare scalar factor) applied to that rail's NIC lanes (``up``/``down``
    links) on top of any static ``rail_speeds`` factor. ``failures`` lists
    :class:`FailStopEvent` instances (rail/NIC/node deaths with optional
    repair); ``retry`` configures the stranded-chunk redelivery loop
    (defaults to ``RetryConfig()`` whenever failures are present). ``seed``
    drives the fault-layer RNG (loss draws), decoupled from the policy
    seed so the same fault realization can be replayed across policies.
    """

    rail_profiles: dict = dataclasses.field(default_factory=dict)
    pfc: PfcConfig | None = None
    ecn: EcnConfig | None = None
    loss: LossConfig | None = None
    fec: FecConfig | None = None
    failures: tuple = ()
    retry: RetryConfig | None = None
    seed: int = 0

    def __post_init__(self):
        object.__setattr__(
            self,
            "rail_profiles",
            {int(r): as_link_model(p) for r, p in self.rail_profiles.items()},
        )
        object.__setattr__(self, "failures", tuple(self.failures))
        for ev in self.failures:
            if not isinstance(ev, FailStopEvent):
                raise TypeError(f"failures entries must be FailStopEvent, got {ev!r}")

    @property
    def is_static(self) -> bool:
        """True when the spec degenerates to a frozen fabric: constant
        profiles only and no PFC/ECN/loss/fail-stop — the zero-cost case
        both backends run bit-exactly."""
        return (
            self.pfc is None
            and self.ecn is None
            and self.loss is None
            and not self.failures
            and all(m.is_constant for m in self.rail_profiles.values())
        )

    def profile_for_rail(self, rail: int) -> LinkModel | None:
        return self.rail_profiles.get(rail)
