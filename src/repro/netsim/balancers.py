"""Load-balancing policies (paper §VI-A baselines + RailS).

Each policy answers one question per atomic chunk: *which path does this
chunk take?* The structural differences the paper identifies are encoded
explicitly:

* **ECMP** — per-flow static hash; the source NIC is pinned to the source
  GPU's NIC (no intra-domain forwarding), the (dst-rail, spine) pair is
  hashed. Topology-blind; elephant flows collide (Challenge 1/2).
* **PLB** — ECMP start, but a flow re-hashes its (dst-rail, spine) choice
  when its chunks experience queueing beyond a threshold (flowlet repath).
  Still pinned to the source NIC — host-level rehashing cannot move a flow
  off its NIC in a rail fabric, which is why PLB cannot fix NIC imbalance.
* **MinRTT** — MPTCP-style multipath: one subflow per rail (direct paths,
  any local NIC reachable over NVLink). Each chunk goes to the subflow with
  the smallest estimated RTT: fresh local up-link backlog + *stale* remote
  backlog. Reactive; herds under incast when the stale signal flips.
* **REPS** — per-chunk spraying across rails, recycling entropy away from
  congestion: uniform random over rails whose stale path estimate is not
  flagged congested. Near-perfect *sender* balance; receiver-side it can
  only react after the fact (paper Fig. 11).
* **RailS** — the paper: LPT plan per sender domain over its atomic chunks
  (local info only), direct rail paths, proactive. Uniform send ⇒ uniform
  receive by Theorem 3; no probes, no feedback.
* **RailS-online** — the streaming control plane (`repro.sched`): chunks
  are only revealed at release time, so each arrival batch is LPT-assigned
  against a *persistent* per-domain LoadState, optionally pre-charged by
  EWMA rail-health feedback and a routing-replay forecast of bytes still
  to come. With every chunk released at t=0 and no feedback it reproduces
  RailS exactly (the offline-parity anchor).
* **hier-RailS** — two-level RailS for multi-pod fabrics
  (:func:`repro.core.lpt.hier_lpt_schedule`): rails exactly as RailS, and
  inter-pod chunks additionally LPT'd per destination pod over the scarce
  wan lanes. Flat RailS on a multi-pod fabric sprays lane ``rail mod L``
  — per-rail balance says nothing about per-lane balance, which is the
  uniform-send symmetry break the cross-DC bench quantifies. On a flat
  fabric (P=1) hier-RailS degenerates to RailS bit-exactly.

Under fabric dynamics (:mod:`repro.netsim.linkmodel`) the reactive
policies' shared estimate — ``Engine.path_delay`` — additionally folds in
recent ECN marks (stale, refreshed on the probe-snapshot cadence) and live
PFC pause assertions. PLB's repath trigger, MinRTT's subflow choice and
REPS's congestion flag thereby react to mark/pause signals instead of only
backlog; because every sender reads the same stale signals at once, they
herd exactly the way the paper's §VI-E testbed shows, while the proactive
RailS plans are untouched by the noise.
"""

from __future__ import annotations

import math

import numpy as np

from ..core.lpt import LptState, hier_lpt_schedule, lpt_schedule
from ..sched.feedback import speed_precharge
from .events import ChunkJob, Engine
from .topology import RailTopology

__all__ = [
    "Policy",
    "EcmpPolicy",
    "PlbPolicy",
    "MinRttPolicy",
    "RepsPolicy",
    "RailSPolicy",
    "HierRailSPolicy",
    "OnlineRailSPolicy",
    "make_policy",
    "POLICIES",
]


class Policy:
    name = "base"

    def __init__(self, topo: RailTopology, seed: int = 0):
        self.topo = topo
        self.rng = np.random.default_rng(seed)

    def prepare(self, jobs_by_sender: dict[tuple[int, int], list[ChunkJob]]) -> None:
        """Hook for proactive policies (RailS plans here)."""

    def choose_path(self, eng: Engine, job: ChunkJob) -> list[str]:
        raise NotImplementedError

    def assign_batch(
        self,
        eng: Engine,
        batch_by_sender: dict[tuple[int, int], list[ChunkJob]],
        now: float = 0.0,
    ) -> list[ChunkJob]:
        """Assign one release batch; returns jobs in fabric-entry order.

        Senders are visited round-robin (an all-to-all burst is symmetric);
        reactive policies decide chunk-by-chunk via :meth:`choose_path`,
        planners override this to schedule the whole batch jointly.
        Cursor-based — per-sender queues are walked by index, so a batch of
        F chunks costs O(F), not the O(F²/senders) of repeated ``pop(0)``.
        """
        queues = [batch_by_sender[k] for k in sorted(batch_by_sender) if batch_by_sender[k]]
        out: list[ChunkJob] = []
        commit = eng._commit
        choose = self.choose_path
        pos = 0
        while queues:
            nxt = []
            for q in queues:
                job = q[pos]
                commit(job, choose(eng, job))
                out.append(job)
                if pos + 1 < len(q):
                    nxt.append(q)
            queues = nxt
            pos += 1
        return out


class EcmpPolicy(Policy):
    """RoCE reality: the QP endpoints are pinned — src NIC is the source
    GPU's, dst NIC is the destination GPU's (GPUDirect affinity). ECMP only
    hashes the *spine* choice between the two leaves (same-rail pairs go
    direct). This is the paper's "fixed NIC-leaf bindings" critique."""

    name = "ecmp"

    def plan_arrays(self, ja, index):
        """Array-native plan: the per-flow hash is stateless, so the whole
        collective's spine choices vectorize to one splitmix64 pass. On a
        multi-pod fabric the leaf/spine ids are pod-translated and
        cross-pod chunks recycle the hash as wan-lane entropy, exactly
        like :meth:`RailTopology.spine_path`."""
        topo = self.topo
        # uint64 arithmetic wraps, so the scalar path's explicit & masks
        # are implicit here.
        x = ja.flow_id.astype(np.uint64)
        x = x + np.uint64(0x9E3779B97F4A7C15)
        x = (x ^ (x >> np.uint64(30))) * np.uint64(0xBF58476D1CE4E5B9)
        x = (x ^ (x >> np.uint64(27))) * np.uint64(0x94D049BB133111EB)
        x = x ^ (x >> np.uint64(31))
        spine = (x % np.uint64(topo.num_spines)).astype(np.int64)
        src_rail = ja.src_gpu
        dst_rail = ja.dst_gpu
        f = ja.num_chunks
        lbl = np.full((f, index.num_levels), -1, dtype=index.id_dtype, order="F")
        lbl[:, 0] = index.up[ja.src_domain, src_rail]
        lbl[:, index.down_level] = index.down[ja.dst_domain, dst_rail]
        l2s_lv = index.level_of_kind["l2s"]
        s2l_lv = index.level_of_kind["s2l"]
        if index.wan is None:
            cross = src_rail != dst_rail
            lbl[cross, l2s_lv] = index.l2s[src_rail[cross], spine[cross]]
            lbl[cross, s2l_lv] = index.s2l[spine[cross], dst_rail[cross]]
        else:
            dpp = topo.domains_per_pod
            ps = ja.src_domain // dpp
            pd = ja.dst_domain // dpp
            same = ps == pd
            cross = (src_rail != dst_rail) & same
            leaf_s = ps * topo.n + src_rail
            leaf_d = pd * topo.n + dst_rail
            sp = ps * topo.num_spines + (spine % topo.num_spines)
            lbl[cross, l2s_lv] = index.l2s[leaf_s[cross], sp[cross]]
            lbl[cross, s2l_lv] = index.s2l[sp[cross], leaf_d[cross]]
            xp = ~same
            lane = spine % topo.wan_lanes
            lbl[xp, index.level_of_kind["wan"]] = index.wan[
                ps[xp], pd[xp], lane[xp]
            ]
        return lbl

    def __init__(self, topo: RailTopology, seed: int = 0):
        super().__init__(topo, seed)
        self._flow_spine: dict[int, int] = {}

    @staticmethod
    def _mix(x: int) -> int:
        # splitmix64 finalizer — a real switch hash, avoids modular aliasing.
        x = (x + 0x9E3779B97F4A7C15) & 0xFFFFFFFFFFFFFFFF
        x = ((x ^ (x >> 30)) * 0xBF58476D1CE4E5B9) & 0xFFFFFFFFFFFFFFFF
        x = ((x ^ (x >> 27)) * 0x94D049BB133111EB) & 0xFFFFFFFFFFFFFFFF
        return x ^ (x >> 31)

    def choose_path(self, eng: Engine, job: ChunkJob) -> list[str]:
        spine = self._flow_spine.get(job.flow_id)
        if spine is None:
            spine = self._mix(job.flow_id) % self.topo.num_spines
            self._flow_spine[job.flow_id] = spine
        return self.topo.spine_path(
            job.src_domain, job.dst_domain, job.src_gpu, job.dst_gpu, spine
        )


class PlbPolicy(Policy):
    """PLB rehashes the IPv6 flow label on congestion — which can move a
    flow across *spines*, but never off its NIC endpoints. In a rail fabric
    the NICs are the bottleneck, so PLB's repath authority is structurally
    insufficient (paper §VI-D/E)."""

    name = "plb"

    def __init__(self, topo: RailTopology, seed: int = 0, threshold: float = 4.0):
        super().__init__(topo, seed)
        self.threshold = threshold  # backlog multiple of one chunk's service
        self._flow_spine: dict[int, int] = {}

    def choose_path(self, eng: Engine, job: ChunkJob) -> list[str]:
        spine = self._flow_spine.get(job.flow_id)
        if spine is None:
            spine = int(self.rng.integers(self.topo.num_spines))
        path = self.topo.spine_path(
            job.src_domain, job.dst_domain, job.src_gpu, job.dst_gpu, spine
        )
        # Congestion check: if current backlog along the path exceeds
        # threshold x this chunk's own service time, repath (flowlet gap).
        service = job.size / self.topo.r2
        if eng.path_delay(path, job.src_domain) > self.threshold * service:
            spine = int(self.rng.integers(self.topo.num_spines))
            path = self.topo.spine_path(
                job.src_domain, job.dst_domain, job.src_gpu, job.dst_gpu, spine
            )
        self._flow_spine[job.flow_id] = spine
        return path


class MinRttPolicy(Policy):
    """MPTCP-style multipath: one subflow per *source* NIC (bandwidth
    aggregation across the sender's rails), each chunk on the subflow with
    the smallest estimated RTT. Delivery is still pinned to the destination
    GPU's NIC — transport-level multipath cannot exploit parallel reception
    (paper §VI-F: "they fail to leverage parallel reception")."""

    name = "minrtt"

    def _subflow(self, job: ChunkJob, src_rail: int) -> list[str]:
        spine = (src_rail * 7 + job.dst_gpu) % self.topo.num_spines
        return self.topo.spine_path(
            job.src_domain, job.dst_domain, src_rail, job.dst_gpu, spine
        )

    def choose_path(self, eng: Engine, job: ChunkJob) -> list[str]:
        # `<=` keeps a path selected even if every estimate is the inf
        # sentinel (all subflows cross dead links — nothing better exists,
        # and the fabric-level retry machinery owns the recovery). With
        # any finite estimate present the comparison picks the first
        # minimum exactly as `<` over finite floats did.
        best_path, best = None, float("inf")
        for rail in range(self.topo.n):
            path = self._subflow(job, rail)
            est = eng.path_delay(path, job.src_domain)
            if best_path is None or est < best:
                best, best_path = est, path
        return best_path


class RepsPolicy(Policy):
    """Per-chunk spraying with entropy recycling: chunks spray uniformly
    across source rails/spines whose (stale) estimate is not flagged
    congested. Sender side this is near-perfect; receiver side delivery is
    pinned to the destination GPU's NIC, so incast hotspots remain."""

    name = "reps"

    def __init__(self, topo: RailTopology, seed: int = 0, congest_factor: float = 2.0):
        super().__init__(topo, seed)
        self.congest_factor = congest_factor

    def choose_path(self, eng: Engine, job: ChunkJob) -> list[str]:
        n = self.topo.n
        num_spines = self.topo.num_spines
        integers = self.rng.integers
        spine_path = self.topo.spine_path
        path_delay = eng.path_delay
        src_domain, dst_domain, dst_gpu = job.src_domain, job.dst_domain, job.dst_gpu
        ests, paths = [], []
        for rail in range(n):
            spine = int(integers(num_spines))
            path = spine_path(src_domain, dst_domain, rail, dst_gpu, spine)
            paths.append(path)
            ests.append(path_delay(path, src_domain))
        # Dead links read as the inf sentinel: they never enter the good
        # pool, and the congestion threshold is computed over finite
        # estimates only (inf would otherwise poison the mean and make
        # `inf <= inf` admit unusable paths). Healthy fabrics see the
        # exact historical arithmetic — every estimate is finite.
        finite = [est for est in ests if math.isfinite(est)]
        mean = sum(finite) / len(finite) if finite else 0.0
        threshold = self.congest_factor * max(mean, 1e-12)
        good = [
            r for r, est in enumerate(ests)
            if math.isfinite(est) and est <= threshold
        ]
        pool = good if good else list(range(n))
        return paths[int(self.rng.choice(pool))]


class RailSPolicy(Policy):
    """The paper: per-domain LPT over atomic chunks, direct rails only."""

    name = "rails"

    def __init__(self, topo: RailTopology, seed: int = 0):
        super().__init__(topo, seed)
        self._assignment: dict[int, int] = {}  # chunk_id -> rail

    def prepare(self, jobs_by_sender: dict[tuple[int, int], list[ChunkJob]]) -> None:
        # Algorithm 2: collect all atomic flows of each source *domain*
        # (intra-domain NVLink forwarding pools the GPUs), LPT-assign to the
        # domain's N NICs using only local information.
        by_domain: dict[int, list[ChunkJob]] = {}
        for (_d, _g), jobs in jobs_by_sender.items():
            for j in jobs:
                by_domain.setdefault(j.src_domain, []).append(j)
        for _domain, jobs in by_domain.items():
            weights = np.array([j.size for j in jobs])
            src_ids = np.array([j.src_gpu for j in jobs])
            res = lpt_schedule(weights, self.topo.n, source_ids=src_ids)
            for j, rail in zip(jobs, res.assignment):
                self._assignment[j.chunk_id] = int(rail)

    def plan_arrays(self, ja, index):
        """Array-native Algorithm 2: per-domain LPT without ChunkJob lists.

        Domains are contiguous runs in chunk order, so each domain's
        weights/source-ids are plain slices; the ``lpt_schedule`` calls are
        byte-identical to :meth:`prepare`'s, so assignments match the event
        path exactly.
        """
        from .fastsim import _group_bounds

        f = ja.num_chunks
        rail = np.empty(f, dtype=np.int64)
        if f:
            starts, ends = _group_bounds(ja.src_domain)
            for s, e in zip(starts.tolist(), ends.tolist()):
                res = lpt_schedule(
                    ja.size[s:e], self.topo.n, source_ids=ja.src_gpu[s:e]
                )
                rail[s:e] = res.assignment
        lbl = np.full((f, index.num_levels), -1, dtype=index.id_dtype, order="F")
        if f:
            lbl[:, 0] = index.up[ja.src_domain, rail]
            lbl[:, index.down_level] = index.down[ja.dst_domain, rail]
            self._fill_wan(ja, index, rail, lbl)
        return lbl

    def _fill_wan(self, ja, index, rail, lbl) -> None:
        """Cross-pod chunks ride the rail's default wan lane (``rail mod
        L`` — :meth:`RailTopology.rail_path`'s static spray). Hier-RailS
        overrides this with its per-pod lane LPT."""
        if index.wan is None:
            return
        dpp = self.topo.domains_per_pod
        ps = ja.src_domain // dpp
        pd = ja.dst_domain // dpp
        xp = ps != pd
        if xp.any():
            lane = rail % self.topo.wan_lanes
            lbl[xp, index.level_of_kind["wan"]] = index.wan[
                ps[xp], pd[xp], lane[xp]
            ]

    def choose_path(self, eng: Engine, job: ChunkJob) -> list[str]:
        rail = self._assignment[job.chunk_id]
        return self.topo.rail_path(job.src_domain, job.dst_domain, rail)


class HierRailSPolicy(RailSPolicy):
    """Two-level RailS for hierarchical fabrics (`hier_lpt_schedule`).

    Level 1 (rails) is byte-identical to :class:`RailSPolicy` — every
    chunk still serializes through one NIC, so NIC balance stays the
    first-order term and flat-fabric behavior is bit-exact. Level 2 LPTs
    each source domain's *inter-pod* chunks per destination pod over the
    ``L`` wan lanes of that pod pair, replacing flat RailS's static
    ``lane = rail mod L`` spray. Per-rail balance says nothing about how
    a rail's bytes split across destination pods; under skewed (MoE-gated)
    traffic the static spray loads wan lanes unevenly — the uniform-send
    symmetry break of the cross-DC study. The lane LPT carries a shared
    per-source-pod load state across the pod's domains (the
    ``lane_loads`` carry of :func:`hier_lpt_schedule`), so the *pod
    aggregate* per-lane load is balanced — Theorem 3 restored one tier
    up, by coordination rather than by symmetry.
    """

    name = "hier-rails"

    def __init__(self, topo: RailTopology, seed: int = 0):
        super().__init__(topo, seed)
        self._lane: dict[int, int] = {}  # chunk_id -> wan lane (-1 intra)

    def prepare(self, jobs_by_sender: dict[tuple[int, int], list[ChunkJob]]) -> None:
        topo = self.topo
        if topo.num_pods <= 1:
            return super().prepare(jobs_by_sender)
        by_domain: dict[int, list[ChunkJob]] = {}
        for (_d, _g), jobs in jobs_by_sender.items():
            for j in jobs:
                by_domain.setdefault(j.src_domain, []).append(j)
        dpp = topo.domains_per_pod
        # Shared lane-load carry per source pod: later domains see the wan
        # bytes earlier siblings already placed, balancing the aggregate.
        pod_lanes: dict[int, dict[int, np.ndarray]] = {}
        for domain in sorted(by_domain):
            jobs = by_domain[domain]
            weights = np.array([j.size for j in jobs])
            src_ids = np.array([j.src_gpu for j in jobs])
            dst_pods = np.array([j.dst_domain // dpp for j in jobs])
            res = hier_lpt_schedule(
                weights,
                topo.n,
                topo.wan_lanes,
                dst_pods,
                domain // dpp,
                source_ids=src_ids,
                lane_loads=pod_lanes.setdefault(domain // dpp, {}),
            )
            for j, rail, lane in zip(jobs, res.rail.assignment, res.lane):
                self._assignment[j.chunk_id] = int(rail)
                self._lane[j.chunk_id] = int(lane)

    def plan_arrays(self, ja, index):
        topo = self.topo
        if topo.num_pods <= 1:
            return super().plan_arrays(ja, index)
        from .fastsim import _group_bounds

        f = ja.num_chunks
        rail = np.empty(f, dtype=np.int64)
        lane = np.full(f, -1, dtype=np.int64)
        dpp = topo.domains_per_pod
        src_pods = ja.src_domain // dpp
        dst_pods = ja.dst_domain // dpp
        if f:
            pod_lanes: dict[int, dict[int, np.ndarray]] = {}
            starts, ends = _group_bounds(ja.src_domain)
            for s, e in zip(starts.tolist(), ends.tolist()):
                res = hier_lpt_schedule(
                    ja.size[s:e],
                    topo.n,
                    topo.wan_lanes,
                    dst_pods[s:e],
                    int(src_pods[s]),
                    source_ids=ja.src_gpu[s:e],
                    lane_loads=pod_lanes.setdefault(int(src_pods[s]), {}),
                )
                rail[s:e] = res.rail.assignment
                lane[s:e] = res.lane
        lbl = np.full((f, index.num_levels), -1, dtype=index.id_dtype, order="F")
        if f:
            lbl[:, 0] = index.up[ja.src_domain, rail]
            lbl[:, index.down_level] = index.down[ja.dst_domain, rail]
            xp = lane >= 0
            if xp.any():
                lbl[xp, index.level_of_kind["wan"]] = index.wan[
                    src_pods[xp], dst_pods[xp], lane[xp]
                ]
        return lbl

    def choose_path(self, eng: Engine, job: ChunkJob) -> list[str]:
        rail = self._assignment[job.chunk_id]
        lane = self._lane.get(job.chunk_id, -1)
        if lane >= 0:
            return self.topo.rail_path(
                job.src_domain, job.dst_domain, rail, lane=lane
            )
        return self.topo.rail_path(job.src_domain, job.dst_domain, rail)


class OnlineRailSPolicy(Policy):
    """Streaming RailS: per-batch LPT over a persistent per-domain LoadState.

    Three optional information sources sharpen the plan (all default off so
    the bare policy is the offline-parity anchor):

    * ``window`` — re-plan granularity inside a release batch: ``None``
      plans the whole batch at once (equals Algorithm 2 when everything
      releases together), ``1`` is greedy list scheduling on arrival, and
      intermediate K bounds decision latency to K chunks.
    * ``health`` — a ``RailHealthEstimator``; its EWMA speed estimates are
      folded in as a LoadState pre-charge so byte-LPT approximates
      time-LPT on degraded rails (`repro.sched.feedback`).
    * ``replay`` — a ``RoutingReplayState``; its forecast of the domain's
      *total* iteration egress right-sizes the pre-charge before most
      chunks have arrived (routing replay from previous gating counts).
      The pre-charge exists only when ``health`` is set — with nominal
      speeds it is identically zero, so replay without health is a no-op
      here (it still drives chunk sizing in the pipeline driver).
    * ``detector`` — a ``DeadRailDetector`` (silence watchdog); it is
      swept at every assignment batch and its survivor mask restricts the
      windowed LPT to alive rails — the degraded N−k Theorem-2 regime.
      The EWMA ``health`` estimator cannot do this (a dead rail emits no
      observations, so its speed estimate freezes); the watchdog reads
      the silence itself.
    """

    name = "rails-online"

    def __init__(
        self,
        topo: RailTopology,
        seed: int = 0,
        window: int | None = None,
        health=None,
        replay=None,
        detector=None,
    ):
        super().__init__(topo, seed)
        self.window = window
        self.health = health
        self.replay = replay
        self.detector = detector
        # Persistent per-domain LPT state: realized bytes per rail plus the
        # incremental assigner — each arrival window extends the plan in
        # O(K log N) without re-sorting the committed backlog.
        self._state: dict[int, LptState] = {}
        self.loads: dict[int, np.ndarray] = {}  # realized bytes per domain rail
        self._assignment: dict[int, int] = {}  # chunk_id -> rail

    def _domain_state(self, domain: int) -> LptState:
        state = self._state.get(domain)
        if state is None:
            state = self._state[domain] = LptState(self.topo.n)
            self.loads[domain] = state.loads
        return state

    def _precharge(self, domain: int, batch_total: float) -> np.ndarray | None:
        """Phantom LoadState bias for degraded rails (None when healthy)."""
        if self.health is None:
            return None
        real = self._domain_state(domain).loads
        known = float(real.sum()) + batch_total
        forecast = (
            self.replay.expected_total(domain) if self.replay is not None else 0.0
        )
        # Pre-charge against the larger of what we can see and what the
        # replay predicts for the full iteration — an undersized total
        # under-penalizes the slow rail for the chunks yet to come.
        return speed_precharge(max(known, forecast), self.health.speeds())

    def assign_batch(
        self,
        eng: Engine,
        batch_by_sender: dict[tuple[int, int], list[ChunkJob]],
        now: float = 0.0,
    ) -> list[ChunkJob]:
        by_domain: dict[int, list[ChunkJob]] = {}
        for key in sorted(batch_by_sender):
            for j in batch_by_sender[key]:
                by_domain.setdefault(j.src_domain, []).append(j)
        mask = None
        if self.detector is not None:
            # Sweep the silence watchdog at control-plane cadence (every
            # assignment batch); plan this batch over survivors only.
            self.detector.sweep(now)
            m = self.detector.survivor_mask()
            if not m.all():
                mask = m
        for domain, jobs in by_domain.items():
            weights = np.array([j.size for j in jobs])
            src_ids = np.array([j.src_gpu for j in jobs])
            state = self._domain_state(domain)
            extra = self._precharge(domain, float(weights.sum()))
            f = weights.size
            step = f if self.window is None else max(self.window, 1)
            assignment = np.empty(f, dtype=np.int64)
            for lo in range(0, f, step):
                hi = min(lo + step, f)
                res = state.assign(
                    weights[lo:hi],
                    source_ids=src_ids[lo:hi],
                    extra_loads=extra,
                    rail_mask=mask,
                )
                assignment[lo:hi] = res.assignment
            for j, rail in zip(jobs, assignment):
                self._assignment[j.chunk_id] = int(rail)
        # Fabric-entry order stays the generic round-robin over senders.
        return super().assign_batch(eng, batch_by_sender, now=now)

    def choose_path(self, eng: Engine, job: ChunkJob) -> list[str]:
        rail = self._assignment[job.chunk_id]
        return self.topo.rail_path(job.src_domain, job.dst_domain, rail)


POLICIES = {
    p.name: p
    for p in (
        EcmpPolicy,
        PlbPolicy,
        MinRttPolicy,
        RepsPolicy,
        RailSPolicy,
        HierRailSPolicy,
        OnlineRailSPolicy,
    )
}


def make_policy(name: str, topo: RailTopology, seed: int = 0, **kwargs) -> Policy:
    """Instantiate a policy by name; ``kwargs`` pass through to the policy
    constructor (e.g. ``window``/``health``/``replay`` for rails-online)."""
    try:
        cls = POLICIES[name]
    except KeyError:
        raise ValueError(f"unknown policy {name!r}; choose {sorted(POLICIES)}") from None
    return cls(topo, seed=seed, **kwargs)
