"""Sharded checkpointing with atomic commit + async double-buffering."""

from .checkpoint import Checkpointer, latest_step, restore, save

__all__ = ["Checkpointer", "latest_step", "restore", "save"]
