"""Sharded checkpointing with atomic commit and async double-buffering.

Layout (one directory per step)::

    <root>/step_000100.tmp/          # written here first
        manifest.json                # tree structure, shapes, dtypes, step
        shard_00000.npz              # this host's leaves
    <root>/step_000100/              # atomic rename on commit

Design points for 1000+ node deployments:
* every host writes only its own shard file; the manifest is written by
  host 0; commit is a single atomic ``rename`` (restart never sees a
  half-written checkpoint);
* ``save_async`` runs serialization on a worker thread double-buffered
  against the train loop (at most one outstanding save — backpressure
  instead of unbounded memory);
* ``restore`` validates the manifest tree against the expected pytree and
  re-shards on load (elastic restarts: host count may differ from save).
"""

from __future__ import annotations

import json
import os
import threading
from pathlib import Path
from typing import Any, Optional

import numpy as np

import jax

__all__ = ["save", "restore", "latest_step", "Checkpointer"]

_MANIFEST = "manifest.json"


def _flatten(tree: Any):
    leaves, treedef = jax.tree_util.tree_flatten_with_path(tree)
    keys = ["/".join(str(getattr(k, "key", getattr(k, "idx", k))) for k in path)
            for path, _ in leaves]
    vals = [leaf for _, leaf in leaves]
    return keys, vals, treedef


def save(root: str | Path, step: int, tree: Any, host_id: int = 0, num_hosts: int = 1) -> Path:
    """Synchronous sharded save with atomic commit."""
    root = Path(root)
    final = root / f"step_{step:08d}"
    tmp = root / f"step_{step:08d}.tmp"
    tmp.mkdir(parents=True, exist_ok=True)
    keys, vals, _ = _flatten(tree)
    arrays = {k: np.asarray(v) for k, v in zip(keys, vals)}
    # Each host stores the leaves it owns; single-host stores everything.
    mine = {k: v for i, (k, v) in enumerate(arrays.items()) if i % num_hosts == host_id}
    # npz cannot represent ml_dtypes (bfloat16 etc.) — store the raw bits as
    # uint16/uint8 with a dtype tag in the entry name.
    encoded = {}
    for k, v in mine.items():
        name = k.replace("/", "|")
        if v.dtype.kind == "V":  # ml_dtypes (bfloat16, fp8, ...) -> raw bits
            encoded[f"{name}::{v.dtype.name}"] = v.view(
                np.uint8 if v.dtype.itemsize == 1 else np.uint16
            )
        else:
            encoded[name] = v
    np.savez(tmp / f"shard_{host_id:05d}.npz", **encoded)
    if host_id == 0:
        manifest = {
            "step": step,
            "num_hosts": num_hosts,
            "leaves": {
                k: {"shape": list(np.shape(v)), "dtype": str(np.asarray(v).dtype),
                    "host": i % num_hosts}
                for i, (k, v) in enumerate(arrays.items())
            },
        }
        (tmp / _MANIFEST).write_text(json.dumps(manifest, indent=1))
    # Atomic commit (host 0 after barrier in a real deployment).
    if final.exists():
        return final
    os.replace(tmp, final)
    return final


def latest_step(root: str | Path) -> Optional[int]:
    root = Path(root)
    if not root.exists():
        return None
    steps = [
        int(p.name.split("_")[1])
        for p in root.iterdir()
        if p.is_dir() and p.name.startswith("step_") and not p.name.endswith(".tmp")
    ]
    return max(steps) if steps else None


def restore(root: str | Path, tree_like: Any, step: Optional[int] = None) -> tuple[Any, int]:
    """Restore into the structure of ``tree_like``. Returns ``(tree, step)``."""
    root = Path(root)
    if step is None:
        step = latest_step(root)
        if step is None:
            raise FileNotFoundError(f"no checkpoints under {root}")
    d = root / f"step_{step:08d}"
    manifest = json.loads((d / _MANIFEST).read_text())
    arrays: dict[str, np.ndarray] = {}
    for shard in sorted(d.glob("shard_*.npz")):
        with np.load(shard) as z:
            for k in z.files:
                val = z[k]
                if "::" in k:
                    k, dtype_name = k.rsplit("::", 1)
                    import ml_dtypes

                    val = val.view(np.dtype(getattr(ml_dtypes, dtype_name)))
                arrays[k.replace("|", "/")] = val
    keys, vals, treedef = _flatten(tree_like)
    missing = [k for k in keys if k not in arrays]
    if missing:
        raise ValueError(f"checkpoint missing {len(missing)} leaves, e.g. {missing[:3]}")
    new_vals = []
    for k, v in zip(keys, vals):
        a = arrays[k]
        want = manifest["leaves"].get(k)
        if want is not None and list(a.shape) != want["shape"]:
            raise ValueError(f"manifest/shard mismatch for {k}")
        if tuple(a.shape) != tuple(np.shape(v)):
            raise ValueError(f"shape mismatch for {k}: ckpt {a.shape} vs expected {np.shape(v)}")
        new_vals.append(a.astype(np.asarray(v).dtype) if hasattr(v, "dtype") else a)
    return jax.tree_util.tree_unflatten(treedef.treedef if hasattr(treedef, "treedef") else treedef, new_vals), step


class Checkpointer:
    """Async double-buffered checkpoint writer (at most one in flight)."""

    def __init__(self, root: str | Path, host_id: int = 0, num_hosts: int = 1,
                 keep: int = 3):
        self.root = Path(root)
        self.host_id = host_id
        self.num_hosts = num_hosts
        self.keep = keep
        self._thread: Optional[threading.Thread] = None
        self._error: Optional[BaseException] = None

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._error is not None:
            err, self._error = self._error, None
            raise err

    def save_async(self, step: int, tree: Any) -> None:
        self.wait()  # backpressure: one outstanding save max
        host_tree = jax.tree.map(np.asarray, tree)  # snapshot off-device

        def work():
            try:
                save(self.root, step, host_tree, self.host_id, self.num_hosts)
                self._gc()
            except BaseException as e:  # noqa: BLE001
                self._error = e

        self._thread = threading.Thread(target=work, daemon=True)
        self._thread.start()

    def _gc(self) -> None:
        steps = sorted(
            int(p.name.split("_")[1])
            for p in self.root.iterdir()
            if p.is_dir() and p.name.startswith("step_") and not p.name.endswith(".tmp")
        )
        for s in steps[: -self.keep]:
            d = self.root / f"step_{s:08d}"
            for f in d.iterdir():
                f.unlink()
            d.rmdir()
