"""AdamW in pure JAX (pytree-structured, sharding-transparent).

bf16 parameters with fp32 first/second moments (no separate fp32 master —
moments carry the precision; update math in fp32). State pytrees mirror the
parameter tree, so parameter PartitionSpecs apply verbatim to the moments —
ZeRO-style optimizer-state sharding falls out of the param sharding rules.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp

__all__ = ["AdamWConfig", "adamw_init", "adamw_update"]


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    learning_rate: float | Callable[[jnp.ndarray], jnp.ndarray] = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0


def adamw_init(params: Any) -> dict:
    zeros = lambda p: jnp.zeros(p.shape, dtype=jnp.float32)
    return {
        "m": jax.tree.map(zeros, params),
        "v": jax.tree.map(zeros, params),
        "count": jnp.zeros((), dtype=jnp.int32),
    }


def _global_norm(tree: Any) -> jnp.ndarray:
    leaves = [jnp.sum(jnp.square(g.astype(jnp.float32))) for g in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def adamw_update(
    grads: Any, state: dict, params: Any, cfg: AdamWConfig
) -> tuple[Any, dict, dict]:
    """Returns ``(new_params, new_state, stats)``."""
    count = state["count"] + 1
    lr = cfg.learning_rate(count) if callable(cfg.learning_rate) else cfg.learning_rate
    gnorm = _global_norm(grads)
    scale = jnp.minimum(1.0, cfg.grad_clip / jnp.maximum(gnorm, 1e-12))

    def upd(g, m, v, p):
        g = g.astype(jnp.float32) * scale
        m_new = cfg.b1 * m + (1 - cfg.b1) * g
        v_new = cfg.b2 * v + (1 - cfg.b2) * g * g
        m_hat = m_new / (1 - cfg.b1**count)
        v_hat = v_new / (1 - cfg.b2**count)
        step = m_hat / (jnp.sqrt(v_hat) + cfg.eps) + cfg.weight_decay * p.astype(
            jnp.float32
        )
        p_new = (p.astype(jnp.float32) - lr * step).astype(p.dtype)
        return p_new, m_new, v_new

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_m = treedef.flatten_up_to(state["m"])
    flat_v = treedef.flatten_up_to(state["v"])
    out = [upd(g, m, v, p) for g, m, v, p in zip(flat_g, flat_m, flat_v, flat_p)]
    new_params = treedef.unflatten([o[0] for o in out])
    new_m = treedef.unflatten([o[1] for o in out])
    new_v = treedef.unflatten([o[2] for o in out])
    stats = {"grad_norm": gnorm, "lr": jnp.asarray(lr, jnp.float32)}
    return new_params, {"m": new_m, "v": new_v, "count": count}, stats
