"""Int8 error-feedback gradient compression for cross-pod (DCN) reduction.

Beyond-paper distributed-optimization trick (DESIGN.md §4.3): the pod axis
crosses the data-center network, where bandwidth is ~10x scarcer than ICI.
Gradients are quantized to int8 with a per-tensor scale before the pod
all-reduce; the quantization residual is carried in an error-feedback
buffer so the compression bias vanishes over steps (Karimireddy et al.).

``compressed_psum`` is used inside a partial-manual ``shard_map`` over the
``pod`` axis (see launch/steps.py); everything else stays auto-sharded.
"""

from __future__ import annotations

from typing import Any, Optional

import jax
import jax.numpy as jnp

__all__ = ["quantize_int8", "dequantize_int8", "ef_init", "compressed_psum"]


def quantize_int8(x: jnp.ndarray) -> tuple[jnp.ndarray, jnp.ndarray]:
    scale = jnp.max(jnp.abs(x)) / 127.0 + 1e-12
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale.astype(jnp.float32)


def dequantize_int8(q: jnp.ndarray, scale: jnp.ndarray) -> jnp.ndarray:
    return q.astype(jnp.float32) * scale


def ef_init(params: Any) -> Any:
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)


def compressed_psum(
    grads: Any, ef: Any, axis_name: str, pod_count: int
) -> tuple[Any, Any]:
    """Error-feedback int8 all-reduce over ``axis_name``.

    Per leaf: ``c = g + ef``; quantize ``c``; psum int8 (wire traffic is
    1/4 of fp32); dequantize with psum'd scales / pod_count; new
    ``ef = c - dequant(local contribution)``.
    """

    def one(g, e):
        c = g.astype(jnp.float32) + e
        # Shared scale across pods (one scalar pmax) keeps the int8 sum
        # exact: sum_i q_i * s == s * sum_i q_i.
        scale = jax.lax.pmax(jnp.max(jnp.abs(c)), axis_name) / 127.0 + 1e-12
        q = jnp.clip(jnp.round(c / scale), -127, 127).astype(jnp.int8)
        local = q.astype(jnp.float32) * scale
        # int8 sums can overflow int8; accumulate in int32 on the wire-ish
        # representation (XLA will still move 8-bit operands where legal).
        q_sum = jax.lax.psum(q.astype(jnp.int32), axis_name)
        g_avg = q_sum.astype(jnp.float32) * scale / pod_count
        e_new = c - local
        return g_avg, e_new

    flat_g, treedef = jax.tree.flatten(grads)
    flat_e = treedef.flatten_up_to(ef)
    outs = [one(g, e) for g, e in zip(flat_g, flat_e)]
    return treedef.unflatten([o[0] for o in outs]), treedef.unflatten(
        [o[1] for o in outs]
    )
