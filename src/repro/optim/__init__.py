"""Optimizer substrate: AdamW, LR schedules, gradient compression."""

from .adamw import AdamWConfig, adamw_init, adamw_update
from .compress import compressed_psum, dequantize_int8, ef_init, quantize_int8
from .schedule import constant, warmup_cosine

__all__ = [
    "AdamWConfig",
    "adamw_init",
    "adamw_update",
    "compressed_psum",
    "constant",
    "dequantize_int8",
    "ef_init",
    "quantize_int8",
    "warmup_cosine",
]
