"""While-loop-aware cost analysis over compiled HLO text.

``compiled.cost_analysis()`` counts each while-loop *body once* (verified on
the CPU backend), which under-counts scanned layers / microbatches by their
trip counts. This walker parses the post-optimization HLO module, builds
the computation call graph, and accumulates:

* **dot FLOPs** — ``2 * numel(result) * contracted_size`` per dot;
* **elementwise FLOPs** — 1 * numel(result) for arithmetic/transcendental
  ops (what SSM/xLSTM recurrences are made of);
* **HBM bytes** — operand + result bytes of top-level ops per computation
  (ops inside fusions touch VMEM/registers only; the fusion op's own
  operands/results are the HBM traffic);
* **collective bytes** by type (result-shape convention, matching
  roofline.analysis).

Loop multipliers come from ``backend_config={"known_trip_count":{"n":...}}``
on while ops (emitted by XLA for scan-derived loops), falling back to 1.
"""

from __future__ import annotations

import dataclasses
import json
import re

__all__ = ["HloCost", "analyze_hlo"]

_COLLECTIVES = (
    "all-gather",
    "all-reduce",
    "reduce-scatter",
    "all-to-all",
    "collective-permute",
)

_ELEMENTWISE = {
    "add", "subtract", "multiply", "divide", "maximum", "minimum", "power",
    "exponential", "tanh", "log", "rsqrt", "sqrt", "negate", "abs",
    "exponential-minus-one", "logistic", "cosine", "sine", "select",
    "compare", "and", "or", "xor",
}

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "bf16": 2, "f16": 2, "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8,
    "f64": 8, "c64": 8, "c128": 16,
}

_COMP_HEADER = re.compile(r"^(?:ENTRY\s+)?%?([\w\.\-]+)\s*\(.*\)\s*->\s*.+\{\s*$")
_NAME_RE = re.compile(r"^\s*(?:ROOT\s+)?%([\w\.\-]+)\s*=\s*")
_OPCODE_RE = re.compile(r"^\s*([\w\-]+)\(")


def _parse_def(line: str):
    """Parse ``%name = <shape> opcode(...)`` with tuple-shape awareness.

    Tuple shapes may contain ``/*index=N*/`` comments and nested layout
    parens, so the shape is extracted by paren matching, not regex.
    """
    m = _NAME_RE.match(line)
    if not m:
        return None
    name = m.group(1)
    rest = line[m.end():]
    if rest.startswith("("):
        depth = 0
        for i, ch in enumerate(rest):
            if ch == "(":
                depth += 1
            elif ch == ")":
                depth -= 1
                if depth == 0:
                    shape, tail = rest[: i + 1], rest[i + 1 :]
                    break
        else:
            return None
    else:
        sp = rest.find(" ")
        if sp < 0:
            return None
        shape, tail = rest[:sp], rest[sp:]
    om = _OPCODE_RE.match(tail.lstrip())
    if not om:
        return None
    return name, shape, om.group(1)
_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_OPERAND_RE = re.compile(r"%([\w\.\-]+)")
_TRIP_RE = re.compile(r'"known_trip_count":\{"n":"(\d+)"\}')
_CALLS_RE = re.compile(r"(?:calls=|condition=|body=|to_apply=)%?([\w\.\-]+)")


def _shape_info(shape_str: str) -> tuple[int, int]:
    """(numel, bytes) summed over all arrays in the (possibly tuple) shape."""
    numel = 0
    total = 0
    for dtype, dims in _SHAPE_RE.findall(shape_str):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        numel += n
        total += n * _DTYPE_BYTES[dtype]
    return numel, total


@dataclasses.dataclass
class HloCost:
    dot_flops: float = 0.0
    elementwise_flops: float = 0.0
    hbm_bytes: float = 0.0
    collective: dict = dataclasses.field(
        default_factory=lambda: {k: 0.0 for k in _COLLECTIVES}
    )
    collective_ops: dict = dataclasses.field(
        default_factory=lambda: {k: 0 for k in _COLLECTIVES}
    )

    @property
    def flops(self) -> float:
        return self.dot_flops + self.elementwise_flops

    @property
    def collective_bytes(self) -> float:
        return sum(self.collective.values())

    def add(self, other: "HloCost", mult: float = 1.0) -> None:
        self.dot_flops += other.dot_flops * mult
        self.elementwise_flops += other.elementwise_flops * mult
        self.hbm_bytes += other.hbm_bytes * mult
        for k in _COLLECTIVES:
            self.collective[k] += other.collective[k] * mult
            self.collective_ops[k] += int(other.collective_ops[k] * mult)


@dataclasses.dataclass
class _Op:
    name: str
    shape_str: str
    opcode: str
    line: str


def _split_computations(text: str) -> dict[str, list[_Op]]:
    comps: dict[str, list[_Op]] = {}
    current: list[_Op] | None = None
    shapes: dict[str, str] = {}
    for raw in text.splitlines():
        line = raw.rstrip()
        header = _COMP_HEADER.match(line)
        if header and ("->" in line):
            current = comps.setdefault(header.group(1), [])
            continue
        if line.strip() == "}":
            current = None
            continue
        if current is None:
            continue
        parsed = _parse_def(line)
        if parsed:
            current.append(_Op(parsed[0], parsed[1], parsed[2], line))
    return comps


def _local_cost(ops: list[_Op], shapes: dict[str, str]) -> tuple[HloCost, list[tuple[str, float]]]:
    """Cost of one computation's top-level ops + (callee, multiplier) list."""
    cost = HloCost()
    calls: list[tuple[str, float]] = []
    for op in ops:
        numel, rbytes = _shape_info(op.shape_str)
        opcode = op.opcode
        base = opcode.replace("-start", "").replace("-done", "")
        if base in _COLLECTIVES:
            if "-done(" in op.line:
                continue
            b = rbytes // 2 if "-start(" in op.line else rbytes
            cost.collective[base] += b
            cost.collective_ops[base] += 1
            cost.hbm_bytes += rbytes
            continue
        if opcode == "dot":
            # First operand name; newer HLO prints the operand type before
            # the name ("dot(f32[256,256]{1,0} %lhs, ...)"), older prints
            # the bare "%lhs" — skip anything up to the first %.
            lhs_m = re.search(r"dot\([^%)]*%([\w\.\-]+)", op.line)
            contract = 1
            cm = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", op.line)
            if lhs_m and cm and lhs_m.group(1) in shapes:
                lhs_dims = _SHAPE_RE.search(shapes[lhs_m.group(1)])
                if lhs_dims and lhs_dims.group(2):
                    dims = [int(d) for d in lhs_dims.group(2).split(",")]
                    for ci in cm.group(1).split(","):
                        if ci:
                            contract *= dims[int(ci)]
            cost.dot_flops += 2.0 * numel * contract
            cost.hbm_bytes += rbytes
            # operand bytes
            for om in _OPERAND_RE.findall(op.line.split("dot(")[1].split(")")[0]):
                if om in shapes:
                    cost.hbm_bytes += _shape_info(shapes[om])[1]
            continue
        if opcode in ("while",):
            trip = 1
            tm = _TRIP_RE.search(op.line)
            if tm:
                trip = int(tm.group(1))
            for role, cname in re.findall(r"(condition|body)=%?([\w\.\-]+)", op.line):
                calls.append((cname, float(trip)))
            continue
        if opcode in ("fusion", "call", "custom-call", "reduce", "sort", "scatter", "map", "conditional", "select-and-scatter", "reduce-window"):
            for cname in _CALLS_RE.findall(op.line):
                calls.append((cname, 1.0))
            if opcode == "reduce":
                cost.elementwise_flops += numel
            cost.hbm_bytes += rbytes
            paren = op.line.find("(")
            if paren >= 0:
                for om in _OPERAND_RE.findall(op.line[paren:]):
                    if om in shapes:
                        cost.hbm_bytes += _shape_info(shapes[om])[1]
            continue
        if opcode in _ELEMENTWISE:
            cost.elementwise_flops += numel
            continue
        # parameters / constants / tuples / gte / copies: no flops; copies
        # move bytes at top level.
        if opcode in ("copy", "transpose", "reshape", "broadcast", "convert"):
            cost.hbm_bytes += rbytes
    return cost, calls


def analyze_hlo(text: str) -> HloCost:
    comps = _split_computations(text)
    shapes: dict[str, str] = {}
    for ops in comps.values():
        for op in ops:
            shapes[op.name] = op.shape_str
    local: dict[str, tuple[HloCost, list[tuple[str, float]]]] = {
        name: _local_cost(ops, shapes) for name, ops in comps.items()
    }
    # Find entry: computation not called by anyone, or the one named main*.
    called = {c for _, (_, calls) in local.items() for c, _ in calls}
    entry = None
    for name in local:
        if name.startswith("main"):
            entry = name
            break
    if entry is None:
        candidates = [n for n in local if n not in called]
        entry = candidates[0] if candidates else next(iter(local))

    memo: dict[str, HloCost] = {}
    visiting: set[str] = set()

    def total(name: str) -> HloCost:
        if name in memo:
            return memo[name]
        if name in visiting or name not in local:
            return HloCost()
        visiting.add(name)
        cost = HloCost()
        own, calls = local[name]
        cost.add(own)
        for cname, mult in calls:
            cost.add(total(cname), mult)
        visiting.discard(name)
        memo[name] = cost
        return cost

    return total(entry)
