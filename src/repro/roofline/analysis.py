"""Roofline analysis from compiled dry-run artifacts (no hardware needed).

Per (arch x shape x mesh) cell we derive three roofline terms, in seconds,
for TPU v5e hardware constants:

    compute    = device_FLOPs / peak_FLOP/s          (197 TF/s bf16)
    memory     = device_bytes / HBM_bw               (819 GB/s)
    collective = device_collective_bytes / link_bw   (~50 GB/s/link ICI)

``compiled.cost_analysis()`` is evaluated on the post-SPMD per-device
module, so its FLOPs/bytes are per-chip; global figures are ``x chips``.
Collective bytes are not in cost_analysis — :func:`collective_bytes`
parses the compiled HLO and sums the *result* bytes of every all-gather /
all-reduce / reduce-scatter / all-to-all / collective-permute (a consistent
payload upper bound; convention recorded in EXPERIMENTS.md).
"""

from __future__ import annotations

import dataclasses
import re

__all__ = ["HW_V5E", "collective_bytes", "roofline_terms", "model_flops"]

HW_V5E = {
    "peak_flops": 197e12,  # bf16 FLOP/s per chip
    "hbm_bw": 819e9,  # bytes/s per chip
    "ici_bw": 50e9,  # bytes/s per ICI link
}

_DTYPE_BYTES = {
    "pred": 1,
    "s4": 1,
    "u4": 1,
    "s8": 1,
    "u8": 1,
    "s16": 2,
    "u16": 2,
    "bf16": 2,
    "f16": 2,
    "s32": 4,
    "u32": 4,
    "f32": 4,
    "s64": 8,
    "u64": 8,
    "f64": 8,
    "c64": 8,
    "c128": 16,
}

_COLLECTIVES = (
    "all-gather",
    "all-reduce",
    "reduce-scatter",
    "all-to-all",
    "collective-permute",
)

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_OP_RE = re.compile(
    r"=\s+((?:\([^)]*\))|(?:\w+\[[\d,]*\](?:\{[^}]*\})?))\s+"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start|-done)?\("
)


def _shape_bytes(shape_str: str) -> int:
    total = 0
    for dtype, dims in _SHAPE_RE.findall(shape_str):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


def collective_bytes(hlo_text: str) -> dict:
    """Sum result bytes of every collective op, by type.

    Handles both sync ops and async ``-start`` forms (the ``-done`` halves
    carry no payload shape of their own in post-opt HLO and are skipped via
    the tuple-shape heuristic: ``-start`` results are tuples; we count the
    final element group once per op line).
    """
    out = {k: 0 for k in _COLLECTIVES}
    counts = {k: 0 for k in _COLLECTIVES}
    for line in hlo_text.splitlines():
        m = _OP_RE.search(line)
        if not m:
            continue
        shape_str, op = m.group(1), m.group(2)
        if "-done(" in line:
            continue
        b = _shape_bytes(shape_str)
        if "-start(" in line:
            # start-op results are (operand, result[, ...]) tuples; halve to
            # count the payload once.
            b //= 2
        out[op] += b
        counts[op] += 1
    out["total"] = sum(out[k] for k in _COLLECTIVES)
    out["op_counts"] = counts
    return out


def roofline_terms(
    device_flops: float,
    device_bytes: float,
    device_collective_bytes: float,
    hw: dict = HW_V5E,
) -> dict:
    compute = device_flops / hw["peak_flops"]
    memory = device_bytes / hw["hbm_bw"]
    collective = device_collective_bytes / hw["ici_bw"]
    terms = {"compute_s": compute, "memory_s": memory, "collective_s": collective}
    dominant = max(terms, key=lambda k: terms[k])
    terms["dominant"] = dominant
    terms["bound_s"] = terms[dominant]
    return terms


def model_flops(
    active_params: int, tokens: int, kind: str = "train"
) -> float:
    """``6 * N_active * D`` for training; ``2 * N_active * D`` for inference."""
    mult = 6.0 if kind == "train" else 2.0
    return mult * active_params * tokens
