"""Roofline tooling: cost_analysis + HLO collective-bytes parsing."""

from .analysis import HW_V5E, collective_bytes, model_flops, roofline_terms

__all__ = ["HW_V5E", "collective_bytes", "model_flops", "roofline_terms"]
