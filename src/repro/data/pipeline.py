"""Deterministic sharded synthetic-token pipeline.

Production shape without production data: a seeded, host-shardable token
stream with document packing. Every (step, host) pair maps to a disjoint,
reproducible slice of the stream — restart-safe (the checkpoint stores only
the step counter) and elastic-safe (re-sharding by host count is pure
arithmetic).
"""

from __future__ import annotations

import dataclasses
from typing import Iterator

import numpy as np

__all__ = ["DataConfig", "SyntheticTokens", "make_batch", "pack_documents"]


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    num_hosts: int = 1
    host_id: int = 0
    mean_doc_len: int = 512


class SyntheticTokens:
    """Zipf-distributed token documents, packed into fixed-length rows.

    The per-(step, row) RNG key is ``hash(seed, step, global_row)`` so any
    host can regenerate any row — the property fault-tolerant restart and
    elastic re-sharding rely on.
    """

    def __init__(self, cfg: DataConfig):
        if cfg.global_batch % cfg.num_hosts:
            raise ValueError("global_batch must divide evenly across hosts")
        self.cfg = cfg
        self.rows_per_host = cfg.global_batch // cfg.num_hosts

    def _row(self, step: int, global_row: int) -> np.ndarray:
        cfg = self.cfg
        seed = np.uint64(cfg.seed) * np.uint64(1_000_003)
        seed += np.uint64(step) * np.uint64(8_191) + np.uint64(global_row)
        rng = np.random.default_rng(int(seed))
        docs = []
        total = 0
        while total < cfg.seq_len + 1:
            n = int(rng.integers(cfg.mean_doc_len // 2, cfg.mean_doc_len * 2))
            doc = rng.zipf(1.2, size=n) % (cfg.vocab_size - 2) + 2
            docs.append(np.concatenate([[1], doc]))  # BOS=1
            total += n + 1
        return pack_documents(docs, cfg.seq_len + 1)

    def batch(self, step: int) -> dict:
        cfg = self.cfg
        start = self.cfg.host_id * self.rows_per_host
        rows = np.stack(
            [self._row(step, start + r) for r in range(self.rows_per_host)]
        )
        return {
            "tokens": rows[:, :-1].astype(np.int32),
            "labels": rows[:, 1:].astype(np.int32),
        }

    def __iter__(self) -> Iterator[dict]:
        step = 0
        while True:
            yield self.batch(step)
            step += 1


def pack_documents(docs: list[np.ndarray], row_len: int) -> np.ndarray:
    """Concatenate documents and truncate to ``row_len`` (standard packing)."""
    flat = np.concatenate(docs)
    if flat.size < row_len:
        flat = np.pad(flat, (0, row_len - flat.size))
    return flat[:row_len]


def make_batch(cfg: DataConfig, step: int) -> dict:
    """One global batch (all hosts' shards concatenated) — test helper."""
    parts = []
    for host in range(cfg.num_hosts):
        h = dataclasses.replace(cfg, host_id=host)
        parts.append(SyntheticTokens(h).batch(step))
    return {
        k: np.concatenate([p[k] for p in parts], axis=0) for k in parts[0]
    }
