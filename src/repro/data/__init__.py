"""Deterministic sharded data pipeline."""

from .pipeline import DataConfig, SyntheticTokens, make_batch, pack_documents

__all__ = ["DataConfig", "SyntheticTokens", "make_batch", "pack_documents"]
