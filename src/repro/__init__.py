"""repro — RailS (topology-aware all-to-all load balancing) on JAX/TPU.

Subpackages:
  core      — the paper's algorithms (LPT, LP, theorems, rail collectives)
  netsim    — discrete-event rail-fabric simulator + §VI baselines
  models    — architecture zoo (dense/MoE/hybrid/SSM/enc-dec)
  configs   — assigned architecture configs + smoke variants
  parallel  — mesh views, sharding rules, pipeline parallelism
  launch    — production mesh, dry-run, train/serve drivers
  data      — deterministic sharded data pipeline
  optim     — AdamW, schedules, gradient compression
  checkpoint— sharded save/restore with atomic commit
  runtime   — fault tolerance, elastic re-mesh, straggler mitigation
  kernels   — Pallas TPU kernels (flash attention, grouped GEMM, rmsnorm)
  roofline  — compiled-artifact cost/collective analysis
"""

__version__ = "1.0.0"
