"""Traffic matrices and MoE workload generators (paper §IV-A, §VI-A).

The paper describes communication demand at two levels:

* ``D1`` — GPU-to-GPU traffic: shape ``(M, N, M, N)`` where ``D1[d, n, f, m]``
  is bytes from GPU ``(d, n)`` to GPU ``(f, m)``.
* ``D2`` — domain-to-domain traffic: shape ``(M, M)``,
  ``D2[d, f] = sum_{n,m} D1[d, n, f, m]`` (paper eq. 1).

Workload generators mirror Table I of the paper:

==============  ============  =========================
type            token input   gating
==============  ============  =========================
uniform         uniform       uniform
sparse          uniform       Top-K (column sparsity)
sender-skewed   Zipf          uniform
receiver-skewed uniform       Zipf
real workload   uniform       training-trace phases
==============  ============  =========================
"""

from __future__ import annotations

import dataclasses
from typing import Callable

import numpy as np

__all__ = [
    "TrafficMatrix",
    "aggregate_domains",
    "uniform_workload",
    "sparse_topk_workload",
    "sender_skew_workload",
    "receiver_skew_workload",
    "mixtral_trace_workload",
    "default_expert_shard",
    "expert_counts_to_matrix",
    "uniform_sender_counts",
    "moe_gating_traffic",
    "microbatch_stream",
    "bursty_release_times",
    "drifting_gating_stream",
    "drifting_expert_counts",
    "rl_phase_counts",
    "ServeRequest",
    "ServeRound",
    "ServeWorkload",
    "request_arrival_times",
    "serve_workload",
    "WORKLOADS",
]


@dataclasses.dataclass(frozen=True)
class TrafficMatrix:
    """All-to-all demand at GPU and domain granularity.

    Attributes:
      d1: ``(M, N, M, N)`` GPU-to-GPU bytes.
      d2: ``(M, M)`` domain-to-domain bytes (eq. 1 aggregate of ``d1``).
      name: workload tag for reporting.
    """

    d1: np.ndarray
    d2: np.ndarray
    name: str = "custom"

    @property
    def num_domains(self) -> int:
        return self.d1.shape[0]

    @property
    def num_rails(self) -> int:
        return self.d1.shape[1]

    def total_bytes(self) -> float:
        return float(self.d1.sum())

    def domain_send_totals(self) -> np.ndarray:
        """Total egress bytes per source domain: ``sum_f D2[k, f]``."""
        return self.d2.sum(axis=1)

    def domain_recv_totals(self) -> np.ndarray:
        """Total ingress bytes per destination domain: ``sum_k D2[k, f]``."""
        return self.d2.sum(axis=0)

    def validate(self) -> None:
        if self.d1.ndim != 4:
            raise ValueError(f"d1 must be rank-4 (M,N,M,N), got {self.d1.shape}")
        m, n, m2, n2 = self.d1.shape
        if (m, n) != (m2, n2):
            raise ValueError(f"d1 must be (M,N,M,N) symmetric in shape, got {self.d1.shape}")
        if self.d2.shape != (m, m):
            raise ValueError(f"d2 shape {self.d2.shape} != ({m},{m})")
        if np.any(self.d1 < 0):
            raise ValueError("negative traffic")
        if not np.allclose(self.d2, aggregate_domains(self.d1)):
            raise ValueError("d2 is not the domain aggregate of d1 (eq. 1 violated)")


def aggregate_domains(d1: np.ndarray) -> np.ndarray:
    """Paper eq. (1): ``D2[d,f] = sum_{n,m} D1[d,n,f,m]``."""
    return d1.sum(axis=(1, 3))


def _make(d1: np.ndarray, name: str) -> TrafficMatrix:
    tm = TrafficMatrix(d1=d1, d2=aggregate_domains(d1), name=name)
    tm.validate()
    return tm


# ---------------------------------------------------------------------------
# Synthetic workloads (paper §VI-A, Table I)
# ---------------------------------------------------------------------------


def uniform_workload(
    num_domains: int,
    num_rails: int,
    bytes_per_pair: float = 1.0,
    include_self: bool = False,
) -> TrafficMatrix:
    """Every sender GPU sends equal data to every receiver GPU."""
    m, n = num_domains, num_rails
    d1 = np.full((m, n, m, n), bytes_per_pair, dtype=np.float64)
    if not include_self:
        for d in range(m):
            d1[d, :, d, :] = 0.0
    return _make(d1, "uniform")


def sparse_topk_workload(
    num_domains: int,
    num_rails: int,
    sparsity: float,
    top_k: int = 2,
    bytes_per_pair: float = 1.0,
    seed: int = 0,
    concentrate: str = "gpu",
) -> TrafficMatrix:
    """Top-K expert-selection matrix with column-wise sparsity (paper §VI-C).

    ``sparsity`` is the fraction of receiver domains that are *inactive*
    (carry no expert traffic). The surviving active receivers split the total
    demand; each sender routes to ``top_k`` of the active receivers, so higher
    sparsity concentrates proportionally more traffic on fewer domains —
    the hot-expert regime of the paper. ``sparsity=0`` is the fully dense
    Top-K pattern.

    ``concentrate='gpu'`` (default) lands each hot expert's traffic on one
    GPU of the active domain (experts live on specific GPUs — this is what
    creates single-NIC bottlenecks for topology-blind policies);
    ``concentrate='domain'`` spreads it evenly over the domain's GPUs.
    """
    if not 0.0 <= sparsity < 1.0:
        raise ValueError(f"sparsity must be in [0,1), got {sparsity}")
    m, n = num_domains, num_rails
    rng = np.random.default_rng(seed)
    n_active = max(top_k, int(round(m * (1.0 - sparsity))))
    active = rng.choice(m, size=n_active, replace=False)
    expert_gpu = {int(f): int(rng.integers(n)) for f in active}
    # Preserve total demand of the dense-uniform workload so that CCTs are
    # comparable across sparsity levels (the paper normalizes this way).
    total_per_sender = bytes_per_pair * (m - 1) * n * n
    d1 = np.zeros((m, n, m, n), dtype=np.float64)
    for d in range(m):
        choices = [f for f in active if f != d]
        if not choices:
            continue
        targets = rng.choice(choices, size=min(top_k, len(choices)), replace=False)
        per_target = total_per_sender / len(targets)
        for f in targets:
            if concentrate == "gpu":
                # All of the expert's ingress lands on the expert's GPU.
                d1[d, :, f, expert_gpu[int(f)]] += per_target / n
            else:
                d1[d, :, f, :] += per_target / (n * n)
    return _make(d1, f"sparse-{sparsity:g}")


def _zipf_weights(m: int, alpha: float) -> np.ndarray:
    ranks = np.arange(1, m + 1, dtype=np.float64)
    w = ranks ** (-alpha)
    return w / w.sum()


def sender_skew_workload(
    num_domains: int,
    num_rails: int,
    alpha: float = 1.2,
    total_bytes: float | None = None,
    seed: int = 0,
) -> TrafficMatrix:
    """Zipf token input: a few hotspot *sender GPUs* carry most traffic (§VI-D).

    The Zipf is applied at GPU granularity (M*N senders): uneven input makes
    some expert GPUs far busier than their siblings, so policies pinned to
    the source GPU's NIC (ECMP/PLB) develop high sender-side MSE while
    multi-NIC schemes stay balanced (paper Fig. 10b).
    """
    m, n = num_domains, num_rails
    rng = np.random.default_rng(seed)
    weights = _zipf_weights(m * n, alpha)
    rng.shuffle(weights)
    weights = weights.reshape(m, n)
    if total_bytes is None:
        total_bytes = float(m * (m - 1) * n * n)
    d1 = np.zeros((m, n, m, n), dtype=np.float64)
    for d in range(m):
        others = [f for f in range(m) if f != d]
        for g in range(n):
            per_pair = total_bytes * weights[d, g] / (len(others) * n)
            for f in others:
                d1[d, g, f, :] = per_pair / n
    return _make(d1, "sender-skew")


def receiver_skew_workload(
    num_domains: int,
    num_rails: int,
    alpha: float = 1.2,
    total_bytes: float | None = None,
    seed: int = 0,
) -> TrafficMatrix:
    """Zipf gating: many senders target a few hot *expert GPUs* — incast (§VI-E).

    Zipf at GPU granularity: a hot expert lives on one GPU, so its ingress
    concentrates on a single NIC for delivery-pinned policies, while RailS
    sprays across the domain's N rails and forwards intra-domain (Fig. 11c).
    """
    m, n = num_domains, num_rails
    rng = np.random.default_rng(seed)
    weights = _zipf_weights(m * n, alpha)
    rng.shuffle(weights)
    weights = weights.reshape(m, n)
    if total_bytes is None:
        total_bytes = float(m * (m - 1) * n * n)
    d1 = np.zeros((m, n, m, n), dtype=np.float64)
    for f in range(m):
        others = [d for d in range(m) if d != f]
        for gd in range(n):
            per_pair = total_bytes * weights[f, gd] / (len(others) * n)
            for d in others:
                d1[d, :, f, gd] = per_pair / n
    return _make(d1, "receiver-skew")


# ---------------------------------------------------------------------------
# Mixtral-style training trace (paper §VI-F)
# ---------------------------------------------------------------------------

#: Per-expert payload (bytes) by training phase, from the paper's §VI-F
#: description: ~100 MB at Start growing to 256 MB at Stable.
MIXTRAL_PHASE_BYTES = {
    "start": 100e6,
    "early": 160e6,
    "mid": 208e6,
    "stable": 256e6,
}


def mixtral_trace_workload(
    num_domains: int,
    num_rails: int,
    phase: str = "stable",
    mode: str = "dense",
    num_experts: int = 8,
    top_k: int = 2,
    seed: int = 0,
    popularity_alpha: float = 0.8,
    noise_sigma: float = 1.0,
    expert_shard: np.ndarray | None = None,
) -> TrafficMatrix:
    """Replay of the Mixtral 8x7B trace pattern (paper Figs. 12–13).

    ``mode='dense'``: each expert's payload is spread over the expert
    domain's GPUs (parallel exchange). ``mode='sparse'``: each expert's
    payload is aggregated on a single GPU of the domain (the paper's sparse
    setup — this is what creates single-NIC receiver bottlenecks for
    topology-blind policies).

    Training-based gating is not uniform (paper Fig. 2d): experts have a
    Zipf(``popularity_alpha``) popularity profile and per-(sender, expert)
    token counts carry lognormal(``noise_sigma``) variability. Totals are
    renormalized so every phase moves the same bytes as the paper's trace.
    """
    if phase not in MIXTRAL_PHASE_BYTES:
        raise ValueError(f"unknown phase {phase!r}; choose {sorted(MIXTRAL_PHASE_BYTES)}")
    if mode not in ("dense", "sparse"):
        raise ValueError(f"mode must be dense|sparse, got {mode!r}")
    m, n = num_domains, num_rails
    rng = np.random.default_rng(seed)
    # Experts default to the round-robin layout (``expert_shard=None``);
    # token input stays uniform while the gating popularity and per-pair
    # variability skew the matrix. An explicit expert→shard map re-lays-out
    # the experts (the `repro.placement` co-optimization knob).
    expert_domain = (
        np.arange(num_experts) % m
        if expert_shard is None
        else np.asarray(expert_shard, dtype=np.int64)
    )
    if expert_domain.shape != (num_experts,):
        raise ValueError(f"expert_shard must be ({num_experts},)")
    popularity = _zipf_weights(num_experts, popularity_alpha)
    rng.shuffle(popularity)
    total_bytes = MIXTRAL_PHASE_BYTES[phase] * num_experts * (top_k / num_experts)
    d1 = np.zeros((m, n, m, n), dtype=np.float64)
    for e in range(num_experts):
        f = expert_domain[e]
        senders = [d for d in range(m) if d != f]
        expert_total = total_bytes * popularity[e]
        noise = rng.lognormal(0.0, noise_sigma, size=(len(senders), n))
        noise /= noise.sum()
        if mode == "dense":
            for i, d in enumerate(senders):
                for g in range(n):
                    d1[d, g, f, :] += expert_total * noise[i, g] / n
        else:
            gpu = int(rng.integers(n))  # aggregate on one GPU of the domain
            for i, d in enumerate(senders):
                for g in range(n):
                    d1[d, g, f, gpu] += expert_total * noise[i, g]
    return _make(d1, f"mixtral-{mode}-{phase}")


# ---------------------------------------------------------------------------
# From MoE gating decisions (the framework's own traffic source)
# ---------------------------------------------------------------------------


def default_expert_shard(num_experts: int, num_domains: int) -> np.ndarray:
    """The repo's historical layout: experts round-robin over domains."""
    return np.arange(num_experts, dtype=np.int64) % num_domains


def expert_counts_to_matrix(
    counts, num_domains: int, expert_shard: np.ndarray | None = None
) -> np.ndarray:
    """Expert token counts -> ``(M, M)`` shard-to-shard gating counts.

    ``counts`` is either a flat ``(E,)`` per-expert vector (uniform
    senders: every other domain contributes equally to each expert
    domain's ingress — the historical convention) or a full ``(M, E)``
    per-(shard, expert) matrix recorded from a real gate (``counts[s, e]``
    = tokens shard ``s`` routes to expert ``e``). ``expert_shard`` is the
    explicit expert→shard placement map; ``None`` keeps the default
    round-robin layout bit-identically. Intra-domain traffic stays on
    NVLink (zero diagonal) either way. Shared by the training-loop hook
    (:class:`~repro.sched.online.GatingFeedbackHook`), the serving trace
    replay (:func:`~repro.sched.serving.simulate_decode_trace`) and the
    placement subsystem (:mod:`repro.placement`) so a placement change
    lands in exactly one spot.
    """
    counts = np.asarray(counts, dtype=np.float64)
    m = num_domains
    if counts.ndim == 2:
        if counts.shape[0] != m:
            raise ValueError(
                f"per-(shard, expert) counts must have {m} rows, got {counts.shape}"
            )
        if expert_shard is None:
            expert_shard = default_expert_shard(counts.shape[1], m)
        expert_shard = np.asarray(expert_shard, dtype=np.int64)
        if expert_shard.shape != (counts.shape[1],):
            raise ValueError(
                f"expert_shard must be ({counts.shape[1]},), got {expert_shard.shape}"
            )
        c2 = np.zeros((m, m))
        # c2[s, f] += counts[s, e] for every expert e placed on shard f.
        np.add.at(c2.T, expert_shard, counts.T)
        np.fill_diagonal(c2, 0.0)
        return c2
    counts = counts.ravel()
    if expert_shard is None:
        expert_shard = np.arange(counts.size) % m
    expert_shard = np.asarray(expert_shard, dtype=np.int64)
    domain_tokens = np.zeros(m)
    np.add.at(domain_tokens, expert_shard, counts)
    c2 = np.tile(domain_tokens / max(m - 1, 1), (m, 1))
    np.fill_diagonal(c2, 0.0)
    return c2


def uniform_sender_counts(
    expert_tokens: np.ndarray,
    expert_shard: np.ndarray,
    num_domains: int,
) -> np.ndarray:
    """Expand per-expert totals into ``(M, E)`` per-(shard, expert) counts.

    The uniform-sender convention behind the flat-counts path of
    :func:`expert_counts_to_matrix`: every domain except the expert's own
    shard contributes ``T_e / (M - 1)`` tokens (the host's tokens stay on
    NVLink, so its fabric contribution is zero). Round-tripping through
    the ``(M, E)`` path therefore reproduces the flat path's ``(M, M)``
    matrix up to float reassociation.
    """
    expert_tokens = np.asarray(expert_tokens, dtype=np.float64).ravel()
    expert_shard = np.asarray(expert_shard, dtype=np.int64)
    m = num_domains
    counts = np.tile(expert_tokens / max(m - 1, 1), (m, 1))
    counts[expert_shard, np.arange(expert_tokens.size)] = 0.0
    return counts


def moe_gating_traffic(
    counts: np.ndarray,
    bytes_per_token: float,
    num_rails: int,
) -> TrafficMatrix:
    """Build a TrafficMatrix from MoE gating counts.

    Args:
      counts: ``(M, M)`` token counts — ``counts[k, f]`` tokens routed from
        expert-parallel shard ``k`` to shard ``f`` (gating output; the paper's
        "known traffic matrix" premise).
      bytes_per_token: payload bytes per routed token (``d_model * itemsize``).
      num_rails: rails per domain (spread evenly over GPU pairs).
    """
    counts = np.asarray(counts, dtype=np.float64)
    if counts.ndim != 2 or counts.shape[0] != counts.shape[1]:
        raise ValueError(f"counts must be (M,M), got {counts.shape}")
    m = counts.shape[0]
    n = num_rails
    d2 = counts * bytes_per_token
    d1 = np.broadcast_to(d2[:, None, :, None], (m, n, m, n)) / (n * n)
    return _make(np.ascontiguousarray(d1), "moe-gating")


# ---------------------------------------------------------------------------
# Streaming workloads (the online regime of `repro.sched`)
# ---------------------------------------------------------------------------


def microbatch_stream(
    num_domains: int,
    num_rails: int,
    num_microbatches: int,
    bytes_per_pair: float = 1.0,
    noise_sigma: float = 0.75,
    seed: int = 0,
) -> list[TrafficMatrix]:
    """One iteration's all-to-all split into per-micro-batch rounds.

    The iteration total matches ``uniform_workload(bytes_per_pair *
    num_microbatches)``, but each micro-batch carries lognormal
    (``noise_sigma``) per-(sender GPU, receiver GPU) variability — the
    within-iteration imbalance an offline planner never sees because it
    averages out by the time the full matrix is on the table.
    """
    if num_microbatches < 1:
        raise ValueError("need at least one micro-batch")
    m, n = num_domains, num_rails
    rng = np.random.default_rng(seed)
    out = []
    for _ in range(num_microbatches):
        noise = rng.lognormal(0.0, noise_sigma, size=(m, n, m, n))
        noise /= noise.mean()
        d1 = bytes_per_pair * noise
        for d in range(m):
            d1[d, :, d, :] = 0.0
        out.append(_make(d1, "microbatch"))
    return out


def bursty_release_times(
    num_rounds: int,
    mean_gap: float,
    burstiness: float = 1.0,
    seed: int = 0,
) -> np.ndarray:
    """Release times with gamma-distributed gaps of CoV ``burstiness``.

    ``burstiness=0`` is a deterministic micro-batch cadence; ``1.0`` is
    Poisson-like; larger values cluster releases into bursts separated by
    idle stretches (the incast-prone regime). First release is at t=0.
    """
    if num_rounds < 1:
        raise ValueError("need at least one round")
    if mean_gap < 0 or burstiness < 0:
        raise ValueError("mean_gap and burstiness must be >= 0")
    if num_rounds == 1:
        return np.zeros(1)
    rng = np.random.default_rng(seed)
    if burstiness == 0 or mean_gap == 0:
        gaps = np.full(num_rounds - 1, mean_gap)
    else:
        shape = 1.0 / burstiness**2
        gaps = rng.gamma(shape, mean_gap / shape, size=num_rounds - 1)
    return np.concatenate([[0.0], np.cumsum(gaps)])


def drifting_gating_stream(
    num_domains: int,
    num_rails: int,
    num_rounds: int,
    tokens_per_round: float,
    bytes_per_token: float = 1.0,
    num_experts: int = 8,
    popularity_alpha: float = 0.8,
    drift: float = 0.15,
    seed: int = 0,
    expert_shard: np.ndarray | None = None,
    return_counts: bool = False,
):
    """Gating counts that random-walk between rounds (paper Fig. 2d drift).

    Expert popularity starts Zipf(``popularity_alpha``) and drifts in log
    space by ``drift`` per round — adjacent rounds are similar (which is
    what makes routing replay a usable forecast) while distant rounds can
    look completely different. Experts sit on ``expert_shard`` (default:
    round-robin over domains, bit-identical to the historical output);
    token input stays uniform across senders.

    ``return_counts=True`` additionally returns the per-round ``(M, E)``
    per-(shard, expert) count matrices and the expert→shard map — the raw
    gating view the placement subsystem re-optimizes — as
    ``(tms, counts_rounds, expert_shard)``.
    """
    if num_rounds < 1:
        raise ValueError("need at least one round")
    m, n = num_domains, num_rails
    rng = np.random.default_rng(seed)
    expert_domain = (
        np.arange(num_experts) % m
        if expert_shard is None
        else np.asarray(expert_shard, dtype=np.int64)
    )
    log_pop = np.log(_zipf_weights(num_experts, popularity_alpha))
    rng.shuffle(log_pop)
    out = []
    counts_rounds: list[np.ndarray] = []
    for _ in range(num_rounds):
        popularity = np.exp(log_pop)
        popularity /= popularity.sum()
        expert_tokens = popularity * tokens_per_round
        counts = expert_counts_to_matrix(expert_tokens, m, expert_domain)
        tm = moe_gating_traffic(counts, bytes_per_token, n)
        out.append(TrafficMatrix(d1=tm.d1, d2=tm.d2, name="drifting-gating"))
        if return_counts:
            counts_rounds.append(
                uniform_sender_counts(expert_tokens, expert_domain, m)
            )
        log_pop = log_pop + rng.normal(0.0, drift, size=num_experts)
    if return_counts:
        return out, counts_rounds, expert_domain.copy()
    return out


def drifting_expert_counts(
    num_shards: int,
    num_experts: int,
    num_rounds: int,
    tokens_per_round: float,
    popularity_alpha: float = 0.8,
    drift: float = 0.15,
    sender_alpha: float = 0.0,
    seed: int = 0,
) -> tuple[list[np.ndarray], np.ndarray]:
    """Per-(shard, expert) gating counts random-walking between rounds.

    The placement-native sibling of :func:`drifting_gating_stream`: instead
    of pre-aggregated traffic matrices it emits the raw ``(M, E)`` count
    matrices (``counts[s, e]`` = tokens shard ``s`` routes to expert ``e``)
    plus the default round-robin expert→shard map, leaving the d2
    derivation to whatever placement is in force
    (:func:`expert_counts_to_matrix` / :class:`repro.placement.Placement`).

    ``sender_alpha > 0`` skews token input across shards with a
    Zipf(``sender_alpha``) sender profile — the regime where moving an
    expert *toward* its heaviest sender pays on both egress and ingress.
    Tokens a shard routes to its own experts are included (they stay on
    NVLink; the d2 derivation drops the diagonal).
    """
    if num_rounds < 1:
        raise ValueError("need at least one round")
    m = num_shards
    rng = np.random.default_rng(seed)
    log_pop = np.log(_zipf_weights(num_experts, popularity_alpha))
    rng.shuffle(log_pop)
    if sender_alpha > 0:
        sender_w = _zipf_weights(m, sender_alpha)
        rng.shuffle(sender_w)
    else:
        sender_w = np.full(m, 1.0 / m)
    counts_rounds: list[np.ndarray] = []
    for _ in range(num_rounds):
        popularity = np.exp(log_pop)
        popularity /= popularity.sum()
        counts_rounds.append(tokens_per_round * np.outer(sender_w, popularity))
        log_pop = log_pop + rng.normal(0.0, drift, size=num_experts)
    return counts_rounds, default_expert_shard(num_experts, m)


def rl_phase_counts(
    num_shards: int,
    num_experts: int,
    num_rounds: int,
    tokens_per_round: float,
    rollout_len: int = 8,
    train_len: int = 8,
    rollout_alpha: float = 1.4,
    train_alpha: float = 0.6,
    drift: float = 0.05,
    sender_alpha: float = 0.0,
    seed: int = 0,
    return_phases: bool = False,
):
    """RL-style rollout/train phase alternation (ReLibra, PAPERS.md).

    RLHF-style training interleaves *rollout* (autoregressive generation —
    gating follows the policy's decode distribution, typically peaky) with
    *train* (optimizer steps over the collected batch — gating follows the
    much flatter training distribution). The routing distribution therefore
    **lurches** at every phase boundary instead of drifting smoothly — the
    regime where routing-replay forecasts go stale instantly and a serving
    control plane must absorb step changes in demand shape.

    Each phase keeps its *own* persistent expert-popularity random walk:
    within a phase, adjacent rounds drift gently (``drift`` per round, like
    :func:`drifting_expert_counts`); at a boundary the generator switches
    to the other phase's walk — two independently-shuffled Zipf profiles
    (``rollout_alpha`` peaky, ``train_alpha`` flat) — so the count
    distribution jumps. Emits ``(counts_rounds, expert_shard)`` in the
    placement-native per-(shard, expert) form; ``return_phases=True``
    appends the per-round phase labels (``"rollout"`` / ``"train"``).
    """
    if num_rounds < 1:
        raise ValueError("need at least one round")
    if rollout_len < 1 or train_len < 1:
        raise ValueError("phase lengths must be >= 1")
    m = num_shards
    rng = np.random.default_rng(seed)
    log_pop = {
        "rollout": np.log(_zipf_weights(num_experts, rollout_alpha)),
        "train": np.log(_zipf_weights(num_experts, train_alpha)),
    }
    for phase in ("rollout", "train"):
        rng.shuffle(log_pop[phase])
    if sender_alpha > 0:
        sender_w = _zipf_weights(m, sender_alpha)
        rng.shuffle(sender_w)
    else:
        sender_w = np.full(m, 1.0 / m)
    counts_rounds: list[np.ndarray] = []
    phases: list[str] = []
    period = rollout_len + train_len
    for r in range(num_rounds):
        phase = "rollout" if (r % period) < rollout_len else "train"
        lp = log_pop[phase]
        popularity = np.exp(lp)
        popularity /= popularity.sum()
        counts_rounds.append(tokens_per_round * np.outer(sender_w, popularity))
        phases.append(phase)
        log_pop[phase] = lp + rng.normal(0.0, drift, size=num_experts)
    shard = default_expert_shard(num_experts, m)
    if return_phases:
        return counts_rounds, shard, phases
    return counts_rounds, shard


# ---------------------------------------------------------------------------
# Serving workloads (the request-level regime of `repro.serve`)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ServeRequest:
    """One inference request: a prefill burst plus autoregressive decode.

    ``arrival`` is the instant the request reaches the serving stack — the
    origin every latency metric (TTFT, sojourn) is measured from.
    ``home_domain`` is the expert-parallel shard hosting the request's
    activations (its tokens enter the fabric from that domain's NICs).
    """

    req_id: int
    arrival: float
    home_domain: int
    prefill_tokens: int
    decode_rounds: int


@dataclasses.dataclass(frozen=True)
class ServeRound:
    """One fabric round of a request: its prefill or one decode step.

    ``step`` is 0 for the prefill round, 1..decode_rounds for decode
    steps. ``release`` is when the round's all-to-all hits the fabric.
    """

    release: float
    req_id: int
    kind: str  # "prefill" | "decode"
    step: int
    tm: TrafficMatrix


@dataclasses.dataclass
class ServeWorkload:
    """A request stream lowered to release-timed all-to-all rounds.

    ``rounds`` is sorted by release time, so after
    ``run_streaming_collective`` the streaming ``round_id`` equals the
    index into this list (the driver relies on that to map completions
    back to requests).
    """

    requests: list[ServeRequest]
    rounds: list[ServeRound]
    num_domains: int
    num_rails: int

    def shifted(self, delta: float) -> "ServeWorkload":
        """The same workload translated ``delta`` seconds later in time.

        Latency metrics are release-relative, so a shifted workload must
        report identical TTFT/sojourn statistics — the property the tests
        pin down.
        """
        return ServeWorkload(
            requests=[
                dataclasses.replace(r, arrival=r.arrival + delta)
                for r in self.requests
            ],
            rounds=[
                dataclasses.replace(r, release=r.release + delta)
                for r in self.rounds
            ],
            num_domains=self.num_domains,
            num_rails=self.num_rails,
        )


def request_arrival_times(
    num_requests: int,
    mean_gap: float,
    process: str = "poisson",
    burstiness: float = 3.0,
    diurnal_depth: float = 0.8,
    diurnal_periods: float = 2.0,
    seed: int = 0,
) -> np.ndarray:
    """Request arrival instants for the three serving regimes.

    * ``poisson`` — memoryless arrivals (exponential gaps, the open-loop
      load-test default).
    * ``bursty`` — gamma gaps with CoV ``burstiness`` (>1 clusters
      requests into bursts separated by idle stretches — the incast-prone
      regime).
    * ``diurnal`` — a nonhomogeneous Poisson process whose rate swings
      sinusoidally by ``±diurnal_depth`` around the mean over
      ``diurnal_periods`` full cycles across the trace (peak-hour /
      trough-hour load shape). Implemented by time-warping a homogeneous
      process through the inverse cumulative rate.

    First arrival is at t=0; gaps average ``mean_gap`` in every regime.
    """
    if num_requests < 1:
        raise ValueError("need at least one request")
    if mean_gap < 0:
        raise ValueError("mean_gap must be >= 0")
    rng = np.random.default_rng(seed)
    if process == "poisson":
        gaps = rng.exponential(mean_gap, size=num_requests - 1)
        return np.concatenate([[0.0], np.cumsum(gaps)])
    if process == "bursty":
        return bursty_release_times(num_requests, mean_gap, burstiness, seed=seed)
    if process == "diurnal":
        if not 0.0 <= diurnal_depth < 1.0:
            raise ValueError("diurnal_depth must be in [0, 1)")
        gaps = rng.exponential(mean_gap, size=num_requests - 1)
        u = np.concatenate([[0.0], np.cumsum(gaps)])  # homogeneous arrivals
        horizon = max(float(u[-1]), mean_gap)
        if horizon <= 0.0:  # mean_gap=0: everything arrives at once
            return u
        # rate(t) = 1 + depth*sin(2π·periods·t/horizon); warp through the
        # inverse of Λ(t) = ∫rate so arrivals bunch where the rate peaks.
        grid = np.linspace(0.0, horizon, 4096)
        omega = 2.0 * np.pi * diurnal_periods / horizon
        lam = grid + (diurnal_depth / omega) * (1.0 - np.cos(omega * grid))
        return np.interp(u, lam, grid)
    raise ValueError(f"unknown arrival process {process!r}; "
                     "choose poisson|bursty|diurnal")


def serve_workload(
    num_domains: int,
    num_rails: int,
    num_requests: int,
    mean_gap: float,
    process: str = "poisson",
    prefill_tokens: int = 128,
    decode_rounds: int = 4,
    decode_tokens: int = 8,
    decode_gap: float = 1e-3,
    bytes_per_token: float = 16 * 2**10,
    num_experts: int = 8,
    top_k: int = 2,
    popularity_alpha: float = 0.8,
    burstiness: float = 3.0,
    seed: int = 0,
    expert_shard: np.ndarray | None = None,
) -> ServeWorkload:
    """Request-level serving workload: arrivals → expert-routed rounds.

    Each request lands on a ``home_domain`` (round-robin over domains) and
    emits one *prefill* round at its arrival (``prefill_tokens`` routed
    through the gate) followed by ``decode_rounds`` *decode* rounds at a
    fixed ``decode_gap`` cadence (the per-token compute step), each
    carrying ``decode_tokens`` routed tokens — small and latency-critical,
    the regime where tail sojourn (p99 TTFT) replaces makespan as the
    figure of merit. Tokens choose ``top_k`` of ``num_experts`` experts
    drawn from a Zipf(``popularity_alpha``) popularity profile; experts
    sit round-robin on domains (the `GatingFeedbackHook` convention), and
    traffic to the home domain's own experts stays on NVLink.
    """
    if num_domains < 2:
        raise ValueError("serving fabric needs at least 2 domains")
    m, n = num_domains, num_rails
    rng = np.random.default_rng(seed)
    arrivals = request_arrival_times(
        num_requests, mean_gap, process, burstiness=burstiness, seed=seed
    )
    popularity = _zipf_weights(num_experts, popularity_alpha)
    rng.shuffle(popularity)
    expert_domain = (
        np.arange(num_experts) % m
        if expert_shard is None
        else np.asarray(expert_shard, dtype=np.int64)
    )
    if expert_domain.shape != (num_experts,):
        raise ValueError(f"expert_shard must be ({num_experts},)")

    def round_tm(home: int, tokens: int, kind: str) -> TrafficMatrix:
        # Every token routes to top_k experts (drawn by popularity; the
        # rare same-expert repeat just doubles that expert's share, which
        # is fine for traffic purposes). Tokens landing on the home
        # domain's own experts stay on NVLink — drop them from the matrix
        # so the Theorem-2 bound only counts fabric bytes.
        draws = rng.choice(num_experts, size=(tokens, top_k), p=popularity)
        counts = np.zeros((m, m))
        np.add.at(counts[home], expert_domain[draws].ravel(), 1.0)
        counts[home, home] = 0.0
        tm = moe_gating_traffic(counts, bytes_per_token, n)
        return TrafficMatrix(d1=tm.d1, d2=tm.d2, name=f"serve-{kind}")

    requests: list[ServeRequest] = []
    rounds: list[ServeRound] = []
    for i in range(num_requests):
        home = i % m
        arrival = float(arrivals[i])
        requests.append(
            ServeRequest(
                req_id=i,
                arrival=arrival,
                home_domain=home,
                prefill_tokens=prefill_tokens,
                decode_rounds=decode_rounds,
            )
        )
        rounds.append(
            ServeRound(arrival, i, "prefill", 0, round_tm(home, prefill_tokens, "prefill"))
        )
        for k in range(1, decode_rounds + 1):
            rounds.append(
                ServeRound(
                    arrival + k * decode_gap, i, "decode", k,
                    round_tm(home, decode_tokens, "decode"),
                )
            )
    rounds.sort(key=lambda r: r.release)
    return ServeWorkload(
        requests=requests, rounds=rounds, num_domains=m, num_rails=n
    )


WORKLOADS: dict[str, Callable[..., TrafficMatrix]] = {
    "uniform": uniform_workload,
    "sparse": sparse_topk_workload,
    "sender_skew": sender_skew_workload,
    "receiver_skew": receiver_skew_workload,
    "mixtral": mixtral_trace_workload,
}
