"""RailS-scheduled all-to-all collectives in JAX (shard_map + ppermute).

TPU adaptation of the paper's split→LPT→spray pipeline (DESIGN.md §3):

* A **rail** is an independent collective stream: a chain of ring
  ``ppermute`` steps over the expert-parallel mesh axis. Different rails are
  data-independent op chains, so XLA's async collective scheduler can overlap
  them (and, on hardware, different ring offsets occupy different ICI hops).
* An **atomic chunk** is a fixed token-block slice of one peer's payload
  (``tokens_per_chunk × d_model``), the unit the LPT planner assigns.
* The **LPT plan** is computed on host (SPMD requires every device to run
  the same ppermute schedule). Weights come either from a uniform model
  (static shapes — the Theorem-3 ``P*=1/N`` regime) or from the MoE gating
  count matrix (the paper's "known traffic matrix" premise); the per-offset
  cost is the bottleneck sender of that ring step.

Three transports, all numerically identical to ``jax.lax.all_to_all``:

* :func:`dense_all_to_all` — monolithic baseline (one XLA all-to-all).
* :func:`rails_all_to_all` — N-rail LPT-scheduled ring decomposition.
* :func:`spray_all_to_all` — continuous Theorem-3 spray: the feature dim is
  split into N equal rail slices, one all-to-all per rail (``P*=1/N``).

Layout convention (standard MoE dispatch): per-device input ``x`` has shape
``(E, T, D)`` — row ``e`` is the block destined for the device at index ``e``
of ``axis_name``; output row ``e`` is the block received from device ``e``.
"""

from __future__ import annotations

import dataclasses
from functools import partial

import numpy as np

import jax
import jax.numpy as jnp

from .lpt import lpt_schedule

__all__ = [
    "RailSchedule",
    "build_rail_schedule",
    "dense_all_to_all",
    "ring_all_to_all",
    "rails_all_to_all",
    "spray_all_to_all",
    "rails_dispatch",
]


@dataclasses.dataclass(frozen=True)
class RailSchedule:
    """Static chunk→rail plan for one all-to-all round.

    ``entries[r]`` lists ``(offset, chunk)`` pairs assigned to rail ``r``;
    ``offset`` ∈ [1, E) is the ring shift, ``chunk`` ∈ [0, C) the token block.
    """

    num_devices: int
    num_rails: int
    num_chunks: int
    entries: tuple[tuple[tuple[int, int], ...], ...]
    loads: tuple[float, ...]
    mse: float
    w_max: float

    def num_transfers(self) -> int:
        return sum(len(e) for e in self.entries)

    def bound_holds(self) -> bool:
        return self.mse <= self.w_max**2 + 1e-9


def build_rail_schedule(
    num_devices: int,
    num_rails: int,
    num_chunks: int = 1,
    counts: np.ndarray | None = None,
    bytes_per_token: float = 1.0,
) -> RailSchedule:
    """LPT-plan the ``(E-1) * C`` atomic transfers onto N rails.

    Args:
      num_devices: E, size of the expert-parallel axis.
      num_rails: N parallel rail streams.
      num_chunks: C token-block chunks per peer payload (flow splitting).
      counts: optional ``(E, E)`` token-count matrix (``counts[i, j]`` tokens
        from device i to device j). Per-offset weight is the *bottleneck*
        sender of that ring step: ``w_s = max_i counts[i, (i+s) % E]`` —
        every device participates in a ppermute step, so the step costs its
        heaviest payload. ``None`` means the uniform/static-shape model.
      bytes_per_token: scales counts into bytes for reporting.
    """
    e, n, c = num_devices, num_rails, num_chunks
    if e < 2:
        raise ValueError("need at least 2 devices for an all-to-all")
    if n < 1 or c < 1:
        raise ValueError("num_rails and num_chunks must be >= 1")
    offsets = list(range(1, e))
    flows = [(s, k) for s in offsets for k in range(c)]
    if counts is not None:
        counts = np.asarray(counts, dtype=np.float64)
        if counts.shape != (e, e):
            raise ValueError(f"counts must be ({e},{e}), got {counts.shape}")
        idx = np.arange(e)
        w_offset = {
            s: float(counts[idx, (idx + s) % e].max()) * bytes_per_token
            for s in offsets
        }
    else:
        w_offset = {s: 1.0 * bytes_per_token for s in offsets}
    weights = np.array([w_offset[s] / c for (s, k) in flows])
    res = lpt_schedule(weights, n)
    entries: list[list[tuple[int, int]]] = [[] for _ in range(n)]
    for flow, rail in zip(flows, res.assignment):
        entries[int(rail)].append(flow)
    return RailSchedule(
        num_devices=e,
        num_rails=n,
        num_chunks=c,
        entries=tuple(tuple(es) for es in entries),
        loads=tuple(float(v) for v in res.loads),
        mse=float(res.mse),
        w_max=float(weights.max()) if weights.size else 0.0,
    )


# ---------------------------------------------------------------------------
# Transports (to be called inside shard_map)
# ---------------------------------------------------------------------------


def dense_all_to_all(x: jnp.ndarray, axis_name: str) -> jnp.ndarray:
    """Baseline: one monolithic XLA all-to-all (tiled, dim-0 blocks)."""
    return jax.lax.all_to_all(x, axis_name, split_axis=0, concat_axis=0, tiled=True)


def _self_block(x: jnp.ndarray, axis_name: str) -> tuple[jnp.ndarray, jnp.ndarray]:
    e = x.shape[0]
    j = jax.lax.axis_index(axis_name)
    out = jnp.zeros_like(x)
    mine = jax.lax.dynamic_index_in_dim(x, j, axis=0, keepdims=True)
    out = jax.lax.dynamic_update_slice_in_dim(out, mine, j, axis=0)
    return out, j


def ring_all_to_all(x: jnp.ndarray, axis_name: str) -> jnp.ndarray:
    """Single-stream ring decomposition: E-1 sequential ppermute steps.

    Equivalent to ``dense_all_to_all``; exists as the 1-rail reference of the
    rail decomposition (and as the paper's "single NIC path" strawman).
    """
    e = x.shape[0]
    out, j = _self_block(x, axis_name)
    for s in range(1, e):
        perm = [(i, (i + s) % e) for i in range(e)]
        send = jnp.take(x, (j + s) % e, axis=0)
        recv = jax.lax.ppermute(send[None], axis_name, perm)
        out = jax.lax.dynamic_update_slice_in_dim(out, recv, (j - s) % e, axis=0)
    return out


def rails_all_to_all(
    x: jnp.ndarray,
    axis_name: str,
    schedule: RailSchedule,
) -> jnp.ndarray:
    """N-rail LPT-scheduled all-to-all (the paper's technique, on TPU).

    Each rail executes its LPT-assigned ``(offset, chunk)`` transfers as an
    independent chain of ppermutes on disjoint token-block chunks; the N
    chains have no data dependencies between them, so they overlap. The
    self-block never leaves the device (Theorem 1: intra-domain traffic does
    not cross rails).
    """
    e, t, *_ = x.shape
    if schedule.num_devices != e:
        raise ValueError(
            f"schedule built for E={schedule.num_devices}, payload has E={e}"
        )
    c = schedule.num_chunks
    if t % c != 0:
        raise ValueError(f"tokens per peer ({t}) not divisible by chunks ({c})")
    tc = t // c
    out, j = _self_block(x, axis_name)

    rail_outputs = []
    for rail_entries in schedule.entries:
        # Each rail contributes a partial output holding only its chunks.
        partial_out = jnp.zeros_like(x)
        for s, k in rail_entries:
            perm = [(i, (i + s) % e) for i in range(e)]
            blk = jnp.take(x, (j + s) % e, axis=0)  # (T, D...)
            chunk = jax.lax.dynamic_slice_in_dim(blk, k * tc, tc, axis=0)
            recv = jax.lax.ppermute(chunk[None], axis_name, perm)  # (1, tc, D...)
            src = (j - s) % e
            partial_out = jax.lax.dynamic_update_slice(
                partial_out,
                recv.astype(partial_out.dtype),
                (src, k * tc) + (0,) * (x.ndim - 2),
            )
        rail_outputs.append(partial_out)
    for po in rail_outputs:
        out = out + po
    return out


def spray_all_to_all(
    x: jnp.ndarray,
    axis_name: str,
    num_rails: int,
) -> jnp.ndarray:
    """Continuous Theorem-3 spray: ``P* = 1/N`` along the feature dimension.

    The trailing dim is cut into N equal rail slices and each slice moves in
    its own all-to-all — every (src, dst) flow is divided exactly 1/N per
    rail, the closed-form optimum for arbitrarily divisible traffic. The N
    collectives are independent and overlap.
    """
    d = x.shape[-1]
    if d % num_rails != 0:
        raise ValueError(f"feature dim {d} not divisible by num_rails {num_rails}")
    slices = jnp.split(x, num_rails, axis=-1)
    moved = [
        jax.lax.all_to_all(s, axis_name, split_axis=0, concat_axis=0, tiled=True)
        for s in slices
    ]
    return jnp.concatenate(moved, axis=-1)


def rails_dispatch(
    x: jnp.ndarray,
    axis_name: str,
    mode: str = "dense",
    num_rails: int = 4,
    num_chunks: int = 1,
    counts: np.ndarray | None = None,
) -> jnp.ndarray:
    """Uniform entry point used by the MoE layer's dispatch/combine.

    Modes: ``dense`` (baseline single all-to-all), ``ring`` (1-stream ring),
    ``rails`` (LPT-scheduled N-rail ring — the paper), ``spray``
    (continuous 1/N feature spray — Theorem 3's closed form).
    """
    if mode == "dense":
        return dense_all_to_all(x, axis_name)
    if mode == "ring":
        return ring_all_to_all(x, axis_name)
    if mode == "rails":
        sched = build_rail_schedule(
            num_devices=x.shape[0],
            num_rails=num_rails,
            num_chunks=num_chunks,
            counts=counts,
        )
        return rails_all_to_all(x, axis_name, sched)
    if mode == "spray":
        return spray_all_to_all(x, axis_name, num_rails)
    raise ValueError(f"unknown dispatch mode {mode!r}")
