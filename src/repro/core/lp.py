"""Min–max completion-time LP (paper §IV-C/§IV-D, eq. 24) + simplex solver.

The paper reformulates all-to-all completion time as::

    min_{P, t}  t
    s.t.  sum_f D2[k,f] * P[k,f,n] <= t      (send load,  ∀k,n)
          sum_k D2[k,f] * P[k,f,n] <= t      (recv load,  ∀f,n)
          sum_n P[k,f,n] = 1                 (∀k,f)
          P >= 0

Theorem 3 gives the closed-form optimum ``P* = 1/N`` with::

    t* = max( max_k sum_f D2[k,f],  max_f sum_k D2[k,f] ) / N

We implement (a) :func:`solve_minmax_lp` — a dense two-phase simplex over the
exact LP (used for validation and for *heterogeneous-rail* extensions the
closed form does not cover), and (b) :func:`closed_form_opt` — Theorem 3.
Tests assert both agree on rail topologies.
"""

from __future__ import annotations

import dataclasses

import numpy as np

__all__ = [
    "LpSolution",
    "simplex",
    "solve_minmax_lp",
    "closed_form_opt",
    "optimal_completion_time",
    "loads_from_allocation",
]

_EPS = 1e-9


@dataclasses.dataclass(frozen=True)
class LpSolution:
    x: np.ndarray
    objective: float
    status: str  # "optimal" | "infeasible" | "unbounded"
    iterations: int


def simplex(
    c: np.ndarray,
    a_ub: np.ndarray | None = None,
    b_ub: np.ndarray | None = None,
    a_eq: np.ndarray | None = None,
    b_eq: np.ndarray | None = None,
    max_iter: int = 50_000,
) -> LpSolution:
    """Two-phase tableau simplex for ``min c@x  s.t. A_ub x<=b_ub, A_eq x=b_eq, x>=0``.

    Dense, Bland's-rule pivoting (no cycling), suitable for the small/medium
    LPs arising from eq. 24 (hundreds of variables). Not a production LP
    code — a verification oracle for the closed form.
    """
    c = np.asarray(c, dtype=np.float64)
    n = c.size
    rows: list[np.ndarray] = []
    rhs: list[float] = []
    n_ub = 0
    if a_ub is not None:
        a_ub = np.asarray(a_ub, dtype=np.float64)
        b_ub = np.asarray(b_ub, dtype=np.float64)
        n_ub = a_ub.shape[0]
        for i in range(n_ub):
            rows.append(a_ub[i])
            rhs.append(float(b_ub[i]))
    n_eq = 0
    if a_eq is not None:
        a_eq = np.asarray(a_eq, dtype=np.float64)
        b_eq = np.asarray(b_eq, dtype=np.float64)
        n_eq = a_eq.shape[0]
        for i in range(n_eq):
            rows.append(a_eq[i])
            rhs.append(float(b_eq[i]))
    m = len(rows)
    a = np.vstack(rows) if rows else np.zeros((0, n))
    b = np.asarray(rhs, dtype=np.float64)
    # Normalize to b >= 0 (flip rows; flips slack sign for ub rows).
    slack_sign = np.ones(m)
    for i in range(m):
        if b[i] < 0:
            a[i] = -a[i]
            b[i] = -b[i]
            slack_sign[i] = -1.0
    # Columns: [x (n)] [slack (n_ub)] [artificial (m)]
    n_slack = n_ub
    total = n + n_slack + m
    tab = np.zeros((m, total))
    tab[:, :n] = a
    for i in range(n_ub):
        tab[i, n + i] = slack_sign[i]
    for i in range(m):
        tab[i, n + n_slack + i] = 1.0
    basis = [n + n_slack + i for i in range(m)]
    # Rows whose slack sign is +1 can start with the slack basic instead of
    # the artificial (cheaper phase 1).
    for i in range(n_ub):
        if slack_sign[i] > 0:
            basis[i] = n + i
            tab[i, n + n_slack + i] = 0.0

    b_col = b.copy()
    it_count = 0

    def pivot(tab, b_col, basis, row, col):
        piv = tab[row, col]
        tab[row] /= piv
        b_col[row] /= piv
        for r in range(tab.shape[0]):
            if r != row and abs(tab[r, col]) > _EPS:
                factor = tab[r, col]
                tab[r] -= factor * tab[row]
                b_col[r] -= factor * b_col[row]
        basis[row] = col

    def run_phase(obj_row, allowed_cols):
        nonlocal it_count
        # Reduced costs for current basis.
        z = obj_row.copy()
        for r, bv in enumerate(basis):
            if abs(obj_row[bv]) > _EPS:
                z -= obj_row[bv] * tab[r]
        obj_val = -sum(obj_row[bv] * b_col[r] for r, bv in enumerate(basis))
        while it_count < max_iter:
            it_count += 1
            # Bland's rule: smallest-index entering column with z < -eps.
            enter = -1
            for j in allowed_cols:
                if z[j] < -1e-8:
                    enter = j
                    break
            if enter < 0:
                return "optimal"
            # Ratio test (Bland: smallest basis index on ties).
            best_ratio, leave = np.inf, -1
            for r in range(m):
                if tab[r, enter] > _EPS:
                    ratio = b_col[r] / tab[r, enter]
                    if ratio < best_ratio - _EPS or (
                        abs(ratio - best_ratio) <= _EPS
                        and (leave < 0 or basis[r] < basis[leave])
                    ):
                        best_ratio, leave = ratio, r
            if leave < 0:
                return "unbounded"
            pivot(tab, b_col, basis, leave, enter)
            # Recompute reduced costs (dense refresh keeps it simple/robust).
            z = obj_row.copy()
            for r, bv in enumerate(basis):
                if abs(obj_row[bv]) > _EPS:
                    z -= obj_row[bv] * tab[r]
        return "maxiter"

    # Phase 1: minimize sum of artificials.
    art_cols = list(range(n + n_slack, total))
    phase1_obj = np.zeros(total)
    for j in art_cols:
        phase1_obj[j] = 1.0
    status = run_phase(phase1_obj, list(range(total)))
    art_val = sum(b_col[r] for r, bv in enumerate(basis) if bv >= n + n_slack)
    if status != "optimal" or art_val > 1e-6:
        return LpSolution(np.zeros(n), np.inf, "infeasible", it_count)
    # Drive remaining artificial basics out (degenerate rows).
    for r in range(m):
        if basis[r] >= n + n_slack:
            for j in range(n + n_slack):
                if abs(tab[r, j]) > 1e-7:
                    pivot(tab, b_col, basis, r, j)
                    break
    # Phase 2: original objective, artificial columns barred.
    phase2_obj = np.zeros(total)
    phase2_obj[:n] = c
    status = run_phase(phase2_obj, list(range(n + n_slack)))
    x = np.zeros(total)
    for r, bv in enumerate(basis):
        x[bv] = b_col[r]
    obj = float(c @ x[:n])
    return LpSolution(x[:n], obj, "optimal" if status == "optimal" else status, it_count)


# ---------------------------------------------------------------------------
# Eq. 24 construction and closed form
# ---------------------------------------------------------------------------


def solve_minmax_lp(
    d2: np.ndarray,
    num_rails: int,
    rail_rates: np.ndarray | None = None,
) -> tuple[np.ndarray, float, LpSolution]:
    """Solve eq. 24 exactly. Returns ``(P, t_star, raw_solution)``.

    ``rail_rates`` optionally scales per-rail capacity (heterogeneous rails —
    a beyond-paper extension; the paper assumes all rails at rate R2). Loads
    on rail n are divided by ``rail_rates[n]`` inside the constraints, so
    ``t`` is in time units of a unit-rate rail.
    """
    d2 = np.asarray(d2, dtype=np.float64)
    m = d2.shape[0]
    n = num_rails
    if rail_rates is None:
        rail_rates = np.ones(n)
    rail_rates = np.asarray(rail_rates, dtype=np.float64)
    nvar = m * m * n + 1  # P flattened (k,f,n) + t
    t_idx = nvar - 1

    def pidx(k, f, r):
        return (k * m + f) * n + r

    a_ub = np.zeros((2 * m * n, nvar))
    b_ub = np.zeros(2 * m * n)
    row = 0
    for k in range(m):
        for r in range(n):
            for f in range(m):
                a_ub[row, pidx(k, f, r)] = d2[k, f] / rail_rates[r]
            a_ub[row, t_idx] = -1.0
            row += 1
    for f in range(m):
        for r in range(n):
            for k in range(m):
                a_ub[row, pidx(k, f, r)] = d2[k, f] / rail_rates[r]
            a_ub[row, t_idx] = -1.0
            row += 1
    a_eq = np.zeros((m * m, nvar))
    b_eq = np.ones(m * m)
    for k in range(m):
        for f in range(m):
            for r in range(n):
                a_eq[k * m + f, pidx(k, f, r)] = 1.0
    c = np.zeros(nvar)
    c[t_idx] = 1.0
    sol = simplex(c, a_ub, b_ub, a_eq, b_eq)
    p = sol.x[: m * m * n].reshape(m, m, n)
    return p, sol.objective, sol


def closed_form_opt(d2: np.ndarray, num_rails: int) -> tuple[np.ndarray, float]:
    """Theorem 3: ``P* = 1/N`` and ``t* = max(row sums, col sums) / N``."""
    d2 = np.asarray(d2, dtype=np.float64)
    m = d2.shape[0]
    p = np.full((m, m, num_rails), 1.0 / num_rails)
    t_star = max(d2.sum(axis=1).max(), d2.sum(axis=0).max()) / num_rails
    return p, float(t_star)


def optimal_completion_time(d2: np.ndarray, num_rails: int, rate: float) -> float:
    """Theorem 2 with P* plugged in: ``T* = t*/R2`` in seconds."""
    _, t_star = closed_form_opt(d2, num_rails)
    return t_star / rate


def loads_from_allocation(d2: np.ndarray, p: np.ndarray) -> tuple[np.ndarray, np.ndarray]:
    """Paper eqs. (4)–(5): send loads ``S[k,n]`` and recv loads ``R[f,n]``."""
    d2 = np.asarray(d2, dtype=np.float64)
    s = np.einsum("kf,kfn->kn", d2, p)
    r = np.einsum("kf,kfn->fn", d2, p)
    return s, r
