"""Flow splitting and the chunk→rail spray plan (paper §V).

The paper's pipeline is *split → LPT-schedule → spray*:

1. **Flow splitting** (§V-A "Flow Splitting and Atomicity"): large messages
   are cut into fixed-size atomic chunks (32 KB default on the wire; here the
   chunk is a configurable byte size, or a token block for MoE dispatch).
   Splitting directly controls ``w_max`` and hence the Theorem-4 bound.
2. **LPT scheduling** (§V-B): each sender independently assigns its atomic
   chunks to the N rails with the LPT greedy rule over ``LoadState[N]``.
3. **Spraying**: the transport layer transmits each chunk on its assigned
   rail (here: the rail stream of :mod:`repro.core.rails_all_to_all`, or a
   netsim NIC).

This module is host-side planning shared by the netsim and the JAX
collective. Everything is deterministic.
"""

from __future__ import annotations

import dataclasses

import numpy as np

from .lpt import LptResult, load_mse, lpt_schedule, random_schedule, round_robin_schedule

__all__ = [
    "AtomicFlow",
    "SprayPlan",
    "split_message",
    "split_sizes_vector",
    "split_traffic_row",
    "build_spray_plan",
    "build_all_plans",
    "plan_quality",
]


@dataclasses.dataclass(frozen=True)
class AtomicFlow:
    """One indivisible chunk: ``src_domain -> dst_domain`` of ``size`` bytes.

    ``src_gpu`` tags the originating GPU for Algorithm-2 tie-breaking;
    ``flow_id`` identifies the parent (pre-split) message; ``seq`` orders the
    chunks of one parent for reassembly.
    """

    src_domain: int
    dst_domain: int
    size: float
    src_gpu: int = 0
    flow_id: int = 0
    seq: int = 0


@dataclasses.dataclass
class SprayPlan:
    """Per-sender plan: chunk → rail assignment plus predicted loads."""

    src_domain: int
    flows: list[AtomicFlow]
    assignment: np.ndarray  # (F,) rail index per flow
    loads: np.ndarray  # (N,) predicted per-rail send bytes
    mse: float
    w_max: float
    policy: str

    def rail_chunks(self, rail: int) -> list[AtomicFlow]:
        return [f for f, a in zip(self.flows, self.assignment) if a == rail]

    def bound_holds(self) -> bool:
        """Theorem 4: MSE <= w_max^2 (only guaranteed for the LPT policy)."""
        return bool(self.mse <= self.w_max**2 + 1e-9)


def split_message(
    size: float,
    chunk_bytes: float,
    src_domain: int,
    dst_domain: int,
    src_gpu: int = 0,
    flow_id: int = 0,
) -> list[AtomicFlow]:
    """Split one message into atomic chunks of at most ``chunk_bytes``."""
    if size <= 0:
        return []
    if chunk_bytes <= 0:
        raise ValueError("chunk_bytes must be positive")
    n_full, rem = divmod(size, chunk_bytes)
    chunks = [chunk_bytes] * int(n_full)
    if rem > 1e-12:
        chunks.append(rem)
    return [
        AtomicFlow(src_domain, dst_domain, s, src_gpu=src_gpu, flow_id=flow_id, seq=i)
        for i, s in enumerate(chunks)
    ]


def split_sizes_vector(
    sizes: np.ndarray, chunk_bytes: float
) -> tuple[np.ndarray, np.ndarray]:
    """Vectorized :func:`split_message` over an array of message sizes.

    Returns ``(counts, chunk_sizes)``: ``counts[i]`` chunks for message ``i``
    (0 for empty or sub-remainder messages), and the flat per-chunk size
    array in message order. Chunk sizes match the scalar splitter exactly:
    ``counts[i] - 1`` full chunks of ``chunk_bytes`` followed by the
    remainder iff it exceeds the 1e-12 dust threshold. This is the
    struct-of-arrays entry of the split → LPT → spray pipeline: 10⁶-chunk
    collectives never materialize per-chunk Python objects.
    """
    if chunk_bytes <= 0:
        raise ValueError("chunk_bytes must be positive")
    sizes = np.asarray(sizes, dtype=np.float64)
    if np.any(sizes < 0):
        raise ValueError("message sizes must be non-negative")
    n_full, rem = np.divmod(sizes, chunk_bytes)
    has_rem = rem > 1e-12
    counts = n_full.astype(np.int64) + has_rem
    total = int(counts.sum())
    out = np.full(total, float(chunk_bytes))
    if total:
        ends = np.cumsum(counts)
        out[ends[has_rem] - 1] = rem[has_rem]
    return counts, out


def split_traffic_row(
    d1_row: np.ndarray,
    src_domain: int,
    chunk_bytes: float,
) -> list[AtomicFlow]:
    """Split all of one domain's egress (``D1[src]``, shape ``(N, M, N)``).

    Each GPU-to-GPU demand becomes its own message before chunking, matching
    Algorithm 2's "receive atomic flows from each local GPU".
    """
    n_src, m, n_dst = d1_row.shape
    flows: list[AtomicFlow] = []
    fid = 0
    for g in range(n_src):
        for f in range(m):
            if f == src_domain:
                continue  # intra-domain traffic stays on NVLink (Thm 1)
            for gd in range(n_dst):
                size = float(d1_row[g, f, gd])
                if size > 0:
                    flows.extend(
                        split_message(size, chunk_bytes, src_domain, f, g, fid)
                    )
                    fid += 1
    return flows


def build_spray_plan(
    flows: list[AtomicFlow],
    num_rails: int,
    src_domain: int,
    policy: str = "lpt",
    seed: int = 0,
    rail_mask=None,
) -> SprayPlan:
    """Assign atomic flows to rails under the chosen policy.

    Policies: ``lpt`` (the paper), ``round_robin`` (static), ``random``
    (REPS-style spray). All are *local* — they use only the sender's own
    flows, which Theorem 3 shows is sufficient for global optimality.

    ``rail_mask`` (bool ``(N,)``, LPT only) restricts assignment to the
    surviving rails after a fail-stop — loads keep full-N indexing with
    dead rails pinned at zero.
    """
    weights = np.array([f.size for f in flows], dtype=np.float64)
    src_ids = np.array([f.src_gpu for f in flows], dtype=np.int64)
    if policy == "lpt":
        res: LptResult = lpt_schedule(
            weights, num_rails, source_ids=src_ids, rail_mask=rail_mask
        )
    elif policy == "round_robin":
        res = round_robin_schedule(weights, num_rails)
    elif policy == "random":
        res = random_schedule(weights, num_rails, seed=seed)
    else:
        raise ValueError(f"unknown policy {policy!r}")
    w_max = float(weights.max()) if weights.size else 0.0
    return SprayPlan(
        src_domain=src_domain,
        flows=flows,
        assignment=res.assignment,
        loads=res.loads,
        mse=res.mse,
        w_max=w_max,
        policy=policy,
    )


def build_all_plans(
    d1: np.ndarray,
    chunk_bytes: float,
    policy: str = "lpt",
    seed: int = 0,
    rail_mask=None,
) -> list[SprayPlan]:
    """Fully distributed planning: one independent SprayPlan per sender domain.

    This is the paper's core operational claim (Theorem 3): each node
    schedules *only its own* sending load, with no cross-node coordination,
    yet the union of plans is globally near-optimal. ``rail_mask``
    restricts every sender's LPT to the surviving rails (the N−k
    post-failure planning regime).
    """
    m = d1.shape[0]
    n = d1.shape[1]
    plans = []
    for k in range(m):
        flows = split_traffic_row(d1[k], k, chunk_bytes)
        plans.append(
            build_spray_plan(
                flows, n, k, policy=policy, seed=seed + k, rail_mask=rail_mask
            )
        )
    return plans


def plan_quality(plans: list[SprayPlan], num_rails: int) -> dict:
    """Aggregate send/recv rail loads implied by a set of per-sender plans.

    Returns global max send/recv load (the Theorem-2 objective), per-domain
    MSEs, and the receive-side loads reconstructed from the one-to-one rail
    mapping (chunk on rail n arrives on the destination's NIC n — §IV-E).
    """
    m = len(plans)
    send = np.zeros((m, num_rails))
    recv = np.zeros((m, num_rails))
    for plan in plans:
        send[plan.src_domain] = plan.loads
        for f, a in zip(plan.flows, plan.assignment):
            recv[f.dst_domain, a] += f.size
    return {
        "send_loads": send,
        "recv_loads": recv,
        "max_load": float(max(send.max(), recv.max())),
        "send_mse": [load_mse(send[k]) for k in range(m)],
        "recv_mse": [load_mse(recv[k]) for k in range(m)],
    }
