"""RailS core: the paper's contribution as composable JAX/numpy modules.

Layers:
  traffic   — D1/D2 traffic matrices + MoE workload generators (Table I)
  lpt       — LPT schedulers (host numpy + device jax.lax), Algorithm 2
  lp        — min–max completion-time LP (eq. 24) + simplex + Theorem-3 form
  theorems  — executable Theorems 1–4 used as test/benchmark invariants
  plan      — flow splitting + per-sender chunk→rail spray plans (§V)
  rails_all_to_all — the JAX collective: N-rail LPT-scheduled all-to-all
"""

from .lpt import (
    LptResult,
    LptState,
    load_mse,
    lpt_schedule,
    lpt_schedule_jax,
    lpt_schedule_reference,
    normalized_load_mse,
    random_schedule,
    round_robin_schedule,
)
from .lp import (
    LpSolution,
    closed_form_opt,
    loads_from_allocation,
    optimal_completion_time,
    simplex,
    solve_minmax_lp,
)
from .plan import (
    AtomicFlow,
    SprayPlan,
    build_all_plans,
    build_spray_plan,
    plan_quality,
    split_message,
    split_traffic_row,
)
from .rails_all_to_all import (
    RailSchedule,
    build_rail_schedule,
    dense_all_to_all,
    rails_all_to_all,
    rails_dispatch,
    ring_all_to_all,
    spray_all_to_all,
)
from .theorems import (
    lpt_makespan_bound,
    theorem1_capacity,
    theorem1_maxflow_check,
    theorem2_lower_bound,
    theorem2_optimal_time,
    theorem3_check_symmetry,
    theorem4_mse_bound,
)
from .traffic import (
    WORKLOADS,
    TrafficMatrix,
    aggregate_domains,
    mixtral_trace_workload,
    moe_gating_traffic,
    receiver_skew_workload,
    sender_skew_workload,
    sparse_topk_workload,
    uniform_workload,
)

__all__ = [k for k in dir() if not k.startswith("_")]
