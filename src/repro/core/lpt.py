"""LPT (Longest Processing Time first) schedulers — paper §IV-F, Algorithm 2.

Two interchangeable implementations:

* :func:`lpt_schedule` — host/numpy, a line-by-line transcription of
  Algorithm 2 (sort descending, break ties by source id, greedily assign to
  the least-loaded rail, maintain ``LoadState[N]``).
* :func:`lpt_schedule_jax` — device version in pure ``jax.lax`` (sort +
  ``lax.scan`` over flows with an argmin inner step) so the scheduler can be
  jitted into a training step. Produces identical assignments to the host
  version for identical tie-breaking keys.

Both return the assignment vector, the final per-rail loads, and the load
MSE against the uniform target (paper eq. 6 / Algorithm 2 step 6).
"""

from __future__ import annotations

import dataclasses

import numpy as np

import jax
import jax.numpy as jnp

__all__ = [
    "LptResult",
    "lpt_schedule",
    "lpt_schedule_jax",
    "round_robin_schedule",
    "random_schedule",
    "load_mse",
    "normalized_load_mse",
]


@dataclasses.dataclass(frozen=True)
class LptResult:
    """Outcome of a scheduling pass.

    Attributes:
      assignment: ``(F,)`` int — rail index per flow (original flow order).
      loads: ``(N,)`` float — final per-rail cumulative load (LoadState).
      order: ``(F,)`` int — the descending-weight processing order used.
      mse: mean squared error of ``loads`` vs the uniform target (eq. 6).
    """

    assignment: np.ndarray
    loads: np.ndarray
    order: np.ndarray
    mse: float


def load_mse(loads: np.ndarray, target: np.ndarray | float | None = None) -> float:
    """Paper eq. (6): ``MSE = (1/N) * sum_j (L_j - T_opt)^2``."""
    loads = np.asarray(loads, dtype=np.float64)
    if target is None:
        target = loads.mean()
    return float(np.mean((loads - np.asarray(target, dtype=np.float64)) ** 2))


def normalized_load_mse(loads: np.ndarray) -> float:
    """MSE normalized to [0, 1]: 0 = perfectly uniform (paper §VI-A metric).

    Normalizes by the worst case where the entire load sits on one rail.
    """
    loads = np.asarray(loads, dtype=np.float64)
    total = loads.sum()
    n = loads.size
    if total <= 0:
        return 0.0
    worst = np.zeros(n)
    worst[0] = total
    denom = load_mse(worst, total / n)
    return float(load_mse(loads) / denom) if denom > 0 else 0.0


def lpt_schedule(
    weights: np.ndarray,
    num_rails: int,
    source_ids: np.ndarray | None = None,
    initial_loads: np.ndarray | None = None,
) -> LptResult:
    """Algorithm 2: LPT assignment of atomic flows to rails.

    Args:
      weights: ``(F,)`` flow sizes (bytes).
      num_rails: N, the number of parallel rails / lanes.
      source_ids: optional ``(F,)`` GPU ids used for tie-breaking (Alg. 2
        step "Break ties by GPU index"); defaults to the flow index.
      initial_loads: optional ``(N,)`` starting LoadState (default zeros —
        the state is reset before each all-to-all round, §V-B).
    """
    weights = np.asarray(weights, dtype=np.float64)
    if weights.ndim != 1:
        raise ValueError(f"weights must be rank-1, got {weights.shape}")
    if np.any(weights < 0):
        raise ValueError("flow weights must be non-negative")
    f = weights.size
    if source_ids is None:
        source_ids = np.arange(f)
    source_ids = np.asarray(source_ids)
    if source_ids.shape != (f,):
        raise ValueError("source_ids must match weights shape")
    loads = (
        np.zeros(num_rails, dtype=np.float64)
        if initial_loads is None
        else np.asarray(initial_loads, dtype=np.float64).copy()
    )
    if loads.shape != (num_rails,):
        raise ValueError("initial_loads must be (num_rails,)")

    # Step 2: sort by descending weight, ties by source GPU index.
    order = np.lexsort((source_ids, -weights))
    assignment = np.empty(f, dtype=np.int64)
    # Step 3: iterative allocation to the currently least-loaded rail.
    for i in order:
        j = int(np.argmin(loads))  # ties -> lowest rail index (np.argmin)
        assignment[i] = j
        loads[j] += weights[i]
    return LptResult(
        assignment=assignment,
        loads=loads,
        order=order,
        mse=load_mse(loads),
    )


def _lpt_scan(weights_sorted: jnp.ndarray, initial_loads: jnp.ndarray):
    """Greedy least-loaded assignment over pre-sorted weights via lax.scan."""

    def step(loads, w):
        j = jnp.argmin(loads)
        loads = loads.at[j].add(w)
        return loads, j

    return jax.lax.scan(step, initial_loads, weights_sorted)


def lpt_schedule_jax(
    weights: jnp.ndarray,
    num_rails: int,
    initial_loads: jnp.ndarray | None = None,
):
    """Device LPT: jit-friendly Algorithm 2 on a ``jax.lax`` substrate.

    Args:
      weights: ``(F,)`` flow sizes (any float dtype; promoted to f32).
      num_rails: static N.
      initial_loads: optional ``(N,)`` starting LoadState.

    Returns:
      ``(assignment, loads, mse)`` — assignment is in original flow order.
    """
    weights = jnp.asarray(weights, dtype=jnp.float32)
    f = weights.shape[0]
    if initial_loads is None:
        initial_loads = jnp.zeros((num_rails,), dtype=jnp.float32)
    # Descending sort; jnp.argsort is stable, so equal weights keep index
    # order — matching the host tie-break (source_ids == arange).
    order = jnp.argsort(-weights, stable=True)
    loads, assignment_sorted = _lpt_scan(weights[order], initial_loads)
    # Scatter assignments back to original flow order.
    assignment = jnp.zeros((f,), dtype=jnp.int32).at[order].set(
        assignment_sorted.astype(jnp.int32)
    )
    mse = jnp.mean((loads - jnp.mean(loads)) ** 2)
    return assignment, loads, mse


def round_robin_schedule(weights: np.ndarray, num_rails: int) -> LptResult:
    """Topology-blind baseline: flow i -> rail i mod N (static hashing)."""
    weights = np.asarray(weights, dtype=np.float64)
    f = weights.size
    assignment = np.arange(f, dtype=np.int64) % num_rails
    loads = np.zeros(num_rails, dtype=np.float64)
    np.add.at(loads, assignment, weights)
    return LptResult(
        assignment=assignment, loads=loads, order=np.arange(f), mse=load_mse(loads)
    )


def random_schedule(weights: np.ndarray, num_rails: int, seed: int = 0) -> LptResult:
    """REPS-style baseline: uniform random spraying of chunks over rails."""
    weights = np.asarray(weights, dtype=np.float64)
    f = weights.size
    rng = np.random.default_rng(seed)
    assignment = rng.integers(0, num_rails, size=f)
    loads = np.zeros(num_rails, dtype=np.float64)
    np.add.at(loads, assignment, weights)
    return LptResult(
        assignment=assignment, loads=loads, order=np.arange(f), mse=load_mse(loads)
    )
