"""LPT (Longest Processing Time first) schedulers — paper §IV-F, Algorithm 2.

Three interchangeable implementations:

* :func:`lpt_schedule` — host fast path: a heap-based O(F log N) greedy
  with a closed-form round-robin shortcut for runs of equal-weight chunks
  over a uniform LoadState (the common case — :func:`repro.core.plan.
  split_message` cuts messages into equal chunks). Bit-identical
  assignments and loads to the reference below.
* :func:`lpt_schedule_reference` — host/numpy, a line-by-line transcription
  of Algorithm 2 (sort descending, break ties by source id, greedily assign
  to the least-loaded rail via ``argmin``, maintain ``LoadState[N]``).
  O(F·N); kept as the parity oracle for the fast path.
* :func:`lpt_schedule_jax` — device version in pure ``jax.lax`` (sort +
  ``lax.scan`` over flows with an argmin inner step, unrolled to amortize
  per-flow scan overhead) so the scheduler can be jitted into a training
  step. ``assume_uniform=True`` swaps the scan for a pre-sorted
  round-robin + ``segment_sum`` batched assignment — exact when all chunks
  share one size and the initial LoadState is uniform (no per-flow scan at
  all). Produces identical assignments to the host version for identical
  tie-breaking keys.

:class:`LptState` is the incremental form: a persistent LoadState whose
heap survives across re-planning windows, so online schedulers extend a
plan in O(window · log N) instead of re-sorting the full backlog.

:func:`hier_lpt_schedule` is the two-level form for hierarchical
(multi-pod) fabrics: level 1 is the flat per-domain rail LPT unchanged —
Theorem 3 still wants every NIC balanced — and level 2 re-runs LPT *per
destination pod* over the scarce inter-pod wan lanes. Flat LPT balances
bytes per rail summed over all destinations; nothing controls how each
rail's bytes split across destination pods, so the static ``lane = rail
mod L`` spray can overload one wan lane while another idles. The second
level restores the Theorem-3 symmetry argument one tier up: each source
domain locally balancing its per-pod egress over L lanes makes the pod's
aggregate per-lane load uniform.

All return the assignment vector, the final per-rail loads, and the load
MSE against the uniform target (paper eq. 6 / Algorithm 2 step 6).
"""

from __future__ import annotations

import dataclasses
import heapq

import numpy as np

import jax
import jax.numpy as jnp

__all__ = [
    "LptResult",
    "LptState",
    "HierLptResult",
    "lpt_schedule",
    "lpt_schedule_reference",
    "lpt_schedule_jax",
    "hier_lpt_schedule",
    "round_robin_schedule",
    "random_schedule",
    "load_mse",
    "normalized_load_mse",
]


@dataclasses.dataclass(frozen=True)
class LptResult:
    """Outcome of a scheduling pass.

    Attributes:
      assignment: ``(F,)`` int — rail index per flow (original flow order).
      loads: ``(N,)`` float — final per-rail cumulative load (LoadState).
      order: ``(F,)`` int — the descending-weight processing order used.
      mse: mean squared error of ``loads`` vs the uniform target (eq. 6).
    """

    assignment: np.ndarray
    loads: np.ndarray
    order: np.ndarray
    mse: float


def load_mse(loads: np.ndarray, target: np.ndarray | float | None = None) -> float:
    """Paper eq. (6): ``MSE = (1/N) * sum_j (L_j - T_opt)^2``."""
    loads = np.asarray(loads, dtype=np.float64)
    if target is None:
        target = loads.mean()
    return float(np.mean((loads - np.asarray(target, dtype=np.float64)) ** 2))


def normalized_load_mse(loads: np.ndarray) -> float:
    """MSE normalized to [0, 1]: 0 = perfectly uniform (paper §VI-A metric).

    Normalizes by the worst case where the entire load sits on one rail.
    """
    loads = np.asarray(loads, dtype=np.float64)
    total = loads.sum()
    n = loads.size
    if total <= 0:
        return 0.0
    worst = np.zeros(n)
    worst[0] = total
    denom = load_mse(worst, total / n)
    return float(load_mse(loads) / denom) if denom > 0 else 0.0


def _validate(
    weights: np.ndarray,
    num_rails: int,
    source_ids: np.ndarray | None,
    initial_loads: np.ndarray | None,
) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
    weights = np.asarray(weights, dtype=np.float64)
    if weights.ndim != 1:
        raise ValueError(f"weights must be rank-1, got {weights.shape}")
    if np.any(weights < 0):
        raise ValueError("flow weights must be non-negative")
    f = weights.size
    if source_ids is not None:
        source_ids = np.asarray(source_ids)
        if source_ids.shape != (f,):
            raise ValueError("source_ids must match weights shape")
    loads = (
        np.zeros(num_rails, dtype=np.float64)
        if initial_loads is None
        else np.asarray(initial_loads, dtype=np.float64).copy()
    )
    if loads.shape != (num_rails,):
        raise ValueError("initial_loads must be (num_rails,)")
    return weights, source_ids, loads


def _sort_order(weights: np.ndarray, source_ids: np.ndarray | None) -> np.ndarray:
    """Descending-weight order, ties by source GPU index (Alg. 2 step 2).

    With default tie-break ids (the flow index) a single stable argsort
    replaces the two-key lexsort — same order, roughly half the sort cost.
    """
    if source_ids is None:
        return np.argsort(-weights, kind="stable")
    return np.lexsort((source_ids, -weights))


def _assign_sorted(loads: np.ndarray, weights_sorted: np.ndarray) -> np.ndarray:
    """LPT-assign pre-sorted (descending) weights onto ``loads`` in place.

    Hybrid of two exact strategies, both reproducing the reference
    ``argmin`` greedy bit-for-bit (ties go to the lowest rail index):

    * while the LoadState is uniform, a leading run of equal weights is a
      pure round-robin — assigned closed-form, O(run) with O(run/N) float
      adds (repeated addition, to match the reference's accumulation
      exactly);
    * everything after the first non-uniformity goes through a single
      (load, rail) min-heap — O(remaining · log N).
    """
    f = weights_sorted.size
    n = loads.size
    assignment = np.empty(f, dtype=np.int64)
    pos = 0
    neg = None  # ascending view for run-boundary searches, built lazily
    # Phase A: closed-form round-robin over equal-weight runs while the
    # LoadState stays uniform.
    while pos < f and n > 0 and (loads == loads[0]).all():
        if neg is None:
            neg = -weights_sorted
        w = weights_sorted[pos]
        end = int(np.searchsorted(neg, -w, side="right"))
        k = end - pos
        assignment[pos:end] = np.arange(k, dtype=np.int64) % n
        # Repeated addition (not k*w) so the accumulated floats match the
        # reference's one-add-per-flow arithmetic bit-for-bit —
        # ``np.add.accumulate`` materializes exactly the left-to-right
        # partial sums, without a Python loop per lap.
        q, rem = divmod(k, n)
        steps = q + (1 if rem else 0)
        acc = np.empty(steps + 1)
        acc[0] = loads[0]
        acc[1:] = w
        np.add.accumulate(acc, out=acc)
        if rem:
            loads[:rem] = acc[q + 1]
        loads[rem:] = acc[q]
        pos = end
    if pos >= f:
        return assignment
    # Phase B: heap greedy for the remainder.
    heap = [(float(loads[j]), j) for j in range(n)]
    heapq.heapify(heap)
    heapreplace = heapq.heapreplace
    out = assignment[pos:]
    for i, w in enumerate(weights_sorted[pos:].tolist()):
        load, j = heap[0]
        out[i] = j
        heapreplace(heap, (load + w, j))
    for load, j in heap:
        loads[j] = load
    return assignment


def _check_rail_mask(rail_mask, num_rails: int) -> np.ndarray:
    """Validate a survivor mask: bool ``(N,)`` with at least one rail alive."""
    mask = np.asarray(rail_mask, dtype=bool)
    if mask.shape != (num_rails,):
        raise ValueError(f"rail_mask must be ({num_rails},), got {mask.shape}")
    if not mask.any():
        raise ValueError("rail_mask leaves no rail alive — nothing to plan over")
    return mask


def lpt_schedule(
    weights: np.ndarray,
    num_rails: int,
    source_ids: np.ndarray | None = None,
    initial_loads: np.ndarray | None = None,
    rail_mask: np.ndarray | None = None,
) -> LptResult:
    """Algorithm 2, fast path: O(F log F + F log N) LPT assignment.

    Bit-identical to :func:`lpt_schedule_reference` (same assignments,
    same accumulated loads) — the reference is the naive O(F·N) transcript
    kept for parity testing.

    Args:
      weights: ``(F,)`` flow sizes (bytes).
      num_rails: N, the number of parallel rails / lanes.
      source_ids: optional ``(F,)`` GPU ids used for tie-breaking (Alg. 2
        step "Break ties by GPU index"); defaults to the flow index.
      initial_loads: optional ``(N,)`` starting LoadState (default zeros —
        the state is reset before each all-to-all round, §V-B).
      rail_mask: optional bool ``(N,)`` survivor mask — False rails are
        fail-stopped and receive nothing; the plan runs over the compacted
        N−k alive set (the degraded Theorem-2 regime) and assignments map
        back to original rail indices. Dead rails' loads are untouched.
        The MSE is over *alive* rails only — a dead rail is not load
        imbalance.
    """
    weights, source_ids, loads = _validate(weights, num_rails, source_ids, initial_loads)
    if rail_mask is not None:
        mask = _check_rail_mask(rail_mask, num_rails)
        if not mask.all():
            alive = np.flatnonzero(mask)
            sub = lpt_schedule(
                weights,
                alive.size,
                source_ids=source_ids,
                initial_loads=loads[alive],
            )
            loads[alive] = sub.loads
            return LptResult(
                assignment=alive[sub.assignment],
                loads=loads,
                order=sub.order,
                mse=load_mse(loads[alive]),
            )
    order = _sort_order(weights, source_ids)
    assignment_sorted = _assign_sorted(loads, weights[order])
    assignment = np.empty(weights.size, dtype=np.int64)
    assignment[order] = assignment_sorted
    return LptResult(
        assignment=assignment,
        loads=loads,
        order=order,
        mse=load_mse(loads),
    )


def lpt_schedule_reference(
    weights: np.ndarray,
    num_rails: int,
    source_ids: np.ndarray | None = None,
    initial_loads: np.ndarray | None = None,
    rail_mask: np.ndarray | None = None,
) -> LptResult:
    """Algorithm 2, naive transcript: argmin re-scan per flow, O(F·N).

    The parity oracle for :func:`lpt_schedule` — every fast-path change
    must keep the two bit-identical (tests pin this down). ``rail_mask``
    here is the direct transcript (masked argmin per flow; dead rails
    never win against any finite load), which the fast path's
    compact-recurse-remap formulation must reproduce exactly.
    """
    weights, source_ids, loads = _validate(weights, num_rails, source_ids, initial_loads)
    mask = (
        _check_rail_mask(rail_mask, num_rails) if rail_mask is not None else None
    )
    f = weights.size
    order = _sort_order(weights, source_ids)
    assignment = np.empty(f, dtype=np.int64)
    visible = loads if mask is None else np.where(mask, loads, np.inf)
    # Step 3: iterative allocation to the currently least-loaded rail.
    for i in order:
        j = int(np.argmin(visible))  # ties -> lowest rail index (np.argmin)
        assignment[i] = j
        loads[j] += weights[i]
        if mask is not None:
            visible[j] = loads[j]
    return LptResult(
        assignment=assignment,
        loads=loads,
        order=order,
        mse=load_mse(loads if mask is None else loads[mask]),
    )


class LptState:
    """Persistent LoadState for incremental (windowed / streaming) LPT.

    Online re-planning extends an existing plan window by window; the naive
    formulation re-ran :func:`lpt_schedule` per window, re-materializing
    the LoadState each time. ``LptState`` keeps the loads as mutable state:
    :meth:`assign` LPT-sorts *only the new window* and pushes it through
    the same hybrid assigner as the offline fast path — O(K log K + K
    log N) per window of K chunks, independent of how many chunks were
    already committed.

    ``extra_loads`` lets a caller bias one window's assignment (e.g. a
    rail-health pre-charge, recomputed per batch as EWMA estimates move)
    without the phantom bytes leaking into the persistent realized loads.
    """

    def __init__(self, num_rails: int, initial_loads: np.ndarray | None = None):
        self.num_rails = int(num_rails)
        self.loads = (
            np.zeros(self.num_rails, dtype=np.float64)
            if initial_loads is None
            else np.asarray(initial_loads, dtype=np.float64).copy()
        )
        if self.loads.shape != (self.num_rails,):
            raise ValueError("initial_loads must be (num_rails,)")

    def assign(
        self,
        weights: np.ndarray,
        source_ids: np.ndarray | None = None,
        extra_loads: np.ndarray | None = None,
        rail_mask: np.ndarray | None = None,
    ) -> LptResult:
        """LPT-assign one window of chunks against the persistent state.

        Returns an :class:`LptResult` for the window (assignment in the
        window's original order, loads = the updated persistent LoadState
        plus ``extra_loads`` if given). ``rail_mask`` (bool ``(N,)``,
        False = fail-stopped) restricts this window to surviving rails:
        the window plans over the compacted alive set while dead rails'
        persistent loads stay frozen, so a later repair (mask back to
        True) resumes from a consistent LoadState.
        """
        weights, source_ids, _ = _validate(weights, self.num_rails, source_ids, None)
        if rail_mask is not None:
            mask = _check_rail_mask(rail_mask, self.num_rails)
            if not mask.all():
                return self._assign_masked(weights, source_ids, extra_loads, mask)
        order = _sort_order(weights, source_ids)
        if extra_loads is None:
            eff = self.loads
        else:
            extra_loads = np.asarray(extra_loads, dtype=np.float64)
            if extra_loads.shape != (self.num_rails,):
                raise ValueError("extra_loads must be (num_rails,)")
            eff = self.loads + extra_loads
        assignment_sorted = _assign_sorted(eff, weights[order])
        assignment = np.empty(weights.size, dtype=np.int64)
        assignment[order] = assignment_sorted
        if extra_loads is None:
            self.loads = eff
        else:
            # Keep the realized LoadState free of phantom pre-charge bytes;
            # accumulation order matches per-chunk sequential addition.
            np.add.at(self.loads, assignment, weights)
        return LptResult(
            assignment=assignment,
            loads=eff,
            order=order,
            mse=load_mse(eff),
        )

    def _assign_masked(
        self,
        weights: np.ndarray,
        source_ids: np.ndarray | None,
        extra_loads: np.ndarray | None,
        mask: np.ndarray,
    ) -> LptResult:
        """Window assignment over the compacted survivor set (N−k rails)."""
        alive = np.flatnonzero(mask)
        order = _sort_order(weights, source_ids)
        eff_alive = self.loads[alive].copy()
        if extra_loads is not None:
            extra_loads = np.asarray(extra_loads, dtype=np.float64)
            if extra_loads.shape != (self.num_rails,):
                raise ValueError("extra_loads must be (num_rails,)")
            eff_alive += extra_loads[alive]
        assignment_sorted = _assign_sorted(eff_alive, weights[order])
        assignment_sub = np.empty(weights.size, dtype=np.int64)
        assignment_sub[order] = assignment_sorted
        assignment = alive[assignment_sub]
        # Persist realized bytes only (never phantom pre-charge, never
        # anything on a dead rail).
        np.add.at(self.loads, assignment, weights)
        eff = self.loads.copy()
        eff[alive] = eff_alive
        return LptResult(
            assignment=assignment,
            loads=eff,
            order=order,
            mse=load_mse(eff_alive),
        )


def _lpt_scan(
    weights_sorted: jnp.ndarray,
    initial_loads: jnp.ndarray,
    unroll: int,
    rail_mask: jnp.ndarray | None = None,
):
    """Greedy least-loaded assignment over pre-sorted weights via lax.scan.

    A survivor mask pins dead rails' loads to +inf inside the argmin only
    — the accumulated loads themselves stay untouched, so ties still
    resolve to the lowest *alive* original index, exactly like the host
    path's compact-recurse-and-map-back.
    """

    def step(loads, w):
        visible = loads if rail_mask is None else jnp.where(
            rail_mask, loads, jnp.inf
        )
        j = jnp.argmin(visible)
        loads = loads.at[j].add(w)
        return loads, j

    return jax.lax.scan(step, initial_loads, weights_sorted, unroll=unroll)


def lpt_schedule_jax(
    weights: jnp.ndarray,
    num_rails: int,
    initial_loads: jnp.ndarray | None = None,
    assume_uniform: bool = False,
    unroll: int = 8,
    rail_mask: jnp.ndarray | None = None,
):
    """Device LPT: jit-friendly Algorithm 2 on a ``jax.lax`` substrate.

    Args:
      weights: ``(F,)`` flow sizes (any float dtype; promoted to f32).
      num_rails: static N.
      initial_loads: optional ``(N,)`` starting LoadState.
      assume_uniform: static flag — the caller promises all weights are
        equal and the initial LoadState is uniform (the equal-chunk common
        case). Assignment is then the closed-form pre-sorted round-robin
        and loads come from one ``segment_sum`` — no per-flow scan at all.
        Unchecked under jit (weights are traced); parity with the host
        path holds exactly when the promise does.
      unroll: scan unroll factor for the general path — amortizes per-flow
        scan overhead at large F.
      rail_mask: optional bool ``(N,)`` survivor mask (may be traced) —
        False rails receive nothing and keep their initial loads, matching
        the masked host scheduler: ties resolve to the lowest alive
        original index, the MSE is over alive rails only. Under
        ``assume_uniform`` the round-robin runs over the alive set in
        ascending original order (the compacted Theorem-2 regime).

    Returns:
      ``(assignment, loads, mse)`` — assignment is in original flow order.
    """
    weights = jnp.asarray(weights, dtype=jnp.float32)
    f = weights.shape[0]
    if initial_loads is None:
        initial_loads = jnp.zeros((num_rails,), dtype=jnp.float32)
    mask = None
    if rail_mask is not None:
        mask = jnp.asarray(rail_mask, dtype=bool)
        if mask.shape != (num_rails,):
            raise ValueError(
                f"rail_mask must be ({num_rails},), got {mask.shape}"
            )
        try:
            if not bool(mask.any()):
                raise ValueError(
                    "rail_mask leaves no rail alive — nothing to plan over"
                )
        except jax.errors.TracerBoolConversionError:
            pass  # traced mask: liveness is the caller's promise
    # Descending sort; jnp.argsort is stable, so equal weights keep index
    # order — matching the host tie-break (source_ids == arange).
    order = jnp.argsort(-weights, stable=True)
    if assume_uniform:
        # Equal weights over a uniform LoadState reduce LPT to round-robin
        # in sorted order; the per-rail loads are a batched segment-sum.
        if mask is None:
            assignment_sorted = jnp.arange(f, dtype=jnp.int32) % num_rails
        else:
            # Alive rails first, ascending original index (argsort of the
            # dead flag is stable) — round-robin over that prefix is the
            # compacted host round-robin mapped back in one gather.
            alive_order = jnp.argsort(~mask, stable=True).astype(jnp.int32)
            num_alive = jnp.sum(mask).astype(jnp.int32)
            assignment_sorted = alive_order[
                jnp.arange(f, dtype=jnp.int32) % num_alive
            ]
        assignment = jnp.zeros((f,), dtype=jnp.int32).at[order].set(assignment_sorted)
        loads = initial_loads + jax.ops.segment_sum(
            weights, assignment, num_segments=num_rails
        )
    else:
        loads, assignment_sorted = _lpt_scan(
            weights[order], initial_loads, unroll=max(int(unroll), 1),
            rail_mask=mask,
        )
        # Scatter assignments back to original flow order.
        assignment = jnp.zeros((f,), dtype=jnp.int32).at[order].set(
            assignment_sorted.astype(jnp.int32)
        )
    if mask is None:
        mse = jnp.mean((loads - jnp.mean(loads)) ** 2)
    else:
        # A dead rail is not load imbalance: moments over alive rails only.
        num_alive_f = jnp.sum(mask).astype(loads.dtype)
        mean_alive = jnp.sum(jnp.where(mask, loads, 0.0)) / num_alive_f
        mse = jnp.sum(
            jnp.where(mask, (loads - mean_alive) ** 2, 0.0)
        ) / num_alive_f
    return assignment, loads, mse


@dataclasses.dataclass(frozen=True)
class HierLptResult:
    """Outcome of a two-level (rails x wan-lanes) hierarchical LPT pass.

    Attributes:
      rail: the level-1 :class:`LptResult` over rails — byte-identical to
        the flat scheduler's (hier-LPT never trades NIC balance away).
      lane: ``(F,)`` int — wan-lane index per chunk, ``-1`` for intra-pod
        chunks (which never touch a wan link).
      lane_loads: dst pod -> ``(L,)`` accumulated per-lane bytes.
      lane_mse: mean over destination pods of the per-lane load MSE —
        the level-2 analogue of eq. 6.
    """

    rail: LptResult
    lane: np.ndarray
    lane_loads: dict[int, np.ndarray]
    lane_mse: float


def hier_lpt_schedule(
    weights: np.ndarray,
    num_rails: int,
    num_lanes: int,
    dst_pods: np.ndarray,
    src_pod: int,
    source_ids: np.ndarray | None = None,
    initial_loads: np.ndarray | None = None,
    rail_mask: np.ndarray | None = None,
    lane_loads: dict[int, np.ndarray] | None = None,
) -> HierLptResult:
    """Two-level LPT for one source domain on a multi-pod fabric.

    Level 1 is exactly :func:`lpt_schedule` over rails (all chunks, intra-
    and inter-pod alike — the NIC is serialized either way, and keeping it
    identical preserves flat-fabric parity). Level 2 runs one independent
    LPT per remote destination pod over the ``L = num_lanes`` wan links of
    that pod pair, balancing this domain's per-pod egress across the
    scarce oversubscribed lanes; summed over the pod's domains the
    per-lane load is uniform (the Theorem-3 argument, one tier up).

    Args:
      weights: ``(F,)`` chunk sizes for this source domain.
      num_rails: N (level-1 bins).
      num_lanes: L, wan links per ordered pod pair (level-2 bins).
      dst_pods: ``(F,)`` destination pod per chunk.
      src_pod: this domain's pod — chunks with ``dst_pods == src_pod``
        get lane ``-1``.
      source_ids / initial_loads / rail_mask: forwarded to level 1
        untouched (feedback pre-charges and survivor masks keep working).
      lane_loads: optional persistent dst-pod -> ``(L,)`` LoadStates for
        incremental use; mutated in place when given.

    Returns a :class:`HierLptResult`.
    """
    rail_res = lpt_schedule(
        weights,
        num_rails,
        source_ids=source_ids,
        initial_loads=initial_loads,
        rail_mask=rail_mask,
    )
    weights = np.asarray(weights, dtype=np.float64)
    dst_pods = np.asarray(dst_pods)
    if dst_pods.shape != weights.shape:
        raise ValueError("dst_pods must match weights shape")
    if num_lanes < 1:
        raise ValueError("num_lanes must be >= 1")
    lane = np.full(weights.size, -1, dtype=np.int64)
    out_loads: dict[int, np.ndarray] = {}
    mses: list[float] = []
    for q in np.unique(dst_pods).tolist():
        if q == src_pod:
            continue
        idx = np.flatnonzero(dst_pods == q)
        init = None if lane_loads is None else lane_loads.get(q)
        sub = lpt_schedule(
            weights[idx],
            num_lanes,
            source_ids=None if source_ids is None else np.asarray(source_ids)[idx],
            initial_loads=init,
        )
        lane[idx] = sub.assignment
        out_loads[q] = sub.loads
        if lane_loads is not None:
            lane_loads[q] = sub.loads
        mses.append(sub.mse)
    return HierLptResult(
        rail=rail_res,
        lane=lane,
        lane_loads=out_loads,
        lane_mse=float(np.mean(mses)) if mses else 0.0,
    )


def round_robin_schedule(weights: np.ndarray, num_rails: int) -> LptResult:
    """Topology-blind baseline: flow i -> rail i mod N (static hashing)."""
    weights = np.asarray(weights, dtype=np.float64)
    f = weights.size
    assignment = np.arange(f, dtype=np.int64) % num_rails
    loads = np.zeros(num_rails, dtype=np.float64)
    np.add.at(loads, assignment, weights)
    return LptResult(
        assignment=assignment, loads=loads, order=np.arange(f), mse=load_mse(loads)
    )


def random_schedule(weights: np.ndarray, num_rails: int, seed: int = 0) -> LptResult:
    """REPS-style baseline: uniform random spraying of chunks over rails."""
    weights = np.asarray(weights, dtype=np.float64)
    f = weights.size
    rng = np.random.default_rng(seed)
    assignment = rng.integers(0, num_rails, size=f)
    loads = np.zeros(num_rails, dtype=np.float64)
    np.add.at(loads, assignment, weights)
    return LptResult(
        assignment=assignment, loads=loads, order=np.arange(f), mse=load_mse(loads)
    )
