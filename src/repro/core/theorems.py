"""Executable forms of the paper's Theorems 1–4 (§IV).

These are used as invariants by the tests, by the netsim (to report the
theoretical optimum alongside measured CCT), and by the roofline tooling
(lower bounds for collective time).
"""

from __future__ import annotations

import numpy as np

from .lp import closed_form_opt, loads_from_allocation

__all__ = [
    "rail_graph",
    "theorem1_capacity",
    "theorem1_maxflow_check",
    "theorem2_lower_bound",
    "theorem2_optimal_time",
    "theorem3_check_symmetry",
    "theorem4_mse_bound",
    "lpt_makespan_bound",
]


def theorem1_capacity(num_rails: int, r1: float, r2: float) -> float:
    """Theorem 1: ``Cap_{k->f} = N * R2`` provided ``R1 > R2``."""
    if not r1 > r2:
        raise ValueError(
            f"Theorem 1 requires R1 > R2 (intra-domain faster); got R1={r1}, R2={r2}"
        )
    return num_rails * r2


def rail_graph(num_domains: int, num_rails: int, r1: float, r2: float):
    """Directed capacitated graph of the Rail topology (proof of Thm 1).

    Nodes: ``("gpu", d, n)``, ``("nic", d, n)``, ``("leaf", n)``.
    Edges: GPU<->NIC and full intra-domain GPU mesh at rate R1; NIC<->leaf at
    rate R2. Returns a networkx DiGraph with ``capacity`` attributes.
    """
    import networkx as nx

    g = nx.DiGraph()
    for d in range(num_domains):
        for n in range(num_rails):
            g.add_edge(("gpu", d, n), ("nic", d, n), capacity=r1)
            g.add_edge(("nic", d, n), ("gpu", d, n), capacity=r1)
            g.add_edge(("nic", d, n), ("leaf", n), capacity=r2)
            g.add_edge(("leaf", n), ("nic", d, n), capacity=r2)
        # Intra-domain all-to-all fabric (NVLink analogue) at R1.
        for a in range(num_rails):
            for b in range(num_rails):
                if a != b:
                    g.add_edge(("gpu", d, a), ("gpu", d, b), capacity=r1)
    return g


def theorem1_maxflow_check(
    num_domains: int, num_rails: int, r1: float, r2: float
) -> float:
    """Compute the max flow domain k->f on the explicit graph; must equal N*R2."""
    import networkx as nx

    g = rail_graph(num_domains, num_rails, r1, r2)
    # Contract domain 0 to super-source, domain 1 to super-sink.
    g.add_node("s")
    g.add_node("t")
    for n in range(num_rails):
        g.add_edge("s", ("gpu", 0, n), capacity=float("inf"))
        g.add_edge(("gpu", 1, n), "t", capacity=float("inf"))
    value, _ = nx.maximum_flow(g, "s", "t")
    return float(value)


def theorem2_lower_bound(d2: np.ndarray, p: np.ndarray, r2: float) -> float:
    """Eq. 22: any schedule with allocation P takes at least max(S,R)/R2."""
    s, r = loads_from_allocation(d2, p)
    return float(max(s.max(), r.max()) / r2)


def theorem2_optimal_time(d2: np.ndarray, num_rails: int, r2: float) -> float:
    """Eq. 20 with the Theorem-3 optimum: ``T* = max(row,col)/N/R2``."""
    _, t_star = closed_form_opt(d2, num_rails)
    return float(t_star / r2)


def theorem3_check_symmetry(
    d2: np.ndarray, num_rails: int, atol: float = 1e-9
) -> dict:
    """Verify: with ``P*=1/N``, send loads AND recv loads are both uniform.

    Returns the send/recv load matrices and their max deviation from the
    per-domain uniform targets (eqs. 25–26). Deviations must be ~0.
    """
    d2 = np.asarray(d2, dtype=np.float64)
    m = d2.shape[0]
    p = np.full((m, m, num_rails), 1.0 / num_rails)
    s, r = loads_from_allocation(d2, p)
    send_target = d2.sum(axis=1, keepdims=True) / num_rails
    recv_target = d2.sum(axis=0)[:, None] / num_rails
    send_dev = float(np.abs(s - send_target).max())
    recv_dev = float(np.abs(r - recv_target).max())
    ok = send_dev <= atol and recv_dev <= atol
    return {
        "send_loads": s,
        "recv_loads": r,
        "send_dev": send_dev,
        "recv_dev": recv_dev,
        "uniform": ok,
    }


def theorem4_mse_bound(
    loads: np.ndarray, w_max: float, target: float | None = None
) -> tuple[float, float, bool]:
    """Theorem 4: LPT load MSE vs uniform target is bounded by ``w_max**2``.

    Returns ``(mse, bound, holds)``.
    """
    loads = np.asarray(loads, dtype=np.float64)
    if target is None:
        target = float(loads.mean())
    mse = float(np.mean((loads - target) ** 2))
    bound = float(w_max) ** 2
    return mse, bound, mse <= bound + 1e-9


def lpt_makespan_bound(num_rails: int) -> float:
    """Graham's LPT approximation ratio (eq. 39): ``4/3 - 1/(3N)``."""
    return 4.0 / 3.0 - 1.0 / (3.0 * num_rails)
