"""GQA attention layer: projections, rotary, flash core, KV-cache decode.

Three entry modes share weights:
* ``attn_forward``  — full-sequence (train / prefill), flash-attention core.
* ``attn_decode``   — single-token step against a KV cache (einsum; decode
  is memory-bound, flash brings nothing at q_len=1).
* cross-attention (whisper decoder) via ``attn_forward(kv_override=...)``.
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig
from ..kernels import ops
from .layers import apply_mrope, apply_rope, dense_init, rmsnorm, rmsnorm_init, soft_cap

__all__ = ["attn_init", "attn_forward", "attn_decode", "init_kv_cache"]


def attn_init(key, cfg: ModelConfig, dtype, cross: bool = False) -> dict:
    d, h, hkv, hd = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    ks = jax.random.split(key, 4)
    params = {
        "wq": dense_init(ks[0], d, h * hd, dtype),
        "wk": dense_init(ks[1], d, hkv * hd, dtype),
        "wv": dense_init(ks[2], d, hkv * hd, dtype),
        "wo": dense_init(ks[3], h * hd, d, dtype),
    }
    if cfg.use_qk_norm:
        params["q_norm"] = rmsnorm_init(hd, dtype)
        params["k_norm"] = rmsnorm_init(hd, dtype)
    del cross  # same shapes for cross-attention
    return params


def _project_qkv(params, cfg: ModelConfig, x, kv_src):
    b, t, _ = x.shape
    s = kv_src.shape[1]
    h, hkv, hd = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    q = jnp.einsum("btd,dk->btk", x, params["wq"]).reshape(b, t, h, hd)
    k = jnp.einsum("bsd,dk->bsk", kv_src, params["wk"]).reshape(b, s, hkv, hd)
    v = jnp.einsum("bsd,dk->bsk", kv_src, params["wv"]).reshape(b, s, hkv, hd)
    if cfg.use_qk_norm:
        q = rmsnorm(q, params["q_norm"], cfg.rms_eps)
        k = rmsnorm(k, params["k_norm"], cfg.rms_eps)
    return q, k, v


def _rotary(cfg: ModelConfig, q, k, positions):
    if positions is None:
        return q, k
    if cfg.use_mrope and positions.ndim == 3:
        q = apply_mrope(q, positions, cfg.rope_theta, cfg.mrope_sections)
        k = apply_mrope(k, positions, cfg.rope_theta, cfg.mrope_sections)
    else:
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
    return q, k


def attn_forward(
    params: dict,
    cfg: ModelConfig,
    x: jnp.ndarray,
    positions: Optional[jnp.ndarray],
    *,
    causal: bool = True,
    window: Optional[int] = None,
    kv_override: Optional[jnp.ndarray] = None,
    return_kv: bool = False,
):
    """Full-sequence attention. ``x: (B, T, D)``.

    ``kv_override`` switches to cross-attention against the given memory
    (whisper decoder). ``return_kv`` also returns (k, v) for cache priming.
    """
    kv_src = x if kv_override is None else kv_override
    q, k, v = _project_qkv(params, cfg, x, kv_src)
    if kv_override is None:
        q, k = _rotary(cfg, q, k, positions)
    out = ops.flash_attention(
        q,
        k,
        v,
        causal=causal and kv_override is None,
        window=window,
        softcap=cfg.attn_logit_softcap,
    )
    b, t = x.shape[:2]
    out = jnp.einsum(
        "btk,kd->btd", out.reshape(b, t, cfg.num_heads * cfg.head_dim), params["wo"]
    )
    if return_kv:
        return out, (k, v)
    return out


def init_kv_cache(cfg: ModelConfig, batch: int, max_len: int, dtype) -> dict:
    hkv, hd = cfg.num_kv_heads, cfg.head_dim
    return {
        "k": jnp.zeros((batch, max_len, hkv, hd), dtype=dtype),
        "v": jnp.zeros((batch, max_len, hkv, hd), dtype=dtype),
    }


def attn_decode(
    params: dict,
    cfg: ModelConfig,
    x: jnp.ndarray,
    cache: dict,
    pos: jnp.ndarray,
    *,
    window: Optional[int] = None,
    kv_override_cache: Optional[dict] = None,
):
    """One-token decode. ``x: (B, 1, D)``, ``pos``: scalar current position.

    Returns ``(out, new_cache)``. With ``kv_override_cache`` (cross-attn
    pre-computed memory) the cache is static and returned unchanged.
    """
    b = x.shape[0]
    h, hkv, hd = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    if kv_override_cache is not None:
        k, v = kv_override_cache["k"], kv_override_cache["v"]
        q = jnp.einsum("btd,dk->btk", x, params["wq"]).reshape(b, 1, h, hd)
        if cfg.use_qk_norm:
            q = rmsnorm(q, params["q_norm"], cfg.rms_eps)
        out = _decode_core(q, k, v, None, cfg, s_valid=k.shape[1])
        out = jnp.einsum("btk,kd->btd", out.reshape(b, 1, h * hd), params["wo"])
        return out, kv_override_cache

    q, k_new, v_new = _project_qkv(params, cfg, x, x)
    pos_arr = jnp.full((b, 1), pos, dtype=jnp.int32)
    if cfg.use_mrope:
        pos3 = jnp.broadcast_to(pos_arr[:, None, :], (b, 3, 1))
        q, k_new = _rotary(cfg, q, k_new, pos3)
    else:
        q, k_new = _rotary(cfg, q, k_new, pos_arr)
    k = jax.lax.dynamic_update_slice(cache["k"], k_new, (0, pos, 0, 0))
    v = jax.lax.dynamic_update_slice(cache["v"], v_new, (0, pos, 0, 0))
    out = _decode_core(q, k, v, pos, cfg, s_valid=None, window=window)
    out = jnp.einsum("btk,kd->btd", out.reshape(b, 1, h * hd), params["wo"])
    return out, {"k": k, "v": v}


def _decode_core(q, k, v, pos, cfg: ModelConfig, s_valid, window=None):
    """Einsum attention for q_len=1 with position masking over the cache."""
    b, _, h, hd = q.shape
    s = k.shape[1]
    hkv = k.shape[2]
    rep = h // hkv
    qf = q.astype(jnp.float32).reshape(b, hkv, rep, hd) * hd**-0.5
    scores = jnp.einsum("bhrd,bshd->bhrs", qf, k.astype(jnp.float32))
    scores = soft_cap(scores, cfg.attn_logit_softcap)
    k_pos = jnp.arange(s)
    if pos is not None:
        mask = k_pos <= pos
        if window is not None:
            mask = mask & (pos - k_pos < window)
    else:
        mask = k_pos < (s if s_valid is None else s_valid)
    scores = jnp.where(mask[None, None, None, :], scores, -jnp.inf)
    p = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhrs,bshd->bhrd", p, v.astype(jnp.float32))
    return out.reshape(b, 1, h, hd).astype(q.dtype)
