"""Model assembly for every assigned architecture family.

One ``init_params`` / ``forward_hidden`` / ``loss_fn`` / ``prefill_fn`` /
``decode_fn`` quintet covers all 10 archs through family-specific block
stacks, all scanned over layers (compact HLO, fast 512-device compiles)
with configurable remat:

* dense / vlm    — [attn + MLP] x L            (gemma2: [local, global] pairs)
* moe            — [attn + MoE] x L            (RailS dispatch inside MoE)
* hybrid(zamba2) — [6 x mamba + shared-attn] x 6 + trailing mamba
* ssm(xlstm)     — [mLSTM, sLSTM] x 6
* audio(whisper) — encoder [attn+MLP] x L  +  decoder [self+cross+MLP] x L

Caches are stacked along the scan dimension so decode is also a scan.
``shard_fn`` is an injection point for sharding constraints at block
boundaries (supplied by :mod:`repro.parallel.sharding`).
"""

from __future__ import annotations

import functools
from typing import Callable, Optional

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig
from .attention import attn_decode, attn_forward, attn_init, init_kv_cache
from .layers import (
    chunked_cross_entropy,
    dtype_of,
    embedding_init,
    mlp_apply,
    mlp_init,
    rmsnorm,
    rmsnorm_init,
    soft_cap,
)
from .mamba import init_mamba_cache, mamba_decode, mamba_forward, mamba_init
from .moe import EpInfo, moe_apply, moe_init
from .xlstm import (
    init_mlstm_cache,
    init_slstm_cache,
    mlstm_forward,
    mlstm_init,
    slstm_forward,
    slstm_init,
)

__all__ = ["init_params", "loss_fn", "prefill_fn", "decode_fn", "init_cache"]

Identity: Callable = lambda x, kind=None: x


def _stacked(init_one, key, n, *args):
    return jax.vmap(lambda k: init_one(k, *args))(jax.random.split(key, n))


# ---------------------------------------------------------------------------
# Parameter init
# ---------------------------------------------------------------------------


def init_params(cfg: ModelConfig, key) -> dict:
    dt = dtype_of(cfg)
    keys = jax.random.split(key, 8)
    params: dict = {
        "embed": embedding_init(keys[0], cfg.vocab_size, cfg.d_model, dt),
        "final_norm": rmsnorm_init(cfg.d_model, dt),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = embedding_init(keys[1], cfg.vocab_size, cfg.d_model, dt)

    fam = cfg.family
    d = cfg.d_model
    if fam in ("dense", "vlm"):
        if cfg.attn_pattern == "alt_local_global":
            half = cfg.num_layers // 2
            params["blocks"] = {
                kind: {
                    "attn": _stacked(lambda k: attn_init(k, cfg, dt), keys[2 + i], half),
                    "mlp": _stacked(lambda k: mlp_init(k, d, cfg.d_ff, dt), keys[4 + i], half),
                    "ln1": jnp.ones((half, d), dt),
                    "ln2": jnp.ones((half, d), dt),
                    "post1": jnp.ones((half, d), dt),
                    "post2": jnp.ones((half, d), dt),
                }
                for i, kind in enumerate(("local", "global"))
            }
        else:
            n = cfg.num_layers
            params["blocks"] = {
                "attn": _stacked(lambda k: attn_init(k, cfg, dt), keys[2], n),
                "mlp": _stacked(lambda k: mlp_init(k, d, cfg.d_ff, dt), keys[3], n),
                "ln1": jnp.ones((n, d), dt),
                "ln2": jnp.ones((n, d), dt),
            }
    elif fam == "moe":
        n = cfg.num_layers
        params["blocks"] = {
            "attn": _stacked(lambda k: attn_init(k, cfg, dt), keys[2], n),
            "moe": _stacked(lambda k: moe_init(k, cfg, dt), keys[3], n),
            "ln1": jnp.ones((n, d), dt),
            "ln2": jnp.ones((n, d), dt),
        }
    elif fam == "hybrid":
        period = cfg.shared_attn_period
        n_groups = cfg.num_layers // period
        n_tail = cfg.num_layers - n_groups * period
        params["blocks"] = {
            "mamba": _stacked(lambda k: mamba_init(k, cfg, dt), keys[2], n_groups * period),
            "mamba_ln": jnp.ones((n_groups * period, d), dt),
            "tail": _stacked(lambda k: mamba_init(k, cfg, dt), keys[3], max(n_tail, 1)),
            "tail_ln": jnp.ones((max(n_tail, 1), d), dt),
            "shared_attn": attn_init(keys[4], cfg, dt),
            "shared_mlp": mlp_init(keys[5], d, cfg.d_ff, dt),
            "shared_ln1": rmsnorm_init(d, dt),
            "shared_ln2": rmsnorm_init(d, dt),
        }
    elif fam == "ssm":
        n_m = sum(1 for c in cfg.xlstm_pattern if c == "m")
        n_s = sum(1 for c in cfg.xlstm_pattern if c == "s")
        params["blocks"] = {
            "m": _stacked(lambda k: mlstm_init(k, cfg, dt), keys[2], n_m),
            "m_ln": jnp.ones((n_m, d), dt),
            "s": _stacked(lambda k: slstm_init(k, cfg, dt), keys[3], n_s),
            "s_ln": jnp.ones((n_s, d), dt),
        }
    elif fam == "audio":
        ne, nd = cfg.encoder_layers, cfg.num_layers
        params["enc_pos"] = embedding_init(keys[6], cfg.encoder_seq, d, dt)
        params["enc_final_norm"] = rmsnorm_init(d, dt)
        params["blocks"] = {
            "enc": {
                "attn": _stacked(lambda k: attn_init(k, cfg, dt), keys[2], ne),
                "mlp": _stacked(lambda k: mlp_init(k, d, cfg.d_ff, dt), keys[3], ne),
                "ln1": jnp.ones((ne, d), dt),
                "ln2": jnp.ones((ne, d), dt),
            },
            "dec": {
                "self_attn": _stacked(lambda k: attn_init(k, cfg, dt), keys[4], nd),
                "cross_attn": _stacked(lambda k: attn_init(k, cfg, dt, cross=True), keys[5], nd),
                "mlp": _stacked(lambda k: mlp_init(k, d, cfg.d_ff, dt), keys[7], nd),
                "ln1": jnp.ones((nd, d), dt),
                "ln2": jnp.ones((nd, d), dt),
                "ln3": jnp.ones((nd, d), dt),
            },
        }
    else:
        raise ValueError(f"unknown family {fam!r}")
    return params


# ---------------------------------------------------------------------------
# Forward (full sequence): train / prefill
# ---------------------------------------------------------------------------


def _window_for(cfg: ModelConfig, kind: str) -> Optional[int]:
    if cfg.attn_pattern == "swa":
        return cfg.sliding_window
    if cfg.attn_pattern == "alt_local_global" and kind == "local":
        return cfg.sliding_window
    return None


def _maybe_remat(cfg: ModelConfig, fn):
    if not cfg.remat:
        return fn
    if cfg.remat_policy == "dots":
        return jax.checkpoint(
            fn, policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable
        )
    return jax.checkpoint(fn)


def _dense_block(x, p, cfg: ModelConfig, positions, kind: str, shard_fn, collect_kv=False):
    h = attn_forward(
        p["attn"], cfg, rmsnorm(x, p["ln1"], cfg.rms_eps), positions,
        window=_window_for(cfg, kind), return_kv=collect_kv,
    )
    kv = None
    if collect_kv:
        h, kv = h
    if cfg.use_post_norm:
        h = rmsnorm(h, p["post1"], cfg.rms_eps)
    x = shard_fn(x + h, "resid")
    h2 = mlp_apply(p["mlp"], rmsnorm(x, p["ln2"], cfg.rms_eps), cfg.act)
    if cfg.use_post_norm:
        h2 = rmsnorm(h2, p["post2"], cfg.rms_eps)
    x = shard_fn(x + h2, "resid")
    return (x, kv) if collect_kv else x


def _moe_block(x, p, cfg, positions, ep_info, shard_fn, collect_kv=False):
    h = attn_forward(
        p["attn"], cfg, rmsnorm(x, p["ln1"], cfg.rms_eps), positions,
        window=_window_for(cfg, "swa"), return_kv=collect_kv,
    )
    kv = None
    if collect_kv:
        h, kv = h
    x = shard_fn(x + h, "resid")
    out, aux, counts = moe_apply(p["moe"], cfg, rmsnorm(x, p["ln2"], cfg.rms_eps), ep_info)
    x = shard_fn(x + out, "resid")
    return (x, aux, counts, kv) if collect_kv else (x, aux, counts)


def forward_hidden(
    params: dict,
    cfg: ModelConfig,
    batch: dict,
    ep_info: Optional[EpInfo] = None,
    shard_fn: Callable = Identity,
    collect_cache: bool = False,
):
    """Full-sequence forward. Returns ``(hidden, aux_metrics, caches|None)``."""
    dt = dtype_of(cfg)
    tokens = batch["tokens"]
    b, t = tokens.shape
    x = params["embed"][tokens]
    if cfg.embed_scale:
        x = x * jnp.asarray(cfg.d_model**0.5, dt)
    x = shard_fn(x, "resid")
    positions = batch.get("positions")
    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(t, dtype=jnp.int32), (b, t))
        if cfg.use_mrope:
            positions = jnp.broadcast_to(positions[:, None, :], (b, 3, t))
    aux = {"moe_aux": jnp.float32(0.0), "moe_counts": jnp.zeros((max(cfg.num_experts, 1),), jnp.int32)}
    caches = {} if collect_cache else None
    fam = cfg.family
    bl = params["blocks"]

    if fam in ("dense", "vlm"):
        if cfg.attn_pattern == "alt_local_global":
            def pair(xc, p):
                xc = _dense_block(xc, p["local"], cfg, positions, "local", shard_fn)
                xc = _dense_block(xc, p["global"], cfg, positions, "global", shard_fn)
                return xc, None
            if collect_cache:
                def pair_kv(xc, p):
                    xc, kv_l = _dense_block(xc, p["local"], cfg, positions, "local", shard_fn, True)
                    xc, kv_g = _dense_block(xc, p["global"], cfg, positions, "global", shard_fn, True)
                    return xc, {"local": kv_l, "global": kv_g}
                x, kvs = jax.lax.scan(_maybe_remat(cfg, pair_kv), x, bl)
                caches["kv"] = kvs
            else:
                x, _ = jax.lax.scan(_maybe_remat(cfg, pair), x, bl)
        else:
            def body(xc, p):
                return _dense_block(xc, p, cfg, positions, "full", shard_fn), None
            if collect_cache:
                def body_kv(xc, p):
                    xc, kv = _dense_block(xc, p, cfg, positions, "full", shard_fn, True)
                    return xc, kv
                x, kvs = jax.lax.scan(_maybe_remat(cfg, body_kv), x, bl)
                caches["kv"] = kvs
            else:
                x, _ = jax.lax.scan(_maybe_remat(cfg, body), x, bl)

    elif fam == "moe":
        if collect_cache:
            def body_kv(xc, p):
                xc, a, c, kv = _moe_block(xc, p, cfg, positions, ep_info, shard_fn, True)
                return xc, (a, c, kv)
            x, (auxs, counts, kvs) = jax.lax.scan(_maybe_remat(cfg, body_kv), x, bl)
            caches["kv"] = kvs
        else:
            def body(xc, p):
                xc, a, c = _moe_block(xc, p, cfg, positions, ep_info, shard_fn)
                return xc, (a, c)
            x, (auxs, counts) = jax.lax.scan(_maybe_remat(cfg, body), x, bl)
        aux["moe_aux"] = jnp.sum(auxs)
        aux["moe_counts"] = jnp.sum(counts, axis=0)

    elif fam == "hybrid":
        period = cfg.shared_attn_period
        n_groups = cfg.num_layers // period
        n_tail = cfg.num_layers - n_groups * period
        mamba_p = jax.tree.map(
            lambda a: a.reshape(n_groups, period, *a.shape[1:]), bl["mamba"]
        )
        mamba_ln = bl["mamba_ln"].reshape(n_groups, period, -1)
        shared = {k: bl[k] for k in ("shared_attn", "shared_mlp", "shared_ln1", "shared_ln2")}
        states: list = []

        def group(xc, p):
            pm, ln = p
            def inner(xc2, pi):
                pm_i, ln_i = pi
                out, state = mamba_forward(pm_i, cfg, rmsnorm(xc2, ln_i, cfg.rms_eps))
                return shard_fn(xc2 + out, "resid"), state
            xc, st = jax.lax.scan(inner, xc, (pm, ln))
            h = attn_forward(shared["shared_attn"], cfg,
                             rmsnorm(xc, shared["shared_ln1"], cfg.rms_eps), positions)
            xc = shard_fn(xc + h, "resid")
            h2 = mlp_apply(shared["shared_mlp"], rmsnorm(xc, shared["shared_ln2"], cfg.rms_eps), cfg.act)
            xc = shard_fn(xc + h2, "resid")
            return xc, st
        x, _states = jax.lax.scan(_maybe_remat(cfg, group), x, (mamba_p, mamba_ln))
        if n_tail:
            tail_p = jax.tree.map(lambda a: a[:n_tail], bl["tail"])
            def tail(xc, pi):
                pm_i, ln_i = pi
                out, state = mamba_forward(pm_i, cfg, rmsnorm(xc, ln_i, cfg.rms_eps))
                return shard_fn(xc + out, "resid"), state
            x, _ = jax.lax.scan(_maybe_remat(cfg, tail), x, (tail_p, bl["tail_ln"][:n_tail]))

    elif fam == "ssm":
        def super_block(xc, p):
            pm, ln_m, ps, ln_s = p
            out, _ = mlstm_forward(pm, cfg, rmsnorm(xc, ln_m, cfg.rms_eps))
            xc = shard_fn(xc + out, "resid")
            out, _ = slstm_forward(ps, cfg, rmsnorm(xc, ln_s, cfg.rms_eps))
            return shard_fn(xc + out, "resid"), None
        x, _ = jax.lax.scan(
            _maybe_remat(cfg, super_block), x, (bl["m"], bl["m_ln"], bl["s"], bl["s_ln"])
        )

    elif fam == "audio":
        memory = _whisper_encode(params, cfg, batch, shard_fn)
        def dec_body(xc, p):
            h = attn_forward(p["self_attn"], cfg, rmsnorm(xc, p["ln1"], cfg.rms_eps),
                             positions, return_kv=collect_cache)
            kv = None
            if collect_cache:
                h, kv = h
            xc = shard_fn(xc + h, "resid")
            h = attn_forward(p["cross_attn"], cfg, rmsnorm(xc, p["ln2"], cfg.rms_eps),
                             None, kv_override=memory, return_kv=collect_cache)
            ckv = None
            if collect_cache:
                h, ckv = h
            xc = shard_fn(xc + h, "resid")
            h = mlp_apply(p["mlp"], rmsnorm(xc, p["ln3"], cfg.rms_eps), cfg.act)
            xc = shard_fn(xc + h, "resid")
            return xc, (kv, ckv) if collect_cache else None
        if collect_cache:
            x, (kvs, ckvs) = jax.lax.scan(_maybe_remat(cfg, dec_body), x, bl["dec"])
            caches["kv"] = kvs
            caches["cross_kv"] = ckvs
        else:
            x, _ = jax.lax.scan(_maybe_remat(cfg, dec_body), x, bl["dec"])
    else:
        raise ValueError(fam)

    x = rmsnorm(x, params["final_norm"], cfg.rms_eps)
    return x, aux, caches


def _whisper_encode(params, cfg: ModelConfig, batch, shard_fn):
    """Frontend stub: ``batch['embeds']`` are precomputed frame embeddings."""
    mem = batch["embeds"].astype(dtype_of(cfg))
    mem = mem + params["enc_pos"][None, : mem.shape[1]]
    def body(xc, p):
        h = attn_forward(p["attn"], cfg, rmsnorm(xc, p["ln1"], cfg.rms_eps), None, causal=False)
        xc = shard_fn(xc + h, "resid")
        h = mlp_apply(p["mlp"], rmsnorm(xc, p["ln2"], cfg.rms_eps), cfg.act)
        return shard_fn(xc + h, "resid"), None
    mem, _ = jax.lax.scan(_maybe_remat(cfg, body), mem, params["blocks"]["enc"])
    return rmsnorm(mem, params["enc_final_norm"], cfg.rms_eps)


# ---------------------------------------------------------------------------
# Heads: loss / prefill / decode
# ---------------------------------------------------------------------------


def _vocab_matrix(params, cfg: ModelConfig):
    return params["embed"].T if cfg.tie_embeddings else params["lm_head"].T


def loss_fn(params, cfg: ModelConfig, batch, ep_info=None, shard_fn: Callable = Identity):
    hidden, aux, _ = forward_hidden(params, cfg, batch, ep_info, shard_fn)
    nll = chunked_cross_entropy(
        hidden, _vocab_matrix(params, cfg), batch["labels"],
        chunk=cfg.xent_chunk, final_softcap=cfg.final_logit_softcap,
        shard_fn=None if shard_fn is Identity else shard_fn,
    )
    loss = nll + cfg.router_aux_coef * aux["moe_aux"]
    metrics = {"nll": nll, "moe_aux": aux["moe_aux"], "moe_counts": aux["moe_counts"]}
    return loss, metrics


def logits_last(params, cfg: ModelConfig, hidden):
    h_last = hidden[:, -1]
    logits = jnp.einsum("bd,dv->bv", h_last, _vocab_matrix(params, cfg)).astype(jnp.float32)
    return soft_cap(logits, cfg.final_logit_softcap)


def prefill_fn(params, cfg: ModelConfig, batch, ep_info=None, shard_fn: Callable = Identity):
    """Full-sequence prefill: last-position logits + caches (KV to length T)."""
    hidden, aux, caches = forward_hidden(
        params, cfg, batch, ep_info, shard_fn, collect_cache=cfg.family in ("dense", "vlm", "moe", "audio")
    )
    return logits_last(params, cfg, hidden), caches, aux


# -- decode ------------------------------------------------------------------


def init_cache(cfg: ModelConfig, batch: int, max_len: int) -> dict:
    """Stacked decode caches matching the scan layout of ``decode_fn``."""
    dt = dtype_of(cfg)
    fam = cfg.family

    def kv(n):
        return jax.vmap(lambda _: init_kv_cache(cfg, batch, max_len, dt))(jnp.arange(n))

    if fam in ("dense", "vlm"):
        if cfg.attn_pattern == "alt_local_global":
            half = cfg.num_layers // 2
            return {"local": kv(half), "global": kv(half)}
        return {"kv": kv(cfg.num_layers)}
    if fam == "moe":
        return {"kv": kv(cfg.num_layers)}
    if fam == "hybrid":
        period = cfg.shared_attn_period
        n_groups = cfg.num_layers // period
        n_tail = cfg.num_layers - n_groups * period
        return {
            "mamba": jax.vmap(lambda _: init_mamba_cache(cfg, batch, dt))(
                jnp.arange(n_groups * period)
            ),
            "tail": jax.vmap(lambda _: init_mamba_cache(cfg, batch, dt))(
                jnp.arange(max(n_tail, 1))
            ),
            "shared_kv": kv(n_groups),
        }
    if fam == "ssm":
        n_m = sum(1 for c in cfg.xlstm_pattern if c == "m")
        n_s = sum(1 for c in cfg.xlstm_pattern if c == "s")
        return {
            "m": jax.vmap(lambda _: jax.tree.map(jnp.asarray, init_mlstm_cache(cfg, batch)))(jnp.arange(n_m)),
            "s": jax.vmap(lambda _: jax.tree.map(jnp.asarray, init_slstm_cache(cfg, batch)))(jnp.arange(n_s)),
        }
    if fam == "audio":
        enc = cfg.encoder_seq
        hkv, hd = cfg.num_kv_heads, cfg.head_dim
        return {
            "kv": kv(cfg.num_layers),
            "cross_kv": {
                "k": jnp.zeros((cfg.num_layers, batch, enc, hkv, hd), dt),
                "v": jnp.zeros((cfg.num_layers, batch, enc, hkv, hd), dt),
            },
        }
    raise ValueError(fam)


def _scan_layers_inplace(body, params_stack, cache, x, n_layers: int):
    """Decode-layer scan with the cache in the CARRY (not xs/ys).

    Carrying the full stacked cache and updating layer ``i`` via
    dynamic-update-slice lets XLA keep ONE cache buffer alive (in-place
    while-loop update); the xs->ys form double-buffers the entire cache,
    which at 32k-context scale is gigabytes per device.
    """
    def step(carry, inputs):
        xc, cache_c = carry
        i, p = inputs
        c_l = jax.tree.map(lambda a: a[i], cache_c)
        xc, c_new = body(xc, p, c_l)
        cache_c = jax.tree.map(
            lambda a, u: jax.lax.dynamic_update_index_in_dim(a, u.astype(a.dtype), i, 0),
            cache_c,
            c_new,
        )
        return (xc, cache_c), None

    (x, cache), _ = jax.lax.scan(
        step, (x, cache), (jnp.arange(n_layers), params_stack)
    )
    return x, cache


def decode_fn(params, cfg: ModelConfig, cache: dict, tokens, pos, ep_info=None,
              shard_fn: Callable = Identity, return_counts: bool = False):
    """One decode step. ``tokens: (B, 1)``, ``pos``: scalar position.

    Returns ``(logits (B, V-softcapped), new_cache)``, or with
    ``return_counts=True`` ``(logits, new_cache, moe_counts)`` where
    ``moe_counts`` is the step's per-expert routed-token counts summed
    over layers (``(num_experts,)`` int32; all zeros for non-MoE
    families) — the real gating trace the serving-path fabric replay
    (``launch/serve.py --sim-fabric``) consumes.
    """
    dt = dtype_of(cfg)
    x = params["embed"][tokens]
    if cfg.embed_scale:
        x = x * jnp.asarray(cfg.d_model**0.5, dt)
    fam = cfg.family
    bl = params["blocks"]
    new_cache: dict = {}
    moe_counts = jnp.zeros((max(cfg.num_experts, 1),), jnp.int32)

    if fam in ("dense", "vlm", "moe"):
        is_moe = fam == "moe"
        if return_counts and is_moe and cfg.attn_pattern == "alt_local_global":
            # The alt-pattern branch has no MoE layers to count; failing
            # loudly beats replaying an all-zero gating trace.
            raise ValueError(
                "return_counts is not supported for MoE configs with "
                "attn_pattern='alt_local_global'"
            )
        if cfg.attn_pattern == "alt_local_global":
            def pair(xc, p, c):
                c_l, c_g = c["local"], c["global"]
                h, c_l = attn_decode(p["local"]["attn"], cfg,
                                     rmsnorm(xc, p["local"]["ln1"], cfg.rms_eps), c_l, pos,
                                     window=cfg.sliding_window)
                if cfg.use_post_norm:
                    h = rmsnorm(h, p["local"]["post1"], cfg.rms_eps)
                xc = xc + h
                h2 = mlp_apply(p["local"]["mlp"], rmsnorm(xc, p["local"]["ln2"], cfg.rms_eps), cfg.act)
                if cfg.use_post_norm:
                    h2 = rmsnorm(h2, p["local"]["post2"], cfg.rms_eps)
                xc = xc + h2
                h, c_g = attn_decode(p["global"]["attn"], cfg,
                                     rmsnorm(xc, p["global"]["ln1"], cfg.rms_eps), c_g, pos)
                if cfg.use_post_norm:
                    h = rmsnorm(h, p["global"]["post1"], cfg.rms_eps)
                xc = xc + h
                h2 = mlp_apply(p["global"]["mlp"], rmsnorm(xc, p["global"]["ln2"], cfg.rms_eps), cfg.act)
                if cfg.use_post_norm:
                    h2 = rmsnorm(h2, p["global"]["post2"], cfg.rms_eps)
                return xc + h2, {"local": c_l, "global": c_g}
            x, new_cache = _scan_layers_inplace(
                pair, bl, {"local": cache["local"], "global": cache["global"]},
                x, cfg.num_layers // 2,
            )
        elif is_moe and return_counts:
            # Thread a per-expert count accumulator through the layer-scan
            # carry: the gating trace of this decode step, summed over
            # layers — what forward_hidden reports for training steps.
            def body_counts(carry, p, c):
                xc, cnts = carry
                h, c = attn_decode(p["attn"], cfg, rmsnorm(xc, p["ln1"], cfg.rms_eps),
                                   c, pos, window=_window_for(cfg, "swa"))
                xc = xc + h
                out, _a, cnt = moe_apply(p["moe"], cfg, rmsnorm(xc, p["ln2"], cfg.rms_eps), ep_info)
                return (xc + out, cnts + cnt), c
            (x, moe_counts), kv = _scan_layers_inplace(
                body_counts, bl, cache["kv"], (x, moe_counts), cfg.num_layers
            )
            new_cache = {"kv": kv}
        else:
            def body(xc, p, c):
                h, c = attn_decode(p["attn"], cfg, rmsnorm(xc, p["ln1"], cfg.rms_eps),
                                   c, pos, window=_window_for(cfg, "swa"))
                xc = xc + h
                if is_moe:
                    out, _a, _c = moe_apply(p["moe"], cfg, rmsnorm(xc, p["ln2"], cfg.rms_eps), ep_info)
                else:
                    out = mlp_apply(p["mlp"], rmsnorm(xc, p["ln2"], cfg.rms_eps), cfg.act)
                return xc + out, c
            x, kv = _scan_layers_inplace(body, bl, cache["kv"], x, cfg.num_layers)
            new_cache = {"kv": kv}

    elif fam == "hybrid":
        period = cfg.shared_attn_period
        n_groups = cfg.num_layers // period
        n_tail = cfg.num_layers - n_groups * period
        mamba_p = jax.tree.map(lambda a: a.reshape(n_groups, period, *a.shape[1:]), bl["mamba"])
        mamba_ln = bl["mamba_ln"].reshape(n_groups, period, -1)
        mcache = jax.tree.map(lambda a: a.reshape(n_groups, period, *a.shape[1:]), cache["mamba"])
        def group(xc, xs):
            pm, ln, mc, kc = xs
            def inner(xc2, ys):
                pm_i, ln_i, mc_i = ys
                out, mc_i = mamba_decode(pm_i, cfg, rmsnorm(xc2, ln_i, cfg.rms_eps), mc_i)
                return xc2 + out, mc_i
            xc, mc = jax.lax.scan(inner, xc, (pm, ln, mc))
            h, kc = attn_decode(bl["shared_attn"], cfg,
                                rmsnorm(xc, bl["shared_ln1"], cfg.rms_eps), kc, pos)
            xc = xc + h
            h2 = mlp_apply(bl["shared_mlp"], rmsnorm(xc, bl["shared_ln2"], cfg.rms_eps), cfg.act)
            return xc + h2, (mc, kc)
        x, (mc, kc) = jax.lax.scan(group, x, (mamba_p, mamba_ln, mcache, cache["shared_kv"]))
        new_cache["mamba"] = jax.tree.map(lambda a: a.reshape(n_groups * period, *a.shape[2:]), mc)
        new_cache["shared_kv"] = kc
        if n_tail:
            def tail(xc, ys):
                pm_i, ln_i, mc_i = ys
                out, mc_i = mamba_decode(pm_i, cfg, rmsnorm(xc, ln_i, cfg.rms_eps), mc_i)
                return xc + out, mc_i
            tail_p = jax.tree.map(lambda a: a[:n_tail], bl["tail"])
            tail_c = jax.tree.map(lambda a: a[:n_tail], cache["tail"])
            x, tc = jax.lax.scan(tail, x, (tail_p, bl["tail_ln"][:n_tail], tail_c))
            pad = jax.tree.map(lambda a: a[n_tail:], cache["tail"])
            new_cache["tail"] = jax.tree.map(lambda a, b: jnp.concatenate([a, b]), tc, pad)
        else:
            new_cache["tail"] = cache["tail"]

    elif fam == "ssm":
        def super_block(xc, xs):
            pm, ln_m, ps, ln_s, cm, cs = xs
            out, cm = mlstm_forward(pm, cfg, rmsnorm(xc, ln_m, cfg.rms_eps), cache=cm)
            xc = xc + out
            out, cs = slstm_forward(ps, cfg, rmsnorm(xc, ln_s, cfg.rms_eps), cache=cs)
            return xc + out, (cm, cs)
        x, (cm, cs) = jax.lax.scan(
            super_block, x, (bl["m"], bl["m_ln"], bl["s"], bl["s_ln"], cache["m"], cache["s"])
        )
        new_cache = {"m": cm, "s": cs}

    elif fam == "audio":
        # cross-attn memory is static per layer; self-attn kv carried inplace.
        def dec_step(xc, p, c):
            c_self, cc = c["kv"], c["cross"]
            h, c_self = attn_decode(p["self_attn"], cfg,
                                    rmsnorm(xc, p["ln1"], cfg.rms_eps), c_self, pos)
            xc = xc + h
            h, _ = attn_decode(p["cross_attn"], cfg, rmsnorm(xc, p["ln2"], cfg.rms_eps),
                               c_self, pos, kv_override_cache=cc)
            xc = xc + h
            h = mlp_apply(p["mlp"], rmsnorm(xc, p["ln3"], cfg.rms_eps), cfg.act)
            return xc + h, {"kv": c_self, "cross": cc}
        x, merged = _scan_layers_inplace(
            dec_step, bl["dec"], {"kv": cache["kv"], "cross": cache["cross_kv"]},
            x, cfg.num_layers,
        )
        new_cache = {"kv": merged["kv"], "cross_kv": merged["cross"]}
    else:
        raise ValueError(fam)

    x = rmsnorm(x, params["final_norm"], cfg.rms_eps)
    logits = logits_last(params, cfg, x)
    if return_counts:
        return logits, new_cache, moe_counts
    return logits, new_cache
