"""Mamba2-style selective state-space block (zamba2's mixer).

Simplified SSD recurrence with multi-head state:

    h_t = exp(A * dt_t) * h_{t-1} + dt_t * (B_t ⊗ x_t)      h: (nh, hd, ds)
    y_t = C_t · h_t + D * x_t
    out = out_proj( rmsnorm(y * silu(z)) )

Train/prefill runs the recurrence as a ``lax.scan`` over time (O(T) state,
sub-quadratic — this is what qualifies the hybrid archs for long_500k);
decode is a single-step state update (O(1) per token).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig
from .layers import dense_init, rmsnorm, rmsnorm_init

__all__ = ["mamba_init", "mamba_forward", "mamba_decode", "init_mamba_cache"]


def _dims(cfg: ModelConfig):
    d_in = cfg.mamba_expand * cfg.d_model
    nh = d_in // cfg.mamba_head_dim
    return d_in, nh, cfg.mamba_head_dim, cfg.ssm_state


def mamba_init(key, cfg: ModelConfig, dtype) -> dict:
    d = cfg.d_model
    d_in, nh, hd, ds = _dims(cfg)
    conv_ch = d_in + 2 * ds
    ks = jax.random.split(key, 4)
    return {
        "in_proj": dense_init(ks[0], d, 2 * d_in + 2 * ds + nh, dtype),
        "conv_w": (
            jax.random.normal(ks[1], (cfg.conv_width, conv_ch), dtype=jnp.float32)
            * cfg.conv_width**-0.5
        ).astype(dtype),
        "a_log": jnp.zeros((nh,), dtype=jnp.float32),
        "d_skip": jnp.ones((nh,), dtype=jnp.float32),
        "dt_bias": jnp.zeros((nh,), dtype=jnp.float32),
        "norm_w": rmsnorm_init(d_in, dtype),
        "out_proj": dense_init(ks[3], d_in, d, dtype),
    }


def _split_proj(params, cfg: ModelConfig, x):
    d_in, nh, hd, ds = _dims(cfg)
    proj = jnp.einsum("btd,dk->btk", x, params["in_proj"])
    xs, z, b_c, c_c, dt = jnp.split(
        proj, [d_in, 2 * d_in, 2 * d_in + ds, 2 * d_in + 2 * ds], axis=-1
    )
    return xs, z, b_c, c_c, dt


def _causal_conv(x, w, state=None):
    """Depthwise causal conv. ``x: (B, T, C)``, ``w: (W, C)``.

    ``state``: previous ``W-1`` inputs ``(B, W-1, C)`` for decode; returns
    ``(y, new_state)``.
    """
    width = w.shape[0]
    if state is None:
        pad = jnp.zeros_like(x[:, : width - 1])
    else:
        pad = state
    xp = jnp.concatenate([pad, x], axis=1)  # (B, T+W-1, C)
    y = sum(xp[:, i : i + x.shape[1]] * w[i] for i in range(width))
    new_state = xp[:, -(width - 1) :]
    return jax.nn.silu(y), new_state


def _ssm_step(h, inputs, a):
    """One recurrence step. ``h: (B, nh, hd, ds)``."""
    x_h, b_t, c_t, dt_t = inputs  # (B,nh,hd), (B,ds), (B,ds), (B,nh)
    decay = jnp.exp(dt_t * a)  # (B, nh); a < 0
    h = h * decay[..., None, None] + (
        dt_t[..., None, None] * x_h[..., None] * b_t[:, None, None, :]
    )
    y = jnp.einsum("bnhs,bs->bnh", h, c_t)
    return h, y


def mamba_forward(params: dict, cfg: ModelConfig, x: jnp.ndarray, h0=None, conv0=None):
    """``x: (B, T, D)`` -> ``(out, (h_T, conv_state))``."""
    b, t, d = x.shape
    d_in, nh, hd, ds = _dims(cfg)
    xs, z, b_c, c_c, dt = _split_proj(params, cfg, x)
    conv_in = jnp.concatenate([xs, b_c, c_c], axis=-1)
    conv_out, conv_state = _causal_conv(conv_in, params["conv_w"], conv0)
    xs, b_c, c_c = jnp.split(conv_out, [d_in, d_in + ds], axis=-1)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + params["dt_bias"])
    a = -jnp.exp(params["a_log"])  # (nh,)

    x_heads = xs.reshape(b, t, nh, hd).astype(jnp.float32)
    if h0 is None:
        h0 = jnp.zeros((b, nh, hd, ds), dtype=jnp.float32)

    def step(h, ins):
        return _ssm_step(h, ins, a)

    inputs = (
        x_heads.transpose(1, 0, 2, 3),
        b_c.astype(jnp.float32).transpose(1, 0, 2),
        c_c.astype(jnp.float32).transpose(1, 0, 2),
        dt.transpose(1, 0, 2),
    )
    # Chunked remat scan: the backward pass of a plain T-step scan stores
    # the (B, nh, hd, ds) state at every step — O(T) memory. Scanning over
    # sqrt-sized chunks with a checkpointed inner scan stores only chunk
    # boundaries (O(T/chunk)) and recomputes inside — this is what keeps
    # train_4k on the SSM/hybrid archs inside the HBM budget.
    chunk = min(128, t)
    if t % chunk == 0 and t > chunk:
        nc = t // chunk
        chunked = jax.tree.map(
            lambda a_: a_.reshape(nc, chunk, *a_.shape[1:]), inputs
        )

        @jax.checkpoint
        def chunk_body(h, ins):
            h2, ys = jax.lax.scan(step, h, ins)
            return h2, ys

        h_f, ys = jax.lax.scan(chunk_body, h0, chunked)
        ys = ys.reshape(t, b, nh, hd)
    else:
        h_f, ys = jax.lax.scan(step, h0, inputs)
    ys = ys.transpose(1, 0, 2, 3)  # (B, T, nh, hd)
    ys = ys + params["d_skip"][None, None, :, None] * x_heads
    y = ys.reshape(b, t, d_in).astype(x.dtype)
    y = rmsnorm(y * jax.nn.silu(z), params["norm_w"], cfg.rms_eps)
    out = jnp.einsum("btk,kd->btd", y, params["out_proj"])
    return out, (h_f, conv_state)


def init_mamba_cache(cfg: ModelConfig, batch: int, dtype) -> dict:
    d_in, nh, hd, ds = _dims(cfg)
    conv_ch = d_in + 2 * ds
    return {
        "h": jnp.zeros((batch, nh, hd, ds), dtype=jnp.float32),
        "conv": jnp.zeros((batch, cfg.conv_width - 1, conv_ch), dtype=dtype),
    }


def mamba_decode(params: dict, cfg: ModelConfig, x: jnp.ndarray, cache: dict):
    """One-token step. ``x: (B, 1, D)`` -> ``(out, new_cache)``."""
    out, (h, conv) = mamba_forward(params, cfg, x, h0=cache["h"], conv0=cache["conv"])
    return out, {"h": h, "conv": conv}
