"""Shared model building blocks (pure JAX, params as pytrees of arrays).

Conventions:
* Parameters are nested dicts of ``jnp.ndarray``; init functions take an
  explicit PRNG key and return the pytree. Everything works under
  ``jax.eval_shape`` (the dry-run never allocates).
* Compute dtype is the config dtype (bf16 by default); normalizations and
  softmax statistics accumulate in fp32.
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig
from ..kernels import ops

__all__ = [
    "dense_init",
    "rmsnorm_init",
    "rmsnorm",
    "embedding_init",
    "rope_angles",
    "apply_rope",
    "apply_mrope",
    "mlp_init",
    "mlp_apply",
    "chunked_cross_entropy",
    "soft_cap",
]


def dtype_of(cfg: ModelConfig):
    return jnp.dtype(cfg.dtype)


def dense_init(key, d_in: int, d_out: int, dtype, scale: Optional[float] = None):
    if scale is None:
        scale = d_in**-0.5
    return (jax.random.normal(key, (d_in, d_out), dtype=jnp.float32) * scale).astype(
        dtype
    )


def rmsnorm_init(d: int, dtype) -> jnp.ndarray:
    return jnp.ones((d,), dtype=dtype)


def rmsnorm(x: jnp.ndarray, w: jnp.ndarray, eps: float) -> jnp.ndarray:
    return ops.rmsnorm(x, w, eps)


def embedding_init(key, vocab: int, d: int, dtype) -> jnp.ndarray:
    return (jax.random.normal(key, (vocab, d), dtype=jnp.float32) * d**-0.5).astype(
        dtype
    )


def soft_cap(x: jnp.ndarray, cap: Optional[float]) -> jnp.ndarray:
    if cap is None:
        return x
    return jnp.tanh(x / cap) * cap


# ---------------------------------------------------------------------------
# Rotary embeddings (RoPE + M-RoPE)
# ---------------------------------------------------------------------------


def rope_angles(positions: jnp.ndarray, head_dim: int, theta: float) -> jnp.ndarray:
    """``positions (..., T) -> angles (..., T, head_dim//2)`` in fp32."""
    half = head_dim // 2
    freq = theta ** (-jnp.arange(half, dtype=jnp.float32) / half)
    return positions[..., None].astype(jnp.float32) * freq


def _rotate(x: jnp.ndarray, angles: jnp.ndarray) -> jnp.ndarray:
    """Rotate pairs (x1, x2) of the last dim by ``angles``.

    ``x: (B, T, H, hd)``, ``angles: (B, T, hd//2)`` (broadcast over heads).
    """
    half = x.shape[-1] // 2
    x1 = x[..., :half].astype(jnp.float32)
    x2 = x[..., half:].astype(jnp.float32)
    cos = jnp.cos(angles)[..., None, :]
    sin = jnp.sin(angles)[..., None, :]
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


def apply_rope(
    x: jnp.ndarray, positions: jnp.ndarray, theta: float
) -> jnp.ndarray:
    """Standard RoPE. ``x: (B, T, H, hd)``, ``positions: (B, T)``."""
    angles = rope_angles(positions, x.shape[-1], theta)  # (B, T, hd/2)
    return _rotate(x, angles)


def apply_mrope(
    x: jnp.ndarray,
    positions: jnp.ndarray,
    theta: float,
    sections: tuple,
) -> jnp.ndarray:
    """Multimodal RoPE (Qwen2-VL): the rotary spectrum is split into
    ``sections`` frequency bands, each driven by its own position stream
    (temporal / height / width). ``positions: (B, 3, T)``; ``sum(sections)
    == head_dim // 2``.
    """
    half = x.shape[-1] // 2
    if sum(sections) != half:
        raise ValueError(f"mrope sections {sections} must sum to head_dim/2={half}")
    freq = theta ** (-jnp.arange(half, dtype=jnp.float32) / half)
    parts = []
    start = 0
    for i, width in enumerate(sections):
        pos_i = positions[:, i, :].astype(jnp.float32)  # (B, T)
        parts.append(pos_i[..., None] * freq[start : start + width])
        start += width
    angles = jnp.concatenate(parts, axis=-1)  # (B, T, hd/2)
    return _rotate(x, angles)


# ---------------------------------------------------------------------------
# Gated MLP (SwiGLU / GeGLU)
# ---------------------------------------------------------------------------


def mlp_init(key, d: int, d_ff: int, dtype) -> dict:
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "w_gate": dense_init(k1, d, d_ff, dtype),
        "w_up": dense_init(k2, d, d_ff, dtype),
        "w_down": dense_init(k3, d_ff, d, dtype),
    }


def mlp_apply(params: dict, x: jnp.ndarray, act: str = "silu") -> jnp.ndarray:
    gate = jnp.einsum("btd,df->btf", x, params["w_gate"])
    up = jnp.einsum("btd,df->btf", x, params["w_up"])
    a = jax.nn.silu(gate) if act == "silu" else jax.nn.gelu(gate)
    return jnp.einsum("btf,fd->btd", a * up, params["w_down"])


# ---------------------------------------------------------------------------
# Chunked vocab cross-entropy — never materializes (tokens, vocab) logits
# ---------------------------------------------------------------------------


def chunked_cross_entropy(
    hidden: jnp.ndarray,
    w_vocab: jnp.ndarray,
    labels: jnp.ndarray,
    chunk: int = 2048,
    final_softcap: Optional[float] = None,
    shard_fn=None,
) -> jnp.ndarray:
    """Mean NLL over tokens, computed in token chunks.

    Args:
      hidden: ``(B, T, D)`` final hidden states.
      w_vocab: ``(D, V)`` output projection (tied embedding transpose or
        untied lm_head).
      labels: ``(B, T)`` int32 targets; ``-1`` marks padding (ignored).
      chunk: tokens per chunk; peak live logits are ``chunk x V``.
      shard_fn: optional activation-constraint hook — applied to each logits
        chunk (kind='logits') so the vocab dim stays model-sharded; the gold
        logit is extracted with an iota mask (not a gather) so the whole
        chunk partitions elementwise over the sharded vocab dim.
    """
    b, t, d = hidden.shape
    n = b * t
    h = hidden.reshape(n, d)
    y = labels.reshape(n)
    chunk = min(chunk, n)
    pad = (-n) % chunk
    if pad:
        h = jnp.pad(h, ((0, pad), (0, 0)))
        y = jnp.pad(y, (0, pad), constant_values=-1)
    n_chunks = h.shape[0] // chunk
    h = h.reshape(n_chunks, chunk, d)
    y = y.reshape(n_chunks, chunk)
    v = w_vocab.shape[1]

    def body(carry, inputs):
        loss_sum, count = carry
        hc, yc = inputs
        logits = jnp.einsum("cd,dv->cv", hc, w_vocab).astype(jnp.float32)
        logits = soft_cap(logits, final_softcap)
        if shard_fn is not None:
            logits = shard_fn(logits, "logits")
        lse = jax.nn.logsumexp(logits, axis=-1)
        gold_mask = jax.lax.broadcasted_iota(jnp.int32, (chunk, v), 1) == jnp.maximum(
            yc, 0
        )[:, None]
        gold = jnp.sum(jnp.where(gold_mask, logits, 0.0), axis=-1)
        valid = (yc >= 0).astype(jnp.float32)
        loss_sum = loss_sum + jnp.sum((lse - gold) * valid)
        count = count + jnp.sum(valid)
        return (loss_sum, count), None

    (loss_sum, count), _ = jax.lax.scan(
        body, (jnp.float32(0.0), jnp.float32(0.0)), (h, y)
    )
    return loss_sum / jnp.maximum(count, 1.0)
