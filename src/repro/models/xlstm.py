"""xLSTM blocks: mLSTM (matrix memory) and sLSTM (scalar memory).

Following arXiv:2405.04517 with exponential gating and max-state
stabilization:

mLSTM (per head, state ``C: (hd, hd)``, normalizer ``n: (hd,)``, max ``m``):

    m_t = max(f̃_t + m_{t-1}, ĩ_t)
    f_t = exp(f̃_t + m_{t-1} - m_t);  i_t = exp(ĩ_t - m_t)
    C_t = f_t C_{t-1} + i_t (v_t k_t^T);  n_t = f_t n_{t-1} + i_t k_t
    y_t = o_t ⊙ (C_t q_t) / max(|n_t · q_t|, 1)

sLSTM is the scalar-memory analogue over units. Both are ``lax.scan``
recurrences (O(1) state per token ⇒ sub-quadratic; xlstm-125m runs
long_500k). Blocks carry their own projections (the assignment's d_ff=0).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig
from .layers import dense_init, rmsnorm, rmsnorm_init

__all__ = [
    "mlstm_init",
    "mlstm_forward",
    "slstm_init",
    "slstm_forward",
    "init_mlstm_cache",
    "init_slstm_cache",
]


def mlstm_init(key, cfg: ModelConfig, dtype) -> dict:
    d = cfg.d_model
    d_in = 2 * d  # up-projection factor 2
    h = cfg.num_heads
    hd = d_in // h
    ks = jax.random.split(key, 7)
    return {
        "w_up": dense_init(ks[0], d, 2 * d_in, dtype),  # (x_m, z)
        "wq": dense_init(ks[1], d_in, d_in, dtype),
        "wk": dense_init(ks[2], d_in, d_in, dtype),
        "wv": dense_init(ks[3], d_in, d_in, dtype),
        "w_gates": dense_init(ks[4], d_in, 3 * h, dtype),  # i, f, o per head
        "norm_w": rmsnorm_init(d_in, dtype),
        "w_down": dense_init(ks[5], d_in, d, dtype),
    }


def _mlstm_scan(q, k, v, gi, gf, go, state):
    """``q/k/v: (B, T, H, hd)``, gates ``(B, T, H)``; state=(C, n, m)."""
    hd = q.shape[-1]
    scale = hd**-0.5

    def step(carry, ins):
        c, n, m = carry
        qt, kt, vt, it, ft, ot = ins
        m_new = jnp.maximum(ft + m, it)
        f = jnp.exp(ft + m - m_new)
        i = jnp.exp(it - m_new)
        c = f[..., None, None] * c + i[..., None, None] * (
            vt[..., :, None] * kt[..., None, :]
        )
        n = f[..., None] * n + i[..., None] * kt
        num = jnp.einsum("bhvk,bhk->bhv", c, qt * scale)
        den = jnp.maximum(jnp.abs(jnp.einsum("bhk,bhk->bh", n, qt * scale)), 1.0)
        y = jax.nn.sigmoid(ot)[..., None] * num / den[..., None]
        return (c, n, m_new), y

    xs = tuple(
        a.transpose(1, 0, 2, 3) if a.ndim == 4 else a.transpose(1, 0, 2)
        for a in (q, k, v, gi, gf, go)
    )
    state, ys = _chunked_scan(step, state, xs)
    return ys.transpose(1, 0, 2, 3), state


def _chunked_scan(step, state, xs, chunk: int = 128):
    """Chunked remat scan: O(T/chunk) stored states instead of O(T) — the
    mLSTM matrix memory (hd x hd per head) is far too big to store per step
    in the backward pass (see mamba.py for the same pattern)."""
    t = jax.tree.leaves(xs)[0].shape[0]
    chunk = min(chunk, t)
    if t % chunk or t == chunk:
        return jax.lax.scan(step, state, xs)
    nc = t // chunk
    chunked = jax.tree.map(lambda a: a.reshape(nc, chunk, *a.shape[1:]), xs)

    @jax.checkpoint
    def chunk_body(carry, ins):
        return jax.lax.scan(step, carry, ins)

    state, ys = jax.lax.scan(chunk_body, state, chunked)
    ys = jax.tree.map(lambda a: a.reshape(t, *a.shape[2:]), ys)
    return state, ys


def mlstm_forward(params: dict, cfg: ModelConfig, x: jnp.ndarray, cache=None):
    b, t, d = x.shape
    h = cfg.num_heads
    up = jnp.einsum("btd,dk->btk", x, params["w_up"])
    x_m, z = jnp.split(up, 2, axis=-1)
    d_in = x_m.shape[-1]
    hd = d_in // h
    q = jnp.einsum("btk,kj->btj", x_m, params["wq"]).reshape(b, t, h, hd)
    k = jnp.einsum("btk,kj->btj", x_m, params["wk"]).reshape(b, t, h, hd)
    v = jnp.einsum("btk,kj->btj", x_m, params["wv"]).reshape(b, t, h, hd)
    gates = jnp.einsum("btk,kj->btj", x_m, params["w_gates"]).astype(jnp.float32)
    gi, gf, go = jnp.split(gates.reshape(b, t, 3, h), 3, axis=2)
    gi, gf, go = gi[:, :, 0], gf[:, :, 0], go[:, :, 0]
    if cache is None:
        cache = init_mlstm_cache_dims(b, h, hd)
    qf, kf, vf = (a.astype(jnp.float32) for a in (q, k, v))
    ys, state = _mlstm_scan(qf, kf, vf, gi, gf, go, cache)
    y = ys.reshape(b, t, d_in).astype(x.dtype)
    y = rmsnorm(y * jax.nn.silu(z), params["norm_w"], cfg.rms_eps)
    out = jnp.einsum("btk,kd->btd", y, params["w_down"])
    return out, state


def init_mlstm_cache_dims(b: int, h: int, hd: int):
    return (
        jnp.zeros((b, h, hd, hd), dtype=jnp.float32),
        jnp.zeros((b, h, hd), dtype=jnp.float32),
        jnp.full((b, h), -1e30, dtype=jnp.float32),
    )


def init_mlstm_cache(cfg: ModelConfig, batch: int):
    d_in = 2 * cfg.d_model
    hd = d_in // cfg.num_heads
    return init_mlstm_cache_dims(batch, cfg.num_heads, hd)


def slstm_init(key, cfg: ModelConfig, dtype) -> dict:
    d = cfg.d_model
    ks = jax.random.split(key, 4)
    d_ff = int(d * 4 / 3)
    return {
        "w_gates": dense_init(ks[0], d, 4 * d, dtype),  # z, i, f, o per unit
        "norm_w": rmsnorm_init(d, dtype),
        "w_ff1": dense_init(ks[1], d, 2 * d_ff, dtype),
        "w_ff2": dense_init(ks[2], d_ff, d, dtype),
    }


def slstm_forward(params: dict, cfg: ModelConfig, x: jnp.ndarray, cache=None):
    """Scalar-memory LSTM with exponential gating + GeGLU channel mix."""
    b, t, d = x.shape
    gates = jnp.einsum("btd,dk->btk", x, params["w_gates"]).astype(jnp.float32)
    z, gi, gf, go = jnp.split(gates, 4, axis=-1)  # each (B, T, d)
    if cache is None:
        cache = init_slstm_cache_dims(b, d)

    def step(carry, ins):
        c, n, m = carry
        zt, it, ft, ot = ins
        m_new = jnp.maximum(ft + m, it)
        f = jnp.exp(ft + m - m_new)
        i = jnp.exp(it - m_new)
        c = f * c + i * jnp.tanh(zt)
        n = f * n + i
        y = jax.nn.sigmoid(ot) * c / jnp.maximum(n, 1.0)
        return (c, n, m_new), y

    xs = tuple(a.transpose(1, 0, 2) for a in (z, gi, gf, go))
    state, ys = _chunked_scan(step, cache, xs)
    y = ys.transpose(1, 0, 2).astype(x.dtype)
    y = rmsnorm(y, params["norm_w"], cfg.rms_eps)
    ff = jnp.einsum("btd,dk->btk", y, params["w_ff1"])
    a, g = jnp.split(ff, 2, axis=-1)
    out = jnp.einsum("btf,fd->btd", jax.nn.gelu(a) * g, params["w_ff2"])
    return out, state


def init_slstm_cache_dims(b: int, d: int):
    return (
        jnp.zeros((b, d), dtype=jnp.float32),
        jnp.zeros((b, d), dtype=jnp.float32),
        jnp.full((b, d), -1e30, dtype=jnp.float32),
    )


def init_slstm_cache(cfg: ModelConfig, batch: int):
    return init_slstm_cache_dims(batch, cfg.d_model)
