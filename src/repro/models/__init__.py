"""Architecture zoo: pure-JAX model definitions for the assigned archs."""

from .moe import EpInfo, moe_apply, moe_init
from .transformer import decode_fn, init_cache, init_params, loss_fn, prefill_fn

__all__ = [
    "EpInfo",
    "decode_fn",
    "init_cache",
    "init_params",
    "loss_fn",
    "moe_apply",
    "moe_init",
    "prefill_fn",
]
