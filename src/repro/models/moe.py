"""Mixture-of-Experts layer with RailS-scheduled expert-parallel dispatch.

Layout strategy (DESIGN.md §4.2):

* Tokens are flattened ``(B, T, D) -> (Ntot, D)`` and factored
  ``(ep, G, Tg, D)``: ``ep`` = expert-parallel shards (manual axis inside a
  partial ``shard_map``), ``G`` = dispatch groups (auto-sharded over the
  data axis), ``Tg`` = tokens per group (capacity is per group, so all
  scatter/cumsum work stays group-local and partitions cleanly).
* Dispatch: per group, top-k routing -> capacity-bounded buckets
  ``(E, C, D)`` -> all-to-all over the ``expert`` axis. The all-to-all is
  the paper's target collective: ``cfg.dispatch_mode`` selects
  ``dense`` (one monolithic collective), ``ring``, ``rails`` (LPT-scheduled
  N-rail spraying — the paper), or ``spray`` (Theorem-3 1/N feature spray).
* Expert FFN: grouped GEMM over local experts (Pallas kernel on TPU when
  running in a fully-manual region; einsum under auto partitioning).
* Combine: inverse all-to-all, per-group gather, weighted sum over k.

Decode-sized batches (a handful of tokens) use a dense-EP path instead:
every expert shard computes its local experts for all tokens and the
results sum across the expert axis — no dispatch, no capacity drops.

The gating count vector (the paper's "known traffic matrix" ``D``) is
returned to the caller for the host-side LPT planner.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from ..configs.base import ModelConfig
from ..compat import shard_map as compat_shard_map
from ..core.rails_all_to_all import build_rail_schedule, rails_all_to_all, ring_all_to_all, spray_all_to_all, dense_all_to_all
from .layers import dense_init

__all__ = ["moe_init", "moe_apply", "EpInfo"]


class EpInfo:
    """Expert-parallel context: mesh + axis names for the partial shard_map."""

    def __init__(self, mesh, expert_axis: str, ep: int, data_axis: str = "data"):
        self.mesh = mesh
        self.expert_axis = expert_axis
        self.ep = ep
        self.data_axis = data_axis


def moe_init(key, cfg: ModelConfig, dtype) -> dict:
    d, e, f = cfg.d_model, cfg.num_experts, cfg.moe_d_ff
    ks = jax.random.split(key, 4)
    scale_in, scale_out = d**-0.5, f**-0.5

    def expert_w(k, d_in, d_out, scale):
        return (
            jax.random.normal(k, (e, d_in, d_out), dtype=jnp.float32) * scale
        ).astype(dtype)

    return {
        "router": dense_init(ks[0], d, e, jnp.float32),  # router math in fp32
        "w_gate": expert_w(ks[1], d, f, scale_in),
        "w_up": expert_w(ks[2], d, f, scale_in),
        "w_down": expert_w(ks[3], f, d, scale_out),
    }


def _gate(x2: jnp.ndarray, router: jnp.ndarray, cfg: ModelConfig):
    """Top-k routing. ``x2: (..., D)`` -> idx/weights ``(..., k)``, aux, counts."""
    logits = jnp.einsum("...d,de->...e", x2.astype(jnp.float32), router)
    probs = jax.nn.softmax(logits, axis=-1)
    weights, idx = jax.lax.top_k(probs, cfg.experts_per_token)
    weights = weights / jnp.sum(weights, axis=-1, keepdims=True)
    e = cfg.num_experts
    onehot = jax.nn.one_hot(idx, e, dtype=jnp.float32)  # (..., k, E)
    frac = jnp.mean(jnp.sum(onehot, axis=-2), axis=tuple(range(onehot.ndim - 2)))
    prob_mean = jnp.mean(probs, axis=tuple(range(probs.ndim - 1)))
    aux = e * jnp.sum(frac * prob_mean) / cfg.experts_per_token
    counts = jnp.sum(onehot, axis=tuple(range(onehot.ndim - 1))).astype(jnp.int32)
    return idx, weights.astype(x2.dtype), aux, counts


def _dispatch_group(x_g, idx_g, w_g, num_experts: int, cap: int):
    """One group's capacity dispatch. ``x_g: (Tg, D)``, ``idx_g/w_g: (Tg, k)``.

    Returns buckets ``(E, C, D)`` plus (flat_e, slot, keep, w_flat) for the
    combine gather.
    """
    tg, k = idx_g.shape
    d = x_g.shape[-1]
    flat_e = idx_g.reshape(-1)  # (Tg*k,)
    onehot = jax.nn.one_hot(flat_e, num_experts, dtype=jnp.int32)
    pos = jnp.cumsum(onehot, axis=0) - 1
    pos = jnp.sum(pos * onehot, axis=-1)  # (Tg*k,) position within expert
    keep = pos < cap
    slot = jnp.minimum(pos, cap - 1)
    x_rep = jnp.repeat(x_g, k, axis=0)  # (Tg*k, D)
    contrib = x_rep * keep[:, None].astype(x_g.dtype)
    buckets = jnp.zeros((num_experts, cap, d), dtype=x_g.dtype)
    buckets = buckets.at[flat_e, slot].add(contrib)
    return buckets, (flat_e, slot, keep, w_g.reshape(-1))


def _combine_group(buckets_out, meta, tg: int, k: int):
    flat_e, slot, keep, w_flat = meta
    vals = buckets_out[flat_e, slot]  # (Tg*k, D)
    vals = vals * (keep.astype(vals.dtype) * w_flat)[:, None]
    return vals.reshape(tg, k, -1).sum(axis=1)


def _expert_ffn(xe: jnp.ndarray, params: dict, cfg: ModelConfig, local_slice=None):
    """Grouped FFN. ``xe: (E_loc, M, D)`` -> ``(E_loc, M, D)``.

    ``local_slice`` selects this shard's experts from the stacked weights
    (inside shard_map the weights arrive already sliced — pass None).
    """
    wg, wu, wd = params["w_gate"], params["w_up"], params["w_down"]
    if local_slice is not None:
        wg, wu, wd = wg[local_slice], wu[local_slice], wd[local_slice]
    gate = jnp.einsum("gnd,gdf->gnf", xe, wg)
    up = jnp.einsum("gnd,gdf->gnf", xe, wu)
    act = jax.nn.silu(gate) if cfg.act == "silu" else jax.nn.gelu(gate)
    return jnp.einsum("gnf,gfd->gnd", act * up, wd)


def _a2a(payload: jnp.ndarray, axis: Optional[str], cfg: ModelConfig):
    """The paper's collective. ``payload: (ep, G, ...)``, dim0 = peer."""
    if axis is None or payload.shape[0] == 1:
        return payload
    mode = cfg.dispatch_mode
    if mode == "dense":
        return dense_all_to_all(payload, axis)
    if mode == "ring":
        return ring_all_to_all(payload, axis)
    if mode == "spray":
        return spray_all_to_all(payload, axis, cfg.num_rails)
    if mode == "rails":
        chunks = max(1, min(cfg.dispatch_chunks, payload.shape[1]))
        sched = build_rail_schedule(payload.shape[0], cfg.num_rails, chunks)
        return rails_all_to_all(payload, axis, sched)
    raise ValueError(f"unknown dispatch_mode {cfg.dispatch_mode!r}")


def _moe_body(x_sh, params, cfg: ModelConfig, ep: int, axis: Optional[str]):
    """Per-expert-shard MoE. ``x_sh: (1|ep_local, G, Tg, D)`` (dim0 manual)."""
    e = cfg.num_experts
    e_loc = e // ep
    x_loc = x_sh[0]  # (G, Tg, D) — shard-local view
    g, tg, d = x_loc.shape
    cap = max(1, int(tg * cfg.experts_per_token * cfg.capacity_factor / e))

    idx, w, aux, counts = _gate(x_loc, params["router"], cfg)
    buckets, meta = jax.vmap(
        functools.partial(_dispatch_group, num_experts=e, cap=cap)
    )(x_loc, idx, w)  # (G, E, C, D)

    payload = buckets.reshape(g, ep, e_loc, cap, d).transpose(1, 0, 2, 3, 4)
    payload = _a2a(payload, axis, cfg)  # (ep, G, E_loc, C, D) dim0 = source
    xe = payload.transpose(2, 0, 1, 3, 4).reshape(e_loc, ep * g * cap, d)

    # Inside shard_map the expert weights arrive pre-sliced to E_loc.
    local = {k: params[k] for k in ("w_gate", "w_up", "w_down")}
    ye = _expert_ffn(xe, local, cfg)

    back = ye.reshape(e_loc, ep, g, cap, d).transpose(1, 2, 0, 3, 4)
    back = _a2a(back, axis, cfg)  # (ep, G, E_loc, C, D) dim0 = dest-expert shard
    buckets_out = back.transpose(1, 0, 2, 3, 4).reshape(g, e, cap, d)

    out = jax.vmap(functools.partial(_combine_group, tg=tg, k=cfg.experts_per_token))(
        buckets_out, meta
    )
    return out[None], aux[None], counts[None]  # restore manual dim


def _moe_dense_small(x2, params, cfg: ModelConfig):
    """Dense-EP path for decode-sized token counts: all experts computed for
    all tokens (weights sharded over the expert axis; XLA reduces)."""
    idx, w, aux, counts = _gate(x2, params["router"], cfg)
    e = cfg.num_experts
    gates = jnp.zeros((x2.shape[0], e), dtype=x2.dtype)
    gates = jax.vmap(lambda g_row, i_row, w_row: g_row.at[i_row].add(w_row))(
        gates, idx, w
    )
    gate_h = jnp.einsum("nd,edf->nef", x2, params["w_gate"])
    up_h = jnp.einsum("nd,edf->nef", x2, params["w_up"])
    act = jax.nn.silu(gate_h) if cfg.act == "silu" else jax.nn.gelu(gate_h)
    ye = jnp.einsum("nef,efd->ned", act * up_h, params["w_down"])
    out = jnp.einsum("ned,ne->nd", ye, gates)
    return out, aux, counts


def moe_apply(
    params: dict,
    cfg: ModelConfig,
    x: jnp.ndarray,
    ep_info: Optional[EpInfo] = None,
    group_tokens: int = 1024,
):
    """MoE layer. ``x: (B, T, D)`` -> ``(out, aux_loss, gating_counts)``."""
    b, t, d = x.shape
    n = b * t
    ep = ep_info.ep if ep_info is not None else 1
    x2 = x.reshape(n, d)

    # Decode-sized batches: dense-EP, no dispatch (and no capacity drops).
    if n < ep * 8 or n % ep != 0:
        out, aux, counts = _moe_dense_small(x2, params, cfg)
        return out.reshape(b, t, d), aux, counts

    rows = n // ep
    tg = min(group_tokens, rows)
    while rows % tg:
        tg -= 1
    g = rows // tg
    x4 = x2.reshape(ep, g, tg, d)

    if ep_info is None or ep == 1:
        out, aux, counts = _moe_body(x4, params, cfg, 1, None)
        out = out.reshape(n, d)
        return out.reshape(b, t, d), aux[0], counts[0]

    axis = ep_info.expert_axis
    body = functools.partial(_moe_body, cfg=cfg, ep=ep, axis=axis)
    pspec = {
        "router": P(),
        "w_gate": P(axis, None, None),
        "w_up": P(axis, None, None),
        "w_down": P(axis, None, None),
    }
    out, aux, counts = compat_shard_map(
        lambda xs, pr: body(xs, pr),
        mesh=ep_info.mesh,
        in_specs=(P(axis, None, None, None), pspec),
        out_specs=(P(axis, None, None, None), P(axis), P(axis, None)),
        axis_names={axis},
    )(x4, params)
    out = out.reshape(n, d)
    # aux/counts are per-shard; average/sum across shards happens in fp32
    # outside (they are tiny).
    return out.reshape(b, t, d), jnp.mean(aux), jnp.sum(counts, axis=0)
