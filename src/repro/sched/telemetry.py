"""Telemetry for simulated collectives: timelines, histograms, traces.

The engine reports two event kinds to its observers: a *service interval*
(one chunk occupying one link for ``[start, end)``) and a *chunk
completion*. :class:`TraceRecorder` buffers both and derives:

* **per-link utilization timelines** — busy fraction per time bin, the
  view that makes stragglers and incast collapse visible at a glance;
* **per-rail completion histograms** — when each rail's chunks finish, the
  receive-side balance evidence behind the paper's MSE metric;
* **Chrome-trace JSON export** — open in ``chrome://tracing`` / Perfetto:
  one row per link, one slice per chunk service.

Everything here is read-only with respect to the simulation: recording
never perturbs scheduling decisions.
"""

from __future__ import annotations

import dataclasses
import json

import numpy as np

__all__ = ["ServiceRecord", "TraceRecorder"]


@dataclasses.dataclass(frozen=True)
class ServiceRecord:
    """One chunk's occupancy of one link."""

    link: str
    start: float
    end: float
    size: float
    chunk_id: int
    flow_id: int
    src_domain: int
    dst_domain: int
    round_id: int


class TraceRecorder:
    """Engine observer that accumulates service intervals and completions."""

    def __init__(self) -> None:
        self.services: list[ServiceRecord] = []
        self.completions: list[tuple[int, int, float]] = []  # (chunk_id, round_id, t)
        self._completion_rail: list[int] = []  # last-hop rail per completion

    # -- engine observer protocol -------------------------------------------

    def record_service(self, link: str, start: float, end: float, job) -> None:
        self.services.append(
            ServiceRecord(
                link=link,
                start=start,
                end=end,
                size=job.size,
                chunk_id=job.chunk_id,
                flow_id=job.flow_id,
                src_domain=job.src_domain,
                dst_domain=job.dst_domain,
                round_id=job.round_id,
            )
        )

    def record_completion(self, job, t: float) -> None:
        self.completions.append((job.chunk_id, job.round_id, t))
        last = job.path[-1] if job.path else "down:0:0"
        self._completion_rail.append(int(last.split(":")[2]))

    # -- derived views -------------------------------------------------------

    @property
    def makespan(self) -> float:
        return max((s.end for s in self.services), default=0.0)

    def link_utilization(
        self, num_bins: int = 50, links: list[str] | None = None
    ) -> tuple[np.ndarray, dict[str, np.ndarray]]:
        """Busy-fraction timeline per link.

        Returns ``(bin_edges, {link: (num_bins,) busy fraction})`` — edges
        have ``num_bins + 1`` entries over ``[0, makespan]``.
        """
        span = self.makespan
        edges = np.linspace(0.0, span if span > 0 else 1.0, num_bins + 1)
        width = edges[1] - edges[0]
        wanted = None if links is None else set(links)
        out: dict[str, np.ndarray] = {}
        for s in self.services:
            if wanted is not None and s.link not in wanted:
                continue
            tl = out.setdefault(s.link, np.zeros(num_bins))
            lo = int(np.searchsorted(edges, s.start, side="right")) - 1
            hi = int(np.searchsorted(edges, s.end, side="left"))
            for b in range(max(lo, 0), min(hi, num_bins)):
                overlap = min(s.end, edges[b + 1]) - max(s.start, edges[b])
                if overlap > 0:
                    tl[b] += overlap / width
        return edges, out

    def rail_utilization(self, num_rails: int, num_bins: int = 50) -> tuple[np.ndarray, np.ndarray]:
        """Mean NIC-link busy fraction per rail: ``(edges, (N, num_bins))``."""
        edges, per_link = self.link_utilization(num_bins=num_bins)
        agg = np.zeros((num_rails, num_bins))
        counts = np.zeros(num_rails)
        for link, tl in per_link.items():
            kind, _d, rail = link.split(":")
            if kind in ("up", "down"):
                agg[int(rail)] += tl
                counts[int(rail)] += 1
        nonzero = counts > 0
        agg[nonzero] /= counts[nonzero, None]
        return edges, agg

    def rail_completion_histogram(
        self, num_rails: int, num_bins: int = 20
    ) -> tuple[np.ndarray, np.ndarray]:
        """Histogram of chunk completion times per delivery rail.

        Returns ``(bin_edges, (N, num_bins) counts)``. A balanced collective
        shows near-identical rows; a hot rail shows a long right tail.
        """
        times = np.array([t for _c, _r, t in self.completions])
        rails = np.array(self._completion_rail, dtype=np.int64)
        span = float(times.max()) if times.size else 1.0
        edges = np.linspace(0.0, span, num_bins + 1)
        hist = np.zeros((num_rails, num_bins))
        for rail in range(num_rails):
            if np.any(rails == rail):
                hist[rail], _ = np.histogram(times[rails == rail], bins=edges)
        return edges, hist

    def round_latencies(self) -> dict[int, tuple[float, float]]:
        """Per streaming round: (first completion, last completion)."""
        out: dict[int, tuple[float, float]] = {}
        for _c, rnd, t in self.completions:
            lo, hi = out.get(rnd, (t, t))
            out[rnd] = (min(lo, t), max(hi, t))
        return out

    # -- Chrome trace export -------------------------------------------------

    def to_chrome_trace(self, time_scale: float = 1e6) -> dict:
        """Trace-event JSON (chrome://tracing / Perfetto).

        Links become threads grouped into processes by link kind; each
        service interval is a complete ("X") slice. ``time_scale`` converts
        simulated seconds to trace microseconds.
        """
        pids = {"up": 0, "down": 1, "l2s": 2, "s2l": 2}
        pid_names = {0: "NIC TX (up-links)", 1: "NIC RX (down-links)", 2: "spine"}
        tids: dict[str, int] = {}
        events: list[dict] = []
        for pid, name in pid_names.items():
            events.append(
                {"ph": "M", "name": "process_name", "pid": pid, "tid": 0,
                 "args": {"name": name}}
            )
        for s in self.services:
            kind = s.link.split(":")[0]
            pid = pids.get(kind, 3)
            if s.link not in tids:
                tids[s.link] = len(tids)
                events.append(
                    {"ph": "M", "name": "thread_name", "pid": pid,
                     "tid": tids[s.link], "args": {"name": s.link}}
                )
            events.append(
                {
                    "ph": "X",
                    "name": f"chunk{s.chunk_id} f{s.flow_id} r{s.round_id}",
                    "cat": kind,
                    "pid": pid,
                    "tid": tids[s.link],
                    "ts": s.start * time_scale,
                    "dur": max((s.end - s.start) * time_scale, 1e-3),
                    "args": {
                        "bytes": s.size,
                        "src_domain": s.src_domain,
                        "dst_domain": s.dst_domain,
                        "round": s.round_id,
                    },
                }
            )
        return {"traceEvents": events, "displayTimeUnit": "ms"}

    def dump_chrome_trace(self, path: str, time_scale: float = 1e6) -> None:
        with open(path, "w") as f:
            json.dump(self.to_chrome_trace(time_scale=time_scale), f)
