"""Multi-round streaming driver: overlap round k's tail with k+1's head.

Training emits one all-to-all per MoE layer per micro-batch; running them
back-to-back leaves the fabric idle whenever a round's stragglers drain.
This driver releases round k+1 a configurable fraction of round k's
Theorem-2 optimal time after round k — the head of the next round fills
the tail slack of the current one, and the online policy's persistent
LoadState keeps the union balanced across round boundaries.

The driver also owns the iteration-scale feedback loops: a
:class:`~repro.sched.online.RoutingReplayState` warmed from the first
round (standing in for "the previous training iteration"), and an
:class:`~repro.sched.online.AdaptiveChunker` that sizes atomic chunks from
the replayed totals.
"""

from __future__ import annotations

import dataclasses

import numpy as np

from ..core.theorems import theorem2_optimal_time
from ..core.traffic import TrafficMatrix
from .online import AdaptiveChunker, RoutingReplayState

__all__ = ["PipelineResult", "plan_releases", "run_pipeline"]


@dataclasses.dataclass
class PipelineResult:
    """Outcome of a multi-round streaming run."""

    streaming: object  # netsim.simulate.StreamingResult
    releases: list[float]
    round_cct: dict[int, float]  # round -> absolute completion time
    round_latency: dict[int, float]  # round -> completion minus release
    sequential_makespan: float | None  # sum of standalone rounds, if computed
    chunk_bytes: float

    @property
    def makespan(self) -> float:
        return self.streaming.metrics.makespan

    @property
    def overlap_speedup(self) -> float | None:
        """Sequential-sum over pipelined makespan (>1 = overlap pays)."""
        if self.sequential_makespan is None or self.makespan <= 0:
            return None
        return self.sequential_makespan / self.makespan


def plan_releases(
    tms: list[TrafficMatrix],
    gap_fraction: float,
    r2: float,
) -> list[float]:
    """Release times: round k+1 starts ``gap_fraction`` of round k's
    Theorem-2 optimum after round k (1.0 = optimum-paced back-to-back,
    smaller = deeper overlap, 0.0 = everything at once)."""
    if not 0.0 <= gap_fraction:
        raise ValueError("gap_fraction must be >= 0")
    releases = [0.0]
    for tm in tms[:-1]:
        opt = theorem2_optimal_time(tm.d2, tm.num_rails, r2)
        releases.append(releases[-1] + gap_fraction * opt)
    return releases


def run_pipeline(
    tms: list[TrafficMatrix],
    policy: str = "rails-online",
    gap_fraction: float = 0.5,
    chunk_bytes: float | None = None,
    r1: float = 400e9,
    r2: float = 50e9,
    seed: int = 0,
    rail_speeds=None,
    fault_spec=None,
    feedback: bool = False,
    window: int | None = None,
    use_replay: bool = True,
    recorder=None,
    compare_sequential: bool = False,
    releases: list[float] | None = None,
    backend: str = "event",
) -> PipelineResult:
    """Run a sequence of rounds as one overlapped streaming collective.

    Args:
      tms: per-round traffic matrices (micro-batches / iterations).
      fault_spec: optional :class:`repro.netsim.linkmodel.FaultSpec` — the
        link-dynamics layer (time-varying rails, PFC/ECN/loss), passed
        through to every simulated collective (the standalone rounds of
        ``compare_sequential`` included, so the comparison is
        apples-to-apples on the same faulty fabric).
      chunk_bytes: atomic chunk size; ``None`` lets the
        :class:`AdaptiveChunker` size it from the replayed totals.
      use_replay: warm a :class:`RoutingReplayState` covering the whole
        session (the stand-in for the previous training iteration). The
        forecast sizes chunks when ``chunk_bytes is None`` and — only
        together with ``feedback=True`` — right-sizes the health
        pre-charge before arrivals accumulate; with feedback off and an
        explicit chunk size it has no scheduling effect.
      compare_sequential: additionally simulate each round standalone and
        report the sum of makespans (the no-overlap baseline) — roughly
        doubles the simulation cost.
      releases: explicit per-round release times, overriding
        :func:`plan_releases`. The placement layer uses this to pin every
        placement mode to one arrival process (cadence derived from the
        round-robin lowering) so makespans stay comparable when a
        re-layout shrinks a round's Theorem-2 time.
      backend: simulation engine (``event`` or ``vector``), forwarded to
        :func:`~repro.netsim.simulate.run_streaming_collective` — the
        vector backend carries its usual proactive-planner-only limits.
    """
    # Imported lazily: netsim.simulate pulls in the sched feedback and
    # telemetry modules, so a module-level import here would be circular.
    from ..netsim.simulate import run_streaming_collective

    if not tms:
        raise ValueError("run_pipeline needs at least one round")
    n = tms[0].num_rails
    replay = None
    if use_replay:
        # The previous training iteration ran the same stream of rounds, so
        # its replayed forecast covers the *whole* session's egress — that
        # magnitude is what right-sizes the health pre-charge before most
        # chunks have arrived.
        replay = RoutingReplayState(tms[0].num_domains, n)
        replay.update_from_loads(sum(tm.domain_send_totals() for tm in tms))
    if chunk_bytes is None:
        chunker = AdaptiveChunker(chunk_bytes=4 * 2**20)
        expected = (
            float(np.max(tms[0].domain_send_totals()))
            if replay is None
            else max(replay.expected_total(d) for d in range(tms[0].num_domains))
        )
        chunk_bytes = chunker.suggest(expected, n)
    if releases is None:
        releases = plan_releases(tms, gap_fraction, r2)
    elif len(releases) != len(tms):
        raise ValueError(
            f"releases must have one entry per round, got {len(releases)} for {len(tms)}"
        )
    else:
        releases = [float(t) for t in releases]
    rounds = list(zip(releases, tms))
    streaming = run_streaming_collective(
        rounds,
        policy,
        r1=r1,
        r2=r2,
        chunk_bytes=chunk_bytes,
        seed=seed,
        rail_speeds=rail_speeds,
        fault_spec=fault_spec,
        feedback=feedback,
        window=window,
        replay=replay,
        recorder=recorder,
        backend=backend,
    )
    sequential = None
    if compare_sequential:
        sequential = 0.0
        for i, tm in enumerate(tms):
            solo = run_streaming_collective(
                tm,
                policy,
                r1=r1,
                r2=r2,
                chunk_bytes=chunk_bytes,
                seed=seed + i,
                rail_speeds=rail_speeds,
                fault_spec=fault_spec,
                feedback=feedback,
                window=window,
                backend=backend,
            )
            sequential += solo.metrics.makespan
    # The simulation backends report release-relative sojourns directly
    # (SimResult.round_sojourn_times); the old `cct - releases[rnd]`
    # hand-correction lives in the engine now.
    round_cct = streaming.round_cct
    round_latency = dict(streaming.round_sojourn)
    return PipelineResult(
        streaming=streaming,
        releases=releases,
        round_cct=round_cct,
        round_latency=round_latency,
        sequential_makespan=sequential,
        chunk_bytes=chunk_bytes,
    )
