"""Per-rail health estimation from observed completions.

RailS proper is feedback-free (Theorem 3 makes local LPT globally optimal
*when all rails run at nominal speed*). When a rail degrades — flapping
optics, a slow leaf, PFC storms — byte-balanced plans are no longer
time-balanced. This module closes the loop without giving up the proactive
structure: an EWMA estimator turns observed link-service intervals into
per-rail *speed* estimates, and those speeds are folded into the LPT greedy
as a **pre-charge** of the LoadState (a rail at speed ``s`` starts with
``(1/s - 1)``-proportional phantom load, so the byte-greedy routes around
it exactly as a time-greedy would).

The same pre-charge formula powers :func:`repro.runtime.straggler.
degraded_rail_schedule` (one-shot, speeds known a priori) — both paths call
:func:`speed_precharge`, so offline straggler mitigation and online
feedback stay consistent by construction.
"""

from __future__ import annotations

import dataclasses

import numpy as np

__all__ = ["speed_precharge", "RailHealthEstimator"]


def speed_precharge(total_weight: float, rail_speeds: np.ndarray) -> np.ndarray:
    """Phantom initial load per rail so byte-LPT approximates time-LPT.

    With per-rail speeds ``s_j`` (1.0 = nominal) and ``W`` total bytes to
    place, the time-balanced ideal gives rail ``j`` the share
    ``W * s_j / sum(s)``. Seeding LoadState with
    ``pre_j = (W / sum(s)) * (1 - s_j)`` makes the byte-greedy's uniform
    target land each rail at exactly that share: equal *pre + real* loads
    imply real loads proportional to speed.

    Returns the ``(N,)`` pre-charge vector (all zeros when every speed is
    1.0, so healthy fabrics are untouched).
    """
    rail_speeds = np.asarray(rail_speeds, dtype=np.float64)
    if np.any(rail_speeds <= 0):
        raise ValueError("rail speeds must be positive")
    return (float(total_weight) / rail_speeds.sum()) * (1.0 - rail_speeds)


@dataclasses.dataclass
class RailHealthEstimator:
    """EWMA service-rate tracker per rail, fed by engine service intervals.

    Plugs into the netsim engine as an observer (``record_service``) and
    into the online scheduler as a speed source (``speeds`` /
    ``precharge``). Rates are learned from NIC links only (``up:``/
    ``down:``); spine hops say nothing about rail lane health.

    Attributes:
      num_rails: N.
      nominal_rate: the healthy per-NIC rate R2 (bytes/s).
      alpha: EWMA smoothing factor for new observations.
      floor: lower clamp on the speed estimate — keeps a dying rail
        schedulable (the paper never blackholes a lane, it de-weights it).
    """

    num_rails: int
    nominal_rate: float
    alpha: float = 0.3
    floor: float = 0.05

    def __post_init__(self) -> None:
        self._rates = np.full(self.num_rails, float(self.nominal_rate))
        self._observations = np.zeros(self.num_rails, dtype=np.int64)

    # -- engine observer protocol -------------------------------------------

    def record_service(self, link: str, start: float, end: float, job) -> None:
        kind, _d, rail = link.split(":")
        if kind not in ("up", "down"):
            return
        duration = end - start
        if duration <= 0:
            return
        j = int(rail)
        rate = job.size / duration
        k = self._observations[j]
        self._rates[j] = rate if k == 0 else (
            self.alpha * rate + (1 - self.alpha) * self._rates[j]
        )
        self._observations[j] = k + 1

    # -- scheduler-facing view ----------------------------------------------

    @property
    def observations(self) -> np.ndarray:
        return self._observations.copy()

    def speeds(self) -> np.ndarray:
        """Per-rail speed estimates in [floor, 1], 1.0 until first observed."""
        return np.clip(self._rates / self.nominal_rate, self.floor, 1.0)

    def precharge(self, total_weight: float) -> np.ndarray:
        """LoadState pre-charge for ``total_weight`` pending bytes."""
        return speed_precharge(total_weight, self.speeds())

    def reset(self) -> None:
        self._rates[:] = self.nominal_rate
        self._observations[:] = 0
