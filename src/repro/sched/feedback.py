"""Per-rail health estimation from observed completions.

RailS proper is feedback-free (Theorem 3 makes local LPT globally optimal
*when all rails run at nominal speed*). When a rail degrades — flapping
optics, a slow leaf, PFC storms — byte-balanced plans are no longer
time-balanced. This module closes the loop without giving up the proactive
structure: an EWMA estimator turns observed link-service intervals into
per-rail *speed* estimates, and those speeds are folded into the LPT greedy
as a **pre-charge** of the LoadState (a rail at speed ``s`` starts with
``(1/s - 1)``-proportional phantom load, so the byte-greedy routes around
it exactly as a time-greedy would).

The same pre-charge formula powers :func:`repro.runtime.straggler.
degraded_rail_schedule` (one-shot, speeds known a priori) — both paths call
:func:`speed_precharge`, so offline straggler mitigation and online
feedback stay consistent by construction.
"""

from __future__ import annotations

import dataclasses

import numpy as np

__all__ = ["speed_precharge", "RailHealthEstimator", "DeadRailDetector"]


def speed_precharge(total_weight: float, rail_speeds: np.ndarray) -> np.ndarray:
    """Phantom initial load per rail so byte-LPT approximates time-LPT.

    With per-rail speeds ``s_j`` (1.0 = nominal) and ``W`` total bytes to
    place, the time-balanced ideal gives rail ``j`` the share
    ``W * s_j / sum(s)``. Seeding LoadState with
    ``pre_j = (W / sum(s)) * (1 - s_j)`` makes the byte-greedy's uniform
    target land each rail at exactly that share: equal *pre + real* loads
    imply real loads proportional to speed.

    Returns the ``(N,)`` pre-charge vector (all zeros when every speed is
    1.0, so healthy fabrics are untouched).
    """
    rail_speeds = np.asarray(rail_speeds, dtype=np.float64)
    if np.any(rail_speeds <= 0):
        raise ValueError("rail speeds must be positive")
    return (float(total_weight) / rail_speeds.sum()) * (1.0 - rail_speeds)


@dataclasses.dataclass
class RailHealthEstimator:
    """EWMA service-rate tracker per rail, fed by engine service intervals.

    Plugs into the netsim engine as an observer (``record_service``) and
    into the online scheduler as a speed source (``speeds`` /
    ``precharge``). Rates are learned from NIC links only (``up:``/
    ``down:``); spine hops say nothing about rail lane health.

    The estimator is deliberately *non-stationary-aware*: the EWMA forgets
    geometrically, so when a rail's true speed steps mid-run (degradation,
    flapping optics — :mod:`repro.netsim.linkmodel` profiles) the estimate
    tracks the new level instead of converging once and freezing. With
    ``track_history=True`` every post-observation estimate is kept as a
    ``(time, rail, speed)`` record, from which :meth:`time_to_detect` and
    :meth:`steady_state_error` quantify the tracking loop — how many
    seconds/observations a step takes to show up, and how far the settled
    estimate sits from truth.

    Attributes:
      num_rails: N.
      nominal_rate: the healthy per-NIC rate R2 (bytes/s).
      alpha: EWMA smoothing factor for new observations.
      floor: lower clamp on the speed estimate — keeps a dying rail
        schedulable (the paper never blackholes a lane, it de-weights it).
      track_history: record per-observation speed estimates (off by
        default; 10⁶-chunk sweeps do not want the memory).
    """

    num_rails: int
    nominal_rate: float
    alpha: float = 0.3
    floor: float = 0.05
    track_history: bool = False

    def __post_init__(self) -> None:
        self._rates = np.full(self.num_rails, float(self.nominal_rate))
        self._observations = np.zeros(self.num_rails, dtype=np.int64)
        self._history: list[tuple[float, int, float]] = []

    # -- engine observer protocol -------------------------------------------

    def record_service(self, link: str, start: float, end: float, job) -> None:
        # Multi-pod wan links are 4-part (wan:p:q:lane) and say nothing
        # about rail lane health; only 3-part NIC links feed the EWMA.
        parts = link.split(":")
        if len(parts) != 3:
            return
        kind, _d, rail = parts
        if kind not in ("up", "down"):
            return
        duration = end - start
        if duration <= 0:
            return
        j = int(rail)
        rate = job.size / duration
        k = self._observations[j]
        self._rates[j] = rate if k == 0 else (
            self.alpha * rate + (1 - self.alpha) * self._rates[j]
        )
        self._observations[j] = k + 1
        if self.track_history:
            speed = float(np.clip(self._rates[j] / self.nominal_rate, self.floor, 1.0))
            self._history.append((end, j, speed))

    # -- scheduler-facing view ----------------------------------------------

    @property
    def observations(self) -> np.ndarray:
        return self._observations.copy()

    def speeds(self) -> np.ndarray:
        """Per-rail speed estimates in [floor, 1], 1.0 until first observed."""
        return np.clip(self._rates / self.nominal_rate, self.floor, 1.0)

    def precharge(self, total_weight: float) -> np.ndarray:
        """LoadState pre-charge for ``total_weight`` pending bytes."""
        return speed_precharge(total_weight, self.speeds())

    # -- tracking metrics (require track_history=True) -----------------------

    def history(self, rail: int | None = None) -> list[tuple[float, int, float]]:
        """Recorded ``(time, rail, speed)`` estimates, optionally filtered."""
        if rail is None:
            return list(self._history)
        return [h for h in self._history if h[1] == rail]

    def time_to_detect(
        self, rail: int, target_speed: float, tol: float = 0.15, after: float = 0.0
    ):
        """Tracking latency of a speed change: ``(seconds, observations)``
        until the rail's estimate first lands within ``tol`` (relative) of
        ``target_speed``, counting from ``after`` (the true change time).
        Returns ``None`` if the estimate never got there.
        """
        if not self.track_history:
            raise ValueError("time_to_detect needs track_history=True")
        seen = 0
        for t, r, speed in self._history:
            if r != rail or t < after:
                continue
            seen += 1
            if abs(speed - target_speed) <= tol * target_speed:
                return (t - after, seen)
        return None

    def steady_state_error(
        self, rail: int, target_speed: float, tail: int = 10
    ) -> float:
        """Mean relative error of the rail's last ``tail`` estimates —
        where the EWMA settles once the transient has passed."""
        if not self.track_history:
            raise ValueError("steady_state_error needs track_history=True")
        speeds = [s for _t, r, s in self._history if r == rail][-tail:]
        if not speeds:
            return float("nan")
        err = np.abs(np.array(speeds) - target_speed) / target_speed
        return float(err.mean())

    def reset(self) -> None:
        self._rates[:] = self.nominal_rate
        self._observations[:] = 0
        self._history.clear()


class DeadRailDetector:
    """Silence-based dead-rail watchdog: per-rail ``last_seen`` + the
    HEALTHY→SUSPECT→FAILED state machine of
    :class:`repro.runtime.fault_tolerance.HeartbeatRegistry`.

    The EWMA :class:`RailHealthEstimator` goes *blind* on a fail-stopped
    rail — a dead lane emits no service observations, so its speed
    estimate freezes at the last healthy value. This detector closes that
    gap with the inverse signal: silence. Each observed NIC-lane service
    is a heartbeat for its rail (rails are the registry's "nodes"); a rail
    whose last beat ages past ``suspect_after`` turns SUSPECT, past
    ``deadline`` turns FAILED.

    Ages are measured against the **activity clock** — the newest service
    end observed on *any* rail — not wall time. During a fabric-wide idle
    gap (between micro-batch releases) every rail is silent and none
    should be suspected; once the survivors speak again, a rail silent for
    a full deadline of *fabric activity* is genuinely dead. This also
    detects a rail dead from t=0 (it never beats, so its age grows as the
    others serve). A FAILED rail observed serving again (repair landed,
    backed-off retries came back) is revived, bumping the registry
    generation — the same semantics a node replacement has.

    **Revive hysteresis.** A flapping rail that squeezes one service
    through every watchdog deadline would oscillate dead↔alive each
    window, thrashing the plan cache and re-spraying onto a lane that is
    about to vanish again. ``revive_hysteresis=K`` requires K consecutive
    healthy observations — each within one ``deadline`` of activity of the
    previous — before a FAILED rail is re-admitted; a gap longer than the
    deadline resets the count. The default ``K=1`` preserves the original
    revive-on-first-service behavior bit for bit.

    Plug it into the engine as an observer and :meth:`sweep` it from the
    control plane (the online policy sweeps at every assignment batch);
    :meth:`survivor_mask` is the ``(N,)`` bool mask windowed LPT plans
    over (:func:`repro.core.lpt.lpt_schedule` ``rail_mask``).
    """

    def __init__(
        self,
        num_rails: int,
        deadline: float,
        suspect_after: float | None = None,
        revive_hysteresis: int = 1,
    ):
        from repro.runtime.fault_tolerance import HeartbeatRegistry, NodeState

        if not deadline > 0.0:
            raise ValueError("deadline must be positive")
        if suspect_after is None:
            suspect_after = 0.5 * deadline
        if not 0.0 <= suspect_after <= deadline:
            raise ValueError("need 0 <= suspect_after <= deadline")
        if revive_hysteresis < 1:
            raise ValueError("revive_hysteresis must be >= 1")
        self.num_rails = int(num_rails)
        self.deadline = float(deadline)
        self.revive_hysteresis = int(revive_hysteresis)
        self._NodeState = NodeState
        self.registry = HeartbeatRegistry(
            self.num_rails, deadline=deadline, suspect_after=suspect_after
        )
        self.activity = 0.0  # newest observed service end, any rail
        self.detected_at: dict[int, float] = {}  # rail -> sweep wall time
        self.recovered_at: dict[int, float] = {}
        # rail -> (consecutive healthy observations, last observation end);
        # the pending-revive counter behind the hysteresis.
        self._revive_pending: dict[int, tuple[int, float]] = {}

    # -- engine observer protocol -------------------------------------------

    def record_service(self, link: str, start: float, end: float, job) -> None:
        parts = link.split(":")
        if len(parts) != 3:
            return  # wan links (4-part) are not rail heartbeats
        kind, _d, rail = parts
        if kind not in ("up", "down"):
            return
        r = int(rail)
        if end > self.activity:
            self.activity = end
        node = self.registry.nodes[r]
        if node.state is self._NodeState.FAILED:
            # A dead rail serving again *may* mean the repair landed — but
            # one beat per deadline is exactly what a flapping lane emits,
            # so require revive_hysteresis consecutive observations, each
            # within a deadline of the previous, before re-admitting.
            count, last = self._revive_pending.get(r, (0, -np.inf))
            count = count + 1 if end - last <= self.deadline else 1
            if count >= self.revive_hysteresis:
                # Repair confirmed: revive (replacement-node semantics —
                # generation bumps).
                self.registry.revive(r, end)
                self.recovered_at[r] = end
                self.detected_at.pop(r, None)
                self._revive_pending.pop(r, None)
            else:
                self._revive_pending[r] = (count, end)
        elif end > node.last_beat:
            self.registry.beat(r, end)

    # -- control-plane protocol ---------------------------------------------

    def sweep(self, now: float) -> list[int]:
        """Advance the watchdog; returns newly-FAILED rails.

        Ages run on the activity clock (see class docstring); ``now`` is
        the control plane's wall time, recorded as the *detection* time —
        the instant the scheduler actually learned of the death.
        """
        newly = self.registry.sweep(self.activity)
        for r in newly:
            self.detected_at[r] = now
        return newly

    def state(self, rail: int):
        """The rail's :class:`NodeState` (HEALTHY / SUSPECT / FAILED)."""
        return self.registry.nodes[rail].state

    def dead_rails(self) -> list[int]:
        FAILED = self._NodeState.FAILED
        return [
            r for r, n in self.registry.nodes.items() if n.state is FAILED
        ]

    def survivor_mask(self) -> np.ndarray:
        """Bool ``(N,)``: True = rail not FAILED (SUSPECT still plans)."""
        mask = np.ones(self.num_rails, dtype=bool)
        for r in self.dead_rails():
            mask[r] = False
        return mask

    def time_to_detect(self, rail: int, t_fail: float) -> float | None:
        """Seconds from the true failure to the sweep that caught it."""
        at = self.detected_at.get(rail)
        return None if at is None else at - t_fail
