"""Serving control-plane primitives: admission, shedding, brownout.

``run_serving`` replays a fixed request stream through a fixed policy —
under overload or a rail cut, p99 TTFT blows past any SLO with nothing
pushing back. This module supplies the *decisions* a production gateway
makes, and :mod:`repro.serve.gateway` closes the loop by applying them
per epoch window:

* **Admission control** (:class:`AdmissionController`) — a token bucket
  gates the arrival rate, a queue-depth limit bounds in-flight work, and
  a p99-TTFT tracker sheds new requests while the observed tail exceeds
  the SLO. Priority classes are structural: only *new prefills* pass
  through the controller — decode rounds of already-admitted requests are
  protected unconditionally (a half-served request that gets dropped
  wasted everything spent on it; a never-started one wasted nothing).
* **Graceful degradation** (:class:`BrownoutController`) — a two-state
  machine (NORMAL ↔ BROWNOUT) with entry/exit hysteresis. Brownout is
  entered on dead/masked rails or a sustained p99 overshoot; while
  active the gateway tightens admission to survivor capacity, reduces
  decode expert fan-out, and caps the decode batch — degrading quality
  of service instead of collapsing it.
* **Rail masking for the vector loop** (:class:`RailProbeMonitor`) —
  out-of-band probes feed the EWMA
  :class:`~repro.sched.feedback.RailHealthEstimator`; rails whose speed
  estimate collapses are masked out of the planner (the survivor-mask
  protocol of :class:`~repro.sched.feedback.DeadRailDetector`, whose
  revive hysteresis this monitor mirrors). The event-loop gateway path
  wires the real detector instead — silence is observable there.
* **SLO accounting** (:func:`slo_summary`) — shed-aware goodput: shed
  requests are excluded from latency percentiles and reported as
  ``shed_rate``; *goodput* counts only served requests whose TTFT met the
  SLO, per second of trace — the quantity SLO-attainment curves sweep.
"""

from __future__ import annotations

import dataclasses

import numpy as np

from .feedback import RailHealthEstimator

__all__ = [
    "TokenBucket",
    "AdmissionConfig",
    "AdmissionController",
    "BrownoutConfig",
    "BrownoutController",
    "ControlConfig",
    "RailProbeMonitor",
    "slo_summary",
]


class TokenBucket:
    """Deterministic token bucket: ``rate`` tokens/s, ``burst`` capacity.

    Starts full. :meth:`allow` refills by elapsed time × rate (monotone
    timestamps required), then spends one token if available. Rate changes
    (brownout tightening) apply from the *current* instant — accumulated
    tokens are kept, so momentary tightening does not confiscate burst
    credit already earned.
    """

    def __init__(self, rate: float, burst: float = 8.0):
        if rate <= 0 or burst < 1:
            raise ValueError("need rate > 0 and burst >= 1")
        self.rate = float(rate)
        self.burst = float(burst)
        self.tokens = float(burst)
        self._last = 0.0

    def allow(self, t: float) -> bool:
        if t > self._last:
            self.tokens = min(self.burst, self.tokens + (t - self._last) * self.rate)
            self._last = t
        if self.tokens >= 1.0:
            self.tokens -= 1.0
            return True
        return False

    def set_rate(self, rate: float) -> None:
        if rate <= 0:
            raise ValueError("rate must be positive")
        self.rate = float(rate)


@dataclasses.dataclass(frozen=True)
class AdmissionConfig:
    """Admission-control knobs (all gates optional; None disables one).

    Attributes:
      rate_rps: token-bucket refill rate (requests/s); None = no bucket.
      burst: token-bucket capacity (requests).
      queue_limit: max admitted requests in flight; None = unbounded.
      shed_p99_factor: shed new prefills while the EWMA-tracked window
        p99 TTFT exceeds ``factor × SLO``; None disables the tracker.
      p99_alpha: EWMA weight for each window's observed p99.
    """

    rate_rps: float | None = None
    burst: float = 8.0
    queue_limit: int | None = None
    shed_p99_factor: float | None = 1.0
    p99_alpha: float = 0.5


class AdmissionController:
    """Arrival gate for *new requests* (prefill priority class).

    Decode rounds never pass through here — the gateway protects them
    structurally. Gates are checked cheapest-signal-first: queue depth
    (instantaneous), tracked p99 (one EWMA read), then the token bucket
    (consumed only when everything else admits, so shed requests do not
    burn rate credit).
    """

    def __init__(self, cfg: AdmissionConfig, slo_s: float):
        if slo_s <= 0:
            raise ValueError("slo_s must be positive")
        self.cfg = cfg
        self.slo_s = float(slo_s)
        self.bucket = (
            TokenBucket(cfg.rate_rps, cfg.burst) if cfg.rate_rps is not None else None
        )
        self._rate_scale = 1.0
        self.p99_est: float | None = None  # EWMA of window p99 TTFTs
        self.admitted = 0
        self.shed_by_reason: dict[str, int] = {}

    def admit(self, arrival: float, inflight: int) -> tuple[bool, str]:
        """Admit or shed one new request arriving at ``arrival``.

        Returns ``(admitted, reason)`` with reason in ``{"admitted",
        "queue", "p99", "bucket"}``.
        """
        cfg = self.cfg
        if cfg.queue_limit is not None and inflight >= cfg.queue_limit:
            return self._shed("queue")
        if (
            cfg.shed_p99_factor is not None
            and self.p99_est is not None
            and self.p99_est > cfg.shed_p99_factor * self.slo_s
        ):
            return self._shed("p99")
        if self.bucket is not None and not self.bucket.allow(arrival):
            return self._shed("bucket")
        self.admitted += 1
        return True, "admitted"

    def _shed(self, reason: str) -> tuple[bool, str]:
        self.shed_by_reason[reason] = self.shed_by_reason.get(reason, 0) + 1
        return False, reason

    def observe_window(self, p99_ttft: float | None) -> None:
        """Fold one window's observed prefill-TTFT p99 into the tracker.

        ``None`` (no prefills finished this window) leaves the estimate
        untouched — absence of samples is not evidence of health.
        """
        if p99_ttft is None:
            return
        a = self.cfg.p99_alpha
        self.p99_est = (
            float(p99_ttft)
            if self.p99_est is None
            else a * float(p99_ttft) + (1 - a) * self.p99_est
        )

    def set_rate_scale(self, scale: float) -> None:
        """Brownout tightening: effective bucket rate = base × scale."""
        if self.bucket is None:
            return
        if scale <= 0:
            raise ValueError("scale must be positive")
        if scale != self._rate_scale:
            base = self.bucket.rate / self._rate_scale
            self.bucket.set_rate(base * scale)
            self._rate_scale = scale

    @property
    def shed(self) -> int:
        return sum(self.shed_by_reason.values())


@dataclasses.dataclass(frozen=True)
class BrownoutConfig:
    """Graceful-degradation knobs.

    Entry: immediately when any rail is masked/dead, or after
    ``enter_windows`` consecutive windows with tracked p99 >
    ``enter_p99_factor × SLO``. Exit: after ``exit_windows`` consecutive
    windows with no masked rails and tracked p99 ≤ ``exit_p99_factor ×
    SLO`` (entry and exit thresholds deliberately straddle the SLO —
    that gap is the hysteresis band that prevents mode flapping).

    While active the gateway (a) multiplies the admission rate by
    ``survivor_fraction × admission_tighten``, (b) scales decode-round
    traffic by ``fanout_keep`` (serving top-1 of top-2 experts moves half
    the bytes), and (c) caps continuous decode batches at
    ``decode_batch_cap`` merged rounds.
    """

    enter_p99_factor: float = 1.5
    enter_windows: int = 2
    exit_p99_factor: float = 0.8
    exit_windows: int = 3
    admission_tighten: float = 0.9
    fanout_keep: float = 0.5
    decode_batch_cap: int | None = 8

    def __post_init__(self):
        if not 0 < self.fanout_keep <= 1:
            raise ValueError("fanout_keep must be in (0, 1]")
        if not 0 < self.admission_tighten <= 1:
            raise ValueError("admission_tighten must be in (0, 1]")
        if self.enter_windows < 1 or self.exit_windows < 1:
            raise ValueError("entry/exit window counts must be >= 1")


class BrownoutController:
    """NORMAL ↔ BROWNOUT state machine with entry/exit hysteresis."""

    def __init__(self, cfg: BrownoutConfig):
        self.cfg = cfg
        self.active = False
        self._enter_streak = 0
        self._exit_streak = 0
        self.transitions: list[tuple[float, str]] = []  # (t, "enter"|"exit")

    def observe_window(
        self,
        t: float,
        p99_est: float | None,
        slo_s: float,
        masked_rails: int,
    ) -> bool:
        """Advance the state machine at one window boundary; returns
        whether brownout is active for the *next* window."""
        cfg = self.cfg
        overloaded = p99_est is not None and p99_est > cfg.enter_p99_factor * slo_s
        if not self.active:
            self._enter_streak = self._enter_streak + 1 if overloaded else 0
            if masked_rails > 0 or self._enter_streak >= cfg.enter_windows:
                self.active = True
                self._enter_streak = 0
                self._exit_streak = 0
                self.transitions.append((t, "enter"))
        else:
            healthy = masked_rails == 0 and (
                p99_est is None or p99_est <= cfg.exit_p99_factor * slo_s
            )
            self._exit_streak = self._exit_streak + 1 if healthy else 0
            if self._exit_streak >= cfg.exit_windows:
                self.active = False
                self._exit_streak = 0
                self.transitions.append((t, "exit"))
        return self.active

    def admission_scale(self, survivor_fraction: float) -> float:
        """Admission-rate multiplier for the coming window."""
        if not self.active:
            return 1.0
        return max(survivor_fraction, 1e-9) * self.cfg.admission_tighten

    @property
    def entries(self) -> list[float]:
        return [t for t, kind in self.transitions if kind == "enter"]

    @property
    def exits(self) -> list[float]:
        return [t for t, kind in self.transitions if kind == "exit"]


class _ProbeJob:
    """Minimal job stand-in for synthetic ``record_service`` observations."""

    __slots__ = ("size",)

    def __init__(self, size: float):
        self.size = size


class RailProbeMonitor:
    """Out-of-band rail prober + survivor mask for the vector epoch loop.

    The vector backend has no live service stream to observe, so the
    gateway probes every rail once per window: each probe's measured
    speed is folded into the EWMA
    :class:`~repro.sched.feedback.RailHealthEstimator` through its normal
    ``record_service`` observer interface (a ``probe_bytes`` transfer at
    the rail's current rate), keeping one estimator implementation across
    both loops. Rails whose EWMA speed collapses below ``dead_speed`` are
    masked out of the planner; a masked rail is re-admitted only after
    ``revive_windows`` *consecutive* windows with EWMA speed ≥
    ``healthy_speed`` — the same revive hysteresis
    :class:`~repro.sched.feedback.DeadRailDetector` applies to in-band
    silence, so both detection paths flap-proof the plan the same way.

    Duck-types the detector's control-plane surface (``sweep`` /
    ``survivor_mask`` / ``dead_rails``) so it plugs into
    ``OnlineRailSPolicy(detector=...)`` unchanged.
    """

    def __init__(
        self,
        health: RailHealthEstimator,
        dead_speed: float = 0.2,
        healthy_speed: float = 0.6,
        revive_windows: int = 3,
        probe_bytes: float = 1 * 2**20,
    ):
        if not 0 < dead_speed < healthy_speed <= 1.0:
            raise ValueError("need 0 < dead_speed < healthy_speed <= 1")
        if revive_windows < 1:
            raise ValueError("revive_windows must be >= 1")
        self.health = health
        self.dead_speed = float(dead_speed)
        self.healthy_speed = float(healthy_speed)
        self.revive_windows = int(revive_windows)
        self.probe_bytes = float(probe_bytes)
        self._mask = np.ones(health.num_rails, dtype=bool)
        self._revive_streak = np.zeros(health.num_rails, dtype=np.int64)
        self.masked_at: dict[int, float] = {}
        self.revived_at: dict[int, float] = {}

    def observe(self, rail_speeds, t: float) -> None:
        """Fold one probe round (true per-rail speeds at ``t``) into the
        EWMA estimator, then update the survivor mask."""
        speeds = np.asarray(rail_speeds, dtype=np.float64)
        if speeds.shape != (self.health.num_rails,):
            raise ValueError(
                f"need ({self.health.num_rails},) speeds, got {speeds.shape}"
            )
        for j, s in enumerate(speeds.tolist()):
            if s <= 0:
                raise ValueError("probe speeds must be positive (vector loop)")
            # A probe_bytes transfer at the rail's current rate; the
            # estimator recovers rate = size/duration = s * nominal.
            duration = self.probe_bytes / (s * self.health.nominal_rate)
            self.health.record_service(
                f"up:0:{j}", t - duration, t, _ProbeJob(self.probe_bytes)
            )
        est = self.health.speeds()
        for j in range(est.size):
            if self._mask[j]:
                if est[j] <= self.dead_speed:
                    self._mask[j] = False
                    self._revive_streak[j] = 0
                    self.masked_at[j] = t
            else:
                if est[j] >= self.healthy_speed:
                    self._revive_streak[j] += 1
                    if self._revive_streak[j] >= self.revive_windows:
                        self._mask[j] = True
                        self._revive_streak[j] = 0
                        self.revived_at[j] = t
                else:
                    self._revive_streak[j] = 0

    # -- detector-compatible control-plane surface ---------------------------

    def sweep(self, now: float) -> list[int]:
        """No-op (masking happens in :meth:`observe`); detector protocol."""
        return []

    def survivor_mask(self) -> np.ndarray:
        return self._mask.copy()

    def dead_rails(self) -> list[int]:
        return [int(j) for j in np.flatnonzero(~self._mask)]


@dataclasses.dataclass(frozen=True)
class ControlConfig:
    """Everything the closed-loop gateway needs beyond the workload.

    Attributes:
      slo_s: the p99-TTFT SLO (seconds) goodput is scored against.
      epoch_s: feedback window length — plan/react cadence of the loop.
        None lets the gateway pick ~20 windows across the trace.
      admission: admission gates; None admits everything.
      brownout: degradation mode; None never degrades.
      batch_quantum_s: continuous-batching quantum — decode rounds
        releasing within one quantum merge into a shared all-to-all.
        None disables merging.
      dead_speed / healthy_speed / revive_windows / probe_bytes: the
        :class:`RailProbeMonitor` knobs (vector loop).
      feedback: fold EWMA speed estimates into the planner pre-charge.
    """

    slo_s: float = 0.05
    epoch_s: float | None = None
    admission: AdmissionConfig | None = None
    brownout: BrownoutConfig | None = None
    batch_quantum_s: float | None = None
    dead_speed: float = 0.2
    healthy_speed: float = 0.6
    revive_windows: int = 3
    probe_bytes: float = 1 * 2**20
    feedback: bool = True

    def __post_init__(self):
        if self.slo_s <= 0:
            raise ValueError("slo_s must be positive")
        if self.epoch_s is not None and self.epoch_s <= 0:
            raise ValueError("epoch_s must be positive")
        if self.batch_quantum_s is not None and self.batch_quantum_s <= 0:
            raise ValueError("batch_quantum_s must be positive")


def slo_summary(
    ttft: np.ndarray,
    slo_s: float,
    horizon_s: float,
    offered: int,
    shed: int,
) -> dict:
    """Shed-aware SLO accounting for one run.

    ``ttft`` holds *served* requests only (shed requests are excluded
    from every percentile by construction — they have no latency, they
    have a rejection). Goodput counts served requests whose TTFT met the
    SLO, per second of trace — the y-axis of an SLO-attainment curve.
    Fully-shed runs are a valid outcome (0 served, goodput 0), not an
    error.
    """
    ttft = np.asarray(ttft, dtype=np.float64)
    served = int(ttft.size)
    met = int((ttft <= slo_s).sum()) if served else 0
    horizon = max(float(horizon_s), 0.0)
    return {
        "offered": int(offered),
        "served": served,
        "shed": int(shed),
        "shed_rate": shed / offered if offered else 0.0,
        "slo_met": met,
        "slo_attainment": met / served if served else 0.0,
        "offered_rps": offered / horizon if horizon > 0 else 0.0,
        "served_rps": served / horizon if horizon > 0 else 0.0,
        "goodput_rps": met / horizon if horizon > 0 else 0.0,
    }
