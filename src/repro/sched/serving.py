"""Request-level serving driver: tail latency under degraded fabrics.

Training judges the fabric by makespan — one big collective, everyone
waits for the last chunk. Serving judges it by *per-request tails*:
decode-batch all-to-alls are small and latency-critical, and the figure
of merit is p99/p99.9 time-to-first-token (TTFT) and per-token sojourn,
exactly the metric regime that motivates REPS-style multipath spraying
and the MoE-serving latency analyses in PAPERS.md.

This module maps a :class:`~repro.core.traffic.ServeWorkload` (requests →
release-timed prefill/decode rounds) through
:func:`~repro.netsim.simulate.run_streaming_collective` — any policy, any
:class:`~repro.netsim.linkmodel.FaultSpec` — and folds the per-round
completions back into per-request metrics:

* **TTFT** — prefill-round completion minus the request's *arrival*
  (release-relative, like every latency here; the first token cannot be
  emitted before its all-to-all drains).
* **per-token latency** — each decode round's sojourn (finish − release).
* **request sojourn** — last round completion minus arrival.

**Shift invariance by construction.** The driver normalizes the workload
to its earliest release before simulating and measures every metric
against normalized arrivals, so translating the whole workload by Δ
seconds reproduces bit-identical statistics — the property
``tests/test_serving.py`` pins down. (Absolute time origins are
arbitrary; only the physics between releases matters.) Normalized times
are snapped to a 1 ns grid first: ``(r + Δ) − (t0 + Δ)`` differs from
``r − t0`` by an ulp of Δ, and the snap absorbs that rounding (sub-ns
release placement is far below NIC timestamping resolution anyway), so
the invariance is exact for any |Δ| up to ~10⁵ s rather than merely
within fp tolerance.

:func:`simulate_decode_trace` is the replay half: per-step expert counts
recorded from a *real* decode loop (``launch/serve.py --sim-fabric``)
drive the simulated fabric at the loop's measured cadence.
"""

from __future__ import annotations

import dataclasses

import numpy as np

from ..core.traffic import (
    ServeWorkload,
    TrafficMatrix,
    expert_counts_to_matrix,
    moe_gating_traffic,
)
from ..netsim.events import cct_percentile_dict

__all__ = [
    "SERVE_QS",
    "RequestMetrics",
    "ServingResult",
    "DecodeTraceResult",
    "normalized_rounds",
    "run_serving",
    "ttft_recovery_curve",
    "expert_counts_to_matrix",
    "simulate_decode_trace",
]

#: Serving-path quantiles: the tail is the product (p50 for the body,
#: p99/p99.9 for the SLO).
SERVE_QS = (50.0, 90.0, 99.0, 99.9)

#: Release-time grid (seconds). Normalized releases/arrivals snap to this
#: before simulation so whole-workload time shifts are *exactly* invariant
#: (the snap absorbs the ulp the shift's own rounding introduces).
RELEASE_TICK = 1e-9


def _snap(t: float) -> float:
    """Quantize a normalized (release-relative) time to the 1 ns grid."""
    return round(t / RELEASE_TICK) * RELEASE_TICK


def normalized_rounds(workload: ServeWorkload):
    """Release-sorted rounds with grid-snapped normalized release times.

    Returns ``(ordered, releases, t0)``: the rounds sorted by release
    (stable), their normalized-and-snapped release times, and the time
    origin ``t0`` (the earliest release) that request arrivals must be
    normalized against for release-relative metrics. Shared between
    :func:`run_serving` and the gateway's epoch-windowed loop so both
    paths measure from the identical 1 ns grid — the bit-exactness
    anchor for the control-off parity tests.
    """
    ordered = sorted(workload.rounds, key=lambda r: r.release)
    if not ordered:
        return [], [], 0.0
    t0 = ordered[0].release
    return ordered, [_snap(r.release - t0) for r in ordered], t0


@dataclasses.dataclass
class RequestMetrics:
    """Per-request latency vectors, all release-relative.

    ``ttft[i]`` / ``sojourn[i]`` align with ``workload.requests[i]``;
    ``token_latency`` is one entry per decode round (across requests, in
    round-release order).
    """

    ttft: np.ndarray
    token_latency: np.ndarray
    sojourn: np.ndarray

    def ttft_percentiles(self, qs=SERVE_QS) -> dict[str, float]:
        return cct_percentile_dict(self.ttft, qs)

    def token_percentiles(self, qs=SERVE_QS) -> dict[str, float]:
        return cct_percentile_dict(self.token_latency, qs)

    def sojourn_percentiles(self, qs=SERVE_QS) -> dict[str, float]:
        return cct_percentile_dict(self.sojourn, qs)

    def summary(self, qs=SERVE_QS) -> dict[str, dict[str, float]]:
        return {
            "ttft": self.ttft_percentiles(qs),
            "token_latency": self.token_percentiles(qs),
            "sojourn": self.sojourn_percentiles(qs),
        }


@dataclasses.dataclass
class ServingResult:
    """Outcome of one simulated serving run."""

    workload: ServeWorkload
    policy: str
    streaming: object  # netsim.simulate.StreamingResult
    request: RequestMetrics

    @property
    def makespan(self) -> float:
        return self.streaming.metrics.makespan

    def row(self) -> dict:
        """Flat benchmark row (grid sweeps / BENCH_netsim.json)."""
        s = self.request.summary()
        dyn = getattr(self.streaming.sim, "dynamics", None) or {}
        return {
            "policy": self.policy,
            "num_requests": len(self.workload.requests),
            "ttft_p50_s": s["ttft"]["p50"],
            "ttft_p99_s": s["ttft"]["p99"],
            "ttft_p99.9_s": s["ttft"]["p99.9"],
            "token_p99_s": s["token_latency"]["p99"],
            "sojourn_p99_s": s["sojourn"]["p99"],
            "retransmits": dyn.get("retransmits", 0),
        }


def run_serving(
    workload: ServeWorkload,
    policy: str = "rails-online",
    r1: float = 400e9,
    r2: float = 50e9,
    chunk_bytes: float = 256 * 2**10,
    seed: int = 0,
    probe_every: int = 64,
    rail_speeds=None,
    fault_spec=None,
    feedback: bool = False,
    window: int | None = None,
    detector=None,
    backend: str = "event",
) -> ServingResult:
    """Simulate one serving workload under one policy; return tail metrics.

    Arguments mirror :func:`~repro.netsim.simulate.run_streaming_collective`
    (``fault_spec`` attaches the PR-4 link-dynamics layer — degraded
    fabrics are the whole point of a p99 study; ``detector`` attaches the
    PR-7 dead-rail watchdog so mid-trace fail-stop events re-spray onto
    survivors — see :func:`ttft_recovery_curve` for the recovery view).
    The default chunk size is small: decode rounds move tens of KiB, and
    Theorem-4 multiplicity needs several chunks per rail even then.
    """
    from ..netsim.simulate import run_streaming_collective

    if not workload.rounds:
        raise ValueError("serving workload has no rounds")
    # Order by release (stable; serve_workload already sorts, but the
    # mutable dataclass doesn't enforce it and the streaming round_id
    # mapping below depends on it). Then normalize to the earliest
    # release and snap to the 1 ns grid: identical simulations for
    # time-shifted workloads (exact shift invariance), and the engine's
    # release>=0 contract holds for any absolute arrival origin.
    ordered, releases, t0 = normalized_rounds(workload)
    rounds = [(rel, r.tm) for rel, r in zip(releases, ordered)]
    streaming = run_streaming_collective(
        rounds,
        policy,
        r1=r1,
        r2=r2,
        chunk_bytes=chunk_bytes,
        seed=seed,
        probe_every=probe_every,
        rail_speeds=rail_speeds,
        fault_spec=fault_spec,
        feedback=feedback,
        window=window,
        detector=detector,
        backend=backend,
    )
    round_cct = streaming.round_cct
    num_req = len(workload.requests)
    ttft = np.zeros(num_req)
    sojourn = np.zeros(num_req)
    token_latency: list[float] = []
    for i, rnd in enumerate(ordered):
        # A round whose traffic matrix is empty (every routed token stayed
        # on the home domain's NVLink) produces no chunks and never appears
        # in round_cct — it completes at its own release.
        fin = round_cct.get(i, releases[i])
        req = workload.requests[rnd.req_id]
        arrival = _snap(req.arrival - t0)
        if rnd.kind == "prefill":
            ttft[rnd.req_id] = fin - arrival
        else:
            # Engine-side sojourn; 0.0 for empty (all-NVLink) rounds —
            # same convention as simulate_decode_trace.
            token_latency.append(streaming.round_sojourn.get(i, 0.0))
        sojourn[rnd.req_id] = max(sojourn[rnd.req_id], fin - arrival)
    return ServingResult(
        workload=workload,
        policy=policy,
        streaming=streaming,
        request=RequestMetrics(
            ttft=ttft,
            token_latency=np.asarray(token_latency),
            sojourn=sojourn,
        ),
    )


def ttft_recovery_curve(result: ServingResult, bucket_s: float) -> dict:
    """Bucket TTFTs by request *arrival* into a p50/p99 time series.

    The failure-drill view: run :func:`run_serving` with a mid-trace
    :class:`~repro.netsim.linkmodel.FailStopEvent` and plot how the TTFT
    tail degrades at ``t_fail`` and recovers once the watchdog re-sprays
    onto survivors (and, with ``t_repair``, once the rail returns).
    Arrivals are normalized to the earliest round release — the same
    origin every latency in ``result.request`` uses — so the curve lines
    up with the fault spec's event times directly.

    Returns ``{"t": [...], "p50": [...], "p99": [...], "count": [...]}``
    where ``t`` is each bucket's left edge; empty buckets are skipped.
    """
    if bucket_s <= 0.0:
        raise ValueError("bucket_s must be positive")
    ordered = sorted(result.workload.rounds, key=lambda r: r.release)
    t0 = ordered[0].release
    prefill_reqs = [r.req_id for r in ordered if r.kind == "prefill"]
    buckets: dict[int, list[float]] = {}
    for rid in prefill_reqs:
        arrival = _snap(result.workload.requests[rid].arrival - t0)
        buckets.setdefault(int(arrival // bucket_s), []).append(
            float(result.request.ttft[rid])
        )
    curve: dict[str, list[float]] = {"t": [], "p50": [], "p99": [], "count": []}
    for idx in sorted(buckets):
        vals = np.asarray(buckets[idx])
        curve["t"].append(idx * bucket_s)
        curve["p50"].append(float(np.percentile(vals, 50.0)))
        curve["p99"].append(float(np.percentile(vals, 99.0)))
        curve["count"].append(int(vals.size))
    return curve


# ---------------------------------------------------------------------------
# Replay from a real decode loop (launch/serve.py --sim-fabric)
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class DecodeTraceResult:
    """Simulated-fabric view of one recorded decode trace."""

    streaming: object  # netsim.simulate.StreamingResult
    token_latency: np.ndarray  # per decode step, release-relative

    def summary(self, qs=SERVE_QS) -> dict[str, float]:
        return cct_percentile_dict(self.token_latency, qs)


def simulate_decode_trace(
    counts_per_step,
    releases,
    num_domains: int,
    num_rails: int,
    bytes_per_token: float,
    policy: str = "rails-online",
    chunk_bytes: float = 256 * 2**10,
    fault_spec=None,
    feedback: bool = False,
    r1: float = 400e9,
    r2: float = 50e9,
    seed: int = 0,
) -> DecodeTraceResult:
    """Drive the simulated fabric with a *real* decode loop's routing.

    ``counts_per_step`` are per-step expert token counts recorded from the
    model's gate (``decode_fn(..., return_counts=True)``); ``releases``
    are the loop's measured step timestamps (any origin — normalized
    internally). Each step becomes one streaming round; the result's
    per-token latencies are what those decode all-to-alls would have cost
    on the chosen fabric/policy — closing the trace half of the ROADMAP's
    "replay from real gating traces" item for the serving path.
    """
    from ..netsim.simulate import run_streaming_collective

    releases = np.asarray(releases, dtype=np.float64)
    if len(counts_per_step) != releases.size:
        raise ValueError("one release timestamp per decode step required")
    if releases.size == 0:
        raise ValueError("decode trace is empty")
    order = np.argsort(releases, kind="stable")
    t0 = float(releases[order[0]])
    rounds: list[tuple[float, TrafficMatrix]] = []
    for i in order.tolist():
        c2 = expert_counts_to_matrix(counts_per_step[i], num_domains)
        tm = moe_gating_traffic(c2, bytes_per_token, num_rails)
        rounds.append(
            (
                _snap(float(releases[i]) - t0),
                TrafficMatrix(d1=tm.d1, d2=tm.d2, name="decode-trace"),
            )
        )
    streaming = run_streaming_collective(
        rounds,
        policy,
        r1=r1,
        r2=r2,
        chunk_bytes=chunk_bytes,
        seed=seed,
        fault_spec=fault_spec,
        feedback=feedback,
    )
    # Engine-side sojourns (finish − release); a step whose counts all map
    # intra-domain produces no chunks and costs the fabric nothing.
    latency = np.array(
        [streaming.round_sojourn.get(i, 0.0) for i in range(len(rounds))]
    )
    return DecodeTraceResult(streaming=streaming, token_latency=latency)
