"""Online scheduling control plane (`repro.sched`).

**Offline vs online regimes.** The core reproduction (``core.lpt``,
``core.plan``, ``netsim.simulate.run_collective``) is *offline*: the full
traffic matrix is known before the first chunk moves, one LPT plan is
computed per sender domain, one collective runs. Real MoE training and
serving are *online*: micro-batches release chunks over time, gating
counts drift between iterations, and rails degrade mid-run — the
scheduler must commit chunks with partial, evolving information. This
package layers that regime on the offline core without changing it:

* :mod:`~repro.sched.online` — online LPT variants: greedy list
  scheduling on arrival (``window=1``), windowed re-planning every K
  chunks, and a routing-replay mode that forecasts each domain's egress
  from previous iterations' gating counts; plus adaptive chunk sizing
  against the Theorem-4 MSE bound.
* :mod:`~repro.sched.feedback` — per-rail health estimation: EWMA
  service rates observed from the fabric pre-charge the LPT LoadState so
  byte-balanced plans stay *time*-balanced on degraded rails. The same
  pre-charge formula backs ``runtime.straggler.degraded_rail_schedule``.
* :mod:`~repro.sched.telemetry` — per-link utilization timelines,
  per-rail completion histograms, Chrome-trace JSON export.
* :mod:`~repro.sched.pipeline` — multi-round streaming driver that
  overlaps round k's tail with round k+1's head.
* :mod:`~repro.sched.serving` — request-level serving driver: Poisson /
  bursty / diurnal request streams lowered to prefill + decode rounds,
  scored by release-relative tails (p99/p99.9 TTFT, per-token sojourn)
  instead of makespan; ``repro.serve`` is the façade.
* :mod:`~repro.sched.control` — overload-control primitives for the
  serving gateway: token-bucket + queue-depth + p99-tracking admission
  control, brownout (graceful degradation) hysteresis, the out-of-band
  rail-probe monitor for the vector loop, and shed-aware SLO accounting.
  ``repro.serve.gateway.run_gateway`` is the closed loop built on them.

Entry points: ``netsim.simulate.run_streaming_collective`` (one streaming
collective, any policy), ``sched.pipeline.run_pipeline`` (overlapped
multi-round), and the ``rails-online`` policy in ``netsim.balancers``.
Anchors: with every chunk released at t=0 and feedback disabled, the
online path reproduces the offline one exactly (tests pin this down).
"""

from .control import (
    AdmissionConfig,
    AdmissionController,
    BrownoutConfig,
    BrownoutController,
    ControlConfig,
    RailProbeMonitor,
    TokenBucket,
    slo_summary,
)
from .feedback import DeadRailDetector, RailHealthEstimator, speed_precharge
from .online import (
    AdaptiveChunker,
    GatingFeedbackHook,
    PlanCache,
    RoutingReplayState,
    online_greedy_schedule,
    windowed_lpt_schedule,
)
from .pipeline import PipelineResult, plan_releases, run_pipeline
from .serving import (
    DecodeTraceResult,
    RequestMetrics,
    ServingResult,
    expert_counts_to_matrix,
    run_serving,
    simulate_decode_trace,
    ttft_recovery_curve,
)
from .telemetry import ServiceRecord, TraceRecorder

__all__ = [
    "AdaptiveChunker",
    "AdmissionConfig",
    "AdmissionController",
    "BrownoutConfig",
    "BrownoutController",
    "ControlConfig",
    "DeadRailDetector",
    "DecodeTraceResult",
    "GatingFeedbackHook",
    "PipelineResult",
    "PlanCache",
    "RailHealthEstimator",
    "RailProbeMonitor",
    "RequestMetrics",
    "RoutingReplayState",
    "ServiceRecord",
    "ServingResult",
    "TokenBucket",
    "TraceRecorder",
    "expert_counts_to_matrix",
    "online_greedy_schedule",
    "plan_releases",
    "run_pipeline",
    "run_serving",
    "simulate_decode_trace",
    "slo_summary",
    "speed_precharge",
    "ttft_recovery_curve",
    "windowed_lpt_schedule",
]
