"""Online LPT variants: scheduling with partial, evolving information.

The offline pipeline (``core.plan``) assumes the full traffic matrix is on
the table before the first chunk moves. Streaming MoE training violates
that three ways, and each gets its own mechanism here:

* **Chunks arrive over time** (micro-batch releases, bursty gating) —
  :func:`windowed_lpt_schedule` list-schedules each arrival window with the
  LPT greedy over a *persistent* LoadState. ``window=1`` is pure greedy
  list scheduling (decide the instant a chunk arrives); ``window=None``
  re-plans over everything currently on the table; intermediate ``K``
  bounds decision latency to K chunks. With a single window covering all
  chunks and zero initial state this is exactly Algorithm 2, which is the
  offline-parity anchor the tests pin down.
* **Gating counts drift between iterations** — :class:`RoutingReplayState`
  keeps an EWMA of per-domain egress totals and rail profiles from previous
  iterations; replaying it gives the scheduler a forecast of bytes that
  have not arrived yet (ReLibra-style routing replay).
* **The right atomicity is workload-dependent** — :class:`AdaptiveChunker`
  sizes chunks from the replayed totals (enough multiplicity per rail for
  the Theorem-4 bound to bite) and reacts to observed imbalance.

:class:`GatingFeedbackHook` packages the three for the training loop: feed
it each step's gating counts and it maintains the replay state and emits
the next iteration's spray-plan forecast.
"""

from __future__ import annotations

import dataclasses
import hashlib

import numpy as np

from ..core.lpt import (
    HierLptResult,
    LptResult,
    LptState,
    load_mse,
    lpt_schedule,
    normalized_load_mse,
)

__all__ = [
    "online_greedy_schedule",
    "windowed_lpt_schedule",
    "windowed_hier_lpt_schedule",
    "PlanCache",
    "RoutingReplayState",
    "AdaptiveChunker",
    "GatingFeedbackHook",
]


def windowed_lpt_schedule(
    weights: np.ndarray,
    num_rails: int,
    window: int | None = None,
    source_ids: np.ndarray | None = None,
    initial_loads: np.ndarray | None = None,
    extra_loads: np.ndarray | None = None,
    rail_mask: np.ndarray | None = None,
) -> LptResult:
    """LPT over consecutive arrival windows with carried LoadState.

    Args:
      weights: ``(F,)`` chunk sizes in *arrival order* (the online regime's
        only ordering; no global sort is available).
      num_rails: N.
      window: chunks per re-planning window. ``None`` = one window over all
        F chunks (offline LPT); ``1`` = greedy list scheduling on arrival.
      source_ids: optional ``(F,)`` tie-break ids (Algorithm 2).
      initial_loads: optional ``(N,)`` starting LoadState — carried backlog,
        health pre-charge, or a routing replay seed.
      extra_loads: optional ``(N,)`` phantom bias added for comparison but
        not committed — the health pre-charge convention of
        :meth:`repro.core.lpt.LptState.assign`.
      rail_mask: optional ``(N,)`` bool survivor mask — the degraded N−k
        regime; masked rails receive nothing (the mask the control plane /
        :class:`~repro.sched.feedback.DeadRailDetector` derives).

    Returns an :class:`~repro.core.lpt.LptResult`; ``order`` is the global
    processing order actually used (windows in arrival order, LPT-sorted
    inside each window).
    """
    weights = np.asarray(weights, dtype=np.float64)
    if weights.ndim != 1:
        raise ValueError(f"weights must be rank-1, got {weights.shape}")
    f = weights.size
    if window is not None and window < 1:
        raise ValueError(f"window must be >= 1 or None, got {window}")
    source_ids = None if source_ids is None else np.asarray(source_ids)
    # The persistent LoadState is carried by an LptState: each window is
    # sorted and heap-assigned on its own, O(K log K + K log N) per window
    # — the already-committed backlog is never touched again.
    state = LptState(num_rails, initial_loads=initial_loads)
    step = f if window is None else window
    assignment = np.empty(f, dtype=np.int64)
    order_parts: list[np.ndarray] = []
    for lo in range(0, f, max(step, 1)):
        hi = min(lo + step, f)
        res = state.assign(
            weights[lo:hi],
            source_ids=None if source_ids is None else source_ids[lo:hi],
            extra_loads=extra_loads,
            rail_mask=rail_mask,
        )
        assignment[lo:hi] = res.assignment
        order_parts.append(res.order + lo)
    order = np.concatenate(order_parts) if order_parts else np.arange(0)
    return LptResult(
        assignment=assignment, loads=state.loads, order=order, mse=load_mse(state.loads)
    )


def windowed_hier_lpt_schedule(
    weights: np.ndarray,
    num_rails: int,
    num_lanes: int,
    dst_pods: np.ndarray,
    src_pod: int,
    window: int | None = None,
    source_ids: np.ndarray | None = None,
    initial_loads: np.ndarray | None = None,
    extra_loads: np.ndarray | None = None,
    rail_mask: np.ndarray | None = None,
    lane_loads: dict[int, np.ndarray] | None = None,
) -> HierLptResult:
    """Windowed two-level LPT for hierarchical fabrics.

    Level 1 is exactly :func:`windowed_lpt_schedule` — rails keep the
    carried LoadState, health ``extra_loads`` pre-charge, and survivor
    ``rail_mask``, so all of the online control plane's feedback plumbing
    applies unchanged. Level 2 LPTs each window's *inter-pod* chunks per
    destination pod over the ``num_lanes`` wan lanes, with per-pod lane
    loads carried across windows (pass ``lane_loads`` — a mutable dict —
    to also carry them across *calls*, e.g. across a pod's domains or
    across release batches).

    Intra-pod chunks (``dst_pods == src_pod``) get lane ``-1``.

    Returns a :class:`~repro.core.lpt.HierLptResult` whose ``rail`` field
    is the windowed level-1 result.
    """
    weights = np.asarray(weights, dtype=np.float64)
    dst_pods = np.asarray(dst_pods)
    if dst_pods.shape != weights.shape:
        raise ValueError(
            f"dst_pods shape {dst_pods.shape} != weights shape {weights.shape}"
        )
    if num_lanes < 1:
        raise ValueError(f"num_lanes must be >= 1, got {num_lanes}")
    rail_res = windowed_lpt_schedule(
        weights,
        num_rails,
        window=window,
        source_ids=source_ids,
        initial_loads=initial_loads,
        extra_loads=extra_loads,
        rail_mask=rail_mask,
    )
    if lane_loads is None:
        lane_loads = {}
    f = weights.size
    lane = np.full(f, -1, dtype=np.int64)
    step = f if window is None else max(window, 1)
    source_ids = None if source_ids is None else np.asarray(source_ids)
    for lo in range(0, f, step):
        hi = min(lo + step, f)
        wp = dst_pods[lo:hi]
        for q in np.unique(wp).tolist():
            if q == src_pod:
                continue
            idx = np.flatnonzero(wp == q) + lo
            sub = lpt_schedule(
                weights[idx],
                num_lanes,
                source_ids=None if source_ids is None else source_ids[idx],
                initial_loads=lane_loads.get(q),
            )
            lane[idx] = sub.assignment
            lane_loads[q] = sub.loads
    mses = [load_mse(v) for v in lane_loads.values()]
    return HierLptResult(
        rail=rail_res,
        lane=lane,
        lane_loads=dict(lane_loads),
        lane_mse=float(np.mean(mses)) if mses else 0.0,
    )


def online_greedy_schedule(
    weights: np.ndarray,
    num_rails: int,
    initial_loads: np.ndarray | None = None,
) -> LptResult:
    """Pure greedy list scheduling: each chunk, on arrival, to the least-
    loaded rail. Graham's 2 - 1/N competitive baseline; equals
    :func:`windowed_lpt_schedule` with ``window=1``."""
    return windowed_lpt_schedule(weights, num_rails, window=1, initial_loads=initial_loads)


class PlanCache:
    """Memoized spray plans keyed by (traffic-matrix hash, LoadState digest).

    Gating counts drift slowly (paper Fig. 2d): consecutive iterations
    frequently replay the *same* forecast, and re-running split → LPT →
    quality scoring on an unchanged matrix is pure waste on the training
    loop's critical path. The cache digests the forecast arrays (content,
    not identity) and returns the previously computed plan when both the
    traffic matrix and the scheduler's load/pre-charge state are unchanged.

    A small LRU bound keeps memory flat under slow drift (phases revisit
    earlier matrices; unbounded growth would leak across a long run).
    """

    def __init__(self, capacity: int = 16):
        if capacity < 1:
            raise ValueError("capacity must be >= 1")
        self.capacity = capacity
        self._entries: dict[bytes, object] = {}
        self.hits = 0
        self.misses = 0

    @staticmethod
    def digest(*parts) -> bytes:
        """Content hash of a mix of arrays / scalars — the cache key."""
        h = hashlib.blake2b(digest_size=16)
        for part in parts:
            if part is None:
                h.update(b"\x00none")
                continue
            arr = np.asarray(part)
            h.update(str(arr.shape).encode())
            h.update(str(arr.dtype).encode())
            h.update(np.ascontiguousarray(arr).tobytes())
        return h.digest()

    def get(self, key: bytes):
        """Cached value for ``key`` or None; refreshes LRU order on hit."""
        value = self._entries.pop(key, None)
        if value is None:
            self.misses += 1
            return None
        self._entries[key] = value  # re-insert -> most recently used
        self.hits += 1
        return value

    def put(self, key: bytes, value) -> None:
        self._entries.pop(key, None)
        self._entries[key] = value
        while len(self._entries) > self.capacity:
            self._entries.pop(next(iter(self._entries)))

    def clear(self) -> None:
        """Drop every entry (hit/miss counters survive). Failover calls
        this: a cached plan sprays over rails that may no longer exist,
        and replaying it after a topology change would resurrect traffic
        onto a dead rail."""
        self._entries.clear()

    @property
    def hit_rate(self) -> float:
        total = self.hits + self.misses
        return self.hits / total if total else 0.0


@dataclasses.dataclass
class RoutingReplayState:
    """EWMA replay of per-domain egress observed in previous iterations.

    Gating counts drift slowly between training iterations (paper Fig. 2d:
    phase-to-phase movement, not step-to-step chaos), so iteration k's
    realized loads are a usable forecast for k+1. The scheduler seeds its
    LoadState pre-charge and chunk sizing from this forecast instead of
    assuming zero knowledge at the start of each round.

    Attributes:
      num_domains: M.
      num_rails: N.
      alpha: EWMA weight of the newest iteration.
    """

    num_domains: int
    num_rails: int
    alpha: float = 0.5

    def __post_init__(self) -> None:
        self._totals = np.zeros(self.num_domains)
        self._rail_loads = np.zeros((self.num_domains, self.num_rails))
        self.iterations = 0

    def _blend(self, old: np.ndarray, new: np.ndarray) -> np.ndarray:
        return new if self.iterations == 0 else self.alpha * new + (1 - self.alpha) * old

    def update_from_loads(self, domain_totals: np.ndarray, rail_loads: np.ndarray | None = None) -> None:
        """Fold one finished iteration's realized per-domain egress in."""
        domain_totals = np.asarray(domain_totals, dtype=np.float64)
        if domain_totals.shape != (self.num_domains,):
            raise ValueError(f"domain_totals must be ({self.num_domains},)")
        self._totals = self._blend(self._totals, domain_totals)
        if rail_loads is not None:
            rail_loads = np.asarray(rail_loads, dtype=np.float64)
            self._rail_loads = self._blend(self._rail_loads, rail_loads)
        self.iterations += 1

    def update_from_counts(self, counts: np.ndarray, bytes_per_token: float) -> None:
        """Fold one iteration's ``(M, M)`` gating-count matrix in."""
        counts = np.asarray(counts, dtype=np.float64)
        if counts.shape != (self.num_domains, self.num_domains):
            raise ValueError(f"counts must be (M, M), got {counts.shape}")
        off_diag = counts * (1.0 - np.eye(self.num_domains))
        self.update_from_loads(off_diag.sum(axis=1) * bytes_per_token)

    def expected_total(self, domain: int) -> float:
        """Forecast of the domain's egress bytes next iteration (0 = no data)."""
        return float(self._totals[domain])

    def expected_totals(self) -> np.ndarray:
        """``(M,)`` forecast of per-domain egress bytes next iteration —
        what :class:`GatingFeedbackHook` scores its forecast error against
        once the iteration's realized loads land."""
        return self._totals.copy()

    def expected_rail_profile(self, domain: int) -> np.ndarray:
        """Normalized ``(N,)`` rail-load profile from previous iterations;
        uniform when nothing has been observed. Diagnostic view of where
        the scheduler has been landing a domain's bytes (a skewed profile
        under nominal speeds means the pre-charge is doing work)."""
        row = self._rail_loads[domain]
        total = row.sum()
        if total <= 0:
            return np.full(self.num_rails, 1.0 / self.num_rails)
        return row / total


@dataclasses.dataclass
class AdaptiveChunker:
    """Chunk sizing from forecast totals + observed imbalance.

    Theorem 4 bounds the load MSE by ``w_max^2``: enough chunks per rail
    and LPT is near-perfect, but over-splitting pays per-chunk overhead.
    ``suggest`` targets ``target_multiplicity`` chunks per rail from the
    forecast egress, capped by the running ``chunk_bytes``; ``adapt``
    halves that cap when realized normalized MSE exceeds ``mse_hi``
    (forcing the next suggestion below the multiplicity ideal) and
    relaxes it when comfortably below ``mse_lo``.
    """

    chunk_bytes: float
    min_bytes: float = 32 * 2**10
    max_bytes: float = 64 * 2**20
    target_multiplicity: int = 8
    mse_hi: float = 1e-3
    mse_lo: float = 1e-5
    grow: float = 1.5

    def suggest(self, expected_total: float, num_rails: int) -> float:
        """Chunk size giving ~target_multiplicity chunks per rail, never
        above the feedback-adapted ``chunk_bytes`` cap."""
        if expected_total <= 0:
            return self.chunk_bytes
        ideal = expected_total / (num_rails * self.target_multiplicity)
        return float(np.clip(min(ideal, self.chunk_bytes), self.min_bytes, self.max_bytes))

    def adapt(self, observed_norm_mse: float) -> float:
        """Feedback step on the running chunk-size cap; returns the new cap."""
        if observed_norm_mse > self.mse_hi:
            self.chunk_bytes = max(self.chunk_bytes / 2.0, self.min_bytes)
        elif observed_norm_mse < self.mse_lo:
            self.chunk_bytes = min(self.chunk_bytes * self.grow, self.max_bytes)
        return self.chunk_bytes


class GatingFeedbackHook:
    """Training-loop adapter: per-iteration gating counts -> next plan.

    The train step already surfaces summed expert token counts
    (``metrics['moe_counts']``). Each call folds them into the replay
    state, sizes chunks adaptively, and LPT-plans the *next* iteration's
    all-to-all from the replayed forecast — the control-plane half of the
    dispatch the real transport would execute.

    ``expert_counts`` may be flat ``(E,)`` per-expert totals (the uniform-
    sender convention of ``core.traffic.mixtral_trace_workload``) or a
    real per-(shard, expert) ``(M, E)`` matrix straight from the gate.
    With no ``placement`` the layout is the historical round-robin map —
    flat-counts outputs are bit-identical to the pre-placement hook. A
    :class:`~repro.placement.Placement` makes the lowering layout-aware,
    and an :class:`~repro.placement.OnlinePlacementController` lets the
    hook migrate experts mid-run: each migration's weight bytes are
    injected into that iteration's planned traffic so the forecast prices
    the re-layout it just decided on.
    """

    def __init__(
        self,
        num_domains: int,
        num_rails: int,
        bytes_per_token: float,
        chunk_bytes: float = 4 * 2**20,
        replay_alpha: float = 0.5,
        plan_cache: PlanCache | None = None,
        placement=None,
        controller=None,
    ):
        self.num_domains = num_domains
        self.num_rails = num_rails
        self.bytes_per_token = float(bytes_per_token)
        self.replay = RoutingReplayState(num_domains, num_rails, alpha=replay_alpha)
        self.chunker = AdaptiveChunker(chunk_bytes=chunk_bytes)
        # Steady gating phases replay identical forecasts; skip re-planning
        # whenever (counts matrix, chunk size) digests to a known key.
        self.plan_cache = PlanCache() if plan_cache is None else plan_cache
        if controller is not None and placement is None:
            placement = controller.placement
        self.placement = placement  # repro.placement.Placement | None
        self.controller = controller  # OnlinePlacementController | None
        # All rails alive until the dead-rail watchdog says otherwise;
        # with the full mask every code path below is bit-identical to
        # the pre-failover hook.
        self.survivor_mask = np.ones(num_rails, dtype=bool)

    def on_rail_failure(self, dead_rails) -> None:
        """Watchdog callback: shrink the planning fabric to survivors.

        Clears the plan cache (cached plans spray over the dead rail) and
        records the survivor mask so subsequent :meth:`on_step` calls
        plan, size chunks, and score the Theorem-2 bound over the
        asymmetric N−k rail set.
        """
        mask = self.survivor_mask.copy()
        for r in dead_rails:
            if not 0 <= int(r) < self.num_rails:
                raise ValueError(f"rail {r} out of range [0, {self.num_rails})")
            mask[int(r)] = False
        if not mask.any():
            raise ValueError("on_rail_failure would leave no rail alive")
        self.survivor_mask = mask
        self.plan_cache.clear()

    def on_rail_repair(self, rails) -> None:
        """Repaired rails rejoin the planning fabric (cache cleared again
        — survivor-set plans under-use the returned capacity)."""
        mask = self.survivor_mask.copy()
        for r in rails:
            mask[int(r)] = True
        self.survivor_mask = mask
        self.plan_cache.clear()

    def _counts_matrix(self, expert_counts: np.ndarray) -> np.ndarray:
        from ..core.traffic import expert_counts_to_matrix

        if self.placement is not None:
            return self.placement.counts_d2(expert_counts)
        return expert_counts_to_matrix(expert_counts, self.num_domains)

    def on_step(self, expert_counts: np.ndarray) -> dict:
        """Consume one iteration's gating counts; return the plan forecast."""
        from ..core.plan import build_all_plans, plan_quality
        from ..core.theorems import theorem2_optimal_time
        from ..core.traffic import moe_gating_traffic

        migration_d2 = None
        migration_bytes = 0.0
        migrated = False
        if self.controller is not None:
            decision = self.controller.observe(expert_counts)
            self.placement = decision.placement
            if decision.migrated:
                migrated = True
                migration_d2 = decision.migration_d2
                migration_bytes = decision.migration_bytes
        c2 = self._counts_matrix(expert_counts)
        if migration_d2 is None:
            tm = moe_gating_traffic(c2, self.bytes_per_token, self.num_rails)
        else:
            # The re-layout's weight transfers ride the same fabric as the
            # gating payload — plan them together.
            tm = moe_gating_traffic(
                c2 * self.bytes_per_token + migration_d2, 1.0, self.num_rails
            )
        # Plan over the *surviving* rail set: with the full mask this is
        # the historical N-rail path, bit-identical; after a failure every
        # sizing/quality/bound computation sees N−k rails.
        alive = int(self.survivor_mask.sum())
        degraded = alive < self.num_rails
        # Plan from the replayed forecast (what the scheduler would know at
        # the *start* of the next iteration), falling back to this
        # iteration's counts on the very first call.
        chunk = self.chunker.suggest(
            max((self.replay.expected_total(d) for d in range(self.num_domains)),
                default=0.0)
            or tm.domain_send_totals().max(),
            alive,
        )
        key = PlanCache.digest(
            c2, np.float64(chunk), migration_d2, self.survivor_mask
        )
        cached = self.plan_cache.get(key)
        if cached is None:
            plans = build_all_plans(
                tm.d1, chunk, rail_mask=self.survivor_mask if degraded else None
            )
            quality = plan_quality(plans, self.num_rails)
            # MSE over the *alive* columns only — a dead rail's frozen
            # zero load is the plan working, not imbalance.
            send_mse = max(
                normalized_load_mse(quality["send_loads"][d][self.survivor_mask])
                for d in range(self.num_domains)
            )
            self.plan_cache.put(key, (quality, send_mse))
        else:
            quality, send_mse = cached
        self.chunker.adapt(send_mse)
        # Score last iteration's replayed forecast against what this
        # iteration actually put on the wire (L1, relative): the hook's
        # view of how fast gating is drifting under its feet.
        realized = tm.domain_send_totals()
        predicted = self.replay.expected_totals()
        forecast_err = float(
            np.abs(predicted - realized).sum() / max(np.abs(realized).sum(), 1e-12)
        )
        self.replay.update_from_loads(realized, quality["send_loads"])
        return {
            "chunk_bytes": chunk,
            "total_bytes": tm.total_bytes(),
            "pred_send_mse": send_mse,
            "pred_max_load": quality["max_load"],
            "opt_time_s": theorem2_optimal_time(tm.d2, alive, 50e9),
            "plan_cache_hit": cached is not None,
            "forecast_err": forecast_err,
            "migrated": migrated,
            "migration_bytes": migration_bytes,
            "alive_rails": alive,
        }
