"""Request-level serving simulation layer (``repro.serve``).

Façade over the serving-path subsystem:

* workload generation — :func:`~repro.core.traffic.serve_workload`
  (Poisson / bursty / diurnal request arrivals; per-request prefill +
  autoregressive decode rounds, each decode round emitting a small
  expert-routed all-to-all);
* simulation driver — :func:`~repro.sched.serving.run_serving` (any
  policy, any :class:`~repro.netsim.linkmodel.FaultSpec` degraded
  fabric), scoring release-relative tails: TTFT, per-token latency and
  request sojourn at p50/p90/p99/p99.9;
* trace replay — :func:`~repro.sched.serving.simulate_decode_trace`
  drives the simulated fabric with per-step expert counts recorded from
  a real decode loop (``python -m repro.launch.serve --sim-fabric``).

Quick start::

    from repro.serve import serve_workload, run_serving
    wl = serve_workload(8, 8, num_requests=64, mean_gap=2e-3)
    res = run_serving(wl, "rails-online", feedback=True)
    print(res.request.ttft_percentiles())   # {'p50': ..., 'p99.9': ...}
"""

from .core.traffic import (
    ServeRequest,
    ServeRound,
    ServeWorkload,
    request_arrival_times,
    serve_workload,
)
from .sched.serving import (
    SERVE_QS,
    DecodeTraceResult,
    RequestMetrics,
    ServingResult,
    expert_counts_to_matrix,
    run_serving,
    simulate_decode_trace,
)

__all__ = [
    "SERVE_QS",
    "DecodeTraceResult",
    "RequestMetrics",
    "ServeRequest",
    "ServeRound",
    "ServeWorkload",
    "ServingResult",
    "expert_counts_to_matrix",
    "request_arrival_times",
    "run_serving",
    "serve_workload",
    "simulate_decode_trace",
]
