"""Backend-dispatching jit wrappers for the Pallas kernels.

On TPU the Pallas kernels run natively; everywhere else the pure-jnp oracle
(ref.py) executes — same semantics, so model code calls these
unconditionally. ``REPRO_PALLAS=interpret`` forces the Pallas path in
interpret mode (used by kernel tests), ``REPRO_PALLAS=off`` forces the ref.
"""

from __future__ import annotations

import os
from typing import Optional

import jax
import jax.numpy as jnp

from .flash_attention import flash_attention_pallas
from .grouped_matmul import grouped_matmul_pallas
from .ref import flash_attention_ref, grouped_matmul_ref, rmsnorm_ref
from .rmsnorm import rmsnorm_pallas

__all__ = ["flash_attention", "grouped_matmul", "rmsnorm", "kernel_backend"]


def kernel_backend() -> str:
    mode = os.environ.get("REPRO_PALLAS", "auto")
    if mode == "interpret":
        return "interpret"
    if mode == "off":
        return "ref"
    if mode == "auto":
        return "pallas" if jax.default_backend() == "tpu" else "ref"
    return mode


def flash_attention(
    q: jnp.ndarray,
    k: jnp.ndarray,
    v: jnp.ndarray,
    *,
    causal: bool = True,
    q_offset: int = 0,
    window: Optional[int] = None,
    softcap: Optional[float] = None,
    scale: Optional[float] = None,
    block_q: int = 128,
    block_k: int = 128,
) -> jnp.ndarray:
    backend = kernel_backend()
    if backend in ("pallas", "interpret"):
        return flash_attention_pallas(
            q,
            k,
            v,
            causal=causal,
            q_offset=q_offset,
            window=window,
            softcap=softcap,
            scale=scale,
            block_q=block_q,
            block_k=block_k,
            interpret=backend == "interpret",
        )
    return flash_attention_ref(
        q,
        k,
        v,
        causal=causal,
        q_offset=q_offset,
        window=window,
        softcap=softcap,
        scale=scale,
        block_k=block_k,
    )


def grouped_matmul(x: jnp.ndarray, w: jnp.ndarray) -> jnp.ndarray:
    backend = kernel_backend()
    if backend in ("pallas", "interpret"):
        return grouped_matmul_pallas(x, w, interpret=backend == "interpret")
    return grouped_matmul_ref(x, w)


def rmsnorm(x: jnp.ndarray, weight: jnp.ndarray, eps: float = 1e-6) -> jnp.ndarray:
    backend = kernel_backend()
    if backend in ("pallas", "interpret"):
        return rmsnorm_pallas(x, weight, eps, interpret=backend == "interpret")
    return rmsnorm_ref(x, weight, eps)
