"""Pure-jnp oracles for every Pallas kernel (and the CPU execution path).

Each function here is the semantic ground truth: the Pallas kernels in this
package must match these to float tolerance (tests sweep shapes/dtypes in
``interpret=True``), and non-TPU backends execute these directly.
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

__all__ = ["flash_attention_ref", "grouped_matmul_ref", "rmsnorm_ref"]


def _soft_cap(x: jnp.ndarray, cap: Optional[float]) -> jnp.ndarray:
    if cap is None:
        return x
    return jnp.tanh(x / cap) * cap


def flash_attention_ref(
    q: jnp.ndarray,
    k: jnp.ndarray,
    v: jnp.ndarray,
    *,
    causal: bool = True,
    q_offset: int | jnp.ndarray = 0,
    window: Optional[int] = None,
    softcap: Optional[float] = None,
    scale: Optional[float] = None,
    block_k: int = 512,
) -> jnp.ndarray:
    """Blockwise online-softmax attention (FlashAttention semantics).

    Args:
      q: ``(B, T, H, hd)`` queries.
      k: ``(B, S, Hkv, hd)`` keys (GQA: ``H % Hkv == 0``).
      v: ``(B, S, Hkv, hd)`` values.
      causal: causal masking using absolute positions ``q_pos = q_offset + t``.
      q_offset: absolute position of the first query (decode: ``S - T``).
      window: sliding-window size (None = unlimited). A key at position
        ``p`` is visible iff ``q_pos - p < window`` (and ``p <= q_pos``).
      softcap: attention-logit soft cap (gemma2): ``tanh(x/c) * c``.
      scale: score scale (default ``hd ** -0.5``).
      block_k: KV block length for the scan (memory control).

    Returns ``(B, T, H, hd)`` in the dtype of ``q``.
    """
    b, t, h, hd = q.shape
    s = k.shape[1]
    hkv = k.shape[2]
    rep = h // hkv
    if scale is None:
        scale = hd**-0.5
    orig_dtype = q.dtype
    qf = q.astype(jnp.float32).reshape(b, t, hkv, rep, hd) * scale
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)

    blk = min(block_k, s)
    pad = (-s) % blk
    if pad:
        kf = jnp.pad(kf, ((0, 0), (0, pad), (0, 0), (0, 0)))
        vf = jnp.pad(vf, ((0, 0), (0, pad), (0, 0), (0, 0)))
    n_blocks = kf.shape[1] // blk
    kf = kf.reshape(b, n_blocks, blk, hkv, hd)
    vf = vf.reshape(b, n_blocks, blk, hkv, hd)

    q_pos = q_offset + jnp.arange(t)  # (T,)

    def body(carry, inputs):
        m_prev, l_prev, acc_prev = carry
        k_blk, v_blk, blk_idx = inputs  # (B, blk, Hkv, hd) x2, scalar
        scores = jnp.einsum("bthrd,bshd->bhrts", qf, k_blk)  # (B,Hkv,rep,T,blk)
        scores = _soft_cap(scores, softcap)
        k_pos = blk_idx * blk + jnp.arange(blk)  # (blk,)
        mask = k_pos[None, :] < s  # padding
        if causal:
            mask = mask & (k_pos[None, :] <= q_pos[:, None])
        if window is not None:
            mask = mask & (q_pos[:, None] - k_pos[None, :] < window)
        scores = jnp.where(mask[None, None, None], scores, -jnp.inf)
        m_cur = jnp.max(scores, axis=-1)  # (B,Hkv,rep,T)
        m_new = jnp.maximum(m_prev, m_cur)
        # Guard fully-masked rows (m == -inf) against NaNs.
        m_safe = jnp.where(jnp.isneginf(m_new), 0.0, m_new)
        p = jnp.exp(scores - m_safe[..., None])
        p = jnp.where(mask[None, None, None], p, 0.0)
        correction = jnp.where(
            jnp.isneginf(m_prev), 0.0, jnp.exp(m_prev - m_safe)
        )
        l_new = l_prev * correction + p.sum(axis=-1)
        acc_new = acc_prev * correction.transpose(0, 3, 1, 2)[..., None] + jnp.einsum(
            "bhrts,bshd->bthrd", p, v_blk
        )
        return (m_new, l_new, acc_new), None

    m0 = jnp.full((b, hkv, rep, t), -jnp.inf, dtype=jnp.float32)
    l0 = jnp.zeros((b, hkv, rep, t), dtype=jnp.float32)
    acc0 = jnp.zeros((b, t, hkv, rep, hd), dtype=jnp.float32)
    (m_f, l_f, acc_f), _ = jax.lax.scan(
        body,
        (m0, l0, acc0),
        (kf.transpose(1, 0, 2, 3, 4), vf.transpose(1, 0, 2, 3, 4), jnp.arange(n_blocks)),
    )
    l_t = l_f.transpose(0, 3, 1, 2)[..., None]  # (B,T,Hkv,rep,1)
    out = acc_f / jnp.maximum(l_t, 1e-37)
    return out.reshape(b, t, h, hd).astype(orig_dtype)


def grouped_matmul_ref(
    x: jnp.ndarray, w: jnp.ndarray, *, preferred_dtype=jnp.float32
) -> jnp.ndarray:
    """Per-group GEMM: ``(G, N, K) @ (G, K, M) -> (G, N, M)``.

    The MoE expert-FFN hot loop: group g is expert g's token bucket.
    """
    out = jnp.einsum("gnk,gkm->gnm", x, w, preferred_element_type=preferred_dtype)
    return out.astype(x.dtype)


def rmsnorm_ref(x: jnp.ndarray, weight: jnp.ndarray, eps: float = 1e-6) -> jnp.ndarray:
    """RMSNorm in fp32 accumulation: ``x * rsqrt(mean(x^2)+eps) * w``."""
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    out = xf * jax.lax.rsqrt(var + eps) * weight.astype(jnp.float32)
    return out.astype(x.dtype)
