"""Pallas TPU fused RMSNorm kernel.

Row-block kernel: grid over token blocks, each block ``(block_rows, D)`` is
normalized in one VMEM-resident pass (fp32 mean-square + rsqrt + scale) —
fuses what XLA would otherwise emit as several HBM round trips.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["rmsnorm_pallas"]


def _rmsnorm_kernel(x_ref, w_ref, o_ref, *, eps: float):
    x = x_ref[...].astype(jnp.float32)
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    o_ref[...] = (x * jax.lax.rsqrt(var + eps) * w_ref[...].astype(jnp.float32)).astype(
        o_ref.dtype
    )


def rmsnorm_pallas(
    x: jnp.ndarray,
    weight: jnp.ndarray,
    eps: float = 1e-6,
    *,
    block_rows: int = 256,
    interpret: bool = False,
) -> jnp.ndarray:
    """Fused RMSNorm matching ``ref.rmsnorm_ref``. ``x: (..., D)``."""
    orig_shape = x.shape
    d = orig_shape[-1]
    x2 = x.reshape(-1, d)
    rows = x2.shape[0]
    block_rows = min(block_rows, rows)
    pad = (-rows) % block_rows
    if pad:
        x2 = jnp.pad(x2, ((0, pad), (0, 0)))
    grid = (x2.shape[0] // block_rows,)
    out = pl.pallas_call(
        functools.partial(_rmsnorm_kernel, eps=eps),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_rows, d), lambda i: (i, 0)),
            pl.BlockSpec((d,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((block_rows, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct(x2.shape, x.dtype),
        interpret=interpret,
    )(x2, weight)
    if pad:
        out = out[:rows]
    return out.reshape(orig_shape)
