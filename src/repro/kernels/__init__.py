"""Pallas TPU kernels for the framework's compute hot spots.

The paper's contribution is communication-level (no custom compute kernel),
so this package covers the compute on either side of the all-to-all:

* ``flash_attention`` — blockwise online-softmax attention (32k prefill).
* ``grouped_matmul`` — per-expert GEMM over token buckets (MoE FFN).
* ``rmsnorm`` — fused normalization.

Layout: ``<name>.py`` holds the ``pl.pallas_call`` kernel with explicit
BlockSpec VMEM tiling; ``ops.py`` is the backend-dispatching jit wrapper;
``ref.py`` the pure-jnp oracle. Tests sweep shapes/dtypes in interpret mode.
"""

from .ops import flash_attention, grouped_matmul, kernel_backend, rmsnorm

__all__ = ["flash_attention", "grouped_matmul", "kernel_backend", "rmsnorm"]
