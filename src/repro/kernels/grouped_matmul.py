"""Pallas TPU grouped-GEMM kernel — the MoE expert-FFN hot loop.

``x: (G, N, K) @ w: (G, K, M) -> (G, N, M)`` where group g is expert g's
token bucket (post all-to-all layout of :mod:`repro.models.moe`).

TPU-native tiling: grid ``(G, N/bn, M/bm, K/bk)`` with the contraction axis
minor so the f32 accumulator tile stays in VMEM scratch across K steps.
Tiles are MXU-aligned (bn, bm, bk multiples of 128 for full utilization on
real payloads; smaller shapes are padded by the wrapper).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["grouped_matmul_pallas"]


def _gmm_kernel(x_ref, w_ref, o_ref, acc_scr):
    ki = pl.program_id(3)
    nk = pl.num_programs(3)

    @pl.when(ki == 0)
    def _init():
        acc_scr[...] = jnp.zeros_like(acc_scr)

    acc_scr[...] += jnp.dot(
        x_ref[...].astype(jnp.float32),
        w_ref[...].astype(jnp.float32),
        preferred_element_type=jnp.float32,
    )

    @pl.when(ki == nk - 1)
    def _store():
        o_ref[...] = acc_scr[...].astype(o_ref.dtype)


def grouped_matmul_pallas(
    x: jnp.ndarray,
    w: jnp.ndarray,
    *,
    block_n: int = 128,
    block_m: int = 128,
    block_k: int = 128,
    interpret: bool = False,
) -> jnp.ndarray:
    """Per-group GEMM matching ``ref.grouped_matmul_ref``."""
    g, n, k = x.shape
    g2, k2, m = w.shape
    if (g2, k2) != (g, k):
        raise ValueError(f"shape mismatch: x {x.shape} vs w {w.shape}")
    block_n = min(block_n, n)
    block_m = min(block_m, m)
    block_k = min(block_k, k)
    pn, pm, pk = (-n) % block_n, (-m) % block_m, (-k) % block_k
    xp = jnp.pad(x, ((0, 0), (0, pn), (0, pk))) if (pn or pk) else x
    wp = jnp.pad(w, ((0, 0), (0, pk), (0, pm))) if (pk or pm) else w
    np_, mp_, kp_ = xp.shape[1], wp.shape[2], xp.shape[2]

    grid = (g, np_ // block_n, mp_ // block_m, kp_ // block_k)
    out = pl.pallas_call(
        _gmm_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((None, block_n, block_k), lambda gi, ni, mi, ki: (gi, ni, ki)),
            pl.BlockSpec((None, block_k, block_m), lambda gi, ni, mi, ki: (gi, ki, mi)),
        ],
        out_specs=pl.BlockSpec(
            (None, block_n, block_m), lambda gi, ni, mi, ki: (gi, ni, mi)
        ),
        out_shape=jax.ShapeDtypeStruct((g, np_, mp_), x.dtype),
        scratch_shapes=[pltpu.VMEM((block_n, block_m), jnp.float32)],
        interpret=interpret,
    )(xp, wp)
    if pn or pm:
        out = out[:, :n, :m]
    return out
