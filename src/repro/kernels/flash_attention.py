"""Pallas TPU flash-attention kernel (BlockSpec VMEM tiling).

TPU-native design notes (DESIGN.md §3 hardware adaptation):

* Tiles are MXU-aligned: ``block_q x head_dim`` and ``block_k x head_dim``
  with ``head_dim`` padded to a lane multiple (128) by the caller.
* The grid is ``(batch*heads, T/block_q, S/block_k)``; the KV dimension is
  the minor (sequential) axis so the f32 accumulator, running max ``m`` and
  normalizer ``l`` live in VMEM scratch across KV steps — the online-softmax
  recurrence never touches HBM.
* GQA is expressed in the BlockSpec index maps: the K/V index map divides
  the head id by ``rep = H // Hkv``, so query heads of one group stream the
  same KV tiles (VMEM reuse instead of materializing repeated KV).
* Causal / sliding-window masks are applied with ``broadcasted_iota`` over
  absolute positions; fully-masked tiles still execute (documented trade-off
  — grid pruning is a possible follow-up, see EXPERIMENTS.md §Perf).
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["flash_attention_pallas"]

NEG_INF = -1e30


def _flash_kernel(
    q_ref,
    k_ref,
    v_ref,
    o_ref,
    m_scr,
    l_scr,
    acc_scr,
    *,
    scale: float,
    causal: bool,
    window: Optional[int],
    softcap: Optional[float],
    q_offset: int,
    block_q: int,
    block_k: int,
    seq_k: int,
):
    qi = pl.program_id(1)
    ki = pl.program_id(2)
    nk = pl.num_programs(2)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[...].astype(jnp.float32) * scale  # (block_q, hd)
    k = k_ref[...].astype(jnp.float32)  # (block_k, hd)
    s = jnp.dot(q, k.T, preferred_element_type=jnp.float32)  # (bq, bk)
    if softcap is not None:
        s = jnp.tanh(s / softcap) * softcap

    q_pos = q_offset + qi * block_q + jax.lax.broadcasted_iota(
        jnp.int32, (block_q, block_k), 0
    )
    k_pos = ki * block_k + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 1)
    mask = k_pos < seq_k
    if causal:
        mask = mask & (k_pos <= q_pos)
    if window is not None:
        mask = mask & (q_pos - k_pos < window)
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_scr[...]
    m_cur = jnp.max(s, axis=-1)
    m_new = jnp.maximum(m_prev, m_cur)
    p = jnp.exp(s - m_new[:, None])
    p = jnp.where(mask, p, 0.0)
    correction = jnp.exp(m_prev - m_new)
    l_new = l_scr[...] * correction + p.sum(axis=-1)
    v = v_ref[...].astype(jnp.float32)
    acc_scr[...] = acc_scr[...] * correction[:, None] + jnp.dot(
        p, v, preferred_element_type=jnp.float32
    )
    m_scr[...] = m_new
    l_scr[...] = l_new

    @pl.when(ki == nk - 1)
    def _finalize():
        denom = jnp.maximum(l_scr[...], 1e-37)[:, None]
        o_ref[...] = (acc_scr[...] / denom).astype(o_ref.dtype)


def flash_attention_pallas(
    q: jnp.ndarray,
    k: jnp.ndarray,
    v: jnp.ndarray,
    *,
    causal: bool = True,
    q_offset: int = 0,
    window: Optional[int] = None,
    softcap: Optional[float] = None,
    scale: Optional[float] = None,
    block_q: int = 128,
    block_k: int = 128,
    interpret: bool = False,
) -> jnp.ndarray:
    """Pallas flash attention. Same contract as ``ref.flash_attention_ref``.

    ``q: (B, T, H, hd)``, ``k/v: (B, S, Hkv, hd)`` with ``H % Hkv == 0``.
    """
    b, t, h, hd = q.shape
    s = k.shape[1]
    hkv = k.shape[2]
    rep = h // hkv
    if scale is None:
        scale = hd**-0.5

    block_q = min(block_q, t)
    block_k = min(block_k, s)
    pad_q = (-t) % block_q
    pad_k = (-s) % block_k
    qp = jnp.pad(q, ((0, 0), (0, pad_q), (0, 0), (0, 0))) if pad_q else q
    kp = jnp.pad(k, ((0, 0), (0, pad_k), (0, 0), (0, 0))) if pad_k else k
    vp = jnp.pad(v, ((0, 0), (0, pad_k), (0, 0), (0, 0))) if pad_k else v
    tq, sk = qp.shape[1], kp.shape[1]

    # (B*H, T, hd) query-major layout; KV stays (B*Hkv, S, hd).
    q3 = qp.transpose(0, 2, 1, 3).reshape(b * h, tq, hd)
    k3 = kp.transpose(0, 2, 1, 3).reshape(b * hkv, sk, hd)
    v3 = vp.transpose(0, 2, 1, 3).reshape(b * hkv, sk, hd)

    grid = (b * h, tq // block_q, sk // block_k)

    kernel = functools.partial(
        _flash_kernel,
        scale=scale,
        causal=causal,
        window=window,
        softcap=softcap,
        q_offset=q_offset,
        block_q=block_q,
        block_k=block_k,
        seq_k=s,
    )
    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((None, block_q, hd), lambda bh, qi, ki: (bh, qi, 0)),
            pl.BlockSpec((None, block_k, hd), lambda bh, qi, ki, rep=rep: (bh // rep, ki, 0)),
            pl.BlockSpec((None, block_k, hd), lambda bh, qi, ki, rep=rep: (bh // rep, ki, 0)),
        ],
        out_specs=pl.BlockSpec((None, block_q, hd), lambda bh, qi, ki: (bh, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((b * h, tq, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q, hd), jnp.float32),
        ],
        interpret=interpret,
    )(q3, k3, v3)
    out = out.reshape(b, h, tq, hd).transpose(0, 2, 1, 3)
    if pad_q:
        out = out[:, :t]
    return out
