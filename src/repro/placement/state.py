"""Expert-placement state and cost model (`repro.placement`).

RailS balances a *given* traffic matrix by LPT-spraying chunks over
rails; this layer reshapes the matrix itself by choosing *where experts
live*. The state is an explicit expert→shard map plus per-expert weight
sizes; the cost model exposes the two quantities every placement decision
trades off:

* **Gating cost** — the shard-to-shard traffic a gating-count matrix
  induces under a placement (``counts_d2``), and its Theorem-2 optimal
  drain time (``placement_bound``) — the CCT floor LPT spraying
  approaches.
* **Migration cost** — re-laying-out experts moves weight bytes across
  the same fabric. ``migration_to`` returns the extra all-to-all flows a
  re-layout injects (one ``weight_bytes[e]`` message from the old shard
  to the new one per moved expert), which the controller amortizes
  against projected gating savings.

On hierarchical fabrics (:class:`repro.netsim.topology.MultiPodFabric`)
both costs are pod-aware: bytes that must cross pods ride the
oversubscribed WAN tier, so ``pod_priced_d2`` scales cross-pod entries by
the fabric's ``inter_pod_cost_factor`` before the Theorem-2 bound — an
expert migration between pods is ``oversub×`` as expensive as the same
move inside one, which is exactly the asymmetry a pod-aware re-layout
search must see to prefer intra-pod moves.

Everything is numpy + the existing traffic/theorem helpers; the simulated
(vector-backend) CCT scoring lives in :mod:`repro.placement.search`.
"""

from __future__ import annotations

import dataclasses

import numpy as np

from ..core.theorems import theorem2_optimal_time
from ..core.traffic import (
    TrafficMatrix,
    default_expert_shard,
    expert_counts_to_matrix,
    moe_gating_traffic,
    uniform_sender_counts,
)

__all__ = [
    "Placement",
    "as_shard_expert_counts",
    "placement_loads",
    "placement_bound",
    "pod_priced_d2",
]


def pod_priced_d2(d2: np.ndarray, fabric) -> np.ndarray:
    """Price cross-pod bytes at the fabric's oversubscribed WAN rate.

    Scales every ``d2[i, j]`` whose shards ``i``/``j`` live in different
    pods by ``fabric.inter_pod_cost_factor`` (= ``oversub`` at the default
    WAN rate), leaving intra-pod entries untouched. Flat fabrics (or
    ``fabric=None``) are the identity — every existing flat-pod call is
    bit-unchanged.
    """
    if fabric is None or getattr(fabric, "num_pods", 1) <= 1:
        return d2
    m = d2.shape[0]
    if m != fabric.m:
        raise ValueError(
            f"d2 covers {m} shards but the fabric has {fabric.m} domains"
        )
    pods = np.arange(m) // fabric.domains_per_pod
    cross = pods[:, None] != pods[None, :]
    return np.where(cross, d2 * fabric.inter_pod_cost_factor, d2)


def as_shard_expert_counts(counts: np.ndarray, num_shards: int) -> np.ndarray:
    """Normalize gating counts to the ``(M, E)`` per-(shard, expert) form.

    A flat ``(E,)`` vector is expanded under the uniform-sender convention
    with ``T_e / (M - 1)`` from *every* shard — including the (unknown at
    this point) host, whose contribution every consumer suppresses (the
    d2 diagonal / the ``1 - x[e,s]`` term of the LP). That keeps the
    expansion placement-independent: column sums minus the host row equal
    ``T_e`` whichever shard ends up hosting ``e``.
    """
    counts = np.asarray(counts, dtype=np.float64)
    if counts.ndim == 2:
        if counts.shape[0] != num_shards:
            raise ValueError(
                f"per-(shard, expert) counts need {num_shards} rows, got {counts.shape}"
            )
        return counts
    flat = counts.ravel()
    return np.tile(flat / max(num_shards - 1, 1), (num_shards, 1))


@dataclasses.dataclass(frozen=True)
class Placement:
    """An expert→shard map plus per-expert weight footprint.

    Attributes:
      expert_shard: ``(E,)`` shard index hosting each expert.
      num_shards: M (the fabric's expert-parallel domains).
      weight_bytes: ``(E,)`` parameter bytes per expert — what a
        migration of that expert puts on the wire (scalar broadcasts).
    """

    expert_shard: np.ndarray
    num_shards: int
    weight_bytes: np.ndarray = dataclasses.field(default_factory=lambda: np.float64(0.0))

    def __post_init__(self) -> None:
        es = np.asarray(self.expert_shard, dtype=np.int64).copy()
        es.setflags(write=False)
        object.__setattr__(self, "expert_shard", es)
        if es.ndim != 1 or es.size == 0:
            raise ValueError(f"expert_shard must be a non-empty vector, got {es.shape}")
        if self.num_shards < 1:
            raise ValueError("num_shards must be >= 1")
        if es.min() < 0 or es.max() >= self.num_shards:
            raise ValueError(
                f"expert_shard values must lie in [0, {self.num_shards}), "
                f"got range [{es.min()}, {es.max()}]"
            )
        wb = np.broadcast_to(
            np.asarray(self.weight_bytes, dtype=np.float64), es.shape
        ).copy()
        if np.any(wb < 0):
            raise ValueError("weight_bytes must be >= 0")
        wb.setflags(write=False)
        object.__setattr__(self, "weight_bytes", wb)

    # -- construction -------------------------------------------------------

    @classmethod
    def round_robin(
        cls, num_experts: int, num_shards: int, weight_bytes=0.0
    ) -> "Placement":
        """The historical static layout: expert ``e`` on shard ``e % M``."""
        return cls(
            default_expert_shard(num_experts, num_shards), num_shards, weight_bytes
        )

    @property
    def num_experts(self) -> int:
        return self.expert_shard.size

    def shard_expert_counts(self) -> np.ndarray:
        """``(M,)`` number of experts hosted per shard (capacity view)."""
        return np.bincount(self.expert_shard, minlength=self.num_shards)

    def move(self, expert: int, shard: int) -> "Placement":
        es = self.expert_shard.copy()
        es[expert] = shard
        return dataclasses.replace(self, expert_shard=es)

    def swap(self, e1: int, e2: int) -> "Placement":
        es = self.expert_shard.copy()
        es[e1], es[e2] = es[e2], es[e1]
        return dataclasses.replace(self, expert_shard=es)

    # -- gating cost --------------------------------------------------------

    def counts_d2(self, counts: np.ndarray) -> np.ndarray:
        """Gating counts → ``(M, M)`` shard-to-shard token matrix.

        Accepts flat ``(E,)`` per-expert totals (uniform senders) or a
        full ``(M, E)`` per-(shard, expert) matrix; intra-shard tokens
        stay on NVLink (zero diagonal). With the round-robin map and flat
        counts this is bit-identical to the historical
        :func:`~repro.core.traffic.expert_counts_to_matrix` output.
        """
        return expert_counts_to_matrix(counts, self.num_shards, self.expert_shard)

    def traffic(
        self,
        counts: np.ndarray,
        bytes_per_token: float,
        num_rails: int,
        migration_d2: np.ndarray | None = None,
        name: str = "placed-gating",
    ) -> TrafficMatrix:
        """Lower gating counts (plus optional migration flows) to a
        :class:`TrafficMatrix` under this placement.

        ``migration_d2`` is an ``(M, M)`` *bytes* matrix of in-flight
        expert-weight transfers (from :meth:`migration_to`) — the modeled
        cost of a re-layout rides the same all-to-all as the gating
        payload it competes with.
        """
        d2_bytes = self.counts_d2(counts) * float(bytes_per_token)
        if migration_d2 is not None:
            migration_d2 = np.asarray(migration_d2, dtype=np.float64)
            if migration_d2.shape != d2_bytes.shape:
                raise ValueError(
                    f"migration_d2 must be {d2_bytes.shape}, got {migration_d2.shape}"
                )
            d2_bytes = d2_bytes + migration_d2
        tm = moe_gating_traffic(d2_bytes, 1.0, num_rails)
        return TrafficMatrix(d1=tm.d1, d2=tm.d2, name=name)

    def uniform_counts(self, expert_tokens: np.ndarray) -> np.ndarray:
        """Expand per-expert totals to ``(M, E)`` under *this* layout
        (host shard sends zero — its tokens stay on NVLink)."""
        return uniform_sender_counts(
            expert_tokens, self.expert_shard, self.num_shards
        )

    # -- migration cost -----------------------------------------------------

    def migration_to(
        self, other: "Placement", fabric=None
    ) -> tuple[np.ndarray, float]:
        """Extra all-to-all flows of re-laying-out to ``other``.

        Returns ``(migration_d2, total_bytes)``: an ``(M, M)`` bytes
        matrix with ``weight_bytes[e]`` at ``[old_shard, new_shard]`` for
        every moved expert, and its total. The matrix plugs straight into
        :meth:`traffic` / :func:`placement_bound` so migration cost is
        measured in the same simulated-CCT units as the gating savings.

        With a multi-pod ``fabric``, the returned *total* prices
        inter-pod moves at the oversubscribed rate (raw bytes ×
        ``inter_pod_cost_factor``) — the matrix stays raw bytes, since the
        simulators charge the WAN slowdown themselves.
        """
        if other.num_shards != self.num_shards:
            raise ValueError("placements must share the shard count")
        if other.num_experts != self.num_experts:
            raise ValueError("placements must share the expert count")
        moved = np.flatnonzero(other.expert_shard != self.expert_shard)
        mig = np.zeros((self.num_shards, self.num_shards))
        np.add.at(
            mig,
            (self.expert_shard[moved], other.expert_shard[moved]),
            self.weight_bytes[moved],
        )
        if fabric is None or getattr(fabric, "num_pods", 1) <= 1:
            return mig, float(self.weight_bytes[moved].sum())
        return mig, float(pod_priced_d2(mig, fabric).sum())


def placement_loads(
    counts: np.ndarray, placement: Placement
) -> tuple[np.ndarray, np.ndarray]:
    """Per-shard fabric loads under a placement: ``(egress, ingress)`` tokens.

    The placement analogue of the paper's eqs. (4)–(5) at domain
    granularity — the quantities whose max the greedy search descends on.
    """
    d2 = placement.counts_d2(counts)
    return d2.sum(axis=1), d2.sum(axis=0)


def placement_bound(
    counts: np.ndarray,
    placement: Placement,
    num_rails: int,
    bytes_per_token: float,
    r2: float = 50e9,
    migration_d2: np.ndarray | None = None,
    fabric=None,
) -> float:
    """Theorem-2 optimal drain time (seconds) of the placed traffic.

    ``max(row sums, col sums) / (N · R2)`` of the placed d2 — the CCT an
    ideal LPT spray approaches, and the cheap inner-loop score the search
    descends on before the vector-backend simulation ranks finalists.

    With a multi-pod ``fabric``, cross-pod entries are first scaled by
    ``inter_pod_cost_factor`` (see :func:`pod_priced_d2`): a byte that
    must cross the oversubscribed WAN tier counts ``oversub×`` toward the
    drain-time floor, so the search sees pod locality.
    """
    d2 = placement.counts_d2(counts) * float(bytes_per_token)
    if migration_d2 is not None:
        d2 = d2 + migration_d2
    return theorem2_optimal_time(pod_priced_d2(d2, fabric), num_rails, r2)
