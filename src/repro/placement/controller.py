"""Online expert re-layout: drift-triggered, migration-cost-amortized.

The controller watches per-(shard, expert) gating counts through an EWMA
(the same slow-drift premise behind routing replay: paper Fig. 2d), and
re-lays-out experts only when both gates pass:

* **Hysteresis** — the candidate layout must beat the current one by at
  least ``hysteresis`` of the current Theorem-2 drain time on the EWMA
  counts. Small drifts that LPT spraying already absorbs never trigger a
  migration; a real phase change (a hot expert moving) does.
* **Amortization** — the projected per-round saving times ``horizon``
  rounds must exceed the migration's own drain time (its weight bytes
  ride the same fabric, modeled as extra all-to-all flows injected into
  the next round's plan). Expert weights are large relative to one
  round's activations, so this is the gate that keeps the controller from
  thrashing at high drift.

:func:`run_relayout_trace` is the end-to-end driver behind the headline
result: a gating-count trace → per-round placed traffic (+ migration
flows) → one overlapped streaming collective via
:func:`repro.sched.pipeline.run_pipeline` — iteration-time curves of
placement+spraying vs spraying-only RailS.
"""

from __future__ import annotations

import dataclasses

import numpy as np

from .search import greedy_placement, lp_placement, search_placement
from .state import Placement, as_shard_expert_counts, placement_bound

__all__ = [
    "RelayoutConfig",
    "RelayoutDecision",
    "OnlinePlacementController",
    "RelayoutResult",
    "run_relayout_trace",
]


@dataclasses.dataclass(frozen=True)
class RelayoutConfig:
    """Knobs of the online controller.

    Attributes:
      alpha: EWMA weight of the newest round's counts.
      check_every: rounds between candidate searches (1 = every round).
      horizon: rounds over which a migration's cost must amortize —
        projected per-round saving × horizon must exceed the migration's
        own Theorem-2 drain time.
      hysteresis: minimum relative bound improvement (fraction of the
        current drain time) before a migration is even considered.
      cooldown: rounds after a migration during which no new search runs
        (lets the EWMA re-converge on the post-migration regime).
      method: candidate generator (``greedy`` or ``lp``).
    """

    alpha: float = 0.5
    check_every: int = 1
    horizon: float = 8.0
    hysteresis: float = 0.1
    cooldown: int = 2
    method: str = "greedy"

    def __post_init__(self) -> None:
        if not 0.0 < self.alpha <= 1.0:
            raise ValueError("alpha must be in (0, 1]")
        if self.check_every < 1 or self.cooldown < 0:
            raise ValueError("check_every >= 1 and cooldown >= 0 required")
        if self.horizon <= 0 or self.hysteresis < 0:
            raise ValueError("horizon > 0 and hysteresis >= 0 required")
        if self.method not in ("greedy", "lp"):
            raise ValueError(f"method must be greedy|lp, got {self.method!r}")


@dataclasses.dataclass(frozen=True)
class RelayoutDecision:
    """Outcome of one controller tick."""

    migrated: bool
    placement: Placement
    migration_d2: np.ndarray | None  # (M, M) weight bytes in flight this round
    migration_bytes: float
    current_bound_s: float  # EWMA drain time under the pre-tick placement
    candidate_bound_s: float  # EWMA drain time under the searched candidate
    projected_gain_s: float  # per-round saving the migration was judged on


class OnlinePlacementController:
    """Hysteresis-thresholded expert migration driven by EWMA gating drift."""

    def __init__(
        self,
        placement: Placement,
        num_rails: int,
        bytes_per_token: float,
        r2: float = 50e9,
        capacity: int | None = None,
        config: RelayoutConfig | None = None,
        fabric=None,
    ):
        self.placement = placement
        self.fabric = fabric  # pod-aware cost pricing when multi-pod
        self.num_rails = int(num_rails)
        self.bytes_per_token = float(bytes_per_token)
        self.r2 = float(r2)
        self.capacity = capacity
        self.config = RelayoutConfig() if config is None else config
        self._ewma: np.ndarray | None = None
        self.rounds_seen = 0
        self._last_migration_round = -(10**9)
        self.total_migration_bytes = 0.0
        self.migrations: list[tuple[int, float]] = []  # (round, bytes)

    def ewma_counts(self) -> np.ndarray | None:
        """The drift-tracking ``(M, E)`` gating history (None before data)."""
        return None if self._ewma is None else self._ewma.copy()

    def _search(self) -> Placement:
        if self.config.method == "lp":
            return lp_placement(
                self._ewma,
                self.placement.num_shards,
                self.placement.weight_bytes,
                capacity=self.capacity,
            )
        return greedy_placement(
            self._ewma,
            self.placement.num_shards,
            self.placement.weight_bytes,
            capacity=self.capacity,
            start=self.placement,
        )

    def observe(self, counts: np.ndarray) -> RelayoutDecision:
        """Fold one round's gating counts in; maybe migrate.

        Returns the decision for *this* round: the placement its traffic
        should be derived under and, when a migration fires, the weight
        flows to inject into the same round's plan.
        """
        counts_se = as_shard_expert_counts(counts, self.placement.num_shards)
        if self._ewma is None:
            self._ewma = counts_se.astype(np.float64).copy()
        else:
            a = self.config.alpha
            self._ewma = a * counts_se + (1.0 - a) * self._ewma
        rnd = self.rounds_seen
        self.rounds_seen += 1
        cur = placement_bound(
            self._ewma, self.placement, self.num_rails, self.bytes_per_token,
            self.r2, fabric=self.fabric,
        )
        due = (
            rnd % self.config.check_every == 0
            and rnd - self._last_migration_round > self.config.cooldown
        )
        if not due:
            return RelayoutDecision(False, self.placement, None, 0.0, cur, cur, 0.0)
        candidate = self._search()
        cand = placement_bound(
            self._ewma, candidate, self.num_rails, self.bytes_per_token,
            self.r2, fabric=self.fabric,
        )
        gain = cur - cand
        if gain <= self.config.hysteresis * cur:
            return RelayoutDecision(False, self.placement, None, 0.0, cur, cand, gain)
        mig_d2, mig_bytes = self.placement.migration_to(
            candidate, fabric=self.fabric
        )
        from ..core.theorems import theorem2_optimal_time
        from .state import pod_priced_d2

        mig_time = (
            theorem2_optimal_time(
                pod_priced_d2(mig_d2, self.fabric), self.num_rails, self.r2
            )
            if mig_bytes > 0
            else 0.0
        )
        if gain * self.config.horizon <= mig_time:
            return RelayoutDecision(False, self.placement, None, 0.0, cur, cand, gain)
        self.placement = candidate
        self._last_migration_round = rnd
        self.total_migration_bytes += mig_bytes
        self.migrations.append((rnd, mig_bytes))
        return RelayoutDecision(True, candidate, mig_d2, mig_bytes, cur, cand, gain)

    def evacuate(self, failed_shards, counts=None) -> RelayoutDecision:
        """Mandatory re-layout off failed shards onto the survivors.

        Unlike :meth:`observe`, no hysteresis or amortization gate
        applies — experts hosted on dead hardware are unreachable and
        *must* move. Each victim expert is greedily reassigned to the
        least-loaded surviving shard (load = per-expert token demand from
        ``counts``, the EWMA history, or uniform, in that order of
        preference; ``capacity`` is still honored). The weight transfers
        use the checkpoint-replica model: the dead shard cannot source
        its own weights, so each destination pulls the expert's bytes
        evenly from the *other* surviving shards — those flows ride the
        same fabric and plug into the next round's plan via
        ``migration_d2`` exactly like an :meth:`observe` migration.
        """
        failed = sorted({int(s) for s in failed_shards})
        m = self.placement.num_shards
        for s in failed:
            if not 0 <= s < m:
                raise ValueError(f"shard {s} out of range [0, {m})")
        survivors = [s for s in range(m) if s not in failed]
        if not survivors:
            raise ValueError("evacuation would leave no surviving shard")
        es = self.placement.expert_shard
        victims = np.flatnonzero(np.isin(es, failed))
        if counts is not None:
            counts_se = as_shard_expert_counts(counts, m)
        elif self._ewma is not None:
            counts_se = self._ewma
        else:
            counts_se = np.ones((m, self.placement.num_experts))
        cur = placement_bound(
            counts_se, self.placement, self.num_rails, self.bytes_per_token,
            self.r2, fabric=self.fabric,
        )
        if victims.size == 0:
            return RelayoutDecision(False, self.placement, None, 0.0, cur, cur, 0.0)
        demand = counts_se.sum(axis=0)
        load = np.zeros(m)
        np.add.at(load, es, demand)
        load[failed] = np.inf  # never a destination
        cap = None if self.capacity is None else int(self.capacity)
        hosted = np.bincount(es, minlength=m)
        new_es = es.copy()
        # Heaviest demand first (LPT flavor): big experts get first pick
        # of the emptiest survivor.
        order = victims[np.argsort(-demand[victims], kind="stable")]
        for e in order:
            open_shards = [s for s in survivors if cap is None or hosted[s] < cap]
            if not open_shards:
                raise ValueError(
                    f"capacity={cap} leaves no room on the {len(survivors)} "
                    f"surviving shards for expert {int(e)}"
                )
            dest = min(open_shards, key=lambda s: (load[s], hosted[s], s))
            hosted[new_es[e]] -= 1
            new_es[e] = dest
            load[dest] += demand[e]
            hosted[dest] += 1
        candidate = dataclasses.replace(self.placement, expert_shard=new_es)
        wb = self.placement.weight_bytes
        mig = np.zeros((m, m))
        mig_bytes = 0.0
        for e in victims:
            dest = int(new_es[e])
            srcs = [s for s in survivors if s != dest]
            if srcs:  # lone survivor already holds the replica locally
                mig[srcs, dest] += wb[e] / len(srcs)
                mig_bytes += float(wb[e])
        cand = placement_bound(
            counts_se, candidate, self.num_rails, self.bytes_per_token,
            self.r2, fabric=self.fabric,
        )
        rnd = self.rounds_seen
        self.placement = candidate
        self._last_migration_round = rnd
        self.total_migration_bytes += mig_bytes
        self.migrations.append((rnd, mig_bytes))
        return RelayoutDecision(
            True,
            candidate,
            mig if mig_bytes > 0 else None,
            mig_bytes,
            cur,
            cand,
            cur - cand,
        )


@dataclasses.dataclass
class RelayoutResult:
    """End-to-end outcome of a placed gating trace."""

    pipeline: object  # repro.sched.pipeline.PipelineResult
    placements: list[Placement]  # per-round placement (post-decision)
    decisions: list[RelayoutDecision]  # online mode only, else []
    migration_bytes: float
    mode: str

    @property
    def makespan(self) -> float:
        return self.pipeline.makespan

    @property
    def num_migrations(self) -> int:
        return sum(1 for d in self.decisions if d.migrated)


def run_relayout_trace(
    counts_rounds: list[np.ndarray],
    num_shards: int,
    num_rails: int,
    bytes_per_token: float,
    mode: str = "static",
    weight_bytes=0.0,
    capacity: int | None = None,
    config: RelayoutConfig | None = None,
    policy: str = "rails-online",
    chunk_bytes: float | None = None,
    gap_fraction: float = 0.5,
    r1: float = 400e9,
    r2: float = 50e9,
    seed: int = 0,
    backend: str = "event",
) -> RelayoutResult:
    """Run a gating-count trace under a placement mode, end to end.

    Modes: ``static`` (round-robin — spraying-only RailS), ``greedy`` /
    ``lp`` (one up-front re-layout planned from the first round's counts,
    then fixed; its migration flows from round-robin ride round 0), and
    ``online`` (the :class:`OnlinePlacementController` migrates mid-trace
    as the EWMA drifts, injecting weight flows into the round that
    triggered them).

    Release cadence is derived from the *round-robin* lowering of each
    round (``gap_fraction`` of its Theorem-2 time) for every mode, so the
    makespans of different placements are comparable on an identical
    arrival process.
    """
    from ..sched.pipeline import run_pipeline

    if not counts_rounds:
        raise ValueError("need at least one round of gating counts")
    counts_rounds = [as_shard_expert_counts(c, num_shards) for c in counts_rounds]
    rr = Placement.round_robin(counts_rounds[0].shape[1], num_shards, weight_bytes)
    # Placement-independent release cadence (see docstring).
    releases, t = [], 0.0
    for c in counts_rounds[:-1]:
        releases.append(t)
        t += gap_fraction * placement_bound(c, rr, num_rails, bytes_per_token, r2)
    releases.append(t)

    placements: list[Placement] = []
    decisions: list[RelayoutDecision] = []
    tms = []
    migration_total = 0.0
    if mode == "static":
        for c in counts_rounds:
            placements.append(rr)
            tms.append(rr.traffic(c, bytes_per_token, num_rails))
    elif mode in ("greedy", "lp"):
        cand = search_placement(
            counts_rounds[0], num_shards, num_rails, bytes_per_token,
            method=mode, weight_bytes=weight_bytes, capacity=capacity,
            chunk_bytes=chunk_bytes or 256 * 2**10, r2=r2, score=False,
        ).placement
        mig_d2, migration_total = rr.migration_to(cand)
        for i, c in enumerate(counts_rounds):
            placements.append(cand)
            tms.append(
                cand.traffic(
                    c, bytes_per_token, num_rails,
                    migration_d2=mig_d2 if i == 0 and migration_total > 0 else None,
                )
            )
    elif mode == "online":
        ctl = OnlinePlacementController(
            rr, num_rails, bytes_per_token, r2=r2, capacity=capacity, config=config
        )
        for c in counts_rounds:
            dec = ctl.observe(c)
            decisions.append(dec)
            placements.append(dec.placement)
            tms.append(
                dec.placement.traffic(
                    c, bytes_per_token, num_rails, migration_d2=dec.migration_d2
                )
            )
        migration_total = ctl.total_migration_bytes
    else:
        raise ValueError(
            f"unknown mode {mode!r}; choose static|greedy|lp|online"
        )
    pipe = run_pipeline(
        tms,
        policy=policy,
        gap_fraction=gap_fraction,
        chunk_bytes=chunk_bytes,
        r1=r1,
        r2=r2,
        seed=seed,
        releases=releases,
        backend=backend,
    )
    return RelayoutResult(
        pipeline=pipe,
        placements=placements,
        decisions=decisions,
        migration_bytes=migration_total,
        mode=mode,
    )
