"""Expert placement × spraying co-optimization (`repro.placement`).

RailS sprays a *given* all-to-all matrix optimally (split → LPT → spray);
this subsystem reshapes the matrix itself by choosing where experts live
and migrating them as gating load drifts, trading weight-transfer cost
against projected CCT savings. See ``README.md`` § Expert placement.
"""

from .controller import (
    OnlinePlacementController,
    RelayoutConfig,
    RelayoutDecision,
    RelayoutResult,
    run_relayout_trace,
)
from .search import (
    PLACEMENT_METHODS,
    PlacementCandidate,
    greedy_placement,
    lp_placement,
    score_placement,
    search_placement,
    static_placement,
)
from .state import (
    Placement,
    as_shard_expert_counts,
    placement_bound,
    pod_priced_d2,
    placement_loads,
)

__all__ = [
    "Placement",
    "as_shard_expert_counts",
    "placement_loads",
    "placement_bound",
    "pod_priced_d2",
    "PlacementCandidate",
    "PLACEMENT_METHODS",
    "static_placement",
    "greedy_placement",
    "lp_placement",
    "score_placement",
    "search_placement",
    "RelayoutConfig",
    "RelayoutDecision",
    "OnlinePlacementController",
    "RelayoutResult",
    "run_relayout_trace",
]
