"""Placement candidate generators + simulated-CCT scoring.

Three generators, all returning a :class:`~repro.placement.state.Placement`
respecting a per-shard capacity (default ``ceil(E / M)`` experts — the
memory budget of an even layout):

* :func:`static_placement` — the round-robin baseline (what RailS-only
  assumes today).
* :func:`greedy_placement` — swap/move hill descent on the Theorem-2
  max-load objective (the LPT-load imbalance of the placed d2). Cheap
  enough to run per control-loop tick.
* :func:`lp_placement` — an LP relaxation solved with the in-tree simplex
  (:mod:`repro.core.lp`): fractional expert→shard assignment minimizing
  the max of per-shard egress/ingress, greedily rounded under capacity.

Candidates are *ranked* by :func:`score_placement` — the simulated CCT of
the placed traffic on the vector prefix-scan backend, i.e. what the fabric
actually does once LPT spraying runs on the reshaped matrix. The bound
descends monotonically during search; the simulation decides ties and
catches bound/simulation divergence (e.g. chunk-granularity effects).
"""

from __future__ import annotations

import dataclasses

import numpy as np

from ..core.lp import simplex
from .state import Placement, as_shard_expert_counts, placement_bound

__all__ = [
    "PlacementCandidate",
    "static_placement",
    "greedy_placement",
    "lp_placement",
    "score_placement",
    "score_placements_batch",
    "search_placement",
    "PLACEMENT_METHODS",
]


def _default_capacity(num_experts: int, num_shards: int) -> int:
    return -(-num_experts // num_shards)  # ceil


def _objective(counts_se: np.ndarray, expert_shard: np.ndarray, m: int) -> float:
    """Theorem-2 numerator: max per-shard egress/ingress tokens."""
    d2 = np.zeros((m, m))
    np.add.at(d2.T, expert_shard, counts_se.T)
    np.fill_diagonal(d2, 0.0)
    return float(max(d2.sum(axis=1).max(), d2.sum(axis=0).max()))


def static_placement(
    num_experts: int, num_shards: int, weight_bytes=0.0
) -> Placement:
    """Round-robin (the spraying-only RailS baseline)."""
    return Placement.round_robin(num_experts, num_shards, weight_bytes)


def greedy_placement(
    counts: np.ndarray,
    num_shards: int,
    weight_bytes=0.0,
    capacity: int | None = None,
    start: Placement | None = None,
    max_rounds: int = 64,
) -> Placement:
    """Swap/move hill descent on the placed max-load objective.

    Starts from ``start`` (default round-robin) and repeatedly applies the
    best strictly-improving single-expert move (to a shard with spare
    capacity) or expert pair swap until a local optimum or ``max_rounds``.
    Deterministic: ties break toward the lowest expert/shard index.
    """
    counts_se = as_shard_expert_counts(counts, num_shards)
    m, e = num_shards, counts_se.shape[1]
    cap = _default_capacity(e, m) if capacity is None else int(capacity)
    if cap * m < e:
        raise ValueError(f"capacity {cap} cannot host {e} experts on {m} shards")
    pl = Placement.round_robin(e, m, weight_bytes) if start is None else start
    es = pl.expert_shard.copy()
    occupancy = np.bincount(es, minlength=m)
    if occupancy.max() > cap:
        raise ValueError("start placement exceeds capacity")
    best = _objective(counts_se, es, m)
    for _ in range(max_rounds):
        move_best, move_arg = best, None
        # Single-expert moves into shards with spare capacity.
        for ex in range(e):
            src = es[ex]
            for dst in range(m):
                if dst == src or occupancy[dst] >= cap:
                    continue
                es[ex] = dst
                obj = _objective(counts_se, es, m)
                if obj < move_best - 1e-12:
                    move_best, move_arg = obj, ("move", ex, dst)
                es[ex] = src
        # Pairwise swaps (capacity-neutral).
        for e1 in range(e):
            for e2 in range(e1 + 1, e):
                if es[e1] == es[e2]:
                    continue
                es[e1], es[e2] = es[e2], es[e1]
                obj = _objective(counts_se, es, m)
                if obj < move_best - 1e-12:
                    move_best, move_arg = obj, ("swap", e1, e2)
                es[e1], es[e2] = es[e2], es[e1]
        if move_arg is None:
            break
        kind, a, b = move_arg
        if kind == "move":
            occupancy[es[a]] -= 1
            es[a] = b
            occupancy[b] += 1
        else:
            es[a], es[b] = es[b], es[a]
        best = move_best
    return dataclasses.replace(pl, expert_shard=es)


def lp_placement(
    counts: np.ndarray,
    num_shards: int,
    weight_bytes=0.0,
    capacity: int | None = None,
) -> Placement:
    """LP relaxation of min-max placed load, rounded under capacity.

    Variables ``x[e, f] ∈ [0, 1]`` (fraction of expert ``e`` on shard
    ``f``) and the bottleneck ``t``::

        min t
        s.t.  egress[s]  = Σ_e C[s,e] (1 − x[e,s])      ≤ t   ∀s
              ingress[f] = Σ_e (T_e − C[f,e]) x[e,f]    ≤ t   ∀f
              Σ_f x[e,f] = 1                                  ∀e
              Σ_e x[e,f] ≤ capacity                           ∀f

    with ``C`` the ``(M, E)`` counts and ``T_e = Σ_s C[s,e]``. Both load
    expressions drop the host's own tokens (NVLink), so the relaxation
    models exactly the fabric bytes of :meth:`Placement.counts_d2`.
    Rounding: experts in decreasing ``T_e`` order go to their largest
    fractional shard with spare capacity.
    """
    counts_se = as_shard_expert_counts(counts, num_shards)
    m, e = num_shards, counts_se.shape[1]
    cap = _default_capacity(e, m) if capacity is None else int(capacity)
    if cap * m < e:
        raise ValueError(f"capacity {cap} cannot host {e} experts on {m} shards")
    totals = counts_se.sum(axis=0)
    nvar = e * m + 1
    t_idx = nvar - 1

    def xidx(ex, f):
        return ex * m + f

    a_ub = np.zeros((3 * m, nvar))
    b_ub = np.zeros(3 * m)
    for s in range(m):  # egress: -Σ_e C[s,e] x[e,s] - t <= -Σ_e C[s,e]
        for ex in range(e):
            a_ub[s, xidx(ex, s)] = -counts_se[s, ex]
        a_ub[s, t_idx] = -1.0
        b_ub[s] = -counts_se[s].sum()
    for f in range(m):  # ingress: Σ_e (T_e - C[f,e]) x[e,f] - t <= 0
        row = m + f
        for ex in range(e):
            a_ub[row, xidx(ex, f)] = totals[ex] - counts_se[f, ex]
        a_ub[row, t_idx] = -1.0
    for f in range(m):  # capacity
        row = 2 * m + f
        for ex in range(e):
            a_ub[row, xidx(ex, f)] = 1.0
        b_ub[row] = float(cap)
    a_eq = np.zeros((e, nvar))
    for ex in range(e):
        a_eq[ex, xidx(ex, 0) : xidx(ex, 0) + m] = 1.0
    b_eq = np.ones(e)
    c = np.zeros(nvar)
    c[t_idx] = 1.0
    sol = simplex(c, a_ub, b_ub, a_eq, b_eq)
    if sol.status != "optimal":
        # Degenerate inputs (all-zero counts etc.) fall back to round-robin.
        return Placement.round_robin(e, m, weight_bytes)
    x = sol.x[: e * m].reshape(e, m)
    es = np.full(e, -1, dtype=np.int64)
    occupancy = np.zeros(m, dtype=np.int64)
    for ex in np.argsort(-totals, kind="stable"):
        order = np.argsort(-x[ex], kind="stable")
        dst = next((int(f) for f in order if occupancy[f] < cap), None)
        if dst is None:  # cap*m >= e guarantees a slot exists
            dst = int(np.argmin(occupancy))
        es[ex] = dst
        occupancy[dst] += 1
    return Placement(es, m, weight_bytes)


def score_placement(
    counts: np.ndarray,
    placement: Placement,
    num_rails: int,
    bytes_per_token: float,
    chunk_bytes: float = 256 * 2**10,
    r1: float = 400e9,
    r2: float = 50e9,
    policy: str = "rails",
    backend: str = "vector",
    migration_d2: np.ndarray | None = None,
    seed: int = 0,
) -> float:
    """Simulated CCT (seconds) of the placed traffic under LPT spraying.

    Lowers ``counts`` (plus optional in-flight migration flows) through
    the placement and runs one collective on the chosen backend — the
    vector prefix-scan simulator by default, which is what makes
    candidate scoring cheap enough for an online inner loop.
    """
    from ..netsim.simulate import run_collective  # netsim imports sched; keep lazy

    tm = placement.traffic(
        counts, bytes_per_token, num_rails, migration_d2=migration_d2
    )
    if tm.total_bytes() <= 0:
        return 0.0
    return run_collective(
        tm, policy, r1=r1, r2=r2, chunk_bytes=chunk_bytes,
        backend=backend, seed=seed,
    ).makespan


def score_placements_batch(
    counts: np.ndarray,
    placements: list[Placement],
    num_rails: int,
    bytes_per_token: float,
    chunk_bytes: float = 256 * 2**10,
    r1: float = 400e9,
    r2: float = 50e9,
    policy: str = "rails",
    migration_d2: np.ndarray | None = None,
    seed: int = 0,
    probe_every: int = 64,
) -> list[float]:
    """Simulated CCTs of many candidates in one device dispatch.

    The device-backend counterpart of looping :func:`score_placement`:
    every candidate's traffic is planned host-side (the LPT spraying is
    Python) and the fabric scans run as one ``vmap``-ed batch on the
    jax backend — the whole candidate grid costs one dispatch, which is
    what makes wide placement searches affordable. Candidates share the
    fabric (same shard/rail counts); empty traffic scores 0.0 without
    simulating. Per-candidate results match ``score_placement(...,
    backend="device")`` exactly and the vector backend to float
    tolerance.
    """
    from ..netsim.devicesim import (  # netsim imports sched; keep lazy
        PlannedJobs,
        check_device_supports,
        simulate_many_device,
    )
    from ..netsim.fastsim import LinkIndex
    from ..netsim.simulate import _plan_collective
    from ..netsim.topology import RailTopology

    if not placements:
        return []
    m = placements[0].num_shards
    topo = RailTopology(m, num_rails, r1=r1, r2=r2)
    check_device_supports(topo)
    index = LinkIndex(topo)
    scores = [0.0] * len(placements)
    planned: list[PlannedJobs] = []
    live: list[int] = []  # candidate index of each planned member
    for i, pl in enumerate(placements):
        tm = pl.traffic(
            counts, bytes_per_token, num_rails, migration_d2=migration_d2
        )
        if tm.total_bytes() <= 0:
            continue
        ja, link_by_level, entry_rank = _plan_collective(
            topo, index, tm, policy, chunk_bytes, seed, probe_every
        )
        planned.append(
            PlannedJobs(
                link_by_level=link_by_level,
                size=ja.size,
                release=ja.release,
                entry_rank=entry_rank,
                flow_id=ja.flow_id,
                round_id=ja.round_id,
            )
        )
        live.append(i)
    for i, res in zip(live, simulate_many_device(index, planned)):
        scores[i] = float(res.makespan)
    return scores


@dataclasses.dataclass(frozen=True)
class PlacementCandidate:
    """A scored placement: simulated CCT + the bound it descended on."""

    placement: Placement
    method: str
    cct_s: float
    bound_s: float


PLACEMENT_METHODS = ("static", "greedy", "lp")


def search_placement(
    counts: np.ndarray,
    num_shards: int,
    num_rails: int,
    bytes_per_token: float,
    method: str = "greedy",
    weight_bytes=0.0,
    capacity: int | None = None,
    chunk_bytes: float = 256 * 2**10,
    r2: float = 50e9,
    start: Placement | None = None,
    score: bool = True,
    backend: str = "vector",
) -> PlacementCandidate:
    """Generate one candidate with ``method`` and score it.

    ``score=False`` skips the simulation (bound only) — the controller's
    drift check uses that cheap path and simulates only when a migration
    is actually on the table. ``backend`` picks the scoring simulator
    (scoring many candidates at once is cheaper through
    :func:`score_placements_batch` on the device backend).
    """
    if method == "static":
        pl = (
            static_placement(
                as_shard_expert_counts(counts, num_shards).shape[1],
                num_shards,
                weight_bytes,
            )
            if start is None
            else start
        )
    elif method == "greedy":
        pl = greedy_placement(
            counts, num_shards, weight_bytes, capacity=capacity, start=start
        )
    elif method == "lp":
        pl = lp_placement(counts, num_shards, weight_bytes, capacity=capacity)
    else:
        raise ValueError(
            f"unknown placement method {method!r}; choose {PLACEMENT_METHODS}"
        )
    bound = placement_bound(counts, pl, num_rails, bytes_per_token, r2)
    cct = (
        score_placement(
            counts, pl, num_rails, bytes_per_token,
            chunk_bytes=chunk_bytes, r2=r2, backend=backend,
        )
        if score
        else float("nan")
    )
    return PlacementCandidate(placement=pl, method=method, cct_s=cct, bound_s=bound)
