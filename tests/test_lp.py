"""LP layer: simplex correctness + Theorem 2/3 equivalences."""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.lp import (
    closed_form_opt,
    loads_from_allocation,
    optimal_completion_time,
    simplex,
    solve_minmax_lp,
)


def test_simplex_known_lp():
    # max 3x+5y s.t. x<=4, 2y<=12, 3x+2y<=18  -> min -3x-5y; opt (2,6) = 36
    sol = simplex(
        c=np.array([-3.0, -5.0]),
        a_ub=np.array([[1.0, 0.0], [0.0, 2.0], [3.0, 2.0]]),
        b_ub=np.array([4.0, 12.0, 18.0]),
    )
    assert sol.status == "optimal"
    np.testing.assert_allclose(sol.x, [2.0, 6.0], atol=1e-7)
    np.testing.assert_allclose(sol.objective, -36.0, atol=1e-7)


def test_simplex_equality_constraints():
    # min x+y s.t. x+y = 2, x >= 0: objective 2
    sol = simplex(
        c=np.array([1.0, 1.0]),
        a_eq=np.array([[1.0, 1.0]]),
        b_eq=np.array([2.0]),
    )
    assert sol.status == "optimal"
    np.testing.assert_allclose(sol.objective, 2.0, atol=1e-8)


def test_simplex_infeasible():
    sol = simplex(
        c=np.array([1.0]),
        a_ub=np.array([[1.0]]),
        b_ub=np.array([-1.0]),
        a_eq=np.array([[0.0]]),
        b_eq=np.array([5.0]),
    )
    assert sol.status == "infeasible"


@settings(max_examples=25, deadline=None)
@given(
    m=st.integers(2, 4),
    n=st.integers(2, 4),
    seed=st.integers(0, 1000),
)
def test_lp_matches_closed_form(m, n, seed):
    """The simplex optimum of eq. 24 equals Theorem 3's t* = max(row,col)/N."""
    rng = np.random.default_rng(seed)
    d2 = rng.uniform(0.0, 10.0, (m, m))
    np.fill_diagonal(d2, 0.0)
    _, t_lp, sol = solve_minmax_lp(d2, n)
    _, t_cf = closed_form_opt(d2, n)
    assert sol.status == "optimal"
    np.testing.assert_allclose(t_lp, t_cf, rtol=1e-6, atol=1e-9)


def test_lp_heterogeneous_rails_beats_uniform_on_slow_rail():
    """Beyond-paper: with a degraded rail, the LP shifts load off it and
    beats the P*=1/N closed form (which is only optimal for equal rails)."""
    d2 = np.array([[0.0, 8.0], [8.0, 0.0]])
    rates = np.array([1.0, 0.25, 1.0, 1.0])  # rail 1 at quarter speed
    p, t_het, sol = solve_minmax_lp(d2, 4, rail_rates=rates)
    assert sol.status == "optimal"
    # uniform allocation cost on these rails:
    uniform_cost = max((d2.sum(axis=1) / 4 / rates.min()).max(), 0)
    assert t_het < uniform_cost
    # the slow rail receives less traffic than fast rails
    loads_s, _ = loads_from_allocation(d2, p)
    assert loads_s[0, 1] < loads_s[0, 0]


def test_optimal_completion_time_units():
    d2 = np.array([[0.0, 100.0], [100.0, 0.0]])
    t = optimal_completion_time(d2, num_rails=4, rate=50.0)
    np.testing.assert_allclose(t, 100.0 / 4 / 50.0)


def test_loads_from_allocation_eq45():
    d2 = np.array([[0.0, 6.0], [3.0, 0.0]])
    p = np.full((2, 2, 3), 1 / 3)
    s, r = loads_from_allocation(d2, p)
    np.testing.assert_allclose(s, [[2.0, 2.0, 2.0], [1.0, 1.0, 1.0]])
    np.testing.assert_allclose(r, [[1.0, 1.0, 1.0], [2.0, 2.0, 2.0]])


# --- degenerate / unbounded simplex inputs ----------------------------------


def test_simplex_unbounded():
    # min -x with x >= 0 and no other constraints: drive x -> inf.
    sol = simplex(c=np.array([-1.0]))
    assert sol.status == "unbounded"


def test_simplex_unbounded_with_slack_direction():
    # min -x - y s.t. x - y <= 1: the ray (t, t) stays feasible forever.
    sol = simplex(
        c=np.array([-1.0, -1.0]),
        a_ub=np.array([[1.0, -1.0]]),
        b_ub=np.array([1.0]),
    )
    assert sol.status == "unbounded"


def test_simplex_degenerate_redundant_constraints():
    # Redundant copies of the same binding constraint force degenerate
    # pivots (zero-ratio rows); Bland's rule must still terminate at x=1.
    sol = simplex(
        c=np.array([-1.0]),
        a_ub=np.array([[1.0], [1.0], [2.0]]),
        b_ub=np.array([1.0, 1.0, 2.0]),
    )
    assert sol.status == "optimal"
    np.testing.assert_allclose(sol.x, [1.0], atol=1e-9)


def test_simplex_degenerate_zero_rhs():
    # A binding constraint with b = 0: the optimum sits at the degenerate
    # vertex x = 0 rather than cycling.
    sol = simplex(
        c=np.array([-1.0, 0.0]),
        a_ub=np.array([[1.0, 1.0], [1.0, -1.0]]),
        b_ub=np.array([0.0, 0.0]),
    )
    assert sol.status == "optimal"
    np.testing.assert_allclose(sol.objective, 0.0, atol=1e-9)


def test_simplex_zero_sized_objective_all_slack():
    # Feasible region nonempty, objective constant: any vertex is optimal.
    sol = simplex(
        c=np.array([0.0]),
        a_ub=np.array([[1.0]]),
        b_ub=np.array([3.0]),
    )
    assert sol.status == "optimal"
    np.testing.assert_allclose(sol.objective, 0.0, atol=1e-12)


# --- minmax LP vs closed form on uniform matrices ---------------------------


def test_minmax_lp_uniform_matrix_matches_closed_form():
    """On the uniform all-to-all, the LP optimum equals Theorem 3's
    t* = (M-1)·w/N and the closed-form P* = 1/N achieves it exactly."""
    from repro.core.traffic import uniform_workload

    for m, n in [(2, 2), (4, 4), (4, 8)]:
        tm = uniform_workload(m, n, bytes_per_pair=3.0)
        p_lp, t_lp, sol = solve_minmax_lp(tm.d2, n)
        p_cf, t_cf = closed_form_opt(tm.d2, n)
        assert sol.status == "optimal"
        np.testing.assert_allclose(t_lp, t_cf, rtol=1e-8)
        # Row sums of the d2 are (m-1) * n^2 * bytes_per_pair.
        np.testing.assert_allclose(t_cf, (m - 1) * n * n * 3.0 / n)
        # The closed-form allocation is feasible at the LP optimum.
        s, r = loads_from_allocation(tm.d2, p_cf)
        assert s.max() <= t_lp * (1 + 1e-9)
        assert r.max() <= t_lp * (1 + 1e-9)


@settings(max_examples=20, deadline=None)
@given(m=st.integers(2, 4), n=st.integers(2, 5), seed=st.integers(0, 10_000))
def test_minmax_lp_randomized_optimality(m, n, seed):
    """Seeded spot-check of LP optimality conditions on random matrices:
    the solution is a feasible allocation, achieves the closed-form lower
    bound (tight for equal rails, Theorem 3), and no load exceeds t*."""
    rng = np.random.default_rng(seed)
    d2 = rng.uniform(0.0, 50.0, (m, m)) * (rng.random((m, m)) < 0.7)
    np.fill_diagonal(d2, 0.0)
    p, t_lp, sol = solve_minmax_lp(d2, n)
    assert sol.status == "optimal"
    # Allocation rows with traffic must sum to 1 across rails.
    mask = d2 > 0
    np.testing.assert_allclose(p.sum(axis=2)[mask], 1.0, atol=1e-7)
    # Feasibility: every per-rail load fits under the bottleneck.
    s, r = loads_from_allocation(d2, p)
    assert s.max() <= t_lp + 1e-6
    assert r.max() <= t_lp + 1e-6
    # Optimality (equal rails): t* can't beat the Theorem-3 closed form.
    _, t_cf = closed_form_opt(d2, n)
    np.testing.assert_allclose(t_lp, t_cf, rtol=1e-6, atol=1e-9)


@settings(max_examples=10, deadline=None)
@given(m=st.integers(2, 3), seed=st.integers(0, 10_000))
def test_minmax_lp_heterogeneous_rails_lower_bound(m, seed):
    """With unequal rail rates, t* still respects the aggregate-capacity
    lower bound max_load / sum(rates) and the per-rail feasibility t >=
    load_n / rate_n."""
    n = 4
    rng = np.random.default_rng(seed)
    d2 = rng.uniform(1.0, 20.0, (m, m))
    np.fill_diagonal(d2, 0.0)
    rates = rng.uniform(0.25, 1.0, n)
    p, t_het, sol = solve_minmax_lp(d2, n, rail_rates=rates)
    assert sol.status == "optimal"
    worst = max(d2.sum(axis=1).max(), d2.sum(axis=0).max())
    assert t_het >= worst / rates.sum() - 1e-9
    s, r = loads_from_allocation(d2, p)
    assert (s / rates).max() <= t_het + 1e-6
    assert (r / rates).max() <= t_het + 1e-6
