"""LP layer: simplex correctness + Theorem 2/3 equivalences."""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.lp import (
    closed_form_opt,
    loads_from_allocation,
    optimal_completion_time,
    simplex,
    solve_minmax_lp,
)


def test_simplex_known_lp():
    # max 3x+5y s.t. x<=4, 2y<=12, 3x+2y<=18  -> min -3x-5y; opt (2,6) = 36
    sol = simplex(
        c=np.array([-3.0, -5.0]),
        a_ub=np.array([[1.0, 0.0], [0.0, 2.0], [3.0, 2.0]]),
        b_ub=np.array([4.0, 12.0, 18.0]),
    )
    assert sol.status == "optimal"
    np.testing.assert_allclose(sol.x, [2.0, 6.0], atol=1e-7)
    np.testing.assert_allclose(sol.objective, -36.0, atol=1e-7)


def test_simplex_equality_constraints():
    # min x+y s.t. x+y = 2, x >= 0: objective 2
    sol = simplex(
        c=np.array([1.0, 1.0]),
        a_eq=np.array([[1.0, 1.0]]),
        b_eq=np.array([2.0]),
    )
    assert sol.status == "optimal"
    np.testing.assert_allclose(sol.objective, 2.0, atol=1e-8)


def test_simplex_infeasible():
    sol = simplex(
        c=np.array([1.0]),
        a_ub=np.array([[1.0]]),
        b_ub=np.array([-1.0]),
        a_eq=np.array([[0.0]]),
        b_eq=np.array([5.0]),
    )
    assert sol.status == "infeasible"


@settings(max_examples=25, deadline=None)
@given(
    m=st.integers(2, 4),
    n=st.integers(2, 4),
    seed=st.integers(0, 1000),
)
def test_lp_matches_closed_form(m, n, seed):
    """The simplex optimum of eq. 24 equals Theorem 3's t* = max(row,col)/N."""
    rng = np.random.default_rng(seed)
    d2 = rng.uniform(0.0, 10.0, (m, m))
    np.fill_diagonal(d2, 0.0)
    _, t_lp, sol = solve_minmax_lp(d2, n)
    _, t_cf = closed_form_opt(d2, n)
    assert sol.status == "optimal"
    np.testing.assert_allclose(t_lp, t_cf, rtol=1e-6, atol=1e-9)


def test_lp_heterogeneous_rails_beats_uniform_on_slow_rail():
    """Beyond-paper: with a degraded rail, the LP shifts load off it and
    beats the P*=1/N closed form (which is only optimal for equal rails)."""
    d2 = np.array([[0.0, 8.0], [8.0, 0.0]])
    rates = np.array([1.0, 0.25, 1.0, 1.0])  # rail 1 at quarter speed
    p, t_het, sol = solve_minmax_lp(d2, 4, rail_rates=rates)
    assert sol.status == "optimal"
    # uniform allocation cost on these rails:
    uniform_cost = max((d2.sum(axis=1) / 4 / rates.min()).max(), 0)
    assert t_het < uniform_cost
    # the slow rail receives less traffic than fast rails
    loads_s, _ = loads_from_allocation(d2, p)
    assert loads_s[0, 1] < loads_s[0, 0]


def test_optimal_completion_time_units():
    d2 = np.array([[0.0, 100.0], [100.0, 0.0]])
    t = optimal_completion_time(d2, num_rails=4, rate=50.0)
    np.testing.assert_allclose(t, 100.0 / 4 / 50.0)


def test_loads_from_allocation_eq45():
    d2 = np.array([[0.0, 6.0], [3.0, 0.0]])
    p = np.full((2, 2, 3), 1 / 3)
    s, r = loads_from_allocation(d2, p)
    np.testing.assert_allclose(s, [[2.0, 2.0, 2.0], [1.0, 1.0, 1.0]])
    np.testing.assert_allclose(r, [[1.0, 1.0, 1.0], [2.0, 2.0, 2.0]])
