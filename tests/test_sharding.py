"""Sharding rules + mesh views: validity for every arch on mesh replicas.

Divisibility is mesh-size dependent; the production (16,16) rules are
exercised by the dry-run itself. Here a scaled-down (2,2)/(2,2,2) replica
checks the same code paths on 8 fake devices, for every architecture.
"""

import pytest

from helpers import run_multidevice

from repro.configs import list_archs
from repro.runtime import plan_remesh


@pytest.mark.parametrize("arch", list_archs())
def test_param_specs_valid_on_mesh(arch):
    out = run_multidevice(
        f"""
        import numpy as np, jax
        from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
        from repro.configs import get_config
        from repro.launch.steps import abstract_train_state
        from repro.parallel.mesh_view import build_mesh_context
        from repro.parallel.sharding import param_pspecs, cache_pspecs
        from repro.models import init_cache

        cfg = get_config("{arch}")
        from repro import compat
        mesh = compat.make_mesh((2, 4), ("data", "model"))
        ctx = build_mesh_context(mesh, cfg)
        params_abs, opt_abs = abstract_train_state(cfg)
        specs = param_pspecs(cfg, ctx, params_abs)

        def check(leaf, spec):
            sizes = dict(zip(ctx.mesh.axis_names, ctx.mesh.devices.shape))
            for dim, entry in zip(leaf.shape, spec):
                if entry is None:
                    continue
                axes = entry if isinstance(entry, tuple) else (entry,)
                prod = int(np.prod([sizes[a] for a in axes]))
                assert dim % prod == 0, (leaf.shape, spec)
        jax.tree.map(check, params_abs, specs,
                     is_leaf=lambda x: hasattr(x, "shape"))
        cache = jax.eval_shape(lambda: init_cache(cfg, 8, 64))
        cspecs = cache_pspecs(cfg, ctx, cache)
        jax.tree.map(check, cache, cspecs, is_leaf=lambda x: hasattr(x, "shape"))
        print("SPECS_OK", ctx.ep, ctx.tp)
        """,
        devices=8,
    )
    assert "SPECS_OK" in out


def test_mesh_view_factors_experts():
    out = run_multidevice(
        """
        import jax
        from repro.configs import get_config
        from repro.parallel.mesh_view import build_mesh_context

        from repro import compat
        mesh = compat.make_mesh((2, 4), ("data", "model"))
        ctx = build_mesh_context(mesh, get_config("mixtral-8x7b"))
        assert ctx.ep == 4 and ctx.tp == 1, (ctx.ep, ctx.tp)
        assert ctx.expert_axis == "expert"
        ctx2 = build_mesh_context(mesh, get_config("deepseek-7b"))
        assert ctx2.ep == 1 and ctx2.expert_axis is None
        # device order preserved between production mesh and view
        assert (ctx.mesh.devices.flatten() == mesh.devices.flatten()).all()
        print("VIEW_OK")
        """,
        devices=8,
    )
    assert "VIEW_OK" in out


def test_remesh_plan_consistency():
    plan = plan_remesh(2, 4, new_devices=6)
    assert plan.feasible and plan.new_data * plan.new_model == 6
