"""Online scheduling control plane (`repro.sched`) — anchors + behaviour.

The three acceptance anchors:

1. online LPT over one t=0 window == offline Algorithm 2 (loads identical);
2. the streaming engine conserves bytes against ``build_jobs`` totals;
3. degraded-rail feedback shifts load off the slow rail monotonically.
"""

import json

import numpy as np
import pytest

from repro.core.lpt import lpt_schedule
from repro.core.traffic import (
    bursty_release_times,
    drifting_gating_stream,
    microbatch_stream,
    uniform_workload,
)
from repro.netsim import (
    build_jobs,
    build_streaming_jobs,
    run_collective,
    run_streaming_collective,
)
from repro.runtime.straggler import degraded_rail_schedule
from repro.sched import (
    RailHealthEstimator,
    RoutingReplayState,
    TraceRecorder,
    online_greedy_schedule,
    run_pipeline,
    speed_precharge,
    windowed_lpt_schedule,
)
from repro.sched.online import AdaptiveChunker

M, N = 4, 4
B = 8 * 2**20
CHUNK = 1 * 2**20


# -- anchor 1: offline parity ------------------------------------------------


def test_windowed_lpt_single_window_matches_offline():
    rng = np.random.default_rng(0)
    w = rng.exponential(1.0, 200)
    src = rng.integers(0, 8, size=200)
    off = lpt_schedule(w, N, source_ids=src)
    on = windowed_lpt_schedule(w, N, window=None, source_ids=src)
    np.testing.assert_array_equal(on.assignment, off.assignment)
    np.testing.assert_allclose(on.loads, off.loads)


def test_streaming_collective_reproduces_offline_at_t0():
    """run_streaming_collective == run_collective when everything releases
    at t=0 with feedback disabled (CCT/BusBw within 1%; in fact exact)."""
    tm = uniform_workload(M, N, bytes_per_pair=B)
    off = run_collective(tm, "rails", chunk_bytes=CHUNK)
    for policy in ("rails", "rails-online"):
        st = run_streaming_collective(tm, policy, chunk_bytes=CHUNK)
        assert abs(st.metrics.makespan / off.makespan - 1) < 0.01, policy
        assert abs(st.metrics.bus_bw / off.bus_bw - 1) < 0.01, policy
        assert abs(st.metrics.cct["p99"] / off.cct["p99"] - 1) < 0.01, policy


def test_streaming_reactive_policies_match_offline_at_t0():
    tm = uniform_workload(M, N, bytes_per_pair=B)
    for policy in ("minrtt", "reps", "ecmp"):
        off = run_collective(tm, policy, chunk_bytes=CHUNK, seed=3)
        st = run_streaming_collective(tm, policy, chunk_bytes=CHUNK, seed=3)
        assert st.metrics.makespan == pytest.approx(off.makespan), policy


def test_greedy_is_graham_bounded():
    """Greedy list scheduling stays within 2 - 1/N of the mean bound."""
    rng = np.random.default_rng(1)
    w = rng.exponential(1.0, 300)
    res = online_greedy_schedule(w, N)
    opt_lb = max(w.sum() / N, w.max())
    assert res.loads.max() <= (2 - 1 / N) * opt_lb + 1e-9
    np.testing.assert_allclose(res.loads.sum(), w.sum())


def test_windowed_interpolates():
    """Wider windows can only help (monotone non-increasing final MSE is
    not guaranteed chunk-by-chunk, but window=all must beat window=1 on a
    skewed instance)."""
    rng = np.random.default_rng(2)
    w = np.sort(rng.lognormal(0.0, 1.5, 64))  # adversarial: ascending sizes
    greedy = windowed_lpt_schedule(w, N, window=1)
    full = windowed_lpt_schedule(w, N, window=None)
    assert full.loads.max() <= greedy.loads.max() + 1e-9


# -- anchor 2: byte conservation ---------------------------------------------


def test_streaming_engine_conserves_bytes():
    tms = microbatch_stream(M, N, 4, bytes_per_pair=B / 4, seed=5)
    releases = bursty_release_times(4, 5e-4, burstiness=1.5, seed=6)
    total = sum(float(sum(j.size for js in build_jobs(tm, CHUNK).values() for j in js))
                for tm in tms)
    res = run_streaming_collective(
        list(zip(releases, tms)), "rails-online", chunk_bytes=CHUNK
    )
    np.testing.assert_allclose(res.metrics.nic_tx.sum(), total, rtol=1e-9)
    np.testing.assert_allclose(res.metrics.nic_rx.sum(), total, rtol=1e-9)
    # per-round accounting is complete and ordered
    assert sorted(res.round_cct) == list(range(4))
    assert all(t <= res.metrics.makespan + 1e-12 for t in res.round_cct.values())


def test_build_streaming_jobs_ids_and_releases():
    tms = microbatch_stream(2, 2, 3, bytes_per_pair=CHUNK, seed=7)
    jobs = build_streaming_jobs([(i * 1e-3, tm) for i, tm in enumerate(tms)], CHUNK)
    flat = [j for js in jobs.values() for j in js]
    chunk_ids = [j.chunk_id for j in flat]
    assert len(set(chunk_ids)) == len(chunk_ids)  # globally unique
    for j in flat:
        assert j.arrival_time == pytest.approx(j.round_id * 1e-3)


def test_build_streaming_jobs_even_rounds_unique_ids():
    """Regression: even-sized rounds once produced colliding chunk ids
    (per-chunk offset increment raced the in-round offset)."""
    tm = uniform_workload(2, 2, bytes_per_pair=CHUNK)  # 8 chunks per round
    jobs = build_streaming_jobs([(0.0, tm), (0.0, tm), (1e-3, tm)], CHUNK)
    flat = [j for js in jobs.values() for j in js]
    ids = [j.chunk_id for j in flat]
    assert len(set(ids)) == len(ids) == 24
    assert sorted(ids) == list(range(24))
    # coinciding releases still simulate correctly end-to-end
    res = run_streaming_collective([(0.0, tm), (0.0, tm)], "rails-online",
                                   chunk_bytes=CHUNK)
    np.testing.assert_allclose(res.metrics.nic_tx.sum(), 2 * tm.total_bytes())


# -- anchor 3: feedback monotonicity -----------------------------------------


def test_feedback_shifts_load_off_slow_rail_monotonically():
    """As the estimated speed of one rail decreases, the bytes LPT places
    on it must not increase."""
    rng = np.random.default_rng(3)
    w = rng.exponential(1.0, 400)
    prev = None
    for speed in (1.0, 0.8, 0.6, 0.4, 0.2):
        speeds = np.array([1.0, 1.0, 1.0, speed])
        pre = speed_precharge(float(w.sum()), speeds)
        res = lpt_schedule(w, N, initial_loads=pre)
        slow_bytes = float(res.loads[3] - pre[3])
        if prev is not None:
            assert slow_bytes <= prev + 1e-9, speed
        prev = slow_bytes


def test_estimator_learns_degraded_rate_and_cuts_cct():
    tms = microbatch_stream(M, N, 5, bytes_per_pair=B / 5, seed=8)
    rounds = [(i * 1e-4, tm) for i, tm in enumerate(tms)]
    speeds = [1.0, 1.0, 1.0, 0.4]
    blind = run_streaming_collective(
        rounds, "rails-online", chunk_bytes=CHUNK / 2, rail_speeds=speeds
    )
    fb = run_streaming_collective(
        rounds, "rails-online", chunk_bytes=CHUNK / 2, rail_speeds=speeds,
        feedback=True,
    )
    assert fb.health is not None
    np.testing.assert_allclose(fb.health.speeds(), speeds, rtol=0.05)
    assert fb.metrics.makespan < blind.metrics.makespan
    # feedback moves bytes off the slow rail
    assert fb.metrics.nic_tx[:, 3].sum() < blind.metrics.nic_tx[:, 3].sum()


def test_speed_precharge_matches_degraded_rail_schedule():
    """runtime.straggler and sched.feedback share one pre-charge formula."""
    rng = np.random.default_rng(4)
    w = rng.exponential(1.0, 100)
    speeds = np.array([1.0, 0.5, 1.0, 0.75])
    res, real_loads, _finish, _ideal = degraded_rail_schedule(w, N, speeds)
    pre = speed_precharge(float(w.sum()), speeds)
    res2 = lpt_schedule(w, N, initial_loads=pre)
    np.testing.assert_array_equal(res.assignment, res2.assignment)
    np.testing.assert_allclose(real_loads, res2.loads - pre)


# -- replay, chunking, telemetry, pipeline ------------------------------------


def test_replay_state_forecasts_and_blends():
    rs = RoutingReplayState(2, 2, alpha=0.5)
    assert rs.expected_total(0) == 0.0
    rs.update_from_loads([100.0, 50.0])
    assert rs.expected_total(0) == 100.0
    rs.update_from_loads([200.0, 50.0])
    assert rs.expected_total(0) == pytest.approx(150.0)  # EWMA blend
    counts = np.array([[0.0, 10.0], [4.0, 0.0]])
    rs2 = RoutingReplayState(2, 2)
    rs2.update_from_counts(counts, bytes_per_token=2.0)
    assert rs2.expected_total(0) == pytest.approx(20.0)
    assert rs2.expected_total(1) == pytest.approx(8.0)
    # rail profile: uniform before any rail observation, normalized after
    np.testing.assert_allclose(rs2.expected_rail_profile(0), [0.5, 0.5])
    rs.update_from_loads([100.0, 50.0], rail_loads=[[30.0, 10.0], [25.0, 25.0]])
    np.testing.assert_allclose(rs.expected_rail_profile(0), [0.75, 0.25])


def test_adaptive_chunker_targets_multiplicity_and_reacts():
    ch = AdaptiveChunker(chunk_bytes=4 * 2**20, target_multiplicity=8)
    chunk = ch.suggest(expected_total=64 * 2**20, num_rails=4)
    assert chunk == pytest.approx(2 * 2**20)
    before = ch.chunk_bytes
    ch.adapt(observed_norm_mse=1.0)  # badly imbalanced -> split finer
    assert ch.chunk_bytes == pytest.approx(before / 2)
    # the lowered cap must actually bite the next suggestion
    assert ch.suggest(expected_total=64 * 2**20, num_rails=4) == pytest.approx(
        2 * 2**20
    )
    ch.adapt(observed_norm_mse=1.0)
    assert ch.suggest(expected_total=64 * 2**20, num_rails=4) == pytest.approx(
        2**20
    )
    ch.adapt(observed_norm_mse=0.0)  # perfectly balanced -> coarsen
    assert ch.chunk_bytes > 2**20


def test_build_streaming_jobs_empty_round_keeps_flow_ids_unique():
    """Regression: an all-zero round must not reset the flow-id space."""
    tm = uniform_workload(2, 2, bytes_per_pair=CHUNK)
    empty = uniform_workload(2, 2, bytes_per_pair=CHUNK)
    empty = type(tm)(d1=np.zeros_like(empty.d1), d2=np.zeros_like(empty.d2),
                     name="empty")
    jobs = build_streaming_jobs(
        [(0.0, tm), (1e-3, empty), (2e-3, tm)], CHUNK
    )
    flows_by_round: dict[int, set] = {}
    for js in jobs.values():
        for j in js:
            flows_by_round.setdefault(j.round_id, set()).add(j.flow_id)
    assert not (flows_by_round[0] & flows_by_round[2])


def test_trace_recorder_conserves_and_exports(tmp_path):
    tm = uniform_workload(M, N, bytes_per_pair=B / 4)
    rec = TraceRecorder()
    res = run_streaming_collective(tm, "rails-online", chunk_bytes=CHUNK, recorder=rec)
    n_chunks = len(res.sim.jobs)
    assert len(rec.completions) == n_chunks
    # every chunk crosses exactly two NIC links (up + down) on rail paths
    assert len(rec.services) == 2 * n_chunks
    edges, util = rec.rail_utilization(N, num_bins=8)
    assert util.shape == (N, 8) and float(util.max()) <= 1.0 + 1e-9
    _edges, hist = rec.rail_completion_histogram(N)
    assert hist.sum() == n_chunks
    path = tmp_path / "trace.json"
    rec.dump_chrome_trace(str(path))
    trace = json.loads(path.read_text())
    slices = [e for e in trace["traceEvents"] if e.get("ph") == "X"]
    assert len(slices) == 2 * n_chunks


def test_pipeline_overlap_beats_sequential():
    tms = microbatch_stream(M, N, 3, bytes_per_pair=B / 3, seed=9)
    res = run_pipeline(tms, gap_fraction=0.5, chunk_bytes=CHUNK,
                       compare_sequential=True)
    assert res.overlap_speedup is not None and res.overlap_speedup > 1.0
    assert len(res.releases) == 3
    assert all(res.round_latency[r] > 0 for r in range(3))


def test_bursty_release_times_shape():
    t = bursty_release_times(10, 1e-3, burstiness=0.0, seed=0)
    np.testing.assert_allclose(np.diff(t), 1e-3)
    t2 = bursty_release_times(10, 1e-3, burstiness=2.0, seed=1)
    assert t2[0] == 0.0 and np.all(np.diff(t2) >= 0)


def test_drifting_gating_stream_adjacent_similarity():
    tms = drifting_gating_stream(M, N, 5, tokens_per_round=1000.0, drift=0.05, seed=2)
    assert len(tms) == 5
    for tm in tms:
        tm.validate()
    # small drift: adjacent rounds correlate more than distant ones
    def corr(a, b):
        return float(np.corrcoef(a.d2.ravel(), b.d2.ravel())[0, 1])
    assert corr(tms[0], tms[1]) >= corr(tms[0], tms[4]) - 0.2


def test_health_estimator_ignores_spine_links():
    est = RailHealthEstimator(2, nominal_rate=100.0)

    class _J:
        size = 50.0

    est.record_service("l2s:0:1", 0.0, 10.0, _J())
    np.testing.assert_allclose(est.speeds(), [1.0, 1.0])
    est.record_service("up:0:1", 0.0, 1.0, _J())  # rate 50 = half speed
    np.testing.assert_allclose(est.speeds(), [1.0, 0.5])


# -- plan caching (traffic-hash × load-digest memoization) -------------------


def test_plan_cache_hit_miss_and_lru():
    from repro.sched import PlanCache

    cache = PlanCache(capacity=2)
    a = PlanCache.digest(np.ones((2, 2)), np.float64(1.0))
    b = PlanCache.digest(np.ones((2, 2)) * 2, np.float64(1.0))
    c = PlanCache.digest(np.ones((2, 2)), np.float64(2.0))
    assert a != b != c
    # identical content -> identical key, regardless of array identity
    assert a == PlanCache.digest(np.ones((2, 2)).copy(), np.float64(1.0))
    assert cache.get(a) is None
    cache.put(a, "A")
    cache.put(b, "B")
    assert cache.get(a) == "A" and cache.get(b) == "B"
    cache.put(c, "C")  # evicts LRU (a)
    assert cache.get(a) is None
    assert cache.get(c) == "C"
    assert cache.hits == 3 and cache.misses == 2
    assert 0.0 < cache.hit_rate < 1.0


def test_gating_hook_reuses_plan_for_steady_counts():
    from repro.sched import GatingFeedbackHook

    # Small totals clip the chunk suggestion at min_bytes — constant across
    # steps — so steady counts digest to the same plan key from step 2 on.
    hook = GatingFeedbackHook(M, N, bytes_per_token=1024.0)
    counts = np.full(M * N, 100.0)
    out1 = hook.on_step(counts)
    assert out1["plan_cache_hit"] is False
    out2 = hook.on_step(counts)
    assert out2["plan_cache_hit"] is True
    assert hook.plan_cache.hits == 1
    # same forecast -> same predicted quality
    assert out2["pred_send_mse"] == out1["pred_send_mse"]
    # changed gating -> cache miss, fresh plan
    out3 = hook.on_step(counts * 2)
    assert out3["plan_cache_hit"] is False


def test_windowed_replan_quality_improves_with_window():
    """The ROADMAP sweep's invariant: a full-batch re-plan never balances
    worse than greedy-on-arrival for the same arrivals."""
    rng = np.random.default_rng(11)
    w = rng.exponential(1.0, 400)
    greedy = windowed_lpt_schedule(w, N, window=1)
    full = windowed_lpt_schedule(w, N, window=None)
    assert full.loads.max() <= greedy.loads.max() + 1e-9
    assert full.mse <= greedy.mse + 1e-9


def test_rl_phase_forecast_lurch_regression():
    """PR 8's open question, pinned: on an RL rollout/train stream the
    last-iteration replay forecast is near-perfect within a phase but
    eats the full distribution lurch at every boundary; EWMA smoothing
    cuts the boundary error at a steady-state cost. Seeded so the four
    means are stable; the asserts bound the *ordering*, not the values."""
    from repro.core.traffic import rl_phase_counts
    from repro.placement import Placement

    m, n = 8, 4
    counts_rounds, shard, phases = rl_phase_counts(
        m, num_experts=4 * m, num_rounds=16, tokens_per_round=4096.0,
        rollout_len=4, train_len=4, seed=9, return_phases=True,
    )
    placement = Placement.round_robin(4 * m, m)
    tms = [placement.traffic(c, 1024.0, n) for c in counts_rounds]

    def errs(alpha):
        out = {"boundary": [], "steady": []}
        rs = RoutingReplayState(m, n, alpha=alpha)
        prev = None
        for tm, phase in zip(tms, phases):
            realized = tm.domain_send_totals()
            if rs.iterations > 0:
                err = float(
                    np.abs(rs.expected_totals() - realized).sum()
                    / max(np.abs(realized).sum(), 1e-12)
                )
                out["boundary" if phase != prev else "steady"].append(err)
            rs.update_from_loads(realized)
            prev = phase
        return {k: float(np.mean(v)) for k, v in out.items()}

    replay, ewma = errs(1.0), errs(0.35)
    # Replay is sharp within phases and blind across them...
    assert replay["steady"] < ewma["steady"]
    assert replay["boundary"] > 5 * replay["steady"]
    # ...and EWMA buys boundary absorption with steady-state lag.
    assert ewma["boundary"] < replay["boundary"]
