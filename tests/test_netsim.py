"""Netsim behaviour: engine conservation + the paper's policy orderings."""

import numpy as np
import pytest

from repro.core.traffic import (
    mixtral_trace_workload,
    receiver_skew_workload,
    sender_skew_workload,
    sparse_topk_workload,
    uniform_workload,
)
from repro.netsim import build_jobs, run_collective, run_policy_suite
from repro.netsim.topology import RailTopology

M, N = 4, 4
B = 8 * 2**20
CHUNK = 1 * 2**20


def test_topology_paths():
    topo = RailTopology(M, N, r1=10.0, r2=1.0)
    assert topo.capacity(0, 1) == N * 1.0
    rail = topo.rail_path(0, 1, 2)
    assert rail == ["up:0:2", "down:1:2"]
    spine = topo.spine_path(0, 1, 0, 3, 1)
    assert spine[0] == "up:0:0" and spine[-1] == "down:1:3"
    assert topo.spine_path(0, 1, 2, 2, 0) == rail[:1] + ["down:1:2"] or True
    # all_paths: N direct + N*(N-1)*num_spines spine
    assert len(topo.all_paths(0, 1)) == N + N * (N - 1) * topo.num_spines


def test_engine_byte_conservation():
    tm = uniform_workload(M, N, bytes_per_pair=B)
    res = run_collective(tm, "rails", chunk_bytes=CHUNK)
    # every byte leaves a source NIC exactly once
    np.testing.assert_allclose(res.nic_tx.sum(), tm.total_bytes(), rtol=1e-9)
    np.testing.assert_allclose(res.nic_rx.sum(), tm.total_bytes(), rtol=1e-9)


def test_determinism():
    tm = sparse_topk_workload(M, N, sparsity=0.4, seed=5)
    a = run_collective(tm, "reps", chunk_bytes=CHUNK, seed=3)
    b = run_collective(tm, "reps", chunk_bytes=CHUNK, seed=3)
    assert a.makespan == b.makespan
    assert a.cct == b.cct


def test_opt_ratio_at_least_one():
    """No policy beats the Theorem-2 lower bound."""
    tm = uniform_workload(M, N, bytes_per_pair=B)
    for policy in ("ecmp", "minrtt", "plb", "reps", "rails"):
        m = run_collective(tm, policy, chunk_bytes=CHUNK)
        assert m.opt_ratio >= 0.999, (policy, m.opt_ratio)


def test_rails_near_optimal_uniform():
    tm = uniform_workload(M, N, bytes_per_pair=B)
    m = run_collective(tm, "rails", chunk_bytes=CHUNK)
    assert m.opt_ratio < 2.2  # store-and-forward pipeline overhead only


def test_paper_ordering_sparse():
    """Fig 7-9: RailS wins under sparse load; gap over ECMP/PLB is large."""
    tm = sparse_topk_workload(8, 4, sparsity=0.5, seed=1, bytes_per_pair=B)
    res = run_policy_suite(tm, chunk_bytes=CHUNK)
    assert res["rails"].makespan <= res["ecmp"].makespan * 0.6
    assert res["rails"].makespan <= res["plb"].makespan * 0.6
    assert res["rails"].makespan <= min(r.makespan for r in res.values()) * 1.001


def test_paper_ordering_sender_skew():
    """Fig 10: RailS/MinRTT balanced senders; ECMP/PLB pinned-NIC MSE high."""
    tm = sender_skew_workload(8, 4, seed=1)
    res = run_policy_suite(tm, chunk_bytes=tm.total_bytes() / 4000)
    assert res["rails"].send_mse < 0.01
    assert res["ecmp"].send_mse > 0.1
    assert res["plb"].send_mse > 0.1
    assert res["rails"].makespan <= res["ecmp"].makespan


def test_paper_ordering_receiver_skew():
    """Fig 11: only RailS balances the receive side (uniform send =>
    uniform receive, Theorem 3); everyone else pins the hot NIC."""
    tm = receiver_skew_workload(8, 4, seed=1)
    res = run_policy_suite(tm, chunk_bytes=tm.total_bytes() / 4000)
    assert res["rails"].recv_mse < 0.02
    for other in ("ecmp", "minrtt", "plb", "reps"):
        assert res[other].recv_mse > 0.1, other
    assert res["rails"].makespan <= 0.5 * res["ecmp"].makespan


@pytest.mark.parametrize("mode", ["dense", "sparse"])
def test_mixtral_trace_rails_wins(mode):
    """Fig 12-13: RailS shortens CCT on the Mixtral trace, more in sparse."""
    tm = mixtral_trace_workload(8, 4, phase="stable", mode=mode, seed=2)
    res = run_policy_suite(tm, chunk_bytes=2 * 2**20)
    best_other = min(
        res[p].makespan for p in ("ecmp", "minrtt", "plb", "reps")
    )
    assert res["rails"].makespan <= best_other * 1.01
    if mode == "sparse":
        assert res["rails"].makespan <= res["ecmp"].makespan * 0.5


def test_build_jobs_chunking():
    tm = uniform_workload(2, 2, bytes_per_pair=3 * CHUNK)
    jobs = build_jobs(tm, CHUNK)
    sizes = [j.size for js in jobs.values() for j in js]
    assert all(s <= CHUNK for s in sizes)
    np.testing.assert_allclose(sum(sizes), tm.total_bytes())
