"""RailS all-to-all collectives: exactness vs lax.all_to_all on 8 devices,
schedule invariants, and HLO structure."""

import numpy as np
from hypothesis import given, settings, strategies as st

from repro.core.rails_all_to_all import build_rail_schedule

from helpers import run_multidevice


@settings(max_examples=100, deadline=None)
@given(
    e=st.sampled_from([2, 4, 8, 16]),
    n=st.integers(1, 8),
    c=st.integers(1, 4),
    seed=st.integers(0, 50),
)
def test_schedule_invariants(e, n, c, seed):
    rng = np.random.default_rng(seed)
    counts = rng.integers(1, 100, (e, e))
    sched = build_rail_schedule(e, n, c, counts=counts)
    # every (offset, chunk) assigned exactly once
    all_entries = [x for rail in sched.entries for x in rail]
    assert sorted(all_entries) == [(s, k) for s in range(1, e) for k in range(c)]
    assert sched.bound_holds()  # Theorem 4 on the device schedule


def test_schedule_balances_vs_roundrobin():
    rng = np.random.default_rng(0)
    counts = rng.zipf(1.5, (8, 8)).clip(0, 1000)
    sched = build_rail_schedule(8, 4, 2, counts=counts)
    loads = np.asarray(sched.loads)
    assert loads.max() - loads.min() <= sched.w_max + 1e-9


def test_all_modes_equal_dense_on_devices():
    out = run_multidevice(
        """
        import numpy as np, jax, jax.numpy as jnp
        from jax.sharding import PartitionSpec as P
        from functools import partial
        from repro.core import rails_dispatch, build_rail_schedule, rails_all_to_all

        from repro import compat
        mesh = compat.make_mesh((8,), ("ep",))
        E, T, D = 8, 12, 16
        x = np.random.default_rng(0).normal(size=(E*E, T, D)).astype(np.float32)

        def run(mode, **kw):
            @partial(compat.shard_map, mesh=mesh, in_specs=P("ep"), out_specs=P("ep"))
            def f(xl):
                return rails_dispatch(xl, "ep", mode=mode, **kw)
            return np.asarray(jax.jit(f)(x))

        ref = run("dense")
        for mode, kw in [("ring", {}), ("rails", dict(num_rails=3, num_chunks=2)),
                         ("rails", dict(num_rails=8, num_chunks=4)),
                         ("spray", dict(num_rails=4))]:
            got = run(mode, **kw)
            assert np.array_equal(got, ref), (mode, kw)
        # counts-planned schedule also exact
        counts = np.random.default_rng(1).integers(1, 50, (E, E))
        sched = build_rail_schedule(E, 4, num_chunks=3, counts=counts)
        @partial(compat.shard_map, mesh=mesh, in_specs=P("ep"), out_specs=P("ep"))
        def f2(xl):
            return rails_all_to_all(xl, "ep", sched)
        assert np.array_equal(np.asarray(jax.jit(f2)(x)), ref)
        print("ALL_EQUAL")
        """,
        devices=8,
    )
    assert "ALL_EQUAL" in out


def test_rails_hlo_has_parallel_streams():
    """The rails decomposition must lower to multiple independent
    collective-permute chains (not one monolithic all-to-all)."""
    out = run_multidevice(
        """
        import jax, jax.numpy as jnp
        from jax.sharding import PartitionSpec as P
        from functools import partial
        from repro.core import rails_dispatch

        from repro import compat
        mesh = compat.make_mesh((8,), ("ep",))
        @partial(compat.shard_map, mesh=mesh, in_specs=P("ep"), out_specs=P("ep"))
        def f(xl):
            return rails_dispatch(xl, "ep", mode="rails", num_rails=4, num_chunks=2)
        hlo = jax.jit(f).lower(
            jax.ShapeDtypeStruct((64, 8, 16), jnp.float32)).compile().as_text()
        n_cp = hlo.count(" collective-permute")
        assert n_cp >= 14, n_cp  # (E-1) x C = 14 chunk transfers
        assert " all-to-all" not in hlo
        print("CP_COUNT", n_cp)
        """,
        devices=8,
    )
    assert "CP_COUNT" in out
