"""While-loop-aware HLO cost walker (roofline substrate)."""

import numpy as np

import jax
import jax.numpy as jnp

from repro.roofline.analysis import collective_bytes, model_flops, roofline_terms
from repro.roofline.hlo_cost import analyze_hlo


def _compiled_text(fn, *args):
    return jax.jit(fn).lower(*args).compile().as_text()


def test_scan_trip_count_multiplied():
    def scanned(x):
        def body(c, _):
            return c @ c, None
        y, _ = jax.lax.scan(body, x, None, length=17)
        return y

    c = analyze_hlo(_compiled_text(scanned, jax.ShapeDtypeStruct((256, 256), jnp.float32)))
    expect = 17 * 2 * 256**3
    assert abs(c.dot_flops - expect) / expect < 1e-6


def test_nested_scans():
    def nested(x):
        def outer(c, _):
            def inner(c2, _):
                return c2 @ c2, None
            c2, _ = jax.lax.scan(inner, c, None, length=3)
            return c2, None
        y, _ = jax.lax.scan(outer, x, None, length=5)
        return y

    c = analyze_hlo(_compiled_text(nested, jax.ShapeDtypeStruct((128, 128), jnp.float32)))
    expect = 15 * 2 * 128**3
    assert abs(c.dot_flops - expect) / expect < 1e-6


def test_plain_matmul_exact():
    def f(a, b):
        return a @ b

    c = analyze_hlo(
        _compiled_text(
            f,
            jax.ShapeDtypeStruct((64, 32), jnp.float32),
            jax.ShapeDtypeStruct((32, 48), jnp.float32),
        )
    )
    assert c.dot_flops == 2 * 64 * 32 * 48


def test_elementwise_counted():
    def f(x):
        return jnp.tanh(x) + x * 2.0

    c = analyze_hlo(_compiled_text(f, jax.ShapeDtypeStruct((1000,), jnp.float32)))
    assert c.elementwise_flops >= 1000  # at least the fused body ops


def test_collective_parser_on_text():
    fake = """
  %all-reduce.1 = f32[1024]{0} all-reduce(%x), replica_groups={}
  %ag = bf16[2048,16]{1,0} all-gather(%y), dimensions={0}
  %done = f32[8]{0} all-reduce-done(%s)
"""
    res = collective_bytes(fake)
    assert res["all-reduce"] == 4096
    assert res["all-gather"] == 2048 * 16 * 2


def test_roofline_terms_dominance():
    t = roofline_terms(197e12, 819e9 * 2, 0.0)  # 1s compute, 2s memory
    assert t["dominant"] == "memory_s"
    assert abs(t["bound_s"] - 2.0) < 1e-9


def test_model_flops():
    assert model_flops(1e9, 1e6, "train") == 6e15
    assert model_flops(1e9, 1, "infer") == 2e9
