"""Runtime: failure detection, restart determinism, elastic, stragglers."""

import numpy as np

from repro.runtime import (
    HeartbeatRegistry,
    NodeState,
    StragglerDetector,
    TrainingSupervisor,
    degraded_rail_schedule,
    plan_remesh,
    scale_batch,
    speculative_dispatch,
)


def test_heartbeat_detection():
    reg = HeartbeatRegistry(4, deadline=30.0, suspect_after=10.0)
    for n in range(4):
        reg.beat(n, 0.0)
    assert reg.sweep(5.0) == []
    # node 2 goes silent
    for n in (0, 1, 3):
        reg.beat(n, 20.0)
    assert reg.nodes[2].state is NodeState.HEALTHY
    reg.sweep(20.0)
    assert reg.nodes[2].state is NodeState.SUSPECT
    failed = reg.sweep(40.0)
    assert failed == [2]
    assert reg.healthy() == [0, 1, 3]
    gen = reg.generation
    reg.revive(2, 41.0)
    assert reg.generation == gen + 1
    assert 2 in reg.healthy()


def test_supervisor_restart_replay_deterministic():
    """A failure mid-run restarts from the checkpoint and replays to the
    exact same final state (deterministic step-keyed data)."""
    store = {}

    def save_fn(step, state):
        store["last"] = (step, state)

    def restore_fn():
        step, state = store["last"]
        return state, step

    def step_fn(state, step):
        return state + (step + 1)  # deterministic function of step

    def run(failure_at):
        reg = HeartbeatRegistry(2, deadline=1.0)
        sup = TrainingSupervisor(reg, save_fn, restore_fn, checkpoint_every=5)
        # One-shot injector: the replacement node does not re-fail (a
        # stateless injector would crash-loop — the supervisor's
        # max_restarts guard exists for exactly that pathology).
        fired = []

        def inj(s):
            if failure_at and s == failure_at and not fired:
                fired.append(s)
                return 1
            return None

        state, step = sup.run(0, step_fn, steps=20,
                              failure_injector=inj if failure_at else None)
        return state, sup.restarts

    clean, r0 = run(None)
    failed, r1 = run(12)
    assert r0 == 0 and r1 >= 1
    assert clean == failed  # bitwise-identical result despite the failure


def test_elastic_plans():
    plan = plan_remesh(old_data=16, old_model=16, new_devices=240)
    assert plan.feasible
    assert plan.new_data * plan.new_model == 240
    assert plan.new_model == 16  # keeps model degree when possible
    assert scale_batch(256, plan, multiple=8) % plan.new_data == 0
    bad = plan_remesh(16, 16, new_devices=7, min_model=8)
    assert not bad.feasible


def test_degraded_rail_gets_less_load():
    """The paper's LPT doubles as straggler mitigation: a rail at 50% speed
    receives about half the share, equalizing finish times."""
    rng = np.random.default_rng(0)
    w = rng.exponential(1.0, 400)
    speeds = np.array([1.0, 1.0, 0.5, 1.0])
    res, real_loads, finish, ideal = degraded_rail_schedule(w, 4, speeds)
    assert real_loads[2] < real_loads[0] * 0.7
    # finish times roughly equalized (within one max-weight)
    assert finish.max() - finish.min() <= 3 * w.max() / speeds.min()


def test_straggler_detector_and_speculation():
    det = StragglerDetector(multiplier=2.0)
    for lat in (1.0, 1.1, 0.9, 1.0):
        det.observe(lat)
    assert not det.is_straggler(1.5)
    assert det.is_straggler(10.0)
    lat = speculative_dispatch({0: 1.0, 1: 50.0}, det, backup_latency=1.0)
    assert lat[1] < 50.0  # backup won
