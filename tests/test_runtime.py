"""Runtime: failure detection, restart determinism, elastic, stragglers."""

import numpy as np

from repro.runtime import (
    HeartbeatRegistry,
    NodeState,
    StragglerDetector,
    TrainingSupervisor,
    degraded_rail_schedule,
    plan_remesh,
    scale_batch,
    speculative_dispatch,
)


def test_heartbeat_detection():
    reg = HeartbeatRegistry(4, deadline=30.0, suspect_after=10.0)
    for n in range(4):
        reg.beat(n, 0.0)
    assert reg.sweep(5.0) == []
    # node 2 goes silent
    for n in (0, 1, 3):
        reg.beat(n, 20.0)
    assert reg.nodes[2].state is NodeState.HEALTHY
    reg.sweep(20.0)
    assert reg.nodes[2].state is NodeState.SUSPECT
    failed = reg.sweep(40.0)
    assert failed == [2]
    assert reg.healthy() == [0, 1, 3]
    gen = reg.generation
    reg.revive(2, 41.0)
    assert reg.generation == gen + 1
    assert 2 in reg.healthy()


def test_supervisor_restart_replay_deterministic():
    """A failure mid-run restarts from the checkpoint and replays to the
    exact same final state (deterministic step-keyed data)."""
    store = {}

    def save_fn(step, state):
        store["last"] = (step, state)

    def restore_fn():
        step, state = store["last"]
        return state, step

    def step_fn(state, step):
        return state + (step + 1)  # deterministic function of step

    def run(failure_at):
        reg = HeartbeatRegistry(2, deadline=1.0)
        sup = TrainingSupervisor(reg, save_fn, restore_fn, checkpoint_every=5)
        # One-shot injector: the replacement node does not re-fail (a
        # stateless injector would crash-loop — the supervisor's
        # max_restarts guard exists for exactly that pathology).
        fired = []

        def inj(s):
            if failure_at and s == failure_at and not fired:
                fired.append(s)
                return 1
            return None

        state, step = sup.run(0, step_fn, steps=20,
                              failure_injector=inj if failure_at else None)
        return state, sup.restarts

    clean, r0 = run(None)
    failed, r1 = run(12)
    assert r0 == 0 and r1 >= 1
    assert clean == failed  # bitwise-identical result despite the failure


def test_elastic_plans():
    plan = plan_remesh(old_data=16, old_model=16, new_devices=240)
    assert plan.feasible
    assert plan.new_data * plan.new_model == 240
    assert plan.new_model == 16  # keeps model degree when possible
    assert scale_batch(256, plan, multiple=8) % plan.new_data == 0
    bad = plan_remesh(16, 16, new_devices=7, min_model=8)
    assert not bad.feasible


def test_degraded_rail_gets_less_load():
    """The paper's LPT doubles as straggler mitigation: a rail at 50% speed
    receives about half the share, equalizing finish times."""
    rng = np.random.default_rng(0)
    w = rng.exponential(1.0, 400)
    speeds = np.array([1.0, 1.0, 0.5, 1.0])
    res, real_loads, finish, ideal = degraded_rail_schedule(w, 4, speeds)
    assert real_loads[2] < real_loads[0] * 0.7
    # finish times roughly equalized (within one max-weight)
    assert finish.max() - finish.min() <= 3 * w.max() / speeds.min()


def test_straggler_detector_and_speculation():
    det = StragglerDetector(multiplier=2.0)
    for lat in (1.0, 1.1, 0.9, 1.0):
        det.observe(lat)
    assert not det.is_straggler(1.5)
    assert det.is_straggler(10.0)
    lat = speculative_dispatch({0: 1.0, 1: 50.0}, det, backup_latency=1.0)
    assert lat[1] < 50.0  # backup won


def test_supervisor_clock_never_rewinds():
    """Regression: the supervisor's simulated clock used to be recomputed
    as ``clock + step * step_time``, so a rollback (step jumps backwards)
    rewound time and left future-stamped heartbeats masking real silence.
    Every beat the registry sees must carry a non-decreasing timestamp."""
    beat_times = []

    class SpyRegistry(HeartbeatRegistry):
        def beat(self, node_id, now):
            beat_times.append(now)
            super().beat(node_id, now)

    store = {}
    reg = SpyRegistry(4, deadline=5.0, suspect_after=2.0)
    sup = TrainingSupervisor(
        reg,
        save_fn=lambda s, st: store.update({s: st}),
        restore_fn=lambda: (store[max(store)], max(store)),
        checkpoint_every=5,
    )
    fired = []

    def inj(step):
        if step == 7 and not fired:
            fired.append(step)
            return 1
        return None

    _, step = sup.run(0, lambda st, s: st + s, steps=12, failure_injector=inj)
    assert step == 12 and sup.restarts == 1
    assert beat_times == sorted(beat_times)
    # And the rollback really did replay: beats span both passes over step 5..7.
    assert len(beat_times) > 12 * 4


def test_elastic_shrink_after_node_loss():
    """Losing one node out of a pure-DP mesh: new_data shrinks by one and
    the rescaled batch stays divisible by both the alignment multiple and
    the new data-parallel degree."""
    plan = plan_remesh(old_data=4, old_model=1, new_devices=3)
    assert plan.feasible and plan.new_data == 3 and plan.new_model == 1
    assert plan.batch_scale == 0.75
    batch = scale_batch(256, plan, multiple=8)
    assert batch % 8 == 0 and batch % plan.new_data == 0
    assert batch <= 256  # shrink never grows the batch past the original


def test_elastic_infeasible_min_model():
    plan = plan_remesh(old_data=2, old_model=4, new_devices=5, min_model=2)
    assert not plan.feasible
    assert plan.new_data == 0 and plan.batch_scale == 0.0
    assert "model>=2" in plan.reason
