"""Test-suite bootstrap: fall back to the bundled hypothesis stub.

Environments without network access cannot ``pip install hypothesis``;
rather than failing collection, install the deterministic stub from
``_hypothesis_stub`` into ``sys.modules``. The real package, when
present (CI installs requirements.txt), always wins.
"""

import sys

try:
    import hypothesis  # noqa: F401
except ModuleNotFoundError:
    sys.path.insert(0, str(__import__("pathlib").Path(__file__).parent))
    from _hypothesis_stub import _build_modules

    root, st = _build_modules()
    sys.modules["hypothesis"] = root
    sys.modules["hypothesis.strategies"] = st
