"""Minimal stand-in for `hypothesis` when the real package is absent.

The test suite's property tests use a small surface: ``@given`` with
``floats`` / ``integers`` / ``lists`` / ``sampled_from`` strategies and
``@settings(max_examples=..., deadline=...)``. This stub replays each
property over deterministic pseudo-random samples drawn from the declared
strategies — far weaker than real Hypothesis (no shrinking, no coverage
guidance, capped example counts), but it keeps the properties executable
in environments where dependencies cannot be installed. ``conftest.py``
installs it into ``sys.modules`` only when the real package is missing;
CI installs the real thing from requirements.txt.
"""

from __future__ import annotations

import functools
import inspect
import types
import zlib

import numpy as np

#: Hard cap on examples per property — the stub is a smoke check, not a
#: fuzzer; keep the suite fast even when tests ask for hundreds.
MAX_EXAMPLES_CAP = 20


class _Strategy:
    def __init__(self, draw):
        self._draw = draw

    def draw(self, rng: np.random.Generator):
        return self._draw(rng)


def floats(min_value: float, max_value: float) -> _Strategy:
    return _Strategy(lambda rng: float(rng.uniform(min_value, max_value)))


def integers(min_value: int, max_value: int) -> _Strategy:
    return _Strategy(lambda rng: int(rng.integers(min_value, max_value + 1)))


def sampled_from(options) -> _Strategy:
    options = list(options)
    return _Strategy(lambda rng: options[int(rng.integers(len(options)))])


def lists(elements: _Strategy, min_size: int = 0, max_size: int = 10) -> _Strategy:
    def draw(rng):
        size = int(rng.integers(min_size, max_size + 1))
        return [elements.draw(rng) for _ in range(size)]

    return _Strategy(draw)


def settings(max_examples: int = 20, deadline=None, **_ignored):
    def deco(fn):
        fn._stub_max_examples = max_examples
        return fn

    return deco


def given(**strategies):
    def deco(fn):
        @functools.wraps(fn)
        def wrapper(*args, **kwargs):
            limit = min(
                getattr(wrapper, "_stub_max_examples", MAX_EXAMPLES_CAP),
                MAX_EXAMPLES_CAP,
            )
            # Deterministic per-test seed so failures reproduce.
            rng = np.random.default_rng(zlib.crc32(fn.__qualname__.encode()))
            for example in range(limit):
                drawn = {k: s.draw(rng) for k, s in strategies.items()}
                try:
                    fn(*args, **kwargs, **drawn)
                except Exception as e:
                    raise AssertionError(
                        f"property failed on example {example}: {drawn!r}"
                    ) from e

        # Hide the strategy-filled parameters from pytest's fixture
        # resolution (real hypothesis does the same).
        params = [
            p
            for p in inspect.signature(fn).parameters.values()
            if p.name not in strategies
        ]
        wrapper.__signature__ = inspect.Signature(params)
        del wrapper.__wrapped__
        return wrapper

    return deco


def _build_modules():
    st = types.ModuleType("hypothesis.strategies")
    st.floats = floats
    st.integers = integers
    st.lists = lists
    st.sampled_from = sampled_from
    root = types.ModuleType("hypothesis")
    root.given = given
    root.settings = settings
    root.strategies = st
    root.__stub__ = True
    return root, st
