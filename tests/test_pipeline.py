"""GPipe pipeline parallelism: equivalence with sequential execution."""

from helpers import run_multidevice


def test_gpipe_matches_sequential():
    out = run_multidevice(
        """
        import numpy as np, jax, jax.numpy as jnp
        from repro.parallel.pipeline import gpipe

        S, MB, B, D = 4, 6, 2, 8
        rng = np.random.default_rng(0)
        params = jnp.asarray(rng.normal(size=(S, D, D)) * 0.3, jnp.float32)
        xs = jnp.asarray(rng.normal(size=(MB, B, D)), jnp.float32)

        def stage(w, x):
            return jnp.tanh(x @ w)

        from repro import compat
        mesh = compat.make_mesh((4,), ("stage",))
        got = jax.jit(lambda p, x: gpipe(stage, p, x, mesh))(params, xs)

        ref = xs
        for s in range(S):
            ref = jax.vmap(lambda x: stage(params[s], x))(ref)
        err = float(jnp.abs(got - ref).max())
        assert err < 1e-5, err
        print("PIPE_OK", err)
        """,
        devices=4,
    )
    assert "PIPE_OK" in out


def test_gpipe_differentiable():
    out = run_multidevice(
        """
        import numpy as np, jax, jax.numpy as jnp
        from repro.parallel.pipeline import pipeline_loss

        S, MB, B, D = 2, 4, 2, 4
        rng = np.random.default_rng(1)
        params = jnp.asarray(rng.normal(size=(S, D, D)) * 0.3, jnp.float32)
        xs = jnp.asarray(rng.normal(size=(MB, B, D)), jnp.float32)
        ys = jnp.asarray(rng.normal(size=(MB, B, D)), jnp.float32)

        def stage(w, x):
            return jnp.tanh(x @ w)

        from repro import compat
        mesh = compat.make_mesh((2,), ("stage",))
        loss0, grads = jax.value_and_grad(
            lambda p: pipeline_loss(stage, p, xs, ys, mesh)
        )(params)
        p2 = params - 0.2 * grads
        loss1 = pipeline_loss(stage, p2, xs, ys, mesh)
        assert float(loss1) < float(loss0), (loss0, loss1)
        print("GRAD_OK", float(loss0), float(loss1))
        """,
        devices=2,
    )
    assert "GRAD_OK" in out
