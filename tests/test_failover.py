"""PR-7 fail-stop failover: events, exactly-once retry, silence watchdog,
survivor-mask LPT, control-plane failover, and the end-to-end drill."""

import math

import numpy as np
import pytest

from repro.core.lpt import LptState, lpt_schedule
from repro.core.theorems import theorem2_optimal_time
from repro.core.traffic import serve_workload, uniform_workload
from repro.netsim import (
    ChunkJob,
    FailStopEvent,
    FaultSpec,
    RailTopology,
    RetryConfig,
    run_streaming_collective,
)
from repro.netsim.balancers import MinRttPolicy, RepsPolicy
from repro.runtime.failover import (
    degraded_alive_matrix,
    degraded_theorem2_bound,
    run_failover_drill,
)
from repro.sched.feedback import DeadRailDetector
from repro.sched.online import GatingFeedbackHook, PlanCache
from repro.sched.serving import run_serving, ttft_recovery_curve


M, N = 3, 4
BPP = 256 * 2**10
CHUNK = 64 * 2**10


def _stream(rounds=1, gap=0.0):
    tm = uniform_workload(M, N, bytes_per_pair=BPP)
    return [(i * gap, tm) for i in range(rounds)], tm


# ---------------------------------------------------------------------------
# FailStopEvent / FaultSpec surface
# ---------------------------------------------------------------------------


class TestFailStopSpec:
    def test_validation(self):
        with pytest.raises(ValueError):
            FailStopEvent("rail", 1.0)  # rail kind needs a rail
        with pytest.raises(ValueError):
            FailStopEvent("nic", 1.0, rail=0)  # nic needs a domain too
        with pytest.raises(ValueError):
            FailStopEvent("node", 1.0)  # node needs a domain
        with pytest.raises(ValueError):
            FailStopEvent("rail", 1.0, rail=0, t_repair=0.5)  # repair < fail
        with pytest.raises(ValueError):
            FailStopEvent("gamma-ray", 1.0, rail=0)

    def test_links_enumeration(self):
        rail = FailStopEvent("rail", 1.0, rail=1).links(2, 3)
        assert set(rail) == {"up:0:1", "down:0:1", "up:1:1", "down:1:1"}
        nic = FailStopEvent("nic", 1.0, rail=2, domain=1).links(2, 3)
        assert set(nic) == {"up:1:2", "down:1:2"}
        node = FailStopEvent("node", 1.0, domain=0).links(2, 3)
        assert set(node) == {f"{k}:0:{r}" for k in ("up", "down") for r in range(3)}

    def test_spec_is_static_accounting(self):
        assert FaultSpec().is_static
        assert not FaultSpec(
            failures=(FailStopEvent("rail", 1.0, rail=0),)
        ).is_static

    def test_retry_backoff_caps(self):
        r = RetryConfig(rto=1e-3, backoff=2.0, max_exponent=3)
        assert r.delay(1) == 1e-3
        assert r.delay(3) == 4e-3
        assert r.delay(10) == r.delay(4) == 8e-3  # exponent capped


# ---------------------------------------------------------------------------
# Static parity: no fail-stop events configured -> bit-exact dynamics
# ---------------------------------------------------------------------------


class TestBitExactWithoutFailures:
    def test_far_future_failure_is_bitexact_with_static(self):
        """The dynamic loop with a never-reached fail-stop event replays
        the static engine's exact event sequence (chunk-level parity)."""
        stream, _ = _stream()
        base = run_streaming_collective(stream, "rails-online", chunk_bytes=CHUNK)
        spec = FaultSpec(
            failures=(FailStopEvent("rail", 1e9, rail=0),),
            retry=RetryConfig(),
        )
        dyn = run_streaming_collective(
            stream, "rails-online", chunk_bytes=CHUNK, fault_spec=spec
        )
        assert dyn.metrics.makespan == base.metrics.makespan
        for a, b in zip(base.sim.jobs, dyn.sim.jobs):
            assert a.finish_time == b.finish_time
            assert a.path == b.path
        d = dyn.sim.dynamics
        assert d["fail_strands"] == 0 and d["failovers"] == 0

    def test_reactive_policies_bitexact_without_failures(self):
        """MinRtt/Reps dead-path guards change nothing on healthy fabrics
        (finite-estimate arithmetic is the historical one)."""
        stream, _ = _stream()
        for pol in ("minrtt", "reps"):
            base = run_streaming_collective(stream, pol, chunk_bytes=CHUNK)
            spec = FaultSpec(failures=(FailStopEvent("rail", 1e9, rail=0),))
            dyn = run_streaming_collective(
                stream, pol, chunk_bytes=CHUNK, fault_spec=spec
            )
            assert dyn.metrics.makespan == base.metrics.makespan


# ---------------------------------------------------------------------------
# Exactly-once delivery under fail-stop
# ---------------------------------------------------------------------------


class TestExactlyOnce:
    def _cut(self, kind, policy="rails-online", t_repair=None, **kw):
        tm = uniform_workload(M, N, bytes_per_pair=BPP)
        t_half = 0.5 * theorem2_optimal_time(tm.d2, N, 50e9)
        ev = FailStopEvent(kind, t_half, t_repair=t_repair, **kw)
        spec = FaultSpec(
            failures=(ev,), retry=RetryConfig(rto=t_half / 8, max_retries=50)
        )
        res = run_streaming_collective(
            [(0.0, tm)], policy, chunk_bytes=CHUNK, fault_spec=spec
        )
        return res, t_half

    def test_rail_down_redelivers_every_chunk_once(self):
        res, t_fail = self._cut("rail", rail=1)
        d = res.sim.dynamics
        assert d["delivered_chunks"] == len(res.sim.jobs)
        assert d["fail_strands"] > 0 and d["failovers"] > 0
        assert set(d["dead_links"]) == {
            f"{k}:{dom}:1" for k in ("up", "down") for dom in range(M)
        }
        # Chunks that finish after the cut must have failed over: their
        # final path cannot ride a lane of the dead rail. (Pre-cut
        # deliveries on rail 1 are fine — they completed.)
        dead = {f"{k}:{dom}:1" for k in ("up", "down") for dom in range(M)}
        late = [j for j in res.sim.jobs if j.finish_time > t_fail]
        assert late, "failure landed after the collective finished"
        for job in late:
            assert not dead.intersection(job.path)

    def test_nic_down_with_repair_recovers(self):
        res, _ = self._cut("nic", rail=0, domain=1, t_repair=1.0)
        d = res.sim.dynamics
        assert d["delivered_chunks"] == len(res.sim.jobs)
        assert d["dead_links"] == []  # repair landed before the run ended

    def test_permanent_node_down_is_unrecoverable(self):
        tm = uniform_workload(M, N, bytes_per_pair=BPP)
        t_half = 0.5 * theorem2_optimal_time(tm.d2, N, 50e9)
        spec = FaultSpec(
            failures=(FailStopEvent("node", t_half, domain=0),),
            retry=RetryConfig(rto=t_half / 8, max_retries=6),
        )
        with pytest.raises(RuntimeError, match="unrecoverable"):
            run_streaming_collective(
                [(0.0, tm)], "rails-online", chunk_bytes=CHUNK, fault_spec=spec
            )

    def test_reactive_policies_survive_rail_down(self):
        for pol in ("minrtt", "reps", "plb"):
            res, _ = self._cut("rail", policy=pol, rail=2)
            d = res.sim.dynamics
            assert d["delivered_chunks"] == len(res.sim.jobs)


# ---------------------------------------------------------------------------
# Silence watchdog
# ---------------------------------------------------------------------------


class TestDeadRailDetector:
    def _beat_all_but(self, det, silent, t):
        for r in range(N):
            if r != silent:
                det.record_service(f"up:0:{r}", t - 1e-6, t, None)

    def test_silence_detection_and_survivor_mask(self):
        det = DeadRailDetector(N, deadline=1.0, suspect_after=0.4)
        self._beat_all_but(det, silent=None, t=0.1)
        assert det.sweep(0.1) == []
        self._beat_all_but(det, silent=1, t=0.6)
        det.sweep(0.6)
        assert det.state(1).name == "SUSPECT"
        self._beat_all_but(det, silent=1, t=1.2)
        assert det.sweep(1.2) == [1]
        assert det.dead_rails() == [1]
        assert det.survivor_mask().tolist() == [True, False, True, True]
        assert det.time_to_detect(1, t_fail=0.1) == pytest.approx(1.1)

    def test_activity_clock_ignores_idle_gaps(self):
        """A fabric-wide idle gap (no services anywhere) must not fail
        anyone: ages run on the activity clock, not wall time."""
        det = DeadRailDetector(N, deadline=1.0)
        self._beat_all_but(det, silent=None, t=0.1)
        # Hours of wall-clock idleness later, nothing has been observed.
        assert det.sweep(3600.0) == []
        assert det.dead_rails() == []

    def test_observed_service_revives_failed_rail(self):
        det = DeadRailDetector(N, deadline=0.5)
        self._beat_all_but(det, silent=1, t=0.1)
        self._beat_all_but(det, silent=1, t=0.7)
        assert det.sweep(0.7) == [1]
        gen = det.registry.generation
        det.record_service("down:2:1", 0.9, 1.0, None)  # repair landed
        assert det.dead_rails() == []
        assert det.registry.generation == gen + 1
        assert det.recovered_at[1] == 1.0
        assert det.survivor_mask().all()

    def test_spine_links_are_not_heartbeats(self):
        det = DeadRailDetector(N, deadline=1.0)
        det.record_service("l2s:0:0", 0.0, 5.0, None)
        assert det.activity == 0.0  # spine hops say nothing about lanes


# ---------------------------------------------------------------------------
# Survivor-mask LPT
# ---------------------------------------------------------------------------


class TestLptRailMask:
    def test_masked_lpt_avoids_dead_rails(self):
        w = np.random.default_rng(0).exponential(1.0, 64)
        mask = np.array([True, False, True, True])
        res = lpt_schedule(w, 4, rail_mask=mask)
        assert not np.any(res.assignment == 1)
        assert res.loads[1] == 0.0
        # Equals the compacted-problem LPT mapped back to survivor ids.
        sub = lpt_schedule(w, 3)
        alive = np.flatnonzero(mask)
        np.testing.assert_array_equal(res.assignment, alive[sub.assignment])

    def test_full_mask_is_identity(self):
        w = np.random.default_rng(1).exponential(1.0, 64)
        a = lpt_schedule(w, 4)
        b = lpt_schedule(w, 4, rail_mask=np.ones(4, dtype=bool))
        np.testing.assert_array_equal(a.assignment, b.assignment)
        assert a.mse == b.mse

    def test_all_dead_raises(self):
        with pytest.raises(ValueError, match="no rail alive"):
            lpt_schedule(np.ones(4), 4, rail_mask=np.zeros(4, dtype=bool))

    def test_state_assign_freezes_dead_loads(self):
        state = LptState(4)
        state.assign(np.ones(8))
        frozen = state.loads[2]
        mask = np.array([True, True, False, True])
        res = state.assign(np.ones(9), rail_mask=mask)
        assert state.loads[2] == frozen  # dead rail gained nothing
        assert not np.any(res.assignment == 2)


# ---------------------------------------------------------------------------
# Dead-path guards in reactive policies
# ---------------------------------------------------------------------------


class _FakeEngine:
    """path_delay stub: inf on paths crossing `dead`, else len(path)."""

    def __init__(self, dead):
        self.dead = dead

    def path_delay(self, path, src_domain):
        if any(link in self.dead for link in path):
            return math.inf
        return float(len(path))


class TestReactiveDeadPathGuards:
    def _job(self):
        return ChunkJob(
            chunk_id=0, flow_id=7, src_domain=0, src_gpu=0,
            dst_domain=1, dst_gpu=0, size=1.0,
        )

    def test_minrtt_avoids_infinite_subflows(self):
        topo = RailTopology(M, N)
        pol = MinRttPolicy(topo, seed=0)
        eng = _FakeEngine({f"up:0:{r}" for r in range(N - 1)})
        path = pol.choose_path(eng, self._job())
        assert path[0] == f"up:0:{N - 1}"  # the one finite subflow

    def test_minrtt_all_dead_still_returns_a_path(self):
        topo = RailTopology(M, N)
        pol = MinRttPolicy(topo, seed=0)
        eng = _FakeEngine({f"up:0:{r}" for r in range(N)})
        assert pol.choose_path(eng, self._job()) is not None

    def test_reps_excludes_dead_rails_from_pool(self):
        topo = RailTopology(M, N)
        pol = RepsPolicy(topo, seed=3)
        eng = _FakeEngine({"up:0:0"})
        for _ in range(32):
            path = pol.choose_path(eng, self._job())
            assert path[0] != "up:0:0"


# ---------------------------------------------------------------------------
# Control-plane failover: plan cache + survivor planning + evacuation
# ---------------------------------------------------------------------------


class TestControlPlaneFailover:
    def test_plan_cache_clear(self):
        c = PlanCache(capacity=4)
        key = PlanCache.digest(np.arange(3))
        c.put(key, "plan")
        assert c.get(key) == "plan"
        c.clear()
        assert c.get(key) is None
        assert c.hits == 1 and c.misses == 1  # counters survive

    def _hook(self):
        return GatingFeedbackHook(M, N, bytes_per_token=1024.0)

    def test_on_rail_failure_replans_over_survivors(self):
        hook = self._hook()
        counts = np.full(2 * M, 100.0)
        pre = hook.on_step(counts)
        assert pre["alive_rails"] == N
        hook.on_rail_failure([1])
        post = hook.on_step(counts)
        assert post["alive_rails"] == N - 1
        assert not post["plan_cache_hit"]  # cache flushed + new key
        # Degraded Theorem-2 bound is the N-1 scaling of the healthy one.
        assert post["opt_time_s"] == pytest.approx(
            pre["opt_time_s"] * N / (N - 1)
        )

    def test_on_rail_repair_restores_full_fabric(self):
        hook = self._hook()
        hook.on_rail_failure([0, 2])
        assert hook.survivor_mask.tolist() == [False, True, False, True]
        hook.on_rail_repair([0, 2])
        assert hook.survivor_mask.all()

    def test_on_rail_failure_validation(self):
        hook = self._hook()
        with pytest.raises(ValueError, match="out of range"):
            hook.on_rail_failure([N])
        with pytest.raises(ValueError, match="no rail alive"):
            hook.on_rail_failure(range(N))

    def test_hook_without_failures_is_bitexact(self):
        counts = np.full(2 * M, 100.0)
        plans = [h.on_step(counts) for h in (self._hook(), self._hook())]
        assert plans[0] == plans[1]


class TestEvacuation:
    def _controller(self, weight_bytes=2**20, capacity=None):
        from repro.placement import OnlinePlacementController, Placement

        return OnlinePlacementController(
            Placement.round_robin(8, M, weight_bytes),
            num_rails=N,
            bytes_per_token=1024.0,
            capacity=capacity,
        )

    def test_evacuate_moves_every_victim_off_failed_shards(self):
        ctl = self._controller()
        dec = ctl.evacuate([0])
        assert dec.migrated
        assert not np.any(dec.placement.expert_shard == 0)
        # Round-robin put ceil(8/3)=3 experts on shard 0, 1MiB each.
        assert dec.migration_bytes == 3 * 2**20
        assert ctl.total_migration_bytes == dec.migration_bytes

    def test_evacuation_flows_source_from_survivors_only(self):
        ctl = self._controller()
        dec = ctl.evacuate([0])
        mig = dec.migration_d2
        assert mig[0].sum() == 0.0  # the dead shard cannot send
        assert mig[:, 0].sum() == 0.0  # nothing lands on it either
        assert mig.sum() == pytest.approx(dec.migration_bytes)

    def test_evacuate_respects_capacity(self):
        with pytest.raises(ValueError, match="capacity"):
            self._controller(capacity=3).evacuate([0])

    def test_evacuate_balances_by_demand(self):
        ctl = self._controller()
        counts = np.zeros(8)
        counts[0] = 1000.0  # expert 0 (on shard 0) is hot
        dec = ctl.evacuate([0], counts=counts)
        loads = np.zeros(M)
        d2 = dec.placement.counts_d2(counts)
        np.add.at(loads, dec.placement.expert_shard, counts)
        assert not np.any(dec.placement.expert_shard == 0)
        # The hot expert went to one shard, the cold ones elsewhere.
        hot_shard = dec.placement.expert_shard[0]
        cold = [e for e in (3, 6) if dec.placement.expert_shard[e] == hot_shard]
        assert len(cold) <= 1

    def test_no_victims_is_a_noop(self):
        ctl = self._controller()
        before = ctl.placement.expert_shard.copy()
        dec = ctl.evacuate([])
        assert not dec.migrated and dec.migration_bytes == 0.0
        np.testing.assert_array_equal(ctl.placement.expert_shard, before)


# ---------------------------------------------------------------------------
# Serving-path recovery
# ---------------------------------------------------------------------------


class TestServingRecovery:
    def _workload(self):
        return serve_workload(
            M, N, num_requests=12, mean_gap=4e-4, prefill_tokens=256,
            decode_rounds=2, decode_gap=1e-4, seed=5,
        )

    def test_mid_trace_rail_down_recovery_curve(self):
        wl = self._workload()
        spec = FaultSpec(
            failures=(FailStopEvent("rail", 1e-3, rail=0, t_repair=3e-3),),
            retry=RetryConfig(rto=1e-4),
        )
        det = DeadRailDetector(N, deadline=4e-4)
        res = run_serving(
            wl, "rails-online", chunk_bytes=32 * 2**10,
            fault_spec=spec, detector=det,
        )
        d = res.streaming.sim.dynamics
        assert d["delivered_chunks"] == len(res.streaming.sim.jobs)
        curve = ttft_recovery_curve(res, bucket_s=5e-4)
        assert set(curve) == {"t", "p50", "p99", "count"}
        assert sum(curve["count"]) == len(wl.requests)
        assert all(p99 >= p50 for p50, p99 in zip(curve["p50"], curve["p99"]))

    def test_recovery_curve_validation(self):
        wl = self._workload()
        res = run_serving(wl, "rails-online", chunk_bytes=32 * 2**10)
        with pytest.raises(ValueError, match="bucket_s"):
            ttft_recovery_curve(res, bucket_s=0.0)


# ---------------------------------------------------------------------------
# Degraded bound + the end-to-end drill (ISSUE acceptance)
# ---------------------------------------------------------------------------


class TestDegradedBound:
    def test_rail_down_scales_bound_by_n_over_k(self):
        tm = uniform_workload(4, 4, bytes_per_pair=BPP)
        healthy = theorem2_optimal_time(tm.d2, 4, 50e9)
        ev = FailStopEvent("rail", 0.0, rail=0)
        alive = degraded_alive_matrix(4, 4, ev)
        assert degraded_theorem2_bound(tm.d2, alive, 50e9) == pytest.approx(
            healthy * 4 / 3
        )

    def test_nic_down_degrades_only_its_domain(self):
        tm = uniform_workload(4, 4, bytes_per_pair=BPP)
        alive = degraded_alive_matrix(4, 4, FailStopEvent("nic", 0.0, rail=1, domain=2))
        assert alive.sum() == 15
        bound = degraded_theorem2_bound(tm.d2, alive, 50e9)
        assert bound == pytest.approx(
            theorem2_optimal_time(tm.d2, 4, 50e9) * 4 / 3
        )

    def test_node_down_is_a_partition(self):
        tm = uniform_workload(4, 4, bytes_per_pair=BPP)
        alive = degraded_alive_matrix(4, 4, FailStopEvent("node", 0.0, domain=1))
        assert degraded_theorem2_bound(tm.d2, alive, 50e9) == math.inf


class TestFailoverDrill:
    def test_rail_drill_meets_acceptance(self):
        """ISSUE acceptance: detection within the configured silence
        window, exactly-once redelivery, steady degraded CCT within 10%
        of the survivor-recomputed Theorem-2 bound (relative to the
        engine's healthy bound-tracking factor)."""
        rep = run_failover_drill(fail_kind="rail", fail_rail=1)
        assert rep.time_to_detect is not None
        assert rep.time_to_detect <= 2.0 * rep.deadline
        assert rep.exactly_once
        assert rep.strands > 0 and rep.failovers > 0
        assert rep.survivor_mask == [True, False, True, True]
        assert rep.plan_alive_rails == 3
        assert rep.plan_cache_cleared
        assert 0.90 <= rep.bound_tracking_ratio <= 1.10
        assert rep.supervisor["recovered"]

    def test_two_rail_drill(self):
        rep = run_failover_drill(fail_rail=(1, 3))
        assert rep.exactly_once
        assert rep.survivor_mask == [True, False, True, False]
        assert rep.plan_alive_rails == 2
        assert 0.85 <= rep.bound_tracking_ratio <= 1.15

    def test_node_drill_evacuates_and_remeshes(self):
        """Node loss: repair-gated data plane plus the evacuation +
        elastic-re-mesh control-plane legs (remesh after node loss)."""
        rep = run_failover_drill(fail_kind="node")
        assert rep.exactly_once
        assert rep.evacuated_experts > 0
        assert rep.evacuation_bytes > 0.0
        assert rep.elastic is not None and rep.elastic.feasible
        assert rep.elastic.new_devices == rep.num_domains - 1
        assert rep.supervisor["recovered"]

    def test_fail_round_validation(self):
        with pytest.raises(ValueError, match="fail_round"):
            run_failover_drill(rounds=3, fail_round=2)
