"""Whisper (enc-dec) specifics: cross-attention, prefill/decode parity."""

import numpy as np

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.models import decode_fn, init_cache, init_params
from repro.models.transformer import _whisper_encode, forward_hidden, logits_last
from repro.models.attention import attn_forward

CFG = get_config("whisper-small").reduced()


def _batch(b=1, t=6):
    k = jax.random.PRNGKey(0)
    return {
        "tokens": jax.random.randint(k, (b, t), 0, CFG.vocab_size),
        "embeds": (jax.random.normal(k, (b, CFG.encoder_seq, CFG.d_model)) * 0.2).astype(jnp.bfloat16),
    }


def test_encoder_is_non_causal():
    """Encoder output at position 0 must depend on later frames."""
    params = init_params(CFG, jax.random.PRNGKey(1))
    batch = _batch()
    mem_a = _whisper_encode(params, CFG, batch, lambda x, k=None: x)
    batch2 = {**batch, "embeds": batch["embeds"].at[:, -1].set(9.0)}
    mem_b = _whisper_encode(params, CFG, batch2, lambda x, k=None: x)
    assert not np.allclose(
        np.asarray(mem_a[:, 0], np.float32), np.asarray(mem_b[:, 0], np.float32)
    )


def test_decoder_attends_to_encoder():
    """Changing audio frames changes decoder logits (cross-attn is live)."""
    params = init_params(CFG, jax.random.PRNGKey(1))
    batch = _batch()
    h1, _, _ = forward_hidden(params, CFG, batch)
    batch2 = {**batch, "embeds": batch["embeds"] * -1.0}
    h2, _, _ = forward_hidden(params, CFG, batch2)
    l1 = logits_last(params, CFG, h1)
    l2 = logits_last(params, CFG, h2)
    assert not np.allclose(np.asarray(l1), np.asarray(l2))


def test_whisper_decode_matches_forward():
    """Teacher-forced decode == full forward for the enc-dec family."""
    params = init_params(CFG, jax.random.PRNGKey(2))
    batch = _batch(b=1, t=6)
    # Build decode cache: cross-kv from the encoder memory, per layer.
    mem = _whisper_encode(params, CFG, batch, lambda x, k=None: x)
    cache = init_cache(CFG, 1, 8)
    dec_p = params["blocks"]["dec"]

    def one_layer_kv(p):
        b, s, _ = mem.shape
        hkv, hd = CFG.num_kv_heads, CFG.head_dim
        k = jnp.einsum("bsd,dk->bsk", mem, p["wk"]).reshape(b, s, hkv, hd)
        v = jnp.einsum("bsd,dk->bsk", mem, p["wv"]).reshape(b, s, hkv, hd)
        return k, v

    ks, vs = jax.vmap(one_layer_kv)(dec_p["cross_attn"])
    cache["cross_kv"] = {"k": ks, "v": vs}

    toks = batch["tokens"]
    logits = None
    for pos in range(toks.shape[1]):
        logits, cache = decode_fn(params, CFG, cache, toks[:, pos : pos + 1], pos)
    hidden, _, _ = forward_hidden(params, CFG, batch)
    want = logits_last(params, CFG, hidden)
    np.testing.assert_allclose(
        np.asarray(logits, np.float32), np.asarray(want, np.float32),
        atol=0.15, rtol=0.15,
    )
