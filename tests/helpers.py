"""Test helpers: subprocess execution with a fake multi-device CPU."""

from __future__ import annotations

import os
import subprocess
import sys
import textwrap
from pathlib import Path

SRC = str(Path(__file__).resolve().parent.parent / "src")


def run_multidevice(code: str, devices: int = 8, timeout: int = 600) -> str:
    """Run ``code`` in a subprocess with N fake CPU devices; returns stdout.

    Raises on nonzero exit (stderr attached). Device count is process-global
    in jax, hence the subprocess isolation — the main pytest process stays
    at 1 device per the dry-run contract.
    """
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    proc = subprocess.run(
        [sys.executable, "-c", textwrap.dedent(code)],
        capture_output=True,
        text=True,
        timeout=timeout,
        env=env,
    )
    if proc.returncode != 0:
        raise AssertionError(
            f"subprocess failed (rc={proc.returncode})\n--- stdout ---\n"
            f"{proc.stdout}\n--- stderr ---\n{proc.stderr[-4000:]}"
        )
    return proc.stdout
