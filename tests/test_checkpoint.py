"""Checkpointing: roundtrip, atomic commit, async writer, GC."""

import json

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.checkpoint import Checkpointer, latest_step, restore, save


def _tree(seed=0):
    rng = np.random.default_rng(seed)
    return {
        "params": {"w": jnp.asarray(rng.normal(size=(8, 4)), jnp.bfloat16),
                   "b": jnp.asarray(rng.normal(size=(4,)), jnp.float32)},
        "opt": {"m": jnp.zeros((8, 4)), "count": jnp.int32(7)},
    }


def test_roundtrip(tmp_path):
    tree = _tree()
    save(tmp_path, 42, tree)
    restored, step = restore(tmp_path, tree)
    assert step == 42
    for a, b in zip(_leaves(tree), _leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def _leaves(t):
    return jax.tree.leaves(t)


def test_latest_step_and_multiple(tmp_path):
    tree = _tree()
    for s in (10, 20, 30):
        save(tmp_path, s, tree)
    assert latest_step(tmp_path) == 30
    _, step = restore(tmp_path, tree)
    assert step == 30
    _, step = restore(tmp_path, tree, step=20)
    assert step == 20


def test_atomic_commit_no_tmp_left(tmp_path):
    save(tmp_path, 5, _tree())
    assert not list(tmp_path.glob("*.tmp"))
    assert (tmp_path / "step_00000005" / "manifest.json").exists()


def test_restore_rejects_shape_mismatch(tmp_path):
    save(tmp_path, 1, {"w": jnp.zeros((4, 4))})
    with pytest.raises(ValueError):
        restore(tmp_path, {"w": jnp.zeros((2, 2))})


def test_restore_missing_dir(tmp_path):
    with pytest.raises(FileNotFoundError):
        restore(tmp_path / "nothing", {"w": jnp.zeros(2)})


def test_async_checkpointer_and_gc(tmp_path):
    ck = Checkpointer(tmp_path, keep=2)
    tree = _tree()
    for s in (1, 2, 3, 4):
        ck.save_async(s, tree)
    ck.wait()
    steps = sorted(
        int(p.name.split("_")[1]) for p in tmp_path.iterdir() if p.is_dir()
    )
    assert steps == [3, 4]  # GC kept last 2
    restored, step = restore(tmp_path, tree)
    assert step == 4


def test_manifest_contents(tmp_path):
    save(tmp_path, 9, _tree())
    manifest = json.loads((tmp_path / "step_00000009" / "manifest.json").read_text())
    assert manifest["step"] == 9
    assert "params/w" in manifest["leaves"]
    assert manifest["leaves"]["params/w"]["dtype"] == "bfloat16"
