"""Pallas kernel sweeps: interpret-mode vs pure-jnp oracle (ref.py)."""

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention import flash_attention_pallas
from repro.kernels.grouped_matmul import grouped_matmul_pallas
from repro.kernels.ref import flash_attention_ref, grouped_matmul_ref, rmsnorm_ref
from repro.kernels.rmsnorm import rmsnorm_pallas


def _naive_attention(q, k, v, causal=True, q_offset=0, window=None, softcap=None):
    b, t, h, hd = q.shape
    s = k.shape[1]
    hkv = k.shape[2]
    rep = h // hkv
    qf = q.astype(jnp.float32).reshape(b, t, hkv, rep, hd) * hd**-0.5
    scores = jnp.einsum("bthrd,bshd->bhrts", qf, k.astype(jnp.float32))
    if softcap:
        scores = jnp.tanh(scores / softcap) * softcap
    qp = q_offset + jnp.arange(t)
    kp = jnp.arange(s)
    mask = jnp.ones((t, s), bool)
    if causal:
        mask &= kp[None, :] <= qp[:, None]
    if window:
        mask &= qp[:, None] - kp[None, :] < window
    scores = jnp.where(mask[None, None, None], scores, -jnp.inf)
    p = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhrts,bshd->bthrd", p, v.astype(jnp.float32))
    return out.reshape(b, t, h, hd).astype(q.dtype)


FLASH_CASES = [
    # (b, t, s, h, hkv, hd, kwargs)
    (2, 64, 64, 4, 2, 32, {}),
    (1, 32, 96, 4, 4, 64, {"q_offset": 64}),
    (2, 64, 64, 8, 2, 32, {"window": 17}),
    (1, 64, 64, 2, 1, 32, {"causal": False}),
    (2, 64, 64, 4, 2, 32, {"softcap": 30.0}),
    (1, 1, 40, 4, 2, 32, {"q_offset": 39}),  # decode
    (1, 50, 50, 2, 2, 16, {}),  # non-multiple-of-block sizes
]


@pytest.mark.parametrize("case", FLASH_CASES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_matches_oracle(case, dtype):
    b, t, s, h, hkv, hd, kw = case
    rng = np.random.default_rng(0)
    q = jnp.asarray(rng.normal(size=(b, t, h, hd)), dtype)
    k = jnp.asarray(rng.normal(size=(b, s, hkv, hd)), dtype)
    v = jnp.asarray(rng.normal(size=(b, s, hkv, hd)), dtype)
    got = flash_attention_pallas(q, k, v, block_q=32, block_k=32, interpret=True, **kw)
    want = flash_attention_ref(q, k, v, block_k=48, **kw)
    oracle = _naive_attention(q, k, v, **kw)
    tol = 2e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(
        got.astype(jnp.float32), oracle.astype(jnp.float32), atol=tol, rtol=tol
    )
    np.testing.assert_allclose(
        want.astype(jnp.float32), oracle.astype(jnp.float32), atol=tol, rtol=tol
    )


GMM_CASES = [(1, 64, 32, 48), (4, 100, 64, 72), (8, 33, 17, 129)]


@pytest.mark.parametrize("g,n,k,m", GMM_CASES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_grouped_matmul_matches_oracle(g, n, k, m, dtype):
    rng = np.random.default_rng(1)
    x = jnp.asarray(rng.normal(size=(g, n, k)), dtype)
    w = jnp.asarray(rng.normal(size=(g, k, m)), dtype)
    got = grouped_matmul_pallas(x, w, block_n=32, block_m=32, block_k=32, interpret=True)
    want = grouped_matmul_ref(x, w)
    tol = 1e-5 if dtype == jnp.float32 else 5e-2
    np.testing.assert_allclose(
        got.astype(jnp.float32), want.astype(jnp.float32), atol=tol, rtol=tol
    )


@pytest.mark.parametrize("shape", [(7, 64), (3, 5, 128), (256, 256)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_rmsnorm_matches_oracle(shape, dtype):
    rng = np.random.default_rng(2)
    x = jnp.asarray(rng.normal(size=shape), dtype)
    w = jnp.asarray(rng.normal(size=shape[-1:]), dtype)
    got = rmsnorm_pallas(x, w, 1e-6, block_rows=16, interpret=True)
    want = rmsnorm_ref(x, w, 1e-6)
    np.testing.assert_allclose(
        got.astype(jnp.float32), want.astype(jnp.float32), atol=2e-2, rtol=2e-2
    )


def test_ops_dispatch_env(monkeypatch):
    from repro.kernels import ops

    monkeypatch.setenv("REPRO_PALLAS", "off")
    assert ops.kernel_backend() == "ref"
    monkeypatch.setenv("REPRO_PALLAS", "interpret")
    assert ops.kernel_backend() == "interpret"
    monkeypatch.setenv("REPRO_PALLAS", "auto")
    assert ops.kernel_backend() in ("ref", "pallas")
