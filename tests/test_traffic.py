"""Traffic-matrix generators (paper Table I + eq. 1)."""

import numpy as np
import pytest

from repro.core.traffic import (
    aggregate_domains,
    mixtral_trace_workload,
    moe_gating_traffic,
    receiver_skew_workload,
    sender_skew_workload,
    sparse_topk_workload,
    uniform_workload,
)


@pytest.mark.parametrize(
    "maker,kwargs",
    [
        (uniform_workload, {}),
        (sparse_topk_workload, {"sparsity": 0.5}),
        (sender_skew_workload, {}),
        (receiver_skew_workload, {}),
        (mixtral_trace_workload, {"phase": "stable", "mode": "dense"}),
        (mixtral_trace_workload, {"phase": "start", "mode": "sparse"}),
    ],
)
def test_generators_validate(maker, kwargs):
    tm = maker(6, 4, **kwargs)
    tm.validate()
    assert tm.total_bytes() > 0
    # eq. 1 aggregate
    np.testing.assert_allclose(tm.d2, aggregate_domains(tm.d1))
    # no self-traffic crosses the fabric
    for d in range(6):
        assert tm.d2[d, d] == 0.0


def test_uniform_is_uniform():
    tm = uniform_workload(4, 4, bytes_per_pair=2.0)
    off_diag = tm.d2[~np.eye(4, dtype=bool)]
    assert np.allclose(off_diag, off_diag[0])


def test_sparse_concentrates_receivers():
    tm = sparse_topk_workload(8, 4, sparsity=0.6, seed=0)
    recv = tm.domain_recv_totals()
    assert (recv == 0).sum() >= 3  # inactive receivers exist
    # totals preserved vs dense baseline
    dense = sparse_topk_workload(8, 4, sparsity=0.0, seed=0)
    np.testing.assert_allclose(tm.total_bytes(), dense.total_bytes(), rtol=1e-9)


def test_sender_skew_is_gpu_granular():
    tm = sender_skew_workload(8, 8, seed=1)
    per_gpu = tm.d1.sum(axis=(2, 3))  # (M, N) sender totals
    assert per_gpu.max() / per_gpu.mean() > 3.0  # real skew at GPU level


def test_receiver_skew_is_gpu_granular():
    tm = receiver_skew_workload(8, 8, seed=1)
    per_gpu = tm.d1.sum(axis=(0, 1))
    assert per_gpu.max() / per_gpu.mean() > 3.0


def test_mixtral_phases_grow():
    sizes = [
        mixtral_trace_workload(8, 8, phase=p).total_bytes()
        for p in ("start", "early", "mid", "stable")
    ]
    assert sizes == sorted(sizes)


def test_mixtral_sparse_lands_on_single_gpu():
    tm = mixtral_trace_workload(8, 8, phase="stable", mode="sparse", seed=0)
    # each receiving domain's ingress concentrates on one GPU
    per_gpu = tm.d1.sum(axis=(0, 1))  # (M, N)
    for f in range(8):
        row = per_gpu[f]
        if row.sum() > 0:
            assert row.max() / row.sum() > 0.99


def test_moe_gating_traffic():
    counts = np.array([[0, 10], [20, 0]])
    tm = moe_gating_traffic(counts, bytes_per_token=4.0, num_rails=2)
    tm.validate()
    np.testing.assert_allclose(tm.d2, counts * 4.0)
