"""Integration: fault-tolerant training end-to-end (the examples/ path)."""

import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "examples"))


def test_fault_tolerant_train_recovers_bitwise():
    import fault_tolerant_train

    # main() asserts: >=1 restart AND zero diverging loss steps.
    fault_tolerant_train.main()
