"""DES engine parity — the fast event loop must not change the physics.

The engine rewrite (single release stream + per-link deques + slotted
jobs + observer fast path) is a pure performance change: with flowlet
coalescing off, the fig7–13 workloads must reproduce the pre-rewrite
heap-per-link engine's CCTs *bit for bit* (golden values captured from
the original implementation at test scale), and `run_streaming_collective`
must bit-match `run_collective` for t=0 releases.
"""

import numpy as np
import pytest

from repro.core.traffic import (
    mixtral_trace_workload,
    receiver_skew_workload,
    sender_skew_workload,
    sparse_topk_workload,
    uniform_workload,
)
from repro.netsim import run_collective, run_streaming_collective
from repro.netsim.events import SimResult

M, N = 4, 4
B = 8 * 2**20
CHUNK = 1 * 2**20

# (workload, policy) -> (makespan, cct_p99), captured from the pre-rewrite
# engine (heap-per-link `_FifoNetwork`) on these exact inputs.
#
# Release-relative CCT note: flow_cct became sojourn time (finish − release)
# when the serving path landed. These goldens are all t=0 one-shot
# collectives, where sojourn == absolute finish bit for bit (x - 0.0 == x),
# so the pinned values carry over unchanged — only nonzero-release
# streaming runs report different (smaller, correct) CCTs now.
GOLDEN = {
    ("fig7_uniform", "rails"): (0.0033774147199999924, 0.0033373591167999927),
    ("fig7_uniform", "minrtt"): (0.003545186879999992, 0.003505131276799992),
    ("fig7_sparse04", "rails"): (0.004048503359999993, 0.004048503359999993),
    ("fig7_sparse04", "minrtt"): (0.016128098879999858, 0.016128098879999858),
    ("fig10_sender_skew", "rails"): (0.0001055615595599958, 0.0001055615595599958),
    ("fig10_sender_skew", "minrtt"): (0.00011763329834048856, 0.00011194643800584195),
    ("fig11_receiver_skew", "rails"): (0.00011315713061554098, 0.00011315713061554098),
    ("fig11_receiver_skew", "minrtt"): (0.0002741650942783958, 0.0002351468027895001),
    ("fig12_mixtral_dense", "rails"): (0.001093252904228253, 0.0010531973010282534),
    ("fig12_mixtral_dense", "minrtt"): (0.0011193264966712208, 0.0011047163247448037),
    ("fig13_mixtral_sparse", "rails"): (0.0011282140796018043, 0.001111389393940728),
    ("fig13_mixtral_sparse", "minrtt"): (0.003256978630302309, 0.0032202310006412323),
}


def _workloads():
    return {
        "fig7_uniform": uniform_workload(M, N, bytes_per_pair=B),
        "fig7_sparse04": sparse_topk_workload(
            M, N, sparsity=0.4, bytes_per_pair=B, seed=1
        ),
        "fig10_sender_skew": sender_skew_workload(M, N, total_bytes=B * 16, seed=1),
        "fig11_receiver_skew": receiver_skew_workload(M, N, total_bytes=B * 16, seed=1),
        "fig12_mixtral_dense": mixtral_trace_workload(
            M, N, phase="stable", mode="dense", seed=2
        ),
        "fig13_mixtral_sparse": mixtral_trace_workload(
            M, N, phase="stable", mode="sparse", seed=2
        ),
    }


@pytest.mark.parametrize("policy", ["rails", "minrtt"])
def test_golden_cct_parity(policy):
    """Coalescing-off DES == pre-rewrite CCTs, exactly, on fig7–13.

    ``backend="event"`` explicitly: these goldens guard ``events.py``
    (the offline default is the vector backend, whose own parity suite is
    ``test_fastsim.py``).
    """
    for name, tm in _workloads().items():
        m = run_collective(tm, policy, chunk_bytes=CHUNK, seed=3, backend="event")
        makespan, p99 = GOLDEN[(name, policy)]
        assert m.makespan == makespan, (name, policy)
        assert m.cct["p99"] == p99, (name, policy)


@pytest.mark.parametrize("policy", ["rails", "minrtt"])
def test_golden_cct_parity_with_constant_fault_spec(policy):
    """The link-dynamics layer costs nothing when inactive: attaching a
    FaultSpec of all-constant profiles (no PFC/ECN/loss) must leave every
    golden CCT bit-identical — the engine never enters its dynamic loop."""
    from repro.netsim import FaultSpec

    spec = FaultSpec(rail_profiles={n: 1.0 for n in range(N)})
    assert spec.is_static
    for name, tm in _workloads().items():
        m = run_collective(
            tm, policy, chunk_bytes=CHUNK, seed=3, backend="event", fault_spec=spec
        )
        makespan, p99 = GOLDEN[(name, policy)]
        assert m.makespan == makespan, (name, policy)
        assert m.cct["p99"] == p99, (name, policy)


@pytest.mark.parametrize("policy", ["rails", "minrtt"])
def test_streaming_bitmatches_oneshot_at_t0(policy):
    for name, tm in _workloads().items():
        off = run_collective(tm, policy, chunk_bytes=CHUNK, seed=3, backend="event")
        st = run_streaming_collective(tm, policy, chunk_bytes=CHUNK, seed=3)
        assert st.metrics.makespan == off.makespan, (name, policy)
        assert st.metrics.cct == off.cct, (name, policy)


def test_coalescing_conserves_bytes_and_approximates_cct():
    tm = uniform_workload(M, N, bytes_per_pair=B)
    exact = run_collective(tm, "rails", chunk_bytes=CHUNK)
    merged = run_collective(tm, "rails", chunk_bytes=CHUNK, coalesce=True)
    np.testing.assert_allclose(merged.nic_tx.sum(), tm.total_bytes(), rtol=1e-9)
    np.testing.assert_allclose(merged.nic_rx.sum(), tm.total_bytes(), rtol=1e-9)
    # Coalescing is an approximation: makespan stays within 10% here.
    assert abs(merged.makespan / exact.makespan - 1) < 0.10


def test_coalescing_exact_when_lanes_have_one_chunk():
    # One chunk per (sender, path) lane -> nothing merges -> exact equality.
    tm = uniform_workload(2, 2, bytes_per_pair=CHUNK)
    exact = run_collective(tm, "rails", chunk_bytes=CHUNK)
    merged = run_collective(tm, "rails", chunk_bytes=CHUNK, coalesce=True)
    assert merged.makespan == exact.makespan
    assert merged.cct == exact.cct


def test_streaming_coalescing_conserves_bytes():
    tms = [uniform_workload(M, N, bytes_per_pair=B / 4) for _ in range(3)]
    stream = [(i * 1e-4, tm) for i, tm in enumerate(tms)]
    res = run_streaming_collective(stream, "rails-online", chunk_bytes=CHUNK, coalesce=True)
    total = sum(tm.total_bytes() for tm in tms)
    np.testing.assert_allclose(res.metrics.nic_tx.sum(), total, rtol=1e-9)
    assert res.metrics.makespan > 0


# -- empty-result guards ------------------------------------------------------


def test_simresult_empty_guards():
    empty = SimResult(jobs=[], link_bytes={}, makespan=0.0, flow_cct={})
    pcts = empty.cct_percentiles()
    assert pcts["mean"] == 0.0 and pcts["p99"] == 0.0 and pcts["max"] == 0.0
    assert empty.round_completion_times() == {}
