"""scripts/perf_report.py must tolerate partial result dirs (satellite):
missing roofline blocks, absent dominant keys, and zero baselines used to
KeyError / ZeroDivisionError."""

import importlib.util
import json
import sys
from pathlib import Path

_SPEC = importlib.util.spec_from_file_location(
    "perf_report", Path(__file__).parent.parent / "scripts" / "perf_report.py"
)
perf_report = importlib.util.module_from_spec(_SPEC)
sys.modules["perf_report"] = perf_report
_SPEC.loader.exec_module(perf_report)


def _write(outdir: Path, stem: str, doc: dict) -> None:
    (outdir / f"{stem}.json").write_text(json.dumps(doc))


def test_report_handles_partial_and_zero_rooflines(tmp_path, capsys):
    # Healthy cell: base + one variant.
    _write(tmp_path, "a__s__x", {
        "status": "ok", "arch": "a", "shape": "s",
        "roofline": {"compute_s": 1.0, "memory_s": 0.5, "collective_s": 0.2,
                     "dominant": "compute_s"},
        "memory": {"peak_estimate_gib": 1.5},
    })
    _write(tmp_path, "a__s__x__fast", {
        "status": "ok", "arch": "a", "shape": "s",
        "roofline": {"compute_s": 0.8, "memory_s": 0.5, "collective_s": 0.2,
                     "dominant": "compute_s"},
        "memory": {"peak_estimate_gib": 1.4},
    })
    # Base with a zero dominant value (would ZeroDivisionError).
    _write(tmp_path, "b__s__x", {
        "status": "ok", "arch": "b", "shape": "s",
        "roofline": {"compute_s": 0.0, "memory_s": 0.0, "collective_s": 0.0,
                     "dominant": "compute_s"},
        "memory": {"peak_estimate_gib": 0.0},
    })
    _write(tmp_path, "b__s__x__v", {
        "status": "ok", "arch": "b", "shape": "s",
        "roofline": {"compute_s": 0.1, "memory_s": 0.0, "collective_s": 0.0,
                     "dominant": "compute_s"},
        "memory": {"peak_estimate_gib": 0.1},
    })
    # Base missing the roofline block entirely (would KeyError).
    _write(tmp_path, "c__s__x", {"status": "ok", "arch": "c", "shape": "s"})
    _write(tmp_path, "c__s__x__v", {
        "status": "ok", "arch": "c", "shape": "s",
        "roofline": {"compute_s": 0.1, "memory_s": 0.1, "collective_s": 0.1,
                     "dominant": "compute_s"},
        "memory": {"peak_estimate_gib": 0.1},
    })
    perf_report.main(str(tmp_path))  # must not raise
    out = capsys.readouterr().out
    assert "a__s" in out and "-20.0%" in out
    assert "b__s" in out and "n/a" in out
    assert "c__s" in out
