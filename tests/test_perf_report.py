"""scripts/perf_report.py must tolerate partial result dirs (satellite):
missing roofline blocks, absent dominant keys, and zero baselines used to
KeyError / ZeroDivisionError. The netsim trajectory mode must key rows by
(bench, backend, size) so event and vector measurements of one benchmark
never overwrite each other."""

import importlib.util
import json
import sys
from pathlib import Path

_SPEC = importlib.util.spec_from_file_location(
    "perf_report", Path(__file__).parent.parent / "scripts" / "perf_report.py"
)
perf_report = importlib.util.module_from_spec(_SPEC)
sys.modules["perf_report"] = perf_report
_SPEC.loader.exec_module(perf_report)


def _write(outdir: Path, stem: str, doc: dict) -> None:
    (outdir / f"{stem}.json").write_text(json.dumps(doc))


def test_report_handles_partial_and_zero_rooflines(tmp_path, capsys):
    # Healthy cell: base + one variant.
    _write(tmp_path, "a__s__x", {
        "status": "ok", "arch": "a", "shape": "s",
        "roofline": {"compute_s": 1.0, "memory_s": 0.5, "collective_s": 0.2,
                     "dominant": "compute_s"},
        "memory": {"peak_estimate_gib": 1.5},
    })
    _write(tmp_path, "a__s__x__fast", {
        "status": "ok", "arch": "a", "shape": "s",
        "roofline": {"compute_s": 0.8, "memory_s": 0.5, "collective_s": 0.2,
                     "dominant": "compute_s"},
        "memory": {"peak_estimate_gib": 1.4},
    })
    # Base with a zero dominant value (would ZeroDivisionError).
    _write(tmp_path, "b__s__x", {
        "status": "ok", "arch": "b", "shape": "s",
        "roofline": {"compute_s": 0.0, "memory_s": 0.0, "collective_s": 0.0,
                     "dominant": "compute_s"},
        "memory": {"peak_estimate_gib": 0.0},
    })
    _write(tmp_path, "b__s__x__v", {
        "status": "ok", "arch": "b", "shape": "s",
        "roofline": {"compute_s": 0.1, "memory_s": 0.0, "collective_s": 0.0,
                     "dominant": "compute_s"},
        "memory": {"peak_estimate_gib": 0.1},
    })
    # Base missing the roofline block entirely (would KeyError).
    _write(tmp_path, "c__s__x", {"status": "ok", "arch": "c", "shape": "s"})
    _write(tmp_path, "c__s__x__v", {
        "status": "ok", "arch": "c", "shape": "s",
        "roofline": {"compute_s": 0.1, "memory_s": 0.1, "collective_s": 0.1,
                     "dominant": "compute_s"},
        "memory": {"peak_estimate_gib": 0.1},
    })
    perf_report.main(str(tmp_path))  # must not raise
    out = capsys.readouterr().out
    assert "a__s" in out and "-20.0%" in out
    assert "b__s" in out and "n/a" in out
    assert "c__s" in out


def _bench_doc(rev: str, rows: list[dict]) -> dict:
    return {"schema": 1, "git_rev": rev, "rows": rows}


def test_netsim_trajectory_keys_by_bench_backend_size(tmp_path, capsys):
    """Event and vector rows of one bench — and one bench at two sizes —
    must occupy distinct trajectory rows, across multiple snapshots."""
    a = tmp_path / "a.json"
    b = tmp_path / "b.json"
    a.write_text(json.dumps(_bench_doc("rev_a", [
        {"name": "scale_nodes512_chunks100000_event", "us_per_call": 2_000_000.0,
         "derived": "46kchunks_per_s", "bench": "scale", "backend": "event",
         "size": 100_000},
        {"name": "scale_nodes512_chunks100000_vector", "us_per_call": 150_000.0,
         "derived": "660kchunks_per_s", "bench": "scale", "backend": "vector",
         "size": 100_000},
        {"name": "scale_nodes512_chunks1000000_vector", "us_per_call": 440_000.0,
         "derived": "2276kchunks_per_s", "bench": "scale", "backend": "vector",
         "size": 1_000_000},
        # pre-metadata snapshot row: falls back to the full name as key
        {"name": "lp_eq24_simplex_M4N4", "us_per_call": 10.0, "derived": "x"},
    ])))
    b.write_text(json.dumps(_bench_doc("rev_b", [
        {"name": "scale_nodes512_chunks100000_vector", "us_per_call": 140_000.0,
         "derived": "714kchunks_per_s", "bench": "scale", "backend": "vector",
         "size": 100_000},
    ])))
    perf_report.netsim_trajectory([str(a), str(b)])
    out = capsys.readouterr().out
    lines = [ln for ln in out.splitlines() if ln.startswith("| scale |")]
    # 3 distinct (bench, backend, size) rows — nothing overwritten.
    assert len(lines) == 3
    assert any("| event | 100000 |" in ln for ln in lines)
    assert any("| vector | 100000 |" in ln for ln in lines)
    assert any("| vector | 1000000 |" in ln for ln in lines)
    # both snapshots appear as columns; missing cells render n/a
    assert "rev_a" in out and "rev_b" in out
    vec_row = next(ln for ln in lines if "| vector | 100000 |" in ln)
    assert "660kchunks_per_s" in vec_row and "714kchunks_per_s" in vec_row
    assert "lp_eq24_simplex_M4N4" in out


def test_slo_prefix_filters_control_plane_grid(tmp_path, capsys):
    """--slo (bench_prefix='slo_') must keep only serving-SLO grid rows."""
    a = tmp_path / "a.json"
    a.write_text(json.dumps(_bench_doc("rev_a", [
        {"name": "slo_g0.0002_dead1_ordering", "us_per_call": 1_000_000.0,
         "derived": "admission=28.76x_brownout=20.43x_nocontrol_goodput",
         "bench": "slo_g0.0002_dead1", "backend": "vector", "size": None},
        {"name": "slo_g0.0002_dead1_nocontrol", "us_per_call": 600_000.0,
         "derived": "goodput=132.0rps_shed=0.000_att=0.050_brownout_w=0"},
        {"name": "serve_r500_none_rails", "us_per_call": 50_000.0,
         "derived": "p99=1.2ms", "bench": "serve_r500_none", "backend": "event",
         "size": None},
    ])))
    perf_report.netsim_trajectory([str(a)], bench_prefix="slo_")
    out = capsys.readouterr().out
    assert "slo_g0.0002_dead1" in out
    assert "admission=28.76x" in out
    assert "serve_r500" not in out
