"""Expert placement × spraying co-optimization (`repro.placement`).

Three layers of pins:

* **Bit-exactness** — the static round-robin placement must reproduce the
  pre-placement pipeline byte for byte (the CI placement-off parity gate):
  the refactor moved layout into one spot without changing any default
  output.
* **Search wins** — greedy and LP candidates achieve strictly lower
  simulated CCT than round-robin on a seeded skewed-gating workload (the
  reshape-the-matrix claim of LAER-MoE/MicroMoE applied to RailS).
* **Controller economics** — the online controller migrates under a drift
  step and nets positive (CCT savings − migration cost) over the trace,
  with the migration bytes riding the simulated fabric.
"""

import numpy as np
import pytest

from repro.core.traffic import (
    default_expert_shard,
    drifting_expert_counts,
    drifting_gating_stream,
    expert_counts_to_matrix,
    moe_gating_traffic,
)
from repro.placement import (
    OnlinePlacementController,
    Placement,
    RelayoutConfig,
    as_shard_expert_counts,
    greedy_placement,
    lp_placement,
    placement_bound,
    placement_loads,
    run_relayout_trace,
    score_placement,
    search_placement,
    static_placement,
)
from repro.sched.online import GatingFeedbackHook
from repro.sched.pipeline import run_pipeline

M, N, E = 4, 4, 8
BPT = 2048.0


def skewed_counts(seed=3, rounds=1, drift=0.3):
    counts, _ = drifting_expert_counts(
        M, E, rounds, 8192, popularity_alpha=1.2, drift=drift,
        sender_alpha=0.8, seed=seed,
    )
    return counts


# ---------------------------------------------------------------------------
# state: counts normalization, Placement invariants, migration cost
# ---------------------------------------------------------------------------


class TestState:
    def test_as_shard_expert_counts_expands_flat(self):
        flat = np.arange(1.0, float(E) + 1.0)
        se = as_shard_expert_counts(flat, M)
        assert se.shape == (M, E)
        # Uniform-sender convention: every row carries T_e / (M - 1).
        np.testing.assert_allclose(se, np.tile(flat / (M - 1), (M, 1)))

    def test_as_shard_expert_counts_passthrough_and_shape_check(self):
        se = np.ones((M, E))
        assert as_shard_expert_counts(se, M) is not None
        np.testing.assert_array_equal(as_shard_expert_counts(se, M), se)
        with pytest.raises(ValueError, match="rows"):
            as_shard_expert_counts(np.ones((M + 1, E)), M)

    def test_round_robin_matches_default_map(self):
        pl = Placement.round_robin(E, M)
        np.testing.assert_array_equal(pl.expert_shard, default_expert_shard(E, M))
        np.testing.assert_array_equal(pl.shard_expert_counts(), [2, 2, 2, 2])

    def test_placement_validation(self):
        with pytest.raises(ValueError):
            Placement(np.array([0, M]), M)  # shard index out of range
        with pytest.raises(ValueError):
            Placement(np.array([], dtype=np.int64), M)
        with pytest.raises(ValueError):
            Placement(np.array([0, 1]), M, weight_bytes=-1.0)

    def test_placement_immutable(self):
        pl = Placement.round_robin(E, M)
        with pytest.raises(ValueError):
            pl.expert_shard[0] = 1

    def test_move_and_swap(self):
        pl = Placement.round_robin(E, M)
        moved = pl.move(0, 3)
        assert moved.expert_shard[0] == 3 and pl.expert_shard[0] == 0
        swapped = pl.swap(0, 1)
        assert swapped.expert_shard[0] == 1 and swapped.expert_shard[1] == 0

    def test_migration_to_flows_and_total(self):
        wb = np.arange(1.0, E + 1.0) * 1e6
        pl = Placement.round_robin(E, M, wb)
        same, total = pl.migration_to(pl)
        assert total == 0.0 and same.sum() == 0.0
        dst = pl.move(0, 3).move(5, 2)  # expert 0: shard 0->3, expert 5: 1->2
        mig, total = pl.migration_to(dst)
        assert mig[0, 3] == wb[0]
        assert mig[1, 2] == wb[5]
        assert total == wb[0] + wb[5] == mig.sum()

    def test_migration_to_mismatch_raises(self):
        pl = Placement.round_robin(E, M)
        with pytest.raises(ValueError):
            pl.migration_to(Placement.round_robin(E, M + 1))
        with pytest.raises(ValueError):
            pl.migration_to(Placement.round_robin(E + 2, M))

    def test_placement_loads_match_d2(self):
        c = skewed_counts()[0]
        pl = Placement.round_robin(E, M)
        egress, ingress = placement_loads(c, pl)
        d2 = pl.counts_d2(c)
        np.testing.assert_allclose(egress, d2.sum(axis=1))
        np.testing.assert_allclose(ingress, d2.sum(axis=0))

    def test_traffic_injects_migration_bytes(self):
        c = skewed_counts()[0]
        pl = Placement.round_robin(E, M, 1e6)
        mig, total = pl.migration_to(pl.move(0, 3))
        base = pl.traffic(c, BPT, N)
        with_mig = pl.traffic(c, BPT, N, migration_d2=mig)
        np.testing.assert_allclose(
            with_mig.total_bytes() - base.total_bytes(), total
        )


# ---------------------------------------------------------------------------
# static placement is bit-exact with the pre-placement pipeline
# ---------------------------------------------------------------------------


class TestStaticBitExact:
    def test_counts_d2_flat_counts_bit_exact(self):
        rng = np.random.default_rng(0)
        flat = rng.integers(0, 5000, size=E).astype(np.float64)
        got = Placement.round_robin(E, M).counts_d2(flat)
        want = expert_counts_to_matrix(flat, M)
        assert np.array_equal(got, want)

    def test_drifting_stream_explicit_round_robin_bit_exact(self):
        default = drifting_gating_stream(M, N, 5, 4096.0, seed=7)
        explicit = drifting_gating_stream(
            M, N, 5, 4096.0, seed=7, expert_shard=default_expert_shard(8, M)
        )
        for tm_d, tm_e in zip(default, explicit):
            assert np.array_equal(tm_d.d2, tm_e.d2)
            assert np.array_equal(tm_d.d1, tm_e.d1)

    def test_hook_round_robin_placement_is_identity(self):
        rng = np.random.default_rng(1)
        legacy = GatingFeedbackHook(M, N, BPT)
        placed = GatingFeedbackHook(M, N, BPT, placement=Placement.round_robin(E, M))
        for _ in range(4):
            flat = rng.integers(100, 5000, size=E).astype(np.float64)
            assert legacy.on_step(flat) == placed.on_step(flat)

    def test_static_relayout_trace_matches_plain_pipeline(self):
        """The CI placement-off parity gate: mode='static' must equal the
        hand-built round-robin lowering through run_pipeline exactly."""
        counts = skewed_counts(rounds=4)
        res = run_relayout_trace(
            counts, M, N, BPT, mode="static", chunk_bytes=64 * 2**10
        )
        tms = [
            moe_gating_traffic(expert_counts_to_matrix(c, M), BPT, N)
            for c in counts
        ]
        plain = run_pipeline(
            tms, chunk_bytes=64 * 2**10, releases=res.pipeline.releases
        )
        assert res.makespan == plain.makespan
        assert res.migration_bytes == 0.0
        assert res.pipeline.releases == plain.releases


# ---------------------------------------------------------------------------
# search: greedy/LP beat round-robin on skewed gating
# ---------------------------------------------------------------------------


class TestSearch:
    def test_greedy_and_lp_beat_round_robin_cct(self):
        c = skewed_counts()[0]
        rr = Placement.round_robin(E, M)
        s_rr = score_placement(c, rr, N, BPT)
        s_g = score_placement(c, greedy_placement(c, M), N, BPT)
        s_lp = score_placement(c, lp_placement(c, M), N, BPT)
        assert s_g < s_rr
        assert s_lp < s_rr

    def test_bounds_never_worse_than_round_robin(self):
        for seed in range(5):
            c = skewed_counts(seed=seed)[0]
            b_rr = placement_bound(c, Placement.round_robin(E, M), N, BPT)
            b_g = placement_bound(c, greedy_placement(c, M), N, BPT)
            assert b_g <= b_rr + 1e-12

    def test_capacity_respected(self):
        c = skewed_counts()[0]
        for pl in (greedy_placement(c, M), lp_placement(c, M)):
            assert pl.shard_expert_counts().max() <= -(-E // M)
        tight = greedy_placement(c, M, capacity=E // M)
        assert tight.shard_expert_counts().max() <= E // M

    def test_capacity_too_small_raises(self):
        c = skewed_counts()[0]
        with pytest.raises(ValueError, match="capacity"):
            greedy_placement(c, M, capacity=1)
        with pytest.raises(ValueError, match="capacity"):
            lp_placement(c, M, capacity=1)

    def test_lp_zero_counts_yields_valid_even_layout(self):
        # Degenerate all-zero gating: any capacity-respecting layout is
        # optimal (t* = 0); the rounding must still produce a valid one.
        pl = lp_placement(np.zeros((M, E)), M)
        assert pl.shard_expert_counts().max() <= -(-E // M)
        assert placement_bound(np.zeros((M, E)), pl, N, BPT) == 0.0

    def test_search_placement_dispatch(self):
        c = skewed_counts()[0]
        cand = search_placement(c, M, N, BPT, method="static", score=False)
        np.testing.assert_array_equal(
            cand.placement.expert_shard, static_placement(E, M).expert_shard
        )
        assert np.isnan(cand.cct_s)
        scored = search_placement(c, M, N, BPT, method="greedy")
        assert scored.cct_s > 0 and scored.bound_s > 0
        with pytest.raises(ValueError, match="method"):
            search_placement(c, M, N, BPT, method="anneal")


# ---------------------------------------------------------------------------
# controller: hysteresis, amortization, net-positive drift response
# ---------------------------------------------------------------------------


def drift_step_counts(rounds_a=4, rounds_b=8, tokens=8192.0):
    """Stable skew, then a step: the hot pair jumps onto colliding shards.

    Phase A's hot experts (0, 1) live on different shards under round-robin
    (nothing for placement to fix); at the step the heat moves to experts
    (0, 4), which round-robin co-locates on shard 0 — the collision only a
    re-layout can resolve.
    """
    pop_a = np.array([10.0, 10.0, 1.0, 1.0, 1.0, 1.0, 1.0, 1.0])
    pop_b = np.array([10.0, 1.0, 1.0, 1.0, 10.0, 1.0, 1.0, 1.0])
    sender = np.ones(4)
    mk = lambda pop: tokens * np.outer(
        sender / sender.sum(), pop / pop.sum()
    )
    return [mk(pop_a)] * rounds_a + [mk(pop_b)] * rounds_b


class TestController:
    def test_uniform_counts_never_migrate(self):
        ctl = OnlinePlacementController(
            Placement.round_robin(E, M, 1e6), N, BPT
        )
        for _ in range(6):
            dec = ctl.observe(np.full((M, E), 100.0))
            assert not dec.migrated
        assert ctl.total_migration_bytes == 0.0

    def test_huge_weights_block_migration(self):
        """Amortization gate: weights too heavy to pay back over the horizon."""
        ctl = OnlinePlacementController(
            Placement.round_robin(E, M, 1e18), N, BPT,
            config=RelayoutConfig(horizon=2.0),
        )
        for c in drift_step_counts():
            dec = ctl.observe(c)
            assert not dec.migrated

    def test_cooldown_suppresses_back_to_back_searches(self):
        cfg = RelayoutConfig(cooldown=3)
        ctl = OnlinePlacementController(
            Placement.round_robin(E, M, 1e5), N, BPT, config=cfg
        )
        fired = None
        for i, c in enumerate(drift_step_counts()):
            if ctl.observe(c).migrated:
                fired = i
                break
        assert fired is not None
        for c in drift_step_counts()[fired + 1 : fired + 1 + cfg.cooldown]:
            dec = ctl.observe(c)
            assert not dec.migrated
            assert dec.candidate_bound_s == dec.current_bound_s  # no search ran

    def test_drift_step_migrates_and_nets_positive(self):
        """The acceptance pin: a drift step triggers migration and the trace
        CCT (migration bytes included) beats spraying-only static."""
        counts = drift_step_counts()
        static = run_relayout_trace(
            counts, M, N, BPT, mode="static", chunk_bytes=64 * 2**10
        )
        online = run_relayout_trace(
            counts, M, N, BPT, mode="online", weight_bytes=2e6,
            chunk_bytes=64 * 2**10,
        )
        assert online.num_migrations >= 1
        assert online.migration_bytes > 0
        # The migration is a *response to the step*, not a round-0 fixup.
        assert all(not d.migrated for d in online.decisions[:4])
        # Net positive: savings already account for migration traffic,
        # which rides the simulated fabric inside the online arm.
        assert online.makespan < static.makespan

    def test_one_shot_modes_beat_static_on_stable_skew(self):
        counts = skewed_counts(rounds=4, drift=0.02)
        mk = lambda mode: run_relayout_trace(
            counts, M, N, BPT, mode=mode, weight_bytes=2e6,
            chunk_bytes=64 * 2**10,
        )
        static, greedy, lp = mk("static"), mk("greedy"), mk("lp")
        assert greedy.makespan < static.makespan
        assert lp.makespan < static.makespan
        assert greedy.migration_bytes > 0  # the re-layout itself was priced

    def test_relayout_trace_rejects_unknown_mode(self):
        with pytest.raises(ValueError, match="mode"):
            run_relayout_trace(
                drift_step_counts(1, 1), M, N, BPT, mode="magic"
            )

    def test_relayout_config_validation(self):
        with pytest.raises(ValueError):
            RelayoutConfig(alpha=0.0)
        with pytest.raises(ValueError):
            RelayoutConfig(check_every=0)
        with pytest.raises(ValueError):
            RelayoutConfig(horizon=0.0)
        with pytest.raises(ValueError):
            RelayoutConfig(method="anneal")


# ---------------------------------------------------------------------------
# hook integration: real (M, E) counts, forecast error, migrations
# ---------------------------------------------------------------------------


class TestHookIntegration:
    def test_hook_accepts_shard_expert_matrix(self):
        hook = GatingFeedbackHook(M, N, BPT)
        out = hook.on_step(skewed_counts()[0])
        assert out["total_bytes"] > 0
        assert not out["migrated"]

    def test_forecast_error_tracks_drift_rate(self):
        errs = {}
        for drift in (0.02, 0.6):
            counts, _ = drifting_expert_counts(
                M, E, 10, 8192, drift=drift, sender_alpha=0.8, seed=5
            )
            hook = GatingFeedbackHook(M, N, BPT)
            series = [hook.on_step(c)["forecast_err"] for c in counts]
            errs[drift] = float(np.mean(series[2:]))  # skip cold-start
        assert errs[0.6] > errs[0.02]

    def test_hook_with_controller_migrates_and_reports(self):
        ctl = OnlinePlacementController(
            Placement.round_robin(E, M, 1e5), N, BPT
        )
        hook = GatingFeedbackHook(M, N, BPT, controller=ctl)
        outs = [hook.on_step(c) for c in drift_step_counts()]
        migrated = [o for o in outs if o["migrated"]]
        assert migrated
        assert migrated[0]["migration_bytes"] > 0
        # The hook's placement tracks the controller's.
        np.testing.assert_array_equal(
            hook.placement.expert_shard, ctl.placement.expert_shard
        )
